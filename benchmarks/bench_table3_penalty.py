"""Paper Table 3: underutilization penalty + latency tails for the dense
reference configuration (C1)."""
from benchmarks.common import CONFIGS, emit, sweep_config


def run(quick: bool = False):
    recs = sweep_config(CONFIGS[0], n_scale=0.4 if quick else 1.0)
    rows = [{
        "lam": r.lam,
        "ttft_p50_ms": r.ttft_p50_ms, "ttft_p99_ms": r.ttft_p99_ms,
        "tpot_p99_ms": r.tpot_p99_ms,
        "c_eff": r.c_eff, "penalty": r.penalty,
    } for r in recs]
    emit("table3_penalty", rows)
    return rows


if __name__ == "__main__":
    run()
