"""Paper Table 4 / Fig.4: cost of honoring a fixed SLA
(TTFT p99 <= 300 ms, TPOT p99 <= 50 ms) vs the unconstrained floor."""
from repro.core import slo_operating_point

from benchmarks.common import CONFIGS, emit, sweep_config


def run(quick: bool = False, ttft_ms: float = 300.0, tpot_ms: float = 50.0):
    rows = []
    for bc in CONFIGS:
        recs = sweep_config(bc, n_scale=0.4 if quick else 1.0)
        res = slo_operating_point(recs, ttft_p99_ms=ttft_ms,
                                  tpot_p99_ms=tpot_ms)
        rows.append({
            "config": bc.cid, "arch": bc.arch, "quant": bc.quant,
            "sla_lam_max": res.lam_max if res.lam_max is not None else "none",
            "c_at_sla": res.c_at_sla, "c_sat": res.c_sat,
            "sat_lam": res.sat_lam,
            "sat_ttft_p99_ms": res.sat_ttft_p99_ms,
            "premium": res.premium, "sat_sla_feasible": res.sat_feasible,
        })
    emit("table4_sla", rows)
    return rows


if __name__ == "__main__":
    run()
