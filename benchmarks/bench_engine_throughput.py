"""Engine scheduler throughput: the fast-forward (event-driven) clock vs
the per-token reference loop on the sim tier (ISSUE 1 perf trajectory).

Reported per (lambda, mode): wall seconds for the measured point,
simulated-requests-per-wall-second, scheduler-steps-per-second (simulated
decode steps retired per wall second), iterations, fast-forward jumps,
and the speedup vs the step-by-step baseline. Target: >=10x on the
lambda=200 chat-shape paper-scale point. Timings are medians over
`REPS` interleaved repetitions (the request-synthesis cost is excluded —
this benchmark tracks the scheduler, not workload generation).
"""
from __future__ import annotations

import json
import statistics
import time
from pathlib import Path

from repro.core.sweep import SimEngineSpec
from repro.serving import ArrivalSpec, synth_requests

from benchmarks.common import emit

BENCH_JSON = Path(__file__).resolve().parent.parent / \
    "BENCH_engine_throughput.json"

REPS = 3
# (lambda, paper-scale request count): 60*lam clamped [500, 6000] (§5.8)
POINTS = ((5, 500), (50, 3000), (200, 6000))


def _factory(fast_forward: bool) -> SimEngineSpec:
    return SimEngineSpec("llama31-8b", hw="tpu-v5p", max_batch=256,
                         num_pages=131072, max_pages_per_seq=512,
                         prefill_token_budget=8192,
                         fast_forward=fast_forward)


def _measure(fast_forward: bool, lam: float, n_requests: int):
    walls, eng = [], None
    for _ in range(REPS):
        eng = _factory(fast_forward)()
        reqs = synth_requests(ArrivalSpec(lam=lam, n_requests=n_requests,
                                          seed=0))
        t0 = time.perf_counter()
        eng.run(reqs)
        walls.append(time.perf_counter() - t0)
    done = eng.metrics.get("repro:request_success_total")
    return statistics.median(walls), done, eng


def run(quick: bool = False):
    rows = []
    for lam, n in POINTS:
        if quick:
            n = max(300, n // 4)
        wall = {}
        for ff in (False, True):
            w, done, eng = _measure(ff, lam, n)
            wall[ff] = w
            rows.append({
                "lam": lam, "n_requests": n,
                "mode": "fast_forward" if ff else "reference",
                "wall_s": w,
                "sim_req_per_wall_s": done / w,
                "sched_steps_per_s": eng.n_decode_steps / w,
                "iterations": eng.n_iterations,
                "ff_jumps": eng.n_ff_jumps,
                "speedup_vs_reference": wall[False] / w,
            })
    emit("engine_throughput", rows)
    worst = min(r["speedup_vs_reference"] for r in rows
                if r["mode"] == "fast_forward" and r["lam"] == 200)
    BENCH_JSON.write_text(json.dumps(
        {"bench": "engine_throughput", "quick": quick,
         "lambda200_speedup_vs_reference": worst, "target": 10.0,
         "rows": rows}, indent=2) + "\n")
    print(f"# lambda=200 fast-forward speedup: {worst:.1f}x "
          f"(target >=10x); trajectory -> {BENCH_JSON.name}")


if __name__ == "__main__":
    run()
