"""Resilience layer cost (ISSUE 6). Informational only, no CI gate.

Three questions an operator (and the acceptance bar) cares about:

* `off-overhead` — the zero-cost-when-off claim, measured: the same
  engine run with no resilience arguments vs with *disabled*
  FailureSpec/RetryPolicy objects threaded through. The wall-clock
  ratio should be ~1.0 and the records bit-identical.
* `chaos-throughput` — simulated-seconds-per-wall-second with the full
  failure/retry/shed/deadline machinery active, vs the failure-free
  run: what injecting chaos costs the *simulator* (the paper's cost
  numbers come from sim throughput, so this bounds grid runtimes).
* `reliability-analysis` — `reliability_tables` + availability-priced
  `plan_capacity` over the committed `paper_resilience` store: the
  interactive planning surface under an availability target.
"""
import dataclasses
import time

from benchmarks.common import emit
from repro.core.sweep import SimEngineSpec, run_point
from repro.experiments.analyze import (load_store_records,
                                       reliability_tables)
from repro.planner import AvailabilityTarget, fit_curves, plan_capacity
from repro.serving import ArrivalSpec
from repro.serving.resilience import FailureSpec, RetryPolicy


def _timed(fn, n):
    best, out = float("inf"), None
    for _ in range(n):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def run(quick: bool = False):
    n = 2 if quick else 4
    n_req = 300 if quick else 1000
    fac = SimEngineSpec("llama31-8b", max_batch=64, num_pages=16384)
    guarded_fac = dataclasses.replace(fac, max_queue_depth=512,
                                      deadline_s=30.0)
    spec = ArrivalSpec(lam=25, n_requests=n_req, seed=0)
    kw = dict(config="C1", model="llama31-8b", hw="tpu-v5e")

    rows = []
    t_off, rec_off = _timed(lambda: run_point(fac, spec, **kw), n)
    t_guard, rec_guard = _timed(
        lambda: run_point(fac, spec,
                          failure_spec=FailureSpec(mttf=0.0, seed=1),
                          retry=RetryPolicy(max_attempts=0, seed=2), **kw),
        n)
    assert dataclasses.asdict(rec_off) == dataclasses.asdict(rec_guard), \
        "disabled resilience objects perturbed the record"
    rows.append({"case": "off-overhead", "wall_s": t_guard,
                 "baseline_s": t_off, "ratio": t_guard / t_off,
                 "sim_s_per_wall_s": rec_off.window_s / t_off,
                 "n_retried": 0, "c_eff": rec_off.c_eff})

    t_chaos, rec_chaos = _timed(
        lambda: run_point(
            guarded_fac, spec,
            failure_spec=FailureSpec(mttf=8.0, mttr=1.0, seed=3),
            retry=RetryPolicy(max_attempts=3, base_delay_s=0.25, seed=4),
            **kw),
        n)
    rows.append({"case": "chaos-throughput", "wall_s": t_chaos,
                 "baseline_s": t_off, "ratio": t_chaos / t_off,
                 "sim_s_per_wall_s": rec_chaos.window_s / t_chaos,
                 "n_retried": rec_chaos.n_retried,
                 "c_eff": rec_chaos.c_eff})

    try:
        records = load_store_records("paper_resilience")
    except OSError:
        records = []
    if records:
        t_tab, tab = _timed(lambda: reliability_tables(records), n)
        avail = AvailabilityTarget(0.999, 0.99)
        curves = fit_curves(records)
        t_plan, _ = _timed(
            lambda: [plan_capacity(curves, lam, avail=avail)
                     for lam in (5.0, 30.0, 100.0)], n)
        rows.append({"case": "reliability-analysis", "wall_s": t_tab,
                     "baseline_s": t_plan, "ratio": len(tab),
                     "sim_s_per_wall_s": float("nan"),
                     "n_retried": sum(r["n_retried"] for r in tab),
                     "c_eff": max(r["c_eff_inflation"] for r in tab)})
    else:
        print("# paper_resilience store absent; analysis section skipped")
    emit("resilience", rows)


if __name__ == "__main__":
    run(quick=True)
