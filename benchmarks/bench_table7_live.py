"""Paper Table 7 / §6.7: live validation — six-phase workload (1-50 rps
ramp and back) with the cost meter scraping Prometheus text each tick;
best/worst-minute effective cost per configuration."""
import numpy as np

from repro.core import CostMeter
from repro.serving import ArrivalSpec, synth_requests
from repro.simulate import HW_BY_NAME

from benchmarks.common import CONFIGS, emit, engine_factory

PHASES = (1, 5, 15, 50, 15, 1)            # rps per ~phase
PHASE_S = 120.0                           # seconds per phase


def run(quick: bool = False):
    hw = HW_BY_NAME["tpu-v5p"]
    phase_s = 40.0 if quick else PHASE_S
    rows = []
    for bc in CONFIGS:
        eng = engine_factory(bc)()
        price = hw.price_per_chip_hr * bc.n_chips
        meter = CostMeter(price, scrape=lambda e=eng: e.metrics.render(),
                          minute_s=60.0)
        reqs = []
        t0 = 0.0
        for i, lam in enumerate(PHASES):
            n = max(1, int(lam * phase_s))
            spec = ArrivalSpec(lam=lam, n_requests=n, seed=100 + i)
            batch = synth_requests(spec, start=t0)
            t0 = max(r.arrival_time for r in batch)
            reqs += batch
        meter.tick()
        horizon = 0.0
        while any(r.finish_time is None for r in reqs):
            horizon += 15.0
            eng.run(reqs, horizon=horizon)
            meter.tick()
            if horizon > 24 * 3600:
                break
        s = meter.summary()
        done = [r for r in reqs if r.finish_time is not None]
        rows.append({
            "config": bc.cid, "arch": bc.arch, "quant": bc.quant,
            "requests": len(reqs), "completed": len(done),
            "success_pct": 100.0 * len(done) / len(reqs),
            "best_minute": s["best_minute"],
            "worst_minute": s["worst_minute"],
            "swing": s["swing"], "avg": s["time_weighted_avg"],
        })
    emit("table7_live_meter", rows)
    return rows


if __name__ == "__main__":
    run()
