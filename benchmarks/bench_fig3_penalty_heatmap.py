"""Paper Fig.3: underutilization penalty by configuration x offered load."""
from benchmarks.common import CONFIGS, emit, sweep_config


def run(quick: bool = False):
    rows = []
    for bc in CONFIGS:
        recs = sweep_config(bc, n_scale=0.3 if quick else 1.0)
        row = {"config": bc.cid, "arch": bc.arch, "quant": bc.quant}
        for r in recs:
            row[f"penalty_lam{int(r.lam)}"] = r.penalty
        row["max_penalty"] = max(r.penalty for r in recs)
        rows.append(row)
    emit("fig3_penalty_heatmap", rows)
    return rows


if __name__ == "__main__":
    run()
