"""lambda(t) layer cost (ISSUE 8). Informational only, no CI gate.

Three timings an operator of the day-pricing pipeline cares about:

* `thinning-stream` — arrivals/s of the Lewis-Shedler thinning generator
  on a diurnal profile vs the legacy homogeneous generator at the same
  mean rate: what non-stationarity costs the stream synthesizer.
* `constant-bypass` — the byte-identity fast path: a constant profile
  must route through the legacy generator, so wrapping a stationary spec
  in a profile should cost ~nothing.
* `day-pricing` — simulate_policy + price_day over the committed
  `paper_day` scenario (every deployment x policy), and the full
  `diurnal_tables` analysis over the committed `paper_diurnal` store:
  the interactive cost of re-pricing a day.
"""
import time

import numpy as np

from benchmarks.common import emit
from repro.serving import ArrivalSpec, RateProfile, synth_arrays
from repro.serving.arrivals import profile_arrivals
from repro.serving.autoscale import PAPER_DAY, price_day


def _timed(fn, n):
    best, out = float("inf"), None
    for _ in range(n):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def run(quick: bool = False):
    n = 3 if quick else 6
    n_req = 20_000 if quick else 100_000
    rows = []

    prof = RateProfile.diurnal(trough=2.0, peak=14.0, period_s=86400.0)
    t_thin, times = _timed(
        lambda: profile_arrivals(np.random.default_rng(0), prof, n_req), n)
    t_legacy, _ = _timed(
        lambda: synth_arrays(ArrivalSpec(lam=prof.mean_rate(),
                                         n_requests=n_req, seed=0)), n)
    rows.append({"case": "thinning-stream", "n": n_req,
                 "wall_s": t_thin, "baseline_s": t_legacy,
                 "ratio": t_thin / t_legacy,
                 "arrivals_per_s": n_req / t_thin})

    spec = ArrivalSpec(lam=8.0, n_requests=n_req, seed=1)
    wrapped = ArrivalSpec(lam=8.0, n_requests=n_req, seed=1,
                          profile=RateProfile.constant(8.0))
    t_plain, _ = _timed(lambda: synth_arrays(spec), n)
    t_wrap, _ = _timed(lambda: synth_arrays(wrapped), n)
    rows.append({"case": "constant-bypass", "n": n_req,
                 "wall_s": t_wrap, "baseline_s": t_plain,
                 "ratio": t_wrap / t_plain,
                 "arrivals_per_s": n_req / t_wrap})

    def price_paper_day():
        out = 0.0
        for dep in PAPER_DAY.deployments:
            cap = dep.lam_cap
            for traj in PAPER_DAY.trajectories(dep).values():
                out += price_day(
                    traj, price_per_hr=dep.price_per_hr,
                    tps_at=lambda lam: min(lam, cap) * 256.0,
                    lam_cap=cap)["daily_cost_usd"]
        return out

    t_day, _ = _timed(price_paper_day, n)
    n_traj = len(PAPER_DAY.deployments) * (1 + len(PAPER_DAY.policies))
    rows.append({"case": "day-pricing", "n": n_traj,
                 "wall_s": t_day, "baseline_s": float("nan"),
                 "ratio": float("nan"),
                 "arrivals_per_s": n_traj / t_day})

    try:
        from repro.experiments.analyze import (diurnal_tables,
                                               load_store_records)
        records = load_store_records("paper_diurnal")
    except OSError:
        records = []
    if records:
        t_tab, tab = _timed(lambda: diurnal_tables(records), n)
        rows.append({"case": "diurnal-tables", "n": len(records),
                     "wall_s": t_tab, "baseline_s": float("nan"),
                     "ratio": float("nan"),
                     "arrivals_per_s": len(tab) / t_tab})
    else:
        print("# paper_diurnal store absent; analysis section skipped")
    emit("diurnal", rows)


if __name__ == "__main__":
    run(quick=True)
