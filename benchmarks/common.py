"""Shared benchmark scaffolding.

The paper's six H100 configurations map to the TPU hardware book
(DESIGN §3): v5p-class plays the premium part (H100 analogue), v5e the
cheap/slow part (A100 analogue). The Q axis uses int8 (TPU-native, the
role FP8 plays on H100) with fp8-emulated available for the
hardware-conditional probe.

    C1 llama31-8b   bf16  1 chip     C2 llama31-8b   int8  1 chip
    C3 qwen3-30b    bf16  1 chip     C4 qwen3-30b    int8  1 chip
    C5 mixtral-8x7b bf16  TP=2       C6 mixtral-8x7b int8  TP=2
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence

from repro.core import SimEngineSpec, lambda_sweep, parallel_sweep
from repro.core.records import RunRecord, write_csv
from repro.serving import Engine
from repro.simulate import HW_BY_NAME

RESULTS = Path(__file__).resolve().parent.parent / "results" / "bench"

LADDER = (1, 5, 10, 25, 50, 100, 200)


@dataclasses.dataclass(frozen=True)
class BenchConfig:
    cid: str
    arch: str
    quant: str
    n_chips: int


CONFIGS = (
    BenchConfig("C1", "llama31-8b", "bf16", 1),
    BenchConfig("C2", "llama31-8b", "int8", 1),
    BenchConfig("C3", "qwen3-30b-a3b", "bf16", 1),
    BenchConfig("C4", "qwen3-30b-a3b", "int8", 1),
    BenchConfig("C5", "mixtral-8x7b", "bf16", 2),
    BenchConfig("C6", "mixtral-8x7b", "int8", 2),
)


def engine_factory(bc: BenchConfig, hw_name: str = "tpu-v5p",
                   max_batch: int = 256) -> Callable[[], Engine]:
    """Picklable factory (SimEngineSpec) so any bench sweep can fan its
    ladder points across a process pool via `sweep_config(parallel=True)`."""
    return SimEngineSpec(bc.arch, hw=hw_name, quant=bc.quant,
                         n_chips=bc.n_chips, max_batch=max_batch,
                         page_size=16, num_pages=131072,
                         max_pages_per_seq=512, prefill_token_budget=8192)


def sweep_config(bc: BenchConfig, *, hw_name: str = "tpu-v5p",
                 ladder: Sequence[float] = LADDER, io_shape: str = "chat",
                 process: str = "poisson", cv: float = 1.0,
                 seed: int = 0, n_scale: float = 1.0,
                 parallel: bool = False) -> List[RunRecord]:
    hw = HW_BY_NAME[hw_name]
    driver = parallel_sweep if parallel else lambda_sweep
    return driver(
        engine_factory(bc, hw_name), ladder=ladder, io_shape=io_shape,
        process=process, cv=cv, seed=seed,
        requests_per_point=lambda lam: int(
            n_scale * min(1200, max(150, 25 * lam))),
        warmup_per_point=lambda lam: 0,
        config=bc.cid, model=bc.arch, hw=hw_name, n_chips=bc.n_chips,
        quant=bc.quant, engine_kind="sim",
        price_per_hr=hw.price_per_chip_hr * bc.n_chips)


def merge_trajectory(name: str, key: str, section: dict) -> Path:
    """Merge one section into the repo-root perf-trajectory file
    `BENCH_<name>.json` (read-merge-write, tolerating a missing or
    corrupt file) — the one place that policy lives for gated benches."""
    import json
    path = RESULTS.parent.parent / f"BENCH_{name}.json"
    blob = {}
    if path.exists():
        try:
            blob = json.loads(path.read_text())
        except json.JSONDecodeError:
            blob = {}
    blob["bench"] = name
    blob[key] = section
    path.write_text(json.dumps(blob, indent=2, sort_keys=True) + "\n")
    return path


def emit(name: str, rows: List[dict]):
    """Print benchmark rows as CSV to stdout and persist under results/."""
    RESULTS.mkdir(parents=True, exist_ok=True)
    if not rows:
        print(f"# {name}: no rows")
        return
    keys = list(rows[0].keys())
    lines = [",".join(keys)]
    for r in rows:
        lines.append(",".join(_fmt(r.get(k)) for k in keys))
    text = "\n".join(lines)
    (RESULTS / f"{name}.csv").write_text(text + "\n")
    print(f"\n## {name}")
    print(text)


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def records_rows(recs: List[RunRecord]) -> List[dict]:
    return [{
        "config": r.config, "model": r.model, "hw": r.hw, "quant": r.quant,
        "n_chips": r.n_chips, "lam": r.lam, "tps": r.tps,
        "c_eff": r.c_eff, "penalty": r.penalty, "util": r.util,
        "ttft_p50_ms": r.ttft_p50_ms, "ttft_p99_ms": r.ttft_p99_ms,
        "tpot_p99_ms": r.tpot_p99_ms, "mean_inflight": r.mean_inflight,
        "completed": r.n_completed,
    } for r in recs]
