"""Paper Table 6 / §5.9: cross-hardware validation — the load-driven
spread must reproduce on the cheap/slow part (v5e as the A100 analogue)
with compressed magnitude; the quantization advantage is hardware-
conditional (fp8 emulated on v5e inverts for the compute-bound dense
model); Result 4's TP=2 vs TP=4 inversion on Mixtral."""
from benchmarks.common import BenchConfig, emit, sweep_config


def run(quick: bool = False):
    ns = 0.3 if quick else 1.0
    rows = []
    pairs = [
        ("llama31-8b", "bf16", 1), ("llama31-8b", "int8", 1),
        ("llama31-8b", "fp8", 1),
        ("qwen3-30b-a3b", "bf16", 1), ("qwen3-30b-a3b", "fp8", 1),
        ("mixtral-8x7b", "bf16", 2),
    ]
    spreads = {}
    for arch, quant, chips in pairs:
        for hw in ("tpu-v5p", "tpu-v5e"):
            bc = BenchConfig(f"{arch[:10]}-{quant}", arch, quant, chips)
            recs = sweep_config(bc, hw_name=hw, n_scale=ns)
            cmin = min(r.c_eff for r in recs)
            spread = max(r.c_eff for r in recs) / cmin
            spreads[(arch, quant, hw)] = (cmin, spread)
            rows.append({"arch": arch, "quant": quant, "n_chips": chips,
                         "hw": hw, "c_min": cmin, "spread": spread})
    emit("table6_crosshw", rows)

    # fp8 hardware-conditionality: on v5e (emulated fp8) the dense model's
    # saturation cost should NOT improve the way the MoE's does.
    d_v5e = spreads[("llama31-8b", "fp8", "tpu-v5e")][0] / \
        spreads[("llama31-8b", "bf16", "tpu-v5e")][0]
    m_v5e = spreads[("qwen3-30b-a3b", "fp8", "tpu-v5e")][0] / \
        spreads[("qwen3-30b-a3b", "bf16", "tpu-v5e")][0]
    print(f"# fp8-emulated c_min ratio on v5e: dense {d_v5e:.3f} vs "
          f"moe {m_v5e:.3f} (moe should benefit more)")

    # Result 4: Mixtral TP=2 vs TP=4 on the cheap part
    rows4 = []
    for tp in (2, 4):
        bc = BenchConfig(f"mixtral-tp{tp}", "mixtral-8x7b", "bf16", tp)
        recs = sweep_config(bc, hw_name="tpu-v5e", ladder=(25, 50, 100, 200),
                            n_scale=ns)
        best = max(recs, key=lambda r: r.tps)
        rows4.append({"tp": tp, "peak_tps": best.tps,
                      "c_sat": min(r.c_eff for r in recs)})
    emit("table6b_tp_inversion", rows4)
    if rows4[1]["c_sat"] > rows4[0]["c_sat"]:
        print("# TP inversion reproduced: TP=4 costs more per token "
              "despite higher peak throughput")
    return rows


if __name__ == "__main__":
    run()
