"""Paper Table 6 / §5.9: cross-hardware validation — the load-driven
spread must reproduce on the cheap/slow part (v5e as the A100 analogue)
with compressed magnitude; the quantization advantage is hardware-
conditional (fp8 emulated on v5e inverts for the compute-bound dense
model); Result 4's TP=2 vs TP=4 inversion on Mixtral.

Since ISSUE 3 the spread/fp8 rows come straight from the committed
`paper_crosshw` store (126 cells across v5e + v5p + v6e) through
`experiments.analyze` — no engines are re-run. The live-sweep path is
kept as the fallback when the store is absent or incomplete (a partial
ladder would distort the spread silently) and for `--quick`, which must
not depend on a repo artifact."""
from benchmarks.common import BenchConfig, emit, sweep_config
from repro.experiments.analyze import (fp8_inversion, load_store_records,
                                       spread_compression)
from repro.experiments.plans import get_plan


def _rows_from_store(records):
    rows = []
    for row in spread_compression(records):
        for h in row["per_hw"]:
            rows.append({"arch": row["model"], "quant": row["quant"],
                         "n_chips": h["n_chips"], "hw": h["hw"],
                         "c_min": h["c_min"], "spread": h["spread"]})
    return rows


def run(quick: bool = False):
    records = [] if quick else load_store_records("paper_crosshw")
    if len(records) < len(get_plan("paper_crosshw").cells):
        if records:
            print(f"# paper_crosshw store incomplete ({len(records)} cells) "
                  "-> live sweep")
        records = []
    if records:
        rows = _rows_from_store(records)
        emit("table6_crosshw", rows)
        for r in fp8_inversion(records):
            native = "native" if r["native_fp8"] else "emulated"
            tag = "INVERTED" if r["inverted"] else "gain"
            print(f"# fp8 ({native}) {r['hw']} {r['model']}: "
                  f"{r['tps_uplift']:.3f}x TPS -> {tag}"
                  f"{'' if r['consistent'] else '  !! inconsistent'}")
    else:
        rows = _run_live(quick)

    # Result 4: Mixtral TP=2 vs TP=4 on the cheap part (always live: the
    # TP ladder is not part of the paper_crosshw grid)
    ns = 0.3 if quick else 1.0
    rows4 = []
    for tp in (2, 4):
        bc = BenchConfig(f"mixtral-tp{tp}", "mixtral-8x7b", "bf16", tp)
        recs = sweep_config(bc, hw_name="tpu-v5e", ladder=(25, 50, 100, 200),
                            n_scale=ns)
        best = max(recs, key=lambda r: r.tps)
        rows4.append({"tp": tp, "peak_tps": best.tps,
                      "c_sat": min(r.c_eff for r in recs)})
    emit("table6b_tp_inversion", rows4)
    if rows4[1]["c_sat"] > rows4[0]["c_sat"]:
        print("# TP inversion reproduced: TP=4 costs more per token "
              "despite higher peak throughput")
    return rows


def _run_live(quick: bool):
    ns = 0.3 if quick else 1.0
    rows = []
    pairs = [
        ("llama31-8b", "bf16", 1), ("llama31-8b", "int8", 1),
        ("llama31-8b", "fp8", 1),
        ("qwen3-30b-a3b", "bf16", 1), ("qwen3-30b-a3b", "fp8", 1),
        ("mixtral-8x7b", "bf16", 2),
    ]
    spreads = {}
    for arch, quant, chips in pairs:
        for hw in ("tpu-v5p", "tpu-v5e"):
            bc = BenchConfig(f"{arch[:10]}-{quant}", arch, quant, chips)
            recs = sweep_config(bc, hw_name=hw, n_scale=ns)
            cmin = min(r.c_eff for r in recs)
            spread = max(r.c_eff for r in recs) / cmin
            spreads[(arch, quant, hw)] = (cmin, spread)
            rows.append({"arch": arch, "quant": quant, "n_chips": chips,
                         "hw": hw, "c_min": cmin, "spread": spread})
    emit("table6_crosshw", rows)

    # fp8 hardware-conditionality: on v5e (emulated fp8) the dense model's
    # saturation cost should NOT improve the way the MoE's does.
    d_v5e = spreads[("llama31-8b", "fp8", "tpu-v5e")][0] / \
        spreads[("llama31-8b", "bf16", "tpu-v5e")][0]
    m_v5e = spreads[("qwen3-30b-a3b", "fp8", "tpu-v5e")][0] / \
        spreads[("qwen3-30b-a3b", "bf16", "tpu-v5e")][0]
    print(f"# fp8-emulated c_min ratio on v5e: dense {d_v5e:.3f} vs "
          f"moe {m_v5e:.3f} (moe should benefit more)")
    return rows


if __name__ == "__main__":
    run()
