"""Paper Fig.5 / §5.6: corrected self-host-vs-API crossover thresholds,
including the asymmetric-pricing blended comparison of §6.3."""
from repro.core import crossover_table
from repro.core.pricing import API_TIERS

from benchmarks.common import CONFIGS, emit, sweep_config


def run(quick: bool = False):
    rows = []
    for bc in CONFIGS:
        recs = sweep_config(bc, n_scale=0.4 if quick else 1.0)
        xt = crossover_table(recs, accept_slo_mismatch=True)
        for entry in xt:
            rows.append(dict(config=bc.cid, arch=bc.arch, quant=bc.quant,
                             **entry))
    emit("fig5_crossover", rows)

    # §6.3 asymmetric pricing: blended API cost for three workload shapes
    brows = []
    for name, tier in API_TIERS.items():
        for shape, (i, o) in (("chat", (512, 256)), ("rag", (4096, 1024)),
                              ("codegen", (100, 500))):
            brows.append({"tier": name, "shape": shape,
                          "in_tokens": i, "out_tokens": o,
                          "blended_per_m_out": tier.blended(i, o),
                          "list_out": tier.output_per_mtok})
    emit("fig5b_blended_api", brows)
    return rows


if __name__ == "__main__":
    run()
