"""Kernel micro-benchmarks: wall time of the jnp reference paths on this
host (the Pallas kernels execute in interpret mode on CPU, so wall-clock
kernel timing is TPU-only; the REFERENCE path is what the CPU real-exec
serving tier actually runs, making its throughput worth tracking)."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.kernels.ssm_scan.ref import ssm_scan_ref

from benchmarks.common import emit

RNG = np.random.default_rng(0)


def _timeit(fn, *args, reps=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run(quick: bool = False):
    rows = []
    B, S, Hq, Hkv, D = 2, 512, 8, 2, 64
    q = jnp.asarray(RNG.normal(size=(B, S, Hq, D)), jnp.bfloat16)
    k = jnp.asarray(RNG.normal(size=(B, S, Hkv, D)), jnp.bfloat16)
    v = jnp.asarray(RNG.normal(size=(B, S, Hkv, D)), jnp.bfloat16)
    fa = jax.jit(lambda q, k, v: flash_attention_ref(q, k, v))
    rows.append({"kernel": "flash_attention_ref", "shape": f"B{B}S{S}H{Hq}",
                 "us_per_call": _timeit(fa, q, k, v)})

    P_, page, maxp = 256, 16, 16
    qd = jnp.asarray(RNG.normal(size=(8, Hq, D)), jnp.bfloat16)
    kp = jnp.asarray(RNG.normal(size=(P_, page, Hkv, D)), jnp.bfloat16)
    vp = jnp.asarray(RNG.normal(size=(P_, page, Hkv, D)), jnp.bfloat16)
    bt = jnp.asarray(RNG.choice(P_, size=(8, maxp)), jnp.int32)
    sl = jnp.full((8,), page * maxp, jnp.int32)
    pa = jax.jit(paged_attention_ref)
    rows.append({"kernel": "paged_attention_ref", "shape": "B8ctx256",
                 "us_per_call": _timeit(pa, qd, kp, vp, bt, sl)})

    Bs, Ss, di, N = 2, 256, 512, 16
    u = jnp.asarray(RNG.normal(size=(Bs, Ss, di)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, (Bs, Ss, di)), jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(Bs, Ss, N)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(Bs, Ss, N)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 2.0, (di, N)), jnp.float32)
    Dv = jnp.asarray(RNG.normal(size=(di,)), jnp.float32)
    ss = jax.jit(lambda *a: ssm_scan_ref(*a)[0])
    rows.append({"kernel": "ssm_scan_ref", "shape": f"B{Bs}S{Ss}d{di}",
                 "us_per_call": _timeit(ss, u, dt, Bm, Cm, A, Dv)})
    emit("kernel_micro", rows)
    return rows


if __name__ == "__main__":
    run()
