"""Experiment-matrix runner benchmark: cell-sharded vs serial plan
execution wall time (ISSUE 2).

PR 1's pool parallelized ladder points inside one config; the PlanRunner
shards whole cells, so a multi-(model, quant) matrix scales with cores
instead of with the slowest ladder. This bench runs the same mini matrix
both ways and reports the speedup plus per-cell stats; `--quick` shrinks
to the CI-smoke plan.
"""
from __future__ import annotations

import dataclasses
import time

from benchmarks.common import emit
from repro.core.sweep import LAMBDA_LADDER
from repro.experiments.plan import GridSpec
from repro.experiments.runner import PlanRunner


def _plan(quick: bool):
    return GridSpec(
        name="bench_matrix",
        archs=("llama31-8b", "qwen3-30b-a3b"),
        hws=("tpu-v5e",),
        quants=("bf16",) if quick else ("bf16", "int8"),
        ladder=(5, 50) if quick else LAMBDA_LADDER[:5],
        seed=0,
        protocol="smoke" if quick else "quick",
        max_batch=128,
        num_pages=16384,
    ).expand()


def run(quick: bool = False):
    plan = _plan(quick)
    timings = {}
    results = {}
    for mode, parallel in (("serial", False), ("sharded", True)):
        t0 = time.time()
        results[mode] = PlanRunner(plan).run(parallel=parallel)
        timings[mode] = time.time() - t0
    assert ([dataclasses.asdict(r) for r in results["serial"]] ==
            [dataclasses.asdict(r) for r in results["sharded"]]), \
        "sharded records diverge from serial"

    rows = [{
        "plan": plan.name, "n_cells": len(plan.cells),
        "serial_s": timings["serial"], "sharded_s": timings["sharded"],
        "speedup": timings["serial"] / max(timings["sharded"], 1e-9),
        "records_identical": True,
    }]
    emit("plan_matrix", rows)
    cell_rows = [{
        "cell": c.cell_id, "lam": r.lam, "tps": r.tps, "c_eff": r.c_eff,
        "penalty": r.penalty,
    } for c, r in zip(plan.cells, results["sharded"])]
    emit("plan_matrix_cells", cell_rows)


if __name__ == "__main__":
    run()
