"""Experiment-runner backend benchmark: per-cell (serial + pool) vs the
vectorized fleet backend, with `cells_per_sec` as the tracked metric
(ISSUE 4).

PR 1's pool parallelized ladder points inside one config; PR 2's
PlanRunner sharded whole cells; ISSUE 4's fleet backend runs many cells
as lanes of one struct-of-arrays event loop, so a plan's throughput is
no longer one-engine-per-core. This bench runs the same plan through
every backend, asserts the records are identical (the equivalence
contract), reports cells/s per backend, and writes the perf-trajectory
file `BENCH_plan_matrix.json` at the repo root:

* full mode — a paper_h100-sized plan (42 paper-protocol cells): the
  acceptance surface for the ">=5x cells/s single-process" criterion
  (`vector` vs `serial` below).
* --quick — the CI smoke: mini_2x2 + mini_crosshw (20 smoke cells);
  `benchmarks/check_plan_matrix.py` gates on >20% regression of the
  vector-vs-serial cells/s ratio against the committed baseline (the
  ratio, not the absolute number, so CI hardware speed cancels out).
"""
from __future__ import annotations

import dataclasses
import time

from benchmarks.common import emit, merge_trajectory
from repro.experiments.plans import get_plan, paper_h100
from repro.experiments.runner import PlanRunner

# acceptance floor: fleet backend cells/s over the per-cell serial path,
# single process (ISSUE 4)
VECTOR_SPEEDUP_TARGET = 5.0


def _plans(quick: bool):
    if quick:
        return [get_plan("mini_2x2"), get_plan("mini_crosshw")]
    return [paper_h100()]


def run(quick: bool = False):
    plans = _plans(quick)
    cells = [c for p in plans for c in p.cells]
    timings = {}
    results = {}
    # (mode label, backend, parallel)
    modes = (("serial", "process", False),    # the PR-3 per-cell path
             ("sharded", "process", True),    # per-cell pool
             ("vector", "vector", False),     # fleet, single process
             ("vector_pool", "vector", True))  # fleet chunks x cores
    # Interleaved rounds with medians (the repo's noisy-wall-clock
    # discipline, see .claude/skills/verify): every round times each
    # mode once back-to-back, so machine-load drift hits serial and
    # vector alike and the per-round serial/vector ratio — whose median
    # is the CI-gated metric — stays stable even when absolute cells/s
    # swings 2-3x. Reported seconds are each mode's best round.
    rounds = 8 if quick else 4
    samples = {mode: [] for mode, _, _ in modes}
    for _ in range(rounds):
        for mode, backend, parallel in modes:
            t0 = time.time()
            recs = []
            for plan in plans:
                recs.extend(PlanRunner(plan).run(parallel=parallel,
                                                 backend=backend))
            samples[mode].append(time.time() - t0)
            results[mode] = recs
    for mode, _, _ in modes:
        timings[mode] = min(samples[mode])
    base = [repr(dataclasses.asdict(r)) for r in results["serial"]]
    for mode in ("sharded", "vector", "vector_pool"):
        assert [repr(dataclasses.asdict(r)) for r in results[mode]] == base, \
            f"{mode} records diverge from serial"

    n = len(cells)
    rows = [{
        "mode": mode,
        "backend": backend,
        "parallel": parallel,
        "seconds": timings[mode],
        "cells_per_sec": n / max(timings[mode], 1e-9),
        "speedup_vs_serial": timings["serial"] / max(timings[mode], 1e-9),
        "records_identical": True,
    } for mode, backend, parallel in modes]
    emit("plan_matrix", [{"plan": "+".join(p.name for p in plans),
                          "n_cells": n, **row} for row in rows])
    cell_rows = [{
        "cell": c.cell_id, "lam": r.lam, "tps": r.tps, "c_eff": r.c_eff,
        "penalty": r.penalty,
    } for c, r in zip(cells, results["vector"])]
    emit("plan_matrix_cells", cell_rows)

    # the gated ratio: median of per-round serial/vector ratios
    per_round = sorted(s / max(v, 1e-9) for s, v in
                       zip(samples["serial"], samples["vector"]))
    vec_vs_serial = per_round[len(per_round) // 2]
    section = {
        "plans": [p.name for p in plans],
        "n_cells": n,
        "modes": {row["mode"]: {
            "seconds": row["seconds"],
            "cells_per_sec": row["cells_per_sec"],
        } for row in rows},
        "vector_vs_serial_speedup": vec_vs_serial,
        "records_identical": True,
    }
    if not quick:
        section["target_vector_vs_serial"] = VECTOR_SPEEDUP_TARGET
        section["meets_target"] = vec_vs_serial >= VECTOR_SPEEDUP_TARGET
    path = merge_trajectory("plan_matrix", "quick" if quick else "paper",
                            section)
    print(f"\n# vector vs serial: {vec_vs_serial:.2f}x cells/s "
          f"({section['modes']['vector']['cells_per_sec']:.2f} vs "
          f"{section['modes']['serial']['cells_per_sec']:.2f}); "
          f"trajectory written to {path.name}")


if __name__ == "__main__":
    run()
