"""Experiment-runner backend benchmark: per-cell (serial + pool) vs the
vectorized numpy fleet vs the jit-compiled JAX fleet, with
`cells_per_sec` as the tracked metric (ISSUE 4 + ISSUE 7).

PR 1's pool parallelized ladder points inside one config; PR 2's
PlanRunner sharded whole cells; ISSUE 4's fleet backend runs many cells
as lanes of one struct-of-arrays event loop; ISSUE 7 compiles that loop
with JAX. This bench runs the same plan through every backend, asserts
the records agree (byte-identical for the numpy backends, within
`precision.jit_tolerance()` for the jit ones), reports cells/s per
backend, and writes the perf-trajectory file `BENCH_plan_matrix.json`
at the repo root:

* full mode — a paper_h100-sized plan (42 paper-protocol cells) for the
  ">=5x cells/s single-process" vector-vs-serial criterion (ISSUE 4),
  plus a 288-lane quick-protocol workload (every atlas group x 16
  arrival seeds at one offered rate) for the ">=3x cells/s at >=256
  lanes" jit-vs-vector criterion (ISSUE 7).
* --quick — the CI smoke: mini_2x2 + mini_crosshw (20 smoke cells);
  `benchmarks/check_plan_matrix.py` gates on >20% regression of BOTH
  machine-neutral ratios (vector/serial and jit/vector) against the
  committed baseline (ratios, not absolute numbers, so CI hardware
  speed cancels out).
"""
from __future__ import annotations

import dataclasses
import math
import time

from benchmarks.common import emit, merge_trajectory
from repro.experiments.plans import get_plan, paper_h100
from repro.experiments.runner import PlanRunner
from repro.serving import precision

# acceptance floor: fleet backend cells/s over the per-cell serial path,
# single process (ISSUE 4)
VECTOR_SPEEDUP_TARGET = 5.0
# acceptance floor: jit backend cells/s over the vectorized numpy
# backend at >= 256 lanes, single process (ISSUE 7)
JIT_SPEEDUP_TARGET = 3.0
JIT_MIN_LANES = 256


def _plans(quick: bool):
    if quick:
        return [get_plan("mini_2x2"), get_plan("mini_crosshw")]
    return [paper_h100()]


def _lane_scale_plan():
    """The >=256-lane jit acceptance workload: every `paper_atlas`
    (model, hw, quant) group replicated at 16 arrival seeds, pinned to
    one mid-ladder offered rate — 288 uniform quick-protocol cells, so
    the jit chunk actually runs at the lane width the criterion names
    instead of paper_h100's 42."""
    plan = get_plan("paper_ensemble").subset(lambda c: c.lam == 25.0)
    assert len(plan.cells) >= JIT_MIN_LANES
    return plan


def _records_close(oracle, got, ctx):
    """Tolerance agreement for the jit modes (their records are f64-
    tolerance-identical, not byte-identical, to the numpy oracle)."""
    rtol, atol = precision.jit_tolerance()
    assert len(oracle) == len(got), ctx
    for a, b in zip(oracle, got):
        da, db = dataclasses.asdict(a), dataclasses.asdict(b)
        for key in da:
            va, vb = da[key], db[key]
            if isinstance(va, float):
                if math.isnan(va) and math.isnan(vb):
                    continue
                assert abs(va - vb) <= rtol * abs(va) + atol, \
                    (ctx, a.model, a.lam, key, va, vb)
            else:
                assert va == vb, (ctx, a.model, a.lam, key, va, vb)
    return True


def run(quick: bool = False):
    plans = _plans(quick)
    cells = [c for p in plans for c in p.cells]
    timings = {}
    results = {}
    # (mode label, backend, parallel)
    modes = (("serial", "process", False),    # the PR-3 per-cell path
             ("sharded", "process", True),    # per-cell pool
             ("vector", "vector", False),     # fleet, single process
             ("vector_pool", "vector", True),  # fleet chunks x cores
             ("jit", "jit", False),           # compiled fleet (ISSUE 7)
             ("jit_pool", "jit", True))       # compiled fleet x cores
    # Interleaved rounds with medians (the repo's noisy-wall-clock
    # discipline, see .claude/skills/verify): every round times each
    # mode once back-to-back, so machine-load drift hits serial and
    # vector alike and the per-round serial/vector ratio — whose median
    # is the CI-gated metric — stays stable even when absolute cells/s
    # swings 2-3x. Reported seconds are each mode's best round.
    rounds = 8 if quick else 4
    samples = {mode: [] for mode, _, _ in modes}
    for _ in range(rounds):
        for mode, backend, parallel in modes:
            t0 = time.time()
            recs = []
            for plan in plans:
                recs.extend(PlanRunner(plan).run(parallel=parallel,
                                                 backend=backend))
            samples[mode].append(time.time() - t0)
            results[mode] = recs
    for mode, _, _ in modes:
        timings[mode] = min(samples[mode])
    base = [repr(dataclasses.asdict(r)) for r in results["serial"]]
    for mode in ("sharded", "vector", "vector_pool"):
        assert [repr(dataclasses.asdict(r)) for r in results[mode]] == base, \
            f"{mode} records diverge from serial"
    for mode in ("jit", "jit_pool"):
        _records_close(results["serial"], results[mode], mode)

    n = len(cells)
    rows = [{
        "mode": mode,
        "backend": backend,
        "parallel": parallel,
        "seconds": timings[mode],
        "cells_per_sec": n / max(timings[mode], 1e-9),
        "speedup_vs_serial": timings["serial"] / max(timings[mode], 1e-9),
        "records_identical": backend != "jit",   # jit: tolerance-checked
    } for mode, backend, parallel in modes]
    emit("plan_matrix", [{"plan": "+".join(p.name for p in plans),
                          "n_cells": n, **row} for row in rows])
    cell_rows = [{
        "cell": c.cell_id, "lam": r.lam, "tps": r.tps, "c_eff": r.c_eff,
        "penalty": r.penalty,
    } for c, r in zip(cells, results["vector"])]
    emit("plan_matrix_cells", cell_rows)

    # the gated ratios: medians of per-round time ratios (machine-neutral)
    def _median_ratio(num_mode, den_mode, mode_samples):
        per_round = sorted(s / max(v, 1e-9) for s, v in
                           zip(mode_samples[num_mode],
                               mode_samples[den_mode]))
        return per_round[len(per_round) // 2]

    vec_vs_serial = _median_ratio("serial", "vector", samples)
    section = {
        "plans": [p.name for p in plans],
        "n_cells": n,
        "modes": {row["mode"]: {
            "seconds": row["seconds"],
            "cells_per_sec": row["cells_per_sec"],
        } for row in rows},
        "vector_vs_serial_speedup": vec_vs_serial,
        "records_identical": True,
    }
    if quick:
        # the CI smoke gates the jit ratio on the same 20-cell workload
        # (tiny lanes, so compile amortization is poor — the committed
        # baseline captures that and only regressions fail)
        section["jit_vs_vector_speedup"] = _median_ratio("vector", "jit",
                                                         samples)
        section["jit_lanes"] = n
    else:
        section["target_vector_vs_serial"] = VECTOR_SPEEDUP_TARGET
        section["meets_target"] = vec_vs_serial >= VECTOR_SPEEDUP_TARGET
        # the ISSUE 7 acceptance workload: jit vs vector at >= 256
        # uniform lanes, interleaved rounds, median per-round ratio
        lane_plan = _lane_scale_plan()
        lane_samples = {"vector": [], "jit": []}
        lane_results = {}
        for _ in range(4):
            for mode in ("vector", "jit"):
                t0 = time.time()
                lane_results[mode] = PlanRunner(lane_plan).run(
                    parallel=False, backend=mode)
                lane_samples[mode].append(time.time() - t0)
        _records_close(lane_results["vector"], lane_results["jit"],
                       "jit-lane-scale")
        jit_vs_vector = _median_ratio("vector", "jit", lane_samples)
        nl = len(lane_plan.cells)
        section["jit_vs_vector_speedup"] = jit_vs_vector
        section["jit_lanes"] = nl
        section["jit_lane_scale_modes"] = {
            mode: {"seconds": min(lane_samples[mode]),
                   "cells_per_sec": nl / max(min(lane_samples[mode]), 1e-9)}
            for mode in ("vector", "jit")}
        section["target_jit_vs_vector"] = JIT_SPEEDUP_TARGET
        section["meets_jit_target"] = jit_vs_vector >= JIT_SPEEDUP_TARGET
    path = merge_trajectory("plan_matrix", "quick" if quick else "paper",
                            section)
    print(f"\n# vector vs serial: {vec_vs_serial:.2f}x cells/s "
          f"({section['modes']['vector']['cells_per_sec']:.2f} vs "
          f"{section['modes']['serial']['cells_per_sec']:.2f}); "
          f"jit vs vector: {section['jit_vs_vector_speedup']:.2f}x at "
          f"{section['jit_lanes']} lanes; "
          f"trajectory written to {path.name}")


if __name__ == "__main__":
    run()
