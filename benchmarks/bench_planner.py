"""Capacity-planner throughput (ISSUE 5): time the full-store
fit + optimize path — the interactive surface an operator hits, so it
must stay interactive-fast even over the 450-cell dense atlas.

Measures, best-of-N over the committed `paper_atlas` store (no engines
are re-run):

* `fit`       — fitting every DeploymentCurve from consolidated records
* `optimize`  — plan_capacity across footprints x replica counts + the
                greedy heterogeneous mix, per reference load
* `slo`       — the same optimization under a TTFT p90 target (adds the
                per-curve bisection caps)
* `tables`    — the full `planner_tables` payload (what analyze embeds)

Informational only (no CI gate): the quick section rides the
quick-benches job so a pathological regression is at least *visible* in
the logs. Falls back to the sparse `paper_crosshw` store when the atlas
is absent; fails loudly with the command to build one when neither
store exists."""
import time

from benchmarks.common import emit
from repro.core.slo import SLOTarget
from repro.experiments.analyze import load_store_records
from repro.planner import fit_curves, plan_capacity, planner_tables

LOADS = (1.0, 5.0, 42.0, 200.0)
SLO = SLOTarget(ttft_p90_ms=2000.0)


def _records():
    for plan in ("paper_atlas", "paper_crosshw"):
        try:
            records = load_store_records(plan)
        except OSError:
            records = []
        if records:
            return plan, records
    raise SystemExit(
        "no committed store found (paper_atlas / paper_crosshw); run: "
        "python -m repro.experiments.run --plan paper_atlas "
        "--backend vector")


def _best_of(fn, n):
    best, out = float("inf"), None
    for _ in range(n):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def run(quick: bool = False):
    n = 3 if quick else 5
    plan, records = _records()
    print(f"# store: {plan} ({len(records)} records)")

    t_fit, curves = _best_of(lambda: fit_curves(records), n)
    t_opt, plans = _best_of(
        lambda: [plan_capacity(curves, lam) for lam in LOADS], n)
    t_slo, _ = _best_of(
        lambda: [plan_capacity(curves, lam, SLO) for lam in LOADS], n)
    t_tab, _ = _best_of(lambda: planner_tables(records), n)

    n_options = sum(len(p.ranked) + len(p.rejected)
                    for per_lam in plans for p in per_lam)
    rows = [{
        "store": plan, "n_records": len(records), "n_curves": len(curves),
        "n_loads": len(LOADS), "n_options": n_options,
        "fit_ms": t_fit * 1e3,
        "optimize_ms": t_opt * 1e3,
        "optimize_slo_ms": t_slo * 1e3,
        "planner_tables_ms": t_tab * 1e3,
        # planner_tables refits internally: it IS the end-to-end path
        "end_to_end_ms": t_tab * 1e3,
    }]
    emit("planner", rows)
    print(f"# fit {t_fit * 1e3:.1f}ms + optimize {t_opt * 1e3:.1f}ms "
          f"({n_options} options over {len(LOADS)} loads); "
          f"full planner_tables {t_tab * 1e3:.1f}ms")


if __name__ == "__main__":
    run()
