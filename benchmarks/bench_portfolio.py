"""Portfolio-planner throughput (ISSUE 10): time the route + allocate +
certify path over the committed `paper_atlas` store — the full
multi-model verdict an operator gets from `--portfolio`, including the
exact branch-and-bound runs that certify every greedy allocation.

Measures, best-of-N (no engines are re-run):

* `route`     — the token-budget router across the 3-class blend at
                every reference total rate
* `portfolio` — the full silo / flagship_pool / routed_pool verdict
                (greedy + exact certification per pool)
* `certify`   — the greedy-vs-exact certification table alone, per
                (model, io_shape) group x reference load
* `n_nodes`   — total branch-and-bound nodes explored (trajectory of
                the search cost, not just wall time)

Informational only (no CI gate), same contract as bench_planner: the
trajectory makes a pathological slowdown or a node-count explosion
visible in the logs. Falls back to `paper_crosshw` when the atlas is
absent."""
import time

from benchmarks.common import emit
from repro.experiments.analyze import load_store_records
from repro.planner import (BLENDED_3CLASS, PORTFOLIO_LAMS,
                           certification_rows, fit_curves, plan_portfolio,
                           route_workload)


def _records():
    for plan in ("paper_atlas", "paper_crosshw"):
        try:
            records = load_store_records(plan)
        except OSError:
            records = []
        if records:
            return plan, records
    raise SystemExit(
        "no committed store found (paper_atlas / paper_crosshw); run: "
        "python -m repro.experiments.run --plan paper_atlas "
        "--backend vector")


def _best_of(fn, n):
    best, out = float("inf"), None
    for _ in range(n):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def run(quick: bool = False):
    n = 3 if quick else 5
    plan, records = _records()
    curves = fit_curves(records)
    workloads = [BLENDED_3CLASS.scaled(lam) for lam in PORTFOLIO_LAMS]
    print(f"# store: {plan} ({len(records)} records, "
          f"{len(curves)} curves)")

    t_route, _ = _best_of(
        lambda: [route_workload(w, curves) for w in workloads], n)
    t_port, plans = _best_of(
        lambda: [plan_portfolio(curves, w) for w in workloads], n)
    t_cert, rows = _best_of(lambda: certification_rows(curves), n)

    n_nodes = sum(r.get("n_nodes") or 0 for r in rows)
    n_beaten = sum(1 for r in rows if r.get("greedy_beaten"))
    n_pools = sum(len(a.pools) for p in plans for a in p.arms.values())
    emit("portfolio", [{
        "store": plan, "n_records": len(records), "n_curves": len(curves),
        "n_loads": len(PORTFOLIO_LAMS), "n_pools": n_pools,
        "n_cert_instances": len(rows), "n_nodes": n_nodes,
        "n_greedy_beaten": n_beaten,
        "route_ms": t_route * 1e3,
        "portfolio_ms": t_port * 1e3,
        "certify_ms": t_cert * 1e3,
    }])
    print(f"# route {t_route * 1e3:.1f}ms + portfolio "
          f"{t_port * 1e3:.1f}ms ({n_pools} pools certified); "
          f"certification table {t_cert * 1e3:.1f}ms "
          f"({n_nodes} B&B nodes, {n_beaten} greedy losses)")


if __name__ == "__main__":
    run()
