"""Paper Fig.2 / §5.3: quantization impact on peak throughput per
architecture — the MoE-first pattern (+ the active-params-beat-total
ordering of §5.2/Result 3)."""
from benchmarks.common import BenchConfig, emit, sweep_config


def run(quick: bool = False):
    rows = []
    sat = {}
    for arch, chips in (("llama31-8b", 1), ("qwen3-30b-a3b", 1),
                        ("mixtral-8x7b", 2)):
        for quant in ("bf16", "int8"):
            bc = BenchConfig(f"{arch}-{quant}", arch, quant, chips)
            recs = sweep_config(bc, ladder=(25, 50, 100, 200),
                                n_scale=0.3 if quick else 1.0)
            best = max(recs, key=lambda r: r.tps)
            sat[(arch, quant)] = (best.tps, best.c_eff)
    for arch, chips in (("llama31-8b", 1), ("qwen3-30b-a3b", 1),
                        ("mixtral-8x7b", 2)):
        t0, c0 = sat[(arch, "bf16")]
        t1, c1 = sat[(arch, "int8")]
        rows.append({"arch": arch, "n_chips": chips,
                     "tps_bf16": t0, "tps_int8": t1,
                     "gain_pct": 100.0 * (t1 / t0 - 1.0),
                     "c_sat_bf16": c0, "c_sat_int8": c1})
    emit("fig2_quant_gains", rows)
    # §5.2 Result-3 check: active params beat total at saturation
    q = sat[("qwen3-30b-a3b", "int8")][1]
    l = sat[("llama31-8b", "int8")][1]
    print(f"# active-params ordering: qwen3-int8 ${q:.3f}/MTok "
          f"{'<' if q < l else '>='} llama8b-int8 ${l:.3f}/MTok")
    return rows


if __name__ == "__main__":
    run()
