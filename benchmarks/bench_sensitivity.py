"""Paper §5.7 sensitivity probes: I/O shape (Fig.7), arrival burstiness
(Gamma CV=2), and variable-length arrivals — run on C2/C4 analogues."""
from benchmarks.common import CONFIGS, emit, sweep_config


def run(quick: bool = False):
    c2, c4 = CONFIGS[1], CONFIGS[3]
    ns = 0.3 if quick else 1.0

    # --- I/O shape (chat 512:256, RAG 4096:1024, agentic 1024:4096) -----
    rows = []
    base = {}
    for bc in (c2, c4):
        for shape in ("chat", "rag", "agentic"):
            recs = sweep_config(bc, ladder=(1, 25, 100), io_shape=shape,
                                n_scale=ns)
            for r in recs:
                key = (bc.cid, r.lam)
                if shape == "chat":
                    base[key] = r.c_eff
                rows.append({
                    "config": bc.cid, "io_shape": shape, "lam": r.lam,
                    "tps": r.tps, "c_eff": r.c_eff,
                    "vs_chat": r.c_eff / base[key] if key in base
                    else float("nan")})
    emit("sens_io_shape", rows)

    # --- burstiness: Poisson (CV=1) vs Gamma CV=2 on C4 ------------------
    rows = []
    for lam in (10, 50, 100):
        pois = sweep_config(c4, ladder=(lam,), process="poisson",
                            n_scale=ns)[0]
        gam = sweep_config(c4, ladder=(lam,), process="gamma", cv=2.0,
                           n_scale=ns)[0]
        rows.append({"lam": lam, "c_eff_poisson": pois.c_eff,
                     "c_eff_gamma_cv2": gam.c_eff,
                     "ratio": gam.c_eff / pois.c_eff})
    emit("sens_burstiness", rows)

    # --- variable-length (log-normal) vs fixed 512:256 -------------------
    rows = []
    for bc in (c2, c4):
        fixed = sweep_config(bc, ladder=(1, 10, 50, 100), n_scale=ns)
        varl = sweep_config(bc, ladder=(1, 10, 50, 100),
                            io_shape="variable", n_scale=ns)
        spread_f = max(r.c_eff for r in fixed) / min(r.c_eff for r in fixed)
        spread_v = max(r.c_eff for r in varl) / min(r.c_eff for r in varl)
        rows.append({"config": bc.cid, "spread_fixed": spread_f,
                     "spread_variable": spread_v,
                     "cliff_steeper_under_varlen": spread_v > spread_f})
    emit("sens_varlen", rows)
    return rows


if __name__ == "__main__":
    run()
