"""Paper Fig.1: C_eff vs offered load for the six configurations."""
from benchmarks.common import CONFIGS, emit, records_rows, sweep_config


def run(quick: bool = False):
    rows = []
    for bc in CONFIGS:
        recs = sweep_config(bc, n_scale=0.4 if quick else 1.0)
        rows += records_rows(recs)
    emit("fig1_cost_curves", rows)
    return rows


if __name__ == "__main__":
    run()
