"""Run every benchmark (one per paper table/figure); print consolidated CSV.

    PYTHONPATH=src python -m benchmarks.run [--quick]
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks import (bench_diurnal, bench_engine_throughput,
                        bench_fig1_cost_curves,
                        bench_fig2_quant, bench_fig3_penalty_heatmap,
                        bench_fig5_crossover, bench_kernels,
                        bench_overload, bench_plan_matrix, bench_planner,
                        bench_portfolio, bench_resilience,
                        bench_sensitivity, bench_table3_penalty,
                        bench_table4_sla,
                        bench_table5_stability, bench_table6_crosshw,
                        bench_table7_live)

SUITES = (
    ("engine_throughput", bench_engine_throughput),
    ("plan_matrix", bench_plan_matrix),
    ("planner", bench_planner),
    ("portfolio", bench_portfolio),
    ("resilience", bench_resilience),
    ("diurnal", bench_diurnal),
    ("overload", bench_overload),
    ("fig1_cost_curves", bench_fig1_cost_curves),
    ("table3_penalty", bench_table3_penalty),
    ("fig2_quant", bench_fig2_quant),
    ("fig3_penalty_heatmap", bench_fig3_penalty_heatmap),
    ("table4_sla", bench_table4_sla),
    ("fig5_crossover", bench_fig5_crossover),
    ("sensitivity_5_7", bench_sensitivity),
    ("table5_stability", bench_table5_stability),
    ("table6_crosshw", bench_table6_crosshw),
    ("table7_live", bench_table7_live),
    ("kernel_micro", bench_kernels),
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced request counts (~3x faster)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)

    t_all = time.time()
    for name, mod in SUITES:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        print(f"\n{'=' * 72}\n=== {name} ===")
        mod.run(quick=args.quick)
        print(f"# {name} done in {time.time() - t0:.1f}s")
    print(f"\nALL BENCHMARKS DONE in {time.time() - t_all:.1f}s")


if __name__ == "__main__":
    main()
