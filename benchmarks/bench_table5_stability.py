"""Paper Table 5 / §5.8: repeat-run measurement stability (CV%) on C2."""
from repro.core import stability_table
from repro.core.sweep import run_point
from repro.serving import ArrivalSpec

from benchmarks.common import CONFIGS, emit, engine_factory
from repro.simulate import HW_BY_NAME


def run(quick: bool = False, n_repeats: int = 3):
    bc = CONFIGS[1]      # C2
    hw = HW_BY_NAME["tpu-v5p"]
    runs = {}
    for lam in (1, 10, 50, 100):
        rs = []
        for seed in range(n_repeats):
            n = int(min(1200, max(150, 25 * lam)) * (0.3 if quick else 1.0))
            spec = ArrivalSpec(lam=lam, n_requests=n, seed=seed * 131 + 7)
            rs.append(run_point(
                engine_factory(bc), spec, config=bc.cid, model=bc.arch,
                hw=hw.name, n_chips=bc.n_chips, quant=bc.quant,
                engine_kind="sim",
                price_per_hr=hw.price_per_chip_hr * bc.n_chips))
        runs[lam] = rs
    rows = stability_table(runs)
    emit("table5_stability", rows)
    return rows


if __name__ == "__main__":
    run()
