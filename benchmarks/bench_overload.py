"""Overload controller cost (ISSUE 9). Informational only, no CI gate.

Three timings an operator of the degradation layer cares about:

* `inert-policy` — zero-cost-off: an engine carrying a default (all
  zero) OverloadPolicy vs `overload=None`; the bit-identity contract
  says the records match, this measures that the wall-clock does too.
* `armed-controller` — what the full degradation stack (priority
  classes + state machine + brownout clamping) costs per cell next to
  the same arrivals with no controller.
* `flashcrowd-fleet` — cells/s of the vectorized fleet backend over the
  `mini_flashcrowd` pair (the CI smoke store), admission/brownout
  running in-lane.
* `overload-tables` — re-deriving the degradation-vs-blind-shedding
  verdict from the committed `paper_flashcrowd` store.
"""
import time

from benchmarks.common import emit
from repro.core.sweep import SimEngineSpec, run_point
from repro.experiments.plans import get_plan
from repro.serving.arrivals import ArrivalSpec
from repro.serving.fleet import FleetPoint, fleet_run_points
from repro.serving.overload import OverloadPolicy


def _timed(fn, n):
    best, out = float("inf"), None
    for _ in range(n):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def run(quick: bool = False):
    n = 3 if quick else 6
    n_req = 300 if quick else 1500
    rows = []

    base = dict(arch="llama31-8b", max_batch=16, num_pages=8192,
                max_pages_per_seq=64)
    arr = ArrivalSpec(lam=12.0, n_requests=n_req, seed=0)
    t_plain, _ = _timed(
        lambda: run_point(SimEngineSpec(**base), arr, config="B"), n)
    t_inert, _ = _timed(
        lambda: run_point(SimEngineSpec(overload=OverloadPolicy(), **base),
                          arr, config="B"), n)
    rows.append({"case": "inert-policy", "n": n_req, "wall_s": t_inert,
                 "baseline_s": t_plain, "ratio": t_inert / t_plain,
                 "req_per_s": n_req / t_inert})

    armed = OverloadPolicy(brownout_depth=12, shed_depth=24,
                           recover_depth=4, ttft_slo_s=1.0,
                           brownout_max_new=64)
    classed = ArrivalSpec(lam=12.0, n_requests=n_req, seed=0,
                          class_mix=(0.5, 0.3, 0.2))
    t_armed, rec = _timed(
        lambda: run_point(SimEngineSpec(overload=armed, **base), classed,
                          config="B"), n)
    rows.append({"case": "armed-controller", "n": n_req, "wall_s": t_armed,
                 "baseline_s": t_plain, "ratio": t_armed / t_plain,
                 "req_per_s": n_req / t_armed})
    print(f"# armed cell: shed={rec.n_shed} browned={rec.n_browned} "
          f"slo_viol={rec.n_slo_viol}")

    cells = list(get_plan("mini_flashcrowd").cells)
    points = [FleetPoint(engine=c.engine_spec(), arrivals=c.arrival_spec(),
                         warmup=c.warmup, **c.record_kw())
              for c in cells]
    t_fleet, _ = _timed(lambda: fleet_run_points(points), n)
    rows.append({"case": "flashcrowd-fleet", "n": len(points),
                 "wall_s": t_fleet, "baseline_s": float("nan"),
                 "ratio": float("nan"),
                 "req_per_s": len(points) / t_fleet})

    try:
        from repro.experiments.analyze import (load_store_records,
                                               overload_tables)
        records = load_store_records("paper_flashcrowd")
    except OSError:
        records = []
    if records:
        t_tab, tab = _timed(lambda: overload_tables(records), n)
        rows.append({"case": "overload-tables", "n": len(records),
                     "wall_s": t_tab, "baseline_s": float("nan"),
                     "ratio": float("nan"),
                     "req_per_s": len(tab) / t_tab})
    else:
        print("# paper_flashcrowd store absent; analysis section skipped")
    emit("overload", rows)


if __name__ == "__main__":
    run(quick=True)
