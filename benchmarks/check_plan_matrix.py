"""CI gate: fail on cells/s regression of the fleet backend (ISSUE 4).

Compares a fresh `BENCH_plan_matrix.json` (written by
`python -m benchmarks.run --quick --only plan_matrix`) against the
committed baseline. The gated metric is the *vector-vs-serial cells/s
ratio*, not the absolute cells/s: both backends run on the same runner,
so machine speed cancels and only a real change to the fleet's
amortization (or to the per-cell path) can move the ratio.

    python -m benchmarks.check_plan_matrix \
        --baseline BENCH_plan_matrix.baseline.json \
        --current BENCH_plan_matrix.json --section quick

Exits non-zero when the current ratio falls below (1 - tolerance) of the
baseline ratio (default tolerance 0.20, the ISSUE 4 gate).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--current", required=True)
    ap.add_argument("--section", default="quick",
                    choices=("quick", "paper"))
    ap.add_argument("--tolerance", type=float, default=0.20,
                    help="allowed fractional regression of the "
                         "vector-vs-serial cells/s ratio")
    args = ap.parse_args(argv)

    def load(path):
        blob = json.loads(Path(path).read_text())
        if args.section not in blob:
            raise SystemExit(f"{path} has no {args.section!r} section; "
                             "run the plan_matrix bench first")
        return blob[args.section]

    base = load(args.baseline)
    cur = load(args.current)
    base_ratio = base["vector_vs_serial_speedup"]
    cur_ratio = cur["vector_vs_serial_speedup"]
    floor = (1.0 - args.tolerance) * base_ratio
    print(f"vector-vs-serial cells/s ratio: baseline {base_ratio:.2f}x, "
          f"current {cur_ratio:.2f}x, floor {floor:.2f}x "
          f"(tolerance {args.tolerance:.0%})")
    if not cur.get("records_identical", False):
        print("FAIL: backend records diverged", file=sys.stderr)
        return 1
    if cur_ratio < floor:
        print(f"FAIL: fleet backend regressed >"
              f"{args.tolerance:.0%} vs the committed baseline",
              file=sys.stderr)
        return 1
    print("OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
