"""CI gate: fail on cells/s regression of the fleet backends (ISSUE 4 +
ISSUE 7).

Compares a fresh `BENCH_plan_matrix.json` (written by
`python -m benchmarks.run --quick --only plan_matrix`) against the
committed baseline. The gated metrics are the *vector-vs-serial* and
*jit-vs-vector* cells/s ratios, not the absolute cells/s: the compared
backends run on the same runner, so machine speed cancels and only a
real change to a backend's amortization can move a ratio.

    python -m benchmarks.check_plan_matrix \
        --baseline BENCH_plan_matrix.baseline.json \
        --current BENCH_plan_matrix.json --section quick

Exits non-zero when any gated ratio falls below (1 - tolerance) of its
baseline (default tolerance 0.20, the ISSUE 4/7 gate). The jit ratio is
gated only when the baseline records it, so the gate is
forward-compatible with pre-jit baselines.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--current", required=True)
    ap.add_argument("--section", default="quick",
                    choices=("quick", "paper"))
    ap.add_argument("--tolerance", type=float, default=0.20,
                    help="allowed fractional regression of the "
                         "vector-vs-serial cells/s ratio")
    args = ap.parse_args(argv)

    def load(path):
        blob = json.loads(Path(path).read_text())
        if args.section not in blob:
            raise SystemExit(f"{path} has no {args.section!r} section; "
                             "run the plan_matrix bench first")
        return blob[args.section]

    base = load(args.baseline)
    cur = load(args.current)
    if not cur.get("records_identical", False):
        print("FAIL: backend records diverged", file=sys.stderr)
        return 1
    failed = False
    gates = [("vector-vs-serial", "vector_vs_serial_speedup")]
    if "jit_vs_vector_speedup" in base:
        gates.append(("jit-vs-vector", "jit_vs_vector_speedup"))
    for label, key in gates:
        base_ratio = base[key]
        cur_ratio = cur.get(key)
        if cur_ratio is None:
            print(f"FAIL: current bench has no {key!r} "
                  f"(baseline records it)", file=sys.stderr)
            failed = True
            continue
        floor = (1.0 - args.tolerance) * base_ratio
        print(f"{label} cells/s ratio: baseline {base_ratio:.2f}x, "
              f"current {cur_ratio:.2f}x, floor {floor:.2f}x "
              f"(tolerance {args.tolerance:.0%})")
        if cur_ratio < floor:
            print(f"FAIL: {label} regressed >{args.tolerance:.0%} vs "
                  f"the committed baseline", file=sys.stderr)
            failed = True
    if failed:
        return 1
    print("OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
