"""Workload portfolios: many request classes, one fleet (ISSUE 10
tentpole, parts a + d).

The paper prices one model per deployment; the operator question it
motivates is a portfolio question. A `Workload` describes blended
traffic as frozen request classes — each with its own rate, decode
token budget, io_shape, and an ordered list of model tiers capable
enough to serve it (flagship first). `plan_portfolio` then prices that
workload three ways on one store's fitted curves:

* **silo** — the status quo: every class runs dedicated replicas of its
  flagship model. Utilization penalties compound per class.
* **flagship_pool** — consolidation only: classes sharing a flagship
  pool into one blended rate per (model, io_shape) before allocation.
* **routed_pool** — consolidation + routing: the token-budget router
  (`repro.planner.routing`) first moves each class to its cheapest
  capable tier, then pools per (model, io_shape).

Every pool is allocated by `greedy_mix` and certified against the
exact branch-and-bound optimum (`repro.planner.allocate`), so each
`PoolAllocation` carries its optimality gap. The verdict decomposes
the saving into a consolidation part (silo -> flagship_pool) and a
routing part (flagship_pool -> routed_pool), both on the operator's
actual bill ($/hr for the whole fleet).

Infeasible classes (budget gate, missing curves, SLO) are carried with
reasons and poison the affected arm's totals to None — the plan never
prices a workload the store cannot demonstrate (§6.4 discipline).
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.slo import SLOTarget
from repro.planner.allocate import Certificate, certify
from repro.planner.curves import DeploymentCurve
from repro.planner.optimize import HeterogeneousMix, greedy_mix
from repro.serving.arrivals import IO_SHAPES

ARMS = ("silo", "flagship_pool", "routed_pool")


@dataclasses.dataclass(frozen=True)
class WorkloadClass:
    """One request class of a blended workload.

    ``tiers`` is the capability ladder, flagship first: every listed
    model is assumed *able* to serve the class; the router decides
    which one is worth paying for. ``budget_tokens`` is the class's
    decode budget — it must be within the measured decode length of
    ``io_shape`` or the planner refuses to price the class.
    """
    name: str
    lam: float                       # offered rate, req/s
    tiers: Tuple[str, ...]           # eligible models, flagship first
    io_shape: str = "chat"
    budget_tokens: int = 0           # 0 = io_shape's measured decode len

    def __post_init__(self):
        if not self.name:
            raise ValueError("workload class needs a name")
        if not (math.isfinite(self.lam) and self.lam > 0):
            raise ValueError(
                f"class {self.name!r}: lam must be finite and > 0, "
                f"got {self.lam!r}")
        if not self.tiers:
            raise ValueError(
                f"class {self.name!r}: needs at least one eligible "
                "model tier (flagship first)")
        if len(set(self.tiers)) != len(self.tiers):
            raise ValueError(
                f"class {self.name!r}: duplicate tiers {self.tiers}")
        object.__setattr__(self, "tiers", tuple(self.tiers))
        if self.budget_tokens == 0:
            measured = IO_SHAPES.get(self.io_shape)
            if measured is None:
                raise ValueError(
                    f"class {self.name!r}: io_shape {self.io_shape!r} "
                    f"is not a measured shape {sorted(IO_SHAPES)} and "
                    "no explicit budget_tokens was given")
            object.__setattr__(self, "budget_tokens", measured[1])
        if self.budget_tokens < 0:
            raise ValueError(
                f"class {self.name!r}: budget_tokens must be >= 0, "
                f"got {self.budget_tokens}")

    @property
    def flagship(self) -> str:
        return self.tiers[0]

    def scaled(self, factor: float) -> "WorkloadClass":
        return dataclasses.replace(self, lam=self.lam * factor)

    def to_dict(self) -> dict:
        return {"name": self.name, "lam": self.lam,
                "tiers": list(self.tiers), "io_shape": self.io_shape,
                "budget_tokens": self.budget_tokens}


@dataclasses.dataclass(frozen=True)
class Workload:
    """A named bundle of request classes — the portfolio spec."""
    name: str
    classes: Tuple[WorkloadClass, ...]

    def __post_init__(self):
        if not self.classes:
            raise ValueError(f"workload {self.name!r} has no classes")
        object.__setattr__(self, "classes", tuple(self.classes))
        names = [c.name for c in self.classes]
        if len(set(names)) != len(names):
            raise ValueError(
                f"workload {self.name!r}: duplicate class names "
                f"{sorted(n for n in names if names.count(n) > 1)}")

    @property
    def lam_total(self) -> float:
        return sum(c.lam for c in self.classes)

    @property
    def models(self) -> Tuple[str, ...]:
        seen: List[str] = []
        for c in self.classes:
            for t in c.tiers:
                if t not in seen:
                    seen.append(t)
        return tuple(seen)

    def scaled(self, lam_total: float) -> "Workload":
        """The same class mix rescaled so rates sum to `lam_total`."""
        if not (math.isfinite(lam_total) and lam_total > 0):
            raise ValueError(
                f"lam_total must be finite and > 0, got {lam_total!r}")
        factor = lam_total / self.lam_total
        return Workload(name=self.name,
                        classes=tuple(c.scaled(factor)
                                      for c in self.classes))

    def to_dict(self) -> dict:
        return {"name": self.name,
                "classes": [c.to_dict() for c in self.classes]}

    @classmethod
    def from_dict(cls, d: dict) -> "Workload":
        if "classes" not in d:
            raise ValueError(
                "workload spec needs a 'classes' list; got keys "
                f"{sorted(d)}")
        return cls(
            name=d.get("name", "workload"),
            classes=tuple(
                WorkloadClass(
                    name=c["name"], lam=float(c["lam"]),
                    tiers=tuple(c["tiers"]),
                    io_shape=c.get("io_shape", "chat"),
                    budget_tokens=int(c.get("budget_tokens", 0)))
                for c in d["classes"]))

    @classmethod
    def from_json(cls, path: str) -> "Workload":
        with open(path) as f:
            return cls.from_dict(json.load(f))


# The headline 3-class blend (shares sum to 1 req/s; use .scaled()).
# Tier ladders follow model capability: mixtral-8x7b is the flagship,
# qwen3-30b-a3b the mid tier, llama31-8b the small tier. All classes
# ride the measured "chat" shape; they differ in decode budget and in
# how far down the ladder they may be routed.
BLENDED_3CLASS = Workload(name="blended_3class", classes=(
    WorkloadClass(name="reasoning", lam=0.2, budget_tokens=256,
                  tiers=("mixtral-8x7b",)),
    WorkloadClass(name="chat", lam=0.5, budget_tokens=192,
                  tiers=("mixtral-8x7b", "qwen3-30b-a3b")),
    WorkloadClass(name="autocomplete", lam=0.3, budget_tokens=64,
                  tiers=("mixtral-8x7b", "qwen3-30b-a3b",
                         "llama31-8b")),
))

WORKLOADS: Dict[str, Workload] = {BLENDED_3CLASS.name: BLENDED_3CLASS}


@dataclasses.dataclass(frozen=True)
class PoolAllocation:
    """One (model, io_shape) pool of one arm, priced and certified."""
    model: str
    io_shape: str
    lam: float
    class_names: Tuple[str, ...]
    feasible: bool
    mix: Optional[HeterogeneousMix]
    certificate: Optional[Certificate]
    why_infeasible: str = ""

    @property
    def fleet_price_per_hr(self) -> float:
        return self.mix.fleet_price_per_hr if self.mix else math.inf

    @property
    def c_eff(self) -> float:
        return self.mix.c_eff if self.mix else math.inf

    @property
    def n_replicas(self) -> int:
        return len(self.mix.allocations) if self.mix else 0

    @property
    def n_chips(self) -> int:
        return (sum(a.n_chips for a in self.mix.allocations)
                if self.mix else 0)


@dataclasses.dataclass(frozen=True)
class ArmPlan:
    """One way of running the whole portfolio (see module docstring)."""
    arm: str                          # 'silo' | 'flagship_pool' | 'routed_pool'
    pools: Tuple[PoolAllocation, ...]
    infeasible_classes: Tuple[str, ...]   # class names this arm cannot price

    @property
    def feasible(self) -> bool:
        return (not self.infeasible_classes
                and all(p.feasible for p in self.pools))

    @property
    def fleet_price_per_hr(self) -> Optional[float]:
        if not self.feasible:
            return None
        return sum(p.fleet_price_per_hr for p in self.pools)

    @property
    def n_chips(self) -> Optional[int]:
        if not self.feasible:
            return None
        return sum(p.n_chips for p in self.pools)

    @property
    def n_replicas(self) -> Optional[int]:
        if not self.feasible:
            return None
        return sum(p.n_replicas for p in self.pools)

    @property
    def c_eff(self) -> Optional[float]:
        """Blended $/M output tokens across the whole arm."""
        if not self.feasible:
            return None
        # HeterogeneousMix does not expose total tps; recover it from
        # the identity c_eff = price * 1e6 / (3600 * tps) per pool
        total_tps = sum(
            p.fleet_price_per_hr * 1e6 / (3600.0 * p.c_eff)
            for p in self.pools if math.isfinite(p.c_eff) and p.c_eff > 0)
        if total_tps <= 0:
            return None
        return self.fleet_price_per_hr * 1e6 / (3600.0 * total_tps)

    @property
    def greedy_beaten_pools(self) -> Tuple[PoolAllocation, ...]:
        return tuple(p for p in self.pools
                     if p.certificate and p.certificate.greedy_beaten)

    @property
    def max_gap(self) -> float:
        gaps = [p.certificate.gap for p in self.pools if p.certificate]
        return max(gaps) if gaps else 0.0


@dataclasses.dataclass(frozen=True)
class PortfolioPlan:
    """The full portfolio verdict for one workload on one store."""
    workload: Workload
    arms: Dict[str, ArmPlan]
    routing: "object"                 # RoutingResult (import cycle)
    chip_budget: Optional[int] = None

    @property
    def feasible(self) -> bool:
        return all(a.feasible for a in self.arms.values())

    @property
    def within_chip_budget(self) -> Optional[bool]:
        """Whether the cheapest arm fits the chip budget (None when no
        budget was set or the plan is infeasible)."""
        if self.chip_budget is None:
            return None
        chips = self.arms["routed_pool"].n_chips
        return None if chips is None else chips <= self.chip_budget

    def savings(self) -> Dict[str, Optional[float]]:
        """Fractional $/hr savings: consolidation (silo ->
        flagship_pool), routing (flagship_pool -> routed_pool), and
        total (silo -> routed_pool). None where either arm is
        infeasible — a saving vs. an unpriceable baseline is not a
        number."""
        def frac(a: str, b: str) -> Optional[float]:
            pa = self.arms[a].fleet_price_per_hr
            pb = self.arms[b].fleet_price_per_hr
            if pa is None or pb is None or pa <= 0:
                return None
            return 1.0 - pb / pa
        return {"consolidation": frac("silo", "flagship_pool"),
                "routing": frac("flagship_pool", "routed_pool"),
                "total": frac("silo", "routed_pool")}


def _price_pool(curves_by: Dict[Tuple[str, str],
                                List[DeploymentCurve]],
                model: str, io_shape: str, lam: float,
                class_names: Tuple[str, ...], slo: Optional[SLOTarget],
                max_allocations: int) -> PoolAllocation:
    group = curves_by.get((model, io_shape), [])
    if not group:
        return PoolAllocation(
            model=model, io_shape=io_shape, lam=lam,
            class_names=class_names, feasible=False, mix=None,
            certificate=None,
            why_infeasible=f"no fitted curves for ({model}, {io_shape}) "
                           "in this store")
    mix = greedy_mix(group, lam, slo, max_allocations=max_allocations)
    cert = certify(group, lam, slo, max_allocations=max_allocations,
                   greedy=mix)
    if mix is None or not math.isfinite(mix.c_eff):
        return PoolAllocation(
            model=model, io_shape=io_shape, lam=lam,
            class_names=class_names, feasible=False, mix=None,
            certificate=cert,
            why_infeasible=f"no SLO-feasible allocation serves "
                           f"lam={lam:g} on the measured curves")
    return PoolAllocation(model=model, io_shape=io_shape, lam=lam,
                          class_names=class_names, feasible=True,
                          mix=mix, certificate=cert)


def plan_portfolio(curves: Sequence[DeploymentCurve],
                   workload: Workload,
                   slo: Optional[SLOTarget] = None,
                   max_allocations: int = 16,
                   chip_budget: Optional[int] = None) -> PortfolioPlan:
    """Price `workload` on one store's fitted curves across the three
    arms and certify every pool allocation. Pure and deterministic."""
    from repro.planner.routing import route_workload

    routing = route_workload(workload, curves, slo=slo,
                             max_allocations=max_allocations)
    curves_by: Dict[Tuple[str, str], List[DeploymentCurve]] = {}
    for c in curves:
        curves_by.setdefault((c.model, c.io_shape), []).append(c)

    bad = tuple(d.name for d in routing.infeasible_classes)

    def price(model: str, io_shape: str, lam: float,
              names: Tuple[str, ...]) -> PoolAllocation:
        return _price_pool(curves_by, model, io_shape, lam, names, slo,
                           max_allocations)

    # silo: one dedicated flagship fleet per class, no pooling at all
    silo_pools = tuple(
        price(cls.flagship, cls.io_shape, cls.lam, (cls.name,))
        for cls in workload.classes if cls.name not in bad)

    # flagship_pool / routed_pool: classes blended per (model, io_shape)
    def arm_pools(arm: str) -> Tuple[PoolAllocation, ...]:
        pools = routing.pools(arm)
        return tuple(
            price(model, io_shape,
                  sum(d.lam for d in decisions),
                  tuple(d.name for d in decisions))
            for (model, io_shape), decisions in sorted(pools.items()))

    arms = {
        "silo": ArmPlan(arm="silo", pools=silo_pools,
                        infeasible_classes=bad),
        "flagship_pool": ArmPlan(arm="flagship_pool",
                                 pools=arm_pools("flagship"),
                                 infeasible_classes=bad),
        "routed_pool": ArmPlan(arm="routed_pool",
                               pools=arm_pools("routed"),
                               infeasible_classes=bad),
    }
    return PortfolioPlan(workload=workload, arms=arms, routing=routing,
                         chip_budget=chip_budget)
