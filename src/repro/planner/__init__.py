"""Concurrency-aware capacity planner (ISSUE 5 tentpole).

Everything upstream of this package *reports* the paper's cost surface
C_eff = f(H, M, Q, lambda, L); this package *inverts* it into the
operator's actual decision: given my offered rate lambda and my SLO,
what should I deploy, and at what $/M-tokens?

  curves.py   — fit per-(model, hw, quant, n_chips) lambda -> (C_eff,
                util, TTFT/TPOT percentiles, concurrency) interpolators
                from any consolidated store (the dense `paper_atlas`
                continuum preferred; sparse 7-point ladders accepted
                with extrapolation flags), all through the hardened
                `core.crossover.interp_loglog` primitive.
  optimize.py — enumerate (hw, quant, n_chips) x replica-count
                deployments (each replica serves lambda/R: concurrency
                falls, penalty rises — priced, not hidden), a
                Mélange-style greedy heterogeneous mix across hardware
                generations, SLO feasibility, and the per-API-tier
                crossover verdict via the §6.4-gated `crossover_table`.
  allocate.py — the exact branch-and-bound replica allocator that
                *certifies* `greedy_mix`: same decision space, same
                evaluation, provable optimality gap per instance
                (ISSUE 10).
  portfolio.py— the `Workload` spec (per-class lambda, token budget,
                model-eligibility tiers) and `plan_portfolio`, pricing
                a blended portfolio as per-model silos vs a
                consolidated flagship pool vs a routed pool (ISSUE 10).
  routing.py  — the token-budget-aware router choosing each class's
                cheapest capable model tier off the fitted curves.
  tables.py   — the `planner_tables` JSON payload (embedded in
                `analysis.json` by `experiments.analyze`) + the text
                rendering shared by the CLI and the example.
  __main__.py — the CLI:

    python -m repro.planner --plan paper_atlas --lam 5 --slo-ttft-p90 2000
    python -m repro.planner --plan paper_atlas --portfolio blended_3class

runs from the committed store alone (no engines re-run).
"""
from repro.planner.curves import (  # noqa: F401
    DENSE_MIN_POINTS, DeploymentCurve, curves_by_model, fit_curves,
    penalty_from_util)
from repro.planner.optimize import (  # noqa: F401
    DEFAULT_MAX_REPLICAS, AvailabilityTarget, CapacityPlan,
    DeploymentOption, HeterogeneousMix, MixAllocation, enumerate_options,
    greedy_mix, plan_capacity, rank_options, require_one_model,
    slo_feasible_cap, spares_needed)
from repro.planner.allocate import (  # noqa: F401
    GAP_RTOL, Certificate, ExactMix, certify, exact_mix)
from repro.planner.day import (  # noqa: F401
    curve_lam_cap, day_price_for_curve, day_tables, render_day)
from repro.planner.portfolio import (  # noqa: F401
    ARMS, BLENDED_3CLASS, WORKLOADS, ArmPlan, PoolAllocation,
    PortfolioPlan, Workload, WorkloadClass, plan_portfolio)
from repro.planner.routing import (  # noqa: F401
    RouteDecision, RoutingResult, TierQuote, route_class, route_workload)
from repro.planner.tables import (  # noqa: F401
    PORTFOLIO_LAMS, REFERENCE_LAMS, certification_rows, planner_tables,
    portfolio_row, portfolio_rows, render_certification, render_plan,
    render_plans, render_portfolio)
