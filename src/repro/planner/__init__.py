"""Concurrency-aware capacity planner (ISSUE 5 tentpole).

Everything upstream of this package *reports* the paper's cost surface
C_eff = f(H, M, Q, lambda, L); this package *inverts* it into the
operator's actual decision: given my offered rate lambda and my SLO,
what should I deploy, and at what $/M-tokens?

  curves.py   — fit per-(model, hw, quant, n_chips) lambda -> (C_eff,
                util, TTFT/TPOT percentiles, concurrency) interpolators
                from any consolidated store (the dense `paper_atlas`
                continuum preferred; sparse 7-point ladders accepted
                with extrapolation flags), all through the hardened
                `core.crossover.interp_loglog` primitive.
  optimize.py — enumerate (hw, quant, n_chips) x replica-count
                deployments (each replica serves lambda/R: concurrency
                falls, penalty rises — priced, not hidden), a
                Mélange-style greedy heterogeneous mix across hardware
                generations, SLO feasibility, and the per-API-tier
                crossover verdict via the §6.4-gated `crossover_table`.
  tables.py   — the `planner_tables` JSON payload (embedded in
                `analysis.json` by `experiments.analyze`) + the text
                rendering shared by the CLI and the example.
  __main__.py — the CLI:

    python -m repro.planner --plan paper_atlas --lam 5 --slo-ttft-p90 2000

runs from the committed store alone (no engines re-run).
"""
from repro.planner.curves import (  # noqa: F401
    DENSE_MIN_POINTS, DeploymentCurve, curves_by_model, fit_curves,
    penalty_from_util)
from repro.planner.optimize import (  # noqa: F401
    DEFAULT_MAX_REPLICAS, AvailabilityTarget, CapacityPlan,
    DeploymentOption, HeterogeneousMix, MixAllocation, enumerate_options,
    greedy_mix, plan_capacity, rank_options, slo_feasible_cap,
    spares_needed)
from repro.planner.day import (  # noqa: F401
    curve_lam_cap, day_price_for_curve, day_tables, render_day)
from repro.planner.tables import (  # noqa: F401
    REFERENCE_LAMS, planner_tables, render_plan, render_plans)
