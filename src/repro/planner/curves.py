"""Fitted deployment curves: a store's ladder groups as continuous
lambda -> operating-point functions.

A `DeploymentCurve` wraps one (model, hw, quant, n_chips, io_shape)
ladder group of consolidated RunRecords and exposes every planning-
relevant metric — C_eff, achieved TPS, utilization, in-flight
concurrency and the TTFT/TPOT percentiles — as a function of offered
rate, via `core.crossover.interp_loglog` (the repo's one interpolation
primitive, hardened in this PR: duplicate-lambda knots aggregate,
flat segments and knot hits are exact). On the sim tier the curves are
monotone in lambda by construction (C_eff falls, utilization and latency
rise); `monotone_c_eff` records whether the measured knots actually obey
that, so noisy real-tier stores are flagged instead of silently trusted.

Dense lambda-continuum stores (`paper_atlas`, 25 knots) give the planner
a real curve; sparse 7-point ladders are accepted too — queries between
knots are still interpolation, but `dense` is False and anything outside
the measured span reports `extrapolated(lam) == True` (the paper's
'modeled continuation' caveat, §5.6).

Monte-Carlo ensemble stores (`paper_ensemble`, ISSUE 7: the same ladder
replicated at >= ENSEMBLE_MIN_SEEDS independent arrival seeds) carry
bootstrap confidence `bands` beside the knots: per metric, the
central-95% band of the geometric mean at each lambda, queryable via
`DeploymentCurve.band`. Single-seed stores fit exactly as before with
empty bands.
"""
from __future__ import annotations

import dataclasses
import math
import zlib
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.crossover import aggregate_points, interp_aggregated
from repro.core.records import RunRecord

# a curve is "dense" from this many distinct offered rates on — matches
# analyze.penalty_atlas's min_points, so the same stores qualify
DENSE_MIN_POINTS = 10

# Monte-Carlo ensemble bands (ISSUE 7): a ladder group whose lambdas
# carry at least this many seed replicates (`paper_ensemble` runs 16)
# gets bootstrap confidence bands beside its aggregated knots; below it
# a "band" would just be resampling noise on 1-2 points.
ENSEMBLE_MIN_SEEDS = 3
BOOTSTRAP_RESAMPLES = 200
BAND_QUANTILES = (2.5, 97.5)     # central 95% band
# knot metrics that get bands (penalty bands derive from these two in
# analyze.ensemble_bands; latency percentiles stay point estimates)
BAND_METRICS = ("c_eff", "util", "tps")

# RunRecord fields fitted as lambda -> value interpolators
CURVE_METRICS = ("c_eff", "tps", "util", "mean_inflight",
                 "ttft_p50_ms", "ttft_p90_ms", "ttft_p99_ms",
                 "tpot_p50_ms", "tpot_p99_ms")

# sampling noise near the saturation floor wiggles committed sim-tier
# curves by up to ~1% step-to-step; the monotone flag is for *structural*
# violations (a real-tier store with a genuinely non-monotone curve)
MONOTONE_RTOL = 0.02


@dataclasses.dataclass(frozen=True)
class DeploymentCurve:
    """One deployable footprint's measured lambda continuum."""
    model: str
    hw: str
    quant: str
    n_chips: int
    io_shape: str
    price_per_hr: float         # $/hr for ONE replica of this footprint
    theta_max: float            # saturation output tokens/s (§4.4)
    records: Tuple[RunRecord, ...]      # ladder-ordered source records
    knots: Dict[str, Tuple[Tuple[float, float], ...]]   # metric -> (lam, v)
    # Monte-Carlo confidence bands (ISSUE 7): metric -> ((lam, lo, hi),
    # ...) — the central-95% bootstrap band around each aggregated knot.
    # Empty for single-seed stores; populated when the group carries
    # >= ENSEMBLE_MIN_SEEDS replicates per lambda (`paper_ensemble`).
    bands: Dict[str, Tuple[Tuple[float, float, float], ...]] = \
        dataclasses.field(default_factory=dict)

    @property
    def key(self) -> Tuple:
        return (self.model, self.hw, self.quant, self.n_chips,
                self.io_shape)

    @property
    def label(self) -> str:
        return f"{self.model}/{self.hw}/{self.quant} x{self.n_chips}"

    @property
    def lam_min(self) -> float:
        """Low edge of the demonstrated span: the first *finite-cost*
        knot — a cell that priced to inf (nothing completed) demonstrates
        nothing, so it cannot anchor the span."""
        pts = self.knots.get("c_eff")
        return pts[0][0] if pts else self.records[0].lam

    @property
    def lam_max(self) -> float:
        """The highest offered rate this footprint has *demonstrated* it
        sustains — the last finite-cost knot, so a ladder whose top cell
        collapsed (c_eff = inf, dropped at fit time) caps feasibility at
        the last load that actually served, instead of silently clamping
        prices to it; the planner refuses to promise anything beyond."""
        pts = self.knots.get("c_eff")
        return pts[-1][0] if pts else self.records[-1].lam

    @property
    def n_points(self) -> int:
        return len({r.lam for r in self.records})

    @property
    def dense(self) -> bool:
        return self.n_points >= DENSE_MIN_POINTS

    @property
    def monotone_c_eff(self) -> bool:
        """C_eff non-increasing across the *fitted* knots (the §5 shape)
        within MONOTONE_RTOL per step; False flags a structurally
        non-monotone curve whose interpolants are less trustworthy.
        Judged on the aggregated finite knots the planner actually
        queries — dropped inf-cost cells and duplicate-lambda records
        cannot flip the flag."""
        ceffs = [y for _, y in self.knots.get("c_eff", ())]
        return all(b <= a * (1 + MONOTONE_RTOL)
                   for a, b in zip(ceffs, ceffs[1:]))

    def extrapolated(self, lam: float) -> bool:
        """Outside the measured span: values clamp to the nearest edge and
        are a modeled continuation, not an observed operating point."""
        return lam < self.lam_min or lam > self.lam_max

    def interp(self, metric: str, lam: float) -> float:
        pts = self.knots.get(metric, ())
        if not pts:
            return math.nan
        return interp_aggregated(pts, lam)       # pre-aggregated at fit

    # -- planning metrics ------------------------------------------------
    def c_eff(self, lam: float) -> float:
        """$/M output tokens at offered rate lam (== the PR-4-committed
        store's `interp_c_eff` on this group, knot-exact)."""
        return self.interp("c_eff", lam)

    def tps(self, lam: float) -> float:
        return self.interp("tps", lam)

    def util(self, lam: float) -> float:
        return self.interp("util", lam)

    def penalty(self, lam: float) -> float:
        return penalty_from_util(self.util(lam))

    def operating_point(self, lam: float) -> Dict[str, float]:
        """Every fitted metric interpolated at `lam` (SLO-check input)."""
        return {m: self.interp(m, lam) for m in CURVE_METRICS}

    def band(self, metric: str, lam: float) -> Tuple[float, float]:
        """(lo, hi) of the central-95% bootstrap band at `lam`, each edge
        interpolated through the same log-log primitive as the knots.
        (nan, nan) when this curve carries no ensemble replicates."""
        pts = self.bands.get(metric, ())
        if not pts:
            return (math.nan, math.nan)
        lo = interp_aggregated(tuple((x, l) for x, l, _ in pts), lam)
        hi = interp_aggregated(tuple((x, h) for x, _, h in pts), lam)
        return (lo, hi)


def penalty_from_util(u: float) -> float:
    """1/U with the zero/nan guard — the one underutilization-penalty
    expression both curve queries and option pricing share."""
    return math.inf if not u or not math.isfinite(u) else 1.0 / u


def _metric_value(rec: RunRecord, metric: str) -> float:
    return getattr(rec, metric)


def bootstrap_band(values: Sequence[float], rng: np.random.Generator,
                   n_boot: int = BOOTSTRAP_RESAMPLES,
                   quantiles: Tuple[float, float] = BAND_QUANTILES
                   ) -> Tuple[float, float, float]:
    """(point, lo, hi): the geometric mean of `values` with its
    percentile-bootstrap band — the one band primitive both the planner
    curves and `analyze.ensemble_bands` share. The statistic is the
    geometric mean, matching `aggregate_points`' duplicate-lambda
    policy, so a band always brackets the knot the planner actually
    interpolates. Deterministic given `rng` (callers derive it from the
    group key via CRC32, never from global state)."""
    logv = np.log(np.asarray(values, dtype=float))
    point = float(np.exp(logv.mean()))
    if logv.size == 1:
        return point, point, point      # degenerate but finite
    idx = rng.integers(0, logv.size, size=(n_boot, logv.size))
    means = logv[idx].mean(axis=1)
    lo, hi = np.percentile(means, quantiles)
    return point, float(np.exp(lo)), float(np.exp(hi))


def _band_rng(key: Tuple, metric: str) -> np.random.Generator:
    """Deterministic per-(group, metric) bootstrap stream: same store ->
    same bands, independent of dict order or PYTHONHASHSEED."""
    return np.random.default_rng(
        zlib.crc32(f"{key}|{metric}".encode()))


def fit_bands(key: Tuple, group: Sequence[RunRecord],
              metrics: Sequence[str] = BAND_METRICS,
              min_seeds: int = ENSEMBLE_MIN_SEEDS
              ) -> Dict[str, Tuple[Tuple[float, float, float], ...]]:
    """Bootstrap bands for one ladder group, keyed like `knots`. Only
    lambdas with >= `min_seeds` finite-positive replicate values get a
    band knot (a single-seed lambda inside an ensemble store carries no
    spread information); groups with no such lambda return {}."""
    by_lam: Dict[float, List[RunRecord]] = {}
    for r in group:
        by_lam.setdefault(r.lam, []).append(r)
    if max(len(v) for v in by_lam.values()) < min_seeds:
        return {}
    bands = {}
    for metric in metrics:
        rng = _band_rng(key, metric)
        pts = []
        for lam in sorted(by_lam):
            vals = [_metric_value(r, metric) for r in by_lam[lam]]
            vals = [v for v in vals if math.isfinite(v) and v > 0]
            if len(vals) >= min_seeds:
                _, lo, hi = bootstrap_band(vals, rng)
                pts.append((lam, lo, hi))
        if pts:
            bands[metric] = tuple(pts)
    return bands


def fit_curves(records: Sequence[RunRecord],
               io_shape: Optional[str] = None,
               model: Optional[str] = None) -> List[DeploymentCurve]:
    """Group consolidated records per (model, hw, quant, n_chips,
    io_shape) and fit one DeploymentCurve per group. Non-finite or
    non-positive knot values (e.g. C_eff = inf where nothing completed)
    carry no information in log space and are dropped per metric."""
    groups: Dict[Tuple, List[RunRecord]] = {}
    for r in records:
        if r.mttf > 0.0 or r.retry_max > 0:
            # resilient records (ISSUE 6) measure degraded operation at
            # the same coordinates as their failure-free siblings; the
            # planner's curves price healthy replicas (failure cost
            # enters through the availability/spares model instead)
            continue
        if r.config.startswith("profile:"):
            # non-stationary lambda(t) records (ISSUE 8): `lam` is the
            # profile's nominal mean, not a stationary ladder knot
            continue
        if io_shape is not None and r.io_shape != io_shape:
            continue
        if model is not None and r.model != model:
            continue
        key = (r.model, r.hw, r.quant, r.n_chips, r.io_shape)
        groups.setdefault(key, []).append(r)
    out = []
    for key, group in sorted(groups.items()):
        group.sort(key=lambda r: r.lam)
        knots = {}
        for metric in CURVE_METRICS:
            pts = [(r.lam, _metric_value(r, metric)) for r in group
                   if math.isfinite(_metric_value(r, metric))
                   and _metric_value(r, metric) > 0]
            if pts:
                # aggregate once here (merged stores may duplicate lams);
                # every query then rides the no-aggregation fast path
                knots[metric] = tuple(aggregate_points(pts))
        out.append(DeploymentCurve(
            model=key[0], hw=key[1], quant=key[2], n_chips=key[3],
            io_shape=key[4], price_per_hr=group[0].price_per_hr,
            theta_max=group[0].theta_max, records=tuple(group),
            knots=knots, bands=fit_bands(key, group)))
    return out


def curves_by_model(curves: Sequence[DeploymentCurve]
                    ) -> Dict[str, List[DeploymentCurve]]:
    out: Dict[str, List[DeploymentCurve]] = {}
    for c in curves:
        out.setdefault(c.model, []).append(c)
    return out
