"""Serializable planner artifacts + the text rendering the CLI prints.

`planner_tables(records)` is a pure function of consolidated store
records (same discipline as `analyze.crosshw_tables`, which embeds it in
`analysis.json`): the fitted per-(model, hw, quant, n_chips) curves — the
per-hardware penalty/cost knots a figure would plot — plus the planner's
recommendation at the paper's reference loads. Non-finite floats are
serialized as None so the artifact stays strict-JSON round-trippable.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence

from repro.core.records import RunRecord
from repro.core.slo import SLOTarget
from repro.planner.allocate import certify
from repro.planner.curves import DeploymentCurve, fit_curves
from repro.planner.optimize import (DEFAULT_MAX_REPLICAS, CapacityPlan,
                                    plan_capacity)
from repro.planner.portfolio import (ARMS, BLENDED_3CLASS, PortfolioPlan,
                                     Workload, plan_portfolio)

# the paper's idle / knee-region / saturation reference loads (§5)
REFERENCE_LAMS = (1.0, 10.0, 200.0)
# total portfolio rates the blended-workload verdict is evaluated at
PORTFOLIO_LAMS = REFERENCE_LAMS


def _clean(obj):
    """Recursively replace non-finite floats with None (strict JSON)."""
    if isinstance(obj, float):
        return obj if math.isfinite(obj) else None
    if isinstance(obj, dict):
        return {k: _clean(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_clean(v) for v in obj]
    return obj


def curve_rows(curves: Sequence[DeploymentCurve]) -> List[dict]:
    """The fitted curves as plottable knot tables (per-hw figure input).
    Ensemble-fitted curves (ISSUE 7) additionally carry `bands`: per
    metric the lambda-aligned central-95% bootstrap band, ready to plot
    as a ribbon around the knots."""
    rows = []
    for c in curves:
        rows.append(_clean({
            "model": c.model, "hw": c.hw, "quant": c.quant,
            "n_chips": c.n_chips, "io_shape": c.io_shape,
            "price_per_hr": c.price_per_hr, "theta_max": c.theta_max,
            "dense": c.dense, "monotone_c_eff": c.monotone_c_eff,
            "lam_min": c.lam_min, "lam_max": c.lam_max,
            "lams": [r.lam for r in c.records],
            "c_eff": [r.c_eff for r in c.records],
            "util": [r.util for r in c.records],
            "ttft_p90_ms": [r.ttft_p90_ms for r in c.records],
            "tpot_p99_ms": [r.tpot_p99_ms for r in c.records],
            "bands": {metric: {"lams": [p[0] for p in pts],
                               "lo": [p[1] for p in pts],
                               "hi": [p[2] for p in pts]}
                      for metric, pts in sorted(c.bands.items())},
        }))
    return rows


def plan_row(plan: CapacityPlan) -> dict:
    best = plan.best
    return _clean({
        "model": plan.model, "lam": plan.lam, "io_shape": plan.io_shape,
        "slo": plan.slo.describe() if plan.slo else None,
        "availability": plan.avail.describe() if plan.avail else None,
        "feasible": plan.feasible,
        "n_feasible": len(plan.ranked),
        "n_rejected": len(plan.rejected),
        "best": dataclasses.asdict(best) if best else None,
        "ranked": [dataclasses.asdict(o) for o in plan.ranked],
        "mix": dataclasses.asdict(plan.mix) if plan.mix else None,
        "crossover": plan.crossover,
    })


def certification_rows(curves: Sequence[DeploymentCurve],
                       lams: Sequence[float] = REFERENCE_LAMS,
                       slo: Optional[SLOTarget] = None) -> List[dict]:
    """greedy_mix judged against the exact branch-and-bound optimum for
    every (model, io_shape) group at every reference load. A row with
    ``greedy_beaten`` true is the loud signal the heuristic left money
    on the table — it is always emitted, never filtered."""
    groups: dict = {}
    for c in curves:
        groups.setdefault((c.model, c.io_shape), []).append(c)
    rows = []
    for (model, io_shape), group in sorted(groups.items()):
        for lam in lams:
            cert = certify(group, lam, slo)
            if cert is None:
                rows.append(_clean({
                    "model": model, "io_shape": io_shape, "lam": lam,
                    "feasible": False, "gap": None,
                    "greedy_beaten": False}))
                continue
            rows.append(_clean({
                "model": model, "io_shape": io_shape, "lam": lam,
                "feasible": True,
                "greedy_c_eff": cert.greedy_c_eff,
                "exact_c_eff": cert.exact_c_eff,
                "greedy_label": cert.greedy_label,
                "exact_label": cert.exact_label,
                "gap": cert.gap, "greedy_beaten": cert.greedy_beaten,
                "n_nodes": cert.n_nodes,
                "verdict": cert.describe()}))
    return rows


def _pool_row(pool) -> dict:
    return _clean({
        "model": pool.model, "io_shape": pool.io_shape, "lam": pool.lam,
        "classes": list(pool.class_names), "feasible": pool.feasible,
        "why_infeasible": pool.why_infeasible or None,
        "c_eff": pool.c_eff,
        "fleet_price_per_hr": pool.fleet_price_per_hr,
        "n_replicas": pool.n_replicas, "n_chips": pool.n_chips,
        "label": pool.mix.label if pool.mix else None,
        "gap": pool.certificate.gap if pool.certificate else None,
        "greedy_beaten": bool(pool.certificate.greedy_beaten)
        if pool.certificate else False,
    })


def portfolio_row(plan: PortfolioPlan) -> dict:
    """One portfolio verdict (one workload scale) as strict JSON."""
    arms = {}
    for name in ARMS:
        arm = plan.arms[name]
        arms[name] = {
            "feasible": arm.feasible,
            "fleet_price_per_hr": arm.fleet_price_per_hr,
            "c_eff": arm.c_eff,
            "n_replicas": arm.n_replicas, "n_chips": arm.n_chips,
            "max_gap": arm.max_gap,
            "greedy_beaten_pools": [p.model
                                    for p in arm.greedy_beaten_pools],
            "pools": [_pool_row(p) for p in arm.pools],
            "infeasible_classes": list(arm.infeasible_classes),
        }
    routing = [{
        "class": d.name, "lam": d.lam, "io_shape": d.io_shape,
        "budget_tokens": d.budget_tokens, "flagship": d.flagship,
        "routed": d.routed, "feasible": d.feasible,
        "why_infeasible": d.why_infeasible or None,
        "quotes": [{"model": q.model, "feasible": q.feasible,
                    "c_eff": q.c_eff,
                    "why_infeasible": q.why_infeasible or None}
                   for q in d.quotes],
    } for d in plan.routing.decisions]
    return _clean({
        "workload": plan.workload.name,
        "lam_total": plan.workload.lam_total,
        "classes": [c.to_dict() for c in plan.workload.classes],
        "feasible": plan.feasible,
        "chip_budget": plan.chip_budget,
        "within_chip_budget": plan.within_chip_budget,
        "routing": routing,
        "arms": arms,
        "savings": plan.savings(),
    })


def portfolio_rows(curves: Sequence[DeploymentCurve],
                   workload: Workload = BLENDED_3CLASS,
                   lams: Sequence[float] = PORTFOLIO_LAMS,
                   slo: Optional[SLOTarget] = None,
                   chip_budget: Optional[int] = None) -> List[dict]:
    """The blended-workload verdict at each total rate in `lams`."""
    return [portfolio_row(plan_portfolio(
        curves, workload.scaled(lam), slo=slo, chip_budget=chip_budget))
        for lam in lams]


def planner_tables(records: Sequence[RunRecord],
                   lams: Sequence[float] = REFERENCE_LAMS,
                   slo: Optional[SLOTarget] = None,
                   max_replicas: int = DEFAULT_MAX_REPLICAS,
                   workload: Workload = BLENDED_3CLASS) -> dict:
    """The planner payload `analyze.crosshw_tables` embeds in
    analysis.json: fitted curves + recommendations at reference loads,
    plus the greedy-vs-exact certification table and the portfolio
    verdict for the blended workload."""
    curves = fit_curves(records)
    recommendations = []
    for lam in lams:
        for plan in plan_capacity(curves, lam, slo,
                                  max_replicas=max_replicas):
            recommendations.append(plan_row(plan))
    return {
        "reference_lams": list(lams),
        "curves": curve_rows(curves),
        "recommendations": recommendations,
        "certification": certification_rows(curves, lams, slo),
        "portfolio": portfolio_rows(curves, workload, lams, slo),
    }


# ---------------------------------------------------------------------------
# text rendering (CLI + example)
# ---------------------------------------------------------------------------


def _ms(v: float) -> str:
    return "-" if not math.isfinite(v) else f"{v:.0f}ms"


def _flags(o) -> str:
    out = []
    if o.spares:
        out.append(f"+{o.spares} spare(s) @ {o.availability:.4g} avail")
    if o.extrapolated:
        out.append("extrapolated")
    if not o.dense:
        out.append("sparse-ladder")
    return ",".join(out)


def render_plan(plan: CapacityPlan, top: int = 6) -> str:
    lines = [f"-- {plan.model} @ lambda={plan.lam:g} rps "
             f"({plan.io_shape}) --"]
    if not plan.feasible:
        lines.append("  INFEASIBLE: no measured deployment serves this "
                     "load" + (f" within the SLO ({plan.slo.describe()})"
                               if plan.slo else ""))
        reasons = sorted({o.why_infeasible for o in plan.rejected})
        for why in reasons[:4]:
            lines.append(f"    - {why}")
    else:
        lines.append(
            f"  {'rank':<4} {'deployment':<34} {'R':>2} {'lam/R':>7} "
            f"{'util':>5} {'pen':>6} {'$/hr':>7} {'$/M-tok':>8} "
            f"{'TTFT p90':>9}  flags")
        for i, o in enumerate(plan.ranked[:top], 1):
            dep = f"{o.hw}/{o.quant} x{o.n_chips}"
            lines.append(
                f"  {i:<4} {dep:<34} {o.replicas:>2} "
                f"{o.lam_per_replica:>7.3g} {o.util:>5.2f} "
                f"{o.penalty:>5.1f}x {o.fleet_price_per_hr:>7.2f} "
                f"{o.c_eff:>8.3f} {_ms(o.ttft_p90_ms):>9}  {_flags(o)}")
        if len(plan.ranked) > top:
            lines.append(f"  ... {len(plan.ranked) - top} more feasible "
                         f"option(s)")
        if plan.rejected:
            lines.append(f"  rejected {len(plan.rejected)} option(s): "
                         + "; ".join(sorted(
                             {o.why_infeasible for o in plan.rejected}))[:160])
    if plan.mix is not None and len(plan.mix.allocations) > 1:
        best = plan.best
        verdict = ("beats the best homogeneous fleet"
                   if best and plan.mix.c_eff < best.c_eff else
                   "no cheaper than the best homogeneous fleet")
        lines.append(f"  mix ({verdict}): {plan.mix.label} -> "
                     f"${plan.mix.c_eff:.3f}/M-tok at "
                     f"${plan.mix.fleet_price_per_hr:.2f}/hr")
    lines.append("  vs API tiers (list price, no SLA — §6.4 gate "
                 "acknowledged):")
    best = plan.best
    for tier in plan.crossover:
        lam_star = tier["lambda_star"]
        if best is not None:
            cheaper = best.c_eff <= tier["api_output_per_mtok"]
            now = "self-host CHEAPER" if cheaper else "API cheaper"
        else:
            now = "no feasible self-host point"
        if tier["self_host_always_cheaper"]:
            star = "always cheaper on the measured curve"
        elif math.isinf(lam_star):
            star = "never crosses on the measured curve"
        else:
            star = f"crossover at lam*={lam_star:.2f} rps"
        lines.append(f"    {tier['tier']:<18} "
                     f"(${tier['api_output_per_mtok']:>5.2f}/M-tok): "
                     f"{now} at lam={plan.lam:g}; {star}")
    return "\n".join(lines)


def render_plans(plans: Sequence[CapacityPlan], title: str = "") -> str:
    lines = []
    if title:
        lines.append(f"=== capacity plan: {title} ===")
    if plans and plans[0].slo is not None:
        lines.append(f"SLO target: {plans[0].slo.describe()}")
    if plans and plans[0].avail is not None:
        lines.append(f"availability target: {plans[0].avail.describe()} "
                     "— spares priced as utilization loss")
    for plan in plans:
        lines.append("")
        lines.append(render_plan(plan))
    return "\n".join(lines)


def _money(v: Optional[float]) -> str:
    return "-" if v is None or not math.isfinite(v) else f"{v:.2f}"


def render_portfolio(plan: PortfolioPlan) -> str:
    """The portfolio verdict as the CLI prints it: routing decisions,
    the three arms side by side, certification flags, and the savings
    decomposition."""
    w = plan.workload
    lines = [f"== portfolio: {w.name} @ {w.lam_total:g} rps total =="]
    for d in plan.routing.decisions:
        if not d.feasible:
            lines.append(f"  {d.name:<14} lam={d.lam:<7.3g} "
                         f"INFEASIBLE: {d.why_infeasible}")
            continue
        arrow = (f"{d.flagship} -> {d.routed}" if d.routed_off_flagship
                 else f"stays on {d.flagship}")
        q = d.routed_quote
        lines.append(f"  {d.name:<14} lam={d.lam:<7.3g} "
                     f"budget={d.budget_tokens:<5d} {arrow} "
                     f"(${q.c_eff:.3f}/M-tok standalone)")
    lines.append("")
    lines.append(f"  {'arm':<14} {'$/hr':>8} {'$/M-tok':>8} "
                 f"{'chips':>5} {'repl':>4}  allocation")
    for name in ARMS:
        arm = plan.arms[name]
        if not arm.feasible:
            why = "; ".join(
                [f"{p.model}: {p.why_infeasible}" for p in arm.pools
                 if not p.feasible]
                + [f"{c}: unroutable" for c in arm.infeasible_classes])
            lines.append(f"  {name:<14} INFEASIBLE: {why[:120]}")
            continue
        label = " | ".join(f"{p.model}: {p.mix.label}"
                           for p in arm.pools)
        lines.append(f"  {name:<14} {_money(arm.fleet_price_per_hr):>8} "
                     f"{_money(arm.c_eff):>8} {arm.n_chips:>5} "
                     f"{arm.n_replicas:>4}  {label}")
        for p in arm.greedy_beaten_pools:
            lines.append(f"      !! greedy BEATEN on {p.model}: "
                         f"{p.certificate.describe()}")
    sav = plan.savings()

    def pct(v: Optional[float]) -> str:
        return "n/a" if v is None else f"{100 * v:+.1f}%"
    lines.append(f"  savings on the bill vs silo: "
                 f"consolidation {pct(sav['consolidation'])}, "
                 f"routing {pct(sav['routing'])}, "
                 f"total {pct(sav['total'])}")
    if plan.chip_budget is not None:
        fit = plan.within_chip_budget
        lines.append(f"  chip budget {plan.chip_budget}: "
                     + ("n/a" if fit is None else
                        "routed arm FITS" if fit else
                        "routed arm EXCEEDS budget"))
    return "\n".join(lines)


def render_certification(rows: Sequence[dict]) -> str:
    """The greedy-vs-exact table. Beaten rows shout; optimal rows are
    one quiet line each."""
    lines = ["== greedy_mix vs exact allocator =="]
    beaten = [r for r in rows if r.get("greedy_beaten")]
    for r in rows:
        if not r.get("feasible"):
            lines.append(f"  {r['model']:<16} lam={r['lam']:<7g} "
                         "infeasible for both arms")
            continue
        mark = "!! BEATEN" if r["greedy_beaten"] else "ok"
        gap = r.get("gap")
        lines.append(f"  {r['model']:<16} lam={r['lam']:<7g} "
                     f"gap={gap if gap is not None else float('nan'):.2e} "
                     f"{mark}  greedy={r['greedy_label']}")
    lines.append(f"  {len(beaten)}/{len(rows)} instances beat greedy"
                 if beaten else
                 f"  greedy certified optimal on all {len(rows)} "
                 "instances")
    return "\n".join(lines)
