"""Invert the cost atlas into deployment decisions (ISSUE 5 tentpole).

The paper's thesis is that the operator's offered rate lambda — not a
utilization preset — drives the self-host decision (C_eff = f(H, M, Q,
lambda, L)). The analysis layer *reports* that surface; this module
*inverts* it: given lambda, an io shape and an optional SLO, enumerate
every deployment the store has measured and rank what the operator
should actually buy.

Three decision axes:

* **Footprint** — every (hw, quant, n_chips) the store has curves for.
* **Replica count R** — each replica serves lambda/R. By Little's law the
  per-replica concurrency falls with R, so utilization falls and the
  underutilization penalty rises: a replica split is never cheaper per
  token on a monotone curve (the fleet's $/M-tok at lambda equals one
  replica's C_eff at lambda/R >= C_eff(lambda)), but it is how an
  SLO-infeasible load becomes feasible — the planner prices that
  tradeoff instead of hiding it.
* **Heterogeneous mix** — a Mélange-style (Griggs et al.) greedy pass
  across hardware generations: repeatedly hand the largest
  SLO-feasible slice of the remaining load to the footprint that serves
  it at the lowest $/M-token, so a premium part carries the bulk while a
  cheap part mops up the remainder.

Loads nothing here can demonstrably serve (lambda/R beyond every
measured curve, or no operating point within the SLO) are **rejected
with a reason, never silently priced** — the paper's §6.4 discipline.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.crossover import crossover_table
from repro.core.slo import SLOTarget
from repro.planner.curves import DeploymentCurve, penalty_from_util

DEFAULT_MAX_REPLICAS = 8
# bisection iterations for the SLO-feasible rate cap (log-space; 60
# halvings pin the cap far below any meaningful resolution)
_CAP_ITERS = 60
# give up past this many spares: a replica_availability low enough to
# need more is not a deployable story, it is a broken fleet
_MAX_SPARES = 64


@dataclasses.dataclass(frozen=True)
class AvailabilityTarget:
    """Fleet availability requirement (ISSUE 6): with each replica
    independently up with probability `replica_availability` (its
    steady-state MTTF/(MTTF+MTTR)), the probability that at least the R
    *active* replicas are up must reach `availability`. The planner buys
    N+1-style spares until it does and prices them as pure utilization
    loss: spares burn $/hr without adding delivered tokens."""
    availability: float = 0.999
    replica_availability: float = 0.99

    def __post_init__(self):
        # ISSUE 10 satellite: a target of 1.0+ can never be met by
        # finitely many spares (the binomial tail is < 1 for any p < 1),
        # and a replica availability outside (0, 1] turns the exact
        # binomial into nonsense (negative "probabilities") — both used
        # to loop through all _MAX_SPARES and return garbage quietly.
        if not 0.0 < self.availability < 1.0:
            raise ValueError(
                f"availability must be in (0, 1), got "
                f"{self.availability!r}: a fleet of finitely many "
                f"imperfect replicas can never certify availability "
                f">= 1.0, and <= 0 is not a target")
        if not 0.0 < self.replica_availability <= 1.0:
            raise ValueError(
                f"replica_availability must be in (0, 1], got "
                f"{self.replica_availability!r} (the steady-state "
                f"MTTF/(MTTF+MTTR) of one replica)")

    def describe(self) -> str:
        return (f"availability >= {self.availability:g} "
                f"(replica availability {self.replica_availability:g})")


def _p_at_least(total: int, k: int, p: float) -> float:
    """P(Binomial(total, p) >= k), exact (totals here are tiny)."""
    if k <= 0:
        return 1.0
    return sum(math.comb(total, j) * p ** j * (1.0 - p) ** (total - j)
               for j in range(k, total + 1))


def spares_needed(active: int, target: AvailabilityTarget) -> Optional[int]:
    """Smallest spare count s such that a (active + s)-replica fleet has
    >= active replicas up with probability >= the target; None when even
    `_MAX_SPARES` spares cannot reach it."""
    p = target.replica_availability
    for s in range(_MAX_SPARES + 1):
        if _p_at_least(active + s, active, p) >= target.availability:
            return s
    return None


@dataclasses.dataclass(frozen=True)
class DeploymentOption:
    """One priced deployment: R replicas of a footprint at offered lambda."""
    model: str
    hw: str
    quant: str
    n_chips: int
    replicas: int
    lam: float                  # total offered rate
    lam_per_replica: float
    c_eff: float                # $/M output tokens for the whole fleet
    fleet_price_per_hr: float   # R x the footprint's hourly price
    util: float
    penalty: float
    mean_inflight: float        # per-replica concurrency (Little's law)
    ttft_p90_ms: float
    ttft_p99_ms: float
    tpot_p99_ms: float
    slo_ok: bool
    extrapolated: bool          # lam/R outside the measured span
    dense: bool                 # fitted from a lambda-continuum store
    feasible: bool
    why_infeasible: str = ""
    # availability-aware pricing (ISSUE 6): spares idle behind the R
    # active replicas; c_eff above is already scaled by (R + spares) / R
    spares: int = 0
    availability: float = 1.0   # achieved P(>= R replicas up)

    @property
    def label(self) -> str:
        tag = f"{self.model}/{self.hw}/{self.quant} x{self.n_chips}"
        return tag if self.replicas == 1 else f"{tag} R={self.replicas}"


@dataclasses.dataclass(frozen=True)
class MixAllocation:
    hw: str
    quant: str
    n_chips: int
    lam: float                  # slice of the offered load on this replica
    c_eff: float
    util: float
    price_per_hr: float
    extrapolated: bool


@dataclasses.dataclass(frozen=True)
class HeterogeneousMix:
    """A Mélange-style multi-generation fleet serving one model."""
    model: str
    lam: float
    allocations: Tuple[MixAllocation, ...]
    c_eff: float                # blended $/M output tokens
    fleet_price_per_hr: float
    slo_ok: bool

    @property
    def label(self) -> str:
        groups: List[List[MixAllocation]] = []
        for a in self.allocations:
            tag = (a.hw, a.quant, a.n_chips, f"{a.lam:.3g}")
            if groups and groups[-1][0] == tag:
                groups[-1][1].append(a)
            else:
                groups.append([tag, [a]])
        return " + ".join(
            f"{len(allocs)}x {hw}/{quant} x{chips}@{lam}rps"
            if len(allocs) > 1 else f"{hw}/{quant} x{chips}@{lam}rps"
            for (hw, quant, chips, lam), allocs in groups)


@dataclasses.dataclass
class CapacityPlan:
    """The planner's answer for one model at one offered rate."""
    model: str
    lam: float
    io_shape: str
    slo: Optional[SLOTarget]
    ranked: List[DeploymentOption]      # feasible, cheapest first
    rejected: List[DeploymentOption]    # priced-but-refused, with reasons
    mix: Optional[HeterogeneousMix]
    crossover: List[dict]               # per-API-tier verdict (best curve)
    avail: Optional[AvailabilityTarget] = None

    @property
    def best(self) -> Optional[DeploymentOption]:
        return self.ranked[0] if self.ranked else None

    @property
    def feasible(self) -> bool:
        return bool(self.ranked)


def _option(curve: DeploymentCurve, lam: float, replicas: int,
            slo: Optional[SLOTarget],
            avail: Optional[AvailabilityTarget] = None) -> DeploymentOption:
    lam_per = lam / replicas
    op = curve.operating_point(lam_per)
    # the fleet's $/M-token equals one replica's C_eff at lambda/R:
    # R x price over R x tps(lambda/R) cancels
    cost = op["c_eff"]
    util = op["util"]
    beyond = lam_per > curve.lam_max
    priceable = math.isfinite(cost)
    slo_ok = slo.ok(op) if slo is not None else True
    spares, achieved, avail_ok = 0, 1.0, True
    if avail is not None:
        s = spares_needed(replicas, avail)
        if s is None:
            avail_ok = False
            achieved = _p_at_least(replicas + _MAX_SPARES, replicas,
                                   avail.replica_availability)
        else:
            spares = s
            achieved = _p_at_least(replicas + s, replicas,
                                   avail.replica_availability)
            # spares are pure utilization loss: tokens still come from
            # the R active replicas while (R + s) replicas burn $/hr
            cost = cost * (replicas + s) / replicas
    feasible = not beyond and priceable and slo_ok and avail_ok
    why = ""
    if beyond:
        why = (f"lambda/R = {lam_per:g} beyond the measured range "
               f"(<= {curve.lam_max:g} rps demonstrated)")
    elif not priceable:
        why = "no finite-cost operating point measured on this curve"
    elif not slo_ok:
        why = f"violates SLO ({slo.describe()})"
    elif not avail_ok:
        why = (f"cannot reach {avail.describe()} with <= {_MAX_SPARES} "
               "spares")
    return DeploymentOption(
        model=curve.model, hw=curve.hw, quant=curve.quant,
        n_chips=curve.n_chips, replicas=replicas, lam=lam,
        lam_per_replica=lam_per, c_eff=cost,
        fleet_price_per_hr=(replicas + spares) * curve.price_per_hr,
        util=util, penalty=penalty_from_util(util),
        mean_inflight=op["mean_inflight"],
        ttft_p90_ms=op["ttft_p90_ms"], ttft_p99_ms=op["ttft_p99_ms"],
        tpot_p99_ms=op["tpot_p99_ms"],
        slo_ok=slo_ok, extrapolated=curve.extrapolated(lam_per),
        dense=curve.dense, feasible=feasible, why_infeasible=why,
        spares=spares, availability=achieved)


def enumerate_options(curves: Sequence[DeploymentCurve], lam: float,
                      slo: Optional[SLOTarget] = None,
                      max_replicas: int = DEFAULT_MAX_REPLICAS,
                      avail: Optional[AvailabilityTarget] = None
                      ) -> List[DeploymentOption]:
    """Every (footprint, R) candidate for one model at offered rate lam,
    priced; feasibility and reasons attached, no ranking applied. With an
    `avail` target each option carries its spare count and its c_eff is
    the per-*delivered*-token cost including the idle spares."""
    out = []
    for curve in curves:
        for replicas in range(1, max_replicas + 1):
            out.append(_option(curve, lam, replicas, slo, avail))
            if lam / replicas <= curve.lam_min:
                # further splits only push deeper into the idle edge:
                # same clamped metrics, strictly more hardware
                break
    return out


def rank_options(options: Sequence[DeploymentOption]
                 ) -> Tuple[List[DeploymentOption], List[DeploymentOption]]:
    """(feasible cheapest-first, rejected). Ties break toward fewer
    replicas, then lower fleet price, then the stable label order."""
    feasible = sorted(
        (o for o in options if o.feasible),
        key=lambda o: (o.c_eff, o.replicas, o.fleet_price_per_hr, o.label))
    rejected = [o for o in options if not o.feasible]
    return feasible, rejected


def require_one_model(curves: Sequence[DeploymentCurve]
                      ) -> Tuple[str, str]:
    """Validate that `curves` all describe one (model, io_shape) — the
    homogeneity every single-workload allocator here assumes (a replica
    serves one model; operating points measured under different workload
    shapes never blend). Returns the (model, io_shape) pair. A mixed
    list used to be silently labeled with ``curves[0].model`` (ISSUE 10
    satellite); now it raises, and the portfolio entry points
    (`planner.allocate`, `planner.portfolio`) reuse the same gate."""
    if not curves:
        raise ValueError("empty curve group: nothing to allocate")
    pairs = {(c.model, c.io_shape) for c in curves}
    if len(pairs) > 1:
        raise ValueError(
            "heterogeneous curve group: one allocation serves one "
            f"(model, io_shape), got {sorted(pairs)} — split per model "
            "with repro.planner.portfolio instead")
    return next(iter(pairs))


def _slo_ok_at(curve: DeploymentCurve, slo: SLOTarget, lam: float) -> bool:
    """SLO check interpolating only the constrained metrics (the bisection
    hot path probes this ~60x per curve)."""
    return slo.ok({name: curve.interp(name, lam)
                   for name, _ in slo.bounds()})


def slo_feasible_cap(curve: DeploymentCurve,
                     slo: Optional[SLOTarget]) -> float:
    """The highest offered rate one replica of `curve` demonstrably serves
    within the SLO: lam_max when unconstrained, else a log-space bisection
    over the fitted operating points; 0.0 when even the idle edge violates
    the target (this footprint cannot serve this SLA at any load)."""
    if slo is None or _slo_ok_at(curve, slo, curve.lam_max):
        return curve.lam_max
    if not _slo_ok_at(curve, slo, curve.lam_min):
        return 0.0
    lo, hi = math.log(curve.lam_min), math.log(curve.lam_max)
    for _ in range(_CAP_ITERS):
        mid = (lo + hi) / 2
        if _slo_ok_at(curve, slo, math.exp(mid)):
            lo = mid
        else:
            hi = mid
    return math.exp(lo)


def greedy_mix(curves: Sequence[DeploymentCurve], lam: float,
               slo: Optional[SLOTarget] = None,
               max_allocations: int = 16) -> Optional[HeterogeneousMix]:
    """Mélange-style greedy heterogeneous allocation for one model.

    Repeatedly assign the remaining load's largest SLO-feasible slice to
    a fresh replica of whichever footprint serves *that slice* at the
    lowest $/M-token. With a full load remaining that picks the cheapest
    saturated part (the bulk carrier); for the tail remainder it picks
    whichever part prices the scraps cheapest — heterogeneity emerges
    exactly when the tail is cheaper on a smaller generation. Returns
    None when no footprint can take any load within the SLO, or when the
    load cannot be exhausted within `max_allocations` replicas.
    """
    model, _ = require_one_model(curves)
    caps = {c.key: slo_feasible_cap(c, slo) for c in curves}
    usable = [c for c in curves if caps[c.key] > 0]
    if not usable:
        return None
    assigned: List[Tuple[DeploymentCurve, float]] = []
    remaining = lam
    for _ in range(max_allocations):
        if remaining <= 0:
            break
        best_curve, best_serve, best_cost = None, 0.0, math.inf
        for c in usable:
            serve = min(remaining, caps[c.key])
            cost = c.c_eff(serve)
            if cost < best_cost:
                best_curve, best_serve, best_cost = c, serve, cost
        if best_curve is None:
            return None                 # nothing prices finitely
        assigned.append((best_curve, best_serve))
        remaining -= best_serve
    if remaining > 1e-9 * lam:
        return None                     # could not exhaust the load
    allocations = tuple(MixAllocation(
        hw=c.hw, quant=c.quant, n_chips=c.n_chips, lam=serve,
        c_eff=c.c_eff(serve), util=c.util(serve),
        price_per_hr=c.price_per_hr, extrapolated=c.extrapolated(serve))
        for c, serve in assigned)
    total_price = sum(c.price_per_hr for c, _ in assigned)
    total_tps = sum(c.tps(serve) for c, serve in assigned)
    blended = math.inf if total_tps <= 0 else \
        total_price * 1e6 / (3600.0 * total_tps)
    return HeterogeneousMix(
        model=model, lam=lam, allocations=allocations,
        c_eff=blended, fleet_price_per_hr=total_price, slo_ok=True)


def _finite_or_inf(v: float) -> float:
    return v if math.isfinite(v) else math.inf


def plan_capacity(curves: Sequence[DeploymentCurve], lam: float,
                  slo: Optional[SLOTarget] = None,
                  max_replicas: int = DEFAULT_MAX_REPLICAS,
                  avail: Optional[AvailabilityTarget] = None
                  ) -> List[CapacityPlan]:
    """One CapacityPlan per (model, io_shape) present in `curves`, in
    that order — operating points measured under different workload
    shapes never compete inside one ranking."""
    by_group: Dict[Tuple[str, str], List[DeploymentCurve]] = {}
    for c in curves:
        by_group.setdefault((c.model, c.io_shape), []).append(c)
    plans = []
    for (model, io_shape), group in sorted(by_group.items()):
        options = enumerate_options(group, lam, slo,
                                    max_replicas=max_replicas,
                                    avail=avail)
        ranked, rejected = rank_options(options)
        # the greedy mix is not availability-aware (it has no replica
        # structure to buy spares against) — suppressing it under an
        # availability target keeps the ranking honest
        mix = greedy_mix(group, lam, slo) \
            if len(group) > 1 and avail is None else None
        # the API verdict belongs to the curve the operator would deploy
        if ranked:
            key = (model, ranked[0].hw, ranked[0].quant,
                   ranked[0].n_chips, io_shape)
            best_curve = next(c for c in group if c.key == key)
        else:
            best_curve = min(
                group, key=lambda c: _finite_or_inf(c.c_eff(c.lam_max)))
        crossover = crossover_table(best_curve.records,
                                    accept_slo_mismatch=True)
        plans.append(CapacityPlan(
            model=model, lam=lam, io_shape=io_shape, slo=slo,
            ranked=ranked, rejected=rejected, mix=mix,
            crossover=crossover, avail=avail))
    return plans
