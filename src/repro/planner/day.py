"""Time-aware planning: price a 24h lambda(t) profile from fitted curves.

`analyze.diurnal_tables` prices the committed day scenarios from their
dedicated stores (exact stationary measurements at every per-replica
rate a trajectory visits). This module is the planner-side counterpart:
it prices a `DayScenario`'s profile against ANY store's fitted
`DeploymentCurve`s — interpolating per-replica throughput from whatever
ladder the store measured — so an operator can ask "what does my day of
traffic cost on each footprint, static vs autoscaled?" from e.g. the
dense `paper_atlas` store without running new cells.

Interpolated prices inherit the curves' caveats: queries outside a
curve's demonstrated span are clamped to its edge knots and the result
is flagged `interpolated_beyond_span` (the §5.6 'modeled continuation'
caveat, time-resolved). The exact-store path in `analyze` has no such
caveat — its ladder measures every visited rate by construction.

    PYTHONPATH=src python -m repro.planner --plan paper_atlas \\
        --day paper_day
"""
from __future__ import annotations

import math
from typing import Dict, List, Sequence

from repro.planner.curves import DeploymentCurve
from repro.planner.tables import _clean
from repro.serving.arrivals import IO_SHAPES
from repro.serving.autoscale import DayScenario, price_day

# tokens a completed request delivers, per io_shape — converts a curve's
# saturation throughput (tok/s) into a per-replica request capacity
OUT_TOKENS = {shape: float(out) for shape, (_, out) in IO_SHAPES.items()}


def curve_lam_cap(curve: DeploymentCurve) -> float:
    """Demonstrated per-replica request capacity: saturation tokens/s
    over tokens per request. Falls back to the demonstrated lam span's
    top for io_shapes without a fixed output length."""
    out_tok = OUT_TOKENS.get(curve.io_shape)
    if out_tok:
        return curve.theta_max / out_tok
    return curve.lam_max


def day_price_for_curve(curve: DeploymentCurve, scenario: DayScenario
                        ) -> Dict:
    """Price the scenario's day on one footprint: static fleet sized for
    the peak vs every scenario policy, per-replica throughput
    interpolated from the curve (clamped to its demonstrated span)."""
    lam_cap = curve_lam_cap(curve)

    def tps_at(lam_per: float) -> float:
        return curve.tps(min(max(lam_per, curve.lam_min), curve.lam_max))

    from repro.serving.autoscale import (simulate_policy, static_size,
                                         static_windows)
    replicas = static_size(scenario.peak_lam, lam_cap, scenario.util_sla)
    trajs = {"static": static_windows(replicas, scenario.window_rates,
                                      scenario.window_s)}
    for pol in scenario.policies:
        trajs[pol.name] = simulate_policy(pol, scenario.window_rates,
                                          scenario.window_s, lam_cap)

    beyond = set()
    policies = []
    for pname, traj in trajs.items():
        for fw in traj:
            if fw.lam > 0 and fw.serving > 0 \
                    and curve.extrapolated(fw.lam / fw.serving):
                beyond.add(pname)
        priced = price_day(traj, price_per_hr=curve.price_per_hr,
                           tps_at=tps_at, lam_cap=lam_cap)
        policies.append({"policy": pname, **priced})
    finite = [p for p in policies if math.isfinite(p["day_c_eff"])]
    winner = min(finite, key=lambda p: p["day_c_eff"]) if finite else None
    static = next(p for p in policies if p["policy"] == "static")
    saving = None
    if winner is not None and math.isfinite(static["day_c_eff"]) \
            and static["day_c_eff"] > 0:
        saving = 1.0 - winner["day_c_eff"] / static["day_c_eff"]
    return _clean({
        "scenario": scenario.name,
        "deployment": curve.label,
        "model": curve.model, "hw": curve.hw, "quant": curve.quant,
        "n_chips": curve.n_chips, "io_shape": curve.io_shape,
        "price_per_hr": curve.price_per_hr, "lam_cap": lam_cap,
        "static_replicas": replicas,
        "window_s": scenario.window_s,
        "n_windows": len(scenario.window_rates),
        "peak_lam": scenario.peak_lam,
        "policies": policies,
        "winner": winner["policy"] if winner else None,
        "autoscaling_pays": bool(winner) and winner["policy"] != "static",
        "winner_saving_vs_static": saving,
        "interpolated_beyond_span": sorted(beyond),
        "dense_curve": curve.dense,
    })


def day_tables(curves: Sequence[DeploymentCurve], scenario: DayScenario
               ) -> List[Dict]:
    """One `day_price_for_curve` row per fitted curve, cheapest day
    first — the store-wide answer to "who should serve this day"."""
    rows = [day_price_for_curve(c, scenario) for c in curves]
    rows.sort(key=lambda r: (
        r["policies"] and min(p["day_c_eff"] or math.inf
                              for p in r["policies"]) or math.inf))
    return rows


def render_day(rows: Sequence[Dict], title: str = "") -> str:
    lines = []
    if title:
        lines.append(f"=== cost of a day of traffic: {title} ===")
    if rows:
        r0 = rows[0]
        lines.append(f"profile: {r0['n_windows']} windows x "
                     f"{r0['window_s']:g} s, peak {r0['peak_lam']:g} req/s")
    for row in rows:
        lines.append("")
        lines.append(f"-- {row['deployment']} "
                     f"(static R={row['static_replicas']}, lam_cap "
                     f"{row['lam_cap']:.3g} req/s/replica) --")
        lines.append(f"  {'policy':<10} {'repl-hrs':>8} {'daily $':>8} "
                     f"{'Mtok':>7} {'day C_eff':>9} {'peak pen':>8} "
                     f"{'idle':>4}")
        for p in row["policies"]:
            pen = f"{p['peak_penalty']:.2f}x" \
                if p["peak_penalty"] is not None else "n/a"
            dce = f"{p['day_c_eff']:.4f}" \
                if p["day_c_eff"] is not None else "inf"
            lines.append(f"  {p['policy']:<10} {p['replica_hours']:>8.2f} "
                         f"{p['daily_cost_usd']:>8.3f} "
                         f"{p['daily_tokens'] / 1e6:>7.2f} {dce:>9} "
                         f"{pen:>8} {p['idle_windows']:>4d}")
        if row["winner"]:
            tag = f"cheapest: {row['winner']}"
            if row["winner_saving_vs_static"]:
                tag += (f" ({100 * row['winner_saving_vs_static']:.0f}% "
                        f"below static)")
            if not row["autoscaling_pays"]:
                tag += "  [autoscaling does NOT pay]"
            lines.append(f"  -> {tag}")
        if row["interpolated_beyond_span"]:
            lines.append("  (caveat: per-replica rates clamped to the "
                         "measured span for: "
                         + ", ".join(row["interpolated_beyond_span"]) + ")")
    return "\n".join(lines)
