"""Time-aware planning: price a 24h lambda(t) profile from fitted curves.

`analyze.diurnal_tables` prices the committed day scenarios from their
dedicated stores (exact stationary measurements at every per-replica
rate a trajectory visits). This module is the planner-side counterpart:
it prices a `DayScenario`'s profile against ANY store's fitted
`DeploymentCurve`s — interpolating per-replica throughput from whatever
ladder the store measured — so an operator can ask "what does my day of
traffic cost on each footprint, static vs autoscaled?" from e.g. the
dense `paper_atlas` store without running new cells.

Interpolated prices inherit the curves' caveats: queries outside a
curve's demonstrated span are clamped to its edge knots and the result
is flagged `interpolated_beyond_span` (the §5.6 'modeled continuation'
caveat, time-resolved). The exact-store path in `analyze` has no such
caveat — its ladder measures every visited rate by construction.

    PYTHONPATH=src python -m repro.planner --plan paper_atlas \\
        --day paper_day
"""
from __future__ import annotations

import math
from typing import Dict, List, Sequence

from repro.planner.curves import DeploymentCurve
from repro.planner.tables import _clean
from repro.serving.arrivals import IO_SHAPES
from repro.serving.autoscale import (DayScenario, SLOAutoscalePolicy,
                                     price_day)

# tokens a completed request delivers, per io_shape — converts a curve's
# saturation throughput (tok/s) into a per-replica request capacity
OUT_TOKENS = {shape: float(out) for shape, (_, out) in IO_SHAPES.items()}


def curve_lam_cap(curve: DeploymentCurve) -> float:
    """Demonstrated per-replica request capacity: saturation tokens/s
    over tokens per request. Falls back to the demonstrated lam span's
    top for io_shapes without a fixed output length."""
    out_tok = OUT_TOKENS.get(curve.io_shape)
    if out_tok:
        return curve.theta_max / out_tok
    return curve.lam_max


def day_price_for_curve(curve: DeploymentCurve, scenario: DayScenario,
                        slo_policy: SLOAutoscalePolicy = None) -> Dict:
    """Price the scenario's day on one footprint: static fleet sized for
    the peak vs every scenario policy, per-replica throughput
    interpolated from the curve (clamped to its demonstrated span).

    With `slo_policy` (ISSUE 9 tentpole b) an SLO-aware trajectory is
    added head-to-head: it scales on the curve's fitted TTFT p90 at the
    previous window's realized per-replica rate, and every policy row
    gains `slo_violation_minutes` scored against the same fitted p90 —
    so the table shows both what each controller costs AND how long it
    leaves the day out of SLO."""
    lam_cap = curve_lam_cap(curve)

    def clamp(lam_per: float) -> float:
        return min(max(lam_per, curve.lam_min), curve.lam_max)

    def tps_at(lam_per: float) -> float:
        return curve.tps(clamp(lam_per))

    def ttft_p90_at(lam_per: float) -> float:
        return curve.interp("ttft_p90_ms", clamp(lam_per))

    from repro.serving.autoscale import (simulate_policy,
                                         simulate_slo_policy,
                                         slo_violation_minutes,
                                         static_size, static_windows)
    replicas = static_size(scenario.peak_lam, lam_cap, scenario.util_sla)
    trajs = {"static": static_windows(replicas, scenario.window_rates,
                                      scenario.window_s)}
    for pol in scenario.policies:
        trajs[pol.name] = simulate_policy(pol, scenario.window_rates,
                                          scenario.window_s, lam_cap)
    if slo_policy is not None:
        trajs[slo_policy.name] = simulate_slo_policy(
            slo_policy, scenario.window_rates, scenario.window_s,
            ttft_p90_at)

    beyond = set()
    policies = []
    for pname, traj in trajs.items():
        for fw in traj:
            if fw.lam > 0 and fw.serving > 0 \
                    and curve.extrapolated(fw.lam / fw.serving):
                beyond.add(pname)
        priced = price_day(traj, price_per_hr=curve.price_per_hr,
                           tps_at=tps_at, lam_cap=lam_cap)
        if slo_policy is not None:
            priced["slo_violation_minutes"] = slo_violation_minutes(
                traj, ttft_p90_at, slo_policy.ttft_p90_slo_ms)
        policies.append({"policy": pname, **priced})
    finite = [p for p in policies if math.isfinite(p["day_c_eff"])]
    winner = min(finite, key=lambda p: p["day_c_eff"]) if finite else None
    static = next(p for p in policies if p["policy"] == "static")
    saving = None
    if winner is not None and math.isfinite(static["day_c_eff"]) \
            and static["day_c_eff"] > 0:
        saving = 1.0 - winner["day_c_eff"] / static["day_c_eff"]
    slo_extra = {}
    if slo_policy is not None:
        tightest = min(policies, key=lambda p: (
            p["slo_violation_minutes"], p["day_c_eff"] or math.inf))
        slo_extra = {"ttft_p90_slo_ms": slo_policy.ttft_p90_slo_ms,
                     "tightest_slo_policy": tightest["policy"]}
    return _clean({
        "scenario": scenario.name,
        "deployment": curve.label,
        "model": curve.model, "hw": curve.hw, "quant": curve.quant,
        "n_chips": curve.n_chips, "io_shape": curve.io_shape,
        "price_per_hr": curve.price_per_hr, "lam_cap": lam_cap,
        "static_replicas": replicas,
        "window_s": scenario.window_s,
        "n_windows": len(scenario.window_rates),
        "peak_lam": scenario.peak_lam,
        "policies": policies,
        "winner": winner["policy"] if winner else None,
        "autoscaling_pays": bool(winner) and winner["policy"] != "static",
        "winner_saving_vs_static": saving,
        "interpolated_beyond_span": sorted(beyond),
        "dense_curve": curve.dense,
        **slo_extra,
    })


def day_tables(curves: Sequence[DeploymentCurve], scenario: DayScenario,
               slo_policy: SLOAutoscalePolicy = None) -> List[Dict]:
    """One `day_price_for_curve` row per fitted curve, cheapest day
    first — the store-wide answer to "who should serve this day"."""
    rows = [day_price_for_curve(c, scenario, slo_policy) for c in curves]
    rows.sort(key=lambda r: (
        r["policies"] and min(p["day_c_eff"] or math.inf
                              for p in r["policies"]) or math.inf))
    return rows


def render_day(rows: Sequence[Dict], title: str = "") -> str:
    lines = []
    if title:
        lines.append(f"=== cost of a day of traffic: {title} ===")
    if rows:
        r0 = rows[0]
        lines.append(f"profile: {r0['n_windows']} windows x "
                     f"{r0['window_s']:g} s, peak {r0['peak_lam']:g} req/s")
    for row in rows:
        lines.append("")
        lines.append(f"-- {row['deployment']} "
                     f"(static R={row['static_replicas']}, lam_cap "
                     f"{row['lam_cap']:.3g} req/s/replica) --")
        slo_col = any("slo_violation_minutes" in p
                      for p in row["policies"])
        hdr = (f"  {'policy':<10} {'repl-hrs':>8} {'daily $':>8} "
               f"{'Mtok':>7} {'day C_eff':>9} {'peak pen':>8} "
               f"{'idle':>4}")
        if slo_col:
            hdr += f" {'SLO-viol min':>12}"
        lines.append(hdr)
        for p in row["policies"]:
            pen = f"{p['peak_penalty']:.2f}x" \
                if p["peak_penalty"] is not None else "n/a"
            dce = f"{p['day_c_eff']:.4f}" \
                if p["day_c_eff"] is not None else "inf"
            line = (f"  {p['policy']:<10} {p['replica_hours']:>8.2f} "
                    f"{p['daily_cost_usd']:>8.3f} "
                    f"{p['daily_tokens'] / 1e6:>7.2f} {dce:>9} "
                    f"{pen:>8} {p['idle_windows']:>4d}")
            if slo_col:
                line += f" {p.get('slo_violation_minutes', 0.0):>12.1f}"
            lines.append(line)
        if row["winner"]:
            tag = f"cheapest: {row['winner']}"
            if row["winner_saving_vs_static"]:
                tag += (f" ({100 * row['winner_saving_vs_static']:.0f}% "
                        f"below static)")
            if not row["autoscaling_pays"]:
                tag += "  [autoscaling does NOT pay]"
            lines.append(f"  -> {tag}")
        if row.get("tightest_slo_policy"):
            lines.append(f"  -> tightest SLO (p90 TTFT <= "
                         f"{row['ttft_p90_slo_ms']:g} ms): "
                         f"{row['tightest_slo_policy']}")
        if row["interpolated_beyond_span"]:
            lines.append("  (caveat: per-replica rates clamped to the "
                         "measured span for: "
                         + ", ".join(row["interpolated_beyond_span"]) + ")")
    return "\n".join(lines)
