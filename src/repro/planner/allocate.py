"""Exact replica allocation: a branch-and-bound search that certifies
`greedy_mix` (ISSUE 10 tentpole, part c).

`greedy_mix` (PR 5) is the Mélange-style heuristic: repeatedly hand the
largest SLO-feasible slice of the remaining load to whichever footprint
prices it cheapest. Mélange (Griggs et al., PAPERS.md) observes that at
realistic fleet sizes the underlying allocation problem is a small
integer program that can be solved *exactly* — so instead of trusting
the greedy pass, this module searches its entire decision space and
reports the optimality gap.

The decision space (identical to greedy's closure): a fleet serving one
(model, io_shape) at offered rate lambda is a multiset of *full*
replicas — each loaded to its SLO-feasible cap — plus at most one
*tail* replica carrying the remainder (every greedy step serves
``min(remaining, cap)``, so a partial replica always ends the
sequence). The objective is the same blended $/M-token both arms
evaluate identically::

    c_eff = total_price_per_hr * 1e6 / (3600 * sum_i tps_i(load_i))

Because greedy's solutions are a subset of this space and both sides
share one evaluation function, the certified gap is nonnegative by
construction; a negative gap is a search bug and raises instead of
being clamped away.

The search is a depth-first branch-and-bound over footprint counts,
ordered deterministically by curve key. The prune is the mediant bound:
every replica added from a node onward costs at least
``u_min = min_f price_f / tps_f(cap_f)`` dollars per token (tps is
non-decreasing in load, so a replica is never cheaper per token below
its cap), and ``(P + dP) / (T + dT) >= min(P/T, dP/dT)`` — so once
``min(P/T, u_min)`` cannot beat the incumbent, the whole subtree is
dead. Store-scale instances (<= ~8 footprints, <= 16 replicas) explore
a few hundred nodes.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

from repro.core.slo import SLOTarget
from repro.planner.curves import DeploymentCurve
from repro.planner.optimize import (HeterogeneousMix, MixAllocation,
                                    greedy_mix, require_one_model,
                                    slo_feasible_cap)

# a greedy-vs-exact ratio within this relative tolerance is float noise
# (the two sides sum the same terms in different orders), reported as a
# clean 0.0 gap; anything beyond it is a real greedy loss
GAP_RTOL = 1e-9
# replica budget shared with greedy_mix so the certificate compares
# like against like
DEFAULT_MAX_ALLOCATIONS = 16
# $/M-tok from a $/hr-over-tokens/s ratio
_MTOK_PER_HR = 1e6 / 3600.0


@dataclasses.dataclass(frozen=True)
class ExactMix:
    """The provably cheapest replica multiset for one (model, io_shape)
    at one offered rate — same shape as `HeterogeneousMix`, plus the
    search observability fields."""
    model: str
    io_shape: str
    lam: float
    allocations: Tuple[MixAllocation, ...]
    c_eff: float                # blended $/M output tokens
    fleet_price_per_hr: float
    total_tps: float
    n_nodes: int                # branch-and-bound nodes explored

    @property
    def n_replicas(self) -> int:
        return len(self.allocations)

    @property
    def n_chips(self) -> int:
        return sum(a.n_chips for a in self.allocations)

    @property
    def label(self) -> str:
        groups: List[list] = []
        for a in self.allocations:
            tag = (a.hw, a.quant, a.n_chips, f"{a.lam:.3g}")
            if groups and groups[-1][0] == tag:
                groups[-1][1] += 1
            else:
                groups.append([tag, 1])
        return " + ".join(
            (f"{n}x " if n > 1 else "") + f"{hw}/{quant} x{chips}@{lam}rps"
            for (hw, quant, chips, lam), n in groups)


@dataclasses.dataclass(frozen=True)
class Certificate:
    """greedy_mix judged against the exact optimum on one instance.
    ``gap`` is greedy's relative cost excess (0.0 = certified optimal,
    inf = greedy found nothing where exact did); ``greedy_beaten`` is
    the loud flag every table row must surface, never hide."""
    model: str
    io_shape: str
    lam: float
    greedy_c_eff: float         # inf when greedy returned None
    exact_c_eff: float
    greedy_label: str
    exact_label: str
    gap: float
    greedy_beaten: bool
    n_nodes: int

    def describe(self) -> str:
        if math.isinf(self.gap):
            return (f"greedy found NO allocation at lam={self.lam:g}; "
                    f"exact serves it at ${self.exact_c_eff:.4f}/M-tok "
                    f"({self.exact_label})")
        if self.greedy_beaten:
            return (f"greedy BEATEN by {100 * self.gap:.2f}% at "
                    f"lam={self.lam:g}: {self.greedy_label} -> "
                    f"{self.exact_label}")
        return f"greedy optimal at lam={self.lam:g} (gap 0)"


def _mix_allocation(curve: DeploymentCurve, load: float) -> MixAllocation:
    return MixAllocation(
        hw=curve.hw, quant=curve.quant, n_chips=curve.n_chips, lam=load,
        c_eff=curve.c_eff(load), util=curve.util(load),
        price_per_hr=curve.price_per_hr,
        extrapolated=curve.extrapolated(load))


def exact_mix(curves: Sequence[DeploymentCurve], lam: float,
              slo: Optional[SLOTarget] = None,
              max_allocations: int = DEFAULT_MAX_ALLOCATIONS
              ) -> Optional[ExactMix]:
    """The cheapest blended-$/M-token replica multiset serving `lam`
    within the SLO, found by exhaustive branch-and-bound over full-cap
    footprint counts + one tail. None when no multiset of at most
    `max_allocations` SLO-feasible replicas covers the load (the same
    refusal greedy_mix makes, proven rather than heuristic)."""
    model, io_shape = require_one_model(curves)
    fleet = []
    for c in sorted(curves, key=lambda c: c.key):
        cap = slo_feasible_cap(c, slo)
        if cap <= 0:
            continue
        tps_cap = c.tps(cap)
        if math.isfinite(tps_cap) and tps_cap > 0 \
                and math.isfinite(c.price_per_hr):
            fleet.append((c, cap, tps_cap))
    if not fleet:
        return None
    eps = 1e-9 * lam
    # mediant-bound density: no replica anywhere prices below this $/tok
    u_min = min(c.price_per_hr / tps_cap for c, _, tps_cap in fleet)
    best_ratio = math.inf          # $/hr per token/s (c_eff / _MTOK_PER_HR)
    best: Optional[Tuple[Tuple[int, float], ...]] = None
    n_nodes = 0

    def close(stack: Tuple[Tuple[int, float], ...], price: float,
              tps: float, remaining: float, used: int) -> None:
        """Try every way of finishing the current full-replica multiset:
        done already, or one tail replica carrying the remainder."""
        nonlocal best_ratio, best
        if remaining <= eps:
            if tps > 0 and price / tps < best_ratio:
                best_ratio, best = price / tps, stack
            return
        if used >= max_allocations:
            return
        for idx, (c, cap, _) in enumerate(fleet):
            if cap + eps < remaining:
                continue                   # cannot be a tail, only a full
            tail_tps = c.tps(remaining)
            total = tps + tail_tps
            if total > 0 and (price + c.price_per_hr) / total < best_ratio:
                best_ratio = (price + c.price_per_hr) / total
                best = stack + ((idx, remaining),)

    def dfs(start: int, stack: Tuple[Tuple[int, float], ...],
            price: float, tps: float, remaining: float, used: int) -> None:
        nonlocal n_nodes
        n_nodes += 1
        close(stack, price, tps, remaining, used)
        if used >= max_allocations:
            return
        # mediant prune: every further replica costs >= u_min per token,
        # so no descendant can price below min(current ratio, u_min)
        floor = u_min if tps <= 0 else min(price / tps, u_min)
        if floor >= best_ratio:
            return
        for idx in range(start, len(fleet)):
            c, cap, tps_cap = fleet[idx]
            if cap < remaining - eps:      # room for a full replica
                dfs(idx, stack + ((idx, cap),), price + c.price_per_hr,
                    tps + tps_cap, remaining - cap, used + 1)

    dfs(0, (), 0.0, 0.0, lam, 0)
    if best is None:
        return None
    allocations = tuple(_mix_allocation(fleet[idx][0], load)
                        for idx, load in best)
    price = sum(fleet[idx][0].price_per_hr for idx, _ in best)
    total_tps = sum(fleet[idx][0].tps(load) for idx, load in best)
    return ExactMix(
        model=model, io_shape=io_shape, lam=lam, allocations=allocations,
        c_eff=price * _MTOK_PER_HR / total_tps,
        fleet_price_per_hr=price, total_tps=total_tps, n_nodes=n_nodes)


def certify(curves: Sequence[DeploymentCurve], lam: float,
            slo: Optional[SLOTarget] = None,
            max_allocations: int = DEFAULT_MAX_ALLOCATIONS,
            greedy: Optional[HeterogeneousMix] = None
            ) -> Optional[Certificate]:
    """Run greedy_mix and exact_mix on one instance and report the
    optimality gap. None when the instance is infeasible for both (the
    exact search space contains greedy's, so exact-None implies
    greedy-None; the reverse — greedy blind, exact feasible — is a real
    finding and reports gap = inf). Pass `greedy` to certify an
    already-computed mix without re-running the heuristic."""
    if greedy is None:
        greedy = greedy_mix(curves, lam, slo,
                            max_allocations=max_allocations)
    exact = exact_mix(curves, lam, slo, max_allocations=max_allocations)
    if exact is None:
        if greedy is not None:
            raise RuntimeError(
                "exact allocator found nothing where greedy_mix "
                f"did (lam={lam:g}) — the search space must contain "
                "every greedy solution; this is a bug")
        return None
    greedy_c = greedy.c_eff if greedy is not None else math.inf
    gap = greedy_c / exact.c_eff - 1.0
    if gap < -GAP_RTOL:
        raise RuntimeError(
            f"greedy_mix ({greedy_c:.6g}) undercut the 'exact' optimum "
            f"({exact.c_eff:.6g}) at lam={lam:g} — the branch-and-bound "
            "missed part of its own space; this is a bug")
    if abs(gap) <= GAP_RTOL:
        gap = 0.0
    return Certificate(
        model=exact.model, io_shape=exact.io_shape, lam=lam,
        greedy_c_eff=greedy_c, exact_c_eff=exact.c_eff,
        greedy_label=greedy.label if greedy is not None else "-",
        exact_label=exact.label, gap=gap,
        greedy_beaten=gap > GAP_RTOL, n_nodes=exact.n_nodes)
