"""CLI: invert a committed experiment store into deployment decisions.

    PYTHONPATH=src python -m repro.planner --plan paper_atlas --lam 5
    PYTHONPATH=src python -m repro.planner --plan paper_atlas --lam 5 \
        --slo-ttft-p90 2000 --slo-tpot-p99 100
    PYTHONPATH=src python -m repro.planner --plan paper_crosshw --lam 40 \
        --model mixtral-8x7b --json plan.json
    PYTHONPATH=src python -m repro.planner --plan paper_atlas \
        --portfolio blended_3class --lam 10
    PYTHONPATH=src python -m repro.planner --plan paper_atlas \
        --portfolio workload.json --chip-budget 8

Runs from the store alone — no engines are re-run. Exit status 3 when no
model has any feasible deployment at the requested load, or — in
portfolio mode — when any workload class is infeasible (the planner
refuses to silently price an SLO-infeasible load, paper §6.4).
"""
from __future__ import annotations

import argparse
import json

from repro.core.slo import SLOTarget
from repro.experiments.analyze import load_store_records
from repro.planner.curves import fit_curves
from repro.planner.optimize import (DEFAULT_MAX_REPLICAS,
                                    AvailabilityTarget, plan_capacity)
from repro.planner.tables import plan_row, render_plans


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--plan", required=True,
                    help="experiment plan whose store to invert "
                         "(e.g. paper_atlas)")
    ap.add_argument("--lam", type=float, default=None,
                    help="offered rate, req/s (stationary planning)")
    ap.add_argument("--day", default=None, metavar="SCENARIO",
                    help="price a 24h lambda(t) scenario (e.g. paper_day) "
                         "against every fitted curve: static-vs-autoscaled "
                         "day cost per footprint (time-aware planning, "
                         "ISSUE 8); combine with --slo-ttft-p90 to add an "
                         "SLO-aware autoscaler head-to-head (ISSUE 9)")
    ap.add_argument("--flash-crowd", action="store_true",
                    help="render the store's overload verdict: graceful "
                         "degradation vs blind shedding on paired MMPP "
                         "burst cells (requires a flash-crowd store, e.g. "
                         "--plan paper_flashcrowd; ISSUE 9)")
    ap.add_argument("--portfolio", default=None, metavar="SPEC",
                    help="price a multi-class workload portfolio: SPEC "
                         "is a registered workload name (e.g. "
                         "blended_3class) or a path to a workload JSON "
                         "({'classes': [{name, lam, tiers, io_shape, "
                         "budget_tokens}, ...]}). Prints the silo vs "
                         "consolidated vs routed verdict with greedy-vs-"
                         "exact certification; with --lam, the class mix "
                         "is rescaled to that total rate (ISSUE 10)")
    ap.add_argument("--chip-budget", type=int, default=None, metavar="N",
                    help="portfolio mode: flag whether the routed arm "
                         "fits within N total chips")
    ap.add_argument("--model", default=None,
                    help="restrict to one model (default: every model "
                         "in the store)")
    ap.add_argument("--io-shape", default="chat")
    ap.add_argument("--max-replicas", type=int,
                    default=DEFAULT_MAX_REPLICAS)
    ap.add_argument("--slo-ttft-p90", type=float, default=None,
                    metavar="MS")
    ap.add_argument("--slo-ttft-p99", type=float, default=None,
                    metavar="MS")
    ap.add_argument("--slo-tpot-p99", type=float, default=None,
                    metavar="MS")
    ap.add_argument("--availability", type=float, default=None,
                    metavar="P",
                    help="fleet availability target (e.g. 0.999): buy "
                         "N+1-style spares per option and price them as "
                         "utilization loss on $/M-delivered-tok")
    ap.add_argument("--replica-availability", type=float, default=0.99,
                    metavar="P",
                    help="per-replica steady-state availability "
                         "MTTF/(MTTF+MTTR) used for the spare "
                         "calculation (default 0.99)")
    ap.add_argument("--root", default=None,
                    help="store root (default results/experiments)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the per-model plans as JSON")
    args = ap.parse_args(argv)
    if args.portfolio is not None:
        # portfolio is its own mode; --lam becomes the optional total
        # rate the class mix is rescaled to
        if args.day is not None or args.flash_crowd:
            ap.error("--portfolio cannot be combined with --day or "
                     "--flash-crowd")
    else:
        modes = sum((args.lam is not None, args.day is not None,
                     args.flash_crowd))
        if modes != 1:
            ap.error("exactly one of --lam (stationary), --day "
                     "(lambda(t)), --flash-crowd (overload verdict) or "
                     "--portfolio (workload portfolio) is required")

    records = load_store_records(args.plan, args.root)
    if not records:
        raise SystemExit(
            f"no completed cells in store for {args.plan!r}; run: "
            f"python -m repro.experiments.run --plan {args.plan}")

    slo = None
    if (args.slo_ttft_p90 is not None or args.slo_ttft_p99 is not None
            or args.slo_tpot_p99 is not None):
        slo = SLOTarget(ttft_p90_ms=args.slo_ttft_p90,
                        ttft_p99_ms=args.slo_ttft_p99,
                        tpot_p99_ms=args.slo_tpot_p99)

    if args.portfolio is not None:
        import os
        from repro.planner.portfolio import (WORKLOADS, Workload,
                                             plan_portfolio)
        from repro.planner.tables import portfolio_row, render_portfolio
        if args.portfolio in WORKLOADS:
            workload = WORKLOADS[args.portfolio]
        elif os.path.exists(args.portfolio):
            workload = Workload.from_json(args.portfolio)
        else:
            raise SystemExit(
                f"unknown workload {args.portfolio!r}: not a registered "
                f"name {sorted(WORKLOADS)} and not a JSON file")
        if args.lam is not None:
            workload = workload.scaled(args.lam)
        curves = fit_curves(records, model=args.model)
        if not curves:
            raise SystemExit(
                f"store for {args.plan!r} has no fitted curves")
        plan = plan_portfolio(curves, workload, slo=slo,
                              chip_budget=args.chip_budget)
        print(render_portfolio(plan))
        if args.json:
            with open(args.json, "w") as f:
                json.dump(portfolio_row(plan), f, indent=1,
                          sort_keys=True)
            print(f"\nportfolio verdict written to {args.json}")
        if not plan.feasible:
            raise SystemExit(3)
        return

    if args.flash_crowd:
        from repro.experiments.analyze import (overload_tables,
                                               overload_verdict,
                                               render_overload)
        pairs = overload_tables(records)
        if not pairs:
            raise SystemExit(
                f"store for {args.plan!r} has no flash-crowd pairs "
                f"(no 'flash:<scenario>:<arm>' cells); run: python -m "
                f"repro.experiments.run --plan paper_flashcrowd")
        print(render_overload(pairs))
        verdict = overload_verdict(pairs)
        if args.json:
            with open(args.json, "w") as f:
                json.dump({"pairs": pairs, "verdict": verdict}, f,
                          indent=1, sort_keys=True)
            print(f"\noverload tables written to {args.json}")
        if not verdict["degradation_wins"]:
            raise SystemExit(3)
        return

    curves = fit_curves(records, io_shape=args.io_shape, model=args.model)
    if not curves:
        raise SystemExit(
            f"store for {args.plan!r} has no curves for "
            f"model={args.model!r} io_shape={args.io_shape!r}")

    if args.day is not None:
        from repro.planner.day import day_tables, render_day
        from repro.serving.autoscale import (DAY_SCENARIOS,
                                             SLOAutoscalePolicy)
        if args.day not in DAY_SCENARIOS:
            raise SystemExit(f"unknown day scenario {args.day!r}; known: "
                             f"{sorted(DAY_SCENARIOS)}")
        scenario = DAY_SCENARIOS[args.day]
        slo_pol = None
        if args.slo_ttft_p90 is not None:
            # mechanics matched to the scenario's reactive policy so the
            # head-to-head isolates the SIGNAL (p90 vs util), not the lag
            slo_pol = SLOAutoscalePolicy(
                name="slo-p90", ttft_p90_slo_ms=args.slo_ttft_p90,
                scale_up_lag_s=scenario.window_s,
                warmup_s=scenario.window_s,
                scale_down_hold_s=2 * scenario.window_s,
                max_replicas=args.max_replicas)
        rows = day_tables(curves, scenario, slo_pol)
        print(render_day(rows, title=f"{args.plan} x {args.day}"))
        if args.json:
            with open(args.json, "w") as f:
                json.dump(rows, f, indent=1, sort_keys=True)
            print(f"\nday tables written to {args.json}")
        return

    avail = None
    if args.availability is not None:
        avail = AvailabilityTarget(
            availability=args.availability,
            replica_availability=args.replica_availability)

    plans = plan_capacity(curves, args.lam, slo,
                          max_replicas=args.max_replicas, avail=avail)
    print(render_plans(
        plans, title=f"{args.plan} @ lambda={args.lam:g} rps"))
    if args.json:
        with open(args.json, "w") as f:
            json.dump([plan_row(p) for p in plans], f, indent=1,
                      sort_keys=True)
        print(f"\nplans written to {args.json}")
    if not any(p.feasible for p in plans):
        raise SystemExit(3)


if __name__ == "__main__":
    main()
