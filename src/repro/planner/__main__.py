"""CLI: invert a committed experiment store into deployment decisions.

    PYTHONPATH=src python -m repro.planner --plan paper_atlas --lam 5
    PYTHONPATH=src python -m repro.planner --plan paper_atlas --lam 5 \
        --slo-ttft-p90 2000 --slo-tpot-p99 100
    PYTHONPATH=src python -m repro.planner --plan paper_crosshw --lam 40 \
        --model mixtral-8x7b --json plan.json

Runs from the store alone — no engines are re-run. Exit status 3 when no
model has any feasible deployment at the requested load (the planner
refuses to silently price an SLO-infeasible load, paper §6.4).
"""
from __future__ import annotations

import argparse
import json

from repro.core.slo import SLOTarget
from repro.experiments.analyze import load_store_records
from repro.planner.curves import fit_curves
from repro.planner.optimize import (DEFAULT_MAX_REPLICAS,
                                    AvailabilityTarget, plan_capacity)
from repro.planner.tables import plan_row, render_plans


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--plan", required=True,
                    help="experiment plan whose store to invert "
                         "(e.g. paper_atlas)")
    ap.add_argument("--lam", type=float, default=None,
                    help="offered rate, req/s (stationary planning)")
    ap.add_argument("--day", default=None, metavar="SCENARIO",
                    help="price a 24h lambda(t) scenario (e.g. paper_day) "
                         "against every fitted curve: static-vs-autoscaled "
                         "day cost per footprint (time-aware planning, "
                         "ISSUE 8)")
    ap.add_argument("--model", default=None,
                    help="restrict to one model (default: every model "
                         "in the store)")
    ap.add_argument("--io-shape", default="chat")
    ap.add_argument("--max-replicas", type=int,
                    default=DEFAULT_MAX_REPLICAS)
    ap.add_argument("--slo-ttft-p90", type=float, default=None,
                    metavar="MS")
    ap.add_argument("--slo-ttft-p99", type=float, default=None,
                    metavar="MS")
    ap.add_argument("--slo-tpot-p99", type=float, default=None,
                    metavar="MS")
    ap.add_argument("--availability", type=float, default=None,
                    metavar="P",
                    help="fleet availability target (e.g. 0.999): buy "
                         "N+1-style spares per option and price them as "
                         "utilization loss on $/M-delivered-tok")
    ap.add_argument("--replica-availability", type=float, default=0.99,
                    metavar="P",
                    help="per-replica steady-state availability "
                         "MTTF/(MTTF+MTTR) used for the spare "
                         "calculation (default 0.99)")
    ap.add_argument("--root", default=None,
                    help="store root (default results/experiments)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the per-model plans as JSON")
    args = ap.parse_args(argv)
    if (args.lam is None) == (args.day is None):
        ap.error("exactly one of --lam (stationary) or --day (lambda(t)) "
                 "is required")

    records = load_store_records(args.plan, args.root)
    if not records:
        raise SystemExit(
            f"no completed cells in store for {args.plan!r}; run: "
            f"python -m repro.experiments.run --plan {args.plan}")
    curves = fit_curves(records, io_shape=args.io_shape, model=args.model)
    if not curves:
        raise SystemExit(
            f"store for {args.plan!r} has no curves for "
            f"model={args.model!r} io_shape={args.io_shape!r}")

    if args.day is not None:
        from repro.planner.day import day_tables, render_day
        from repro.serving.autoscale import DAY_SCENARIOS
        if args.day not in DAY_SCENARIOS:
            raise SystemExit(f"unknown day scenario {args.day!r}; known: "
                             f"{sorted(DAY_SCENARIOS)}")
        rows = day_tables(curves, DAY_SCENARIOS[args.day])
        print(render_day(rows, title=f"{args.plan} x {args.day}"))
        if args.json:
            with open(args.json, "w") as f:
                json.dump(rows, f, indent=1, sort_keys=True)
            print(f"\nday tables written to {args.json}")
        return

    slo = None
    if (args.slo_ttft_p90 is not None or args.slo_ttft_p99 is not None
            or args.slo_tpot_p99 is not None):
        slo = SLOTarget(ttft_p90_ms=args.slo_ttft_p90,
                        ttft_p99_ms=args.slo_ttft_p99,
                        tpot_p99_ms=args.slo_tpot_p99)

    avail = None
    if args.availability is not None:
        avail = AvailabilityTarget(
            availability=args.availability,
            replica_availability=args.replica_availability)

    plans = plan_capacity(curves, args.lam, slo,
                          max_replicas=args.max_replicas, avail=avail)
    print(render_plans(
        plans, title=f"{args.plan} @ lambda={args.lam:g} rps"))
    if args.json:
        with open(args.json, "w") as f:
            json.dump([plan_row(p) for p in plans], f, indent=1,
                      sort_keys=True)
        print(f"\nplans written to {args.json}")
    if not any(p.feasible for p in plans):
        raise SystemExit(3)


if __name__ == "__main__":
    main()
