"""Token-budget-aware model-tier routing (ISSUE 10 tentpole, part b).

Production fleets put a router in front of a model portfolio: each
request class declares what it *needs* (a decode token budget, a
workload shape, a list of model tiers capable enough to serve it,
flagship first) and the router decides which tier the class should even
hit — Token-Budget-Aware Pool Routing (PAPERS.md) applied to the
planner's fitted curves instead of a live pool.

Two gates, both loud (§6.4 discipline — refuse, never silently price):

* **budget gate** — a class whose declared decode budget exceeds the
  measured decode length of its io_shape cannot be priced off these
  curves at all: no committed cell demonstrates that workload.
* **capability/feasibility gate** — a tier with no fitted curves for
  the class's io_shape, or whose curves cannot serve the class's rate
  within the SLO (per `greedy_mix`), is quoted as infeasible with the
  reason attached.

Among the surviving tiers the router picks the cheapest blended
$/M-token quote (ties break toward the more capable tier, i.e. earlier
in the class's list). Every decision also carries the paired
"route everything to the flagship" baseline arm — tiers[0] — so the
portfolio verdict can split its saving into a routing part and a
consolidation part.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.slo import SLOTarget
from repro.planner.curves import DeploymentCurve
from repro.planner.optimize import greedy_mix
from repro.serving.arrivals import IO_SHAPES


@dataclasses.dataclass(frozen=True)
class TierQuote:
    """One eligible model tier priced for one class (standalone)."""
    model: str
    flagship: bool
    feasible: bool
    c_eff: float                # blended $/M-tok for the class alone
    fleet_price_per_hr: float
    n_replicas: int
    why_infeasible: str = ""


@dataclasses.dataclass(frozen=True)
class RouteDecision:
    """Where one workload class goes, and why."""
    name: str
    lam: float
    io_shape: str
    budget_tokens: int
    flagship: str               # tiers[0] — the baseline arm's target
    quotes: Tuple[TierQuote, ...]
    routed: Optional[str]       # cheapest feasible tier; None = nowhere
    feasible: bool
    why_infeasible: str = ""

    @property
    def routed_off_flagship(self) -> bool:
        return self.feasible and self.routed != self.flagship

    @property
    def routed_quote(self) -> Optional[TierQuote]:
        return next((q for q in self.quotes if q.model == self.routed),
                    None) if self.routed else None

    @property
    def flagship_quote(self) -> Optional[TierQuote]:
        return next((q for q in self.quotes
                     if q.model == self.flagship), None)


@dataclasses.dataclass(frozen=True)
class RoutingResult:
    decisions: Tuple[RouteDecision, ...]

    @property
    def feasible(self) -> bool:
        return all(d.feasible for d in self.decisions)

    @property
    def infeasible_classes(self) -> List[RouteDecision]:
        return [d for d in self.decisions if not d.feasible]

    @property
    def n_routed_off_flagship(self) -> int:
        return sum(1 for d in self.decisions if d.routed_off_flagship)

    def pools(self, arm: str = "routed"
              ) -> Dict[Tuple[str, str], List[RouteDecision]]:
        """Feasible classes grouped by the (model, io_shape) pool they
        share under `arm` ('routed' or 'flagship') — the consolidation
        unit the exact allocator prices as one blended rate."""
        if arm not in ("routed", "flagship"):
            raise ValueError(f"unknown routing arm {arm!r}")
        out: Dict[Tuple[str, str], List[RouteDecision]] = {}
        for d in self.decisions:
            if not d.feasible:
                continue
            model = d.routed if arm == "routed" else d.flagship
            out.setdefault((model, d.io_shape), []).append(d)
        return out


def _quote(tier_curves: Sequence[DeploymentCurve], model: str,
           flagship: bool, lam: float, slo: Optional[SLOTarget],
           max_allocations: int) -> TierQuote:
    if not tier_curves:
        return TierQuote(
            model=model, flagship=flagship, feasible=False,
            c_eff=math.inf, fleet_price_per_hr=math.inf, n_replicas=0,
            why_infeasible="no fitted curves for this (model, io_shape) "
                           "in the store")
    mix = greedy_mix(tier_curves, lam, slo,
                     max_allocations=max_allocations)
    if mix is None or not math.isfinite(mix.c_eff):
        why = (f"no SLO-feasible allocation demonstrably serves "
               f"lam={lam:g}" + (f" within {slo.describe()}" if slo
                                 else " on the measured curves"))
        return TierQuote(model=model, flagship=flagship, feasible=False,
                        c_eff=math.inf, fleet_price_per_hr=math.inf,
                        n_replicas=0, why_infeasible=why)
    return TierQuote(model=model, flagship=flagship, feasible=True,
                     c_eff=mix.c_eff,
                     fleet_price_per_hr=mix.fleet_price_per_hr,
                     n_replicas=len(mix.allocations))


def route_class(cls, curves: Sequence[DeploymentCurve],
                slo: Optional[SLOTarget] = None,
                max_allocations: int = 16) -> RouteDecision:
    """Route one workload class (any object with name/lam/io_shape/
    budget_tokens/tiers attributes — `portfolio.WorkloadClass` in
    practice) across its eligible tiers."""
    flagship = cls.tiers[0]
    measured = IO_SHAPES.get(cls.io_shape)
    if measured is not None and cls.budget_tokens > measured[1]:
        # the budget gate: these curves were measured at io_shape's
        # decode length; a class needing more is NOT demonstrated
        why = (f"token budget {cls.budget_tokens} exceeds the measured "
               f"decode length {measured[1]} of io_shape "
               f"{cls.io_shape!r} — no committed cell demonstrates "
               f"this class")
        return RouteDecision(
            name=cls.name, lam=cls.lam, io_shape=cls.io_shape,
            budget_tokens=cls.budget_tokens, flagship=flagship,
            quotes=(), routed=None, feasible=False, why_infeasible=why)
    by_model: Dict[str, List[DeploymentCurve]] = {}
    for c in curves:
        if c.io_shape == cls.io_shape:
            by_model.setdefault(c.model, []).append(c)
    quotes = tuple(
        _quote(by_model.get(tier, []), tier, tier == flagship, cls.lam,
               slo, max_allocations)
        for tier in cls.tiers)
    # cheapest feasible tier; ties break toward the earlier (more
    # capable) tier because min() keeps the first minimum
    feasible = [q for q in quotes if q.feasible]
    chosen = min(feasible, key=lambda q: q.c_eff) if feasible else None
    why = "" if chosen else (
        "no eligible tier can serve this class: "
        + "; ".join(f"{q.model}: {q.why_infeasible}" for q in quotes))
    return RouteDecision(
        name=cls.name, lam=cls.lam, io_shape=cls.io_shape,
        budget_tokens=cls.budget_tokens, flagship=flagship,
        quotes=quotes, routed=chosen.model if chosen else None,
        feasible=chosen is not None, why_infeasible=why)


def route_workload(workload, curves: Sequence[DeploymentCurve],
                   slo: Optional[SLOTarget] = None,
                   max_allocations: int = 16) -> RoutingResult:
    """Route every class of a `portfolio.Workload` over the fitted
    curves of one store. Pure and deterministic; infeasible classes are
    carried with reasons, never dropped."""
    return RoutingResult(decisions=tuple(
        route_class(cls, curves, slo, max_allocations)
        for cls in workload.classes))
