"""Prometheus-style metrics registry with text exposition.

The live cost meter (repro.core.meter) consumes the *rendered text*, not
engine internals — reproducing the paper's design point that the meter
scrapes a /metrics endpoint any vLLM-compatible dashboard could also read.
Metric names mirror vLLM's (vllm:generation_tokens_total etc.) so the meter
is engine-agnostic.
"""
from __future__ import annotations

import bisect
import math
from typing import Dict, List, Optional, Tuple

_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
            1.0, 2.5, 5.0, 10.0, 25.0, 60.0, 120.0, math.inf)


class Histogram:
    """Bucketed histogram with amortized bookkeeping: observe() is an O(1)
    append; bucket counts and the sorted view are folded lazily on first
    read (render/percentile), which the engine hot loop never hits."""

    def __init__(self):
        self.clear()

    def clear(self):
        self.total = 0.0
        self.n = 0
        self.samples: List[float] = []
        self._counts: List[int] = [0] * len(_BUCKETS)
        self._folded = 0                             # samples already bucketed
        self._sorted: Optional[List[float]] = None   # amortized-sort cache

    def observe(self, v: float):
        self.total += v
        self.n += 1
        self.samples.append(v)
        self._sorted = None

    @property
    def counts(self) -> List[int]:
        for v in self.samples[self._folded:]:
            self._counts[bisect.bisect_left(_BUCKETS, v)] += 1
        self._folded = len(self.samples)
        return self._counts

    def percentile(self, q: float) -> float:
        if not self.samples:
            return float("nan")
        if self._sorted is None:
            self._sorted = sorted(self.samples)
        s = self._sorted
        idx = min(len(s) - 1, max(0, int(round(q / 100.0 * (len(s) - 1)))))
        return s[idx]


class MetricsRegistry:
    """Counters, gauges and histograms; render() emits Prometheus text."""

    def __init__(self, labels: Optional[Dict[str, str]] = None):
        self.labels = labels or {}
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self.hists: Dict[str, Histogram] = {}

    def inc(self, name: str, v: float = 1.0):
        self.counters[name] = self.counters.get(name, 0.0) + v

    def set(self, name: str, v: float):
        self.gauges[name] = v

    def observe(self, name: str, v: float):
        self.hist(name).observe(v)

    def hist(self, name: str) -> Histogram:
        """Get-or-create a histogram; callers on hot paths may keep the
        returned object (reset() clears contents in place, so bound
        references stay live)."""
        h = self.hists.get(name)
        if h is None:
            h = self.hists[name] = Histogram()
        return h

    def reset(self):
        """Drop all recorded state (counters, gauges, histograms) — the
        warmup/measurement boundary in sweep protocols. Unlike clearing
        `counters`/`hists` piecemeal, this also flushes gauges so no
        stale time/running-request readings leak into the window.
        Histograms are cleared in place so pre-bound references survive."""
        self.counters.clear()
        self.gauges.clear()
        for h in self.hists.values():
            h.clear()

    def get(self, name: str) -> float:
        if name in self.counters:
            return self.counters[name]
        return self.gauges.get(name, 0.0)

    def percentile(self, name: str, q: float) -> float:
        h = self.hists.get(name)
        return h.percentile(q) if h else float("nan")

    def _label_str(self) -> str:
        if not self.labels:
            return ""
        inner = ",".join(f'{k}="{v}"' for k, v in sorted(self.labels.items()))
        return "{" + inner + "}"

    def render(self) -> str:
        """Prometheus text exposition format."""
        ls = self._label_str()
        out = []
        for name, v in sorted(self.counters.items()):
            out.append(f"# TYPE {name} counter")
            out.append(f"{name}{ls} {v}")
        for name, v in sorted(self.gauges.items()):
            out.append(f"# TYPE {name} gauge")
            out.append(f"{name}{ls} {v}")
        for name, h in sorted(self.hists.items()):
            out.append(f"# TYPE {name} histogram")
            cum = 0
            for b, c in zip(_BUCKETS, h.counts):
                cum += c
                le = "+Inf" if math.isinf(b) else repr(b)
                sep = "," if self.labels else ""
                lbl = self._label_str()[:-1] + sep + f'le="{le}"}}' if ls \
                    else f'{{le="{le}"}}'
                out.append(f"{name}_bucket{lbl} {cum}")
            out.append(f"{name}_sum{ls} {h.total}")
            out.append(f"{name}_count{ls} {h.n}")
        return "\n".join(out) + "\n"


def parse_prometheus(text: str) -> Dict[str, float]:
    """Minimal scraper: plain counter/gauge samples (labels stripped)."""
    vals: Dict[str, float] = {}
    for line in text.splitlines():
        if not line or line.startswith("#"):
            continue
        try:
            key, v = line.rsplit(" ", 1)
            name = key.split("{")[0]
            vals[name] = float(v)
        except ValueError:
            continue
    return vals
