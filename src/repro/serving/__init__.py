"""Continuous-batching serving runtime with a paged KV cache.

One engine, two execution tiers (DESIGN §3 "CPU container strategy"):
  * RealExecutor — jitted JAX on the local device; wall-clock step timing.
  * SimExecutor  — calibrated TPU step-time model; virtual-clock timing.
Both tiers share the scheduler, paging, arrival processes and the
Prometheus-style metrics registry the cost meter scrapes.

`fleet` (ISSUE 4) is the third scheduler path: a struct-of-arrays
simulator that runs B independent sim-tier cells as lanes of one
vectorized event loop, bit-identical to the scalar fast-forward engine.
"""
from repro.serving.arrivals import (  # noqa: F401
    ArrivalSpec, RateProfile, gamma_arrivals, poisson_arrivals,
    profile_arrivals, synth_arrays, synth_requests)
from repro.serving.autoscale import (  # noqa: F401
    DAY_SCENARIOS, AutoscalePolicy, DayScenario, Deployment, FleetWindow,
    meter_day_report, price_day, simulate_policy, static_size,
    static_windows)
from repro.serving.engine import Engine, EngineConfig  # noqa: F401
from repro.serving.executors import RealExecutor, SimExecutor  # noqa: F401
from repro.serving.fleet import (  # noqa: F401
    FleetEngine, FleetPoint, FleetStepModel, fleet_run_points)
from repro.serving.metrics import MetricsRegistry  # noqa: F401
from repro.serving.request import Request, RequestState  # noqa: F401
from repro.serving.resilience import (  # noqa: F401
    FailureEvent, FailureSpec, FailureStream, FailureTimeline, RetryPolicy,
    as_failure_events)
