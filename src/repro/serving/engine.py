"""Continuous-batching engine: FCFS admission + chunked prefill + paged KV.

The scheduling loop mirrors vLLM's continuous batching: every step admits
as many queued prompts as page capacity and the prefill token budget allow,
prefills them (recording TTFT), then decodes one token for every running
slot. Time is whatever the executor says it is — wall-clock (RealExecutor)
or the TPU model clock (SimExecutor) — so the same queueing dynamics
produce both measured and simulated C_eff(lambda) curves.

Fault handling: `fail_running()` simulates a replica/slot loss; affected
requests release pages and re-queue (bounded retries), matching the
straggler/failure story in DESIGN §5.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serving.kv_cache import PageManager
from repro.serving.metrics import MetricsRegistry
from repro.serving.request import Request, RequestState


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8
    page_size: int = 16
    num_pages: int = 1024
    max_pages_per_seq: int = 128
    prefill_token_budget: int = 2048    # chunked-prefill budget per step
    max_prefill_reqs: int = 8
    max_retries: int = 2


class Engine:
    def __init__(self, cfg: EngineConfig, executor, metrics=None):
        self.cfg = cfg
        self.ex = executor
        self.pm = PageManager(cfg.num_pages, cfg.page_size, cfg.max_batch,
                              cfg.max_pages_per_seq)
        self.metrics = metrics or MetricsRegistry()
        self.t = 0.0
        self.slot_req: Dict[int, Request] = {}
        self.slot_tokens = np.zeros(cfg.max_batch, np.int32)
        self.context_lens = np.zeros(cfg.max_batch, np.int32)
        # time-weighted in-flight integral for Little's-law checks
        self._inflight_area = 0.0
        self._last_t = 0.0

    # ------------------------------------------------------------------
    def _advance(self, dt: float):
        inflight = len(self.slot_req)
        self._inflight_area += inflight * dt
        self.t += dt
        self._last_t = self.t
        self.metrics.set("repro:time_seconds", self.t)
        self.metrics.set("repro:num_requests_running", inflight)

    def mean_inflight(self) -> float:
        return self._inflight_area / max(self.t, 1e-9)

    # ------------------------------------------------------------------
    def _complete(self, slot: int):
        req = self.slot_req.pop(slot)
        req.state = RequestState.DONE
        req.finish_time = self.t
        self.pm.release(slot)
        self.ex.reset_slot(slot)
        self.context_lens[slot] = 0
        m = self.metrics
        m.inc("repro:request_success_total")
        m.observe("repro:e2e_request_latency_seconds", req.e2e)
        if req.ttft is not None:
            m.observe("repro:time_to_first_token_seconds", req.ttft)
        if req.tpot is not None:
            m.observe("repro:time_per_output_token_seconds", req.tpot)

    def fail_running(self, frac: float = 1.0, rng=None):
        """Simulate replica loss: re-queue `frac` of running requests."""
        rng = rng or np.random.default_rng(0)
        slots = list(self.slot_req)
        n = max(1, int(len(slots) * frac)) if slots else 0
        for slot in (rng.choice(slots, n, replace=False) if n else []):
            req = self.slot_req.pop(int(slot))
            self.pm.release(int(slot))
            self.ex.reset_slot(int(slot))
            self.context_lens[int(slot)] = 0
            req.slot = -1
            req.retries += 1
            self.metrics.inc("repro:request_preempted_total")
            if req.retries <= self.cfg.max_retries:
                req.state = RequestState.QUEUED
                req.prefill_done = 0
                req.tokens_out = 0
                req.first_token_time = None
                self._requeue.append(req)
            else:
                req.state = RequestState.FAILED
                self.metrics.inc("repro:request_failure_total")

    # ------------------------------------------------------------------
    def run(self, requests: Sequence[Request], *,
            horizon: Optional[float] = None,
            failure_times: Sequence[float] = ()) -> List[Request]:
        """Open-loop run; returns the request list with timings filled.

        Re-entrant: calling run() again with the same list (e.g. under a
        meter-tick horizon loop) resumes — requests already admitted or
        finished are not re-enqueued."""
        pending = sorted(
            (r for r in requests
             if r.state == RequestState.QUEUED and r.slot < 0),
            key=lambda r: r.arrival_time)
        queue: List[Request] = []
        self._requeue: List[Request] = getattr(self, "_requeue", [])
        fail_iter = iter(sorted(failure_times))
        next_fail = next(fail_iter, None)
        pad = lambda n, m: ((n + m - 1) // m) * m

        while pending or queue or self.slot_req or self._requeue:
            if horizon is not None and self.t >= horizon:
                break
            # failure injection
            if next_fail is not None and self.t >= next_fail:
                self.fail_running(0.5)
                next_fail = next(fail_iter, None)
            # arrivals
            while pending and pending[0].arrival_time <= self.t:
                queue.append(pending.pop(0))
            queue = self._requeue + queue
            self._requeue = []

            # ---- admission: chunked-prefill token budget + page capacity
            batch: List[Request] = []
            budget = self.cfg.prefill_token_budget
            while (queue and len(batch) < self.cfg.max_prefill_reqs and
                   (queue[0].prompt_len <= budget or not batch) and
                   self.pm.can_admit(queue[0].prompt_len,
                                     queue[0].max_new_tokens)):
                req = queue.pop(0)
                slot = self.pm.admit(req.prompt_len, req.max_new_tokens)
                req.slot = slot
                req.state = RequestState.PREFILL
                self.slot_req[slot] = req
                batch.append(req)
                budget -= req.prompt_len
                self.metrics.set("repro:kv_cache_usage_perc",
                                 self.pm.utilization())

            did_work = False
            if batch:
                lp = pad(max(r.prompt_len for r in batch), 64)
                B = self.cfg.max_batch
                tokens = np.zeros((B, lp), np.int32)
                lens = np.zeros(B, np.int32)
                mask = np.zeros(B, bool)
                rng = np.random.default_rng(batch[0].rid)
                for r in batch:
                    row = (np.asarray(r.prompt[:lp], np.int32)
                           if r.prompt else
                           rng.integers(0, 1000, r.prompt_len))
                    tokens[r.slot, :r.prompt_len] = row[:r.prompt_len]
                    lens[r.slot] = r.prompt_len
                    mask[r.slot] = True
                first, dt = self.ex.prefill(tokens, lens, mask,
                                            self.pm.block_tables)
                self._advance(dt)
                for r in batch:
                    r.state = RequestState.RUNNING
                    r.tokens_out = 1
                    r.first_token_time = self.t
                    r.prev_token_time = self.t
                    self.slot_tokens[r.slot] = first[r.slot]
                    self.context_lens[r.slot] = r.prompt_len
                    self.metrics.inc("repro:prompt_tokens_total",
                                     r.prompt_len)
                    self.metrics.inc("repro:generation_tokens_total", 1)
                    if self.slot_tokens[r.slot] >= 0 and \
                            r.tokens_out >= r.max_new_tokens:
                        self._complete(r.slot)
                did_work = True

            # ---- decode step for all running slots
            running = [r for r in self.slot_req.values()
                       if r.state == RequestState.RUNNING]
            if running:
                B = self.cfg.max_batch
                active = np.zeros(B, bool)
                for r in running:
                    active[r.slot] = True
                try:
                    nxt, dt = self.ex.decode(self.slot_tokens, active,
                                             self.pm.block_tables,
                                             context_lens=self.context_lens)
                except TypeError:
                    nxt, dt = self.ex.decode(self.slot_tokens, active,
                                             self.pm.block_tables)
                self._advance(dt)
                ngen = 0
                for r in running:
                    r.tokens_out += 1
                    ngen += 1
                    r.prev_token_time = self.t
                    self.slot_tokens[r.slot] = nxt[r.slot]
                    self.context_lens[r.slot] += 1
                    if r.tokens_out >= r.max_new_tokens:
                        self._complete(r.slot)
                self.metrics.inc("repro:generation_tokens_total", ngen)
                did_work = True

            if not did_work:
                if pending:
                    gap = max(pending[0].arrival_time - self.t, 1e-6)
                    self._advance(gap)
                elif queue:
                    # queued but cannot admit (capacity) and nothing
                    # running -> deadlock guard (shouldn't happen)
                    raise RuntimeError(
                        "scheduler stall: queued request cannot ever fit; "
                        "increase num_pages/max_pages_per_seq")
                else:
                    break
        return list(requests)
