"""Continuous-batching engine: FCFS admission + chunked prefill + paged KV.

The scheduling loop mirrors vLLM's continuous batching: every step admits
as many queued prompts as page capacity and the prefill token budget allow,
prefills them (recording TTFT), then decodes for the running slots. Time is
whatever the executor says it is — wall-clock (RealExecutor) or the TPU
model clock (SimExecutor) — so the same queueing dynamics produce both
measured and simulated C_eff(lambda) curves.

Two scheduler paths share identical semantics (ISSUE 1):

* **event-driven fast-forward** (`EngineConfig.fast_forward`, the default
  when the executor provides `decode_multi`): between scheduling events —
  next arrival while the queue is empty, next completion, next failure
  injection, horizon — the running batch composition is constant, so the
  engine advances the clock by the closed-form sum of the next `k` decode
  steps in one `decode_multi` call and updates all per-slot bookkeeping
  (tokens_out, context_lens, completion detection) with vectorized numpy
  ops. An arrival is *not* an event while the FCFS queue head is blocked
  on capacity: admission can only unblock at a completion or failure.
* **reference per-token loop** (`fast_forward=False`): one Python
  iteration per decode token, kept verbatim as the executable spec; the
  equivalence tests compare the two paths and the throughput benchmark
  uses it as the step-by-step baseline.

Equivalence guarantee: both paths take the same scheduling decisions in
the same order (admissions, prefills, completions, failure re-queues), so
RunRecord fields (tps, c_eff, ttft/tpot/e2e percentiles, mean_inflight)
agree to float-rounding tolerance. `RealExecutor` cannot predict wall
time, so its `decode_multi` falls back to per-step execution internally —
the fast path then degenerates to the reference loop with vectorized
bookkeeping, still semantically identical.

Fault handling: `fail_running()` simulates a replica/slot loss; affected
requests release pages and re-queue (bounded retries), matching the
straggler/failure story in DESIGN §5.
"""
from __future__ import annotations

import dataclasses
import heapq
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.kv_cache import PageManager
from repro.serving.metrics import MetricsRegistry
from repro.serving.overload import OverloadPolicy
from repro.serving.request import Request, RequestState
from repro.serving.resilience import (FailureSpec, FailureTimeline,
                                      RetryPolicy, as_failure_events)


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8
    page_size: int = 16
    num_pages: int = 1024
    max_pages_per_seq: int = 128
    prefill_token_budget: int = 2048    # chunked-prefill budget per step
    max_prefill_reqs: int = 8
    max_retries: int = 2
    fast_forward: bool = True           # event-driven clock; False = per-token
    #                                     reference loop (the baseline/oracle)
    # resilience knobs (ISSUE 6): zero = off, bit-identical to pre-6 engine
    max_queue_depth: int = 0            # >0: shed arrivals over this depth
    deadline_s: float = 0.0             # >0: queue-time deadline at admission
    # overload controller (ISSUE 9): None = no controller; a policy with
    # only ttft_slo_s set is a pure SLO monitor (violation counting)
    overload: Optional[OverloadPolicy] = None


class Engine:
    def __init__(self, cfg: EngineConfig, executor, metrics=None):
        self.cfg = cfg
        self.ex = executor
        self.pm = PageManager(cfg.num_pages, cfg.page_size, cfg.max_batch,
                              cfg.max_pages_per_seq)
        self.metrics = metrics or MetricsRegistry()
        self.t = 0.0
        self.slot_req: Dict[int, Request] = {}
        self.slot_tokens = np.zeros(cfg.max_batch, np.int32)
        self.context_lens = np.zeros(cfg.max_batch, np.int32)
        # per-slot mirrors of request bookkeeping (fast path works on these
        # and syncs back to Request objects at completion / run() exit)
        self.active = np.zeros(cfg.max_batch, bool)
        self.tokens_out_arr = np.zeros(cfg.max_batch, np.int64)
        self.max_new_arr = np.zeros(cfg.max_batch, np.int64)
        self._requeue: List[Request] = []
        # pre-bound latency histograms (reset() clears them in place)
        self._h_e2e = self.metrics.hist("repro:e2e_request_latency_seconds")
        self._h_ttft = self.metrics.hist(
            "repro:time_to_first_token_seconds")
        self._h_tpot = self.metrics.hist(
            "repro:time_per_output_token_seconds")
        # time-weighted in-flight integral for Little's-law checks
        self._inflight_area = 0.0
        self._last_t = 0.0
        # resilience state (ISSUE 6); all inert until a run enables them
        self._fail_rng = None               # persistent victim stream
        self._fail_stream = None            # FailureSpec event stream
        self._down_until = 0.0              # restart lag: no admission before
        self._retry: Optional[RetryPolicy] = None
        self._retry_rng = None
        self._retry_heap: List[Tuple[float, int, Request]] = []
        self._in_retry: set = set()         # rids parked awaiting re-submit
        # overload controller state (ISSUE 9): hysteretic state machine +
        # last observed TTFT. Both persist across run() re-entry AND the
        # warmup/measurement reset (a controller does not forget it is in
        # brownout because the meter rolled a window) — _last_ttft is a
        # duration, so clock resets cannot skew it.
        self._ovl_state = 0                 # overload.NORMAL
        self._last_ttft = 0.0
        # scheduler instrumentation (bench_engine_throughput)
        self.n_iterations = 0
        self.n_decode_steps = 0
        self.n_ff_jumps = 0

    # ------------------------------------------------------------------
    def _advance(self, dt: float):
        inflight = len(self.slot_req)
        self._inflight_area += inflight * dt
        self.t += dt
        self._last_t = self.t
        self.metrics.set("repro:time_seconds", self.t)
        self.metrics.set("repro:num_requests_running", inflight)

    def mean_inflight(self) -> float:
        return self._inflight_area / max(self.t, 1e-9)

    def reset_measurement(self):
        """Zero the virtual clock + metrics at a warmup/measurement boundary.

        Only valid when no request is mid-flight (warmup fully drained) —
        in-flight timestamps would otherwise straddle the reset."""
        self.t = 0.0
        self._inflight_area = 0.0
        self._last_t = 0.0
        self.metrics.reset()

    # ------------------------------------------------------------------
    def _complete(self, slot: int):
        req = self.slot_req.pop(slot)
        req.state = RequestState.DONE
        req.finish_time = self.t
        self.pm.release(slot)
        self.ex.reset_slot(slot)
        self.context_lens[slot] = 0
        self.active[slot] = False
        self.tokens_out_arr[slot] = 0
        self.max_new_arr[slot] = 0
        self.metrics.inc("repro:request_success_total")
        self._h_e2e.observe(req.e2e)
        if req.ttft is not None:
            self._h_ttft.observe(req.ttft)
        if req.tpot is not None:
            self._h_tpot.observe(req.tpot)

    def _sync_inflight_from_mirrors(self):
        """Fast-path only: push the slot mirrors' decode progress back
        onto the Request objects before an event that may terminate them
        (a killed-past-budget request keeps its `tokens_out` at death,
        and the reference loop keeps that field current per token)."""
        for slot, r in self.slot_req.items():
            r.tokens_out = int(self.tokens_out_arr[slot])

    def fail_running(self, frac: float = 1.0, rng=None):
        """Simulate replica loss: re-queue `frac` of running requests.

        With `rng=None` victims come from a persistent engine-owned stream
        seeded once per engine, so stacked failure events draw
        consecutively and two engines given the same schedule pick the
        same victims. `frac <= 0` loses nothing (the pre-ISSUE-6 code
        failed one request); `frac >= 1` loses every running slot."""
        if rng is None:
            if self._fail_rng is None:
                self._fail_rng = np.random.default_rng(0)
            rng = self._fail_rng
        slots = list(self.slot_req)
        if not slots or frac <= 0.0:
            n = 0
        elif frac >= 1.0:
            n = len(slots)
        else:
            n = max(1, int(len(slots) * frac))
        for slot in (rng.choice(slots, n, replace=False) if n else []):
            req = self.slot_req.pop(int(slot))
            self.pm.release(int(slot))
            self.ex.reset_slot(int(slot))
            self.context_lens[int(slot)] = 0
            self.active[int(slot)] = False
            self.tokens_out_arr[int(slot)] = 0
            self.max_new_arr[int(slot)] = 0
            req.slot = -1
            req.retries += 1
            self.metrics.inc("repro:request_preempted_total")
            if req.retries <= self.cfg.max_retries:
                req.state = RequestState.QUEUED
                req.prefill_done = 0
                req.tokens_out = 0
                req.first_token_time = None
                self._requeue.append(req)
            else:
                req.state = RequestState.FAILED
                self.metrics.inc("repro:request_failure_total")
                self._client_reject(req, self.t)

    # ---- client-side retry / shedding (ISSUE 6) ----------------------
    def _client_reject(self, req: Request, base_t: float):
        """Client reaction to a shed/expired/failed request: re-submit
        with capped exponential backoff if the RetryPolicy allows, else
        abandon. `base_t` is the path-independent trigger time (arrival,
        deadline expiry, failure event) so both scheduler paths schedule
        bit-identical re-submission times."""
        pol = self._retry
        if pol is not None and pol.enabled and req.attempts < pol.max_attempts:
            req.attempts += 1
            if self._retry_rng is None:
                self._retry_rng = np.random.default_rng(pol.seed)
            at = base_t + pol.delay(req.attempts, self._retry_rng)
            req.state = RequestState.QUEUED
            req.slot = -1
            req.prefill_done = 0
            req.tokens_out = 0
            req.first_token_time = None
            req.retries = 0
            req.submit_time = at
            self._in_retry.add(req.rid)
            heapq.heappush(self._retry_heap, (at, req.rid, req))
            self.metrics.inc("repro:request_retry_total")
        else:
            req.state = RequestState.FAILED
            self.metrics.inc("repro:request_abandoned_total")

    def _accept(self, queue, req: Request):
        """Arrival-time admission control, one evaluation per drained
        submission (the deterministic point every scheduler path shares):
        the overload controller's state transition + class shedding
        first (ISSUE 9), then the class-blind max_queue_depth cap
        (ISSUE 6), then the brownout token-budget clamp on the admitted
        request. The depth reading is the queue length BEFORE this
        submission joins, same as the legacy cap's."""
        pol = self.cfg.overload
        if pol is not None and pol.enabled:
            self._ovl_state = pol.next_state(self._ovl_state, len(queue),
                                             self._last_ttft)
            if not pol.admits(self._ovl_state, req.priority):
                self.metrics.inc("repro:request_shed_total")
                self.metrics.inc("repro:request_class_shed_total")
                self._client_reject(req, req.submitted_at)
                return
        mqd = self.cfg.max_queue_depth
        if mqd > 0 and len(queue) >= mqd:
            self.metrics.inc("repro:request_shed_total")
            self._client_reject(req, req.submitted_at)
            return
        if pol is not None and pol.enabled:
            clamped = pol.clamp(self._ovl_state, req.max_new_tokens)
            if clamped < req.max_new_tokens:
                self.metrics.inc("repro:request_browned_total")
                self.metrics.inc("repro:browned_tokens_total",
                                 req.max_new_tokens - clamped)
                req.max_new_tokens = clamped
        queue.append(req)

    def _observe_ttfts(self, batch: List[Request]):
        """Post-prefill TTFT observation (both scheduler paths call this
        at the same clock instants, so controller inputs stay
        path-identical): count SLO violations whenever a policy declares
        an SLO — armed or monitor-only — and remember the last observed
        TTFT (batch admission order) for the brownout trigger."""
        pol = self.cfg.overload
        if pol is None:
            return
        slo = pol.ttft_slo_s
        for r in batch:
            ttft = self.t - r.arrival_time
            if slo > 0.0 and ttft > slo:
                self.metrics.inc("repro:request_slo_violation_total")
            self._last_ttft = ttft

    def _next_submit(self, pending, pi: int) -> Optional[float]:
        """Earliest future submission: next arrival or retry re-submit."""
        nxt = pending[pi].arrival_time if pi < len(pending) else None
        if self._retry_heap:
            h = self._retry_heap[0][0]
            nxt = h if nxt is None else min(nxt, h)
        return nxt

    def _drain_submissions(self, queue, pending, pi: int) -> int:
        """Move every due submission (arrival or retry re-submit) into the
        queue in global submission-time order (ties: arrivals first).
        Both scheduler paths process submissions at different clock
        granularities; merging by submission time keeps the FCFS order —
        and thereby shed decisions — identical between them."""
        heap = self._retry_heap
        n = len(pending)
        while True:
            pa = pending[pi].arrival_time if pi < n else None
            ha = heap[0][0] if heap else None
            if (pa is not None and pa <= self.t
                    and (ha is None or pa <= ha)):
                self._accept(queue, pending[pi])
                pi += 1
            elif ha is not None and ha <= self.t:
                _, _, req = heapq.heappop(heap)
                self._in_retry.discard(req.rid)
                self._accept(queue, req)
            else:
                return pi

    # ------------------------------------------------------------------
    def run(self, requests: Sequence[Request], *,
            horizon: Optional[float] = None,
            failure_times: Sequence[float] = (),
            failure_spec: Optional[FailureSpec] = None,
            retry: Optional[RetryPolicy] = None) -> List[Request]:
        """Open-loop run; returns the request list with timings filled.

        Re-entrant: calling run() again with the same list (e.g. under a
        meter-tick horizon loop) resumes — requests already admitted or
        finished are not re-enqueued; a FailureSpec stream keeps its
        place across re-entry. `failure_times` accepts bare floats
        (legacy: lose half the running slots) or FailureEvents."""
        if retry is not None:
            self._retry = retry if retry.enabled else None
        if (failure_spec is not None and failure_spec.enabled
                and self._fail_stream is None):
            self._fail_stream = failure_spec.stream()
        timeline = FailureTimeline(as_failure_events(failure_times),
                                   self._fail_stream)
        if self.cfg.fast_forward and hasattr(self.ex, "decode_multi"):
            return self._run_fast(requests, horizon=horizon,
                                  timeline=timeline)
        return self._run_reference(requests, horizon=horizon,
                                   timeline=timeline)

    # ---- admission (shared helper) -----------------------------------
    def _admit_from(self, queue) -> List[Request]:
        batch: List[Request] = []
        budget = self.cfg.prefill_token_budget
        ddl = self.cfg.deadline_s
        while queue:
            if ddl > 0.0 and self.t - queue[0].submitted_at > ddl:
                # queue-time deadline: expired heads are popped (they no
                # longer block FCFS) and handed back to the client.
                # Tie semantics (ISSUE 9): strictly greater-than, so a
                # request whose wait EQUALS deadline_s is still served —
                # matching the arrival-draw protocol's closed-boundary
                # convention. All three scheduler paths (this helper is
                # shared by reference + fast-forward; the fleet mirrors
                # it in _admit_lane) pin the same choice; a regression
                # test exercises the exact-tie cell on each.
                req = (queue.popleft() if isinstance(queue, deque)
                       else queue.pop(0))
                self.metrics.inc("repro:request_timeout_total")
                self._client_reject(req, req.submitted_at + ddl)
                continue
            if not (len(batch) < self.cfg.max_prefill_reqs and
                    (queue[0].prompt_len <= budget or not batch) and
                    self.pm.can_admit(queue[0].prompt_len,
                                      queue[0].max_new_tokens)):
                break
            req = queue.popleft() if isinstance(queue, deque) else queue.pop(0)
            slot = self.pm.admit(req.prompt_len, req.max_new_tokens)
            req.slot = slot
            req.state = RequestState.PREFILL
            self.slot_req[slot] = req
            batch.append(req)
            budget -= req.prompt_len
            self.metrics.set("repro:kv_cache_usage_perc",
                             self.pm.utilization())
        return batch

    def _prefill_tokens(self, batch: List[Request]) -> np.ndarray:
        """Materialise the padded token matrix (only if the executor reads
        token values; the sim tier meters counts and timing only)."""
        B = self.cfg.max_batch
        if not getattr(self.ex, "needs_tokens", True):
            return np.zeros((B, 0), np.int32)
        lp = -(-max(r.prompt_len for r in batch) // 64) * 64
        tokens = np.zeros((B, lp), np.int32)
        rng = np.random.default_rng(batch[0].rid)
        for r in batch:
            row = (np.asarray(r.prompt[:lp], np.int32)
                   if r.prompt else
                   rng.integers(0, 1000, r.prompt_len))
            tokens[r.slot, :r.prompt_len] = row[:r.prompt_len]
        return tokens

    # ---- fast path ----------------------------------------------------
    def _run_fast(self, requests: Sequence[Request], *,
                  horizon: Optional[float] = None,
                  timeline: Optional[FailureTimeline] = None) -> List[Request]:
        B = self.cfg.max_batch
        pending = sorted(
            (r for r in requests
             if r.state == RequestState.QUEUED and r.slot < 0
             and r.rid not in self._in_retry),
            key=lambda r: r.arrival_time)
        pi = 0                              # pending cursor (no pop(0))
        queue: Deque[Request] = deque()
        timeline = timeline or FailureTimeline(())
        next_ev = timeline.peek()
        ddl = self.cfg.deadline_s
        needs_tok = getattr(self.ex, "needs_tokens", True)

        # resync slot mirrors from request objects (re-entry / mode switch)
        self.active[:] = False
        self.tokens_out_arr[:] = 0
        self.max_new_arr[:] = 0
        for slot, r in self.slot_req.items():
            self.active[slot] = True
            self.tokens_out_arr[slot] = r.tokens_out
            self.max_new_arr[slot] = r.max_new_tokens

        while (pi < len(pending) or queue or self.slot_req or self._requeue
               or self._retry_heap):
            self.n_iterations += 1
            if horizon is not None and self.t >= horizon:
                break
            # failure injection
            if next_ev is not None and self.t >= next_ev.time:
                self._sync_inflight_from_mirrors()
                self.fail_running(next_ev.frac)
                if next_ev.downtime > 0.0:
                    self._down_until = max(self._down_until,
                                           next_ev.time + next_ev.downtime)
                timeline.pop()
                next_ev = timeline.peek()
            # idle regime (ISSUE 2): batch and queue both empty — jump the
            # clock straight to the next submission (arrival or retry
            # re-submit) and admit it (plus any co-arrivals) in this same
            # wakeup, instead of burning a whole scheduler iteration on
            # the advance alone. The reference loop re-checks horizon and
            # failure injection at the top of its next iteration before
            # admitting, so replay those two checks here to keep the
            # event order identical.
            if not self.slot_req and not queue and not self._requeue:
                nxt_sub = self._next_submit(pending, pi)
                if nxt_sub is not None and nxt_sub > self.t:
                    self._advance(max(nxt_sub - self.t, 1e-6))
                    if horizon is not None and self.t >= horizon:
                        break
                    if next_ev is not None and self.t >= next_ev.time:
                        self._sync_inflight_from_mirrors()
                        self.fail_running(next_ev.frac)
                        if next_ev.downtime > 0.0:
                            self._down_until = max(
                                self._down_until,
                                next_ev.time + next_ev.downtime)
                        timeline.pop()
                        next_ev = timeline.peek()
            # arrivals (client re-submissions are arrivals too)
            pi = self._drain_submissions(queue, pending, pi)
            if self._requeue:
                queue.extendleft(reversed(self._requeue))
                self._requeue = []

            blocked = self.t < self._down_until   # restart/warmup lag
            batch = [] if blocked else self._admit_from(queue)
            did_work = False
            if batch:
                lens = np.zeros(B, np.int32)
                mask = np.zeros(B, bool)
                for r in batch:
                    lens[r.slot] = r.prompt_len
                    mask[r.slot] = True
                first, dt = self.ex.prefill(self._prefill_tokens(batch),
                                            lens, mask,
                                            self.pm.block_tables)
                self._advance(dt)
                n_prompt = 0
                for r in batch:
                    r.state = RequestState.RUNNING
                    r.tokens_out = 1
                    r.first_token_time = self.t
                    r.prev_token_time = self.t
                    self.slot_tokens[r.slot] = first[r.slot]
                    self.context_lens[r.slot] = r.prompt_len
                    self.active[r.slot] = True
                    self.tokens_out_arr[r.slot] = 1
                    self.max_new_arr[r.slot] = r.max_new_tokens
                    n_prompt += r.prompt_len
                self.metrics.inc("repro:prompt_tokens_total", n_prompt)
                self.metrics.inc("repro:generation_tokens_total", len(batch))
                self._observe_ttfts(batch)
                for r in batch:
                    if self.slot_tokens[r.slot] >= 0 and \
                            r.tokens_out >= r.max_new_tokens:
                        self._complete(r.slot)
                did_work = True

            # ---- decode: closed-form jump to the next scheduling event
            nrun = int(self.active.sum())
            if nrun:
                if batch:
                    # composition just changed; take exactly one step (the
                    # reference loop decodes once per prefill iteration)
                    k_max, tbudget = 1, None
                else:
                    rem = (self.max_new_arr[self.active] -
                           self.tokens_out_arr[self.active])
                    k_max = int(rem.min())
                    cands = []
                    if not queue:
                        # submissions only matter while nothing is queued:
                        # a blocked FCFS head keeps newcomers unadmittable
                        nxt_sub = self._next_submit(pending, pi)
                        if nxt_sub is not None:
                            cands.append(nxt_sub - self.t)
                    if next_ev is not None:
                        cands.append(next_ev.time - self.t)
                    if blocked and (queue or self._requeue):
                        cands.append(self._down_until - self.t)
                    if ddl > 0.0 and queue and not blocked:
                        # head expiry unblocks FCFS: it is an event
                        cands.append(queue[0].submitted_at + ddl - self.t)
                    if horizon is not None:
                        cands.append(horizon - self.t)
                    tbudget = min(cands) if cands else None
                nxt, dt, steps = self.ex.decode_multi(
                    self.slot_tokens, self.active, self.pm.block_tables,
                    self.context_lens, k_max, tbudget)
                self._advance(dt)
                self.n_decode_steps += steps
                if steps > 1:
                    self.n_ff_jumps += 1
                act = self.active
                if needs_tok:
                    self.slot_tokens[act] = nxt[act]
                self.tokens_out_arr[act] += steps
                self.context_lens[act] += steps
                self.metrics.inc("repro:generation_tokens_total",
                                 steps * nrun)
                done_mask = act & (self.tokens_out_arr >= self.max_new_arr)
                if done_mask.any():
                    for slot in np.flatnonzero(done_mask):
                        slot = int(slot)
                        r = self.slot_req[slot]
                        r.tokens_out = int(self.tokens_out_arr[slot])
                        r.prev_token_time = self.t
                        self._complete(slot)
                did_work = True

            if not did_work:
                cands = []
                nxt_sub = self._next_submit(pending, pi)
                if nxt_sub is not None:
                    cands.append(nxt_sub)
                if blocked and (queue or self._requeue):
                    cands.append(self._down_until)
                if ddl > 0.0 and queue and not blocked:
                    cands.append(queue[0].submitted_at + ddl)
                if cands:
                    self._advance(max(min(cands) - self.t, 1e-6))
                elif queue:
                    raise RuntimeError(
                        "scheduler stall: queued request cannot ever fit; "
                        "increase num_pages/max_pages_per_seq")
                else:
                    break

        # sync slot mirrors back onto in-flight request objects so a
        # re-entrant run() (or the caller) sees consistent progress
        for slot, r in self.slot_req.items():
            r.tokens_out = int(self.tokens_out_arr[slot])
            r.prev_token_time = self.t
        return list(requests)

    # ---- reference path (the executable spec / benchmark baseline) ----
    def _run_reference(self, requests: Sequence[Request], *,
                       horizon: Optional[float] = None,
                       timeline: Optional[FailureTimeline] = None
                       ) -> List[Request]:
        pending = sorted(
            (r for r in requests
             if r.state == RequestState.QUEUED and r.slot < 0
             and r.rid not in self._in_retry),
            key=lambda r: r.arrival_time)
        pi = 0
        queue: List[Request] = []
        timeline = timeline or FailureTimeline(())
        next_ev = timeline.peek()
        ddl = self.cfg.deadline_s

        while (pi < len(pending) or queue or self.slot_req or self._requeue
               or self._retry_heap):
            self.n_iterations += 1
            if horizon is not None and self.t >= horizon:
                break
            # failure injection
            if next_ev is not None and self.t >= next_ev.time:
                self.fail_running(next_ev.frac)
                if next_ev.downtime > 0.0:
                    self._down_until = max(self._down_until,
                                           next_ev.time + next_ev.downtime)
                timeline.pop()
                next_ev = timeline.peek()
            # arrivals (client re-submissions are arrivals too)
            pi = self._drain_submissions(queue, pending, pi)
            queue = self._requeue + queue
            self._requeue = []

            blocked = self.t < self._down_until   # restart/warmup lag
            batch = [] if blocked else self._admit_from(queue)
            did_work = False
            if batch:
                B = self.cfg.max_batch
                tokens = self._prefill_tokens(batch)
                lens = np.zeros(B, np.int32)
                mask = np.zeros(B, bool)
                for r in batch:
                    lens[r.slot] = r.prompt_len
                    mask[r.slot] = True
                first, dt = self.ex.prefill(tokens, lens, mask,
                                            self.pm.block_tables)
                self._advance(dt)
                for r in batch:
                    r.state = RequestState.RUNNING
                    r.tokens_out = 1
                    r.first_token_time = self.t
                    r.prev_token_time = self.t
                    self.slot_tokens[r.slot] = first[r.slot]
                    self.context_lens[r.slot] = r.prompt_len
                    self.metrics.inc("repro:prompt_tokens_total",
                                     r.prompt_len)
                    self.metrics.inc("repro:generation_tokens_total", 1)
                    if self.slot_tokens[r.slot] >= 0 and \
                            r.tokens_out >= r.max_new_tokens:
                        self._complete(r.slot)
                self._observe_ttfts(batch)
                did_work = True

            # ---- decode step for all running slots
            running = [r for r in self.slot_req.values()
                       if r.state == RequestState.RUNNING]
            if running:
                B = self.cfg.max_batch
                active = np.zeros(B, bool)
                for r in running:
                    active[r.slot] = True
                try:
                    nxt, dt = self.ex.decode(self.slot_tokens, active,
                                             self.pm.block_tables,
                                             context_lens=self.context_lens)
                except TypeError:
                    nxt, dt = self.ex.decode(self.slot_tokens, active,
                                             self.pm.block_tables)
                self._advance(dt)
                self.n_decode_steps += 1
                ngen = 0
                for r in running:
                    r.tokens_out += 1
                    ngen += 1
                    r.prev_token_time = self.t
                    self.slot_tokens[r.slot] = nxt[r.slot]
                    self.context_lens[r.slot] += 1
                    if r.tokens_out >= r.max_new_tokens:
                        self._complete(r.slot)
                self.metrics.inc("repro:generation_tokens_total", ngen)
                did_work = True

            if not did_work:
                cands = []
                nxt_sub = self._next_submit(pending, pi)
                if nxt_sub is not None:
                    cands.append(nxt_sub)
                if blocked and (queue or self._requeue):
                    cands.append(self._down_until)
                if ddl > 0.0 and queue and not blocked:
                    cands.append(queue[0].submitted_at + ddl)
                if cands:
                    self._advance(max(min(cands) - self.t, 1e-6))
                elif queue:
                    # queued but cannot admit (capacity) and nothing
                    # running -> deadlock guard (shouldn't happen)
                    raise RuntimeError(
                        "scheduler stall: queued request cannot ever fit; "
                        "increase num_pages/max_pages_per_seq")
                else:
                    break
        return list(requests)
