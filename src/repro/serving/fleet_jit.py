"""JIT-compiled fleet backend: the event loop as one `lax.while_loop`.

`FleetEngine` (the numpy SoA fleet, PR 4) pays ~1ms of numpy dispatch
per event *round* at 256 lanes; this module compiles the whole round to
one XLA program so a round costs microseconds, not interpreter time.
The numpy fleet stays the equivalence oracle — exactly the way
`Engine._run_fast` kept the per-token loop, and the fleet kept
`run_point`.

Why this is not a transliteration
---------------------------------
XLA:CPU makes per-round scatters into (B, max_batch) slot tables or
(B, n_requests) request arrays catastrophically expensive (a functional
`.at[].set` outside the hot path copies the destination; even fused,
a (B, S)-shaped scatter costs ~100x a (B,) op). The port therefore
*eliminates the slot tables entirely*, which is sound for precisely the
lanes the numpy fleet's own vectorized fast path accepts (uniform
request shapes, no failure tracking, no re-queue fronts):

* Every active slot advances by the same `k` each decode round, so a
  request admitted when the lane's cumulative decode-step counter was
  `K_adm` has `tokens_out = 1 + (K_now - K_adm)` — slot state collapses
  to one per-lane counter `K` plus the admission-time snapshot.
* Admission cohorts therefore complete in FIFO order, and because the
  decode burst `k = min(remaining)` is exactly the *oldest* cohort's
  remaining tokens (never more), **at most one cohort completes per
  round**. Completion becomes a cursor walk, not a slot scan.
* Slot ids never reach a RunRecord on untracked lanes, so the
  free-slot stack (which only exists to keep failure-injection RNG
  streams aligned) is replaced by the count `n_free = max_batch -
  n_occ`; pages by `free_pages = (num_pages - 1) - n_occ * need`.

The loop carries only (B,) scalars plus *cohort event logs* — per-lane
append-only columns (Kadm, cumulative-admitted, first-token time,
finish time) written with one-column-per-row scatters (~17us) and read
back on the host, where `r_first`/`r_finish`/`r_out` are reconstructed
with `np.repeat` and fed through the numpy fleet's own `_lane_record`.

Equivalence and tolerance policy (see `serving.precision`)
----------------------------------------------------------
The arithmetic mirrors `FleetStepModel` op-for-op in float64
(`precision.enable_x64`), and the event *decisions* (admission counts,
closed-form burst inversion via bisection, idle jumps, horizon cuts)
are integer/comparison-exact given equal clocks. XLA may contract
mul+add chains into FMAs, so clocks can drift by ~1 ulp per step and
RunRecords agree with the numpy oracle within
`precision.jit_tolerance()` rather than bitwise; the numpy path remains
the byte-identity surface for committed stores. Points the SoA design
cannot express — variable request shapes, deterministic failure
streams, resilience features, `max_new <= 1`, statically inadmissible
shapes — route to `fleet_run_points` unchanged (which in turn routes
retry-feedback cells to the scalar engine).

Warmup is skipped, provably: a jit-eligible lane's warmup phase drains
completely and `reset_measurement` zeroes the clocks, so the measured
phase starts from exactly the reset state — the only state a warmup
leaves behind is free-stack *order*, which cannot reach an untracked
lane's record. (`tests/test_fleet_jit.py` pins record equality against
the warmed numpy path.)

Pallas note (ISSUE 7): profiling shows the compiled round is dominated
by the four event-log scatter/gather ops and the arrival binary search,
each already a single fused XLA:CPU loop; the admission/completion
passes are (B,) elementwise chains XLA fuses into one kernel. A Pallas
lowering of those passes (interpret mode on CPU) would add per-call
overhead without removing any of the remaining cost, so the kernel
stays un-lowered until a real accelerator target makes it worthwhile.
"""
from __future__ import annotations

import dataclasses
import functools
import types
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving import precision
from repro.serving.arrivals import synth_arrays
from repro.serving.fleet import (FleetPoint, FleetStepModel, _lane_record,
                                 _needs_admission, _needs_scalar,
                                 fleet_run_points)

# safety valve: the event loop is bounded by ~4 rounds per request
# (admission, completion, one arrival interrupt, one idle jump); a lane
# still live past this cap indicates a scheduling bug and the chunk
# falls back to the numpy oracle instead of looping forever
_CAP_PER_REQ = 8
_CAP_FLOOR = 256

_MODEL_FIELDS = ("nc", "fixed", "is_moe", "moe_oh", "moe_ratio", "wb",
                 "q_ratio", "kv", "ap2", "pdenom", "cdenom", "bwd",
                 "ici_denom", "ncm1", "L2", "Lf", "dm", "attn_coef")


class JitFallback(RuntimeError):
    """The compiled loop could not finish the chunk (round-cap hit or a
    dynamic scheduler stall); the caller re-runs the chunk on the numpy
    fleet, which either finishes or raises the real error."""


# ---------------------------------------------------------------------------
# eligibility
# ---------------------------------------------------------------------------


def jit_eligible(p: FleetPoint, stream) -> bool:
    """True iff this point can ride the jit loop with record-equivalent
    results: untracked, uniform request shape, `max_new >= 2` (so no
    prefill-time completions) and statically admissible (the numpy path
    raises the scheduler-stall error for the rest)."""
    if _needs_scalar(p) or p.failure_times:
        return False
    # admission control / overload / priority classes (ISSUE 9): the
    # compiled loop has no admission queue, counters, or class streams —
    # these points run on the numpy fleet's explicit admission path
    if _needs_admission(p) or getattr(p.arrivals, "class_mix", ()):
        return False
    times, p_ins, p_outs = stream
    if len(times) == 0:
        return True                       # born-finished lane
    if int(p_ins.min()) != int(p_ins.max()) or \
            int(p_outs.min()) != int(p_outs.max()):
        return False
    uplen, umn = int(p_ins[0]), int(p_outs[0])
    if umn < 2:
        return False
    s = p.engine
    try:
        need = -(-(uplen + umn) // int(s.page_size))
        if (need > int(s.max_pages_per_seq)
                or need > int(s.num_pages) - 1
                or int(s.max_prefill_reqs) <= 0
                or int(s.max_batch) < 1):
            return False
    except (AttributeError, TypeError):
        return False                      # not a SimEngineSpec shape
    return True


# ---------------------------------------------------------------------------
# compiled phase
# ---------------------------------------------------------------------------


def _pow2(n: int, floor: int = 8) -> int:
    return max(floor, 1 << (max(n - 1, 0)).bit_length() if n > 1 else floor)


@functools.lru_cache(maxsize=64)
def _compiled_phase(ilog_n: int, ilog_k: int, cap: int) -> Callable:
    """Build (and cache) the jitted phase runner for one search-depth /
    round-cap bucket; jax's own jit cache further specializes on the
    (B_pad, N_pad) array shapes."""
    import jax
    import jax.numpy as jnp
    from jax import lax

    def take(arr, idx):
        return jnp.take_along_axis(arr, idx[:, None], axis=1)[:, 0]

    # -- FleetStepModel mirrors (same op order; see serving.fleet) -----
    def collective(md, tokens):
        bytes_ar = (md["L2"] * tokens * md["dm"] * 2.0 * 2.0 *
                    md["ncm1"] / md["nc"])
        out = bytes_ar / md["ici_denom"]
        return jnp.where(md["nc"] <= 1.0, 0.0, out)

    def decode_terms(md, b):
        compute = md["ap2"] * b / md["cdenom"]
        inner = jnp.where(md["is_moe"], b * md["moe_ratio"], 1.0)
        touched = jnp.minimum(1.0, jnp.maximum(md["q_ratio"], inner))
        mem_base = md["wb"] * touched / md["bwd"]
        mem_slope = b * md["kv"] / md["bwd"]
        moe_term = jnp.where(md["is_moe"], md["moe_oh"] * b, 0.0)
        const = collective(md, b) + moe_term + md["fixed"]
        return compute, mem_base, mem_slope, const

    def jump(terms, ctx0, kf):
        compute, mem_base, slope, const = terms
        mem0 = mem_base + slope * ctx0
        m = jnp.ceil((compute - mem0) / slope)
        m = jnp.minimum(jnp.maximum(m, 0.0), kf)
        series = (kf - m) * mem0 + slope * (m + kf - 1.0) * (kf - m) / 2.0
        out = m * compute + series + kf * const
        flat = kf * (jnp.maximum(compute, mem0) + const)
        return jnp.where(slope <= 0.0, flat, out)

    def prefill_time(md, n_tok, n_breq):
        mean_len = n_tok / jnp.maximum(n_breq, 1.0)
        flops = md["ap2"] * n_tok
        flops = flops + md["attn_coef"] * n_tok * mean_len
        compute = flops / md["pdenom"]
        mem_bytes = md["wb"] + 2.0 * n_tok * md["dm"] * 2.0 * md["Lf"]
        memory = mem_bytes / md["bwd"]
        moe_term = jnp.where(md["is_moe"], md["moe_oh"] * n_tok, 0.0)
        out = (jnp.maximum(compute, memory) + collective(md, n_tok) +
               moe_term + md["fixed"])
        return jnp.where(n_tok == 0.0, 0.0, out)

    def phase(md, ec, r_arr):
        fdt = r_arr.dtype
        B, W = r_arr.shape                 # W == N_pad + 1
        dump = W - 1
        rows = jnp.arange(B)
        idt = ec["n_req"].dtype
        one = jnp.ones((), idt)
        n_req, mb = ec["n_req"], ec["mb"]
        uplen, umn, uneed = ec["uplen"], ec["umn"], ec["uneed"]
        pf_budget, max_pf_reqs = ec["pf_budget"], ec["max_pf_reqs"]
        num_pages, horizon = ec["num_pages"], ec["horizon"]

        def searchsorted_right(t):
            lo = jnp.zeros(B, idt)
            hi = jnp.full(B, dump, idt)
            for _ in range(ilog_n):
                act = lo < hi
                mid = (lo + hi) // 2
                le = take(r_arr, mid) <= t
                lo = jnp.where(act & le, mid + one, lo)
                hi = jnp.where(act & ~le, mid, hi)
            return lo

        def cond(st):
            return st[1].any() & (st[0] < cap)

        def body(st):
            (i, live, stall, t, area, K, q_next, arrived, ncomp, crd, ne,
             ctx_sum, head_K, head_Q, KadmE, QadmE, TfirstE, TfinE) = st
            n_occ = q_next - ncomp
            live = live & ((arrived < n_req) | (q_next < arrived)
                           | (n_occ > 0))
            alive = live
            # 1. horizon
            hb = alive & (t >= horizon)
            live, alive = live & ~hb, alive & ~hb
            # 3. idle regime: jump to the next arrival, replay horizon
            next_arr = take(r_arr, arrived)
            idle = (alive & (n_occ == 0) & (q_next == arrived)
                    & (arrived < n_req) & (next_arr > t))
            t = jnp.where(idle, t + jnp.maximum(next_arr - t, 1e-6), t)
            hb = idle & (t >= horizon)
            live, alive = live & ~hb, alive & ~hb
            # 4. arrivals (np.searchsorted side="right"; inf padding).
            # The search is skipped outright on rounds with no arrivals
            # (numpy's `if move.any()`), which most decode rounds are.
            move = alive & (next_arr <= t)
            arrived = lax.cond(
                move.any(),
                lambda a: jnp.where(move, searchsorted_right(t), a),
                lambda a: a, arrived)
            next_arr = take(r_arr, arrived)
            # 5. admission: the numpy fast path's closed-form FCFS count
            free_pages = (num_pages - one) - n_occ * uneed
            n_free = mb - n_occ
            can = (alive & (q_next < arrived) & (n_occ < mb)
                   & (free_pages >= uneed))
            n = jnp.maximum(pf_budget // uplen, one)
            n = jnp.minimum(n, max_pf_reqs)
            n = jnp.minimum(n, arrived - q_next)
            n = jnp.minimum(n, free_pages // uneed)
            n = jnp.minimum(n, n_free)
            cnt = jnp.where(can, n, jnp.zeros((), idt))
            had_batch = cnt > 0
            # 6. prefill
            n_tok = cnt * uplen
            dt = prefill_time(md, n_tok.astype(fdt), cnt.astype(fdt))
            n_occ = n_occ + cnt
            t = jnp.where(had_batch, t + dt, t)
            area = jnp.where(had_batch, area + n_occ * dt, area)
            ctx_sum = ctx_sum + n_tok
            q_next = q_next + cnt
            # cohort event log (one cohort per admission round)
            col = jnp.where(had_batch, ne, dump)
            KadmE = KadmE.at[rows, col].set(K)
            QadmE = QadmE.at[rows, col].set(q_next)
            TfirstE = TfirstE.at[rows, col].set(t)
            new_head = (crd == ne) & had_batch
            ne = ne + had_batch.astype(idt)
            head_K = jnp.where(new_head, K, head_K)
            head_Q = jnp.where(new_head, q_next, head_Q)
            # 7. decode: closed-form jump, event-budget bisection
            dec = alive & (n_occ > 0)
            rem = (head_K + (umn - one)) - K
            k = jnp.maximum(jnp.where(had_batch, one, rem), one)
            q_empty = q_next == arrived
            cand = jnp.where(q_empty & (arrived < n_req),
                             next_arr - t, jnp.inf)
            cand = jnp.minimum(cand, horizon - t)
            n_eff = jnp.maximum(n_occ, one)
            b = n_eff.astype(fdt)
            ctx0 = ctx_sum / n_eff
            terms = decode_terms(md, b)
            dtd = jump(terms, ctx0, k.astype(fdt))
            bis = dec & (k > one) & (dtd >= cand)

            def budget_cut(ops):
                # smallest k' in [1, k] with S(k') >= budget — pure
                # bisection; S is strictly increasing so the minimal k'
                # is unique and matches the numpy closed-form+verify
                # inversion integer-for-integer
                k0, dtd0 = ops
                lo, hi = jnp.ones(B, idt), k0
                for _ in range(ilog_k):
                    act = bis & (lo < hi)
                    mid = (lo + hi) // 2
                    ge = jump(terms, ctx0, mid.astype(fdt)) >= cand
                    hi = jnp.where(act & ge, mid, hi)
                    lo = jnp.where(act & ~ge, mid + one, lo)
                k1 = jnp.where(bis, lo, k0)
                return k1, jnp.where(bis, jump(terms, ctx0,
                                               k1.astype(fdt)), dtd0)

            # skipped whole on rounds with no budget-cut lane (numpy's
            # `if bis.any()`): the unrolled probe chain dominates the
            # round's op count when it runs
            k, dtd = lax.cond(bis.any(), budget_cut, lambda o: o, (k, dtd))
            t = jnp.where(dec, t + dtd, t)
            area = jnp.where(dec, area + n_occ * dtd, area)
            ctx_sum = jnp.where(dec, ctx_sum + k * n_occ, ctx_sum)
            K = jnp.where(dec, K + k, K)
            # 8. completion: at most one cohort per round (module doc)
            done_c = dec & (crd < ne) & (head_K <= K - (umn - one))
            ndone = jnp.where(done_c, head_Q - ncomp, jnp.zeros((), idt))
            ncomp = ncomp + ndone
            ctx_sum = ctx_sum - ndone * (uplen + (umn - one))
            TfinE = TfinE.at[rows,
                             jnp.where(done_c, crd, dump)].set(t)
            crd = crd + done_c.astype(idt)
            head_K = jnp.where(done_c, take(KadmE, crd), head_K)
            head_Q = jnp.where(done_c, take(QadmE, crd), head_Q)
            # 9. no work: advance to the next arrival or flag a stall
            nw = alive & ~had_batch & ~dec
            pend = nw & (arrived < n_req)
            t = jnp.where(pend, t + jnp.maximum(next_arr - t, 1e-6), t)
            stall = stall | (nw & ~pend & (q_next < arrived))
            live = live & ~(nw & ~pend)
            return (i + 1, live, stall, t, area, K, q_next, arrived,
                    ncomp, crd, ne, ctx_sum, head_K, head_Q,
                    KadmE, QadmE, TfirstE, TfinE)

        zi = jnp.zeros(B, idt)
        zf = jnp.zeros(B, fdt)
        init = (jnp.zeros((), idt), jnp.ones(B, bool), jnp.zeros(B, bool),
                zf, zf, zi, zi, zi, zi, zi, zi, zi, zi, zi,
                jnp.zeros((B, W), idt), jnp.zeros((B, W), idt),
                jnp.zeros((B, W), fdt), jnp.zeros((B, W), fdt))
        out = lax.while_loop(cond, body, init)
        (i, live, stall, t, area, _K, q_next, _arr, ncomp, crd, ne,
         _ctx, _hk, _hq, _KadmE, QadmE, TfirstE, TfinE) = out
        return (live, stall, t, area, ncomp, crd, ne,
                QadmE, TfirstE, TfinE)

    return jax.jit(phase)


# ---------------------------------------------------------------------------
# host wrapper
# ---------------------------------------------------------------------------


def _edge_pad(a: np.ndarray, b_pad: int) -> np.ndarray:
    return np.pad(a, (0, b_pad - len(a)), mode="edge")


def _run_jit_fleet(points: Sequence[FleetPoint], streams) -> List:
    """Run jit-eligible points as lanes of one compiled phase; returns
    per-lane RunRecords. Raises `JitFallback` when the compiled loop
    could not finish (caller re-runs on the numpy fleet)."""
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.simulate import HW_BY_NAME, StepTimeModel

    fdt = np.float64 if precision.active_x64() else np.float32
    idt = np.int64 if precision.active_x64() else np.int32
    B = len(points)
    n_req = np.asarray([len(s[0]) for s in streams], idt)
    N = int(n_req.max()) if B else 0
    n_pad = _pow2(N)
    b_pad = _pow2(B)

    models = []
    for p in points:
        s = p.engine
        models.append(StepTimeModel(get_config(s.arch), HW_BY_NAME[s.hw],
                                    n_chips=s.n_chips, quant=s.quant))
    fm = FleetStepModel(models)
    md = {}
    for name in _MODEL_FIELDS:
        arr = getattr(fm, name)
        if arr.dtype == bool:
            md[name] = jnp.asarray(_edge_pad(arr, b_pad))
        else:
            md[name] = jnp.asarray(_edge_pad(arr.astype(fdt), b_pad))

    ivec = lambda key: _edge_pad(                            # noqa: E731
        np.asarray([key(p.engine) for p in points], idt), b_pad)
    uplen = np.asarray(
        [int(s[1][0]) if len(s[0]) else 1 for s in streams], idt)
    umn = np.asarray(
        [int(s[2][0]) if len(s[0]) else 2 for s in streams], idt)
    page_size = np.asarray([int(p.engine.page_size) for p in points], idt)
    uneed = (-(-(uplen + umn) // page_size)).astype(idt)
    ec = {
        "n_req": _edge_pad(n_req, b_pad) if B else n_req,
        "mb": ivec(lambda s: int(s.max_batch)),
        "pf_budget": ivec(lambda s: int(s.prefill_token_budget)),
        "max_pf_reqs": ivec(lambda s: int(s.max_prefill_reqs)),
        "num_pages": ivec(lambda s: int(s.num_pages)),
        "uplen": _edge_pad(uplen, b_pad),
        "umn": _edge_pad(umn, b_pad),
        "uneed": _edge_pad(uneed, b_pad),
        "horizon": _edge_pad(np.asarray(
            [np.inf if p.horizon is None else float(p.horizon)
             for p in points], fdt), b_pad),
    }
    # padding lanes are born finished
    ec["n_req"][B:] = 0
    ec = {k: jnp.asarray(v) for k, v in ec.items()}

    r_arr = np.full((b_pad, n_pad + 1), np.inf, fdt)
    for i, (times, _pi, _po) in enumerate(streams):
        r_arr[i, :len(times)] = times

    ilog_n = (n_pad + 1).bit_length()
    ilog_k = max(1, int(umn.max()) if B else 2).bit_length()
    cap = max(_CAP_FLOOR, _CAP_PER_REQ * n_pad)
    phase = _compiled_phase(ilog_n, ilog_k, cap)
    (live, stall, t, area, ncomp, crd, ne, QadmE, TfirstE, TfinE) = [
        np.asarray(a) for a in phase(md, ec, jnp.asarray(r_arr))]
    if live[:B].any() or stall[:B].any():
        raise JitFallback(
            "compiled fleet loop did not converge "
            f"(live={int(live[:B].sum())}, stall={int(stall[:B].sum())})")

    # -- host-side record reconstruction (cohort logs -> request rows) --
    r_first = np.full((B, N), np.nan)
    r_finish = np.full((B, N), np.nan)
    r_out = np.zeros((B, N), np.int64)
    r_plen = np.zeros((B, N), np.int64)
    for i in range(B):
        ne_i, crd_i, nc_i = int(ne[i]), int(crd[i]), int(ncomp[i])
        r_plen[i, :] = uplen[i]
        if ne_i == 0:
            continue
        q = QadmE[i, :ne_i].astype(np.int64)
        cnt = np.diff(np.concatenate(([0], q)))
        n_adm = int(q[-1])
        r_first[i, :n_adm] = np.repeat(TfirstE[i, :ne_i], cnt)
        r_out[i, :n_adm] = 1
        if crd_i:
            r_finish[i, :nc_i] = np.repeat(TfinE[i, :crd_i], cnt[:crd_i])
            r_out[i, :nc_i] = umn[i]
    zc = np.zeros(B, np.int64)
    view = types.SimpleNamespace(
        n_req=n_req.astype(np.int64), r_arr=r_arr[:B].astype(np.float64),
        r_plen=r_plen, r_first=r_first, r_finish=r_finish, r_out=r_out,
        t=t[:B].astype(np.float64), area=area[:B].astype(np.float64),
        # jit-eligible lanes have no admission control or classes; the
        # counters _lane_record reads are identically zero
        cnt_shed=zc, cnt_timeout=zc, cnt_abandoned=zc, cnt_class_shed=zc,
        cnt_browned=zc, cnt_browned_tokens=zc, cnt_slo_viol=zc)
    return [_lane_record(view, i, p) for i, p in enumerate(points)]


def jit_run_points(points: Sequence[FleetPoint],
                   on_result=None) -> List:
    """`fleet_run_points` with the compiled loop for every point it can
    express; the rest (and any chunk the compiled loop rejects) run on
    the numpy fleet unchanged. Records agree with the numpy oracle
    within `precision.jit_tolerance()`; `on_result(index, record)`
    fires per lane once its phase completes (chunk-granular for the
    compiled lanes)."""
    if not points:
        return []
    precision.enable_x64()
    streams = [synth_arrays(p.arrivals) for p in points]
    jit_ids = [i for i, p in enumerate(points)
               if jit_eligible(p, streams[i])]
    out: List = [None] * len(points)
    rest = [i for i in range(len(points)) if i not in set(jit_ids)]
    if rest:
        def _sub(j: int, rec):
            out[rest[j]] = rec
            if on_result is not None:
                on_result(rest[j], rec)
        fleet_run_points([points[i] for i in rest], on_result=_sub)
    if jit_ids:
        sub_pts = [points[i] for i in jit_ids]
        try:
            recs = _run_jit_fleet(sub_pts, [streams[i] for i in jit_ids])
        except JitFallback:
            recs = fleet_run_points(sub_pts)
        for j, rec in zip(jit_ids, recs):
            out[j] = rec
            if on_result is not None:
                on_result(j, rec)
    return out
