"""Open-loop arrival processes and synthetic request generation.

Matches the paper's protocol (§4.3): Poisson arrivals (burstiness 1.0) by
default, Gamma inter-arrivals for the burstiness probe (CV=2 ==
--burstiness 0.25), fixed 512:256 I/O shape by default with the RAG /
agentic / variable-length (log-normal) shapes of §5.7 available.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

import numpy as np

from repro.serving.request import Request


def poisson_arrivals(rng: np.random.Generator, lam: float, n: int,
                     start: float = 0.0) -> np.ndarray:
    """n exponential inter-arrival times at rate lam (CV=1)."""
    gaps = rng.exponential(1.0 / lam, size=n)
    return start + np.cumsum(gaps)


def gamma_arrivals(rng: np.random.Generator, lam: float, cv: float, n: int,
                   start: float = 0.0) -> np.ndarray:
    """Gamma inter-arrivals with coefficient of variation `cv` at rate lam.

    shape k = 1/cv^2, scale = cv^2 / lam  (mean 1/lam, CV = cv).
    """
    k = 1.0 / (cv * cv)
    theta = cv * cv / lam
    gaps = rng.gamma(k, theta, size=n)
    return start + np.cumsum(gaps)


# I/O shapes from the paper: chat 512:256 (headline), RAG 4096:1024,
# agentic 1024:4096 (§5.7).
IO_SHAPES = {
    "chat": (512, 256),
    "rag": (4096, 1024),
    "agentic": (1024, 4096),
}


@dataclasses.dataclass(frozen=True)
class ArrivalSpec:
    lam: float                      # offered rate (req/s)
    n_requests: int
    io_shape: str = "chat"          # key of IO_SHAPES or "variable"
    process: str = "poisson"        # poisson | gamma
    cv: float = 1.0                 # gamma CV (paper probe: 2.0)
    seed: int = 0
    scale: float = 1.0              # token-length scale (CPU tier shrinks)
    shared_prefix_groups: int = 0   # >0 -> prefix-sharing workload (§5.7)


def synth_arrays(spec: ArrivalSpec, start: float = 0.0
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """The request stream as struct-of-arrays: (arrival_times, prompt_lens,
    max_new_tokens), each of length `spec.n_requests` in rid order.

    This is the one place the stream's random draws happen (times first,
    then lengths, off a single generator), so `synth_requests` and the
    fleet simulator's array-native lanes consume bit-identical streams."""
    rng = np.random.default_rng(spec.seed)
    if spec.process == "gamma":
        times = gamma_arrivals(rng, spec.lam, spec.cv, spec.n_requests, start)
    else:
        times = poisson_arrivals(rng, spec.lam, spec.n_requests, start)

    n = spec.n_requests
    if spec.io_shape == "variable":
        # §5.7 log-normal: input median ~400 (p10/p90 120/906),
        # output median ~200 (p10/p90 68/408). One vectorized draw per
        # stream, sampled in rid order (same values as a per-request loop
        # drawing p_in then p_out would need two interleaved calls, so the
        # stream layout here is its own stable protocol).
        p_ins = rng.lognormal(math.log(400), 0.63, size=n)
        p_outs = rng.lognormal(math.log(200), 0.70, size=n)
        p_ins = np.maximum(8, p_ins.astype(np.int64))
        p_outs = np.maximum(4, p_outs.astype(np.int64))
    else:
        p_in, p_out = IO_SHAPES[spec.io_shape]
        p_ins = np.full(n, p_in, np.int64)
        p_outs = np.full(n, p_out, np.int64)
    p_ins = np.maximum(4, (p_ins * spec.scale).astype(np.int64))
    p_outs = np.maximum(2, (p_outs * spec.scale).astype(np.int64))
    return times, p_ins, p_outs


def synth_requests(spec: ArrivalSpec, start: float = 0.0) -> List[Request]:
    times, p_ins, p_outs = synth_arrays(spec, start)
    return [Request(rid=i, arrival_time=float(times[i]),
                    prompt_len=int(p_ins[i]), max_new_tokens=int(p_outs[i]))
            for i in range(spec.n_requests)]
