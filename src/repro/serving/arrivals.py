"""Open-loop arrival processes and synthetic request generation.

Matches the paper's protocol (§4.3): Poisson arrivals (burstiness 1.0) by
default, Gamma inter-arrivals for the burstiness probe (CV=2 ==
--burstiness 0.25), fixed 512:256 I/O shape by default with the RAG /
agentic / variable-length (log-normal) shapes of §5.7 available.

Non-stationary traffic (ISSUE 8): a `RateProfile` turns the stationary
lambda into lambda(t) — piecewise-constant windows, a diurnal sinusoid,
MMPP-style two-state burst switching, or replay of a (t, rate) trace —
and `profile_arrivals` generates the corresponding non-homogeneous
Poisson stream by Lewis-Shedler thinning.

lambda(t) stream protocol (frozen, like the `synth_arrays` contract):

* Candidate points are drawn from a homogeneous Poisson process at the
  profile's max rate in fixed blocks of `THINNING_BLOCK` draws — per
  block, `rng.exponential(1/lam_max, THINNING_BLOCK)` gaps first, then
  `rng.random(THINNING_BLOCK)` acceptance uniforms — and candidate t is
  accepted iff `u * lam_max < lambda(t)`. Block size, draw order and the
  strict `<` are part of the protocol: they fix the rng consumption
  pattern, so the same (spec.seed, profile) always yields the same
  stream on every backend.
* A CONSTANT profile never thins: `synth_arrays` routes it through the
  exact legacy `poisson_arrivals`/`gamma_arrivals` path, so a stationary
  spec with `profile=RateProfile.constant(spec.lam)` is byte-identical
  to the same spec with `profile=None` (tested; committed stores rely on
  it).
* MMPP profiles are *realized* before thinning: the two-state switching
  timeline is drawn from a dedicated generator seeded
  `spec.seed + MMPP_SEED_OFFSET`, never from the arrival stream's
  generator, so the arrival draws stay aligned with the other kinds.
* Zero-rate segments accept nothing — candidates falling inside them are
  rejected, which is exactly "no arrivals in this window". A profile
  whose max rate is 0 raises ValueError, and a profile that accepts too
  few points (e.g. a trace that decays to 0 forever) raises RuntimeError
  after `THINNING_MAX_BLOCKS` candidate blocks instead of spinning.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

import numpy as np

from repro.serving.request import Request


def poisson_arrivals(rng: np.random.Generator, lam: float, n: int,
                     start: float = 0.0) -> np.ndarray:
    """n exponential inter-arrival times at rate lam (CV=1).

    lam == 0 means "no arrivals in this window" and returns an empty
    array (ISSUE 8 — previously 1/lam minted inf times that propagated
    silently through cumsum into engine clocks); lam < 0 raises."""
    if lam < 0:
        raise ValueError(f"arrival rate must be >= 0, got {lam}")
    if lam == 0:
        return np.empty(0, np.float64)
    gaps = rng.exponential(1.0 / lam, size=n)
    return start + np.cumsum(gaps)


def gamma_arrivals(rng: np.random.Generator, lam: float, cv: float, n: int,
                   start: float = 0.0) -> np.ndarray:
    """Gamma inter-arrivals with coefficient of variation `cv` at rate lam.

    shape k = 1/cv^2, scale = cv^2 / lam  (mean 1/lam, CV = cv).
    Zero/negative rates follow `poisson_arrivals`' contract."""
    if lam < 0:
        raise ValueError(f"arrival rate must be >= 0, got {lam}")
    if lam == 0:
        return np.empty(0, np.float64)
    k = 1.0 / (cv * cv)
    theta = cv * cv / lam
    gaps = rng.gamma(k, theta, size=n)
    return start + np.cumsum(gaps)


# ---------------------------------------------------------------------------
# lambda(t): rate profiles + thinning (ISSUE 8)
# ---------------------------------------------------------------------------

# thinning draw-block size — part of the frozen stream protocol above
THINNING_BLOCK = 4096
# candidate blocks before giving up on a profile that accepts ~nothing
THINNING_MAX_BLOCKS = 4096
# MMPP switching timelines draw from spec.seed + this offset (dedicated
# stream, like the warmup stream's +7777 and FailureSpec's +911)
MMPP_SEED_OFFSET = 9973
# priority-class draws (ISSUE 9) come from their own generator at
# spec.seed + this offset: a spec without a class mix performs ZERO
# extra draws, so every historical stream stays byte-identical
CLASS_SEED_OFFSET = 5851


@dataclasses.dataclass(frozen=True)
class RateProfile:
    """lambda(t), picklable and frozen so it can ride Cells/FleetPoints.

    kinds:
      constant   rate(t) = args[0] (routes through the legacy generators)
      piecewise  knots = ((duration_s, rate), ...) cycled forever
      diurnal    sinusoid over period_s: trough/peak = args[0]/args[1],
                 peak centered at args[2] (fraction of the period)
      mmpp       2-state Markov-modulated Poisson: args = (rate_a,
                 rate_b, dwell_a_s, dwell_b_s); the exponential-dwell
                 switching timeline is realized from a dedicated seed
      trace      knots = ((t_s, rate), ...) step-held replay; rate holds
                 past the last knot, and period_s > 0 cycles the trace
    """
    kind: str = "constant"
    knots: Tuple[Tuple[float, float], ...] = ()
    period_s: float = 0.0
    args: Tuple[float, ...] = ()

    # -- constructors ----------------------------------------------------
    @classmethod
    def constant(cls, rate: float) -> "RateProfile":
        return cls(kind="constant", args=(float(rate),))

    @classmethod
    def piecewise(cls, segments) -> "RateProfile":
        return cls(kind="piecewise",
                   knots=tuple((float(d), float(r)) for d, r in segments))

    @classmethod
    def diurnal(cls, trough: float, peak: float, period_s: float,
                peak_frac: float = 0.5) -> "RateProfile":
        return cls(kind="diurnal", period_s=float(period_s),
                   args=(float(trough), float(peak), float(peak_frac)))

    @classmethod
    def mmpp(cls, rate_a: float, rate_b: float, dwell_a_s: float,
             dwell_b_s: float) -> "RateProfile":
        return cls(kind="mmpp", args=(float(rate_a), float(rate_b),
                                      float(dwell_a_s), float(dwell_b_s)))

    @classmethod
    def trace(cls, points, period_s: float = 0.0) -> "RateProfile":
        return cls(kind="trace", period_s=float(period_s),
                   knots=tuple((float(t), float(r)) for t, r in points))

    # -- validation ------------------------------------------------------
    def validate(self) -> "RateProfile":
        if self.kind == "constant":
            if len(self.args) != 1:
                raise ValueError("constant profile needs args=(rate,)")
            if self.args[0] < 0:
                raise ValueError(f"rate must be >= 0, got {self.args[0]}")
        elif self.kind == "piecewise":
            if not self.knots:
                raise ValueError("piecewise profile needs segments")
            for d, r in self.knots:
                if d <= 0:
                    raise ValueError(f"segment duration must be > 0: {d}")
                if r < 0:
                    raise ValueError(f"rate must be >= 0, got {r}")
        elif self.kind == "diurnal":
            if len(self.args) != 3:
                raise ValueError(
                    "diurnal profile needs args=(trough, peak, peak_frac)")
            trough, peak, _ = self.args
            if trough < 0 or peak < trough:
                raise ValueError(
                    f"need 0 <= trough <= peak, got {trough}..{peak}")
            if self.period_s <= 0:
                raise ValueError("diurnal profile needs period_s > 0")
        elif self.kind == "mmpp":
            if len(self.args) != 4:
                raise ValueError("mmpp profile needs args=(rate_a, rate_b, "
                                 "dwell_a_s, dwell_b_s)")
            ra, rb, da, db = self.args
            if ra < 0 or rb < 0:
                raise ValueError(f"rates must be >= 0, got {ra}, {rb}")
            if da <= 0 or db <= 0:
                raise ValueError(f"dwells must be > 0, got {da}, {db}")
        elif self.kind == "trace":
            if not self.knots:
                raise ValueError("trace profile needs (t, rate) knots")
            ts = [t for t, _ in self.knots]
            if ts != sorted(ts):
                raise ValueError("trace knots must ascend in t")
            for _, r in self.knots:
                if r < 0:
                    raise ValueError(f"rate must be >= 0, got {r}")
        else:
            raise ValueError(f"unknown profile kind {self.kind!r}")
        return self

    # -- queries ---------------------------------------------------------
    @property
    def is_constant(self) -> bool:
        return self.kind == "constant"

    def as_constant(self) -> Optional[float]:
        """The constant rate this profile degenerates to, else None.

        An MMPP whose two states share one rate (`rate_a == rate_b`), or
        whose first dwell is infinite (it never leaves state A), IS a
        constant-rate process — thinning it would only burn rng draws and
        float error for the identical distribution. `synth_arrays` routes
        such profiles through the exact legacy generators, so the stream
        is byte-identical to `RateProfile.constant(rate)` (ISSUE 9
        satellite; regression-tested)."""
        if self.kind == "constant":
            return self.args[0]
        if self.kind == "mmpp":
            ra, rb, da, _ = self.args
            if ra == rb or math.isinf(da):
                return ra
        return None

    def max_rate(self) -> float:
        if self.kind == "constant":
            return self.args[0]
        if self.kind in ("piecewise", "trace"):
            return max(r for _, r in self.knots)
        if self.kind == "diurnal":
            return self.args[1]
        if self.kind == "mmpp":
            return max(self.args[0], self.args[1])
        raise ValueError(f"unknown profile kind {self.kind!r}")

    def mean_rate(self) -> float:
        """Long-run mean of lambda(t) (label/reporting, not generation)."""
        if self.kind == "constant":
            return self.args[0]
        if self.kind == "piecewise":
            total = sum(d for d, _ in self.knots)
            return sum(d * r for d, r in self.knots) / total
        if self.kind == "diurnal":
            return 0.5 * (self.args[0] + self.args[1])
        if self.kind == "mmpp":
            ra, rb, da, db = self.args
            return (ra * da + rb * db) / (da + db)
        if self.kind == "trace":
            span = self.period_s if self.period_s > 0 else self.knots[-1][0]
            if span <= self.knots[0][0]:
                return self.knots[-1][1]
            ts = [t for t, _ in self.knots] + [span]
            return sum((t1 - t0) * r for t0, t1, (_, r) in
                       zip(ts, ts[1:], self.knots)) / (span - ts[0])
        raise ValueError(f"unknown profile kind {self.kind!r}")

    def rate_at(self, ts: np.ndarray) -> np.ndarray:
        """Vectorized lambda(t). MMPP profiles must be realized first
        (`profile_arrivals` does; calling this raises)."""
        ts = np.asarray(ts, np.float64)
        if self.kind == "constant":
            return np.full(ts.shape, self.args[0])
        if self.kind == "piecewise":
            durs = np.array([d for d, _ in self.knots])
            rates = np.array([r for _, r in self.knots])
            edges = np.cumsum(durs)
            tt = np.mod(ts, edges[-1])
            return rates[np.searchsorted(edges, tt, side="right")]
        if self.kind == "diurnal":
            trough, peak, peak_frac = self.args
            phase = ts / self.period_s - peak_frac
            return trough + (peak - trough) * 0.5 * (
                1.0 + np.cos(2.0 * np.pi * phase))
        if self.kind == "trace":
            tt = np.mod(ts, self.period_s) if self.period_s > 0 else ts
            t0 = np.array([t for t, _ in self.knots])
            rates = np.array([r for _, r in self.knots])
            idx = np.clip(np.searchsorted(t0, tt, side="right") - 1,
                          0, len(rates) - 1)
            return rates[idx]
        if self.kind == "mmpp":
            raise ValueError("mmpp profiles must be realized before "
                             "evaluation (profile_arrivals does this)")
        raise ValueError(f"unknown profile kind {self.kind!r}")

    def realize(self, seed: int, t_end: float) -> "RateProfile":
        """MMPP -> the equivalent piecewise profile covering [0, t_end):
        alternating exponential dwells drawn from a dedicated generator
        (`seed + MMPP_SEED_OFFSET`). Deterministic and prefix-stable: a
        longer t_end extends the same timeline. Other kinds return self."""
        if self.kind != "mmpp":
            return self
        ra, rb, da, db = self.args
        rng = np.random.default_rng(seed + MMPP_SEED_OFFSET)
        segs, t, state = [], 0.0, 0
        while t < t_end:
            dwell = float(rng.exponential(da if state == 0 else db))
            dwell = max(dwell, 1e-9)
            segs.append((dwell, ra if state == 0 else rb))
            t += dwell
            state ^= 1
        return RateProfile.piecewise(segs)


def profile_arrivals(rng: np.random.Generator, profile: RateProfile,
                     n: int, start: float = 0.0,
                     seed: int = 0) -> np.ndarray:
    """n arrival times from the non-homogeneous Poisson process lambda(t)
    by Lewis-Shedler thinning (see the module docstring for the frozen
    draw protocol). Constant profiles should take the legacy path in
    `synth_arrays` instead — calling this on one works but consumes a
    different rng pattern."""
    profile.validate()
    lam_max = profile.max_rate()
    if lam_max <= 0:
        raise ValueError("profile max rate is 0 — no arrivals can ever be "
                         "accepted (an all-zero profile means no traffic)")
    accepted: List[np.ndarray] = []
    got, t_last, blocks = 0, float(start), 0
    realized = profile
    while got < n:
        if blocks >= THINNING_MAX_BLOCKS:
            raise RuntimeError(
                f"thinning accepted only {got}/{n} arrivals after "
                f"{blocks} candidate blocks — the profile's rate mass is "
                f"(near-)zero over the generated span")
        gaps = rng.exponential(1.0 / lam_max, size=THINNING_BLOCK)
        ts = t_last + np.cumsum(gaps)
        us = rng.random(THINNING_BLOCK)
        if profile.kind == "mmpp":
            realized = profile.realize(seed, float(ts[-1]))
        keep = ts[us * lam_max < realized.rate_at(ts)]
        accepted.append(keep)
        got += len(keep)
        t_last = float(ts[-1])
        blocks += 1
    return np.concatenate(accepted)[:n]


# I/O shapes from the paper: chat 512:256 (headline), RAG 4096:1024,
# agentic 1024:4096 (§5.7).
IO_SHAPES = {
    "chat": (512, 256),
    "rag": (4096, 1024),
    "agentic": (1024, 4096),
}


@dataclasses.dataclass(frozen=True)
class ArrivalSpec:
    lam: float                      # offered rate (req/s); with a non-
    #                                 constant profile this is the nominal
    #                                 label (records/seeds), lambda(t) rules
    n_requests: int
    io_shape: str = "chat"          # key of IO_SHAPES or "variable"
    process: str = "poisson"        # poisson | gamma
    cv: float = 1.0                 # gamma CV (paper probe: 2.0)
    seed: int = 0
    scale: float = 1.0              # token-length scale (CPU tier shrinks)
    shared_prefix_groups: int = 0   # >0 -> prefix-sharing workload (§5.7)
    # lambda(t) (ISSUE 8): None = stationary (exact historical streams);
    # a constant profile routes through the legacy generators and is
    # byte-identical to profile=None at the same rate (tested).
    profile: Optional[RateProfile] = None
    # priority-class mix (ISSUE 9): per-class probabilities in class
    # order (interactive, batch, background, ...). Empty = every request
    # is interactive and NO class draws happen (historical streams and
    # their rng consumption stay byte-identical). Classes draw from a
    # dedicated generator at seed + CLASS_SEED_OFFSET.
    class_mix: Tuple[float, ...] = ()


def synth_arrays(spec: ArrivalSpec, start: float = 0.0
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """The request stream as struct-of-arrays: (arrival_times, prompt_lens,
    max_new_tokens) in rid order — length `spec.n_requests`, except that a
    zero-rate stationary spec yields empty arrays (no arrivals ever).

    This is the one place the stream's random draws happen (times first,
    then lengths, off a single generator), so `synth_requests` and the
    fleet simulator's array-native lanes consume bit-identical streams.
    Non-constant profiles draw times by thinning (module docstring
    protocol) off the same generator, then lengths exactly as before."""
    if spec.shared_prefix_groups:
        # §5.7 declares a prefix-sharing workload, but neither the sim
        # engine nor the step-time model gives shared prefixes a distinct
        # cost yet — running such a cell as plain chat would silently
        # mislabel the measurement (ISSUE 8 satellite: loud > silent).
        raise NotImplementedError(
            "shared_prefix_groups is declared (§5.7) but no execution "
            "tier models prefix sharing yet; set it to 0 — cells claiming "
            "a prefix-sharing workload must not silently run plain chat")
    rng = np.random.default_rng(spec.seed)
    prof = spec.profile
    const_rate = prof.as_constant() if prof is not None else None
    if prof is not None and const_rate is None:
        if spec.process != "poisson":
            raise ValueError(
                "non-constant rate profiles require process='poisson' "
                "(thinning is exact for Poisson streams only)")
        prof.validate()
        times = profile_arrivals(rng, prof, spec.n_requests, start,
                                 seed=spec.seed)
    else:
        lam = const_rate if prof is not None else spec.lam
        if spec.process == "gamma":
            times = gamma_arrivals(rng, lam, spec.cv, spec.n_requests, start)
        else:
            times = poisson_arrivals(rng, lam, spec.n_requests, start)

    n = len(times)
    if spec.io_shape == "variable":
        # §5.7 log-normal: input median ~400 (p10/p90 120/906),
        # output median ~200 (p10/p90 68/408). One vectorized draw per
        # stream, sampled in rid order (same values as a per-request loop
        # drawing p_in then p_out would need two interleaved calls, so the
        # stream layout here is its own stable protocol).
        p_ins = rng.lognormal(math.log(400), 0.63, size=n)
        p_outs = rng.lognormal(math.log(200), 0.70, size=n)
        p_ins = np.maximum(8, p_ins.astype(np.int64))
        p_outs = np.maximum(4, p_outs.astype(np.int64))
    else:
        p_in, p_out = IO_SHAPES[spec.io_shape]
        p_ins = np.full(n, p_in, np.int64)
        p_outs = np.full(n, p_out, np.int64)
    p_ins = np.maximum(4, (p_ins * spec.scale).astype(np.int64))
    p_outs = np.maximum(2, (p_outs * spec.scale).astype(np.int64))
    return times, p_ins, p_outs


def synth_classes(spec: ArrivalSpec, n: int) -> np.ndarray:
    """Per-request priority classes in rid order (ISSUE 9).

    Drawn from a DEDICATED generator (`spec.seed + CLASS_SEED_OFFSET`),
    never from the stream's generator — adding a class mix to a spec
    leaves its (times, lengths) stream byte-identical, and a spec
    without a mix draws nothing at all (all-interactive zeros)."""
    mix = spec.class_mix
    if not mix:
        return np.zeros(n, np.int64)
    if any(p < 0 for p in mix) or sum(mix) <= 0:
        raise ValueError(f"class_mix must be nonnegative with mass: {mix}")
    p = np.asarray(mix, np.float64)
    p = p / p.sum()
    rng = np.random.default_rng(spec.seed + CLASS_SEED_OFFSET)
    return rng.choice(len(p), size=n, p=p).astype(np.int64)


def synth_requests(spec: ArrivalSpec, start: float = 0.0) -> List[Request]:
    times, p_ins, p_outs = synth_arrays(spec, start)
    classes = synth_classes(spec, len(times))
    return [Request(rid=i, arrival_time=float(times[i]),
                    prompt_len=int(p_ins[i]), max_new_tokens=int(p_outs[i]),
                    priority=int(classes[i]))
            for i in range(len(times))]
