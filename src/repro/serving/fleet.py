"""Vectorized multi-cell fleet simulator (ISSUE 4 tentpole).

One fleet = B independent experiment cells run as *lanes* of a single
struct-of-arrays event loop. The scalar engine (`serving.engine.Engine`
under `fast_forward=True`) pays one Python scheduler iteration per
scheduling event per cell; the fleet pays one Python iteration per event
*round* — every live lane advances through exactly one iteration of the
scalar state machine per round, with the per-iteration work (next-event
selection, the closed-form `decode_time_multi` clock jump, slot
bookkeeping, completion detection) computed across all lanes in batched
numpy ops on (B,) / (B, max_batch) / (B, n_requests) arrays. The
Python-interpreter cost of a scheduling event is thereby amortized over
the whole fleet instead of paid per cell.

Equivalence discipline (the PR-1 contract, extended to a third path):
every lane takes bit-for-bit the same scheduling decisions and clock
arithmetic as a scalar `run_point` on the same cell — not merely within
tolerance. Two mechanisms enforce this:

* `FleetStepModel` mirrors `StepTimeModel._decode_terms` /
  `decode_time` / `decode_time_multi` / `prefill_time` op-for-op in
  float64 numpy (same association order, same guards), so each lane's
  step durations are IEEE-identical to the scalar model's
  (`tests/test_fleet.py` asserts `==`, not `approx`). Any new roofline
  term added to `StepTimeModel` must be mirrored here — the bitwise
  test is the tripwire.
* `FleetEngine` replays `Engine._run_fast`'s event order exactly: the
  same iteration structure (horizon check, failure injection, idle
  jump with its horizon/failure replay, arrivals, FCFS admission under
  the chunked-prefill budget, one-step decode after a composition
  change, closed-form jump to the next event otherwise), the same
  `max(gap, 1e-6)` advances, the same per-lane clock accumulation
  order, and the same failure-injection RNG stream (slot ids evolve
  identically, so `default_rng(0).choice` picks the same victims).

Sequential-by-nature work (FCFS admission, free-list bookkeeping,
failure re-queues) stays per-lane Python but is O(#events) — identical
to the scalar path — while everything per-iteration is vectorized; the
speedup is the amortization of the loop body, not a change in what the
scheduler decides. RunRecords produced by `fleet_run_points` are
therefore byte-identical to `core.sweep.run_point`'s after store
consolidation, which is what lets `experiments.runner.execute_cells`
treat `backend="vector"` as a pure execution detail.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.arrivals import ArrivalSpec, synth_arrays, synth_classes

_HUGE = np.iinfo(np.int64).max // 4


@dataclasses.dataclass(frozen=True)
class FleetPoint:
    """One lane: everything `core.sweep.run_point` takes, flattened into a
    picklable record (the fleet analogue of an experiment `Cell`)."""
    engine: "SimEngineSpec"           # sim-tier engine coordinates
    arrivals: ArrivalSpec
    warmup: int = 0
    horizon: Optional[float] = None
    failure_times: Tuple[float, ...] = ()
    # RunRecord labels (run_point's **record_kw)
    config: str = ""
    model: str = ""
    hw: str = "cpu-node"
    n_chips: int = 1
    quant: str = "bf16"
    engine_kind: str = "sim"
    price_per_hr: float = 1.0
    # resilience (ISSUE 6) / overload (ISSUE 9): lanes with a stochastic
    # failure process or client retries run through the scalar engine per
    # lane (fleet_run_points routes them) — the SoA loop's contiguous
    # queue cursors cannot express retry feedback, and per-lane fallback
    # keeps the RNG streams trivially identical to run_point's. Pure
    # admission lanes (max_queue_depth / deadline_s / OverloadPolicy,
    # no failures, no retries) run IN the fleet via an explicit per-lane
    # admission queue (`_accept_lane` / `_admit_lane_adm`).
    failure_spec: Optional["FailureSpec"] = None
    retry: Optional["RetryPolicy"] = None


# ---------------------------------------------------------------------------
# Vectorized step-time model
# ---------------------------------------------------------------------------


class FleetStepModel:
    """Struct-of-arrays mirror of `simulate.step_time.StepTimeModel`.

    Per-lane derived constants are precomputed with exactly the scalar
    model's expressions (association order preserved), and every method
    below mirrors its scalar counterpart op-for-op in float64, so lane i
    answers bitwise what `models[i]` would. All inputs/outputs are (B,)
    float64 arrays; integers are passed as exact float64 values.
    """

    def __init__(self, models: Sequence["StepTimeModel"]):
        f = lambda vals: np.asarray(vals, np.float64)        # noqa: E731
        self.nc = f([m.n_chips for m in models])
        self.fixed = f([m.fixed_overhead for m in models])
        self.is_moe = np.asarray([m.cfg.moe is not None for m in models])
        self.moe_oh = f([m.moe_dispatch_overhead for m in models])
        self.moe_ratio = f([(m.cfg.moe.top_k / m.cfg.moe.num_experts)
                            if m.cfg.moe is not None else 0.0
                            for m in models])
        self.wb = f([m.weight_bytes for m in models])
        # awb/wb with the scalar's own division (one rounding, reused)
        self.q_ratio = f([m.active_weight_bytes / m.weight_bytes
                          for m in models])
        self.kv = f([m._kv_bytes_tok for m in models])
        self.ap2 = f([2.0 * m._active_params for m in models])
        # denominators exactly as the scalar builds them each call:
        # (n_chips * peak) * mfu — association order matters for rounding
        self.cdenom = f([m.n_chips * m._peak_decode * m.mfu_decode
                         for m in models])
        self.pdenom = f([m.n_chips * m._peak * m.mfu for m in models])
        self.bwd = f([m.n_chips * m.hw.hbm_bw * m.mbu for m in models])
        self.ici_denom = f([m.n_chips * m.hw.ici_bw for m in models])
        self.ncm1 = f([m.n_chips - 1 for m in models])
        self.L2 = f([2 * m.cfg.num_layers for m in models])
        self.Lf = f([m.cfg.num_layers for m in models])
        self.dm = f([m.cfg.d_model for m in models])
        self.attn_coef = f([2 * 2 * m._n_attn * m.cfg.num_heads *
                            m.cfg.resolved_head_dim for m in models])

    # -- mirrors of StepTimeModel (op order preserved) -------------------
    def _collective(self, tokens: np.ndarray) -> np.ndarray:
        bytes_ar = (self.L2 * tokens * self.dm * 2.0 * 2.0 *
                    self.ncm1 / self.nc)
        out = bytes_ar / self.ici_denom
        return np.where(self.nc <= 1.0, 0.0, out)

    def _decode_terms(self, b: np.ndarray):
        compute = self.ap2 * b / self.cdenom
        inner = np.where(self.is_moe, b * self.moe_ratio, 1.0)
        touched = np.minimum(1.0, np.maximum(self.q_ratio, inner))
        mem_base = self.wb * touched / self.bwd
        mem_slope = b * self.kv / self.bwd
        moe_term = np.where(self.is_moe, self.moe_oh * b, 0.0)
        const = self._collective(b) + moe_term + self.fixed
        return compute, mem_base, mem_slope, const

    def decode_time(self, b: np.ndarray, ctx: np.ndarray) -> np.ndarray:
        compute, mem_base, mem_slope, const = self._decode_terms(b)
        dt = np.maximum(compute, mem_base + mem_slope * ctx) + const
        return np.where(b == 0.0, self.fixed, dt)

    def jump(self, terms, ctx0: np.ndarray, k: np.ndarray) -> np.ndarray:
        """k-step jump from cached `_decode_terms(b)` — the engine computes
        the terms once per round and reuses them across the initial jump,
        every bisection probe and the final duration. Valid for k >= 1;
        the k == 1 case needs no special-casing: with k = 1 the series
        formula reduces bit-for-bit to `1 * decode_time(b, ctx0)` (m
        clips to 0 or 1, leaving exactly `max(compute, mem0) + const`).
        Requires a caller-scoped errstate/seterr guard: lanes with
        slope == 0 divide by zero here and are overwritten by `flat`."""
        compute, mem_base, slope, const = terms
        mem0 = mem_base + slope * ctx0
        m = np.ceil((compute - mem0) / slope)
        m = np.minimum(np.maximum(m, 0.0), k)
        series = (k - m) * mem0 + slope * (m + k - 1.0) * (k - m) / 2.0
        out = m * compute + series + k * const
        if (slope <= 0.0).any():
            flat = k * (np.maximum(compute, mem0) + const)
            out = np.where(slope <= 0.0, flat, out)
        return out

    def decode_time_multi(self, b: np.ndarray, ctx0: np.ndarray,
                          k: np.ndarray) -> np.ndarray:
        with np.errstate(divide="ignore", invalid="ignore"):
            out = self.jump(self._decode_terms(b), ctx0, k)
        out = np.where(b == 0.0, k * self.fixed, out)
        return np.where(k <= 0.0, 0.0, out)

    def prefill_time(self, n_tok: np.ndarray, n_req: np.ndarray
                     ) -> np.ndarray:
        mean_len = n_tok / np.maximum(n_req, 1.0)
        flops = self.ap2 * n_tok
        flops = flops + self.attn_coef * n_tok * mean_len
        compute = flops / self.pdenom
        mem_bytes = self.wb + 2.0 * n_tok * self.dm * 2.0 * self.Lf
        memory = mem_bytes / self.bwd
        moe_term = np.where(self.is_moe, self.moe_oh * n_tok, 0.0)
        out = (np.maximum(compute, memory) + self._collective(n_tok) +
               moe_term + self.fixed)
        return np.where(n_tok == 0.0, 0.0, out)


# ---------------------------------------------------------------------------
# Struct-of-arrays engine
# ---------------------------------------------------------------------------


class FleetEngine:
    """B scalar fast-forward engines advanced in vectorized lockstep.

    Slot state lives in (B, S) arrays (S = the widest lane's max_batch);
    request streams in (B, N+1) arrays padded with +inf arrivals; the
    free-slot lists are array-backed stacks whose push/pop order matches
    the scalar PageManager's list exactly (so slot ids — and thereby the
    failure-injection RNG stream — are identical). Lanes with uniform
    request shapes (every grid cell: fixed io_shape) admit through a
    closed-form vectorized FCFS pass; variable-shape lanes, re-queue
    fronts and failure-tracked lanes fall back to a per-lane mirror of
    `Engine._admit_from`. Slot context is tracked as a per-lane running
    sum (ctx of a slot is always prompt_len + tokens_out - 1), which is
    all `SimExecutor.decode_multi`'s mean-context input needs."""

    def __init__(self, specs: Sequence["SimEngineSpec"]):
        from repro.configs import get_config
        from repro.simulate import HW_BY_NAME, StepTimeModel

        self.B = B = len(specs)
        self.specs = list(specs)
        models = []
        for s in specs:
            cfg = get_config(s.arch)
            models.append(StepTimeModel(cfg, HW_BY_NAME[s.hw],
                                        n_chips=s.n_chips, quant=s.quant))
        self.model = FleetStepModel(models)
        self.S = S = max(s.max_batch for s in specs)
        ivec = lambda key: np.asarray([key(s) for s in specs], np.int64)  # noqa: E731
        self.mb = ivec(lambda s: s.max_batch)
        self.page_size = ivec(lambda s: s.page_size)
        self.mpps = ivec(lambda s: s.max_pages_per_seq)
        self.pf_budget = ivec(lambda s: s.prefill_token_budget)
        self.max_pf_reqs = ivec(lambda s: s.max_prefill_reqs)
        # page 0 is reserved (PageManager trash page)
        self.num_pages = ivec(lambda s: s.num_pages)
        self.free_pages = self.num_pages - 1
        self.max_retries = np.full(B, 2, np.int64)   # EngineConfig default
        # admission control / overload (ISSUE 9): lanes with any of these
        # run their FCFS queue as an explicit per-lane rid list (the
        # contiguous [q_next, arrived) window cannot express sheds or
        # deadline pops); everything else keeps the windowed fast path
        self.mqd = ivec(lambda s: getattr(s, "max_queue_depth", 0))
        self.ddl = np.asarray(
            [float(getattr(s, "deadline_s", 0.0)) for s in specs])
        self.ovl = [getattr(s, "overload", None) for s in specs]
        self.ovl_enabled = np.asarray(
            [p is not None and p.enabled for p in self.ovl])
        self.has_pol = np.asarray([p is not None for p in self.ovl])
        self.any_pol = bool(self.has_pol.any())
        self.slo_s = np.asarray(
            [p.ttft_slo_s if p is not None else 0.0 for p in self.ovl])
        self.adm = self.ovl_enabled | (self.mqd > 0) | (self.ddl > 0.0)
        self.any_adm = bool(self.adm.any())
        self.adm_ddl = self.adm & (self.ddl > 0.0)
        self.any_adm_ddl = bool(self.adm_ddl.any())
        self.adm_queue: List[List[int]] = [[] for _ in range(B)]
        self.adm_qlen = np.zeros(B, np.int64)
        # controller state persists across phases AND the measurement
        # reset, exactly like the scalar engine's _ovl_state/_last_ttft
        self.ovl_state = np.zeros(B, np.int64)
        self.last_ttft = np.zeros(B)
        # outcome counters — the scalar engine's MetricsRegistry counters,
        # zeroed at the warmup/measurement boundary like metrics.reset()
        self.cnt_shed = np.zeros(B, np.int64)
        self.cnt_timeout = np.zeros(B, np.int64)
        self.cnt_abandoned = np.zeros(B, np.int64)
        self.cnt_class_shed = np.zeros(B, np.int64)
        self.cnt_browned = np.zeros(B, np.int64)
        self.cnt_browned_tokens = np.zeros(B, np.int64)
        self.cnt_slo_viol = np.zeros(B, np.int64)

        # lane clock + Little's-law integral
        self.t = np.zeros(B)
        self.area = np.zeros(B)
        self.n_occ = np.zeros(B, np.int64)
        self.ctx_sum = np.zeros(B, np.int64)
        # slot state (B, S); s_max is _HUGE on inactive slots so the
        # remaining-token min and the completion compare need no mask
        self.s_active = np.zeros((B, S), bool)
        self.s_out = np.zeros((B, S), np.int64)
        self.s_max = np.full((B, S), _HUGE, np.int64)
        self.s_rid = np.zeros((B, S), np.int64)
        self.s_need = np.zeros((B, S), np.int64)
        # free-slot stack: row i valid in [0, n_free[i]), top at the end —
        # push/pop order identical to the scalar free_slots list
        self.free_stack = np.zeros((B, S), np.int64)
        for i, m in enumerate(self.mb):
            self.free_stack[i, :m] = np.arange(int(m) - 1, -1, -1)
        self.n_free = self.mb.copy()
        # slot_req insertion order, kept only where failure injection can
        # read it (fail_running's rng.choice walks admission order)
        self.occ_order: List[Optional[Dict[int, None]]] = [None] * B
        # persistent per-lane victim streams, mirroring the scalar
        # engine's `_fail_rng` (seeded once, consecutive draws across
        # stacked failure events)
        self.fail_rngs: List[Optional[np.random.Generator]] = [None] * B
        self.requeue: List[List[int]] = [[] for _ in range(B)]
        self.n_requeue = np.zeros(B, np.int64)
        # scheduler instrumentation (bench surface)
        self.n_rounds = 0

    # -- phase loading ---------------------------------------------------
    def load_phase(self, streams: Sequence[Sequence[np.ndarray]],
                   horizons: Sequence[Optional[float]],
                   failure_times: Sequence[Sequence[float]]):
        """Install one request stream per lane ((times, p_ins, p_outs)
        from `synth_arrays`, optionally + classes from `synth_classes`);
        empty lanes (n=0) are born finished."""
        B = self.B
        self.n_req = np.asarray([len(s[0]) for s in streams], np.int64)
        N = int(self.n_req.max()) if B else 0
        self.r_arr = np.full((B, N + 1), np.inf)
        self.r_plen = np.zeros((B, N), np.int64)
        self.r_mnew = np.zeros((B, N), np.int64)
        self.r_first = np.full((B, N), np.nan)
        self.r_finish = np.full((B, N), np.nan)
        self.r_out = np.zeros((B, N), np.int64)
        self.r_retry = np.zeros((B, N), np.int64)
        self.times: List[np.ndarray] = []
        self.plen_l: List[List[int]] = []
        self.mnew_l: List[List[int]] = []
        self.cls_l: List[np.ndarray] = []
        self.uniform = np.zeros(B, bool)
        self.uplen = np.ones(B, np.int64)
        self.umn = np.ones(B, np.int64)
        for i, stream in enumerate(streams):
            times, p_ins, p_outs = stream[0], stream[1], stream[2]
            n = len(times)
            self.cls_l.append(np.asarray(stream[3], np.int64)
                              if len(stream) > 3 else np.zeros(n, np.int64))
            self.r_arr[i, :n] = times
            self.r_plen[i, :n] = p_ins
            self.r_mnew[i, :n] = p_outs
            self.times.append(np.asarray(times, np.float64))
            self.plen_l.append([int(v) for v in p_ins])
            self.mnew_l.append([int(v) for v in p_outs])
            if n and p_ins.min() == p_ins.max() and \
                    p_outs.min() == p_outs.max():
                self.uniform[i] = True
                self.uplen[i] = int(p_ins[0])
                self.umn[i] = int(p_outs[0])
        self.uneed = -(-(self.uplen + self.umn) // self.page_size)
        self.q_next = np.zeros(B, np.int64)
        self.arrived = np.zeros(B, np.int64)
        self.horizon = np.asarray(
            [np.inf if h is None else float(h) for h in horizons])
        self.fails: List[List[float]] = [sorted(ft) for ft in failure_times]
        self.fail_idx = [0] * B
        self.next_fail = np.asarray(
            [ft[0] if ft else np.inf for ft in self.fails])
        # track slot_req insertion order only on lanes that can fail;
        # uniform-shape untracked lanes take the vectorized admission path
        self.tracked = np.asarray([bool(ft) for ft in self.fails])
        for i in range(B):
            self.requeue[i] = []
            self.adm_queue[i] = []
            self.occ_order[i] = {} if self.tracked[i] else None
            if self.tracked[i] and self.n_occ[i]:
                raise RuntimeError("failure-tracked lane loaded with "
                                   "slots still occupied")
        self.n_requeue[:] = 0
        self.adm_qlen[:] = 0

    def reset_measurement(self):
        """Scalar `Engine.reset_measurement`: zero clocks + counters at
        the warmup/measurement boundary (engine state stays warm; the
        overload controller's ovl_state/last_ttft persist, exactly like
        the scalar engine's fields vs its metrics)."""
        self.t[:] = 0.0
        self.area[:] = 0.0
        self.cnt_shed[:] = 0
        self.cnt_timeout[:] = 0
        self.cnt_abandoned[:] = 0
        self.cnt_class_shed[:] = 0
        self.cnt_browned[:] = 0
        self.cnt_browned_tokens[:] = 0
        self.cnt_slo_viol[:] = 0

    # -- per-lane sequential helpers ------------------------------------
    def _pop_fail(self, i: int):
        self.fail_idx[i] += 1
        fl = self.fails[i]
        self.next_fail[i] = (fl[self.fail_idx[i]]
                             if self.fail_idx[i] < len(fl) else np.inf)

    def _fail_lane(self, i: int, frac: float):
        """Mirror of `Engine.fail_running(frac)` for one lane (persistent
        per-lane `default_rng(0)` stream, choice over slots in admission
        order, exact frac=0/1 handling — all matching the scalar)."""
        slots = list(self.occ_order[i])
        if not slots or frac <= 0.0:
            return
        n = len(slots) if frac >= 1.0 else max(1, int(len(slots) * frac))
        if self.fail_rngs[i] is None:
            self.fail_rngs[i] = np.random.default_rng(0)
        rng = self.fail_rngs[i]
        requeued: List[int] = []
        for slot in rng.choice(slots, n, replace=False):
            slot = int(slot)
            rid = int(self.s_rid[i, slot])
            self.free_pages[i] += self.s_need[i, slot]
            self.free_stack[i, self.n_free[i]] = slot
            self.n_free[i] += 1
            del self.occ_order[i][slot]
            self.ctx_sum[i] -= self.plen_l[i][rid] + self.s_out[i, slot] - 1
            self.s_active[i, slot] = False
            self.s_out[i, slot] = 0
            self.s_max[i, slot] = _HUGE
            self.s_need[i, slot] = 0
            self.n_occ[i] -= 1
            self.r_retry[i, rid] += 1
            if self.r_retry[i, rid] <= self.max_retries[i]:
                self.r_out[i, rid] = 0
                self.r_first[i, rid] = np.nan
                requeued.append(rid)
            else:
                # FAILED — finish stays NaN; with no client retry the
                # scalar _client_reject counts it abandoned
                self.cnt_abandoned[i] += 1
        # PREPEND this event's victims: the scalar loop front-merges
        # `_requeue` into the FCFS queue every iteration
        # (`queue.extendleft(reversed(...))`), so a later failure's
        # requeues go AHEAD of an earlier failure's still-queued leftovers
        self.requeue[i][:0] = requeued
        self.n_requeue[i] += len(requeued)

    def _accept_lane(self, i: int, rid: int):
        """Mirror of `Engine._accept` for one drained arrival on an
        admission lane: overload state transition + class shed first,
        then the class-blind max_queue_depth cap, then the brownout
        clamp on the admitted request. The depth reading is the queue
        length BEFORE this arrival joins (scalar semantics). Fleet lanes
        never carry a RetryPolicy (`_needs_scalar`), so every rejection
        is a client abandonment."""
        pol = self.ovl[i]
        q = self.adm_queue[i]
        if pol is not None and pol.enabled:
            st = pol.next_state(int(self.ovl_state[i]), len(q),
                                float(self.last_ttft[i]))
            self.ovl_state[i] = st
            if not pol.admits(st, int(self.cls_l[i][rid])):
                self.cnt_shed[i] += 1
                self.cnt_class_shed[i] += 1
                self.cnt_abandoned[i] += 1
                return
        mqd = int(self.mqd[i])
        if mqd > 0 and len(q) >= mqd:
            self.cnt_shed[i] += 1
            self.cnt_abandoned[i] += 1
            return
        if pol is not None and pol.enabled:
            mnew = self.mnew_l[i][rid]
            clamped = pol.clamp(int(self.ovl_state[i]), mnew)
            if clamped < mnew:
                self.cnt_browned[i] += 1
                self.cnt_browned_tokens[i] += mnew - clamped
                self.mnew_l[i][rid] = clamped
                self.r_mnew[i, rid] = clamped
        q.append(rid)
        self.adm_qlen[i] += 1

    def _observe_lane(self, i: int, rids: Sequence[int]):
        """Mirror of `Engine._observe_ttfts` for one lane's prefilled
        batch (admission order, last writer wins)."""
        ttft = self.t[i] - self.times[i][np.asarray(rids, np.int64)]
        slo = float(self.slo_s[i])
        if slo > 0.0:
            self.cnt_slo_viol[i] += int((ttft > slo).sum())
        self.last_ttft[i] = float(ttft[-1])

    def _admit_lane_adm(self, i: int):
        """Mirror of `Engine._admit_from` over the explicit admission
        queue: deadline-expired heads pop unbounded (timeout + abandon —
        strictly greater-than, a wait equal to deadline_s is served; the
        pinned tie choice), interleaved with FCFS admission under the
        chunked-prefill budget. Called whenever the queue is non-empty —
        even when nothing can admit — because the scalar path pops
        expired heads on every iteration regardless of capacity."""
        budget = int(self.pf_budget[i])
        nmax = int(self.max_pf_reqs[i])
        ps = int(self.page_size[i])
        mpps = int(self.mpps[i])
        plen_l, mnew_l = self.plen_l[i], self.mnew_l[i]
        q = self.adm_queue[i]
        times = self.times[i]
        ddl = float(self.ddl[i])
        t = float(self.t[i])
        free_pages = int(self.free_pages[i])
        n_free = int(self.n_free[i])
        slots: List[int] = []
        rids: List[int] = []
        plens: List[int] = []
        mnews: List[int] = []
        n_tok = 0
        while q:
            rid = q[0]
            if ddl > 0.0 and t - times[rid] > ddl:
                q.pop(0)
                self.adm_qlen[i] -= 1
                self.cnt_timeout[i] += 1
                self.cnt_abandoned[i] += 1
                continue
            plen, mnew = plen_l[rid], mnew_l[rid]
            if not (len(slots) < nmax and (plen <= budget or not slots)):
                break
            need = -(-(plen + mnew) // ps)
            if need > mpps or not n_free or free_pages < need:
                break
            q.pop(0)
            self.adm_qlen[i] -= 1
            n_free -= 1
            slot = int(self.free_stack[i, n_free])
            slots.append(slot)
            rids.append(rid)
            plens.append(plen)
            mnews.append(mnew)
            free_pages -= need
            n_tok += plen
            budget -= plen
        if slots:
            self.s_rid[i, slots] = rids
            self.s_need[i, slots] = [
                -(-(p + m) // ps) for p, m in zip(plens, mnews)]
            self.s_max[i, slots] = mnews
            self.free_pages[i] = free_pages
            self.n_free[i] = n_free
            self.n_occ[i] += len(slots)
        return slots, rids, plens, mnews, n_tok

    def _admit_lane(self, i: int):
        """Mirror of `Engine._admit_from` for one lane: FCFS admission
        under the chunked-prefill budget (the general path — re-queue
        fronts, variable shapes, failure-tracked lanes). Returns (slots,
        rids, plens, mnews, n_tok)."""
        budget = int(self.pf_budget[i])
        nmax = int(self.max_pf_reqs[i])
        ps = int(self.page_size[i])
        mpps = int(self.mpps[i])
        plen_l, mnew_l = self.plen_l[i], self.mnew_l[i]
        occ = self.occ_order[i]
        rq = self.requeue[i]
        free_pages = int(self.free_pages[i])
        n_free = int(self.n_free[i])
        q_next = int(self.q_next[i])
        arrived = int(self.arrived[i])
        slots: List[int] = []
        rids: List[int] = []
        plens: List[int] = []
        mnews: List[int] = []
        n_tok = 0
        while len(slots) < nmax:
            if rq:
                rid = rq[0]
                from_rq = True
            elif q_next < arrived:
                rid = q_next
                from_rq = False
            else:
                break
            plen, mnew = plen_l[rid], mnew_l[rid]
            if not (plen <= budget or not slots):
                break
            need = -(-(plen + mnew) // ps)
            if need > mpps or not n_free or free_pages < need:
                break
            if from_rq:
                rq.pop(0)
                self.n_requeue[i] -= 1
            else:
                q_next += 1
            n_free -= 1
            slot = int(self.free_stack[i, n_free])
            if occ is not None:
                occ[slot] = None
            slots.append(slot)
            rids.append(rid)
            plens.append(plen)
            mnews.append(mnew)
            free_pages -= need
            n_tok += plen
            budget -= plen
        if slots:
            self.s_rid[i, slots] = rids
            self.s_need[i, slots] = [
                -(-(p + m) // ps) for p, m in zip(plens, mnews)]
            self.s_max[i, slots] = mnews
            self.free_pages[i] = free_pages
            self.n_free[i] = n_free
            self.q_next[i] = q_next
            self.n_occ[i] += len(slots)
        return slots, rids, plens, mnews, n_tok

    # -- the vectorized event loop ---------------------------------------
    def run_phase(self, on_lane_dead=None):
        """Advance every lane to completion. `on_lane_dead(i)` fires the
        moment lane i leaves the event loop (drained, horizon) — its
        request arrays are final from that point, which is what lets the
        vector backend stream per-cell results into the resumable store
        instead of checkpointing whole chunks."""
        B = self.B
        lanes = np.arange(B)
        live = np.ones(B, bool)
        self._run_phase_inner(B, lanes, live, self.model, on_lane_dead)

    def _run_phase_inner(self, B, lanes, live, model, on_lane_dead):
        any_tracked = bool(self.tracked.any())
        has_horizon = bool(np.isfinite(self.horizon).any())
        reported = np.zeros(B, bool)
        while True:
            # loop condition (top of the scalar while): anything left?
            live &= ((self.arrived < self.n_req)
                     | (self.q_next < self.arrived)
                     | (self.n_requeue > 0) | (self.n_occ > 0)
                     | (self.adm_qlen > 0))
            if on_lane_dead is not None:
                fresh = ~live & ~reported
                if fresh.any():
                    reported |= fresh
                    for i in np.flatnonzero(fresh):
                        on_lane_dead(int(i))
            if not live.any():
                break
            self.n_rounds += 1
            alive = live.copy()
            # 1. horizon
            if has_horizon:
                hb = alive & (self.t >= self.horizon)
                if hb.any():
                    live &= ~hb
                    alive &= ~hb
            # 2. failure injection
            if any_tracked:
                due = alive & (self.t >= self.next_fail)
                for i in np.flatnonzero(due):
                    self._fail_lane(int(i), 0.5)
                    self._pop_fail(int(i))
            # 3. idle regime: batch+queue empty -> jump to next arrival,
            #    replaying the horizon/failure checks (scalar order)
            next_arr = self.r_arr[lanes, self.arrived]
            maybe_idle = alive & (self.n_occ == 0)
            if maybe_idle.any():
                idle = (maybe_idle & (self.q_next == self.arrived)
                        & (self.n_requeue == 0) & (self.adm_qlen == 0)
                        & (self.arrived < self.n_req)
                        & (next_arr > self.t))
                if idle.any():
                    gap = np.maximum(next_arr - self.t, 1e-6)
                    self.t[idle] += gap[idle]   # inflight == 0: area += 0
                    if has_horizon:
                        hb = idle & (self.t >= self.horizon)
                        if hb.any():
                            live &= ~hb
                            alive &= ~hb
                    if any_tracked:
                        due = idle & alive & (self.t >= self.next_fail)
                        for i in np.flatnonzero(due):
                            self._fail_lane(int(i), 0.5)
                            self._pop_fail(int(i))
            # 4. arrivals: advance the arrived cursor past times <= t;
            #    admission lanes drain each arrival through _accept_lane
            #    (shed / clamp / enqueue) and keep q_next == arrived so
            #    the contiguous-window paths see an empty window
            move = alive & (next_arr <= self.t)
            if move.any():
                for i in np.flatnonzero(move):
                    i = int(i)
                    na = int(np.searchsorted(
                        self.times[i], self.t[i], side="right"))
                    if self.adm[i]:
                        for rid in range(int(self.arrived[i]), na):
                            self._accept_lane(i, rid)
                        self.q_next[i] = na
                    self.arrived[i] = na
            # 5+6. admission + prefill
            had_batch, pf_li, pf_ri = self._admit_and_prefill(B, lanes,
                                                              alive,
                                                              any_tracked)
            # 7. decode: closed-form jump to each lane's next event
            dec = alive & (self.n_occ > 0)
            if dec.any():
                self._decode(B, lanes, dec, had_batch, model, any_tracked,
                             has_horizon)
            # 8. no work: advance to the next arrival (or queued-head
            #    deadline expiry on admission lanes) / stall / finished
            nw = alive & ~had_batch & ~dec
            if nw.any():
                tgt = self.r_arr[lanes, self.arrived]
                if self.any_adm_ddl:
                    tgt = tgt.copy()
                    for i in np.flatnonzero(nw & self.adm_ddl
                                            & (self.adm_qlen > 0)):
                        i = int(i)
                        exp = (self.times[i][self.adm_queue[i][0]]
                               + self.ddl[i])
                        if exp < tgt[i]:
                            tgt[i] = exp
                pend = nw & np.isfinite(tgt)
                if pend.any():
                    gap = np.maximum(tgt - self.t, 1e-6)
                    self.t[pend] += gap[pend]
                stall = nw & ~pend & ((self.q_next < self.arrived)
                                      | (self.n_requeue > 0)
                                      | (self.adm_qlen > 0))
                if stall.any():
                    raise RuntimeError(
                        "scheduler stall: queued request cannot ever fit; "
                        "increase num_pages/max_pages_per_seq "
                        f"(lanes {np.flatnonzero(stall).tolist()})")
                live &= ~(nw & ~pend)

    # -- admission + prefill (one round) ---------------------------------
    def _admit_and_prefill(self, B, lanes, alive, any_tracked):
        qc = np.minimum(self.q_next, self.n_req - 1)
        head_tok = self.r_plen[lanes, qc] + self.r_mnew[lanes, qc]
        need = -(-head_tok // self.page_size)
        has_rq = self.n_requeue > 0
        can = (alive & ((self.q_next < self.arrived) | has_rq)
               & (self.n_occ < self.mb) & (self.max_pf_reqs > 0))
        # contiguous-queue head admissibility, vectorized; lanes with a
        # re-queue front fall back to the per-lane loop's own checks
        can &= (has_rq | ((need <= self.mpps) & (self.free_pages >= need)))
        # admission lanes (explicit queue) always take their own per-lane
        # path while the queue is non-empty — even when nothing can admit,
        # because the scalar _admit_from pops deadline-expired heads on
        # every iteration regardless of capacity
        slow_adm = (alive & self.adm & (self.adm_qlen > 0)) \
            if self.any_adm else np.zeros(B, bool)
        had_batch = np.zeros(B, bool)
        if not can.any() and not slow_adm.any():
            return had_batch, None, None
        # fast path: uniform request shape, no re-queue front, untracked —
        # the FCFS admission count is closed-form per lane
        fast = can & self.uniform & ~has_rq
        if any_tracked:
            fast &= ~self.tracked
        slow = can & ~fast
        n_tok = np.zeros(B, np.int64)
        li = ri = None
        if fast.any():
            n = np.maximum(self.pf_budget // self.uplen, 1)
            n = np.minimum(n, self.max_pf_reqs)
            n = np.minimum(n, self.arrived - self.q_next)
            n = np.minimum(n, self.free_pages // self.uneed)
            n = np.minimum(n, self.n_free)
            n_adm = np.where(fast, n, 0)
            fl = np.flatnonzero(n_adm)
            cnt = n_adm[fl]
            total = int(cnt.sum())
            li = np.repeat(fl, cnt)
            ends = np.cumsum(cnt)
            within = np.arange(total) - np.repeat(ends - cnt, cnt)
            si = self.free_stack[li, self.n_free[li] - 1 - within]
            ri = np.repeat(self.q_next[fl], cnt) + within
            self.n_free[fl] -= cnt
            self.q_next[fl] += cnt
            self.free_pages[fl] -= cnt * self.uneed[fl]
            self.n_occ[fl] += cnt
            self.s_rid[li, si] = ri
            self.s_need[li, si] = self.uneed[li]
            self.s_max[li, si] = self.umn[li]
            self.s_out[li, si] = 1
            self.s_active[li, si] = True
            n_tok[fl] = cnt * self.uplen[fl]
            had_batch[fl] = True
        slow_items = []
        if slow.any():
            for i in np.flatnonzero(slow):
                i = int(i)
                slots, rids, plens, mnews, toks = self._admit_lane(i)
                if slots:
                    slow_items.append((i, slots, rids, mnews))
                    had_batch[i] = True
                    n_tok[i] = toks
                    self.s_out[i, slots] = 1
                    self.s_active[i, slots] = True
        if slow_adm.any():
            for i in np.flatnonzero(slow_adm):
                i = int(i)
                slots, rids, plens, mnews, toks = self._admit_lane_adm(i)
                if slots:
                    slow_items.append((i, slots, rids, mnews))
                    had_batch[i] = True
                    n_tok[i] = toks
                    self.s_out[i, slots] = 1
                    self.s_active[i, slots] = True
        if not had_batch.any():
            return had_batch, None, None
        # number of admitted requests per lane this round
        n_breq = np.zeros(B, np.int64)
        if li is not None:
            np.add.at(n_breq, li, 1)
        for i, slots, _, _ in slow_items:
            n_breq[i] = len(slots)
        dt = self.model.prefill_time(n_tok.astype(np.float64),
                                     n_breq.astype(np.float64))
        pb = had_batch
        self.t[pb] += dt[pb]
        self.area[pb] += self.n_occ[pb] * dt[pb]
        self.ctx_sum[pb] += n_tok[pb]
        if li is not None:
            self.r_first[li, ri] = self.t[li]
            self.r_out[li, ri] = 1
        for i, slots, rids, mnews in slow_items:
            self.r_first[i, rids] = self.t[i]
            self.r_out[i, rids] = 1
        # post-prefill TTFT observation (scalar _observe_ttfts): SLO
        # violation counting + last-TTFT brownout input, batch order
        if self.any_pol:
            if li is not None and self.has_pol[li].any():
                for i in np.flatnonzero(fast & had_batch & self.has_pol):
                    i = int(i)
                    self._observe_lane(i, ri[li == i])
            for i, slots, rids, mnews in slow_items:
                if self.has_pol[i]:
                    self._observe_lane(int(i), rids)
        # prefill-time completion (max_new <= 1): scalar post-prefill
        # check, processed in admission order (free-stack push order
        # must match the scalar batch walk)
        pf_watch = [(i, slots, mnews) for i, slots, _, mnews in slow_items
                    if min(mnews) <= 1]
        if li is not None and (self.umn[had_batch] <= 1).any():
            for i in np.flatnonzero(fast & had_batch & (self.umn <= 1)):
                sl = si[li == i]
                pf_watch.append((int(i), sl.tolist(),
                                 [int(self.umn[i])] * len(sl)))
        for i, slots, mnews in pf_watch:
            pf_done = [s for s, m in zip(slots, mnews) if m <= 1]
            if not pf_done:
                continue
            rd = self.s_rid[i, pf_done]
            self.r_out[i, rd] = self.s_out[i, pf_done]
            self.r_finish[i, rd] = self.t[i]
            self._complete_slots(int(i), pf_done)
        return had_batch, li, ri

    def _complete_slots(self, i: int, slots: Sequence[int]):
        """Per-lane completion (prefill-time finishes; the decode path
        uses the flat vectorized pass)."""
        sl = list(slots)
        self.free_pages[i] += int(self.s_need[i, sl].sum())
        nf = int(self.n_free[i])
        self.free_stack[i, nf:nf + len(sl)] = sl
        self.n_free[i] = nf + len(sl)
        if self.occ_order[i] is not None:
            occ = self.occ_order[i]
            for s in sl:
                del occ[s]
        for s in sl:
            self.ctx_sum[i] -= (self.plen_l[i][int(self.s_rid[i, s])]
                                + self.s_out[i, s] - 1)
        self.s_active[i, sl] = False
        self.s_out[i, sl] = 0
        self.s_max[i, sl] = _HUGE
        self.s_need[i, sl] = 0
        self.n_occ[i] -= len(sl)

    # -- decode (one round) ----------------------------------------------
    def _decode(self, B, lanes, dec, had_batch, model, any_tracked,
                has_horizon):
        rem = (self.s_max - self.s_out).min(axis=1)
        k = np.maximum(np.where(had_batch, 1, np.minimum(rem, _HUGE)), 1)
        # time budget = nearest future event (inf when none): arrivals
        # only count while the FCFS queue is empty
        q_empty = ((self.q_next == self.arrived) & (self.n_requeue == 0)
                   & (self.adm_qlen == 0))
        next_arr = self.r_arr[lanes, self.arrived]
        cand = np.where(q_empty & (self.arrived < self.n_req),
                        next_arr - self.t, np.inf)
        if any_tracked:
            cand = np.minimum(cand, self.next_fail - self.t, out=cand)
        if has_horizon:
            cand = np.minimum(cand, self.horizon - self.t, out=cand)
        if self.any_adm_ddl:
            # queued-head deadline expiry unblocks FCFS: it is an event
            for i in np.flatnonzero(dec & self.adm_ddl
                                    & (self.adm_qlen > 0)):
                i = int(i)
                exp = (self.times[i][self.adm_queue[i][0]] + self.ddl[i]
                       - self.t[i])
                if exp < cand[i]:
                    cand[i] = exp
        # b floored to 1 on frozen/empty lanes: their values are masked
        # out below, and a nonzero b keeps slope > 0 (no flat branch).
        # errstate is scoped to the model math only — user callbacks
        # (store writes, progress hooks) must keep their normal fp state
        with np.errstate(divide="ignore", invalid="ignore"):
            n_eff = np.maximum(self.n_occ, 1)
            b = n_eff.astype(np.float64)
            ctx0 = self.ctx_sum / n_eff
            terms = model._decode_terms(b)
            kf = k.astype(np.float64)
            dtd = model.jump(terms, ctx0, kf)
            bis = dec & (k > 1) & (dtd >= cand)
            if bis.any():
                k, dtd = self._event_budget_k(model, terms, ctx0, cand, k,
                                              dtd, bis)
        self.t[dec] += dtd[dec]
        self.area[dec] += self.n_occ[dec] * dtd[dec]
        kk = np.where(dec, k, 0)
        self._apply_decode(B, dec, kk)

    def _event_budget_k(self, model, terms, ctx0, cand, k, dtd, bis):
        """Smallest k' in [1, k] with S(k') >= budget, for lanes whose
        decode burst is cut short by a nearer event (arrival / failure /
        horizon). A closed-form inversion of the k-step series — linear
        while compute-bound, quadratic once the growing KV read crosses
        the roofline — gives a candidate; a <=2-eval minimality check
        (S(k') >= budget, S(k'-1) < budget) confirms it as exactly the
        answer `SimExecutor.decode_multi`'s bisection returns (S is
        strictly increasing, so the minimal k' is unique), and rare
        float-edge stragglers fall back to true bisection."""
        idx = np.flatnonzero(bis)
        tsub = tuple(tt[idx] for tt in terms)
        compute, mem_base, slope, const = tsub
        c0 = ctx0[idx]
        bud = cand[idx]
        kmax = k[idx]
        kmaxf = kmax.astype(np.float64)
        mem0 = mem_base + slope * c0
        flat_step = np.maximum(compute, mem0) + const
        m_full = np.maximum(np.ceil((compute - mem0) / slope), 0.0)
        lin_k = np.ceil(bud / (compute + const))
        a = slope / 2.0
        bq = mem0 + const - a
        cq = m_full * compute - m_full * mem0 + a * (m_full -
                                                     m_full * m_full)
        disc = bq * bq - 4.0 * a * (cq - bud)
        root = (-bq + np.sqrt(np.maximum(disc, 0.0))) / (2.0 * a)
        kc = np.where(lin_k <= m_full, lin_k,
                      np.maximum(np.ceil(root), m_full + 1.0))
        kc = np.where(slope <= 0.0, np.ceil(bud / flat_step), kc)
        kc = np.minimum(np.maximum(kc, 1.0), kmaxf).astype(np.int64)
        sk = model.jump(tsub, c0, kc.astype(np.float64))
        good = np.zeros(len(idx), bool)
        for _ in range(3):
            ge = sk >= bud
            skm1 = model.jump(tsub, c0,
                              np.maximum(kc - 1, 1).astype(np.float64))
            good = ge & ((kc <= 1) | (skm1 < bud))
            if good.all():
                break
            kc = np.where(ge, np.where(good, kc, kc - 1), kc + 1)
            kc = np.minimum(np.maximum(kc, 1), kmax)
            sk = model.jump(tsub, c0, kc.astype(np.float64))
        if not good.all():
            # float-edge stragglers: exact bisection on the leftovers
            bad = ~good
            lo = np.ones(len(idx), np.int64)
            hi = kmax.copy()
            while True:
                act = bad & (lo < hi)
                if not act.any():
                    break
                mid = (lo + hi) // 2
                ge = model.jump(tsub, c0, mid.astype(np.float64)) >= bud
                hi = np.where(act & ge, mid, hi)
                lo = np.where(act & ~ge, mid + 1, lo)
            kc = np.where(bad, lo, kc)
            sk = np.where(bad, model.jump(tsub, c0,
                                          kc.astype(np.float64)), sk)
        k = k.copy()
        dtd = dtd.copy()
        k[idx] = kc
        dtd[idx] = sk
        return k, dtd

    def _apply_decode(self, B, dec, kk):
        self.ctx_sum[dec] += kk[dec] * self.n_occ[dec]
        step = kk[:, None] * self.s_active
        self.s_out += step
        done = self.s_out >= self.s_max
        if done.any():
            # flat completion pass across every lane at once; np.nonzero
            # is row-major, so per-lane slot order is ascending — same as
            # the scalar flatnonzero walk
            li, si = np.nonzero(done)
            rd = self.s_rid[li, si]
            self.r_out[li, rd] = self.s_out[li, si]
            self.r_finish[li, rd] = self.t[li]
            self.free_pages += np.bincount(
                li, self.s_need[li, si], minlength=B).astype(np.int64)
            ctx_del = self.r_plen[li, rd] + self.s_out[li, si] - 1
            counts = np.bincount(li, minlength=B)
            self.ctx_sum -= np.bincount(li, ctx_del,
                                        minlength=B).astype(np.int64)
            self.s_active[li, si] = False
            self.s_out[li, si] = 0
            self.s_max[li, si] = _HUGE
            self.s_need[li, si] = 0
            # push freed slots back on the stacks (ascending per lane)
            ends = np.cumsum(counts)
            within = np.arange(len(li)) - np.repeat(ends - counts, counts)
            self.free_stack[li, self.n_free[li] + within] = si
            self.n_free += counts
            self.n_occ -= counts
            if self.tracked[li].any():
                pos = 0
                for i in np.flatnonzero(counts):
                    c = int(counts[i])
                    if self.tracked[i]:
                        occ = self.occ_order[int(i)]
                        for s in si[pos:pos + c]:
                            del occ[int(s)]
                    pos += c


# ---------------------------------------------------------------------------
# run_point over a fleet
# ---------------------------------------------------------------------------


def _pct(vals: np.ndarray, q: float) -> float:
    """core.sweep._pct over an array (same np.percentile, same *1e3)."""
    return float(np.percentile(vals, q)) * 1e3 if len(vals) else float("nan")


def _lane_record(eng: FleetEngine, i: int, p: FleetPoint) -> "RunRecord":
    """Assemble lane i's RunRecord exactly as `run_point` would (same
    percentile calls, same reductions); valid once the lane has left the
    measured-phase event loop."""
    from repro.core.cost import c_eff
    from repro.core.records import RunRecord

    n = int(eng.n_req[i])
    spec = p.arrivals
    done = ~np.isnan(eng.r_finish[i, :n])
    finish = eng.r_finish[i, :n][done]
    first = eng.r_first[i, :n][done]
    arr = eng.r_arr[i, :n][done]
    toks = eng.r_out[i, :n][done]
    window = float(eng.t[i])
    out_toks = int(toks.sum())
    in_toks = int(eng.r_plen[i, :n][done].sum())
    tps = out_toks / window if window > 0 else 0.0
    tpot = (finish - first) / np.maximum(toks - 1, 1)
    mean_inflight = float(eng.area[i]) / max(window, 1e-9)
    return RunRecord(
        config=p.config, model=p.model, hw=p.hw, n_chips=p.n_chips,
        quant=p.quant, engine=p.engine_kind, lam=spec.lam,
        io_shape=spec.io_shape, n_requests=spec.n_requests,
        n_completed=int(done.sum()), window_s=window,
        tps=tps, prompt_tps=in_toks / window if window else 0.0,
        ttft_p50_ms=_pct(first - arr, 50),
        ttft_p90_ms=_pct(first - arr, 90),
        ttft_p99_ms=_pct(first - arr, 99),
        tpot_p50_ms=_pct(tpot, 50),
        tpot_p99_ms=_pct(tpot, 99),
        e2e_p50_ms=_pct(finish - arr, 50),
        e2e_p99_ms=_pct(finish - arr, 99),
        mean_inflight=mean_inflight,
        price_per_hr=p.price_per_hr,
        c_eff=c_eff(p.price_per_hr, tps),
        seed=spec.seed,
        mttf=p.failure_spec.mttf if p.failure_spec is not None else 0.0,
        retry_max=p.retry.max_attempts if p.retry is not None else 0,
        n_shed=int(eng.cnt_shed[i]),
        n_timeout=int(eng.cnt_timeout[i]),
        n_retried=0,    # RetryPolicy lanes never reach the fleet
        n_abandoned=int(eng.cnt_abandoned[i]),
        n_class_shed=int(eng.cnt_class_shed[i]),
        n_browned=int(eng.cnt_browned[i]),
        browned_tokens=int(eng.cnt_browned_tokens[i]),
        n_slo_viol=int(eng.cnt_slo_viol[i]),
        interactive_tps=(
            int(toks[eng.cls_l[i][:n][done] == 0].sum()) / window
            if (spec.class_mix and window > 0) else 0.0))


def _needs_admission(p: FleetPoint) -> bool:
    """Points whose lanes run the explicit admission queue (shedding,
    deadlines, an overload controller or SLO monitor)."""
    eng = p.engine
    return (getattr(eng, "max_queue_depth", 0) > 0
            or getattr(eng, "deadline_s", 0.0) > 0.0
            or getattr(eng, "overload", None) is not None)


def _needs_scalar(p: FleetPoint) -> bool:
    """Lanes the SoA loop cannot express (retry feedback, stochastic
    failure streams, deterministic failures combined with admission
    control) run per-lane through the scalar engine — the explicitly
    sanctioned fallback, RNG streams identical to `run_point` by
    construction. Pure admission/brownout points (ISSUE 9) are NOT on
    this list: they run vectorized through the fleet's explicit
    admission queue."""
    return ((p.failure_spec is not None and p.failure_spec.enabled)
            or (p.retry is not None and p.retry.enabled)
            or (bool(p.failure_times) and _needs_admission(p)))


def _stream(spec: ArrivalSpec):
    """(times, p_ins, p_outs, classes) — the same draws, in the same
    stream order, as the scalar `synth_requests`."""
    times, p_ins, p_outs = synth_arrays(spec)
    return times, p_ins, p_outs, synth_classes(spec, len(times))


def _scalar_point(p: FleetPoint) -> "RunRecord":
    from repro.core.sweep import run_point
    return run_point(
        p.engine, p.arrivals, warmup=p.warmup, horizon=p.horizon,
        failure_times=p.failure_times, failure_spec=p.failure_spec,
        retry=p.retry, config=p.config, model=p.model, hw=p.hw,
        n_chips=p.n_chips, quant=p.quant, engine_kind=p.engine_kind,
        price_per_hr=p.price_per_hr)


def fleet_run_points(points: Sequence[FleetPoint],
                     on_result=None) -> List["RunRecord"]:
    """Run every point as one lane of one vectorized fleet; returns
    RunRecords equal (field-for-field, bit-for-bit) to running
    `core.sweep.run_point` on each point independently. Points with
    resilience features enabled (`_needs_scalar`) are executed through
    the scalar engine per lane, after the vectorized lanes.

    `on_result(index, record)` streams each lane's record the moment the
    lane finishes its measured phase — the store hook for per-cell
    resume granularity on in-process runs (lanes finish at different sim
    times; a killed 128-lane chunk loses only the lanes still in
    flight, not the whole chunk)."""
    if not points:
        return []
    scalar_ids = [i for i, p in enumerate(points) if _needs_scalar(p)]
    if scalar_ids:
        lane_ids = [i for i in range(len(points)) if i not in
                    set(scalar_ids)]
        out: List[Optional["RunRecord"]] = [None] * len(points)
        if lane_ids:
            sub = [points[i] for i in lane_ids]

            def _sub_result(j: int, rec):
                out[lane_ids[j]] = rec
                if on_result is not None:
                    on_result(lane_ids[j], rec)

            fleet_run_points(sub, on_result=_sub_result)
        for i in scalar_ids:
            out[i] = _scalar_point(points[i])
            if on_result is not None:
                on_result(i, out[i])
        return list(out)
    eng = FleetEngine([p.engine for p in points])
    # warmup phase (per-lane stream seed + 7777, no horizon/failures),
    # exactly run_point's protocol; warmup-free lanes sit it out
    if any(p.warmup for p in points):
        streams = []
        for p in points:
            if p.warmup:
                wspec = dataclasses.replace(p.arrivals,
                                            n_requests=p.warmup,
                                            seed=p.arrivals.seed + 7777)
                streams.append(_stream(wspec))
            else:
                z = np.zeros(0)
                zi = z.astype(np.int64)
                streams.append((z, zi, zi, zi))
        eng.load_phase(streams, [None] * len(points),
                       [()] * len(points))
        eng.run_phase()
        eng.reset_measurement()
    # measured phase
    eng.load_phase([_stream(p.arrivals) for p in points],
                   [p.horizon for p in points],
                   [p.failure_times for p in points])
    out: List[Optional["RunRecord"]] = [None] * len(points)

    def _on_dead(i: int):
        out[i] = _lane_record(eng, i, points[i])
        if on_result is not None:
            on_result(i, out[i])

    eng.run_phase(on_lane_dead=_on_dead)
    return list(out)
