"""Failure, retry, and shedding primitives for the serving stack (ISSUE 6).

The paper's C_eff is only honest if it reflects what infrastructure
*delivers*: replicas crash and lose in-flight work, queued requests time
out, clients retry and thereby raise the offered load. This module holds
the deterministic specifications of those processes so the scalar engine,
the fleet backend, and the experiment grid all consume bit-identical
event streams.

* `FailureSpec` — an exponential crash/recovery process (MTTF/MTTR) with
  a partial-slot loss fraction. `FailureStream` lazily draws the events
  from one seeded generator so runs with unknown horizons (open-loop
  drains) stay deterministic: gap_i ~ Exp(mttf) measured from the last
  recovery, downtime_i ~ Exp(mttr); during downtime the engine admits
  nothing (restart/warmup lag).
* `RetryPolicy` — client-side capped exponential backoff with a retry
  budget and optional jitter. Re-submissions feed back into the arrival
  stream inside the engine loop, so retry amplification visibly raises
  offered lambda.
* `FailureEvent` / `as_failure_events` — normalisation of the legacy
  `failure_times=[t, ...]` replay (a bare float means "lose half the
  running slots at t", the pre-ISSUE-6 hardcoded behaviour).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Optional, Sequence, Union

import numpy as np


@dataclasses.dataclass(frozen=True)
class FailureEvent:
    """One replica-loss event: at `time`, lose `frac` of running slots and
    admit nothing for `downtime` seconds (restart/warmup lag)."""
    time: float
    frac: float = 0.5
    downtime: float = 0.0


def as_failure_events(failure_times: Sequence[Union[float, FailureEvent]]
                      ) -> List[FailureEvent]:
    """Normalise the legacy float replay to events, keeping time order."""
    evs = [ft if isinstance(ft, FailureEvent) else FailureEvent(float(ft))
           for ft in failure_times]
    return sorted(evs, key=lambda e: e.time)


@dataclasses.dataclass(frozen=True)
class FailureSpec:
    """Exponential crash-recovery process. `mttf <= 0` disables it."""
    mttf: float = 0.0       # mean time to failure (s of engine clock)
    mttr: float = 0.0       # mean restart lag; 0 = instant recovery
    loss_frac: float = 0.5  # fraction of running slots lost per crash
    seed: int = 0

    @property
    def enabled(self) -> bool:
        return self.mttf > 0.0

    def availability(self) -> float:
        """Steady-state single-replica availability mttf/(mttf+mttr)."""
        if not self.enabled:
            return 1.0
        return self.mttf / (self.mttf + max(self.mttr, 0.0))

    def stream(self) -> "FailureStream":
        return FailureStream(self)


class FailureStream:
    """Deterministic lazy iterator over a FailureSpec's crash events.

    Draw order per event is fixed (gap, then downtime) so any consumer
    seeing the same spec sees the same stream; `peek()` materialises the
    next event without consuming it, which lets re-entrant engine runs
    keep their place."""

    def __init__(self, spec: FailureSpec):
        self.spec = spec
        self._rng = np.random.default_rng(spec.seed)
        self._clock = 0.0
        self._next: Optional[FailureEvent] = None

    def peek(self) -> Optional[FailureEvent]:
        if not self.spec.enabled:
            return None
        if self._next is None:
            gap = float(self._rng.exponential(self.spec.mttf))
            down = (float(self._rng.exponential(self.spec.mttr))
                    if self.spec.mttr > 0.0 else 0.0)
            self._clock += gap
            self._next = FailureEvent(self._clock, self.spec.loss_frac, down)
            self._clock += down      # next gap counts from recovery
        return self._next

    def pop(self) -> Optional[FailureEvent]:
        ev = self.peek()
        self._next = None
        return ev


class FailureTimeline:
    """Merged view of a legacy replay list and a FailureSpec stream.

    The engine asks `peek()` at the top of every scheduling iteration and
    `pop()` when it injects the event; both paths (per-token reference and
    event-driven fast-forward) therefore agree on event times exactly."""

    def __init__(self, legacy: Iterable[FailureEvent],
                 stream: Optional[FailureStream] = None):
        self._legacy = list(legacy)
        self._li = 0
        self._stream = stream

    def peek(self) -> Optional[FailureEvent]:
        lg = (self._legacy[self._li]
              if self._li < len(self._legacy) else None)
        sp = self._stream.peek() if self._stream is not None else None
        if lg is None:
            return sp
        if sp is None or lg.time <= sp.time:
            return lg
        return sp

    def pop(self) -> Optional[FailureEvent]:
        ev = self.peek()
        if ev is None:
            return None
        lg = (self._legacy[self._li]
              if self._li < len(self._legacy) else None)
        if lg is ev:
            self._li += 1
        else:
            self._stream.pop()
        return ev


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Client retry: capped exponential backoff with a retry budget.

    `max_attempts <= 0` disables it. The k-th re-submission (k = 1..budget)
    waits `min(base_delay_s * 2**(k-1), max_delay_s) + U(0, jitter_s)`
    after the rejection it reacts to; delays are measured from the
    *path-independent* trigger time (arrival, deadline expiry, failure
    event), never from the scheduler's bookkeeping clock, so the reference
    and fast-forward paths re-submit at bit-identical times."""
    max_attempts: int = 0
    base_delay_s: float = 0.5
    max_delay_s: float = 8.0
    jitter_s: float = 0.0
    seed: int = 0

    @property
    def enabled(self) -> bool:
        return self.max_attempts > 0

    def delay(self, attempt: int, rng=None) -> float:
        d = min(self.base_delay_s * (2.0 ** max(attempt - 1, 0)),
                self.max_delay_s)
        if self.jitter_s > 0.0 and rng is not None:
            d += self.jitter_s * float(rng.random())
        return d
