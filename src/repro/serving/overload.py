"""Overload survival: priority classes, brownout, hysteretic shedding.

ISSUE 9 tentpole (a). PR 6 gave the engine *blind* admission control —
`max_queue_depth` sheds whoever arrives over the cap, interactive or
not. Real operators survive flash crowds with graceful degradation:
shed the background work first, clamp output-token budgets ("brownout")
before refusing anyone, and only hard-shed when both levers are
exhausted. This module is that controller, engine-agnostic and
deterministic.

Design constraints (the PR 6/8 discipline):

* **Pure functions of engine-observable state.** The controller never
  owns a clock or an RNG: `next_state` maps (state, queue depth, last
  observed TTFT) -> state, and `admits`/`clamp` are lookups. All three
  execution paths (per-token reference, event-driven fast-forward,
  fleet lanes) evaluate the controller at the same deterministic points
  — per drained submission in `Engine._accept` / the fleet's
  `_accept_lane` — on bit-identical inputs (queue contents and prefill
  times are already path-identical), so records stay bit-identical.
* **Hysteresis.** Entry thresholds (`brownout_depth`, `shed_depth`) and
  the exit threshold (`recover_depth`) form a band: a controller that
  entered BROWNOUT at depth 8 does not flap back at depth 7 — it waits
  for depth <= `recover_depth` (and a TTFT observation back under the
  SLO). Recovery steps DOWN one level per evaluation (SHED -> BROWNOUT
  -> NORMAL), never jumps.
* **Priority-ordered shedding.** Requests carry a priority class
  (interactive=0 < batch=1 < background=2; lower = more important). In
  BROWNOUT only classes >= `brownout_shed_floor` are refused (default:
  background only); in SHED, classes >= `shed_floor` (default: batch
  and background). Interactive traffic is only ever refused by the
  class-blind `max_queue_depth` hard cap, which stays the last line.
* **Brownout clamps, it does not refuse.** In BROWNOUT and SHED,
  admitted requests get `max_new_tokens` clamped to
  `brownout_max_new` — each clamped request frees decode budget and
  KV pages for the crowd. The clipped token count is metered
  (`repro:browned_tokens_total`) so the degradation is *priced*, not
  hidden.

The SLO knob (`ttft_slo_s`) is dual-use: it is the measurement SLO
(every served request whose TTFT exceeds it increments
`repro:request_slo_violation_total`, even under a monitor-only policy)
and, when the controller is armed, a brownout trigger (one observed
TTFT over the SLO enters BROWNOUT regardless of depth). A policy with
*only* `ttft_slo_s` set is a pure monitor: `enabled` is False, nothing
is shed or clamped, violations are counted — that is the
degradation-OFF arm of the flash-crowd experiment.
"""
from __future__ import annotations

import dataclasses

# priority classes (lower = more important; the default class is
# interactive so priority-free workloads are never shed by class rules)
INTERACTIVE = 0
BATCH = 1
BACKGROUND = 2

# controller states, ordered by severity
NORMAL = 0
BROWNOUT = 1
SHED = 2

STATE_NAMES = {NORMAL: "normal", BROWNOUT: "brownout", SHED: "shed"}


@dataclasses.dataclass(frozen=True)
class OverloadPolicy:
    """Deterministic admission/degradation controller (frozen, picklable,
    hashable — rides SimEngineSpec/Cell like FailureSpec/RetryPolicy).

    All-zero fields are the inert policy: `enabled` is False and an
    engine configured with it behaves bit-identically to one with
    `overload=None` (the committed-store invariant)."""
    brownout_depth: int = 0       # queue depth that enters BROWNOUT (0=off)
    shed_depth: int = 0           # queue depth that enters SHED (0=off)
    recover_depth: int = 0        # depth at/below which state steps down
    ttft_slo_s: float = 0.0       # TTFT SLO: measurement + brownout trigger
    brownout_max_new: int = 0     # max_new_tokens clamp in BROWNOUT/SHED
    brownout_shed_floor: int = BACKGROUND   # classes >= floor refused in
    #                                         BROWNOUT (BACKGROUND+1 = none)
    shed_floor: int = BATCH       # classes >= floor refused in SHED

    @property
    def enabled(self) -> bool:
        """Armed iff any degradation lever exists. A policy with only
        `ttft_slo_s` set is a pure SLO monitor (violation counting
        without control) — the degradation-OFF experiment arm."""
        return (self.brownout_depth > 0 or self.shed_depth > 0
                or self.brownout_max_new > 0)

    def validate(self) -> "OverloadPolicy":
        if self.brownout_depth < 0 or self.shed_depth < 0 \
                or self.recover_depth < 0:
            raise ValueError("depth thresholds must be >= 0")
        if self.shed_depth > 0 and self.brownout_depth > 0 \
                and self.shed_depth < self.brownout_depth:
            raise ValueError(
                f"shed_depth {self.shed_depth} below brownout_depth "
                f"{self.brownout_depth}: SHED must be the deeper state")
        lo = min(d for d in (self.brownout_depth, self.shed_depth)
                 if d > 0) if self.enabled and (
                     self.brownout_depth > 0 or self.shed_depth > 0) else 0
        if lo and self.recover_depth >= lo:
            raise ValueError(
                f"recover_depth {self.recover_depth} must sit strictly "
                f"below the lowest entry threshold {lo} (hysteresis band)")
        if self.ttft_slo_s < 0:
            raise ValueError("ttft_slo_s must be >= 0")
        if self.brownout_max_new < 0:
            raise ValueError("brownout_max_new must be >= 0")
        return self

    # -- the state machine (pure) ---------------------------------------
    def next_state(self, state: int, depth: int, last_ttft: float) -> int:
        """One transition, evaluated per drained submission. `depth` is
        the queue length BEFORE the submission joins (the same reading
        `max_queue_depth` shedding uses); `last_ttft` is the most recent
        TTFT observed at a prefill (0.0 before any observation)."""
        ttft_hot = self.ttft_slo_s > 0.0 and last_ttft > self.ttft_slo_s
        if self.shed_depth > 0 and depth >= self.shed_depth:
            return SHED
        hot = (self.brownout_depth > 0 and depth >= self.brownout_depth) \
            or ttft_hot
        cool = depth <= self.recover_depth and not ttft_hot
        if state == SHED:
            return BROWNOUT if cool else SHED
        if state == BROWNOUT:
            return NORMAL if cool else BROWNOUT
        return BROWNOUT if hot else NORMAL

    def admits(self, state: int, priority: int) -> bool:
        """Class admission under the current state (the class-blind
        `max_queue_depth` cap is checked separately by the engine)."""
        if state == SHED:
            return priority < self.shed_floor
        if state == BROWNOUT:
            return priority < self.brownout_shed_floor
        return True

    def clamp(self, state: int, max_new_tokens: int) -> int:
        """Brownout token budget: admitted requests decode at most
        `brownout_max_new` tokens while the controller is degraded."""
        if state >= BROWNOUT and self.brownout_max_new > 0:
            return min(max_new_tokens, self.brownout_max_new)
        return max_new_tokens
