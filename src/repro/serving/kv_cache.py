"""Host-side paged KV-cache bookkeeping (free list + block tables).

Page 0 is reserved as the trash page: inactive batch slots scatter their
(masked) writes there so the jitted step functions never branch on
activity. The device-side pools live in the runner's state pytree.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np


class PageManager:
    def __init__(self, num_pages: int, page_size: int, max_batch: int,
                 max_pages_per_seq: int):
        assert num_pages >= 2
        self.num_pages = num_pages
        self.page_size = page_size
        self.max_batch = max_batch
        self.max_pages_per_seq = max_pages_per_seq
        # page 0 reserved (trash)
        self.free: List[int] = list(range(num_pages - 1, 0, -1))
        self.block_tables = np.zeros((max_batch, max_pages_per_seq),
                                     np.int32)
        self.pages_of: List[List[int]] = [[] for _ in range(max_batch)]
        self.free_slots: List[int] = list(range(max_batch - 1, -1, -1))

    # -- capacity queries ---------------------------------------------------
    def pages_for(self, tokens: int) -> int:
        return -(-tokens // self.page_size)

    def can_admit(self, prompt_len: int, max_new: int) -> bool:
        need = self.pages_for(prompt_len + max_new)
        if need > self.max_pages_per_seq:
            return False                    # request can never fit
        return bool(self.free_slots) and len(self.free) >= need

    @property
    def free_pages(self) -> int:
        return len(self.free)

    # -- allocation ---------------------------------------------------------
    def admit(self, prompt_len: int, max_new: int) -> Optional[int]:
        """Reserve a slot + pages for the whole request. None if full."""
        if not self.can_admit(prompt_len, max_new):
            return None
        slot = self.free_slots.pop()
        need = self.pages_for(prompt_len + max_new)
        assert need <= self.max_pages_per_seq, (
            f"request needs {need} pages > max_pages_per_seq "
            f"{self.max_pages_per_seq}")
        # take the last `need` pages in pop() order (one slice, not n pops);
        # guard need==0: `del free[-0:]` would wipe the whole free list
        pages = self.free[:-need - 1:-1] if need else []
        if need:
            del self.free[-need:]
        self.pages_of[slot] = pages
        self.block_tables[slot, :need] = pages
        self.block_tables[slot, need:] = 0
        return slot

    def release(self, slot: int):
        self.free.extend(self.pages_of[slot])
        self.pages_of[slot] = []
        self.block_tables[slot] = 0
        self.free_slots.append(slot)

    def utilization(self) -> float:
        usable = self.num_pages - 1
        return 1.0 - len(self.free) / usable
