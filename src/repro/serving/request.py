"""Request objects and lifecycle states for the serving engine."""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILL = "prefill"     # admitted, prompt partially processed (chunked)
    RUNNING = "running"     # decoding
    DONE = "done"
    FAILED = "failed"       # replica loss etc.; re-queued by the engine


@dataclasses.dataclass
class Request:
    rid: int
    arrival_time: float
    prompt_len: int
    max_new_tokens: int
    prompt: Optional[List[int]] = None       # None -> synthetic random ids
    priority: int = 0                        # overload class (ISSUE 9):
    #                                          0=interactive, 1=batch,
    #                                          2=background (lower = keep)

    # runtime
    state: RequestState = RequestState.QUEUED
    slot: int = -1                            # engine batch slot
    prefill_done: int = 0                     # tokens of prompt processed
    tokens_out: int = 0
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    prev_token_time: Optional[float] = None
    retries: int = 0                          # engine-side failure requeues
    attempts: int = 0                         # client-side re-submissions
    submit_time: Optional[float] = None       # last (re)submission; None ->
    #                                           arrival_time (first attempt)

    @property
    def submitted_at(self) -> float:
        return (self.arrival_time if self.submit_time is None
                else self.submit_time)

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time

    @property
    def e2e(self) -> Optional[float]:
        if self.finish_time is None:
            return None
        return self.finish_time - self.arrival_time

    @property
    def tpot(self) -> Optional[float]:
        """Mean time-per-output-token over the decode phase."""
        if self.finish_time is None or self.first_token_time is None:
            return None
        n = max(self.tokens_out - 1, 1)
        return (self.finish_time - self.first_token_time) / n
