"""Centralized numeric-precision policy for the jit fleet backend.

The numpy fleet (`serving.fleet`) is float64 by construction — numpy has
no other default — and its RunRecords are *bitwise* commitments (the
committed stores). JAX, by contrast, defaults to float32 unless
``jax_enable_x64`` is flipped, and flipping it ad hoc from inside a
simulator module is how dtype drift starts. This module is the one
place that policy lives (ISSUE 7 satellite):

* `enable_x64()` — idempotently turn on ``jax_enable_x64`` and report
  whether 64-bit mode is actually active. Safe to call any number of
  times, safe to call before or after other jax users; the tier-1 suite
  runs the kernel/model tests under the flag to pin that enabling it
  does not perturb them.
* `active_x64()` — query without side effects (False until someone
  enabled it, or when jax is unavailable).
* `jit_tolerance()` — the documented jit-vs-numpy RunRecord agreement
  bound as ``(rtol, atol)``. Under x64 the jit fleet replays the same
  float64 op sequence as the numpy fleet; XLA:CPU may still contract
  mul+add chains into FMAs, so equality is *tolerance*-based (tight),
  not bitwise — the numpy path stays the bitwise oracle. Without x64
  (jax built without 64-bit support) the jit path runs float32 and the
  bound is correspondingly loose; the backend still works, it is just
  no longer a store-regeneration surface.

The numpy path never touches this module's jax config: `FleetStepModel`
and `FleetEngine` are pure numpy, so enabling x64 cannot move a single
bit of the committed stores (`tests/test_fleet_jit.py` pins this).
"""
from __future__ import annotations

from typing import Tuple

# documented jit-vs-numpy RunRecord agreement (rtol, atol); see module
# docstring. The x64 bound absorbs FMA contraction over ~1e5-step clock
# accumulations; the f32 bound is the honest precision of a float32
# event clock and is only ever used when jax lacks 64-bit support.
X64_TOLERANCE = (1e-9, 1e-12)
F32_TOLERANCE = (2e-3, 1e-4)

_STATE = {"enabled": None}


def enable_x64() -> bool:
    """Idempotently enable ``jax_enable_x64``; returns True iff 64-bit
    mode is active afterwards (False when jax is missing or refuses)."""
    if _STATE["enabled"] is None:
        try:
            import jax
            jax.config.update("jax_enable_x64", True)
            _STATE["enabled"] = bool(
                getattr(jax.config, "jax_enable_x64", False))
        except Exception:                          # pragma: no cover
            _STATE["enabled"] = False
    return _STATE["enabled"]


def active_x64() -> bool:
    """True iff `enable_x64` has run and 64-bit mode is active."""
    return bool(_STATE["enabled"])


def jit_tolerance() -> Tuple[float, float]:
    """(rtol, atol) for jit-vs-numpy RunRecord comparisons under the
    currently active precision (call `enable_x64` first)."""
    return X64_TOLERANCE if active_x64() else F32_TOLERANCE
