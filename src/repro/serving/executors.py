"""Execution tiers behind the engine: real JAX steps or a TPU time model.

RealExecutor — owns the device state (pools, seq_lens), runs the jitted
prefill/decode closures, returns wall-clock durations.

SimExecutor — same interface, zero compute: durations come from a
calibrated step-time model (repro.simulate.step_time) so the engine's
scheduler/queueing dynamics play out on a virtual TPU clock. Token values
are irrelevant to cost metering (only counts and timing matter), so it
emits zeros.
"""
from __future__ import annotations

import time
from typing import Optional, Tuple

import numpy as np

try:
    import jax
    import jax.numpy as jnp
except Exception:                                    # pragma: no cover
    jax = None


class RealExecutor:
    """Wall-clock tier: reduced models, real logits, real latencies."""

    def __init__(self, cfg, params, *, num_pages: int, page_size: int,
                 max_batch: int, qcfg=None, use_kernel: bool = False):
        from repro.serving.runner import init_pools, make_step_fns
        self.cfg = cfg
        self.params = params
        self.page_size = page_size
        self.pools = init_pools(cfg, num_pages, page_size, max_batch)
        self.seq_lens = jnp.zeros((max_batch,), jnp.int32)
        self.prefill_fn, self.decode_fn = make_step_fns(
            cfg, page_size, qcfg=qcfg, use_kernel=use_kernel)

    def reset_slot(self, slot: int):
        self.seq_lens = self.seq_lens.at[slot].set(0)

    def prefill(self, tokens: np.ndarray, lens: np.ndarray,
                do_mask: np.ndarray, block_tables: np.ndarray
                ) -> Tuple[np.ndarray, float]:
        t0 = time.perf_counter()
        first, self.pools, self.seq_lens = self.prefill_fn(
            self.params, self.pools, jnp.asarray(block_tables),
            self.seq_lens, jnp.asarray(tokens), jnp.asarray(lens),
            jnp.asarray(do_mask))
        first = np.asarray(jax.block_until_ready(first))
        return first, time.perf_counter() - t0

    def decode(self, tokens: np.ndarray, active: np.ndarray,
               block_tables: np.ndarray) -> Tuple[np.ndarray, float]:
        t0 = time.perf_counter()
        nxt, self.pools, self.seq_lens = self.decode_fn(
            self.params, self.pools, jnp.asarray(block_tables),
            self.seq_lens, jnp.asarray(tokens), jnp.asarray(active))
        nxt = np.asarray(jax.block_until_ready(nxt))
        return nxt, time.perf_counter() - t0


class SimExecutor:
    """Virtual-clock tier: step durations from the TPU step-time model."""

    def __init__(self, cfg, step_time_model, *, page_size: int = 16):
        self.cfg = cfg
        self.model = step_time_model
        self.page_size = page_size
        self._seq_lens: Optional[np.ndarray] = None

    def reset_slot(self, slot: int):
        pass

    def prefill(self, tokens: np.ndarray, lens: np.ndarray,
                do_mask: np.ndarray, block_tables: np.ndarray
                ) -> Tuple[np.ndarray, float]:
        n_tok = int(lens[do_mask].sum())
        dt = self.model.prefill_time(n_tok, int(do_mask.sum()))
        return np.zeros(tokens.shape[0], np.int32), dt

    def decode(self, tokens: np.ndarray, active: np.ndarray,
               block_tables: np.ndarray, context_lens=None
               ) -> Tuple[np.ndarray, float]:
        bs = int(active.sum())
        ctx = (float(np.mean(context_lens[active]))
               if context_lens is not None and bs else 0.0)
        dt = self.model.decode_time(bs, ctx)
        return np.zeros(tokens.shape[0], np.int32), dt
