"""Execution tiers behind the engine: real JAX steps or a TPU time model.

RealExecutor — owns the device state (pools, seq_lens), runs the jitted
prefill/decode closures, returns wall-clock durations. JAX is imported
lazily so sim-only processes (parallel_sweep workers) never pay for it.

SimExecutor — same interface, zero compute: durations come from a
calibrated step-time model (repro.simulate.step_time) so the engine's
scheduler/queueing dynamics play out on a virtual TPU clock. Token values
are irrelevant to cost metering (only counts and timing matter), so it
emits zeros and advertises `needs_tokens = False` (the engine then skips
materialising prompt token matrices).

`decode_multi(tokens, active, block_tables, context_lens, max_steps,
time_budget)` is the fast-forward hook: take up to `max_steps` decode
steps with a frozen batch, stopping after the first step whose cumulative
duration reaches `time_budget` (events are processed at the top of the
engine loop, i.e. *after* the step that crosses them — identical to the
per-token reference loop). SimExecutor answers in O(log k) closed-form
model evaluations; RealExecutor falls back to per-step execution because
wall-clock durations cannot be predicted.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np


class RealExecutor:
    """Wall-clock tier: reduced models, real logits, real latencies."""

    needs_tokens = True

    def __init__(self, cfg, params, *, num_pages: int, page_size: int,
                 max_batch: int, qcfg=None, use_kernel: bool = False):
        import jax
        import jax.numpy as jnp
        from repro.serving.runner import init_pools, make_step_fns
        self._jax, self._jnp = jax, jnp
        self.cfg = cfg
        self.params = params
        self.page_size = page_size
        self.pools = init_pools(cfg, num_pages, page_size, max_batch)
        self.seq_lens = jnp.zeros((max_batch,), jnp.int32)
        self.prefill_fn, self.decode_fn = make_step_fns(
            cfg, page_size, qcfg=qcfg, use_kernel=use_kernel)

    def reset_slot(self, slot: int):
        self.seq_lens = self.seq_lens.at[slot].set(0)

    def prefill(self, tokens: np.ndarray, lens: np.ndarray,
                do_mask: np.ndarray, block_tables: np.ndarray
                ) -> Tuple[np.ndarray, float]:
        import time
        jax, jnp = self._jax, self._jnp
        t0 = time.perf_counter()
        first, self.pools, self.seq_lens = self.prefill_fn(
            self.params, self.pools, jnp.asarray(block_tables),
            self.seq_lens, jnp.asarray(tokens), jnp.asarray(lens),
            jnp.asarray(do_mask))
        first = np.asarray(jax.block_until_ready(first))
        return first, time.perf_counter() - t0

    def decode(self, tokens: np.ndarray, active: np.ndarray,
               block_tables: np.ndarray) -> Tuple[np.ndarray, float]:
        import time
        jax, jnp = self._jax, self._jnp
        t0 = time.perf_counter()
        nxt, self.pools, self.seq_lens = self.decode_fn(
            self.params, self.pools, jnp.asarray(block_tables),
            self.seq_lens, jnp.asarray(tokens), jnp.asarray(active))
        nxt = np.asarray(jax.block_until_ready(nxt))
        return nxt, time.perf_counter() - t0

    def decode_multi(self, tokens: np.ndarray, active: np.ndarray,
                     block_tables: np.ndarray, context_lens: np.ndarray,
                     max_steps: int, time_budget: Optional[float] = None
                     ) -> Tuple[np.ndarray, float, int]:
        """Per-step fallback: real logits cannot be fast-forwarded."""
        cur = np.array(tokens)
        total = 0.0
        steps = 0
        while steps < int(max_steps):
            nxt, dt = self.decode(cur, active, block_tables)
            cur[active] = nxt[active]
            total += dt
            steps += 1
            if time_budget is not None and total >= time_budget:
                break
        return cur, total, max(steps, 1)


class SimExecutor:
    """Virtual-clock tier: step durations from the TPU step-time model."""

    needs_tokens = False

    def __init__(self, cfg, step_time_model, *, page_size: int = 16):
        self.cfg = cfg
        self.model = step_time_model
        self.page_size = page_size
        self._seq_lens: Optional[np.ndarray] = None

    def reset_slot(self, slot: int):
        pass

    def prefill(self, tokens: np.ndarray, lens: np.ndarray,
                do_mask: np.ndarray, block_tables: np.ndarray
                ) -> Tuple[np.ndarray, float]:
        n_tok = int(lens[do_mask].sum())
        dt = self.model.prefill_time(n_tok, int(do_mask.sum()))
        return np.zeros(tokens.shape[0], np.int32), dt

    def decode(self, tokens: np.ndarray, active: np.ndarray,
               block_tables: np.ndarray, context_lens=None
               ) -> Tuple[np.ndarray, float]:
        bs = int(active.sum())
        ctx = (float(np.mean(context_lens[active]))
               if context_lens is not None and bs else 0.0)
        dt = self.model.decode_time(bs, ctx)
        return np.zeros(tokens.shape[0], np.int32), dt

    def decode_multi(self, tokens: np.ndarray, active: np.ndarray,
                     block_tables: np.ndarray, context_lens: np.ndarray,
                     max_steps: int, time_budget: Optional[float] = None
                     ) -> Tuple[np.ndarray, float, int]:
        """Closed-form jump: every context grows by one token per step, so
        the k-step duration is `StepTimeModel.decode_time_multi`; the step
        count crossing `time_budget` is found by bisection on that O(1)
        sum (smallest k with S(k) >= budget, capped at max_steps)."""
        bs = int(active.sum())
        ctx0 = (float(np.mean(context_lens[active]))
                if context_lens is not None and bs else 0.0)
        k = max(int(max_steps), 1)
        m = self.model
        if (time_budget is not None and k > 1 and
                m.decode_time_multi(bs, ctx0, k) >= time_budget):
            lo, hi = 1, k
            while lo < hi:
                mid = (lo + hi) // 2
                if m.decode_time_multi(bs, ctx0, mid) >= time_budget:
                    hi = mid
                else:
                    lo = mid + 1
            k = lo
        dt = m.decode_time_multi(bs, ctx0, k)
        return np.zeros(tokens.shape[0], np.int32), dt, k
