"""Jitted model execution against the paged KV pool (real-execution tier).

Fixed-shape, mask-driven step functions over `max_batch` slots:
  prefill_fn — process padded prompts for newly admitted slots, scatter K/V
               into their pages, emit the first sampled token (TTFT event).
  decode_fn  — one token for every active slot via the paged-attention op.

SSM / xLSTM / hybrid blocks keep per-slot O(1) states in the same state
pytree (they have no KV pages — the reason those archs run long_500k).
Encoder-decoder archs are not served by this engine (documented limitation;
the dry-run covers their serve path).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.paged_attention.ops import paged_attention
from repro.models import attention as attn_lib
from repro.models import model as model_lib
from repro.models import ssm as ssm_lib
from repro.models import xlstm as xlstm_lib
from repro.models.layers import apply_norm
from repro.quant import linear


def init_pools(cfg: ModelConfig, num_pages: int, page_size: int,
               max_batch: int):
    """Device-side state pytree: KV page pools + per-slot SSM states."""
    U = model_lib.unit_size(cfg)
    R = cfg.num_layers // U
    hd = cfg.resolved_head_dim
    pools: List[Dict[str, Any]] = []
    for kind, _ in model_lib.unit_pattern(cfg):
        if kind == "attn":
            shape = (R, num_pages, page_size, cfg.num_kv_heads, hd)
            pools.append({"k": jnp.zeros(shape, jnp.bfloat16),
                          "v": jnp.zeros(shape, jnp.bfloat16)})
        elif kind == "mamba":
            di = cfg.ssm.expand * cfg.d_model
            pools.append({
                "conv": jnp.zeros((R, max_batch, cfg.ssm.d_conv - 1, di),
                                  jnp.bfloat16),
                "h": jnp.zeros((R, max_batch, di, cfg.ssm.d_state),
                               jnp.float32)})
        elif kind == "mlstm":
            di = int(cfg.xlstm.mlstm_proj_factor * cfg.d_model)
            dh = di // cfg.num_heads
            pools.append({
                "C": jnp.zeros((R, max_batch, cfg.num_heads, dh, dh),
                               jnp.float32),
                "n": jnp.zeros((R, max_batch, cfg.num_heads, dh),
                               jnp.float32),
                "m": jnp.full((R, max_batch, cfg.num_heads), -jnp.inf,
                              jnp.float32)})
        elif kind == "slstm":
            d = cfg.d_model
            pools.append({
                "c": jnp.zeros((R, max_batch, d), jnp.float32),
                "n": jnp.ones((R, max_batch, d), jnp.float32),
                "m": jnp.zeros((R, max_batch, d), jnp.float32),
                "h": jnp.zeros((R, max_batch, d), jnp.float32)})
    return pools


def _scatter_kv(pool_k, pool_v, k, v, block_tables, positions, active,
                page_size: int):
    """Scatter per-token K/V into pages.

    k/v: (B, T, Hkv, D); positions: (B, T) absolute token positions;
    active: (B, T) bool — inactive writes land on trash page 0.
    """
    B, T = positions.shape
    page_idx = positions // page_size                      # (B, T)
    offs = positions % page_size
    cols = jnp.clip(page_idx, 0, block_tables.shape[1] - 1)
    pages = jnp.take_along_axis(block_tables, cols, axis=1)  # (B, T)
    pages = jnp.where(active, pages, 0)
    pf, of = pages.reshape(-1), offs.reshape(-1)
    kf = k.reshape((-1,) + k.shape[2:])
    vf = v.reshape((-1,) + v.shape[2:])
    pool_k = pool_k.at[pf, of].set(kf.astype(pool_k.dtype))
    pool_v = pool_v.at[pf, of].set(vf.astype(pool_v.dtype))
    return pool_k, pool_v


def _mask_state(new, old, active):
    """Per-slot state update mask (active: (B,) bool)."""
    def pick(n, o):
        a = active.reshape((-1,) + (1,) * (n.ndim - 1))
        return jnp.where(a, n, o)
    return jax.tree.map(pick, new, old)


def make_step_fns(cfg: ModelConfig, page_size: int, qcfg=None,
                  use_kernel: bool = False):
    """Build (prefill_fn, decode_fn) jitted closures for this config."""
    if cfg.family == "encdec":
        raise NotImplementedError(
            "encoder-decoder serving uses the dry-run path only")
    pattern = model_lib.unit_pattern(cfg)
    hd = cfg.resolved_head_dim

    # -- decode -------------------------------------------------------------
    @jax.jit
    def decode_fn(params, pools, block_tables, seq_lens, tokens, active):
        """tokens: (B,) int32. Returns (next_tokens, pools, seq_lens)."""
        B = tokens.shape[0]
        x = model_lib.embed_tokens(params, cfg, tokens[:, None])
        positions = model_lib._positions(cfg, B, 1, offset=seq_lens)

        def body(x, xs):
            stacked_p, pools_r = xs
            new_pools = []
            for j, (kind, is_moe) in enumerate(pattern):
                p, pool = stacked_p[j], pools_r[j]
                if kind == "attn":
                    h = apply_norm(p["ln1"], x, cfg.norm_kind)
                    q, k, v = attn_lib.qkv(p["attn"], h, cfg.num_heads,
                                           cfg.num_kv_heads, hd, qcfg)
                    q = attn_lib.rotate(cfg.rope_kind, q, positions,
                                        cfg.rope_theta)
                    k = attn_lib.rotate(cfg.rope_kind, k, positions,
                                        cfg.rope_theta)
                    pk, pv = _scatter_kv(
                        pool["k"], pool["v"], k, v, block_tables,
                        seq_lens[:, None], active[:, None], page_size)
                    o = paged_attention(
                        q[:, 0], pk.astype(x.dtype), pv.astype(x.dtype),
                        block_tables, seq_lens + active.astype(jnp.int32),
                        use_kernel=use_kernel)
                    x = x + linear(o.reshape(B, 1, cfg.num_heads * hd),
                                   p["attn"]["wo"], qcfg)
                    new_pools.append({"k": pk, "v": pv})
                else:
                    h = apply_norm(p["ln1"], x, cfg.norm_kind)
                    if kind == "mamba":
                        y, st = ssm_lib.mamba_decode_step(
                            p["mamba"], h, pool, cfg.ssm, qcfg)
                    elif kind == "mlstm":
                        y, st = xlstm_lib.mlstm_seq(
                            p, h, cfg.num_heads, cfg.xlstm, pool, qcfg)
                    else:
                        y, st = xlstm_lib.slstm_seq(p, h, cfg.xlstm, pool,
                                                    qcfg)
                    x = x + y
                    new_pools.append(_mask_state(st, pool, active))
                x, _ = model_lib._apply_ff(p, cfg, x, is_moe, qcfg)
            return x, tuple(new_pools)

        x, new_pools = jax.lax.scan(body, x, (params["blocks"],
                                              tuple(pools)))
        x = apply_norm(params["final_norm"], x, cfg.norm_kind)
        logits = model_lib.unembed(params, cfg, x, qcfg)
        nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
        return nxt, list(new_pools), seq_lens + active.astype(jnp.int32)

    # -- prefill ------------------------------------------------------------
    @functools.partial(jax.jit, static_argnames=())
    def prefill_fn(params, pools, block_tables, seq_lens, tokens, lens,
                   do_prefill):
        """tokens: (B, Lpad) int32; lens: (B,); do_prefill: (B,) bool.

        Processes prompts for flagged slots; returns (first_tokens, pools,
        seq_lens) with seq_lens set to lens for those slots.
        """
        B, Lp = tokens.shape
        x = model_lib.embed_tokens(params, cfg, tokens)
        positions = model_lib._positions(cfg, B, Lp)
        tok_active = (jnp.arange(Lp)[None] < lens[:, None]) & \
            do_prefill[:, None]

        def body(x, xs):
            stacked_p, pools_r = xs
            new_pools = []
            for j, (kind, is_moe) in enumerate(pattern):
                p, pool = stacked_p[j], pools_r[j]
                if kind == "attn":
                    h = apply_norm(p["ln1"], x, cfg.norm_kind)
                    q, k, v = attn_lib.qkv(p["attn"], h, cfg.num_heads,
                                           cfg.num_kv_heads, hd, qcfg)
                    pos2 = model_lib._positions(cfg, B, Lp)
                    q = attn_lib.rotate(cfg.rope_kind, q, pos2,
                                        cfg.rope_theta)
                    k = attn_lib.rotate(cfg.rope_kind, k, pos2,
                                        cfg.rope_theta)
                    o = attn_lib.causal_attention(q, k, v, kv_len=lens)
                    x = x + linear(o.reshape(B, Lp, cfg.num_heads * hd),
                                   p["attn"]["wo"], qcfg)
                    posmat = jnp.broadcast_to(jnp.arange(Lp)[None], (B, Lp))
                    pk, pv = _scatter_kv(pool["k"], pool["v"], k, v,
                                         block_tables, posmat, tok_active,
                                         page_size)
                    new_pools.append({"k": pk, "v": pv})
                else:
                    h = apply_norm(p["ln1"], x, cfg.norm_kind)
                    if kind == "mamba":
                        y, st = ssm_lib.apply_mamba(p["mamba"], h, cfg.ssm,
                                                    qcfg)
                    elif kind == "mlstm":
                        y, st = xlstm_lib.mlstm_seq(
                            p, h, cfg.num_heads, cfg.xlstm, None, qcfg)
                    else:
                        y, st = xlstm_lib.slstm_seq(p, h, cfg.xlstm, None,
                                                    qcfg)
                    x = x + y
                    new_pools.append(_mask_state(st, pool, do_prefill))
                x, _ = model_lib._apply_ff(p, cfg, x, is_moe, qcfg)
            return x, tuple(new_pools)

        x, new_pools = jax.lax.scan(body, x, (params["blocks"],
                                              tuple(pools)))
        x = apply_norm(params["final_norm"], x, cfg.norm_kind)
        # logits at each request's last prompt position
        idx = jnp.clip(lens - 1, 0, Lp - 1)
        x_last = jnp.take_along_axis(
            x, idx[:, None, None].astype(jnp.int32), axis=1)
        logits = model_lib.unembed(params, cfg, x_last, qcfg)
        first = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
        new_seq = jnp.where(do_prefill, lens, seq_lens)
        return first, list(new_pools), new_seq

    return prefill_fn, decode_fn
