"""Autoscaling policies + "cost of a day of traffic" pricing (ISSUE 8).

The paper prices C_eff at a fixed lambda; a real operator faces a 24h
lambda(t) profile and chooses between a *static* footprint sized for the
peak and an *autoscaled* fleet that tracks demand with lag, warmup cost
and scale-down hysteresis. This module simulates that choice on top of
the measured single-replica cost curves:

* `AutoscalePolicy` — target-utilization controller: desired replicas =
  ceil(lam / (target_util * lam_cap)), scale-up billed after
  `scale_up_lag_s` and serving after a further `warmup_s` (warming
  replicas burn money without delivering tokens), scale-down only after
  `scale_down_hold_s` of consecutive below-target demand (hysteresis),
  with an over-provision floor (`min_replicas`).
* `simulate_policy` — window-granular fleet trajectory over a
  piecewise-constant day profile; `static_windows` is the fixed-fleet
  baseline sized by `static_size` (peak over util_sla).
* `price_day` — prices a trajectory with per-replica throughput looked
  up from MEASURED cells: each (window, policy) pair resolves to a
  per-replica offered rate lam/serving, and the day store measures
  exactly those stationary points (the windows of a piecewise profile
  are stationary segments, so the committed `paper_diurnal` cells are
  policy-agnostic single-replica measurements; see
  `plans.paper_diurnal`). Stationary-window approximation: a window
  whose per-replica rate exceeds the deployment's demonstrated capacity
  delivers at most saturation throughput — the excess queues, it is not
  silently served.

`DayScenario` freezes one committed 24h profile + deployments +
policies; the scenario's `rate_ladder` is the single source of truth for
which per-replica rates the day plan must measure, shared by
`experiments.plans` (cell expansion) and `experiments.analyze` (report),
so the ladder and the report can't drift apart.

Deployment capacity literals (`lam_cap`, price) are frozen from the
committed stores' own measurements (theta_max / 256 output tokens per
chat request) — the autoscaler sizes fleets from demonstrated
throughput, never from specs.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.serving.arrivals import RateProfile


def quantize_rate(lam: float) -> float:
    """Per-replica window rates become cell lambdas, and `int(lam*1000)`
    feeds the per-cell seed derivation — quantize to 3 decimals so the
    ladder is exactly representable and seed-stable."""
    return round(float(lam), 3)


# ---------------------------------------------------------------------------
# policies + fleet trajectories
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AutoscalePolicy:
    """Target-utilization scale-up with lag/warmup, hysteretic scale-down."""
    name: str
    target_util: float = 0.7        # fraction of lam_cap a replica may carry
    scale_up_lag_s: float = 0.0     # order placed -> replica billed
    warmup_s: float = 0.0           # billed -> actually serving
    scale_down_hold_s: float = 0.0  # consecutive low-demand time before down
    min_replicas: int = 1           # over-provision floor
    max_replicas: int = 64

    def desired(self, lam: float, lam_cap: float) -> int:
        """Replicas wanted for offered rate `lam` at per-replica capacity
        `lam_cap`, keeping each replica at <= target_util of capacity."""
        if lam <= 0:
            return self.min_replicas
        want = math.ceil(lam / (self.target_util * lam_cap))
        return max(self.min_replicas, min(self.max_replicas, want))


@dataclasses.dataclass(frozen=True)
class FleetWindow:
    """One window of a fleet trajectory: `serving` replicas take traffic,
    `billed` >= `serving` also counts replicas still warming up."""
    index: int
    t0: float
    t1: float
    lam: float          # fleet-wide offered rate over the window (req/s)
    serving: int
    billed: int


def static_size(peak_lam: float, lam_cap: float,
                util_sla: float = 0.95) -> int:
    """Fixed fleet sized for the peak: smallest R with
    peak_lam <= util_sla * R * lam_cap."""
    if lam_cap <= 0:
        raise ValueError(f"lam_cap must be > 0, got {lam_cap}")
    return max(1, math.ceil(peak_lam / (util_sla * lam_cap)))


def static_windows(replicas: int, rates: Sequence[float],
                   window_s: float) -> Tuple[FleetWindow, ...]:
    return tuple(
        FleetWindow(index=w, t0=w * window_s, t1=(w + 1) * window_s,
                    lam=float(r), serving=replicas, billed=replicas)
        for w, r in enumerate(rates))


def simulate_policy(policy: AutoscalePolicy, rates: Sequence[float],
                    window_s: float, lam_cap: float
                    ) -> Tuple[FleetWindow, ...]:
    """Run the controller over a piecewise-constant day, one decision per
    window boundary, observing the PREVIOUS window's rate (reactive — the
    controller has no oracle). Window 0 opens pre-provisioned at the
    first window's desired size. Scale-ups bill after `scale_up_lag_s`
    and serve after a further `warmup_s` (both in whole windows, rounded
    up); scale-downs need `scale_down_hold_s` of consecutive
    below-target demand, then release immediately — cancelling not-yet-
    warm orders first (newest first), live replicas last."""
    if window_s <= 0:
        raise ValueError(f"window_s must be > 0, got {window_s}")
    lag_w = math.ceil(policy.scale_up_lag_s / window_s)
    warm_w = math.ceil(policy.warmup_s / window_s)
    hold_w = max(1, math.ceil(policy.scale_down_hold_s / window_s))
    live = policy.desired(rates[0], lam_cap)
    orders: List[Dict[str, int]] = []   # {"bill_at", "serve_at", "n"}
    below = 0
    out: List[FleetWindow] = []
    for w, lam in enumerate(rates):
        if w > 0:
            want = policy.desired(rates[w - 1], lam_cap)
            committed = live + sum(o["n"] for o in orders)
            if want > committed:
                orders.append({"bill_at": w + lag_w,
                               "serve_at": w + lag_w + warm_w,
                               "n": want - committed})
                below = 0
            elif want < committed:
                below += 1
                if below >= hold_w:
                    shed = committed - want
                    while shed and orders:
                        take = min(shed, orders[-1]["n"])
                        orders[-1]["n"] -= take
                        shed -= take
                        if orders[-1]["n"] == 0:
                            orders.pop()
                    live -= shed
                    below = 0
            else:
                below = 0
        for o in list(orders):
            if o["serve_at"] <= w:
                live += o["n"]
                orders.remove(o)
        warming = sum(o["n"] for o in orders if o["bill_at"] <= w)
        out.append(FleetWindow(index=w, t0=w * window_s,
                               t1=(w + 1) * window_s, lam=float(lam),
                               serving=live, billed=live + warming))
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class SLOAutoscalePolicy:
    """SLO-aware controller (ISSUE 9 tentpole b): scale on the observed
    TTFT p90 instead of utilization.

    The target-util controller needs a capacity model (`lam_cap`) and a
    utilization target; this one needs neither — it watches the latency
    percentile the SLO is written against. Each window boundary it looks
    up the PREVIOUS window's realized per-replica rate in the measured
    TTFT-p90 curve (`ttft_p90_at`, a day-store record or a fitted
    DeploymentCurve): one breach orders `step_up` replicas (lag/warmup
    semantics identical to `AutoscalePolicy`); p90 below
    `headroom_frac * slo` for `scale_down_hold_s` releases one replica.
    Window 0 opens at `min_replicas` — an SLO controller has no rate
    model to pre-size from, which is exactly its difference from the
    util controller, so the cold start is part of the comparison."""
    name: str
    ttft_p90_slo_ms: float
    headroom_frac: float = 0.5      # scale-down band: p90 < frac * slo
    step_up: int = 1                # replicas ordered per breach window
    scale_up_lag_s: float = 0.0
    warmup_s: float = 0.0
    scale_down_hold_s: float = 0.0
    min_replicas: int = 1
    max_replicas: int = 64


def simulate_slo_policy(policy: SLOAutoscalePolicy,
                        rates: Sequence[float], window_s: float,
                        ttft_p90_at) -> Tuple[FleetWindow, ...]:
    """Run the SLO-aware controller over a piecewise-constant day.
    `ttft_p90_at(lam_per_replica)` returns the measured (or fitted)
    single-replica TTFT p90 in ms at that stationary offered rate.
    Same window-granular mechanics as `simulate_policy`: decisions at
    window boundaries on the previous window's observation, scale-ups
    billed after `scale_up_lag_s` and serving after a further
    `warmup_s`, hysteretic scale-down cancelling newest orders first."""
    if window_s <= 0:
        raise ValueError(f"window_s must be > 0, got {window_s}")
    lag_w = math.ceil(policy.scale_up_lag_s / window_s)
    warm_w = math.ceil(policy.warmup_s / window_s)
    hold_w = max(1, math.ceil(policy.scale_down_hold_s / window_s))
    live = policy.min_replicas
    orders: List[Dict[str, int]] = []
    below = 0
    out: List[FleetWindow] = []
    for w, lam in enumerate(rates):
        if w > 0:
            prev = out[-1]
            p90 = (float(ttft_p90_at(quantize_rate(prev.lam
                                                   / prev.serving)))
                   if prev.lam > 0 and prev.serving > 0 else 0.0)
            committed = live + sum(o["n"] for o in orders)
            if p90 > policy.ttft_p90_slo_ms:
                room = policy.max_replicas - committed
                if room > 0:
                    orders.append({"bill_at": w + lag_w,
                                   "serve_at": w + lag_w + warm_w,
                                   "n": min(policy.step_up, room)})
                below = 0
            elif (p90 < policy.headroom_frac * policy.ttft_p90_slo_ms
                  and committed > policy.min_replicas):
                below += 1
                if below >= hold_w:
                    if orders:
                        orders[-1]["n"] -= 1
                        if orders[-1]["n"] == 0:
                            orders.pop()
                    else:
                        live -= 1
                    below = 0
            else:
                below = 0
        for o in list(orders):
            if o["serve_at"] <= w:
                live += o["n"]
                orders.remove(o)
        warming = sum(o["n"] for o in orders if o["bill_at"] <= w)
        out.append(FleetWindow(index=w, t0=w * window_s,
                               t1=(w + 1) * window_s, lam=float(lam),
                               serving=live, billed=live + warming))
    return tuple(out)


def slo_violation_minutes(windows: Sequence[FleetWindow], ttft_p90_at,
                          slo_ms: float) -> float:
    """Minutes of the day a trajectory spends with the realized
    per-replica rate's TTFT p90 over the SLO (idle windows comply)."""
    total = 0.0
    for fw in windows:
        if fw.lam <= 0 or fw.serving <= 0:
            continue
        p90 = float(ttft_p90_at(quantize_rate(fw.lam / fw.serving)))
        if p90 > slo_ms:
            total += (fw.t1 - fw.t0) / 60.0
    return total


def compare_day_policies(*, util_policy: AutoscalePolicy,
                         slo_policy: SLOAutoscalePolicy,
                         rates: Sequence[float], window_s: float,
                         lam_cap: float, price_per_hr: float,
                         tps_at, ttft_p90_at) -> Dict:
    """Head-to-head (ISSUE 9 tentpole b): the PR-8 target-util
    controller vs the SLO-aware controller on the same day, priced from
    the same measured curves. Reports each policy's day cost AND its
    SLO-violation minutes — the comparison is two-dimensional: the util
    controller can be cheaper while blowing the latency budget, which
    is precisely what scaling on the wrong signal looks like."""
    slo_ms = slo_policy.ttft_p90_slo_ms
    traj_u = simulate_policy(util_policy, rates, window_s, lam_cap)
    traj_s = simulate_slo_policy(slo_policy, rates, window_s, ttft_p90_at)
    rows = {}
    for name, traj in ((util_policy.name, traj_u),
                       (slo_policy.name, traj_s)):
        priced = price_day(traj, price_per_hr=price_per_hr,
                           tps_at=tps_at, lam_cap=lam_cap)
        rows[name] = {
            "policy": name,
            "slo_violation_minutes": slo_violation_minutes(
                traj, ttft_p90_at, slo_ms), **priced}
    u, s = rows[util_policy.name], rows[slo_policy.name]
    return {
        "util": u, "slo": s, "ttft_p90_slo_ms": slo_ms,
        "cheaper": (util_policy.name
                    if u["day_c_eff"] <= s["day_c_eff"]
                    else slo_policy.name),
        "tighter_slo": (slo_policy.name
                        if s["slo_violation_minutes"]
                        <= u["slo_violation_minutes"]
                        else util_policy.name),
        "slo_minutes_saved": (u["slo_violation_minutes"]
                              - s["slo_violation_minutes"]),
    }


# ---------------------------------------------------------------------------
# pricing a trajectory against measured per-replica throughput
# ---------------------------------------------------------------------------

def price_day(windows: Sequence[FleetWindow], *, price_per_hr: float,
              tps_at, lam_cap: float = 0.0,
              mtok_per_req: float = 256e-6) -> Dict:
    """Price one fleet trajectory. `tps_at(lam_per_replica)` returns the
    measured single-replica output-token throughput at that stationary
    offered rate (day-store record, or a fitted DeploymentCurve);
    `price_per_hr` is per replica.

    Per window: cost = billed replicas x price x dt; delivered tokens =
    serving x tps_at(quantized lam/serving) x dt; window C_eff =
    cost * 1e6 / tokens, inf on an idle window (billed, zero goodput —
    flagged, never hidden). Day totals aggregate cost and tokens, so
    `day_c_eff` is the operator's actual $/M-token for the day."""
    rows: List[Dict] = []
    total_cost = total_tok = 0.0
    for fw in windows:
        dt = fw.t1 - fw.t0
        cost = fw.billed * price_per_hr * dt / 3600.0
        if fw.lam > 0 and fw.serving > 0:
            lam_per = quantize_rate(fw.lam / fw.serving)
            tps = float(tps_at(lam_per))
            if not math.isfinite(tps) or tps < 0:
                raise ValueError(
                    f"tps_at({lam_per}) = {tps}: the day ladder must "
                    f"measure every per-replica rate the trajectories "
                    f"visit")
            tokens = fw.serving * tps * dt
        else:
            lam_per, tokens = 0.0, 0.0
        wc = cost * 1e6 / tokens if tokens > 0 else math.inf
        saturated = bool(lam_cap > 0 and lam_per > lam_cap)
        rows.append({
            "window": fw.index, "t0": fw.t0, "t1": fw.t1, "lam": fw.lam,
            "serving": fw.serving, "billed": fw.billed,
            "lam_per_replica": lam_per, "cost_usd": cost,
            "tokens": tokens, "c_eff": wc,
            "idle": tokens <= 0, "saturated": saturated,
        })
        total_cost += cost
        total_tok += tokens
    busy = [r["c_eff"] for r in rows if math.isfinite(r["c_eff"])]
    peak_row = max(rows, key=lambda r: r["lam"])
    best = min(busy) if busy else math.inf
    day_c = total_cost * 1e6 / total_tok if total_tok > 0 else math.inf
    return {
        "windows": rows,
        "daily_cost_usd": total_cost,
        "daily_tokens": total_tok,
        "day_c_eff": day_c,
        "replica_hours": sum(r["billed"] * (r["t1"] - r["t0"])
                             for r in rows) / 3600.0,
        "best_window_c_eff": best,
        "worst_busy_window_c_eff": max(busy) if busy else math.inf,
        "peak_window_c_eff": peak_row["c_eff"],
        # the paper-style penalty, time-resolved: what the peak-rate hour
        # costs per token relative to the day's best hour
        "peak_penalty": (peak_row["c_eff"] / best
                         if busy and math.isfinite(peak_row["c_eff"])
                         else None),
        "idle_windows": sum(1 for r in rows if r["idle"]),
        "saturated_windows": sum(1 for r in rows if r["saturated"]),
    }


# ---------------------------------------------------------------------------
# committed day scenarios
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Deployment:
    """One priced footprint. `lam_cap` and `price_per_hr` are frozen from
    the committed stores (theta_max / 256 tok-per-chat-request)."""
    name: str
    model: str
    hw: str
    quant: str
    n_chips: int
    price_per_hr: float
    lam_cap: float


@dataclasses.dataclass(frozen=True)
class DayScenario:
    """A committed 24h profile x deployments x policies bundle — the one
    definition `plans` expands cells from and `analyze` reports against."""
    name: str
    window_s: float
    window_rates: Tuple[float, ...]
    deployments: Tuple[Deployment, ...]
    policies: Tuple[AutoscalePolicy, ...]
    util_sla: float = 0.95

    @property
    def peak_lam(self) -> float:
        return max(self.window_rates)

    @property
    def day_s(self) -> float:
        return self.window_s * len(self.window_rates)

    def profile(self) -> RateProfile:
        """The scenario's lambda(t) as a piecewise RateProfile (for
        engine-facing streams: the meter walkthrough, smoke cells)."""
        return RateProfile.piecewise(
            [(self.window_s, r) for r in self.window_rates])

    def static_replicas(self, dep: Deployment) -> int:
        return static_size(self.peak_lam, dep.lam_cap, self.util_sla)

    def trajectories(self, dep: Deployment
                     ) -> Dict[str, Tuple[FleetWindow, ...]]:
        """'static' + one trajectory per policy, in declaration order."""
        out = {"static": static_windows(self.static_replicas(dep),
                                        self.window_rates, self.window_s)}
        for pol in self.policies:
            out[pol.name] = simulate_policy(pol, self.window_rates,
                                            self.window_s, dep.lam_cap)
        return out

    def rate_ladder(self, dep: Deployment) -> Tuple[float, ...]:
        """Every distinct quantized per-replica rate any trajectory
        visits — exactly the stationary points the day store must
        measure for this deployment."""
        rates = set()
        for traj in self.trajectories(dep).values():
            for fw in traj:
                if fw.lam > 0 and fw.serving > 0:
                    rates.add(quantize_rate(fw.lam / fw.serving))
        return tuple(sorted(rates))


# The committed 24h profile: a scaled day (1 window = 1 "hour" = 180 s of
# model clock), diurnal double-shoulder shape with a dead-of-night zero
# window (w4) — the idle regime that exposed the meter/arrivals bug
# class. Peak 34 req/s is chosen against the two deployments' measured
# capacities so the static-vs-autoscaled verdict FLIPS between them:
# the small-capacity footprint (llama31-8b @ v5e x2, ~11.8 req/s per
# replica) needs 4 static replicas and autoscaling harvests the trough;
# the big-capacity footprint (qwen3-30b-a3b @ v5e x8, ~36 req/s) covers
# the whole day with 1 static replica, so any autoscaler headroom is
# pure premium.
PAPER_DAY = DayScenario(
    name="paper_day",
    window_s=180.0,
    window_rates=(5.0, 3.0, 2.0, 1.0, 0.0, 1.0, 3.0, 7.0, 14.0, 22.0,
                  28.0, 32.0, 34.0, 33.0, 30.0, 26.0, 22.0, 20.0, 22.0,
                  25.0, 20.0, 14.0, 10.0, 7.0),
    deployments=(
        # theta_max 3009.1 tok/s -> 11.754 req/s; $1.20/chip-hr x2
        Deployment(name="llama31-8b@tpu-v5e x2", model="llama31-8b",
                   hw="tpu-v5e", quant="bf16", n_chips=2,
                   price_per_hr=2.4, lam_cap=11.754),
        # theta_max 9208.0 tok/s -> 35.969 req/s; $1.20/chip-hr x8
        Deployment(name="qwen3-30b-a3b@tpu-v5e x8", model="qwen3-30b-a3b",
                   hw="tpu-v5e", quant="bf16", n_chips=8,
                   price_per_hr=9.6, lam_cap=35.969),
    ),
    policies=(
        AutoscalePolicy(name="reactive", target_util=0.65,
                        scale_up_lag_s=180.0, warmup_s=180.0,
                        scale_down_hold_s=360.0, min_replicas=1,
                        max_replicas=8),
        AutoscalePolicy(name="cautious", target_util=0.5,
                        scale_up_lag_s=180.0, warmup_s=360.0,
                        scale_down_hold_s=1080.0, min_replicas=2,
                        max_replicas=8),
    ),
)

# CI-smoke day: 6 windows x 30 s with a zero window, one small footprint,
# one snappy policy — cheap enough to expand + run + analyze in CI.
MINI_DAY = DayScenario(
    name="mini_day",
    window_s=30.0,
    window_rates=(2.0, 5.0, 0.0, 8.0, 4.0, 1.0),
    deployments=(
        Deployment(name="llama31-8b@tpu-v5e x1", model="llama31-8b",
                   hw="tpu-v5e", quant="bf16", n_chips=1,
                   price_per_hr=1.2, lam_cap=6.0),
    ),
    policies=(
        AutoscalePolicy(name="reactive", target_util=0.6,
                        scale_up_lag_s=30.0, warmup_s=30.0,
                        scale_down_hold_s=60.0, min_replicas=1,
                        max_replicas=4),
    ),
)

DAY_SCENARIOS: Dict[str, DayScenario] = {
    "paper_day": PAPER_DAY,
    "mini_day": MINI_DAY,
}


# ---------------------------------------------------------------------------
# live-meter walkthrough (engine-facing lambda(t))
# ---------------------------------------------------------------------------

def meter_day_report(eng, *, price_per_hr: float, profile: RateProfile,
                     n_requests: int, seed: int = 0, window_s: float = 60.0,
                     io_shape: str = "chat", scale: float = 1.0,
                     max_horizon_s: float = 48 * 3600.0) -> Dict:
    """Drive ONE engine through a lambda(t) stream while the CostMeter
    ticks each half-window — the live counterpart of `price_day`. Idle
    troughs produce real zero-token meter windows, exercising the
    idle-window semantics end to end (CI smoke + example)."""
    from repro.core.meter import CostMeter
    from repro.serving.arrivals import ArrivalSpec, synth_requests

    spec = ArrivalSpec(lam=quantize_rate(max(profile.mean_rate(), 0.001)),
                       n_requests=n_requests, io_shape=io_shape, seed=seed,
                       scale=scale, profile=profile)
    reqs = synth_requests(spec)
    meter = CostMeter(price_per_hr, scrape=lambda: eng.metrics.render(),
                      minute_s=window_s)
    meter.tick()
    horizon = 0.0
    while any(r.finish_time is None for r in reqs):
        horizon += window_s / 2.0
        eng.run(reqs, horizon=horizon)
        meter.tick()
        if horizon > max_horizon_s:
            break
    summ = meter.summary()
    return {
        "summary": summ,
        "window_costs": meter.minute_costs(),
        "completed": sum(1 for r in reqs if r.finish_time is not None),
        "requests": len(reqs),
    }
