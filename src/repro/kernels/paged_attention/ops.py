"""Dispatching wrapper for paged attention (kernel on TPU, ref elsewhere)."""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels.paged_attention.kernel import paged_attention_kernel
from repro.kernels.paged_attention.ref import paged_attention_ref


def paged_attention(q, k_pool, v_pool, block_tables, seq_lens, *,
                    use_kernel: Optional[bool] = None,
                    interpret: Optional[bool] = None):
    """q: (B, Hq, D); pools: (P, page, Hkv, D); block_tables: (B, max_pages)
    int32 page ids; seq_lens: (B,) int32. Returns (B, Hq, D)."""
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if not use_kernel:
        return paged_attention_ref(q, k_pool, v_pool, block_tables, seq_lens)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return paged_attention_kernel(q, k_pool, v_pool, block_tables, seq_lens,
                                  interpret=interpret)
