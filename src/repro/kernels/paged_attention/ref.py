"""Pure-jnp oracle for paged attention: gather pages, then dense decode."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gather_kv(pool, block_tables):
    """pool: (P, page, Hkv, D); block_tables: (B, max_pages) ->
    contiguous (B, max_pages*page, Hkv, D)."""
    gathered = pool[block_tables]            # (B, max_pages, page, Hkv, D)
    B, n, pg, H, D = gathered.shape
    return gathered.reshape(B, n * pg, H, D)


def paged_attention_ref(q, k_pool, v_pool, block_tables, seq_lens):
    """q: (B, Hq, D); pools: (P, page, Hkv, D); block_tables: (B, max_pages)
    int32; seq_lens: (B,) int32 valid context lengths.

    Returns (B, Hq, D).
    """
    B, Hq, D = q.shape
    k = gather_kv(k_pool, block_tables)      # (B, S, Hkv, D)
    v = gather_kv(v_pool, block_tables)
    Hkv = k.shape[2]
    G = Hq // Hkv
    S = k.shape[1]
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k).astype(jnp.float32)
    s = s / jnp.sqrt(jnp.float32(D))
    mask = jnp.arange(S)[None] < seq_lens[:, None]          # (B, S)
    s = jnp.where(mask[:, None, None], s, -1e30)            # finite: matches
    p = jax.nn.softmax(s, axis=-1)                          # kernel at len=0
    o = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v.dtype), v)
    return o.reshape(B, Hq, D).astype(q.dtype)
