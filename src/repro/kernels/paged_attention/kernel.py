"""Pallas TPU paged-attention decode kernel.

The vLLM PagedAttention idea adapted to TPU (DESIGN §3): there is no
pointer-chasing on TPU, so the page table becomes a *scalar-prefetched*
int32 tensor that drives the BlockSpec index_map — each grid step DMAs one
KV page from the HBM pool into VMEM based on block_tables[b, p]. Flash-
decoding style running max/denominator accumulate across pages in VMEM
scratch; invalid tail pages are skipped with @pl.when.

Grid: (B, Hkv, max_pages), pages innermost/sequential.
  q:      (B, Hq, D)        -> block (1, G, D) for the grid's kv head
  k_pool: (P, page, Hkv, D) -> block (1, page, 1, D) at page block_tables[b,p]
  out:    (B, Hq, D)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

NEG_INF = -1e30


def _pa_kernel(bt_ref, sl_ref, q_ref, k_ref, v_ref, o_ref,
               m_sc, l_sc, acc_sc, *, page: int, num_pages: int,
               sm_scale: float):
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    seq_len = sl_ref[b]

    @pl.when(p * page < seq_len)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                   # (G, D)
        k = k_ref[0, :, 0].astype(jnp.float32)             # (page, D)
        v = v_ref[0, :, 0].astype(jnp.float32)             # (page, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale  # (G, page)
        pos = p * page + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(pos < seq_len, s, NEG_INF)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        pr = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_sc[...] = l_sc[...] * alpha + jnp.sum(pr, axis=-1, keepdims=True)
        acc_sc[...] = acc_sc[...] * alpha + jax.lax.dot_general(
            pr, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_sc[...] = m_new

    @pl.when(p == num_pages - 1)
    def _finish():
        o_ref[0] = (acc_sc[...] / jnp.maximum(l_sc[...], 1e-30)
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention_kernel(q, k_pool, v_pool, block_tables, seq_lens, *,
                           interpret: bool = False):
    """q: (B, Hq, D); pools: (P, page, Hkv, D); block_tables: (B, max_pages);
    seq_lens: (B,). Returns (B, Hq, D)."""
    B, Hq, D = q.shape
    P, page, Hkv, _ = k_pool.shape
    max_pages = block_tables.shape[1]
    G = Hq // Hkv
    sm_scale = 1.0 / (D ** 0.5)

    kernel = functools.partial(_pa_kernel, page=page, num_pages=max_pages,
                               sm_scale=sm_scale)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, max_pages),
        in_specs=[
            pl.BlockSpec((1, G, D),
                         lambda b, h, p, bt, sl: (b, h, 0)),
            pl.BlockSpec((1, page, 1, D),
                         lambda b, h, p, bt, sl: (bt[b, p], 0, h, 0)),
            pl.BlockSpec((1, page, 1, D),
                         lambda b, h, p, bt, sl: (bt[b, p], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, D), lambda b, h, p, bt, sl: (b, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    qg = q.reshape(B, Hkv, G, D).reshape(B, Hkv * G, D)  # group-major heads
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hq, D), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(block_tables, seq_lens, qg, k_pool, v_pool)
    return out
