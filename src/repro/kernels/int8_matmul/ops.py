"""Dispatching wrapper for the int8 matmul (kernel on TPU, ref elsewhere)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.int8_matmul.kernel import int8_matmul_kernel
from repro.kernels.int8_matmul.ref import int8_matmul_ref


def int8_matmul(x_q, w_q, x_scale, w_scale, *,
                use_kernel: Optional[bool] = None,
                interpret: Optional[bool] = None):
    """Blocked quantized matmul; see kernel.py for shapes."""
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if not use_kernel:
        return int8_matmul_ref(x_q, w_q, x_scale, w_scale)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    xs = jnp.reshape(x_scale, (1,)).astype(jnp.float32)
    ws = jnp.reshape(w_scale, (1, -1)).astype(jnp.float32)
    return int8_matmul_kernel(x_q, w_q, xs, ws, interpret=interpret)
