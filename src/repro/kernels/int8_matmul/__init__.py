from repro.kernels.int8_matmul.ops import int8_matmul  # noqa: F401
