"""Pallas TPU blocked int8 x int8 -> int32 matmul with fused dequant.

The Q-axis hot path (DESIGN §3): int8 is the natively-accelerated low-
precision MXU path on every TPU generation we model, so the framework's
int8 serving mode runs its projections through this kernel. Grid
(M/bm, N/bn, K/bk), K innermost/sequential, int32 accumulator in VMEM
scratch, dequantized once on the final K step (per-output-channel weight
scale x per-tensor activation scale) — the dequant never round-trips
through HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params


def _mm_kernel(x_ref, w_ref, xs_ref, ws_ref, o_ref, acc_sc, *, num_kb: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_sc[...] = jnp.zeros_like(acc_sc)

    acc_sc[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(ki == num_kb - 1)
    def _finish():
        scale = xs_ref[0] * ws_ref[0]                    # (bn,) fp32
        o_ref[...] = (acc_sc[...].astype(jnp.float32) *
                      scale[None, :]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def int8_matmul_kernel(x_q, w_q, x_scale, w_scale, *, block_m: int = 256,
                       block_n: int = 256, block_k: int = 256,
                       interpret: bool = False):
    """x_q: (M, K) int8; w_q: (K, N) int8; x_scale: (1,) fp32;
    w_scale: (1, N) fp32. Returns (M, N) fp32."""
    M, K = x_q.shape
    N = w_q.shape[1]
    block_m, block_n, block_k = (min(block_m, M), min(block_n, N),
                                 min(block_k, K))
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0
    num_kb = K // block_k

    kernel = functools.partial(_mm_kernel, num_kb=num_kb)
    return pl.pallas_call(
        kernel,
        grid=(M // block_m, N // block_n, num_kb),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((block_k, block_n), lambda mi, ni, ki: (ki, ni)),
            pl.BlockSpec((1,), lambda mi, ni, ki: (0,)),
            pl.BlockSpec((1, block_n), lambda mi, ni, ki: (0, ni)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x_q, w_q, x_scale, w_scale)
