"""Pure-jnp oracle for the blocked int8 matmul."""
from __future__ import annotations

import jax.numpy as jnp


def int8_matmul_ref(x_q, w_q, x_scale, w_scale):
    """x_q: (M, K) int8; w_q: (K, N) int8; x_scale: scalar fp32;
    w_scale: (1, N) fp32 per-output-channel. Returns (M, N) fp32."""
    acc = jnp.matmul(x_q.astype(jnp.int32), w_q.astype(jnp.int32))
    return acc.astype(jnp.float32) * (x_scale * w_scale)
