"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel ships as kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
dispatch wrapper with CPU fallback) and ref.py (pure-jnp oracle used by the
allclose test sweeps; interpret=True executes the kernel body on CPU).

  flash_attention — train/prefill causal GQA attention
  paged_attention — decode against the paged KV pool (vLLM -> TPU adaptation)
  int8_matmul     — natively-accelerated Q-axis matmul with fused dequant
  ssm_scan        — chunked Mamba selective scan with VMEM-resident state
"""
from repro.kernels.flash_attention import flash_attention  # noqa: F401
from repro.kernels.int8_matmul import int8_matmul  # noqa: F401
from repro.kernels.paged_attention import paged_attention  # noqa: F401
from repro.kernels.ssm_scan import ssm_scan  # noqa: F401
