"""Pallas-TPU API compatibility shims.

`pltpu.TPUCompilerParams` was renamed `pltpu.CompilerParams` across JAX
releases; the container may carry either side of the rename. Every kernel
routes its compiler params through `tpu_compiler_params` so the kernels
compile (and run under interpret=True in CI) on both.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")


def tpu_compiler_params(**kwargs):
    """pltpu.CompilerParams(...) under whichever name this JAX exports."""
    return _PARAMS_CLS(**kwargs)
