"""Pure-jnp oracle for the chunked selective scan (Mamba recurrence)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssm_scan_ref(u, dt, Bm, Cm, A, D, h0=None):
    """u/dt: (B, S, di) fp32; Bm/Cm: (B, S, N) fp32; A: (di, N); D: (di,).

    h_t = exp(dt_t * A) * h_{t-1} + (dt_t * u_t) B_t ;  y_t = h_t . C_t + D u_t
    Returns (y (B, S, di) fp32, h_final (B, di, N) fp32).
    """
    B, S, di = u.shape
    h = jnp.zeros((B, di, A.shape[1]), jnp.float32) if h0 is None else h0

    def step(h, xs):
        u_t, dt_t, B_t, C_t = xs
        dA = jnp.exp(dt_t[..., None] * A)
        h = dA * h + (dt_t * u_t)[..., None] * B_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, C_t) + D * u_t
        return h, y

    xs = tuple(jnp.moveaxis(a.astype(jnp.float32), 1, 0)
               for a in (u, dt, Bm, Cm))
    h, ys = jax.lax.scan(step, h, xs)
    return jnp.moveaxis(ys, 0, 1), h
