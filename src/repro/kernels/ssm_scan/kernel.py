"""Pallas TPU chunked selective scan (Mamba recurrence).

TPU adaptation of the CUDA selective-scan (DESIGN §3): instead of a warp-
level parallel prefix, the sequence is processed in VMEM-resident chunks
with the (di-blocked) SSM state carried in VMEM scratch across chunk
iterations — the grid's chunk axis is innermost/sequential, so for a fixed
(batch, di-block) the state never leaves VMEM. The channel axis is blocked
to bound the VMEM working set; N (d_state) stays whole (16-64).

Grid: (B, di/block_di, S/chunk) — chunk innermost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params


def _scan_kernel(u_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, h0_ref,
                 y_ref, hout_ref, h_sc, *, chunk: int, num_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_sc[...] = h0_ref[0]

    A = a_ref[...]                                      # (bdi, N)
    Dv = d_ref[...]                                     # (bdi,)

    def step(t, h):
        u_t = u_ref[0, t, :]                            # (bdi,)
        dt_t = dt_ref[0, t, :]
        B_t = b_ref[0, t, :]                            # (N,)
        C_t = c_ref[0, t, :]
        dA = jnp.exp(dt_t[:, None] * A)                 # (bdi, N)
        h = dA * h + (dt_t * u_t)[:, None] * B_t[None, :]
        y_t = jnp.sum(h * C_t[None, :], axis=1) + Dv * u_t
        y_ref[0, t, :] = y_t.astype(y_ref.dtype)
        return h

    h_sc[...] = jax.lax.fori_loop(0, chunk, step, h_sc[...])

    @pl.when(ci == num_chunks - 1)
    def _finish():
        hout_ref[0] = h_sc[...]


@functools.partial(jax.jit, static_argnames=("chunk", "block_di",
                                             "interpret"))
def ssm_scan_kernel(u, dt, Bm, Cm, A, D, h0, *, chunk: int = 256,
                    block_di: int = 512, interpret: bool = False):
    """u/dt: (B, S, di) fp32; Bm/Cm: (B, S, N) fp32; A: (di, N) fp32;
    D: (di,) fp32; h0: (B, di, N) fp32.
    Returns (y (B, S, di) fp32, h_final (B, di, N) fp32)."""
    B, S, di = u.shape
    N = A.shape[1]
    chunk = min(chunk, S)
    block_di = min(block_di, di)
    assert S % chunk == 0 and di % block_di == 0
    num_chunks = S // chunk

    kernel = functools.partial(_scan_kernel, chunk=chunk,
                               num_chunks=num_chunks)
    y, h = pl.pallas_call(
        kernel,
        grid=(B, di // block_di, num_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, block_di), lambda b, dk, ci: (b, ci, dk)),
            pl.BlockSpec((1, chunk, block_di), lambda b, dk, ci: (b, ci, dk)),
            pl.BlockSpec((1, chunk, N), lambda b, dk, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, dk, ci: (b, ci, 0)),
            pl.BlockSpec((block_di, N), lambda b, dk, ci: (dk, 0)),
            pl.BlockSpec((block_di,), lambda b, dk, ci: (dk,)),
            pl.BlockSpec((1, block_di, N), lambda b, dk, ci: (b, dk, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_di), lambda b, dk, ci: (b, ci, dk)),
            pl.BlockSpec((1, block_di, N), lambda b, dk, ci: (b, dk, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, di), jnp.float32),
            jax.ShapeDtypeStruct((B, di, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_di, N), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(u, dt, Bm, Cm, A, D, h0)
    return y, h
