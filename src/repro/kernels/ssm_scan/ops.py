"""Dispatching wrapper for the chunked selective scan."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.ssm_scan.kernel import ssm_scan_kernel
from repro.kernels.ssm_scan.ref import ssm_scan_ref


def ssm_scan(u, dt, Bm, Cm, A, D, h0=None, *,
             use_kernel: Optional[bool] = None,
             interpret: Optional[bool] = None,
             chunk: int = 256, block_di: int = 512):
    """Selective-scan dispatch; shapes per ref.py. Returns (y, h_final)."""
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if h0 is None:
        h0 = jnp.zeros((u.shape[0], u.shape[2], A.shape[1]), jnp.float32)
    if not use_kernel:
        return ssm_scan_ref(u, dt, Bm, Cm, A, D, h0)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    f32 = lambda a: a.astype(jnp.float32)
    return ssm_scan_kernel(f32(u), f32(dt), f32(Bm), f32(Cm), f32(A), f32(D),
                           f32(h0), chunk=chunk, block_di=block_di,
                           interpret=interpret)
