from repro.kernels.ssm_scan.ops import ssm_scan  # noqa: F401
