"""Pallas TPU flash-attention (forward), GQA-aware, causal or full.

Tiling: grid (B, Hq, Sq/block_q, Sk/block_k) with the KV axis innermost and
sequential; running max / denominator / output accumulator live in VMEM
scratch and persist across KV iterations (re-initialized at kv_idx == 0).
Block shapes are MXU-aligned (multiples of 128 on the matmul dims wherever
the problem size allows). Causal blocks entirely above the diagonal are
skipped with @pl.when — the standard TPU FA schedule.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
               sm_scale: float, causal: bool, block_q: int, block_k: int,
               num_kb: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)            # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale   # (bq, bk)
        if causal:
            rows = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            cols = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(cols <= rows, s, NEG_INF)
        m_prev = m_sc[...]                              # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_sc[...] = l_sc[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_sc[...] = acc_sc[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_sc[...] = m_new

    if causal:
        # skip KV blocks entirely above the causal diagonal
        @pl.when(ki * block_k <= (qi + 1) * block_q - 1)
        def _():
            _compute()
    else:
        _compute()

    @pl.when(ki == num_kb - 1)
    def _finish():
        l = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0, 0] = (acc_sc[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention_kernel(q, k, v, *, causal: bool = True,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D). Returns (B, Hq, Sq, D)."""
    B, Hq, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    G = Hq // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0
    num_qb, num_kb = Sq // block_q, Sk // block_k
    sm_scale = 1.0 / (D ** 0.5)

    kernel = functools.partial(
        _fa_kernel, sm_scale=sm_scale, causal=causal,
        block_q=block_q, block_k=block_k, num_kb=num_kb)

    return pl.pallas_call(
        kernel,
        grid=(B, Hq, num_qb, num_kb),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki, G=G: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki, G=G: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),    # running max
            pltpu.VMEM((block_q, 1), jnp.float32),    # denominator
            pltpu.VMEM((block_q, D), jnp.float32),    # output accumulator
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
