"""Pure-jnp oracle for the flash-attention kernel (fp32 softmax)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True) -> jnp.ndarray:
    """q: (B, Sq, Hq, D); k, v: (B, Sk, Hkv, D) with Hq % Hkv == 0.

    Returns (B, Sq, Hq, D) in q.dtype. Matches the GQA semantics of the
    Pallas kernel: q head h attends to kv head h // (Hq // Hkv).
    """
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32)
    s = s / jnp.sqrt(jnp.float32(D))
    if causal:
        mask = jnp.arange(k.shape[1])[None, :] <= jnp.arange(Sq)[:, None]
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
    return o.reshape(B, Sq, Hq, D).astype(q.dtype)
