"""Dispatching wrapper: Pallas kernel on TPU, jnp reference elsewhere.

Public layout matches the model zoo: (B, S, H, D). The kernel works in
(B, H, S, D); the wrapper transposes at the boundary (free on TPU — layout
assignment folds it into the surrounding ops).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import flash_attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal: bool = True,
                    use_kernel: Optional[bool] = None,
                    interpret: Optional[bool] = None,
                    block_q: int = 128, block_k: int = 128):
    """q: (B, Sq, Hq, D); k, v: (B, Sk, Hkv, D) -> (B, Sq, Hq, D)."""
    if use_kernel is None:
        use_kernel = _on_tpu()
    if not use_kernel:
        return flash_attention_ref(q, k, v, causal=causal)
    if interpret is None:
        interpret = not _on_tpu()
    Hq, G = q.shape[2], q.shape[2] // k.shape[2]
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    o = flash_attention_kernel(qt, kt, vt, causal=causal,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)
    return o.transpose(0, 2, 1, 3)
