"""Distribution substrate: sharding rules, collectives, pipeline parallelism."""
from repro.parallel.sharding import (  # noqa: F401
    DEFAULT_RULES,
    ShardingRules,
    constrain,
    current_mesh,
    logical_spec,
    param_spec_tree,
    shardctx,
    zero1_spec,
)
from repro.parallel.collectives import combine_partial_softmax  # noqa: F401
