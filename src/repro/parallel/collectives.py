"""Collective helpers used inside shard_map regions.

The flash-decoding combine implements the numerically-safe merge of
partial-softmax attention results computed on sequence shards of a KV cache:
each shard returns (numerator, denominator, running_max); the merge rescales
by exp(m_local - m_global) and psums. Used by the long-context decode path
and by the collective hillclimb on decode cells.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def combine_partial_softmax(num, den, m, axis_name: str):
    """Merge flash-decoding partials across `axis_name`.

    num: (..., D) fp32 partial numerator   sum_j e^{s_j - m_local} v_j
    den: (..., 1) fp32 partial denominator sum_j e^{s_j - m_local}
    m:   (..., 1) fp32 local running max (-inf where the shard saw no keys)
    Returns the exact softmax-weighted value combine, fp32.
    """
    m_glob = jax.lax.pmax(m, axis_name)
    m_safe = jnp.where(jnp.isfinite(m_glob), m_glob, 0.0)
    scale = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
    num = jax.lax.psum(num * scale, axis_name)
    den = jax.lax.psum(den * scale, axis_name)
    return num / jnp.maximum(den, 1e-30)


def ring_all_gather(x, axis_name: str):
    """All-gather along `axis_name` via a ring of collective-permutes,
    stacking shards on a new leading axis. Lets XLA overlap each hop with
    compute the caller interleaves (overlap hillclimb lever)."""
    n = jax.lax.axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    perm = [(i, (i + 1) % n) for i in range(n)]

    def body(i, state):
        buf, cur = state
        buf = jax.lax.dynamic_update_index_in_dim(
            buf, cur, (idx - i) % n, axis=0)
        cur = jax.lax.ppermute(cur, axis_name, perm)
        return buf, cur

    buf = jnp.zeros((n,) + x.shape, x.dtype)
    buf, _ = jax.lax.fori_loop(0, n, body, (buf, x))
    return buf
