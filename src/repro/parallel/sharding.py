"""Logical-axis sharding rules (MaxText-style), resolved against the ambient mesh.

Every tensor in the framework carries *logical* axis names ("batch", "ff",
"vocab", ...). A ShardingRules table maps logical names to mesh axes. The
resolver drops any mapping whose mesh-axis product does not divide the
concrete dimension — so ONE uniform rule set compiles for every
(arch x shape x mesh) cell, and the roofline then *measures* what the
fallback (replication / GSPMD resharding) costs. That cost is the input to
the per-cell hillclimb, where cells get explicit beyond-baseline schemes.

The mesh is ambient: the launcher (dryrun/train/serve) enters `shardctx(mesh)`
around tracing; `constrain` is a no-op outside any context so the same model
code runs on a single CPU device in tests.
"""
from __future__ import annotations

import contextlib
import math
import re
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axes = Union[None, str, Tuple[str, ...]]


# Logical axis -> mesh axes. "model" is the tensor-parallel axis; the batch
# dimension spreads over every data-parallel axis (pod x data).
DEFAULT_RULES: Dict[str, Axes] = {
    "batch": ("pod", "data"),
    "seq": None,                # flipped to "model" under sequence parallelism
    "kv_seq": ("pod", "data"),  # long-context (batch=1) KV shards over DP axes
    # KV-cache sequence axis: takes whatever axes the batch dim left free —
    # "model" for batched decode (heads permitting), all 512 ways at batch=1
    "kv_seq_tp": ("pod", "data", "model"),
    "d_model": None,
    "ff": "model",
    "heads_proj": "model",      # fused (H*hd) projection dim
    "qheads": "model",
    "kvheads": "model",
    "vocab": "model",
    "experts": "model",
    "ssm_inner": "model",
    "zero1": "data",            # optimizer-state sharding axis
    "stage": "stage",           # pipeline-parallel stage axis (opt-in meshes)
}


class ShardingRules(dict):
    """A dict of logical-axis -> mesh-axes with an override constructor."""

    def but(self, **overrides: Axes) -> "ShardingRules":
        new = ShardingRules(self)
        new.update(overrides)
        return new


DEFAULT = ShardingRules(DEFAULT_RULES)

_STATE = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_STATE, "mesh", None)


def current_rules() -> ShardingRules:
    return getattr(_STATE, "rules", DEFAULT)


@contextlib.contextmanager
def shardctx(mesh: Optional[Mesh], rules: Optional[ShardingRules] = None):
    """Install an ambient (mesh, rules) pair for constrain()/logical_spec()."""
    prev = (current_mesh(), current_rules())
    _STATE.mesh = mesh
    _STATE.rules = rules or DEFAULT
    try:
        yield
    finally:
        _STATE.mesh, _STATE.rules = prev


def _mesh_axes_size(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    return math.prod(mesh.shape[a] for a in axes)


def logical_spec(shape: Sequence[int], names: Sequence[Optional[str]],
                 mesh: Optional[Mesh] = None,
                 rules: Optional[ShardingRules] = None) -> P:
    """Resolve logical axis names against the mesh into a PartitionSpec.

    Any mapping that does not divide the dimension (or references mesh axes
    that don't exist) is dropped — never an error. A mesh axis is used at
    most once across the whole spec (first dim wins).
    """
    mesh = mesh or current_mesh()
    rules = rules or current_rules()
    if mesh is None:
        return P()
    used: set = set()
    parts = []
    for dim, name in zip(shape, names):
        axes = rules.get(name) if name else None
        if axes is None:
            parts.append(None)
            continue
        if isinstance(axes, str):
            axes = (axes,)
        axes = tuple(a for a in axes if a in mesh.axis_names and a not in used)
        size = _mesh_axes_size(mesh, axes) if axes else 1
        if axes and size > 1 and dim % size == 0:
            used.update(axes)
            parts.append(axes if len(axes) > 1 else axes[0])
        else:
            parts.append(None)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def constrain(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical names; identity with no mesh."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = logical_spec(x.shape, names, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Parameter specs by pytree path (naming convention of the model zoo).
# ---------------------------------------------------------------------------

# (path-regex, logical names per dim). First match wins. Stacked layer params
# gain a leading replicated (scan) dim handled below.
_PARAM_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    (r"(^|/)embed$", ("vocab_embed", "d_model")),
    (r"(^|/)(pos_embed|enc_pos_embed)$", (None, None)),
    (r"(^|/)lm_head$", ("d_model", "vocab")),
    (r"(^|/)w[qkv]$", ("d_model", "heads_proj")),
    (r"(^|/)wo$", ("heads_proj", "d_model")),
    (r"(^|/)(gate|up)$", ("d_model", "ff")),
    (r"(^|/)down$", ("ff", "d_model")),
    (r"(^|/)experts_(gate|up)$", ("experts", "d_model", "ff")),
    (r"(^|/)experts_down$", ("experts", "ff", "d_model")),
    (r"(^|/)router$", ("d_model", None)),
    (r"(^|/)in_proj$", ("d_model", "ssm_inner")),
    (r"(^|/)out_proj$", ("ssm_inner", "d_model")),
    (r"(^|/)x_proj$", ("ssm_inner", None)),
    (r"(^|/)dt_proj$", (None, "ssm_inner")),
    (r"(^|/)conv_w$", ("ssm_inner", None)),
    (r"(^|/)A_log$", ("ssm_inner", None)),
    (r"(^|/)(D|dt_bias)$", ("ssm_inner",)),
    # xLSTM blocks are small: replicate (see DESIGN §5).
    (r"(^|/)(mlstm|slstm)_", ()),
    (r"(^|/)(scale|bias)$", ()),
)


def _names_for_path(path: str, ndim: int) -> Tuple[Optional[str], ...]:
    for pat, names in _PARAM_RULES:
        if re.search(pat, path):
            names = tuple(names)[:ndim]
            if len(names) < ndim:  # stacked (scan) leading dims -> replicated
                names = (None,) * (ndim - len(names)) + names
            return names
    return (None,) * ndim


# vocab-sharded table for tied embeddings, d-sharded for untied lookup-only
# tables (see DESIGN §5): resolved by the model providing `tied` in the path.
def _resolve_embed(names, tied: bool):
    return tuple(("vocab" if tied else None) if n == "vocab_embed"
                 else ("d_model" if (n == "d_model" and not tied) else
                       (None if n == "d_model" else n)) for n in names)


def param_spec_tree(params, mesh: Optional[Mesh] = None,
                    rules: Optional[ShardingRules] = None, *,
                    tied_embeddings: bool = False):
    """PartitionSpec pytree matching `params` (dicts of arrays / quant dicts)."""
    mesh = mesh or current_mesh()
    rules = rules or current_rules()

    def visit(node, path: str):
        if isinstance(node, dict):
            # quantized weight {"q":..,"scale":..} shards like the weight
            if set(node) == {"q", "scale"}:
                qspec = visit(node["q"], path)
                sspec = (P() if node["scale"] is None or mesh is None
                         else logical_spec(node["scale"].shape,
                                           _names_for_path(path, node["scale"].ndim),
                                           mesh, rules))
                return {"q": qspec, "scale": sspec}
            return {k: visit(v, f"{path}/{k}") for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(visit(v, path) for v in node)
        if node is None:
            return None
        names = _names_for_path(path, node.ndim)
        if "vocab_embed" in names:
            names = _resolve_embed(names, tied_embeddings)
        if mesh is None:
            return P()
        return logical_spec(node.shape, names, mesh, rules)

    return visit(params, "")


def zero1_spec(weight_spec: P, shape: Sequence[int],
               mesh: Optional[Mesh] = None,
               rules: Optional[ShardingRules] = None) -> P:
    """Optimizer-state spec: weight spec + ZeRO-1 sharding over the data axis
    on the first still-replicated, divisible dimension."""
    mesh = mesh or current_mesh()
    rules = rules or current_rules()
    if mesh is None:
        return P()
    axes = rules.get("zero1")
    if isinstance(axes, str):
        axes = (axes,)
    axes = tuple(a for a in (axes or ()) if a in mesh.axis_names)
    if not axes:
        return weight_spec
    used = set()
    for part in weight_spec:
        if isinstance(part, tuple):
            used.update(part)
        elif part is not None:
            used.add(part)
    axes = tuple(a for a in axes if a not in used)
    if not axes:
        return weight_spec
    size = _mesh_axes_size(mesh, axes)
    parts = list(weight_spec) + [None] * (len(shape) - len(weight_spec))
    for i, dim in enumerate(shape):
        if parts[i] is None and dim % size == 0 and size > 1:
            parts[i] = axes if len(axes) > 1 else axes[0]
            break
    return P(*parts)


def shardings_for(params, mesh: Optional[Mesh] = None, **kw):
    """NamedSharding pytree for jit in_shardings."""
    mesh = mesh or current_mesh()
    specs = param_spec_tree(params, mesh, **kw)
    if mesh is None:
        return specs
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
