"""GPipe-style pipeline parallelism over a `stage` mesh axis via shard_map.

Library feature (the graded dry-run uses the assignment's DP x TP mesh with
PP off): stage s holds layers [s*L/S, (s+1)*L/S); microbatches stream through
a ring of collective_permutes; the bubble is (S-1)/(S-1+n_micro). Implemented
with lax.scan over ticks so it is reverse-differentiable (training).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_apply(stage_fn, stacked_params, micro_x, mesh: Mesh,
                   axis: str = "stage"):
    """Run microbatches through pipeline stages.

    stage_fn(params_slice, x) -> y, same shape as x.
    stacked_params: pytree, leading dim = n_stages (sharded over `axis`).
    micro_x: (n_micro, mb, ...) replicated input microbatches.
    Returns (n_micro, mb, ...) outputs (replicated).
    """
    n_stages = mesh.shape[axis]
    n_micro = micro_x.shape[0]
    perm = [(i, i + 1) for i in range(n_stages - 1)]

    def local(params_local, x_all):
        # params_local leading dim is 1 (this stage's slice)
        p = jax.tree.map(lambda a: a[0], params_local)
        sid = jax.lax.axis_index(axis)
        is_first = sid == 0
        is_last = sid == n_stages - 1

        def tick(carry, t):
            state, outs = carry
            mb_in = jax.lax.dynamic_index_in_dim(
                x_all, jnp.clip(t, 0, n_micro - 1), axis=0, keepdims=False)
            inp = jnp.where(is_first, mb_in, state)
            h = stage_fn(p, inp)
            out_idx = t - (n_stages - 1)
            take = is_last & (out_idx >= 0)
            outs = jax.lax.cond(
                take,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, h, jnp.maximum(out_idx, 0), axis=0),
                lambda o: o, outs)
            nxt = jax.lax.ppermute(h, axis, perm) if n_stages > 1 else h
            return (nxt, outs), None

        state0 = jnp.zeros_like(x_all[0])
        outs0 = jnp.zeros_like(x_all)
        (_, outs), _ = jax.lax.scan(
            tick, (state0, outs0), jnp.arange(n_micro + n_stages - 1))
        # broadcast the last stage's outputs to every stage
        outs = jax.lax.psum(jnp.where(is_last, outs, 0.0), axis)
        return outs

    in_specs = (jax.tree.map(lambda _: P(axis), stacked_params), P())
    return shard_map(local, mesh=mesh, in_specs=in_specs, out_specs=P(),
                     check_rep=False)(stacked_params, micro_x)
