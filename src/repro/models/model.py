"""Model assembly: decoder-only / hybrid / SSM / encoder-decoder stacks.

HLO stays compact for arbitrarily deep models via scan-over-superblocks: the
layer pattern repeats with period U (= lcm of attention interleave, MoE
interleave, sLSTM cadence); params for each of the U unit positions are
stacked over the R = L/U repeats and the stack is lax.scan'ed. Decode caches
are stacked the same way and stream through the scan as xs/ys.

Entry points (all pure; callers jit/pjit):
  init_params(rng, cfg)                  -> params
  train_loss(params, cfg, tokens, labels, ...) -> (loss, aux)
  prefill(params, cfg, tokens, ...)      -> (last_logits, cache)
  decode_step(params, cfg, token, cache, ...) -> (logits, cache)
  init_cache / abstract_cache            -> cache pytree (zeros / SDS)
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models import xlstm as xlstm_lib
from repro.models.layers import (
    apply_mlp, apply_norm, cross_entropy_loss, dense_init, embed_init,
    init_mlp, init_norm, softcap)
from repro.parallel.sharding import constrain
from repro.quant import linear

MAX_POS = 32768          # learned-position table size (whisper)
AUX_LOSS_COEF = 0.01
VLM_PATCHES = 256        # stubbed patch count for vlm input cells


import dataclasses as _dc


@_dc.dataclass
class PerfConfig:
    """Beyond-baseline performance levers (§Perf hillclimb). The dry-run
    harness mutates the module-global PERF before tracing a cell."""
    kv_cache_dtype: Any = jnp.bfloat16   # fp8_e4m3 halves decode HBM reads
    local_recurrence: bool = False       # shard_map SSM/xLSTM scans: batch-
    #                                      local recurrence, no GSPMD
    #                                      permutes inside the time loop
    flash_decode: bool = False           # shard_map partial-softmax decode
    #                                      over the seq-sharded KV cache


PERF = PerfConfig()


# ---------------------------------------------------------------------------
# Superblock structure
# ---------------------------------------------------------------------------

def unit_size(cfg: ModelConfig) -> int:
    u = 1
    if cfg.attn_every > 1:
        u = math.lcm(u, cfg.attn_every)
    if cfg.moe is not None and cfg.moe.interleave > 1:
        u = math.lcm(u, cfg.moe.interleave)
    if cfg.xlstm is not None:
        u = math.lcm(u, cfg.xlstm.slstm_every)
    if cfg.num_layers % u:
        u = cfg.num_layers     # degenerate: no repetition -> single scan step
    return u


def unit_pattern(cfg: ModelConfig) -> Tuple[Tuple[str, bool], ...]:
    """(block_kind, is_moe) for each of the first U layers."""
    u = unit_size(cfg)
    kinds = cfg.block_pattern()
    moes = cfg.moe_layer_mask()
    return tuple((kinds[i], moes[i]) for i in range(u))


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ModelConfig, kind: str, is_moe: bool, dtype):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {"ln1": init_norm(cfg.norm_kind, d, dtype)}
    if kind == "attn":
        p["attn"] = attn.init_attention(
            ks[0], d, cfg.num_heads, cfg.num_kv_heads, hd, dtype)
    elif kind == "mamba":
        p["mamba"] = ssm_lib.init_mamba(ks[0], d, cfg.ssm, dtype)
    elif kind == "mlstm":
        p.update(xlstm_lib.init_mlstm(ks[0], d, cfg.num_heads, cfg.xlstm, dtype))
        return p    # self-contained (internal ff)
    elif kind == "slstm":
        p.update(xlstm_lib.init_slstm(ks[0], d, cfg.xlstm, dtype))
        return p
    if cfg.family == "encdec":
        p["ln_x"] = init_norm(cfg.norm_kind, d, dtype)
        p["xattn"] = attn.init_cross_attention(
            ks[1], d, cfg.num_heads, cfg.num_kv_heads, hd, dtype)
    # feed-forward half
    if is_moe and cfg.moe is not None:
        p["ln2"] = init_norm(cfg.norm_kind, d, dtype)
        p["moe"] = moe_lib.init_moe(ks[2], d, cfg.moe, cfg.mlp_kind, dtype)
    elif cfg.d_ff:
        p["ln2"] = init_norm(cfg.norm_kind, d, dtype)
        p["mlp"] = init_mlp(ks[2], d, cfg.d_ff, cfg.mlp_kind, dtype)
    return p


def init_params(rng, cfg: ModelConfig, dtype=None) -> Dict[str, Any]:
    dtype = dtype or jnp.bfloat16
    U = unit_size(cfg)
    R = cfg.num_layers // U
    pattern = unit_pattern(cfg)
    keys = jax.random.split(rng, cfg.num_layers + 8)

    blocks = []
    for j, (kind, is_moe) in enumerate(pattern):
        per_repeat = [
            _init_block(keys[r * U + j], cfg, kind, is_moe, dtype)
            for r in range(R)]
        blocks.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_repeat))

    k_embed, k_head, k_pos, k_enc, *_ = jax.random.split(keys[-1], 8)
    params: Dict[str, Any] = {
        "embed": embed_init(k_embed, cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": init_norm(cfg.norm_kind, cfg.d_model, dtype),
        "blocks": blocks,
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, cfg.d_model, cfg.vocab_size, dtype)
    if cfg.rope_kind == "none" and cfg.family == "encdec":
        params["pos_embed"] = embed_init(k_pos, MAX_POS, cfg.d_model, dtype)
    if cfg.encoder_layers:
        ekeys = jax.random.split(k_enc, cfg.encoder_layers + 1)
        enc_layers = [
            {"ln1": init_norm(cfg.norm_kind, cfg.d_model, dtype),
             "attn": attn.init_attention(ekeys[i], cfg.d_model, cfg.num_heads,
                                         cfg.num_kv_heads,
                                         cfg.resolved_head_dim, dtype),
             "ln2": init_norm(cfg.norm_kind, cfg.d_model, dtype),
             "mlp": init_mlp(ekeys[i], cfg.d_model, cfg.d_ff, cfg.mlp_kind,
                             dtype)}
            for i in range(cfg.encoder_layers)]
        params["encoder"] = jax.tree.map(lambda *xs: jnp.stack(xs), *enc_layers)
        params["enc_pos_embed"] = embed_init(
            ekeys[-1], cfg.frontend_len or 1500, cfg.d_model, dtype)
        params["enc_final_norm"] = init_norm(cfg.norm_kind, cfg.d_model, dtype)
    return params


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_tokens(params, cfg: ModelConfig, tokens):
    x = jnp.take(params["embed"], tokens, axis=0)
    return constrain(x, "batch", "seq", "d_model")


def unembed(params, cfg: ModelConfig, x, qcfg=None):
    if cfg.tie_embeddings:
        w = params["embed"]
        if isinstance(w, dict):
            q, s = w["q"], w["scale"]
            w = q if s is None else (q.astype(jnp.bfloat16) *
                                     s.astype(jnp.bfloat16))
        logits = jax.lax.dot_general(
            x, w.astype(x.dtype), (((x.ndim - 1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
    else:
        logits = linear(x, params["lm_head"], qcfg).astype(jnp.float32)
    logits = softcap(logits, cfg.logit_softcap)
    return constrain(logits, "batch", "seq", "vocab")


# ---------------------------------------------------------------------------
# Block application — full-sequence (train / prefill)
# ---------------------------------------------------------------------------

def _apply_attn_block(p, cfg: ModelConfig, x, positions, qcfg,
                      enc_kv=None, make_cache=False):
    """Returns (x, cache_or_None). Cache k/v layout (B,Hkv,S,D)."""
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    h = apply_norm(p["ln1"], x, cfg.norm_kind)
    q, k, v = attn.qkv(p["attn"], h, cfg.num_heads, cfg.num_kv_heads, hd, qcfg)
    q = attn.rotate(cfg.rope_kind, q, positions, cfg.rope_theta)
    k = attn.rotate(cfg.rope_kind, k, positions, cfg.rope_theta)
    q = constrain(q, "batch", "seq", "qheads", None)
    k = constrain(k, "batch", "seq", "kvheads", None)
    v = constrain(v, "batch", "seq", "kvheads", None)
    if S > attn.CHUNKED_THRESHOLD:
        o = attn.causal_attention_chunked(q, k, v)
    else:
        o = attn.causal_attention(q, k, v)
    o = linear(o.reshape(B, S, cfg.num_heads * hd), p["attn"]["wo"], qcfg)
    x = x + o
    cache = None
    if make_cache:
        kv_dt = PERF.kv_cache_dtype
        cache = {"k": k.transpose(0, 2, 1, 3).astype(kv_dt),
                 "v": v.transpose(0, 2, 1, 3).astype(kv_dt)}
    if enc_kv is not None:
        h = apply_norm(p["ln_x"], x, cfg.norm_kind)
        x = x + attn.cross_attention(
            p["xattn"], h, enc_kv["xk"], enc_kv["xv"],
            cfg.num_heads, cfg.num_kv_heads, hd, qcfg)
    return x, cache


def _apply_ff(p, cfg: ModelConfig, x, is_moe: bool, qcfg):
    aux = jnp.zeros((), jnp.float32)
    if is_moe and "moe" in p:
        h = apply_norm(p["ln2"], x, cfg.norm_kind)
        mo, aux = moe_lib.apply_moe(p["moe"], h, cfg.moe, cfg.mlp_kind, qcfg)
        x = x + mo
    elif "mlp" in p:
        h = apply_norm(p["ln2"], x, cfg.norm_kind)
        x = x + apply_mlp(p["mlp"], h, cfg.mlp_kind, qcfg)
    return x, aux


def _local_batch_shard_map(fn, p, x):
    """Run a recurrent block under shard_map with batch-sharded activations
    and replicated params: the time-loop recurrence becomes provably local,
    eliminating the per-step collective-permutes GSPMD otherwise inserts
    (xlstm train baseline: 413 GB/step of permutes — §Perf cell C)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.parallel.sharding import current_mesh, logical_spec
    mesh = current_mesh()
    if mesh is None:
        return fn(p, x)
    bspec = logical_spec(x.shape, ("batch",), mesh)
    bax = bspec[0] if len(bspec) else None
    if bax is None:
        # gate (optimized-sweep lesson, jamba long_500k): batch smaller
        # than the DP degree cannot be shard_map'd — keep the GSPMD path
        return fn(p, x)
    B = x.shape[0]
    out_abs = jax.eval_shape(fn, p, x)
    ospec = jax.tree.map(
        lambda s: P(bax) if (s.shape and s.shape[0] == B) else P(), out_abs)
    return shard_map(fn, mesh=mesh, in_specs=(P(), P(bax)),
                     out_specs=ospec, check_rep=False)(p, x)


def _apply_block_seq(p, cfg: ModelConfig, kind: str, is_moe: bool, x,
                     positions, qcfg, enc_kv=None, make_cache=False):
    """Full-sequence block application. Returns (x, aux, cache)."""
    cache = None
    if kind == "attn":
        x, cache = _apply_attn_block(p, cfg, x, positions, qcfg, enc_kv,
                                     make_cache)
    elif kind == "mamba":
        h = apply_norm(p["ln1"], x, cfg.norm_kind)
        fn = lambda p_, h_: ssm_lib.apply_mamba(p_, h_, cfg.ssm, qcfg)
        y, state = (_local_batch_shard_map(fn, p["mamba"], h)
                    if PERF.local_recurrence else fn(p["mamba"], h))
        x = x + y
        if make_cache:
            cache = state
    elif kind == "mlstm":
        h = apply_norm(p["ln1"], x, cfg.norm_kind)
        fn = lambda p_, h_: xlstm_lib.mlstm_seq(
            p_, h_, cfg.num_heads, cfg.xlstm, None, qcfg)
        y, state = (_local_batch_shard_map(fn, p, h)
                    if PERF.local_recurrence else fn(p, h))
        x = x + y
        if make_cache:
            cache = state
    elif kind == "slstm":
        h = apply_norm(p["ln1"], x, cfg.norm_kind)
        fn = lambda p_, h_: xlstm_lib.slstm_seq(p_, h_, cfg.xlstm, None,
                                                qcfg)
        y, state = (_local_batch_shard_map(fn, p, h)
                    if PERF.local_recurrence else fn(p, h))
        x = x + y
        if make_cache:
            cache = state
    x, aux = _apply_ff(p, cfg, x, is_moe, qcfg)
    x = constrain(x, "batch", "seq", "d_model")
    return x, aux, cache


def _stack_forward(params, cfg: ModelConfig, x, positions, qcfg,
                   enc_out=None, make_cache=False, remat=False):
    """Scan the superblock stack over R repeats.

    Returns (x, aux_sum, caches) — caches is a list over unit positions of
    (R,...)-stacked cache pytrees (or None when make_cache=False).
    """
    pattern = unit_pattern(cfg)
    U = len(pattern)
    hd = cfg.resolved_head_dim

    def body(x, stacked):
        aux_total = jnp.zeros((), jnp.float32)
        caches = []
        for j, (kind, is_moe) in enumerate(pattern):
            p = stacked[j]
            enc_kv = None
            if enc_out is not None and kind == "attn":
                # cross-attn K/V from encoder output, this layer's weights
                Bz, Se, _ = enc_out.shape
                k = linear(enc_out, p["xattn"]["wk"], qcfg).reshape(
                    Bz, Se, cfg.num_kv_heads, hd)
                v = linear(enc_out, p["xattn"]["wv"], qcfg).reshape(
                    Bz, Se, cfg.num_kv_heads, hd)
                enc_kv = {"xk": k, "xv": v}
            x, aux, cache = _apply_block_seq(
                p, cfg, kind, is_moe, x, positions, qcfg, enc_kv, make_cache)
            aux_total = aux_total + aux
            if make_cache:
                if enc_kv is not None:
                    cache = dict(cache or {}, **enc_kv)
                caches.append(cache if cache is not None else {})
        return x, (aux_total, tuple(caches))

    if remat:
        body = jax.checkpoint(body)

    x, (auxes, caches) = jax.lax.scan(body, x, params["blocks"])
    return x, jnp.sum(auxes), (list(caches) if make_cache else None)


# ---------------------------------------------------------------------------
# Encoder (whisper)
# ---------------------------------------------------------------------------

def encode(params, cfg: ModelConfig, frames, qcfg=None):
    """frames: (B, Se, d_model) stubbed frontend embeddings -> (B, Se, d)."""
    Se = frames.shape[1]
    x = frames + params["enc_pos_embed"][:Se].astype(frames.dtype)
    hd = cfg.resolved_head_dim

    def body(x, p):
        h = apply_norm(p["ln1"], x, cfg.norm_kind)
        q, k, v = attn.qkv(p["attn"], h, cfg.num_heads, cfg.num_kv_heads,
                           hd, qcfg)
        o = attn.bidirectional_attention(q, k, v)
        B, S = x.shape[:2]
        x = x + linear(o.reshape(B, S, cfg.num_heads * hd),
                       p["attn"]["wo"], qcfg)
        h = apply_norm(p["ln2"], x, cfg.norm_kind)
        x = x + apply_mlp(p["mlp"], h, cfg.mlp_kind, qcfg)
        return x, None

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return apply_norm(params["enc_final_norm"], x, cfg.norm_kind)


# ---------------------------------------------------------------------------
# Full forward / loss (train path)
# ---------------------------------------------------------------------------

def _positions(cfg: ModelConfig, batch: int, seq: int, offset=None):
    base = jnp.arange(seq, dtype=jnp.int32)[None]
    if offset is not None:
        base = base + offset[:, None]
    else:
        base = jnp.broadcast_to(base, (batch, seq))
    if cfg.rope_kind == "mrope":
        return jnp.broadcast_to(base[None], (3, batch, seq))
    return base


def forward(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            qcfg=None, remat=False):
    """Full-sequence logits. batch: tokens (B,S) [+ frames / patches]."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = embed_tokens(params, cfg, tokens)
    if cfg.frontend == "vision_patches" and "patches" in batch:
        P_ = batch["patches"].shape[1]
        pat = jnp.pad(batch["patches"].astype(x.dtype),
                      ((0, 0), (0, S - P_), (0, 0)))
        is_patch = (jnp.arange(S) < P_)[None, :, None]
        x = jnp.where(is_patch, pat, x)
    if "pos_embed" in params:
        x = x + params["pos_embed"][:S].astype(x.dtype)
    positions = _positions(cfg, B, S)
    enc_out = None
    if cfg.encoder_layers:
        enc_out = encode(params, cfg, batch["frames"].astype(x.dtype), qcfg)
    x, aux, _ = _stack_forward(params, cfg, x, positions, qcfg,
                               enc_out=enc_out, remat=remat)
    x = apply_norm(params["final_norm"], x, cfg.norm_kind)
    return unembed(params, cfg, x, qcfg), aux


def train_loss(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
               qcfg=None, remat=True):
    logits, aux = forward(params, cfg, batch, qcfg, remat=remat)
    loss = cross_entropy_loss(logits, batch["labels"], batch.get("mask"))
    return loss + AUX_LOSS_COEF * aux, {"ce": loss, "aux": aux}


# ---------------------------------------------------------------------------
# Cache init
# ---------------------------------------------------------------------------

def _block_cache_shape(cfg: ModelConfig, kind: str, B: int, S_max: int,
                       R: int, enc_len: int = 0):
    hd = cfg.resolved_head_dim
    d = cfg.d_model
    out = {}
    if kind == "attn":
        kv_dt = PERF.kv_cache_dtype
        out = {"k": ((R, B, cfg.num_kv_heads, S_max, hd), kv_dt),
               "v": ((R, B, cfg.num_kv_heads, S_max, hd), kv_dt)}
        if cfg.family == "encdec":
            out["xk"] = ((R, B, enc_len, cfg.num_kv_heads, hd), jnp.bfloat16)
            out["xv"] = ((R, B, enc_len, cfg.num_kv_heads, hd), jnp.bfloat16)
    elif kind == "mamba":
        di = cfg.ssm.expand * d
        out = {"conv": ((R, B, cfg.ssm.d_conv - 1, di), jnp.bfloat16),
               "h": ((R, B, di, cfg.ssm.d_state), jnp.float32)}
    elif kind == "mlstm":
        di = int(cfg.xlstm.mlstm_proj_factor * d)
        dh = di // cfg.num_heads
        out = {"C": ((R, B, cfg.num_heads, dh, dh), jnp.float32),
               "n": ((R, B, cfg.num_heads, dh), jnp.float32),
               "m": ((R, B, cfg.num_heads), jnp.float32)}
    elif kind == "slstm":
        out = {k: ((R, B, d), jnp.float32) for k in ("c", "n", "m", "h")}
    return out


def cache_spec(cfg: ModelConfig, batch: int, max_len: int, enc_len: int = 0):
    """Shape/dtype tree: {"len": (B,), "blocks": [per unit position]}."""
    U = unit_size(cfg)
    R = cfg.num_layers // U
    pattern = unit_pattern(cfg)
    blocks = [_block_cache_shape(cfg, kind, batch, max_len, R, enc_len)
              for kind, _ in pattern]
    return {"len": ((batch,), jnp.int32), "blocks": blocks}


def init_cache(cfg: ModelConfig, batch: int, max_len: int, enc_len: int = 0):
    spec = cache_spec(cfg, batch, max_len, enc_len)
    return jax.tree.map(lambda sd: jnp.zeros(sd[0], sd[1]), spec,
                        is_leaf=lambda x: isinstance(x, tuple) and
                        len(x) == 2 and isinstance(x[0], tuple))


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int,
                   enc_len: int = 0):
    spec = cache_spec(cfg, batch, max_len, enc_len)
    return jax.tree.map(lambda sd: jax.ShapeDtypeStruct(sd[0], sd[1]), spec,
                        is_leaf=lambda x: isinstance(x, tuple) and
                        len(x) == 2 and isinstance(x[0], tuple))


def constrain_cache(cfg: ModelConfig, cache):
    """Sharding constraints on the cache pytree (names per leaf rank)."""
    def visit(blocks):
        out = []
        for blk in blocks:
            c = {}
            for name, arr in blk.items():
                if name in ("k", "v"):
                    c[name] = constrain(arr, None, "batch", "kvheads",
                                        "kv_seq_tp", None)
                elif name in ("xk", "xv"):
                    c[name] = constrain(arr, None, "batch", None,
                                        "kvheads", None)
                elif name in ("conv", "h", "C", "n", "m", "c"):
                    names = [None, "batch"] + [None] * (arr.ndim - 2)
                    if name in ("h", "C") and arr.ndim >= 3:
                        names[2] = "ssm_inner"
                    c[name] = constrain(arr, *names)
                else:
                    c[name] = constrain(arr, None, "batch",
                                        *([None] * (arr.ndim - 2)))
            out.append(c)
        return out
    return {"len": cache["len"], "blocks": visit(cache["blocks"])}


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------

def prefill(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            qcfg=None, max_len: Optional[int] = None):
    """Run the full prompt, return (last-position logits, filled cache)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    max_len = max_len or S
    x = embed_tokens(params, cfg, tokens)
    if cfg.frontend == "vision_patches" and "patches" in batch:
        P_ = batch["patches"].shape[1]
        pat = jnp.pad(batch["patches"].astype(x.dtype),
                      ((0, 0), (0, S - P_), (0, 0)))
        x = jnp.where((jnp.arange(S) < P_)[None, :, None], pat, x)
    if "pos_embed" in params:
        x = x + params["pos_embed"][:S].astype(x.dtype)
    positions = _positions(cfg, B, S)
    enc_out = None
    if cfg.encoder_layers:
        enc_out = encode(params, cfg, batch["frames"].astype(x.dtype), qcfg)
    x, _, caches = _stack_forward(params, cfg, x, positions, qcfg,
                                  enc_out=enc_out, make_cache=True)
    # pad caches from S to max_len on the sequence axis
    pattern = unit_pattern(cfg)
    for j, (kind, _) in enumerate(pattern):
        if kind == "attn" and max_len > S:
            for nm in ("k", "v"):
                c = caches[j][nm]
                caches[j][nm] = jnp.pad(
                    c, ((0, 0), (0, 0), (0, 0), (0, max_len - S), (0, 0)))
    cache = {"len": jnp.full((B,), S, jnp.int32), "blocks": caches}
    cache = constrain_cache(cfg, cache)
    x = apply_norm(params["final_norm"], x[:, -1:], cfg.norm_kind)
    return unembed(params, cfg, x, qcfg), cache


# ---------------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------------

def _flash_decode_attention(q, kc, vc, cache_len):
    """shard_map flash-decoding over the cache's sequence shards: each
    model-axis shard computes a partial softmax over its local KV slice;
    the exact combine is three tiny psums of (num, den, max) instead of
    GSPMD's gather/reshard of multi-GB score tensors (§Perf cell B)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.parallel.collectives import combine_partial_softmax
    from repro.parallel.sharding import current_mesh, logical_spec
    mesh = current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return attn.decode_attention(q, kc, vc, cache_len)
    bspec = logical_spec(q.shape, ("batch",), mesh)
    bax = bspec[0] if len(bspec) else None
    S = kc.shape[2]
    if S % mesh.shape["model"]:
        return attn.decode_attention(q, kc, vc, cache_len)
    # gate (optimized-sweep lesson, codeqwen1.5-7b): if the KV heads fully
    # occupy the model axis the cache is head-sharded, not seq-sharded —
    # forcing seq-shard flash-decode would reshard the cache every step.
    if kc.shape[1] % mesh.shape["model"] == 0:
        return attn.decode_attention(q, kc, vc, cache_len)
    # gate (jamba long_500k): at batch < DP degree the cache sequence is
    # sharded over (data, model); a model-axis-only shard_map would
    # UN-shard the data dimension — keep the GSPMD path.
    if bax is None:
        return attn.decode_attention(q, kc, vc, cache_len)

    def local(q_, kc_, vc_, cl_):
        i = jax.lax.axis_index("model")
        s_loc = kc_.shape[2]
        pos = i * s_loc + jnp.arange(s_loc)
        valid = pos[None, :] < cl_[:, None]
        num, den, m = attn.decode_attention_partial(q_, kc_, vc_, valid)
        out = combine_partial_softmax(num, den, m, "model")
        return out.astype(q_.dtype)

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(bax), P(bax, None, "model"), P(bax, None, "model"),
                  P(bax)),
        out_specs=P(bax), check_rep=False)(q, kc, vc, cache_len)


def _apply_block_decode(p, cfg: ModelConfig, kind: str, is_moe: bool, x,
                        cache_j, positions, cache_len, qcfg):
    hd = cfg.resolved_head_dim
    B = x.shape[0]
    new_cache = dict(cache_j)
    if kind == "attn":
        h = apply_norm(p["ln1"], x, cfg.norm_kind)
        q, k, v = attn.qkv(p["attn"], h, cfg.num_heads, cfg.num_kv_heads,
                           hd, qcfg)
        q = attn.rotate(cfg.rope_kind, q, positions, cfg.rope_theta)
        k = attn.rotate(cfg.rope_kind, k, positions, cfg.rope_theta)
        kc, vc = attn.update_cache(cache_j["k"], cache_j["v"],
                                   k.astype(cache_j["k"].dtype),
                                   v.astype(cache_j["v"].dtype), cache_len)
        if PERF.flash_decode:
            o = _flash_decode_attention(q, kc.astype(x.dtype),
                                        vc.astype(x.dtype), cache_len + 1)
        else:
            o = attn.decode_attention(q, kc.astype(x.dtype),
                                      vc.astype(x.dtype), cache_len + 1)
        x = x + linear(o.reshape(B, 1, cfg.num_heads * hd),
                       p["attn"]["wo"], qcfg)
        new_cache["k"], new_cache["v"] = kc, vc
        if "xk" in cache_j:
            h = apply_norm(p["ln_x"], x, cfg.norm_kind)
            x = x + attn.cross_attention(
                p["xattn"], h, cache_j["xk"].astype(x.dtype),
                cache_j["xv"].astype(x.dtype),
                cfg.num_heads, cfg.num_kv_heads, hd, qcfg)
    elif kind == "mamba":
        h = apply_norm(p["ln1"], x, cfg.norm_kind)
        y, st = ssm_lib.mamba_decode_step(
            p["mamba"], h, cache_j, cfg.ssm, qcfg)
        x = x + y
        new_cache = st
    elif kind == "mlstm":
        h = apply_norm(p["ln1"], x, cfg.norm_kind)
        y, st = xlstm_lib.mlstm_seq(p, h, cfg.num_heads, cfg.xlstm,
                                    cache_j, qcfg)
        x = x + y
        new_cache = st
    elif kind == "slstm":
        h = apply_norm(p["ln1"], x, cfg.norm_kind)
        y, st = xlstm_lib.slstm_seq(p, h, cfg.xlstm, cache_j, qcfg)
        x = x + y
        new_cache = st
    x, _ = _apply_ff(p, cfg, x, is_moe, qcfg)
    return x, new_cache


def decode_step(params, cfg: ModelConfig, token, cache, qcfg=None):
    """One decode step. token: (B,1) int32; cache from prefill/init_cache.

    Returns (logits (B,1,V), updated cache with len+1).
    """
    B = token.shape[0]
    cache_len = cache["len"]
    x = embed_tokens(params, cfg, token)
    if "pos_embed" in params:
        pos = jnp.take(params["pos_embed"], jnp.clip(cache_len, 0,
                                                     MAX_POS - 1), axis=0)
        x = x + pos[:, None].astype(x.dtype)
    positions = _positions(cfg, B, 1, offset=cache_len)
    pattern = unit_pattern(cfg)

    def body(x, xs):
        stacked_p, caches_r = xs
        new_caches = []
        for j, (kind, is_moe) in enumerate(pattern):
            x, nc = _apply_block_decode(
                stacked_p[j], cfg, kind, is_moe, x, caches_r[j],
                positions, cache_len, qcfg)
            new_caches.append(nc)
        return x, tuple(new_caches)

    x, new_blocks = jax.lax.scan(body, x, (params["blocks"],
                                           tuple(cache["blocks"])))
    x = apply_norm(params["final_norm"], x, cfg.norm_kind)
    logits = unembed(params, cfg, x, qcfg)
    new_cache = {"len": cache_len + 1, "blocks": list(new_blocks)}
    new_cache = constrain_cache(cfg, new_cache)
    return logits, new_cache
