"""Mamba-style selective SSM block: train scan + O(1)-state decode step.

State carried between decode steps:
  conv: (B, d_conv-1, d_inner)   last inputs for the causal depthwise conv
  h:    (B, d_inner, d_state)    SSM hidden state (fp32)

The train/prefill path runs the recurrence with lax.scan over the sequence
(compact HLO); the TPU hot path swaps in the chunked Pallas kernel
(repro.kernels.ssm_scan) via ops-level dispatch.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.layers import dense_init
from repro.parallel.sharding import constrain
from repro.quant import linear


def _dims(d_model: int, cfg: SSMConfig) -> Tuple[int, int]:
    di = cfg.expand * d_model
    dtr = cfg.dt_rank or -(-d_model // 16)
    return di, dtr


def init_mamba(key, d_model: int, cfg: SSMConfig, dtype=jnp.bfloat16) -> Dict:
    di, dtr = _dims(d_model, cfg)
    ks = jax.random.split(key, 6)
    A = jnp.broadcast_to(jnp.arange(1, cfg.d_state + 1, dtype=jnp.float32),
                         (di, cfg.d_state))
    return {
        "in_proj": dense_init(ks[0], d_model, 2 * di, dtype),
        "conv_w": dense_init(ks[1], di, cfg.d_conv, dtype),
        "x_proj": dense_init(ks[2], di, dtr + 2 * cfg.d_state, dtype),
        "dt_proj": dense_init(ks[3], dtr, di, dtype),
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "A_log": jnp.log(A),                       # (di, N) fp32
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[5], di, d_model, dtype),
    }


def _ssm_inputs(p, xz, cfg: SSMConfig, conv_state=None, qcfg=None):
    """Shared front half: split, causal conv, input-dependent discretization.

    xz: (B, S, 2*di). Returns (u, dt, Bm, Cm, z, new_conv_state) where
      u (B,S,di), dt (B,S,di) fp32, Bm/Cm (B,S,N) fp32, z gate (B,S,di).
    """
    di = xz.shape[-1] // 2
    x, z = jnp.split(xz, 2, axis=-1)
    k = cfg.d_conv
    # causal depthwise conv along S with state from previous steps
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], k - 1, di), x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                     # (B, S+k-1, di)
    new_conv = xp[:, -(k - 1):, :] if k > 1 else None
    w = _weight(p["conv_w"], x.dtype)                          # (di, k)
    u = sum(xp[:, i:i + x.shape[1], :] * w[:, i] for i in range(k))
    u = jax.nn.silu(u)

    proj = linear(u, p["x_proj"], qcfg).astype(jnp.float32)    # (B,S,dtr+2N)
    dtr = proj.shape[-1] - 2 * cfg.d_state
    dt_r, Bm, Cm = jnp.split(proj, [dtr, dtr + cfg.d_state], axis=-1)
    dt = jax.nn.softplus(
        linear(dt_r.astype(x.dtype), p["dt_proj"], qcfg).astype(jnp.float32)
        + p["dt_bias"])
    return u, dt, Bm, Cm, z, new_conv


def _weight(wp, dtype):
    if isinstance(wp, dict):
        q, s = wp["q"], wp["scale"]
        return q.astype(dtype) if s is None else (
            q.astype(jnp.bfloat16) * s.astype(jnp.bfloat16)).astype(dtype)
    return wp.astype(dtype)


def mamba_scan_ref(u, dt, Bm, Cm, A, D, h0=None):
    """Reference selective scan: sequential over S in fp32.

    u (B,S,di); dt (B,S,di); Bm/Cm (B,S,N); A (di,N); D (di,).
    Returns (y (B,S,di) fp32, h_final (B,di,N) fp32).
    """
    Bsz, S, di = u.shape
    N = A.shape[-1]
    uf = u.astype(jnp.float32)
    h = jnp.zeros((Bsz, di, N), jnp.float32) if h0 is None else h0

    def step(h, xs):
        u_t, dt_t, B_t, C_t = xs
        dA = jnp.exp(dt_t[..., None] * A)                      # (B,di,N)
        dBu = (dt_t * u_t)[..., None] * B_t[:, None, :]        # (B,di,N)
        h = dA * h + dBu
        y = jnp.einsum("bdn,bn->bd", h, C_t) + D * u_t
        return h, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (uf, dt, Bm, Cm))
    h, ys = jax.lax.scan(step, h, xs)
    return jnp.moveaxis(ys, 0, 1), h


def apply_mamba(p, x, cfg: SSMConfig, qcfg=None):
    """Train/prefill path. x: (B,S,d) -> (y (B,S,d), state dict)."""
    xz = linear(x, p["in_proj"], qcfg)
    xz = constrain(xz, "batch", None, "ssm_inner")
    u, dt, Bm, Cm, z, conv = _ssm_inputs(p, xz, cfg, None, qcfg)
    A = -jnp.exp(p["A_log"])
    y, h = mamba_scan_ref(u, dt, Bm, Cm, A, p["D"])
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = linear(y, p["out_proj"], qcfg)
    state = {"conv": (conv.astype(jnp.bfloat16) if conv is not None else
                      jnp.zeros((x.shape[0], 0, u.shape[-1]), jnp.bfloat16)),
             "h": h}
    return out, state


def mamba_decode_step(p, x, state, cfg: SSMConfig, qcfg=None):
    """Single-token decode. x: (B,1,d); state {conv (B,k-1,di), h (B,di,N)}."""
    xz = linear(x, p["in_proj"], qcfg)
    u, dt, Bm, Cm, z, conv = _ssm_inputs(p, xz, cfg, state["conv"], qcfg)
    A = -jnp.exp(p["A_log"])
    y, h = mamba_scan_ref(u, dt, Bm, Cm, A, p["D"], h0=state["h"])
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = linear(y, p["out_proj"], qcfg)
    return out, {"conv": conv.astype(jnp.bfloat16), "h": h}


def init_mamba_state(batch: int, d_model: int, cfg: SSMConfig):
    di, _ = _dims(d_model, cfg)
    return {"conv": jnp.zeros((batch, cfg.d_conv - 1, di), jnp.bfloat16),
            "h": jnp.zeros((batch, di, cfg.d_state), jnp.float32)}
