"""Model zoo: functional blocks + assembly for all assigned architectures."""
from repro.models.model import (  # noqa: F401
    abstract_cache,
    cache_spec,
    decode_step,
    encode,
    forward,
    init_cache,
    init_params,
    prefill,
    train_loss,
    unit_pattern,
    unit_size,
)
