"""Mixture-of-Experts with capacity-grouped einsum dispatch (Mesh-TF style).

Tokens are processed in groups; each token picks top-k experts; each expert
accepts at most `capacity` tokens per group (overflow dropped, standard for
TPU MoE). Dispatch/combine are one-hot einsums so that, with the expert axis
sharded over `model` (EP), XLA emits all-to-all on the group<->expert
resharding boundary — the paper's MoE cost behaviour (§3.3, §5.2) then shows
up directly in the roofline's collective term.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.layers import dense_init
from repro.parallel.sharding import constrain
from repro.quant import linear


def init_moe(key, d: int, cfg: MoEConfig, mlp_kind: str, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 5)
    E, ff = cfg.num_experts, cfg.expert_ff
    std = 0.02
    def w(k, shape):
        return (jax.random.normal(k, shape, jnp.float32) * std).astype(dtype)
    p = {
        "router": w(ks[0], (d, E)).astype(jnp.float32),
        "experts_up": w(ks[1], (E, d, ff)),
        "experts_down": w(ks[2], (E, ff, d)),
    }
    if mlp_kind == "swiglu":
        p["experts_gate"] = w(ks[3], (E, d, ff))
    if cfg.shared_expert_ff:
        from repro.models.layers import init_mlp
        p["shared"] = init_mlp(ks[4], d, cfg.shared_expert_ff, mlp_kind, dtype)
    return p


def _w(wp, dtype):
    """Materialize a (possibly quantized) expert weight for the einsum path."""
    if isinstance(wp, dict):
        q, s = wp["q"], wp["scale"]
        if s is None:
            return q.astype(dtype)
        return (q.astype(jnp.bfloat16) * s.astype(jnp.bfloat16)).astype(dtype)
    return wp


def _activate(h_up, h_gate, kind: str):
    if kind == "swiglu":
        return jax.nn.silu(h_gate) * h_up
    if kind == "relu2":
        return jnp.square(jax.nn.relu(h_up))
    return jax.nn.gelu(h_up, approximate=True)


def apply_moe(p, x: jnp.ndarray, cfg: MoEConfig, mlp_kind: str,
              qcfg=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar).

    Dispatch pipeline: group tokens -> route top-k -> positional cumsum for
    capacity -> one-hot dispatch einsum -> expert MLPs (batched over E) ->
    combine einsum weighted by gate probs.
    """
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.top_k
    T = B * S
    Sg = min(cfg.group_size, T)
    G = T // Sg
    assert G * Sg == T, f"group_size {Sg} must divide tokens {T}"
    cap = max(K, int(math.ceil(Sg * K / E * cfg.capacity_factor)))

    xg = x.reshape(G, Sg, d)
    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                    # (G,Sg,E)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)              # (G,Sg,K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # Load-balancing aux loss (Switch-style): E * mean(frac_tokens * mean_prob)
    frac = jnp.mean(
        jax.nn.one_hot(gate_idx[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    aux = E * jnp.sum(frac * jnp.mean(probs, axis=(0, 1)))

    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)    # (G,Sg,K,E)
    # position of each (token, k) assignment within its expert's queue
    flat = onehot.reshape(G, Sg * K, E)
    pos = jnp.cumsum(flat, axis=1) - 1.0                       # (G,Sg*K,E)
    pos = pos.reshape(G, Sg, K, E)
    keep = (pos < cap) & (onehot > 0)
    pos_c = jnp.clip(pos, 0, cap - 1).astype(jnp.int32)
    cap_oh = jax.nn.one_hot(pos_c, cap, dtype=jnp.float32)     # (G,Sg,K,E,C)
    dispatch = jnp.where(keep[..., None], cap_oh, 0.0)         # (G,Sg,K,E,C)
    combine = dispatch * gate_vals[..., None, None]
    dispatch_t = jnp.sum(dispatch, axis=2)                     # (G,Sg,E,C)
    combine_t = jnp.sum(combine, axis=2)

    cdt = x.dtype
    expert_in = jnp.einsum("gsec,gsd->egcd", dispatch_t.astype(cdt), xg)
    # e over EP where divisible AND g keeps the batch axes: when the expert
    # count doesn't divide the model axis (mixtral: 8 on 16) the e-spec
    # drops but g-sharding prevents GSPMD replicating a multi-GB tensor
    # (observed: 5.7 TB/step of all-reduce before this constraint carried
    # the batch dim — §Perf log, mixtral train baseline-fix)
    expert_in = constrain(expert_in, "experts", "batch", None, None)

    up = jnp.einsum("egcd,edf->egcf", expert_in, _w(p["experts_up"], cdt))
    gatep = p.get("experts_gate")
    gate_h = (jnp.einsum("egcd,edf->egcf", expert_in, _w(gatep, cdt))
              if gatep is not None else None)
    h = _activate(up, gate_h, mlp_kind)
    out_e = jnp.einsum("egcf,efd->egcd", h, _w(p["experts_down"], cdt))
    out_e = constrain(out_e, "experts", "batch", None, None)

    out = jnp.einsum("gsec,egcd->gsd", combine_t.astype(cdt), out_e)
    out = out.reshape(B, S, d)
    if "shared" in p:
        from repro.models.layers import apply_mlp
        out = out + apply_mlp(p["shared"], x, mlp_kind, qcfg)
    return out, aux
