"""GQA/MQA attention with RoPE / M-RoPE, train & decode paths.

Layouts:
  q:        (B, S, Hq, D)
  k, v:     (B, S, Hkv, D)
  cache:    (B, S_max, Hkv, D) contiguous per layer (dry-run serve path);
            the serving engine uses the paged pool in repro/serving/kv_cache.py
            with the Pallas paged-attention kernel.

The train/prefill path dispatches to the Pallas flash-attention kernel on TPU
and to the fused-jnp reference elsewhere (see kernels/flash_attention/ops.py).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.quant import linear

# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray, theta: float,
                sections=(2, 1, 1)) -> jnp.ndarray:
    """Qwen2-VL M-RoPE: head_dim/2 rotary channels split into (t, h, w)
    sections (ratio 2:1:1); positions3: (3, B, S)."""
    d = x.shape[-1]
    half = d // 2
    tot = sum(sections)
    bounds = []
    acc = 0
    for s in sections:
        acc += (half * s) // tot
        bounds.append(acc)
    bounds[-1] = half
    freqs = rope_freqs(d, theta)                       # (half,)
    # Select per-channel position source by section.
    chan = jnp.arange(half)
    sec_id = jnp.digitize(chan, jnp.array(bounds[:-1]))  # 0/1/2 per channel
    pos = jnp.take(positions3, sec_id, axis=0)         # (half, B, S) via axis trick
    pos = jnp.moveaxis(pos, 0, -1).astype(jnp.float32)  # (B, S, half)
    ang = pos * freqs
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def positions_for(rope_kind: str, batch: int, seq: int):
    base = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None], (batch, seq))
    if rope_kind == "mrope":
        return jnp.broadcast_to(base[None], (3, batch, seq))
    return base


def rotate(rope_kind: str, x, positions, theta):
    if rope_kind == "rope":
        return apply_rope(x, positions, theta)
    if rope_kind == "mrope":
        return apply_mrope(x, positions, theta)
    return x


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_attention(key, d_model: int, n_q: int, n_kv: int, head_dim: int,
                   dtype=jnp.bfloat16):
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d_model, n_q * head_dim, dtype),
        "wk": dense_init(ks[1], d_model, n_kv * head_dim, dtype),
        "wv": dense_init(ks[2], d_model, n_kv * head_dim, dtype),
        "wo": dense_init(ks[3], n_q * head_dim, d_model, dtype),
    }


def qkv(p, x, n_q: int, n_kv: int, head_dim: int, qcfg=None):
    B, S, _ = x.shape
    q = linear(x, p["wq"], qcfg).reshape(B, S, n_q, head_dim)
    k = linear(x, p["wk"], qcfg).reshape(B, S, n_kv, head_dim)
    v = linear(x, p["wv"], qcfg).reshape(B, S, n_kv, head_dim)
    return q, k, v


# ---------------------------------------------------------------------------
# Core attention math (GQA-aware)
# ---------------------------------------------------------------------------

def _gqa_scores(q, k):
    """q: (B,Sq,Hq,D), k: (B,Sk,Hkv,D) -> scores (B,Hkv,G,Sq,Sk)."""
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D)
    return jnp.einsum("bqhgd,bkhd->bhgqk", qg, k) / jnp.sqrt(D).astype(q.dtype)


def causal_attention(q, k, v, *, q_offset: int = 0,
                     kv_len: Optional[jnp.ndarray] = None):
    """Full (training/prefill) causal attention, fp32 softmax.

    q_offset: absolute position of q[0] (for chunked prefill).
    kv_len:   optional (B,) valid KV lengths (padding mask).
    """
    B, Sq, Hq, D = q.shape
    Sk = k.shape[1]
    s = _gqa_scores(q, k).astype(jnp.float32)          # (B,Hkv,G,Sq,Sk)
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(Sk)
    mask = kpos[None, :] <= qpos[:, None]              # (Sq, Sk)
    if kv_len is not None:
        mask = mask[None] & (kpos[None, None, :] < kv_len[:, None, None])
        mask = mask[:, None, None]                      # (B,1,1,Sq,Sk)
    else:
        mask = mask[None, None, None]
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)
    return o.reshape(B, Sq, Hq, D)


CHUNKED_THRESHOLD = 2048    # use online-softmax blocks above this seq len


def causal_attention_chunked(q, k, v, *, block_q: int = 1024,
                             block_k: int = 1024):
    """Flash-style causal attention in pure JAX: nested scans over q/kv
    blocks with online softmax. Working set drops from O(S^2) to
    O(block_q*block_k) — the dry-run-honest stand-in for the Pallas
    flash_attention kernel that runs on real TPUs (same tiling).
    """
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0
    nq, nk = S // block_q, S // block_k
    scale = 1.0 / (D ** 0.5)

    qb = jnp.moveaxis(q.reshape(B, nq, block_q, Hkv, G, D), 1, 0)
    kb = jnp.moveaxis(k.reshape(B, nk, block_k, Hkv, D), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nk, block_k, Hkv, D), 1, 0)

    def outer(_, qx):
        qi, qblk = qx                                   # (B,bq,Hkv,G,D)
        rows = qi * block_q + jnp.arange(block_q)

        def inner(st, kx):
            m, l, acc = st
            ki, kblk, vblk = kx
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, kblk) * scale
            s = s.astype(jnp.float32)                   # (B,Hkv,G,bq,bk)
            cols = ki * block_k + jnp.arange(block_k)
            s = jnp.where(cols[None, :] <= rows[:, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
            p = jnp.exp(s - m_new)
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
            acc = acc * alpha + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(vblk.dtype), vblk
            ).astype(jnp.float32)
            return (m_new, l, acc), None

        m0 = jnp.full((B, Hkv, G, block_q, 1), -1e30, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, block_q, 1), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, block_q, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            inner, (m0, l0, a0), (jnp.arange(nk), kb, vb))
        out = (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)
        return None, out                                # (B,Hkv,G,bq,D)

    _, outs = jax.lax.scan(outer, None, (jnp.arange(nq), qb))
    # (nq,B,Hkv,G,bq,D) -> (B,S,Hq,D)
    outs = jnp.moveaxis(outs, 0, 1)                     # (B,nq,Hkv,G,bq,D)
    outs = outs.transpose(0, 1, 4, 2, 3, 5).reshape(B, S, Hq, D)
    return outs


def bidirectional_attention(q, k, v):
    B, Sq, Hq, D = q.shape
    s = _gqa_scores(q, k).astype(jnp.float32)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)
    return o.reshape(B, Sq, Hq, D)


def decode_attention(q, k_cache, v_cache, cache_len):
    """Single-token decode: q (B,1,Hq,D) vs cache (B,Hkv,S_max,D).

    Cache layout is heads-major so the sharding resolver tries head-TP before
    sequence-TP (see parallel/sharding.py). cache_len: (B,) valid lengths
    (the new token is already written).
    """
    B, _, Hq, D = q.shape
    Hkv, S = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, 1, Hkv, G, D)
    s = jnp.einsum("bqhgd,bhkd->bhgqk", qg, k_cache) / jnp.sqrt(D).astype(q.dtype)
    s = s.astype(jnp.float32)                          # (B,Hkv,G,1,S)
    kpos = jnp.arange(S)
    mask = kpos[None, :] < cache_len[:, None]          # (B,S)
    s = jnp.where(mask[:, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bhgqk,bhkd->bqhgd", p, v_cache)
    return o.reshape(B, 1, Hq, D)


def decode_attention_partial(q, k_cache, v_cache, valid_mask):
    """Flash-decoding partial softmax for sequence-sharded KV caches.

    q (B,1,Hq,D); k_cache/v_cache (B,Hkv,S_shard,D); valid_mask (B,S_shard).
    Returns (numerator (B,1,Hq,D) fp32, denominator (B,1,Hq,1) fp32,
    running max (B,1,Hq,1) fp32) to be combined across shards with
    repro.parallel.collectives.combine_partial_softmax.
    """
    B, _, Hq, D = q.shape
    Hkv = k_cache.shape[1]
    G = Hq // Hkv
    qg = q.reshape(B, 1, Hkv, G, D)
    s = jnp.einsum("bqhgd,bhkd->bhgqk", qg, k_cache) / jnp.sqrt(D).astype(q.dtype)
    s = s.astype(jnp.float32)                          # (B,Hkv,G,1,S)
    s = jnp.where(valid_mask[:, None, None, None, :], s, -jnp.inf)
    m = jnp.max(s, axis=-1, keepdims=True)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    e = jnp.exp(s - m_safe) * jnp.isfinite(s)
    num = jnp.einsum("bhgqk,bhkd->bqhgd",
                     e.astype(q.dtype), v_cache)       # (B,1,Hkv,G,D)
    denom = jnp.sum(e, axis=-1, keepdims=True)         # (B,Hkv,G,1,1)
    num = num.astype(jnp.float32).reshape(B, 1, Hq, D)
    denom = denom.transpose(0, 3, 1, 2, 4).reshape(B, 1, Hq, 1)
    m_out = m_safe.transpose(0, 3, 1, 2, 4).reshape(B, 1, Hq, 1)
    m_out = jnp.where(denom > 0, m_out, -jnp.inf)
    return num, denom, m_out


def update_cache(k_cache, v_cache, k_new, v_new, index):
    """Write one decode step into (B,Hkv,S,D) caches at per-batch `index`."""
    B = k_new.shape[0]
    bidx = jnp.arange(B)
    k_cache = k_cache.at[bidx, :, index].set(k_new[:, 0])
    v_cache = v_cache.at[bidx, :, index].set(v_new[:, 0])
    return k_cache, v_cache


def fill_cache(k_cache, v_cache, k, v):
    """Write a full prefill (B,S,Hkv,D) into (B,Hkv,S_max,D) caches."""
    S = k.shape[1]
    k_cache = k_cache.at[:, :, :S].set(k.transpose(0, 2, 1, 3))
    v_cache = v_cache.at[:, :, :S].set(v.transpose(0, 2, 1, 3))
    return k_cache, v_cache


def init_cross_attention(key, d_model: int, n_q: int, n_kv: int, head_dim: int,
                         dtype=jnp.bfloat16):
    return init_attention(key, d_model, n_q, n_kv, head_dim, dtype)


def cross_attention(p, x, enc_k, enc_v, n_q, n_kv, head_dim, qcfg=None):
    """x: (B,Sq,d); enc_k/enc_v precomputed (B,Se,Hkv,D)."""
    B, Sq, _ = x.shape
    q = linear(x, p["wq"], qcfg).reshape(B, Sq, n_q, head_dim)
    o = bidirectional_attention(q, enc_k, enc_v)
    return linear(o.reshape(B, Sq, n_q * head_dim), p["wo"], qcfg)
