"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) and sLSTM (scalar
memory), each with stabilized exponential gating, train scan + decode step.

mLSTM state: C (B, H, Dk, Dv) matrix memory, n (B, H, Dk) normalizer,
             m (B, H) gate stabilizer.
sLSTM state: c, n (B, di) scalar cells, m (B, di) stabilizer,
             h (B, di) recurrent output.

All states fp32; context-length-independent (the reason xlstm-350m runs the
long_500k cell).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import XLSTMConfig
from repro.models.layers import dense_init, init_norm, apply_norm
from repro.quant import linear

# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm(key, d: int, n_heads: int, cfg: XLSTMConfig, dtype=jnp.bfloat16):
    di = int(cfg.mlstm_proj_factor * d)
    ks = jax.random.split(key, 8)
    return {
        "mlstm_up": dense_init(ks[0], d, 2 * di, dtype),
        "mlstm_q": dense_init(ks[1], di, di, dtype),
        "mlstm_k": dense_init(ks[2], di, di, dtype),
        "mlstm_v": dense_init(ks[3], di, di, dtype),
        "mlstm_if": dense_init(ks[4], di, 2 * n_heads, dtype),  # i,f gates
        "mlstm_norm": init_norm("rmsnorm", di, dtype),
        "mlstm_down": dense_init(ks[6], di, d, dtype),
    }


def _mlstm_gates(p, u, n_heads, qcfg):
    gf = linear(u, p["mlstm_if"], qcfg).astype(jnp.float32)     # (B,S,2H)
    logi, logf = jnp.split(gf, 2, axis=-1)
    return logi, jax.nn.log_sigmoid(logf)                       # log i~, log f


def mlstm_seq(p, x, n_heads: int, cfg: XLSTMConfig, state=None, qcfg=None):
    """x: (B,S,d). Returns (y (B,S,d), new_state)."""
    B, S, d = x.shape
    u2 = linear(x, p["mlstm_up"], qcfg)
    u, z = jnp.split(u2, 2, axis=-1)                            # (B,S,di)
    di = u.shape[-1]
    dh = di // n_heads
    q = linear(u, p["mlstm_q"], qcfg).reshape(B, S, n_heads, dh)
    k = linear(u, p["mlstm_k"], qcfg).reshape(B, S, n_heads, dh) / jnp.sqrt(dh)
    v = linear(u, p["mlstm_v"], qcfg).reshape(B, S, n_heads, dh)
    logi, logf = _mlstm_gates(p, u, n_heads, qcfg)              # (B,S,H)

    if state is None:
        C0 = jnp.zeros((B, n_heads, dh, dh), jnp.float32)
        n0 = jnp.zeros((B, n_heads, dh), jnp.float32)
        m0 = jnp.full((B, n_heads), -jnp.inf, jnp.float32)
    else:
        C0, n0, m0 = state["C"], state["n"], state["m"]

    def step(carry, xs):
        C, n, m = carry
        q_t, k_t, v_t, li, lf = xs                              # (B,H,dh)...
        m_new = jnp.maximum(lf + m, li)                         # (B,H)
        i_g = jnp.exp(li - m_new)
        f_g = jnp.exp(lf + m - m_new)
        kf, vf = k_t.astype(jnp.float32), v_t.astype(jnp.float32)
        C = f_g[..., None, None] * C + i_g[..., None, None] * (
            kf[..., :, None] * vf[..., None, :])                # (B,H,dk,dv)
        n = f_g[..., None] * n + i_g[..., None] * kf
        qf = q_t.astype(jnp.float32)
        num = jnp.einsum("bhkv,bhk->bhv", C, qf)
        den = jnp.abs(jnp.einsum("bhk,bhk->bh", n, qf))
        den = jnp.maximum(den, jnp.exp(-m_new))                 # paper's max(|nq|, e^-m)
        h = num / den[..., None]
        return (C, n, m_new), h

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in
               (q, k, v, logi.reshape(B, S, n_heads),
                logf.reshape(B, S, n_heads)))
    (C, n, m), hs = jax.lax.scan(step, (C0, n0, m0), xs)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, di).astype(x.dtype)
    h = apply_norm(p["mlstm_norm"], h, "rmsnorm")
    y = linear(h * jax.nn.silu(z), p["mlstm_down"], qcfg)
    return y, {"C": C, "n": n, "m": m}


def init_mlstm_state(batch: int, d: int, n_heads: int, cfg: XLSTMConfig):
    di = int(cfg.mlstm_proj_factor * d)
    dh = di // n_heads
    return {"C": jnp.zeros((batch, n_heads, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, n_heads, dh), jnp.float32),
            "m": jnp.full((batch, n_heads), -jnp.inf, jnp.float32)}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm(key, d: int, cfg: XLSTMConfig, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 4)
    ff = int(cfg.slstm_proj_factor * d)
    return {
        "slstm_wx": dense_init(ks[0], d, 4 * d, dtype),    # i,f,z,o from input
        "slstm_wr": dense_init(ks[1], d, 4 * d, dtype),    # recurrent
        "slstm_up": dense_init(ks[2], d, ff, dtype),
        "slstm_down": dense_init(ks[3], ff, d, dtype),
    }


def slstm_seq(p, x, cfg: XLSTMConfig, state=None, qcfg=None):
    """x: (B,S,d) -> (y (B,S,d), state)."""
    B, S, d = x.shape
    wx = linear(x, p["slstm_wx"], qcfg).astype(jnp.float32)     # (B,S,4d)

    if state is None:
        c0 = jnp.zeros((B, d), jnp.float32)
        n0 = jnp.ones((B, d), jnp.float32)
        m0 = jnp.zeros((B, d), jnp.float32)
        h0 = jnp.zeros((B, d), jnp.float32)
    else:
        c0, n0, m0, h0 = state["c"], state["n"], state["m"], state["h"]

    wr = p["slstm_wr"]
    if isinstance(wr, dict):
        q, s = wr["q"], wr["scale"]
        wr = q if s is None else (q.astype(jnp.bfloat16) * s.astype(jnp.bfloat16))
    wrf = wr.astype(jnp.float32)

    def step(carry, wx_t):
        c, n, m, h = carry
        g = wx_t + h @ wrf                                      # (B,4d)
        gi, gf, gz, go = jnp.split(g, 4, axis=-1)
        m_new = jnp.maximum(gf + m, gi)                         # log-space f
        i_g = jnp.exp(gi - m_new)
        f_g = jnp.exp(gf + m - m_new)
        c = f_g * c + i_g * jnp.tanh(gz)
        n = f_g * n + i_g
        h = jax.nn.sigmoid(go) * c / jnp.maximum(n, 1e-6)
        return (c, n, m_new, h), h

    (c, n, m, h), hs = jax.lax.scan(step, (c0, n0, m0, h0),
                                    jnp.moveaxis(wx, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)                  # (B,S,d)
    ff = jax.nn.gelu(linear(y, p["slstm_up"], qcfg), approximate=True)
    out = linear(ff, p["slstm_down"], qcfg)
    return out, {"c": c, "n": n, "m": m, "h": h}


def init_slstm_state(batch: int, d: int):
    return {"c": jnp.zeros((batch, d), jnp.float32),
            "n": jnp.ones((batch, d), jnp.float32),
            "m": jnp.zeros((batch, d), jnp.float32),
            "h": jnp.zeros((batch, d), jnp.float32)}
