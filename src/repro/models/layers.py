"""Shared neural-net building blocks (pure functional, explicit param pytrees).

Params are nested dicts of jnp arrays. Every `init_*` takes a PRNGKey and
returns a pytree; every `apply_*` is pure. Matmuls route through
`repro.quant.linear` so the Q axis (bf16 / int8 / fp8) applies uniformly.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.quant import linear

INIT_STD = 0.02


def dense_init(key, d_in: int, d_out: int, dtype=jnp.bfloat16):
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * INIT_STD).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.bfloat16):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * INIT_STD).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(kind: str, d: int, dtype=jnp.bfloat16):
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p, x, kind: str, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs: swiglu | relu2 (squared ReLU, Nemotron) | gelu
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, ff: int, kind: str, dtype=jnp.bfloat16):
    if ff == 0:
        return None
    ks = jax.random.split(key, 3)
    p = {"down": dense_init(ks[2], ff, d, dtype)}
    if kind == "swiglu":
        p["gate"] = dense_init(ks[0], d, ff, dtype)
        p["up"] = dense_init(ks[1], d, ff, dtype)
    else:
        p["up"] = dense_init(ks[0], d, ff, dtype)
    return p


def apply_mlp(p, x, kind: str, qcfg=None):
    if p is None:
        return jnp.zeros_like(x)
    if kind == "swiglu":
        h = jax.nn.silu(linear(x, p["gate"], qcfg)) * linear(x, p["up"], qcfg)
    elif kind == "relu2":
        h = jnp.square(jax.nn.relu(linear(x, p["up"], qcfg)))
    else:  # gelu
        h = jax.nn.gelu(linear(x, p["up"], qcfg), approximate=True)
    return linear(h, p["down"], qcfg)


def softcap(logits, cap: float):
    if not cap:
        return logits
    return cap * jnp.tanh(logits / cap)


def unembed(emb_or_head, x, qcfg=None, transpose: bool = False):
    """Project hidden states to vocab logits. `transpose` for tied embeddings."""
    w = emb_or_head.T if transpose else emb_or_head
    return linear(x, w, qcfg)


def cross_entropy_loss(logits, labels, mask: Optional[jnp.ndarray] = None):
    """Token-mean cross entropy in fp32."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
