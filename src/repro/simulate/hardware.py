"""TPU hardware generations (the paper's H-axis, adapted per DESIGN §3).

The paper's cross-hardware pair (H100 NVL vs A100 PCIe) maps onto
v5p-class (pricier, faster, higher-bandwidth) vs v5e (cheaper, slower) —
same structure: the load-driven cost spread must reproduce with compressed
magnitude on the cheaper part. fp8 is native on the v6e-class entry only;
v5e runs fp8 through a dequant-emulation path (int8 is native everywhere),
reproducing the paper's hardware-conditional quantization caveat. The
`paper_crosshw` experiment plan (ISSUE 3) spans all three generations in
one store, and `experiments.analyze.fp8_inversion` conditions the uplift
table on `native_fp8` — the dense inversion must vanish on v6e.

Prices are public on-demand list prices (us-central, mid-2026 era); the
framework treats them as a replaceable price book, exactly as the paper
treats Azure rates ("the framework's value is in the methodology, not
specific dollar amounts", §6.9).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HardwareGen:
    name: str
    peak_flops_bf16: float      # FLOP/s per chip
    peak_flops_int8: float
    hbm_bw: float               # bytes/s per chip
    ici_bw: float               # bytes/s per link (one direction)
    hbm_bytes: float            # per chip
    price_per_chip_hr: float    # $/chip-hour on-demand
    native_fp8: bool
    native_int8: bool = True

    def peak(self, quant: str) -> float:
        if quant == "int8" and self.native_int8:
            return self.peak_flops_int8
        if quant == "fp8" and self.native_fp8:
            return self.peak_flops_int8          # fp8 rides the 2x MXU path
        return self.peak_flops_bf16


V5E = HardwareGen("tpu-v5e", 197e12, 394e12, 819e9, 50e9, 16e9, 1.20,
                  native_fp8=False)
V5P = HardwareGen("tpu-v5p", 459e12, 918e12, 2765e9, 100e9, 95e9, 4.20,
                  native_fp8=False)
V6E = HardwareGen("tpu-v6e", 918e12, 1836e12, 1640e9, 100e9, 32e9, 2.70,
                  native_fp8=True)

HW_BY_NAME = {h.name: h for h in (V5E, V5P, V6E)}

# Pseudo-hardware entry for the CPU real-execution tier: throughput is
# measured, only the price matters for C_eff shape validation.
CPU_NODE = HardwareGen("cpu-node", 1e12, 1e12, 5e10, 1e9, 64e9, 1.00,
                       native_fp8=False, native_int8=False)
HW_BY_NAME["cpu-node"] = CPU_NODE
