"""TPU-scale simulation tier: hardware book + calibrated step-time model."""
from repro.simulate.hardware import (  # noqa: F401
    HW_BY_NAME, HardwareGen, V5E, V5P, V6E)
from repro.simulate.step_time import StepTimeModel  # noqa: F401
