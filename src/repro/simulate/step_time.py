"""Analytic TPU step-time model for the virtual-clock serving tier.

Three-term roofline per engine step (compute / HBM / ICI-collective), the
same decomposition as launch/roofline.py — the simulator is the dry-run
roofline turned into a clock. Calibration knobs (mfu, mbu, fixed overhead)
default to conservative public MaxText-era numbers and can be overridden
from measured dry-run terms via `from_roofline`.

Quantization semantics (paper §5.3 / §5.9 Result 2, TPU-adapted):
  int8          — native MXU path: 2x peak, 0.5x weight bytes.
  fp8 native    — v6e-class: 2x peak, 0.5x weight bytes.
  fp8 emulated  — v5e: 0.5x weight bytes (the HBM win survives) but the
                  matmul runs at bf16 peak with a dequant-overhead factor —
                  compute-bound dense models can INVERT, exactly the
                  paper's A100 finding.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.configs.base import ModelConfig
from repro.simulate.hardware import HardwareGen

DEQUANT_OVERHEAD = 1.18      # fp8-emulation compute penalty (v5e path)


@dataclasses.dataclass
class StepTimeModel:
    cfg: ModelConfig
    hw: HardwareGen
    n_chips: int = 1             # TP degree (model sharded over ICI)
    quant: str = "bf16"          # bf16 | int8 | fp8
    mfu: float = 0.55            # prefill compute efficiency (fat GEMMs)
    mfu_decode: float = 0.28     # decode GEMMs are skinny (M = batch):
    #                              MXU utilization is structurally low —
    #                              the mechanism behind the paper's
    #                              active-params-dominate finding (§5.2).
    #                              Calibrated so dense-vs-ultra-sparse
    #                              saturation ordering matches §5.2-5.3:
    #                              dense wins bf16, sparse wins quantized.
    lowprec_decode_discount: float = 0.31  # skinny GEMMs capture ~1.31x of
    #                              the 2x low-precision MXU peak (the
    #                              paper's dense +31% fp8 gain)
    mbu: float = 0.75            # HBM bandwidth utilization
    fixed_overhead: float = 0.004   # s/step: dispatch + host + sampling
    moe_dispatch_overhead: float = 1.5e-6  # s per routed token

    # ---- derived ---------------------------------------------------------
    def __post_init__(self):
        # ModelConfig is frozen, so cfg-derived constants cannot go stale;
        # caching them here keeps the virtual-clock hot path (one
        # decode_time* call per scheduling event) free of the analytic
        # parameter walk. dataclasses.replace() re-runs this.
        self._total_params = self.cfg.param_count()
        self._active_params = self.cfg.active_param_count()
        self._kv_bytes_tok = self.cfg.kv_bytes_per_token()
        self._n_attn = sum(1 for k in self.cfg.block_pattern()
                           if k == "attn")

    @property
    def weight_bytes(self) -> float:
        per = 1 if self.quant in ("int8", "fp8") else 2
        return self._total_params * per

    @property
    def active_weight_bytes(self) -> float:
        per = 1 if self.quant in ("int8", "fp8") else 2
        return self._active_params * per

    @property
    def _peak(self) -> float:
        p = self.hw.peak(self.quant)
        if self.quant == "fp8" and not self.hw.native_fp8:
            p = self.hw.peak_flops_bf16 / DEQUANT_OVERHEAD
        return p

    def _collective_time(self, tokens: float) -> float:
        """Per-step TP all-reduce cost: 2 collectives/layer over d_model."""
        if self.n_chips <= 1:
            return 0.0
        bytes_ar = (2 * self.cfg.num_layers * tokens * self.cfg.d_model * 2
                    * 2 * (self.n_chips - 1) / self.n_chips)
        return bytes_ar / (self.n_chips * self.hw.ici_bw)

    @property
    def _peak_decode(self) -> float:
        base = self.hw.peak_flops_bf16
        if self.quant == "fp8" and not self.hw.native_fp8:
            return base / DEQUANT_OVERHEAD          # emulation penalty
        if self.quant in ("int8", "fp8"):
            return base * (1.0 + self.lowprec_decode_discount)
        return base

    # ---- decode ------------------------------------------------------------
    def _decode_terms(self, batch: int):
        """Shared per-step decode roofline terms at batch size `batch`:
        (compute_s, mem_base_s, mem_slope_s_per_ctx_token, const_s).
        Step time at context c is ``max(compute, mem_base + slope*c) +
        const``. Single source of truth for decode_time AND
        decode_time_multi — the fast-forward clock jump must never drift
        from the per-step reference, so any new roofline term belongs
        here, not in either caller. A third consumer mirrors this method
        op-for-op in vectorized numpy: `serving.fleet.FleetStepModel`
        (the multi-cell fleet backend, ISSUE 4) must stay bit-identical,
        and `tests/test_fleet.py` asserts exact equality — edit both
        together."""
        flops = 2.0 * self._active_params * batch
        compute = flops / (self.n_chips * self._peak_decode *
                           self.mfu_decode)
        bw = self.n_chips * self.hw.hbm_bw * self.mbu
        # dense weights + the touched expert subset stream once per step;
        # with large batches an MoE touches ~all experts, so interpolate
        touched = min(1.0, max(self.active_weight_bytes / self.weight_bytes,
                               batch * (self.cfg.moe.top_k /
                                        self.cfg.moe.num_experts)
                               if self.cfg.moe else 1.0))
        mem_base = self.weight_bytes * touched / bw
        mem_slope = batch * self._kv_bytes_tok / bw
        const = (self._collective_time(batch) +
                 (self.moe_dispatch_overhead * batch
                  if self.cfg.moe is not None else 0.0) +
                 self.fixed_overhead)
        return compute, mem_base, mem_slope, const

    def decode_time(self, batch: int, mean_ctx: float) -> float:
        """One decode step for `batch` in-flight sequences."""
        if batch == 0:
            return self.fixed_overhead
        compute, mem_base, mem_slope, const = self._decode_terms(batch)
        return max(compute, mem_base + mem_slope * mean_ctx) + const

    def decode_time_multi(self, batch: int, ctx0: float, k: int) -> float:
        """Closed-form sum of `k` consecutive decode steps.

        Between scheduling events the batch is frozen and every context
        grows by one token per step, so step i costs
        ``max(compute, mem0 + i*slope) + const`` with a single
        compute->memory crossover along the way — the k-step total
        collapses to one arithmetic series. This is the O(1) clock jump
        behind the engine's event-driven fast-forward path; both paths
        read the same `_decode_terms`, so the sum stays numerically
        equivalent (to float rounding) to summing
        ``decode_time(batch, ctx0 + i)`` for i in range(k).
        """
        if k <= 0:
            return 0.0
        if k == 1 or batch == 0:
            return k * self.decode_time(batch, ctx0)
        compute, mem_base, slope, const = self._decode_terms(batch)
        mem0 = mem_base + slope * ctx0
        if slope <= 0.0:
            return k * (max(compute, mem0) + const)
        # steps with memory below the compute roofline: i < (C - mem0)/slope
        m = min(max(int(math.ceil((compute - mem0) / slope)), 0), k)
        series = (k - m) * mem0 + slope * (m + k - 1) * (k - m) / 2.0
        return m * compute + series + k * const

    # ---- prefill -----------------------------------------------------------
    def prefill_time(self, n_tokens: int, n_reqs: int) -> float:
        if n_tokens == 0:
            return 0.0
        mean_len = n_tokens / max(n_reqs, 1)
        flops = 2.0 * self._active_params * n_tokens
        # quadratic attention term
        flops += (2 * 2 * self._n_attn * self.cfg.num_heads *
                  self.cfg.resolved_head_dim * n_tokens * mean_len)
        compute = flops / (self.n_chips * self._peak * self.mfu)
        mem_bytes = self.weight_bytes + \
            2 * n_tokens * self.cfg.d_model * 2 * self.cfg.num_layers
        memory = mem_bytes / (self.n_chips * self.hw.hbm_bw * self.mbu)
        coll = self._collective_time(n_tokens)
        moe_oh = (self.moe_dispatch_overhead * n_tokens
                  if self.cfg.moe is not None else 0.0)
        return max(compute, memory) + coll + moe_oh + self.fixed_overhead

    # ---- calibration -------------------------------------------------------
    @classmethod
    def from_roofline(cls, cfg: ModelConfig, hw: HardwareGen, terms: dict,
                      **kw) -> "StepTimeModel":
        """Override mfu/mbu from measured dry-run roofline terms: `terms`
        holds {"model_flops_ratio": useful/compiled} — compiled-graph waste
        directly discounts the achievable MFU."""
        ratio = float(terms.get("model_flops_ratio", 1.0))
        kw.setdefault("mfu", max(0.2, min(0.85, 0.62 * ratio)))
        return cls(cfg=cfg, hw=hw, **kw)

    def saturation_tps(self, mean_ctx: float = 640.0,
                       max_batch: int = 512) -> float:
        """Model-implied peak decode throughput (tokens/s)."""
        best = 0.0
        b = 1
        while b <= max_batch:
            tps = b / self.decode_time(b, mean_ctx)
            best = max(best, tps)
            b *= 2
        return best
