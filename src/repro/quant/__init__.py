"""Quantization substrate — the paper's Q axis, adapted to TPU.

The paper sweeps Q in {FP16, FP8} on H100/A100 and finds the FP8 win is
hardware-conditional (native tensor cores vs. emulation). The TPU analogue:

  * bf16  — baseline on every TPU generation.
  * int8  — native MXU path on v5e/v5p/v6e (2x peak FLOP/s, 2x weight bw).
  * fp8   — e4m3; native on v6e-class silicon, *emulated* on v5e (dequant to
            bf16 before the matmul -> bandwidth win but extra convert cost).

`QuantConfig` routes every matmul in the model zoo. `quantize_tree` converts a
bf16 param pytree into quantized storage (per-output-channel scales).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

VALID_MODES = ("bf16", "int8", "fp8")


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    mode: str = "bf16"            # bf16 | int8 | fp8
    native: bool = True           # does the target silicon have native support?
    act_quant: bool = True        # quantize activations too (int8 path)

    def __post_init__(self):
        assert self.mode in VALID_MODES, self.mode

    @property
    def weight_bytes(self) -> int:
        return 2 if self.mode == "bf16" else 1


BF16 = QuantConfig("bf16")
INT8 = QuantConfig("int8", native=True)
FP8_EMULATED = QuantConfig("fp8", native=False)   # v5e: no native fp8 matmul
FP8_NATIVE = QuantConfig("fp8", native=True)      # v6e-class

BY_NAME = {"bf16": BF16, "int8": INT8, "fp8": FP8_EMULATED,
           "fp8_native": FP8_NATIVE}


def _per_channel_scale(w: jnp.ndarray, qmax: float) -> jnp.ndarray:
    # Reduce over the contraction axis (-2) so stacked (layers, d_in, d_out)
    # weights quantize per layer per output channel.
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=-2, keepdims=True)
    return jnp.maximum(amax, 1e-8) / qmax


def quantize_weight(w: jnp.ndarray, mode: str):
    """-> dict(q=storage array, scale=(1, d_out) fp32). bf16 passes through."""
    if mode == "bf16":
        return {"q": w, "scale": None}
    if mode == "int8":
        scale = _per_channel_scale(w, 127.0)
        q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
        return {"q": q, "scale": scale}
    # fp8 e4m3: max normal 448
    scale = _per_channel_scale(w, 448.0)
    q = (w.astype(jnp.float32) / scale).astype(jnp.float8_e4m3fn)
    return {"q": q, "scale": scale}


def linear(x: jnp.ndarray, w, qcfg: Optional[QuantConfig] = None) -> jnp.ndarray:
    """x @ w with the configured quantization. `w` is either a raw array
    (bf16 path) or a quantize_weight() dict."""
    if isinstance(w, dict):
        q, scale = w["q"], w["scale"]
    else:
        q, scale = w, None
    if qcfg is None or qcfg.mode == "bf16" or scale is None:
        return jax.lax.dot_general(
            x, q.astype(x.dtype),
            (((x.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32).astype(x.dtype)

    if qcfg.mode == "int8" and qcfg.native:
        # Dynamic per-tensor activation quantization -> int8 x int8 -> int32.
        xf = x.astype(jnp.float32)
        xamax = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-8)
        xs = xamax / 127.0
        xq = jnp.clip(jnp.round(xf / xs), -127, 127).astype(jnp.int8)
        acc = jax.lax.dot_general(
            xq, q, (((x.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        return (acc.astype(jnp.float32) * (xs * scale)).astype(x.dtype)

    # fp8 (native or emulated) and non-native int8: dequantize the weight
    # stream and matmul in bf16. On real v6e silicon the native path would
    # issue fp8 dots; the emulated path matches v5e where fp8 weights only
    # buy HBM bandwidth. Roofline accounting distinguishes the two.
    wf = q.astype(jnp.bfloat16) * scale.astype(jnp.bfloat16)
    return jax.lax.dot_general(
        x, wf.astype(x.dtype),
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(x.dtype)


def quantize_tree(params, mode: str):
    """Quantize every 2D+ weight matrix in a param pytree (norms/embeddings
    and 1D vectors stay bf16). Returns a pytree where weights become dicts."""
    if mode == "bf16":
        return params

    # Leaves that are not consumed by `linear` (lookups, convs, SSM tensors).
    SKIP = {"embed", "pos_embed", "enc_pos_embed", "scale", "bias", "conv",
            "conv_w", "A_log", "D", "router", "dt_bias", "gates"}

    def visit(p, name=""):
        if isinstance(p, dict):
            return {k: visit(v, k) for k, v in p.items()}
        if isinstance(p, (list, tuple)):
            return type(p)(visit(v, name) for v in p)
        if (hasattr(p, "ndim") and p.ndim >= 2 and p.dtype == jnp.bfloat16
                and name not in SKIP):
            return quantize_weight(p, mode)
        return p

    return visit(params)
