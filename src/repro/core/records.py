"""Run-record schema + CSV corpus IO (the paper's per-run CSV artifact)."""
from __future__ import annotations

import csv
import dataclasses
import math
from pathlib import Path
from typing import Iterable, List, Optional


@dataclasses.dataclass
class RunRecord:
    config: str                 # e.g. "C1" or free-form
    model: str
    hw: str
    n_chips: int
    quant: str
    engine: str                 # real | sim
    lam: float                  # offered rate (req/s)
    io_shape: str
    n_requests: int
    n_completed: int
    window_s: float             # measurement window (completed-req stats)
    tps: float                  # aggregate output tokens/s
    prompt_tps: float
    ttft_p50_ms: float
    ttft_p90_ms: float
    ttft_p99_ms: float
    tpot_p50_ms: float
    tpot_p99_ms: float
    e2e_p50_ms: float
    e2e_p99_ms: float
    mean_inflight: float
    price_per_hr: float
    c_eff: float                # $/M output tokens
    theta_max: float = 0.0      # filled by sweep post-pass (saturation)
    seed: int = 0
    # resilience axis coordinates + outcome counters (ISSUE 6); all zero
    # when FailureSpec/RetryPolicy are off, so failure-free records carry
    # the same numbers as before the resilience layer existed.
    mttf: float = 0.0           # 0 = no injected failures
    retry_max: int = 0          # client retry budget (0 = no retries)
    n_shed: int = 0             # arrivals rejected over max_queue_depth
    n_timeout: int = 0          # queue-time deadline expiries
    n_retried: int = 0          # client re-submissions (amplification)
    n_abandoned: int = 0        # permanently given up (budget exhausted)
    # overload-survival counters (ISSUE 9); all zero without an
    # OverloadPolicy, so pre-9 records regenerate byte-identical.
    n_class_shed: int = 0       # of n_shed: refused by class (not depth cap)
    n_browned: int = 0          # admitted with a brownout-clamped budget
    browned_tokens: int = 0     # output tokens clipped by the clamp
    n_slo_viol: int = 0         # served requests whose TTFT broke the SLO
    interactive_tps: float = 0.0  # delivered interactive-class tokens/s
    #                               (0 unless the cell declares a class_mix)

    @property
    def penalty(self) -> float:
        if self.theta_max <= 0 or self.tps <= 0:
            return math.nan
        return self.theta_max / self.tps

    @property
    def util(self) -> float:
        if self.theta_max <= 0:
            return math.nan
        return self.tps / self.theta_max

    @property
    def goodput_rps(self) -> float:
        """Delivered request rate (completed / window)."""
        if self.window_s <= 0:
            return math.nan
        return self.n_completed / self.window_s

    @property
    def retry_amplification(self) -> float:
        """Submitted attempts per original request (>= 1.0)."""
        if self.n_requests <= 0:
            return math.nan
        return 1.0 + self.n_retried / self.n_requests


FIELDS = [f.name for f in dataclasses.fields(RunRecord)]


def write_csv(path, records: Iterable[RunRecord]):
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=FIELDS + ["penalty", "util"])
        w.writeheader()
        for r in records:
            row = dataclasses.asdict(r)
            row["penalty"] = r.penalty
            row["util"] = r.util
            w.writerow(row)


def read_csv(path) -> List[RunRecord]:
    out = []
    with open(path) as f:
        for row in csv.DictReader(f):
            row.pop("penalty", None)
            row.pop("util", None)
            kw = {}
            for fld in dataclasses.fields(RunRecord):
                v = row[fld.name]
                kw[fld.name] = (fld.type in ("int", int) and int(float(v))) \
                    or (fld.type in ("float", float) and float(v)) or v
                if fld.type in ("int", int):
                    kw[fld.name] = int(float(v))
                elif fld.type in ("float", float):
                    kw[fld.name] = float(v)
                else:
                    kw[fld.name] = v
            out.append(RunRecord(**kw))
    return out
