"""Price books: accelerator $/chip-hr and commercial API $/M-token tiers.

API list prices are the paper's own reference tiers (§6.3, accessed
2026-06-09): asymmetric input/output pricing is retained so the crossover
analysis can price blended workload shapes (§6.3's extension) as well as
the paper's headline output-token basis.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.simulate.hardware import HW_BY_NAME


@dataclasses.dataclass(frozen=True)
class APITier:
    name: str
    input_per_mtok: float
    output_per_mtok: float

    def blended(self, in_tokens: float, out_tokens: float) -> float:
        """$ per M *output* tokens for a workload shape, billing both sides
        at list price (paper §6.3 back-of-envelope convention)."""
        total = (in_tokens * self.input_per_mtok +
                 out_tokens * self.output_per_mtok)
        return total / out_tokens


# Paper §6.3 list prices.
API_TIERS: Dict[str, APITier] = {
    "gpt-5.5": APITier("gpt-5.5", 5.00, 30.00),
    "claude-sonnet-4.6": APITier("claude-sonnet-4.6", 3.00, 15.00),
    "gemini-3.1-pro": APITier("gemini-3.1-pro", 2.00, 12.00),
}


def chip_hour_price(hw_name: str, n_chips: int = 1) -> float:
    return HW_BY_NAME[hw_name].price_per_chip_hr * n_chips
