"""Self-host vs API crossover analysis (paper §3.4, §5.6).

The crossover is not a point but a surface: lambda* solves
C_eff(lambda*) = C_API(tier). We log-interpolate the measured C_eff(lambda)
curve (the paper's Fig. 5 method) and report per-tier thresholds, flagging
extrapolation below the measured ladder exactly as the paper does.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.pricing import API_TIERS, APITier
from repro.core.records import RunRecord


def interp_c_eff(records: Sequence[RunRecord], lam: float) -> float:
    """Log-log interpolation of the measured curve at offered rate lam."""
    pts = sorted(((r.lam, r.c_eff) for r in records))
    if not pts:
        return math.nan
    if lam <= pts[0][0]:
        return pts[0][1]
    if lam >= pts[-1][0]:
        return pts[-1][1]
    for (l0, c0), (l1, c1) in zip(pts, pts[1:]):
        if l0 <= lam <= l1:
            t = (math.log(lam) - math.log(l0)) / (math.log(l1) - math.log(l0))
            return math.exp(math.log(c0) * (1 - t) + math.log(c1) * t)
    return pts[-1][1]


def crossover_lambda(records: Sequence[RunRecord],
                     api_price: float) -> Optional[Tuple[float, bool]]:
    """(lambda*, extrapolated?) where C_eff crosses below api_price.

    None if self-hosting never crosses below the tier on (or beyond) the
    measured curve. extrapolated=True marks a crossover below the lowest
    measured lambda (paper: 'modeled continuation, not a directly observed
    operating point').
    """
    pts = sorted(((r.lam, r.c_eff) for r in records))
    if not pts:
        return None
    if pts[0][1] <= api_price:
        return pts[0][0], True      # cheaper already at the lowest point
    for (l0, c0), (l1, c1) in zip(pts, pts[1:]):
        if c0 > api_price >= c1:
            t = (math.log(api_price) - math.log(c0)) / \
                (math.log(c1) - math.log(c0))
            lam = math.exp(math.log(l0) * (1 - t) + math.log(l1) * t)
            return lam, False
    return None


def crossover_table(records: Sequence[RunRecord],
                    tiers: Optional[Dict[str, APITier]] = None,
                    accept_slo_mismatch: bool = False) -> List[dict]:
    """Per-tier crossover report. Refuses (paper §6.4) unless the caller
    explicitly accepts that serverless tiers carry no latency SLA."""
    if not accept_slo_mismatch:
        raise ValueError(
            "API comparison gated: serverless list prices carry no latency "
            "SLA; pass accept_slo_mismatch=True to acknowledge (paper §6.4)")
    tiers = tiers or API_TIERS
    out = []
    for name, tier in tiers.items():
        res = crossover_lambda(records, tier.output_per_mtok)
        out.append({
            "tier": name,
            "api_output_per_mtok": tier.output_per_mtok,
            "lambda_star": res[0] if res else math.inf,
            "extrapolated": res[1] if res else False,
            "self_host_always_cheaper": bool(res and res[1]),
        })
    return out
