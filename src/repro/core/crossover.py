"""Self-host vs API crossover analysis (paper §3.4, §5.6).

The crossover is not a point but a surface: lambda* solves
C_eff(lambda*) = C_API(tier). We log-interpolate the measured C_eff(lambda)
curve (the paper's Fig. 5 method) and report per-tier thresholds, flagging
extrapolation below the measured ladder exactly as the paper does.

`interp_loglog` is the one interpolation primitive of the repo (ISSUE 5):
`interp_c_eff`, the planner's fitted deployment curves and the crossover
solver all route through it. It is hardened against the edges merged or
overlapping stores produce:

* duplicate-x points (the same lambda measured in two stores) are
  aggregated up front — geometric mean, i.e. the arithmetic mean in the
  log space the interpolation lives in; exact when the duplicates agree —
  so no verdict silently keys off whichever duplicate sorted first, and
  no zero-width log segment can divide by zero;
* flat segments short-circuit exactly: a curve that is 5.0 on both knots
  returns 5.0, not exp(log(5.0)) = 4.999999999999999;
* queries at a knot return the knot value exactly.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.pricing import API_TIERS, APITier
from repro.core.records import RunRecord


def aggregate_points(pairs: Sequence[Tuple[float, float]]
                     ) -> List[Tuple[float, float]]:
    """Sorted (x, y) knots with duplicate-x values collapsed to one knot.

    Duplicates aggregate by geometric mean (the mean of the log-space the
    interpolation works in); identical duplicates collapse exactly
    (no log/exp round-trip), which also keeps inf/0 values intact.
    """
    by_x: Dict[float, List[float]] = {}
    for x, y in pairs:
        by_x.setdefault(x, []).append(y)
    out = []
    for x in sorted(by_x):
        ys = by_x[x]
        if all(y == ys[0] for y in ys):
            out.append((x, ys[0]))
        elif any(y <= 0 for y in ys):
            # a non-positive duplicate has no log; propagate the floor
            # instead of crashing every later query on this curve
            out.append((x, min(ys)))
        elif any(math.isinf(y) for y in ys):
            out.append((x, math.inf))
        else:
            out.append((x, math.exp(sum(math.log(y) for y in ys) / len(ys))))
    return out


def interp_loglog(pairs: Sequence[Tuple[float, float]], x: float) -> float:
    """Log-log interpolation of (x, y) knots at `x`; clamps outside the
    measured range. Duplicate-x knots are aggregated first; knot hits and
    flat segments return the knot value exactly."""
    return interp_aggregated(aggregate_points(pairs), x)


def interp_aggregated(pts: Sequence[Tuple[float, float]], x: float) -> float:
    """`interp_loglog` over knots already sorted and duplicate-free (the
    planner pre-aggregates at fit time; its query paths skip the
    per-call aggregation)."""
    if not pts:
        return math.nan
    if x <= pts[0][0]:
        return pts[0][1]
    if x >= pts[-1][0]:
        return pts[-1][1]
    for (x0, y0), (x1, y1) in zip(pts, pts[1:]):
        if x0 <= x <= x1:
            if x == x0 or y0 == y1:
                return y0
            if x == x1:
                return y1
            t = (math.log(x) - math.log(x0)) / (math.log(x1) - math.log(x0))
            if not (0 < y0 < math.inf and 0 < y1 < math.inf):
                # a segment with an unloggable endpoint (0 or inf knot)
                # cannot be log-interpolated: clamp to the nearer knot
                return y0 if t < 0.5 else y1
            return math.exp(math.log(y0) * (1 - t) + math.log(y1) * t)
    return pts[-1][1]


def interp_c_eff(records: Sequence[RunRecord], lam: float) -> float:
    """Log-log interpolation of the measured curve at offered rate lam."""
    return interp_loglog([(r.lam, r.c_eff) for r in records], lam)


def crossover_lambda(records: Sequence[RunRecord],
                     api_price: float) -> Optional[Tuple[float, bool]]:
    """(lambda*, extrapolated?) where C_eff crosses below api_price.

    None if self-hosting never crosses below the tier on (or beyond) the
    measured curve. extrapolated=True marks a crossover below the lowest
    measured lambda (paper: 'modeled continuation, not a directly observed
    operating point').
    """
    pts = aggregate_points((r.lam, r.c_eff) for r in records)
    if not pts:
        return None
    if pts[0][1] <= api_price:
        return pts[0][0], True      # cheaper already at the lowest point
    for (l0, c0), (l1, c1) in zip(pts, pts[1:]):
        if c0 > api_price >= c1:
            # c0 > api_price >= c1 implies c0 > c1, so the log segment
            # has width; equal-lambda knots were aggregated above
            t = (math.log(api_price) - math.log(c0)) / \
                (math.log(c1) - math.log(c0))
            lam = math.exp(math.log(l0) * (1 - t) + math.log(l1) * t)
            return lam, False
    return None


def crossover_table(records: Sequence[RunRecord],
                    tiers: Optional[Dict[str, APITier]] = None,
                    accept_slo_mismatch: bool = False) -> List[dict]:
    """Per-tier crossover report. Refuses (paper §6.4) unless the caller
    explicitly accepts that serverless tiers carry no latency SLA."""
    if not accept_slo_mismatch:
        raise ValueError(
            "API comparison gated: serverless list prices carry no latency "
            "SLA; pass accept_slo_mismatch=True to acknowledge (paper §6.4)")
    tiers = tiers or API_TIERS
    out = []
    for name, tier in tiers.items():
        res = crossover_lambda(records, tier.output_per_mtok)
        out.append({
            "tier": name,
            "api_output_per_mtok": tier.output_per_mtok,
            "lambda_star": res[0] if res else math.inf,
            "extrapolated": res[1] if res else False,
            "self_host_always_cheaper": bool(res and res[1]),
        })
    return out
