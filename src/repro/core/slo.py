"""SLO-conditioned operating points (paper §5.5, Table 4).

A fixed SLA (TTFT p99 <= a, TPOT p99 <= b) caps the feasible offered load;
the cost at that lambda_max is what an SLA-bound operator actually pays.
The premium is C(sla) / C_sat over the (typically SLA-infeasible)
unconstrained saturation floor.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence

from repro.core.records import RunRecord

# The paper's running example SLA (§6.4).
DEFAULT_TTFT_P99_MS = 300.0
DEFAULT_TPOT_P99_MS = 50.0


@dataclasses.dataclass(frozen=True)
class SLOTarget:
    """A latency target as upper bounds on RunRecord percentile fields
    (ms); unset bounds are unconstrained. `slo_operating_point` keeps its
    historical p99-pair signature; the capacity planner (`repro.planner`)
    checks interpolated operating points against any subset of bounds."""
    ttft_p50_ms: Optional[float] = None
    ttft_p90_ms: Optional[float] = None
    ttft_p99_ms: Optional[float] = None
    tpot_p50_ms: Optional[float] = None
    tpot_p99_ms: Optional[float] = None

    def bounds(self) -> List[tuple]:
        """The set (metric_name, bound_ms) pairs actually constrained."""
        return [(f.name, getattr(self, f.name))
                for f in dataclasses.fields(self)
                if getattr(self, f.name) is not None]

    def ok(self, metrics) -> bool:
        """True iff every constrained metric is present, finite and within
        its bound. `metrics` maps RunRecord field names to values; a
        missing or non-finite value fails the bound (a load we cannot
        price against the SLA is not demonstrably feasible)."""
        for name, bound in self.bounds():
            v = metrics.get(name)
            if v is None or not math.isfinite(v) or v > bound:
                return False
        return True

    def describe(self) -> str:
        return ", ".join(f"{n} <= {b:g}ms" for n, b in self.bounds()) \
            or "unconstrained"


@dataclasses.dataclass
class SLOResult:
    config: str
    ttft_bound_ms: float
    tpot_bound_ms: float
    lam_max: Optional[float]        # highest SLA-feasible ladder point
    c_at_sla: float
    c_sat: float
    sat_lam: float
    sat_ttft_p99_ms: float
    premium: float                  # c_at_sla / c_sat
    sat_feasible: bool              # is the saturation floor SLA-feasible?


def slo_operating_point(records: Sequence[RunRecord],
                        ttft_p99_ms: float = DEFAULT_TTFT_P99_MS,
                        tpot_p99_ms: float = DEFAULT_TPOT_P99_MS
                        ) -> SLOResult:
    recs = sorted(records, key=lambda r: r.lam)
    sat = min(recs, key=lambda r: r.c_eff)
    feasible = [r for r in recs
                if r.ttft_p99_ms <= ttft_p99_ms
                and r.tpot_p99_ms <= tpot_p99_ms]
    best = min(feasible, key=lambda r: r.c_eff) if feasible else None
    return SLOResult(
        config=recs[0].config,
        ttft_bound_ms=ttft_p99_ms, tpot_bound_ms=tpot_p99_ms,
        lam_max=best.lam if best else None,
        c_at_sla=best.c_eff if best else math.inf,
        c_sat=sat.c_eff, sat_lam=sat.lam,
        sat_ttft_p99_ms=sat.ttft_p99_ms,
        premium=(best.c_eff / sat.c_eff) if best else math.inf,
        sat_feasible=(sat.ttft_p99_ms <= ttft_p99_ms and
                      sat.tpot_p99_ms <= tpot_p99_ms))
