"""repro-cost-meter: the paper's live operational cost meter (§6.6, §6.7).

A *meter*, not a calculator: it never asks the operator for a utilization
or a peak-throughput guess. Each tick scrapes the serving engine's
Prometheus text exposition (the same bytes a Grafana dashboard would read),
differences the token counters, and reports the windowed effective
$/M-output-tokens under the operator's own traffic. The engine clock is
also read from the scraped text, so the meter works identically against
the wall-clock and virtual-clock tiers.

The API-comparison feature is gated behind accept_slo_mismatch (paper §6.4:
serverless list prices carry no latency SLA — comparing them to a
dedicated deployment is a category error unless consciously accepted).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional

from repro.core.cost import c_eff
from repro.core.pricing import API_TIERS
from repro.serving.metrics import parse_prometheus

GEN_TOKENS = "repro:generation_tokens_total"
CLOCK = "repro:time_seconds"
RUNNING = "repro:num_requests_running"


@dataclasses.dataclass
class MeterSample:
    t: float
    window_s: float
    tokens: float
    tps: float
    c_eff: float
    inflight: float


class CostMeter:
    def __init__(self, price_per_hr: float,
                 scrape: Callable[[], str],
                 minute_s: float = 60.0):
        self.price_per_hr = price_per_hr
        self.scrape = scrape
        self.minute_s = minute_s
        self.samples: List[MeterSample] = []
        self._last: Optional[Dict[str, float]] = None

    # ------------------------------------------------------------------
    def tick(self) -> Optional[MeterSample]:
        vals = parse_prometheus(self.scrape())
        if self._last is None:
            self._last = vals
            return None
        dt = vals.get(CLOCK, 0.0) - self._last.get(CLOCK, 0.0)
        dtok = vals.get(GEN_TOKENS, 0.0) - self._last.get(GEN_TOKENS, 0.0)
        self._last = vals
        if dt <= 0:
            return None
        tps = dtok / dt
        s = MeterSample(t=vals.get(CLOCK, 0.0), window_s=dt, tokens=dtok,
                        tps=tps, c_eff=c_eff(self.price_per_hr, tps),
                        inflight=vals.get(RUNNING, 0.0))
        self.samples.append(s)
        return s

    # ------------------------------------------------------------------
    def minute_costs(self) -> List[float]:
        """Aggregate samples into minute windows -> per-minute C_eff.

        An idle window (observed seconds but zero tokens — the diurnal
        trough regime, ISSUE 8) is kept as an explicit `inf` entry: the
        deployment was billed while delivering nothing. Callers that
        want only busy windows filter on `math.isfinite`."""
        if not self.samples:
            return []
        out, bucket_t, toks, secs = [], None, 0.0, 0.0
        for s in self.samples:
            b = int(s.t // self.minute_s)
            if bucket_t is None:
                bucket_t = b
            if b != bucket_t:
                if secs > 0:
                    out.append(c_eff(self.price_per_hr, toks / secs))
                bucket_t, toks, secs = b, 0.0, 0.0
            toks += s.tokens
            secs += s.window_s
        if secs > 0:
            out.append(c_eff(self.price_per_hr, toks / secs))
        return out

    def summary(self) -> Dict[str, Optional[float]]:
        """Best/worst minute + hourly-average cost (paper Table 7).

        Idle-window semantics (ISSUE 8): `minutes` counts *all* observed
        windows and `idle_minutes` the zero-goodput ones; an idle window
        makes `worst_minute` inf (cost-at-zero-goodput, flagged rather
        than hidden) and `swing` None — max/min is undefined when a
        window delivered nothing (previously idle windows were silently
        dropped, undercounting `minutes` and understating the swing, and
        a zero-cost minute made `swing` raise ZeroDivisionError)."""
        all_minutes = self.minute_costs()
        finite = [m for m in all_minutes if math.isfinite(m)]
        idle = len(all_minutes) - len(finite)
        total_tok = sum(s.tokens for s in self.samples)
        total_t = sum(s.window_s for s in self.samples)
        avg = c_eff(self.price_per_hr, total_tok / total_t) \
            if total_t > 0 and total_tok > 0 else math.inf
        if idle or not finite or min(finite) <= 0:
            swing: Optional[float] = None
        else:
            swing = max(finite) / min(finite)
        return {
            "best_minute": min(finite) if finite else math.inf,
            "worst_minute": math.inf if idle or not finite else max(finite),
            "swing": swing,
            "time_weighted_avg": avg,
            "minutes": float(len(all_minutes)),
            "idle_minutes": float(idle),
        }

    # ------------------------------------------------------------------
    def compare_api(self, tier: str, *, accept_slo_mismatch: bool = False
                    ) -> Dict[str, float]:
        if not accept_slo_mismatch:
            raise ValueError(
                "--accept-slo-mismatch required: serverless pricing has no "
                "latency SLA counterpart (paper §6.4)")
        api = API_TIERS[tier].output_per_mtok
        cur = self.samples[-1].c_eff if self.samples else math.inf
        return {"api_output_per_mtok": api, "live_c_eff": cur,
                "self_host_cheaper": float(cur < api)}
