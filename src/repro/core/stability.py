"""Measurement-stability analysis (paper §5.8, Table 5): repeat-run CVs."""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

from repro.core.records import RunRecord


def cv(vals: Sequence[float]) -> float:
    a = np.asarray([v for v in vals if np.isfinite(v)], float)
    if len(a) < 2 or a.mean() == 0:
        return float("nan")
    return float(a.std(ddof=1) / a.mean() * 100.0)


def stability_table(runs_by_lam: Dict[float, List[RunRecord]]) -> List[dict]:
    """runs_by_lam: lambda -> list of repeat RunRecords (distinct seeds)."""
    rows = []
    for lam in sorted(runs_by_lam):
        rs = runs_by_lam[lam]
        rows.append({
            "lam": lam,
            "n_repeats": len(rs),
            "tps_mean": float(np.mean([r.tps for r in rs])),
            "tps_cv_pct": cv([r.tps for r in rs]),
            "c_eff_mean": float(np.mean([r.c_eff for r in rs])),
            "c_eff_cv_pct": cv([r.c_eff for r in rs]),
            "ttft_p50_cv_pct": cv([r.ttft_p50_ms for r in rs]),
        })
    return rows
