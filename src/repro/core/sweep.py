"""Lambda-sweep orchestration (paper §4.3 protocol).

For each offered rate on the ladder: warmup requests (discarded), then a
measured run; when the server is queue-limited the statistics use
completed-requests-within-window, exactly as the paper does at lambda>=50.
The sweep emits RunRecords; theta_max is back-filled as the max measured
TPS across the ladder (raw saturation, no SLO bound — §4.4).

Two drivers share the same per-point protocol; both are thin ladder
plans over the experiment-matrix subsystem (`repro.experiments`, ISSUE 2):

* `lambda_sweep`  — serial, any engine factory.
* `parallel_sweep` — independent (lambda, config) points fanned across a
  `concurrent.futures` process pool. Per-point seeds are derived exactly
  as in the serial path (`seed + int(lam * 1000)`), so the two drivers
  return identical records in ladder order. The engine factory must be
  picklable (use `SimEngineSpec`); if the pool cannot be used (factory
  not picklable, pool start failure) the sweep falls back to the serial
  path with a `RuntimeWarning` naming the reason — results are the same
  either way, just single-core.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.core.cost import c_eff
from repro.core.records import RunRecord
from repro.serving.arrivals import ArrivalSpec, synth_requests
from repro.serving.engine import Engine, EngineConfig
from repro.serving.overload import OverloadPolicy

# The paper's 7-point ladder.
LAMBDA_LADDER = (1, 5, 10, 25, 50, 100, 200)


# paper §5.8: prompts = 60*lam clamped [500,6000]; module-level (not
# lambdas) so the defaults survive pickling into pool workers.
def default_requests_per_point(lam: float) -> int:
    return int(min(6000, max(500, 60 * lam)))


def default_warmup_per_point(lam: float) -> int:
    return int(max(100, 30 * lam) // 10)


@dataclasses.dataclass(frozen=True)
class SimEngineSpec:
    """Picklable sim-tier engine factory (the unit parallel_sweep ships to
    pool workers; also handy anywhere a closure-free factory is needed)."""
    arch: str
    hw: str = "tpu-v5e"
    quant: str = "bf16"
    n_chips: int = 1
    max_batch: int = 128
    page_size: int = 16
    num_pages: int = 32768
    max_pages_per_seq: int = 64
    prefill_token_budget: int = 2048
    max_prefill_reqs: int = 8
    fast_forward: bool = True
    max_queue_depth: int = 0            # >0: admission-control shedding
    deadline_s: float = 0.0             # >0: queue-time deadline
    overload: Optional[OverloadPolicy] = None     # ISSUE 9 controller

    def __call__(self) -> Engine:
        from repro.configs import get_config
        from repro.serving.executors import SimExecutor
        from repro.simulate import HW_BY_NAME, StepTimeModel
        cfg = get_config(self.arch)
        stm = StepTimeModel(cfg, HW_BY_NAME[self.hw], n_chips=self.n_chips,
                            quant=self.quant)
        ecfg = EngineConfig(
            max_batch=self.max_batch, page_size=self.page_size,
            num_pages=self.num_pages,
            max_pages_per_seq=self.max_pages_per_seq,
            prefill_token_budget=self.prefill_token_budget,
            max_prefill_reqs=self.max_prefill_reqs,
            fast_forward=self.fast_forward,
            max_queue_depth=self.max_queue_depth,
            deadline_s=self.deadline_s,
            overload=self.overload)
        return Engine(ecfg, SimExecutor(cfg, stm))


def _pct(vals, q):
    vals = [v for v in vals if v is not None]
    return float(np.percentile(vals, q)) * 1e3 if vals else float("nan")


def run_point(engine_factory: Callable[[], Engine], spec: ArrivalSpec, *,
              warmup: int = 0, horizon: Optional[float] = None,
              config: str = "", model: str = "", hw: str = "cpu-node",
              n_chips: int = 1, quant: str = "bf16", engine_kind: str = "sim",
              price_per_hr: float = 1.0,
              failure_times: Sequence[float] = (),
              failure_spec=None, retry=None) -> RunRecord:
    """One (lambda, config) measurement."""
    eng = engine_factory()
    if warmup:
        wspec = dataclasses.replace(spec, n_requests=warmup,
                                    seed=spec.seed + 7777)
        eng.run(synth_requests(wspec))
        # reset clock + metrics (gauges included), keep compiled state warm
        eng.reset_measurement()

    reqs = synth_requests(spec)
    eng.run(reqs, horizon=horizon, failure_times=failure_times,
            failure_spec=failure_spec, retry=retry)
    done = [r for r in reqs if r.finish_time is not None]
    window = eng.t
    out_toks = sum(r.tokens_out for r in done)
    in_toks = sum(r.prompt_len for r in done)
    tps = out_toks / window if window > 0 else 0.0
    m = eng.metrics
    rec = RunRecord(
        config=config, model=model, hw=hw, n_chips=n_chips, quant=quant,
        engine=engine_kind, lam=spec.lam, io_shape=spec.io_shape,
        n_requests=spec.n_requests, n_completed=len(done), window_s=window,
        tps=tps, prompt_tps=in_toks / window if window else 0.0,
        ttft_p50_ms=_pct([r.ttft for r in done], 50),
        ttft_p90_ms=_pct([r.ttft for r in done], 90),
        ttft_p99_ms=_pct([r.ttft for r in done], 99),
        tpot_p50_ms=_pct([r.tpot for r in done], 50),
        tpot_p99_ms=_pct([r.tpot for r in done], 99),
        e2e_p50_ms=_pct([r.e2e for r in done], 50),
        e2e_p99_ms=_pct([r.e2e for r in done], 99),
        mean_inflight=eng.mean_inflight(),
        price_per_hr=price_per_hr,
        c_eff=c_eff(price_per_hr, tps),
        seed=spec.seed,
        mttf=failure_spec.mttf if failure_spec is not None else 0.0,
        retry_max=retry.max_attempts if retry is not None else 0,
        n_shed=int(m.get("repro:request_shed_total")),
        n_timeout=int(m.get("repro:request_timeout_total")),
        n_retried=int(m.get("repro:request_retry_total")),
        n_abandoned=int(m.get("repro:request_abandoned_total")),
        n_class_shed=int(m.get("repro:request_class_shed_total")),
        n_browned=int(m.get("repro:request_browned_total")),
        browned_tokens=int(m.get("repro:browned_tokens_total")),
        n_slo_viol=int(m.get("repro:request_slo_violation_total")),
        # gated on class_mix so classless cells (every pre-9 store)
        # keep the 0.0 default byte-for-byte
        interactive_tps=(sum(r.tokens_out for r in done if r.priority == 0)
                         / window if (spec.class_mix and window > 0)
                         else 0.0))
    return rec


def _ladder_sweep(engine_factory, *, parallel, ladder, io_shape, scale,
                  requests_per_point, warmup_per_point, horizon, seed,
                  process, cv, max_workers=None, mp_context=None,
                  backend="process", **record_kw) -> List[RunRecord]:
    """Both drivers: build the single-group ladder plan (seeds
    `seed + int(lam * 1000)`, unchanged since PR 1) and hand it to the
    experiment runner. Imported lazily — `repro.experiments` depends on
    this module at import time, not vice versa."""
    from repro.experiments.plan import ladder_plan
    from repro.experiments.runner import PlanRunner
    plan = ladder_plan(ladder=ladder, io_shape=io_shape, scale=scale,
                       requests_per_point=requests_per_point,
                       warmup_per_point=warmup_per_point, horizon=horizon,
                       seed=seed, process=process, cv=cv, **record_kw)
    return PlanRunner(plan, factory=engine_factory).run(
        parallel=parallel, max_workers=max_workers, mp_context=mp_context,
        backend=backend)


def lambda_sweep(engine_factory, *, ladder: Sequence[float] = LAMBDA_LADDER,
                 io_shape: str = "chat", scale: float = 1.0,
                 requests_per_point: Callable[[float], int] = None,
                 warmup_per_point: Callable[[float], int] = None,
                 horizon: Optional[float] = None, seed: int = 0,
                 process: str = "poisson", cv: float = 1.0,
                 **record_kw) -> List[RunRecord]:
    """Full ladder sweep; back-fills theta_max = max TPS across points."""
    return _ladder_sweep(engine_factory, parallel=False, ladder=ladder,
                         io_shape=io_shape, scale=scale,
                         requests_per_point=requests_per_point,
                         warmup_per_point=warmup_per_point, horizon=horizon,
                         seed=seed, process=process, cv=cv, **record_kw)


def parallel_sweep(engine_factory, *,
                   ladder: Sequence[float] = LAMBDA_LADDER,
                   io_shape: str = "chat", scale: float = 1.0,
                   requests_per_point: Callable[[float], int] = None,
                   warmup_per_point: Callable[[float], int] = None,
                   horizon: Optional[float] = None, seed: int = 0,
                   process: str = "poisson", cv: float = 1.0,
                   max_workers: Optional[int] = None,
                   mp_context: Optional[str] = None,
                   backend: str = "process",
                   **record_kw) -> List[RunRecord]:
    """`lambda_sweep` with independent ladder points fanned across a
    process pool; records come back in ladder order with identical values
    (same deterministic per-point seeds, same per-point protocol).
    `backend="vector"` runs SimEngineSpec ladders through the fleet
    simulator instead (ISSUE 4) — same records, lanes x cores.

    Start method (`mp_context=None`): `fork` when JAX has not been
    imported into this process (sim-tier parents stay JAX-free because
    the executors import it lazily) — workers then start in
    milliseconds; otherwise `spawn`, which avoids forking a parent that
    may hold live JAX threads at the cost of ~1s interpreter+numpy
    startup per worker. Pool overhead only amortizes for paper-scale
    points; tiny ladders are often faster through `lambda_sweep`.

    If the pool cannot be used (unpicklable factory, pool start failure)
    the sweep emits a `RuntimeWarning` naming the reason and degrades to
    the serial path with identical results.
    """
    return _ladder_sweep(engine_factory, parallel=True, ladder=ladder,
                         io_shape=io_shape, scale=scale,
                         requests_per_point=requests_per_point,
                         warmup_per_point=warmup_per_point, horizon=horizon,
                         seed=seed, process=process, cv=cv,
                         max_workers=max_workers, mp_context=mp_context,
                         backend=backend, **record_kw)
