"""Lambda-sweep orchestration (paper §4.3 protocol).

For each offered rate on the ladder: warmup requests (discarded), then a
measured run; when the server is queue-limited the statistics use
completed-requests-within-window, exactly as the paper does at lambda>=50.
The sweep emits RunRecords; theta_max is back-filled as the max measured
TPS across the ladder (raw saturation, no SLO bound — §4.4).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.core.cost import c_eff
from repro.core.records import RunRecord
from repro.serving.arrivals import ArrivalSpec, synth_requests
from repro.serving.engine import Engine, EngineConfig

# The paper's 7-point ladder.
LAMBDA_LADDER = (1, 5, 10, 25, 50, 100, 200)


def _pct(vals, q):
    vals = [v for v in vals if v is not None]
    return float(np.percentile(vals, q)) * 1e3 if vals else float("nan")


def run_point(engine_factory: Callable[[], Engine], spec: ArrivalSpec, *,
              warmup: int = 0, horizon: Optional[float] = None,
              config: str = "", model: str = "", hw: str = "cpu-node",
              n_chips: int = 1, quant: str = "bf16", engine_kind: str = "sim",
              price_per_hr: float = 1.0,
              failure_times: Sequence[float] = ()) -> RunRecord:
    """One (lambda, config) measurement."""
    eng = engine_factory()
    if warmup:
        wspec = dataclasses.replace(spec, n_requests=warmup,
                                    seed=spec.seed + 7777)
        eng.run(synth_requests(wspec))
        # reset clock + metrics, keep compiled state warm
        eng.t = 0.0
        eng._inflight_area = 0.0
        eng.metrics.counters.clear()
        eng.metrics.hists.clear()

    reqs = synth_requests(spec)
    eng.run(reqs, horizon=horizon, failure_times=failure_times)
    done = [r for r in reqs if r.finish_time is not None]
    window = eng.t
    out_toks = sum(r.tokens_out for r in done)
    in_toks = sum(r.prompt_len for r in done)
    tps = out_toks / window if window > 0 else 0.0
    rec = RunRecord(
        config=config, model=model, hw=hw, n_chips=n_chips, quant=quant,
        engine=engine_kind, lam=spec.lam, io_shape=spec.io_shape,
        n_requests=spec.n_requests, n_completed=len(done), window_s=window,
        tps=tps, prompt_tps=in_toks / window if window else 0.0,
        ttft_p50_ms=_pct([r.ttft for r in done], 50),
        ttft_p90_ms=_pct([r.ttft for r in done], 90),
        ttft_p99_ms=_pct([r.ttft for r in done], 99),
        tpot_p50_ms=_pct([r.tpot for r in done], 50),
        tpot_p99_ms=_pct([r.tpot for r in done], 99),
        e2e_p50_ms=_pct([r.e2e for r in done], 50),
        e2e_p99_ms=_pct([r.e2e for r in done], 99),
        mean_inflight=eng.mean_inflight(),
        price_per_hr=price_per_hr,
        c_eff=c_eff(price_per_hr, tps),
        seed=spec.seed)
    return rec


def lambda_sweep(engine_factory, *, ladder: Sequence[float] = LAMBDA_LADDER,
                 io_shape: str = "chat", scale: float = 1.0,
                 requests_per_point: Callable[[float], int] = None,
                 warmup_per_point: Callable[[float], int] = None,
                 horizon: Optional[float] = None, seed: int = 0,
                 process: str = "poisson", cv: float = 1.0,
                 **record_kw) -> List[RunRecord]:
    """Full ladder sweep; back-fills theta_max = max TPS across points."""
    # paper §5.8: prompts = 60*lam clamped [500,6000]; here scaled down for
    # the CPU tier via requests_per_point.
    if requests_per_point is None:
        requests_per_point = lambda lam: int(min(6000, max(500, 60 * lam)))
    if warmup_per_point is None:
        warmup_per_point = lambda lam: int(max(100, 30 * lam) // 10)

    records = []
    for lam in ladder:
        spec = ArrivalSpec(lam=lam, n_requests=requests_per_point(lam),
                           io_shape=io_shape, process=process, cv=cv,
                           seed=seed + int(lam * 1000), scale=scale)
        rec = run_point(engine_factory, spec, warmup=warmup_per_point(lam),
                        horizon=horizon, **record_kw)
        records.append(rec)
    theta_max = max(r.tps for r in records)
    for r in records:
        r.theta_max = theta_max
    return records
