"""The paper's primary contribution: the concurrency-aware cost framework.

    C_eff = f(H, M, Q, lambda; L)           (Eq. 1)

cost.py     — C_eff / C_naive / U / penalty / Little's law (Eq. 2-4)
pricing.py  — accelerator + API price books
sweep.py    — the 7-point lambda-ladder benchmark protocol (§4.3)
crossover.py— corrected self-host-vs-API crossover surface (§3.4, §5.6)
slo.py      — SLA-conditioned operating points (§5.5)
meter.py    — the live operational cost meter (§6.6-6.7)
stability.py— repeat-run CV analysis (§5.8)
records.py  — per-run CSV corpus schema (§7.1)
"""
from repro.core.cost import (  # noqa: F401
    c_eff, c_naive, littles_law_inflight, tokens_per_dollar,
    underutilization_penalty, utilization)
from repro.core.crossover import (  # noqa: F401
    aggregate_points, crossover_lambda, crossover_table, interp_aggregated,
    interp_c_eff, interp_loglog)
from repro.core.meter import CostMeter, MeterSample  # noqa: F401
from repro.core.pricing import API_TIERS, APITier, chip_hour_price  # noqa: F401
from repro.core.records import RunRecord, read_csv, write_csv  # noqa: F401
from repro.core.slo import (  # noqa: F401
    SLOResult, SLOTarget, slo_operating_point)
from repro.core.stability import cv, stability_table  # noqa: F401
from repro.core.sweep import (  # noqa: F401
    LAMBDA_LADDER, SimEngineSpec, lambda_sweep, parallel_sweep, run_point)
