"""The concurrency-aware cost model (paper §3) — Eq. (1)-(4).

    C_eff   = P_gpu * 1e6 / (3600 * Theta_achieved(lambda, L))     (3)
    C_naive = P_gpu * 1e6 / (3600 * Theta_max(H, M, Q))            (4)
    U       = Theta_achieved / Theta_max                           (2)
    penalty = C_eff / C_naive = 1 / U

Utilization is a *dependent* variable — these functions never accept it as
an input. Throughput is always aggregate OUTPUT tokens/s (dollars per
million output tokens), matching the paper's pricing basis.
"""
from __future__ import annotations

import math
from typing import Optional


def c_eff(price_per_hr: float, tps: float) -> float:
    """Effective $/M-output-tokens at achieved throughput `tps`."""
    if tps <= 0:
        return math.inf
    return price_per_hr * 1e6 / (3600.0 * tps)


def c_naive(price_per_hr: float, theta_max: float) -> float:
    """Token-volume-model cost at assumed peak throughput."""
    return c_eff(price_per_hr, theta_max)


def utilization(theta_achieved: float, theta_max: float) -> float:
    """U(lambda, L | H, M, Q) — Eq. (2)."""
    if theta_max <= 0:
        return 0.0
    return theta_achieved / theta_max


def underutilization_penalty(theta_achieved: float,
                             theta_max: float) -> float:
    """C_eff/C_naive = 1/U — the factor by which naive estimates understate
    true cost (paper headline: 2.5-24x at 1-10 rps, 36.3x at idle)."""
    u = utilization(theta_achieved, theta_max)
    return math.inf if u <= 0 else 1.0 / u


def littles_law_inflight(lam: float, mean_residence: float) -> float:
    """N = lambda * W."""
    return lam * mean_residence


def tokens_per_dollar(price_per_hr: float, tps: float) -> float:
    if price_per_hr <= 0:
        return math.inf
    return tps * 3600.0 / price_per_hr


def monthly_cost(price_per_hr: float, hours: float = 730.0) -> float:
    return price_per_hr * hours
