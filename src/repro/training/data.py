"""Synthetic sharded data pipeline.

Deterministic PRNG token stream (seed + step -> batch), so every data-
parallel host materializes only its shard and restarts resume exactly
(checkpoint stores the step counter — the stream needs no state). Emits
next-token-prediction pairs: labels are tokens shifted by one.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


class SyntheticDataLoader:
    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0,
                 frames: int = 0, d_model: int = 0, patches: int = 0):
        self.vocab, self.batch, self.seq = vocab, batch, seq
        self.seed = seed
        self.frames, self.d_model, self.patches = frames, d_model, patches

    def batch_at(self, step: int) -> Dict[str, jnp.ndarray]:
        rng = np.random.default_rng((self.seed << 20) ^ step)
        # a compressible synthetic language: Zipfian unigrams + local repeat
        toks = rng.zipf(1.3, size=(self.batch, self.seq + 1)) % self.vocab
        rep = rng.random((self.batch, self.seq + 1)) < 0.3
        toks = np.where(rep, np.roll(toks, 1, axis=1), toks)
        out = {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
               "labels": jnp.asarray(toks[:, 1:], jnp.int32)}
        if self.frames:
            out["frames"] = jnp.asarray(
                rng.normal(size=(self.batch, self.frames, self.d_model)),
                jnp.bfloat16)
        if self.patches:
            out["patches"] = jnp.asarray(
                rng.normal(size=(self.batch, self.patches, self.d_model)),
                jnp.bfloat16)
        return out

    def __iter__(self) -> Iterator[Dict[str, jnp.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
