"""Sharded checkpoint manager: async save, keep-k, hashes, elastic restore.

Layout per step:
    <dir>/step_000123/
        manifest.json        {step, leaf paths, shapes, dtypes, sha256}
        arrays.npz           flattened leaves (key = joined tree path)

Restore never requires the saving mesh: arrays are loaded on host and
device_put against whatever sharding the *current* mesh prescribes
(elastic restart onto a different device count — DESIGN §5). Writes go to
a tmp dir + atomic rename so a killed process never leaves a half
checkpoint; `restore_latest` skips corrupt/partial steps (fault tolerance
test coverage in tests/test_checkpoint.py).
"""
from __future__ import annotations

import hashlib
import json
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _np_dtype(name: str) -> np.dtype:
    """Resolve extended dtypes (bfloat16, float8_*) via ml_dtypes."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _flatten(tree) -> Dict[str, Any]:
    flat = {}

    def visit(node, path):
        if isinstance(node, dict):
            for k in sorted(node):
                visit(node[k], path + (str(k),))
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                visit(v, path + (str(i),))
        elif node is None:
            flat["/".join(path) + "#none"] = None
        else:
            flat["/".join(path)] = node

    visit(tree, ())
    return flat


def _unflatten_into(template, flat: Dict[str, Any]):
    def visit(node, path):
        if isinstance(node, dict):
            return {k: visit(v, path + (str(k),)) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(visit(v, path + (str(i),))
                              for i, v in enumerate(node))
        if node is None:
            return None
        return flat["/".join(path)]
    return visit(template, ())


class CheckpointManager:
    def __init__(self, directory, keep: int = 3, async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> Path:
        return self.dir / f"step_{step:09d}"

    def all_steps(self) -> List[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "manifest.json").exists():
                try:
                    out.append(int(p.name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree, extra: Optional[dict] = None):
        """Snapshot to host, then (optionally) write on a background thread
        — the async-save distributed trick: training continues while bytes
        hit disk."""
        flat = _flatten(tree)
        host = {k: (None if v is None else np.asarray(v))
                for k, v in flat.items()}
        self.wait()

        def write():
            tmp = self.dir / f".tmp_step_{step:09d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            arrays = {k: v for k, v in host.items() if v is not None}
            np.savez(tmp / "arrays.npz", **arrays)
            digest = hashlib.sha256()
            for k in sorted(arrays):
                digest.update(k.encode())
                digest.update(arrays[k].tobytes())
            manifest = {
                "step": step,
                "extra": extra or {},
                "none_keys": [k for k, v in host.items() if v is None],
                "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                           for k, v in arrays.items()},
                "sha256": digest.hexdigest(),
            }
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            final = self._step_dir(step)
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    def restore(self, step: int, template, shardings=None,
                verify: bool = True) -> Tuple[Any, dict]:
        d = self._step_dir(step)
        manifest = json.loads((d / "manifest.json").read_text())
        with np.load(d / "arrays.npz") as z:
            arrays = {k: z[k] for k in z.files}
        # npz stores extended dtypes (bfloat16 etc.) as raw void — view back
        for k, v in arrays.items():
            want = _np_dtype(manifest["leaves"][k]["dtype"])
            if v.dtype != want:
                arrays[k] = v.view(want)
        if verify:
            digest = hashlib.sha256()
            for k in sorted(arrays):
                digest.update(k.encode())
                digest.update(arrays[k].tobytes())
            if digest.hexdigest() != manifest["sha256"]:
                raise IOError(f"checkpoint {step}: hash mismatch (corrupt)")
        flat_shard = _flatten(shardings) if shardings is not None else None

        def put(k, v):
            arr = jnp.asarray(v)
            if flat_shard is not None and flat_shard.get(k) is not None:
                return jax.device_put(arr, flat_shard[k])
            return arr
        flat = {k: put(k, v) for k, v in arrays.items()}
        for k in manifest["none_keys"]:
            flat[k.replace("#none", "")] = None
        tree = _unflatten_into(template, flat)
        return tree, manifest["extra"]

    def restore_latest(self, template, shardings=None):
        """Newest non-corrupt checkpoint, or None. Skips damaged steps —
        the restart-after-failure path."""
        for step in reversed(self.all_steps()):
            try:
                tree, extra = self.restore(step, template, shardings)
                return step, tree, extra
            except Exception:
                continue
        return None
