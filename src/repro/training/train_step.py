"""Train-step builder: loss + grads + optimizer update, remat-aware."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import train_loss
from repro.training.optimizer import Optimizer


def build_train_step(cfg: ModelConfig, opt: Optimizer, qcfg=None,
                     remat: bool = True, grad_clip: float = 1.0,
                     accum_steps: int = 1):
    """Returns step(params, opt_state, batch) -> (params, opt_state, stats).

    remat=True checkpoints the superblock scan body (activation memory
    O(R) -> O(1) per repeat). accum_steps>1 microbatches the global batch
    through a lax.scan with fp32 gradient accumulation — activation
    working-set divides by accum_steps, the standard lever that fits
    train_4k cells into 16 GB/chip (§Perf). Grads are clipped by global
    norm before the optimizer update.
    """

    def loss_fn(params, batch):
        loss, aux = train_loss(params, cfg, batch, qcfg=qcfg, remat=remat)
        return loss, aux

    def grads_of(params, batch):
        if accum_steps <= 1:
            return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

        def split(x):
            return x.reshape((accum_steps, x.shape[0] // accum_steps)
                             + x.shape[1:])
        micro = jax.tree.map(split, batch)

        def body(carry, mb):
            gsum, lsum, ce, aux_ = carry
            (loss, aux), g = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            gsum = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), gsum, g)
            return (gsum, lsum + loss, ce + aux["ce"],
                    aux_ + aux["aux"]), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        z = jnp.zeros((), jnp.float32)
        (gsum, lsum, ce, aux_), _ = jax.lax.scan(
            body, (zeros, z, z, z), micro)
        inv = 1.0 / accum_steps
        grads = jax.tree.map(lambda g: g * inv, gsum)
        return (lsum * inv, {"ce": ce * inv, "aux": aux_ * inv}), grads

    def step(params, opt_state, batch):
        (loss, aux), grads = grads_of(params, batch)
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        if grad_clip:
            scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-6))
            grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm,
                                   "ce": aux["ce"], "aux": aux["aux"]}

    return step
