"""Training substrate: optimizers, step builder, data, checkpoints."""
from repro.training.checkpoint import CheckpointManager  # noqa: F401
from repro.training.compression import (  # noqa: F401
    compress_int8, decompress_int8, error_feedback_update)
from repro.training.data import SyntheticDataLoader  # noqa: F401
from repro.training.optimizer import adamw, adamw8bit  # noqa: F401
from repro.training.train_step import build_train_step  # noqa: F401
