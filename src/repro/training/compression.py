"""Gradient compression for the DP all-reduce: int8 + error feedback.

Classic EF-SGD scheme: transmit q = Q(g + e) in int8 with a per-tensor
scale, keep e' = (g + e) - deQ(q) locally. Halving (vs bf16) or quartering
(vs fp32) the DP all-reduce bytes directly shrinks the roofline's
collective term on gradient-bound training steps. Used inside shard_map
(see tests/test_compression.py for the psum-of-compressed demo).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def compress_int8(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def error_feedback_update(g: jnp.ndarray, err: jnp.ndarray
                          ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (q, scale, new_err) for one tensor."""
    corrected = g.astype(jnp.float32) + err
    q, scale = compress_int8(corrected)
    new_err = corrected - decompress_int8(q, scale)
    return q, scale, new_err
