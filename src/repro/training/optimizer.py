"""Optimizers: AdamW and block-wise 8-bit AdamW (memory for 400B on 16 GB).

Pure-functional optax-style API:
    opt = adamw(lr=...); state = opt.init(params)
    params, state = opt.update(grads, state, params)

adamw8bit stores both moments as int8 with per-block fp32 absmax scales
(block = trailing-dim groups of `block_size`), cutting optimizer state from
8 bytes/param (fp32 m+v) to ~2 bytes/param — the difference between a
400B-parameter train_step fitting a v5e pod or not (DESIGN §2). Decode->
update->re-encode happens inside the step; XLA fuses it, so no fp32 copy
ever lands in HBM.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]


def adamw(lr: float = 1e-3, b1: float = 0.9, b2: float = 0.999,
          eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(grads, state, params):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

        flat = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_p = jax.tree.map(lambda x: x[0], flat,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda x: x[1], flat,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda x: x[2], flat,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"step": step, "m": new_m, "v": new_v}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Block-wise 8-bit moments
# ---------------------------------------------------------------------------

def _q8(x: jnp.ndarray, block: int):
    """Quantize fp32 -> (int8 SAME SHAPE as x, per-block absmax) with a
    sqrt dynamic-range codec: q = round(127*sign(x)*sqrt(|x|/absmax)).

    Shape preservation matters twice: (i) the int8 moment inherits the
    weight's PartitionSpec unchanged, so ZeRO-style sharding needs no
    special casing at 400B scale; (ii) the nonlinear code keeps resolution
    near zero — second Adam moments span many decades within one block;
    linear int8 underflows them to 0 and the update explodes (observed,
    then fixed, in the §Perf log). Blocks run along the last axis; a
    ragged tail becomes its own block."""
    *lead, last = x.shape
    nb = -(-last // block)
    pad = nb * block - last
    xp = jnp.pad(x, [(0, 0)] * len(lead) + [(0, pad)])
    blk = xp.reshape(*lead, nb, block)
    absmax = jnp.maximum(jnp.max(jnp.abs(blk), axis=-1, keepdims=True),
                         1e-12)
    y = jnp.sign(blk) * jnp.sqrt(jnp.abs(blk) / absmax)
    q = jnp.clip(jnp.round(127.0 * y), -127, 127).astype(jnp.int8)
    q = q.reshape(*lead, nb * block)[..., :last]
    return q, absmax[..., 0].astype(jnp.float32)        # scale: (*lead, nb)


def _dq8(q: jnp.ndarray, absmax: jnp.ndarray, block: int):
    *lead, last = q.shape
    nb = absmax.shape[-1]
    pad = nb * block - last
    qp = jnp.pad(q, [(0, 0)] * len(lead) + [(0, pad)])
    y = qp.reshape(*lead, nb, block).astype(jnp.float32) / 127.0
    x = jnp.sign(y) * jnp.square(y) * absmax[..., None]
    return x.reshape(*lead, nb * block)[..., :last]


def adamw8bit(lr: float = 1e-3, b1: float = 0.9, b2: float = 0.999,
              eps: float = 1e-8, weight_decay: float = 0.0,
              block_size: int = 256) -> Optimizer:
    def init(params):
        def zq(p):
            nb = -(-p.shape[-1] // block_size) if p.ndim else 1
            return {"q": jnp.zeros(p.shape, jnp.int8),
                    "scale": jnp.full(p.shape[:-1] + (nb,), 1e-12,
                                      jnp.float32)}
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(zq, params),
                "v": jax.tree.map(zq, params)}

    def update(grads, state, params):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def upd(g, mq, vq, p):
            g = g.astype(jnp.float32)
            m = _dq8(mq["q"], mq["scale"], block_size)
            v = _dq8(vq["q"], vq["scale"], block_size)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            # eps inside the sqrt: robust to residual quantization underflow
            u = (m / bc1) / jnp.sqrt(v / bc2 + eps * eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            newp = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
            qm, sm = _q8(m, block_size)
            qv, sv = _q8(v, block_size)
            return newp, {"q": qm, "scale": sm}, {"q": qv, "scale": sv}

        leaves_p, treedef = jax.tree.flatten(params)
        leaves_g = treedef.flatten_up_to(grads)
        leaves_m = treedef.flatten_up_to(state["m"])
        leaves_v = treedef.flatten_up_to(state["v"])
        out = [upd(g, m, v, p) for g, m, v, p in
               zip(leaves_g, leaves_m, leaves_v, leaves_p)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, {"step": step, "m": new_m, "v": new_v}

    return Optimizer(init, update)
