"""Config for --arch mixtral-8x7b."""
from repro.configs.base import (  # noqa: F401
    ModelConfig, MoEConfig, SSMConfig, XLSTMConfig)

CONFIG = ModelConfig(
    # [arXiv:2401.04088] the paper's sparse MoE (C5/C6): 12.9B active / 46.7B.
    name="mixtral-8x7b", family="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=32000,
    moe=MoEConfig(num_experts=8, top_k=2, expert_ff=14336),
)
