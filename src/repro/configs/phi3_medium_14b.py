"""Config for --arch phi3-medium-14b."""
from repro.configs.base import (  # noqa: F401
    ModelConfig, MoEConfig, SSMConfig, XLSTMConfig)

CONFIG = ModelConfig(
    # [arXiv:2404.14219] RoPE SwiGLU GQA.
    name="phi3-medium-14b", family="dense",
    num_layers=40, d_model=5120, num_heads=40, num_kv_heads=10,
    d_ff=17920, vocab_size=100352,
)
