"""Config for --arch xlstm-350m."""
from repro.configs.base import (  # noqa: F401
    ModelConfig, MoEConfig, SSMConfig, XLSTMConfig)

CONFIG = ModelConfig(
    # [arXiv:2405.04517] sLSTM + mLSTM blocks; d_ff=0 (ff inside blocks).
    name="xlstm-350m", family="ssm",
    num_layers=24, d_model=1024, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304,
    xlstm=XLSTMConfig(slstm_every=2), rope_kind="none",
    tie_embeddings=True,
)
