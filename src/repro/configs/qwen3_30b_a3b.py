"""Config for --arch qwen3-30b-a3b."""
from repro.configs.base import (  # noqa: F401
    ModelConfig, MoEConfig, SSMConfig, XLSTMConfig)

CONFIG = ModelConfig(
    # [arXiv:2505.09388] the paper's ultra-sparse MoE (C3/C4): 3B active / 30B.
    name="qwen3-30b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4,
    d_ff=6144, vocab_size=151936, head_dim=128,
    moe=MoEConfig(num_experts=128, top_k=8, expert_ff=768),
)
