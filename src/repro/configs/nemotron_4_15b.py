"""Config for --arch nemotron-4-15b."""
from repro.configs.base import (  # noqa: F401
    ModelConfig, MoEConfig, SSMConfig, XLSTMConfig)

CONFIG = ModelConfig(
    # [arXiv:2402.16819] GQA, squared-ReLU MLP.
    name="nemotron-4-15b", family="dense",
    num_layers=32, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=24576, vocab_size=256000,
    mlp_kind="relu2", norm_kind="layernorm",
)
