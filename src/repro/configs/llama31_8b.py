"""Config for --arch llama31-8b."""
from repro.configs.base import (  # noqa: F401
    ModelConfig, MoEConfig, SSMConfig, XLSTMConfig)

CONFIG = ModelConfig(
    # [arXiv:2407.21783] the paper's dense reference (C1/C2).
    name="llama31-8b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=128256, rope_theta=500000.0,
)
