"""Config for --arch whisper-base."""
from repro.configs.base import (  # noqa: F401
    ModelConfig, MoEConfig, SSMConfig, XLSTMConfig)

CONFIG = ModelConfig(
    # [arXiv:2212.04356] enc-dec, conv frontend stubbed (frame embeddings).
    name="whisper-base", family="encdec",
    num_layers=6, d_model=512, num_heads=8, num_kv_heads=8,
    d_ff=2048, vocab_size=51865,
    mlp_kind="gelu", norm_kind="layernorm", rope_kind="none",
    encoder_layers=6, frontend="audio_frames", frontend_len=1500,
    tie_embeddings=True,
)
