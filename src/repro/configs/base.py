"""Config dataclasses for the repro framework.

A ModelConfig fully describes an architecture; a ShapeConfig describes one
(seq_len, global_batch, kind) input-shape cell from the assignment. The
registry in __init__.py maps --arch ids to ModelConfig builders.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Shape cells (same four for every LM-family arch, per the assignment).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_serving(self) -> bool:
        return self.kind in ("prefill", "decode")


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

ALL_SHAPES: Tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


# ---------------------------------------------------------------------------
# Model configs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_ff: int                 # d_ff of each expert MLP
    capacity_factor: float = 1.25
    group_size: int = 256          # tokens per dispatch group
    # every `interleave`-th layer is MoE (1 = all layers, 2 = alternating)
    interleave: int = 1
    shared_expert_ff: int = 0      # optional always-on shared expert


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-style selective SSM block parameters."""
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2                # d_inner = expand * d_model
    dt_rank: int = 0               # 0 -> ceil(d_model/16)


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block stack: ratio of mLSTM to sLSTM blocks."""
    slstm_every: int = 2           # every k-th block is sLSTM, rest mLSTM
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0
    conv1d_kernel: int = 4


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | encdec | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads
    # block type per layer position: "attn" | "mamba" | "mlstm" | "slstm"
    # resolved by block_pattern() below.
    mlp_kind: str = "swiglu"       # swiglu | relu2 | gelu
    norm_kind: str = "rmsnorm"     # rmsnorm | layernorm
    rope_kind: str = "rope"        # rope | mrope | none
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    # hybrid pattern: attention every k-th layer (jamba: 8 -> 1 attn per 8)
    attn_every: int = 1
    # enc-dec (whisper): number of encoder layers; decoder = num_layers
    encoder_layers: int = 0
    # modality frontend stub: "none" | "audio_frames" | "vision_patches"
    frontend: str = "none"
    # max patches/frames the frontend stub can emit (vlm/audio)
    frontend_len: int = 0
    logit_softcap: float = 0.0
    dtype: str = "bfloat16"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    def block_pattern(self) -> Tuple[str, ...]:
        """Per-layer block kinds for the decoder stack."""
        kinds = []
        for i in range(self.num_layers):
            if self.family == "ssm" and self.xlstm is not None:
                k = "slstm" if (i % self.xlstm.slstm_every == self.xlstm.slstm_every - 1) else "mlstm"
            elif self.attn_every > 1:
                # jamba-style: one attention layer per `attn_every` block window
                k = "attn" if (i % self.attn_every == self.attn_every // 2) else "mamba"
            else:
                k = "attn"
            kinds.append(k)
        return tuple(kinds)

    def moe_layer_mask(self) -> Tuple[bool, ...]:
        if self.moe is None:
            return tuple(False for _ in range(self.num_layers))
        il = self.moe.interleave
        return tuple((i % il == il - 1) for i in range(self.num_layers))

    # ---- parameter counting (used by cost model + roofline) ----
    def param_count(self) -> int:
        return _param_count(self, active_only=False)

    def active_param_count(self) -> int:
        return _param_count(self, active_only=True)

    def kv_bytes_per_token(self, bytes_per_el: int = 2) -> int:
        """KV-cache bytes appended per generated token (all layers)."""
        hd = self.resolved_head_dim
        n_attn = sum(1 for k in self.block_pattern() if k == "attn")
        return n_attn * 2 * self.num_kv_heads * hd * bytes_per_el

    def shapes(self) -> Tuple[ShapeConfig, ...]:
        """Shape cells assigned to this arch (long_500k only for sub-quadratic)."""
        subquad = self.family in ("ssm", "hybrid")
        out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
        if subquad:
            out.append(LONG_500K)
        return tuple(out)


@functools.lru_cache(maxsize=None)
def _param_count(cfg: ModelConfig, active_only: bool) -> int:
    """Analytic parameter count; active_only counts top-k experts only."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    n_q, n_kv = cfg.num_heads, cfg.num_kv_heads
    attn = d * n_q * hd + 2 * d * n_kv * hd + n_q * hd * d  # q,k,v,o

    def mlp(ff: int) -> int:
        if ff == 0:
            return 0
        mults = 3 if cfg.mlp_kind == "swiglu" else 2
        return mults * d * ff

    pattern = cfg.block_pattern()
    moe_mask = cfg.moe_layer_mask()
    total = 0
    for i, kind in enumerate(pattern):
        if kind == "attn":
            total += attn
        elif kind == "mamba":
            assert cfg.ssm is not None
            di = cfg.ssm.expand * d
            dtr = cfg.ssm.dt_rank or -(-d // 16)
            # in_proj (d->2*di), conv, x_proj (di->dtr+2*state), dt_proj, A, D, out_proj
            total += d * 2 * di + di * cfg.ssm.d_conv + di * (dtr + 2 * cfg.ssm.d_state)
            total += dtr * di + di * cfg.ssm.d_state + di + di * d
        elif kind == "mlstm":
            assert cfg.xlstm is not None
            di = int(cfg.xlstm.mlstm_proj_factor * d)
            # up 2x (x + gate), q/k/v projections at di, gates, down
            total += d * 2 * di + 3 * di * di
            total += 3 * di  # i,f,o gate vectors (simplified)
            total += di * d
        elif kind == "slstm":
            assert cfg.xlstm is not None
            di = d
            total += 4 * di * di + 4 * di  # recurrent gates
            pf = cfg.xlstm.slstm_proj_factor
            total += int(2 * di * di * pf)  # ff up/down
        # MLP / MoE
        if kind == "attn" or cfg.family == "hybrid":
            if moe_mask[i] and cfg.moe is not None:
                e = cfg.moe.top_k if active_only else cfg.moe.num_experts
                total += e * mlp(cfg.moe.expert_ff) + d * cfg.moe.num_experts
                total += mlp(cfg.moe.shared_expert_ff)
            else:
                total += mlp(cfg.d_ff)
        # norms
        total += 2 * d
    # embeddings (+ output head unless tied)
    total += cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    # encoder stack (whisper): encoder layers are attn + mlp
    if cfg.encoder_layers:
        total += cfg.encoder_layers * (attn + mlp(cfg.d_ff) + 2 * d)
    return int(total)
