"""Config for --arch granite-34b."""
from repro.configs.base import (  # noqa: F401
    ModelConfig, MoEConfig, SSMConfig, XLSTMConfig)

CONFIG = ModelConfig(
    # [arXiv:2405.04324] llama-arch code model, MQA (kv=1), 88 layers.
    name="granite-34b", family="dense",
    num_layers=88, d_model=6144, num_heads=48, num_kv_heads=1,
    d_ff=24576, vocab_size=49152,
    mlp_kind="gelu",  # GPT-BigCode-style non-gated MLP -> ~34B total params
)
