"""Config for --arch llama4-maverick-400b-a17b."""
from repro.configs.base import (  # noqa: F401
    ModelConfig, MoEConfig, SSMConfig, XLSTMConfig)

CONFIG = ModelConfig(
    # [hf:meta-llama/Llama-4] MoE 128e top-1, interleaved dense/MoE.
    name="llama4-maverick-400b-a17b", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=8192, vocab_size=202048,
    moe=MoEConfig(num_experts=128, top_k=1, expert_ff=8192, interleave=2,
                  shared_expert_ff=8192),
    frontend="vision_patches", frontend_len=0,  # early fusion (stub off by default)
)
