"""Config for --arch granite-moe-1b-a400m."""
from repro.configs.base import (  # noqa: F401
    ModelConfig, MoEConfig, SSMConfig, XLSTMConfig)

CONFIG = ModelConfig(
    # [hf:ibm-granite/granite-3.0-1b-a400m-base] 32 experts top-8.
    name="granite-moe-1b-a400m", family="moe",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=8,
    d_ff=512, vocab_size=49155,
    moe=MoEConfig(num_experts=32, top_k=8, expert_ff=512),
    tie_embeddings=True,
)
