"""Config for --arch codeqwen1.5-7b."""
from repro.configs.base import (  # noqa: F401
    ModelConfig, MoEConfig, SSMConfig, XLSTMConfig)

CONFIG = ModelConfig(
    # [hf:Qwen/CodeQwen1.5-7B] qwen1.5 arch; kv=32 (full MHA).
    name="codeqwen1.5-7b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=32,
    d_ff=13440, vocab_size=92416,
)
