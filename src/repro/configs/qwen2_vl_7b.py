"""Config for --arch qwen2-vl-7b."""
from repro.configs.base import (  # noqa: F401
    ModelConfig, MoEConfig, SSMConfig, XLSTMConfig)

CONFIG = ModelConfig(
    # [arXiv:2409.12191] M-RoPE, dynamic resolution (stubbed patches).
    name="qwen2-vl-7b", family="vlm",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
    d_ff=18944, vocab_size=152064,
    rope_kind="mrope", frontend="vision_patches", frontend_len=1024,
)
