"""Architecture registry: --arch <id> -> ModelConfig.

One module per assigned architecture (exact numbers from the assignment block)
plus the paper's own three benchmark models (Llama-3.1-8B, Qwen3-30B-A3B,
Mixtral-8x7B) so the paper's C1..C6 configurations are reproducible.

`reduced(name)` returns a tiny same-family config for CPU smoke tests and the
real-execution serving engine.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import (  # noqa: F401
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    SHAPES_BY_NAME,
    TRAIN_4K,
    ModelConfig,
    MoEConfig,
    ShapeConfig,
    SSMConfig,
    XLSTMConfig,
)

from repro.configs import (  # noqa: E402
    codeqwen1_5_7b,
    granite_34b,
    granite_moe_1b_a400m,
    jamba_v0_1_52b,
    llama31_8b,
    llama4_maverick_400b_a17b,
    mixtral_8x7b,
    nemotron_4_15b,
    phi3_medium_14b,
    qwen2_vl_7b,
    qwen3_30b_a3b,
    whisper_base,
    xlstm_350m,
)

_MODULES = (
    whisper_base, jamba_v0_1_52b, granite_moe_1b_a400m,
    llama4_maverick_400b_a17b, nemotron_4_15b, codeqwen1_5_7b,
    phi3_medium_14b, granite_34b, qwen2_vl_7b, xlstm_350m,
    llama31_8b, qwen3_30b_a3b, mixtral_8x7b,
)

_REGISTRY: Dict[str, ModelConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}

ASSIGNED_ARCHS = (
    "whisper-base", "jamba-v0.1-52b", "granite-moe-1b-a400m",
    "llama4-maverick-400b-a17b", "nemotron-4-15b", "codeqwen1.5-7b",
    "phi3-medium-14b", "granite-34b", "qwen2-vl-7b", "xlstm-350m",
)
PAPER_ARCHS = ("llama31-8b", "qwen3-30b-a3b", "mixtral-8x7b")
ALL_ARCHS = ASSIGNED_ARCHS + PAPER_ARCHS


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs():
    return list(_REGISTRY)


def reduced(name: str, *, layers: int = 2, d_model: int = 64,
            vocab: int = 256, ff: int = 128) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests and the real-exec engine."""
    cfg = get_config(name)
    heads = max(2, min(4, cfg.num_heads))
    kv = 1 if cfg.num_kv_heads == 1 else max(1, heads // max(1, cfg.q_per_kv))
    kv = min(kv, heads)
    changes = dict(
        name=f"{cfg.name}-reduced",
        num_layers=max(layers, cfg.attn_every if cfg.attn_every > 1 else layers),
        d_model=d_model, num_heads=heads, num_kv_heads=kv,
        d_ff=0 if cfg.d_ff == 0 else ff, vocab_size=vocab, head_dim=0,
        encoder_layers=min(cfg.encoder_layers, 2),
        frontend_len=min(cfg.frontend_len, 16),
    )
    if cfg.moe is not None:
        changes["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=min(2, cfg.moe.top_k),
            expert_ff=ff, group_size=16,
            shared_expert_ff=ff if cfg.moe.shared_expert_ff else 0)
    if cfg.ssm is not None:
        changes["ssm"] = dataclasses.replace(cfg.ssm, d_state=4)
    return dataclasses.replace(cfg, **changes)


__all__ = [
    "ModelConfig", "MoEConfig", "SSMConfig", "XLSTMConfig", "ShapeConfig",
    "ALL_SHAPES", "SHAPES_BY_NAME", "TRAIN_4K", "PREFILL_32K", "DECODE_32K",
    "LONG_500K", "ASSIGNED_ARCHS", "PAPER_ARCHS", "ALL_ARCHS",
    "get_config", "list_archs", "reduced",
]
