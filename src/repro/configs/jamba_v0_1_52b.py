"""Config for --arch jamba-v0.1-52b."""
from repro.configs.base import (  # noqa: F401
    ModelConfig, MoEConfig, SSMConfig, XLSTMConfig)

CONFIG = ModelConfig(
    # [arXiv:2403.19887] Mamba+attn 1:7 interleave, MoE 16e top-2.
    name="jamba-v0.1-52b", family="hybrid",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=65536,
    moe=MoEConfig(num_experts=16, top_k=2, expert_ff=14336, interleave=2),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    attn_every=8, rope_kind="none",  # jamba uses no positional encoding
)
