"""PlanRunner: execution backends for experiment cells.

Three backends behind one `execute_cells` surface (ISSUE 4, ISSUE 7):

* ``backend="process"`` — the PR-2/3 path: every cell is an independent
  (engine, arrival stream) measurement fanned cell-at-a-time across the
  process pool (fork while the parent is JAX-free, spawn otherwise).
* ``backend="vector"`` — the fleet path: sim-tier cells are chunked into
  *lanes* of the struct-of-arrays fleet simulator
  (`repro.serving.fleet`), so one Python event loop advances a whole
  chunk at once (~6x cells/s single-core), and chunks still fan out
  across the pool (lanes x cores). Cells the fleet cannot take (custom
  engine factories that are not `SimEngineSpec`, `fast_forward=False`
  reference runs) silently take the per-cell path; records are
  bit-identical either way, so the backend is purely an execution knob.
  Resume granularity: in-process chunks stream each lane's record into
  the store the moment the lane finishes (per-cell, like the process
  backend); pool-dispatched chunks land at chunk completion, so a
  killed pooled run can lose at most one chunk per worker.
* ``backend="jit"`` — the compiled fleet (`repro.serving.fleet_jit`):
  same lane partitioning, but each chunk runs the jit-compiled
  `lax.while_loop` event loop (~4x the vector backend's cells/s at 256
  lanes). Records agree with the numpy oracle within
  `serving.precision.jit_tolerance()` rather than bitwise — commit
  stores with the vector/process backends, sweep with jit. Points the
  compiled loop cannot express fall back through the numpy fleet
  automatically, and checkpoint granularity is per chunk.

Pooled lane chunks are *work-stolen* (ISSUE 7 satellite): instead of
pre-slicing the lanes into fixed >=16-wide chunks (where a ragged
lambda-ladder's slowest chunk idles every other worker at the tail),
workers draw successive chunks from a shared queue, each sized to the
work remaining — wide while the queue is deep, down to
`MIN_FLEET_LANE_WIDTH` near the tail. Chunking is an execution detail:
records (and therefore stores) are byte-identical to the fixed chunker
(`tests/test_experiments.py` pins this).

The process pool is *persistent* (ISSUE 4 satellite): one pool is kept
alive across a plan's chunks and across `--resume` passes instead of
being respawned per `execute_cells` call, and the shared engine factory
ships to each worker once via the pool initializer instead of being
re-pickled into every payload.

Serial fallback is *loud* (ISSUE 2 satellite): an unpicklable factory, a
pool start failure or a broken pool mid-run emits a `RuntimeWarning`
naming the reason before the remaining work degrades to the serial path —
results are identical either way, but silent 1-core runs of a 56-cell
matrix are a footgun.
"""
from __future__ import annotations

import atexit
import collections
import concurrent.futures
import multiprocessing
import pickle
import sys
import warnings
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.records import RunRecord
from repro.core.sweep import SimEngineSpec, run_point
from repro.experiments.plan import Cell, ExperimentPlan
from repro.experiments.store import ExperimentStore, backfill_theta

# max lanes per fleet chunk: wide enough to amortize the vectorized event
# loop, small enough that (lanes x requests) request-stream arrays stay a
# few MB and chunks spread across pool workers
FLEET_LANE_WIDTH = 128
# the jit backend amortizes one compiled program over the whole chunk;
# wider is strictly better until the (lanes x requests) logs hit memory
JIT_LANE_WIDTH = 512
# never split below this under the pool: a chunk's round count is set by
# its slowest lane, so narrow chunks lose the amortization that makes the
# fleet fast (width 1 would be the scalar path plus IPC)
MIN_FLEET_LANE_WIDTH = 16


def fallback_warning(reason: str):
    warnings.warn(
        f"parallel execution unavailable ({reason}); "
        "falling back to the serial path (results are identical, just "
        "single-core)", RuntimeWarning, stacklevel=3)


def default_mp_context() -> str:
    """fork while the parent is JAX-free (sim-tier workers start in ms);
    spawn otherwise — forking a parent with live JAX threads can hang."""
    if ("fork" in multiprocessing.get_all_start_methods()
            and "jax" not in sys.modules):
        return "fork"
    return "spawn"


def run_cell(cell: Cell, factory: Optional[Callable] = None) -> RunRecord:
    """Execute one cell (top-level, so pool workers can import it under
    spawn). `factory` overrides the cell's own SimEngineSpec — that is how
    ladder plans carry arbitrary (even closure) engine factories."""
    return run_point(factory if factory is not None else cell.engine_spec(),
                     cell.arrival_spec(), warmup=cell.warmup,
                     horizon=cell.horizon,
                     failure_times=cell.failure_times,
                     failure_spec=cell.failure_spec(),
                     retry=cell.retry_policy(), **cell.record_kw())


# ---------------------------------------------------------------------------
# persistent worker pool
# ---------------------------------------------------------------------------

_WORKER_FACTORY: Optional[Callable] = None   # set per worker by _worker_init
_POOL: Dict[str, object] = {}                # the one cached pool + its key


def _worker_init(factory_bytes: Optional[bytes]):
    global _WORKER_FACTORY
    _WORKER_FACTORY = (pickle.loads(factory_bytes)
                       if factory_bytes is not None else None)


def _checkpoint_store(checkpoint) -> Optional[ExperimentStore]:
    """Rebuild the plan's store inside a worker from its (plan_name, root)
    checkpoint handle (the store object itself never crosses the pool)."""
    if checkpoint is None:
        return None
    plan_name, root = checkpoint
    return ExperimentStore(plan_name, root=root)


def _pool_task(cell: Cell, checkpoint=None) -> RunRecord:
    """Per-cell pool task; the factory arrived once via `_worker_init`."""
    rec = run_cell(cell, _WORKER_FACTORY)
    store = _checkpoint_store(checkpoint)
    if store is not None:
        store.write_cell(cell, rec)
    return rec


def _fleet_task(points, cells: Optional[List[Cell]] = None,
                checkpoint=None, backend: str = "vector"
                ) -> List[RunRecord]:
    """Fleet-chunk pool task: run a lane chunk in one vectorized engine
    (numpy fleet, or the compiled fleet under ``backend="jit"``).

    With a checkpoint handle, each lane's record is written to the store
    *from the worker* the moment the lane finishes — a chunk killed
    mid-flight (SIGKILL, OOM) loses only its in-flight lanes on resume
    instead of the whole chunk (writes are atomic; the parent's own
    `on_result` write at chunk completion is byte-identical)."""
    if backend == "jit":
        from repro.serving.fleet_jit import jit_run_points as _run
    else:
        from repro.serving.fleet import fleet_run_points as _run
    store = _checkpoint_store(checkpoint)
    if store is None or cells is None:
        return _run(points)

    def _ckpt(j: int, rec: RunRecord):
        store.write_cell(cells[j], rec)

    return _run(points, on_result=_ckpt)


def shutdown_pool(kill: bool = False):
    """Tear down the persistent pool (atexit, tests, broken-pool reset).
    `kill=True` also terminates the worker processes — required when a
    worker is *wedged* (stuck in a task): plain shutdown(wait=False)
    leaves the stuck process alive and the interpreter joins it at exit."""
    pool = _POOL.pop("pool", None)
    _POOL.pop("key", None)
    if pool is not None:
        if kill:
            for proc in getattr(pool, "_processes", {}).values():
                proc.terminate()
        pool.shutdown(wait=False, cancel_futures=True)


atexit.register(shutdown_pool)


def _get_pool(ctx_name: str, requested: int, n_units: int,
              factory: Optional[Callable]):
    """Reuse one ProcessPoolExecutor across execute_cells calls. A fresh
    pool is sized min(requested, n_units) — a 4-cell smoke must not
    spawn a cpu_count-wide pool — but an already-warm pool with the same
    start method and factory is reused whenever it is big enough and
    within the caller's cap: a plan's chunks and its `--resume`
    re-invocations (which usually have *fewer* units left) hit the same
    warm workers instead of paying a respawn."""
    factory_bytes = pickle.dumps(factory) if factory is not None else None
    size = min(requested, max(n_units, 1))
    key = _POOL.get("key")
    if key is not None:
        c_ctx, c_size, c_bytes = key
        if (c_ctx == ctx_name and c_bytes == factory_bytes
                and size <= c_size <= requested):
            return _POOL["pool"]
    shutdown_pool()
    ctx = multiprocessing.get_context(ctx_name)
    pool = concurrent.futures.ProcessPoolExecutor(
        max_workers=size, mp_context=ctx,
        initializer=_worker_init, initargs=(factory_bytes,))
    _POOL["pool"] = pool
    _POOL["key"] = (ctx_name, size, factory_bytes)
    return pool


# ---------------------------------------------------------------------------
# cell execution
# ---------------------------------------------------------------------------


def _fleet_eligible(cell: Cell, factory: Optional[Callable]) -> bool:
    """A cell can ride a fleet lane iff its engine is a sim-tier
    fast-forward SimEngineSpec (the fleet IS the fast-forward scheduler;
    reference-loop cells and closure factories take the per-cell path)."""
    if factory is not None and not isinstance(factory, SimEngineSpec):
        return False
    spec = factory if factory is not None else cell.engine_spec()
    return bool(spec.fast_forward)


def _fleet_point(cell: Cell, factory: Optional[Callable]):
    from repro.serving.fleet import FleetPoint
    spec = factory if isinstance(factory, SimEngineSpec) \
        else cell.engine_spec()
    return FleetPoint(engine=spec, arrivals=cell.arrival_spec(),
                      warmup=cell.warmup, horizon=cell.horizon,
                      failure_times=cell.failure_times,
                      failure_spec=cell.failure_spec(),
                      retry=cell.retry_policy(), **cell.record_kw())


def _chunk(idxs: List[int], width: int) -> List[List[int]]:
    return [idxs[i:i + width] for i in range(0, len(idxs), width)]


def execute_cells(cells: Sequence[Cell], *,
                  factory: Optional[Callable] = None,
                  parallel: bool = True,
                  max_workers: Optional[int] = None,
                  mp_context: Optional[str] = None,
                  backend: str = "process",
                  lane_width: Optional[int] = None,
                  on_result: Optional[Callable[[Cell, RunRecord],
                                               None]] = None,
                  checkpoint=None,
                  worker_timeout: Optional[float] = None
                  ) -> List[RunRecord]:
    """Run `cells`; returns records in cell order. `on_result` fires per
    finished cell *in completion order* (the store hook). The shared
    engine-room of `PlanRunner` and `core.sweep.parallel_sweep`.

    backend="vector" chunks fleet-eligible cells into lanes of the
    vectorized fleet simulator and composes with the pool (lanes x
    cores); records are identical to backend="process" bit-for-bit.
    backend="jit" runs the chunks on the compiled fleet instead
    (tolerance-equivalent records; see `serving.fleet_jit`). Pooled
    chunks are drawn work-stealing from a shared lane queue.

    `checkpoint=(plan_name, store_root)` lets pool *workers* write each
    finished cell to the store themselves (atomic), so a worker killed
    mid-chunk loses only in-flight lanes on `--resume`.

    `worker_timeout` (seconds) bounds how long the dispatcher waits for
    *any* unit to finish before declaring the pool wedged: the pool is
    killed and unfinished cells are re-dispatched on a fresh pool,
    bounded by each cell's `cell_retries` budget.
    """
    if backend not in ("process", "vector", "jit"):
        raise ValueError(f"unknown backend {backend!r}; "
                         "expected 'process', 'vector' or 'jit'")
    if lane_width is not None and lane_width < 1:
        raise ValueError(f"lane_width must be >= 1, got {lane_width}")
    results: Dict[int, RunRecord] = {}

    if parallel and len(cells) > 1:
        try:
            pickle.dumps(factory)
        except (pickle.PicklingError, AttributeError, TypeError) as e:
            fallback_warning(f"engine factory is not picklable: {e!r}")
            parallel = False

    # -- partition work into units (per-cell or fleet chunks) ----------
    if backend in ("vector", "jit"):
        lane_idx = [i for i, c in enumerate(cells)
                    if _fleet_eligible(c, factory)]
        lane_set = set(lane_idx)
        solo_idx = [i for i in range(len(cells)) if i not in lane_set]
        width_cap = lane_width or (JIT_LANE_WIDTH if backend == "jit"
                                   else FLEET_LANE_WIDTH)
    else:
        lane_idx, solo_idx = [], list(range(len(cells)))
        width_cap = FLEET_LANE_WIDTH

    def _run_chunk_serial(chunk: List[int]):
        if backend == "jit":
            from repro.serving.fleet_jit import jit_run_points as _run
        else:
            from repro.serving.fleet import fleet_run_points as _run

        # in-process chunks stream per lane as lanes finish — the store
        # hook fires per cell, so a killed run loses only in-flight lanes
        def _stream(j: int, rec: RunRecord):
            results[chunk[j]] = rec
            if on_result:
                on_result(cells[chunk[j]], rec)

        _run([_fleet_point(cells[i], factory) for i in chunk],
             on_result=_stream)

    def _serial_missing():
        for chunk in _chunk(lane_idx, max(1, width_cap)):
            missing = [i for i in chunk if i not in results]
            if missing:
                _run_chunk_serial(missing)
        for i in solo_idx:
            if i not in results:
                results[i] = run_cell(cells[i], factory)
                if on_result:
                    on_result(cells[i], results[i])

    # pool sizing: every solo cell is a unit; lanes count as the number
    # of minimum-width chunks they could split into under work stealing
    n_units = -(-len(lane_idx) // MIN_FLEET_LANE_WIDTH) + len(solo_idx)
    if parallel and n_units > 1:
        ctx_name = mp_context or default_mp_context()
        attempts: Dict[int, int] = {}      # per-cell re-dispatch count
        todo_lanes, todo_solo = list(lane_idx), list(solo_idx)
        while todo_lanes or todo_solo:
            try:
                pool = _get_pool(ctx_name,
                                 max_workers or multiprocessing.cpu_count(),
                                 n_units, factory)
            except (ValueError, OSError) as e:
                fallback_warning(f"process pool failed to start: {e!r}")
                break
            pool_size = _POOL["key"][1]
            # -- work-stealing lane queue (ISSUE 7 satellite) ---------
            # workers draw chunks sized to the remaining queue: wide
            # while there is plenty (amortization), narrowing toward
            # MIN_FLEET_LANE_WIDTH at the tail so a ragged ladder's
            # final lanes spread across workers instead of riding one
            # slow chunk. Chunk composition cannot change any record —
            # lanes are independent — so stores stay byte-identical to
            # the fixed chunker.
            queue = collections.deque(todo_lanes)
            futs = {}
            pending = set()

            def _steal_chunk():
                if not queue:
                    return
                w = max(MIN_FLEET_LANE_WIDTH,
                        min(width_cap,
                            -(-len(queue) // (2 * pool_size))))
                chunk = [queue.popleft()
                         for _ in range(min(w, len(queue)))]
                fut = pool.submit(_fleet_task,
                                  [_fleet_point(cells[i], factory)
                                   for i in chunk],
                                  [cells[i] for i in chunk]
                                  if checkpoint else None,
                                  checkpoint, backend)
                futs[fut] = chunk
                pending.add(fut)

            # keep 2 chunks per worker outstanding so a completion never
            # leaves a worker idle while the dispatcher wakes up
            for _ in range(2 * pool_size):
                _steal_chunk()
            for i in todo_solo:
                fut = pool.submit(_pool_task, cells[i], checkpoint)
                futs[fut] = i
                pending.add(fut)
            reason = None
            try:
                while pending:
                    done, _ = concurrent.futures.wait(
                        pending, timeout=worker_timeout,
                        return_when=concurrent.futures.FIRST_COMPLETED)
                    if not done:
                        reason = (f"no unit finished within "
                                  f"{worker_timeout:g}s (wedged worker)")
                        break
                    for fut in concurrent.futures.as_completed(done):
                        tag = futs[fut]
                        # a cell's *own* exception is not in the tuple
                        # below — it propagates, failing fast instead of
                        # silently re-running single-core
                        res = fut.result()
                        pending.discard(fut)
                        if isinstance(tag, list):
                            for i, rec in zip(tag, res):
                                results[i] = rec
                                if on_result:
                                    on_result(cells[i], rec)
                            _steal_chunk()     # refill the worker
                        else:
                            results[tag] = res
                            if on_result:
                                on_result(cells[tag], res)
            except (concurrent.futures.process.BrokenProcessPool,
                    pickle.PicklingError, EOFError) as e:
                reason = repr(e)
            finally:
                for fut in futs:
                    fut.cancel()
            if reason is None:
                break
            # pool *infrastructure* died (or wedged): kill the cached
            # pool, keep whatever finished (already reported through
            # on_result) and re-dispatch only the unfinished cells on a
            # fresh pool. Dispatched-but-unfinished cells consume their
            # `cell_retries` budget; cells still in the steal queue were
            # never dispatched and re-enter free. Over-budget cells fall
            # through to the serial path below.
            shutdown_pool(kill=True)
            queued = set(queue)
            todo_lanes, todo_solo, spent = [], [], []
            for tag in futs.values():
                idx_list = tag if isinstance(tag, list) else [tag]
                missing = [i for i in idx_list if i not in results]
                if not missing:
                    continue
                retry_ok = []
                for i in missing:
                    attempts[i] = attempts.get(i, 0) + 1
                    (retry_ok if attempts[i] <= cells[i].cell_retries
                     else spent).append(i)
                if isinstance(tag, list):
                    todo_lanes.extend(retry_ok)
                elif retry_ok:
                    todo_solo.append(tag)
            todo_lanes.extend(sorted(queued))
            n_left = len(todo_lanes) + len(todo_solo)
            if not (n_left or spent):
                break                     # pool died after the last unit
            warnings.warn(
                f"process pool failed ({reason}); re-dispatching {n_left} "
                f"unfinished cell(s) on a fresh pool"
                + (f"; {len(spent)} cell(s) exhausted their re-dispatch "
                   "budget and fall back to the serial path" if spent
                   else ""),
                RuntimeWarning, stacklevel=2)
    if len(results) < len(cells):
        _serial_missing()
    return [results[i] for i in range(len(cells))]


class PlanRunner:
    """Execute an ExperimentPlan against a resumable store.

    With `store=None` the runner is a pure in-memory fan-out (what the
    refactored `lambda_sweep`/`parallel_sweep` use); with a store, each
    finished cell lands on disk immediately and `run(resume=True)` skips
    cells whose stored fingerprint still matches the plan.
    """

    def __init__(self, plan: ExperimentPlan,
                 store: Optional[ExperimentStore] = None,
                 factory: Optional[Callable] = None):
        self.plan = plan
        self.store = store
        self.factory = factory

    def run(self, *, resume: bool = True, parallel: bool = True,
            max_workers: Optional[int] = None,
            mp_context: Optional[str] = None,
            backend: str = "process",
            lane_width: Optional[int] = None,
            worker_timeout: Optional[float] = None,
            progress: Optional[Callable[[Cell, RunRecord, int, int],
                                        None]] = None
            ) -> List[RunRecord]:
        """Run (the remainder of) the plan; returns plan-ordered records
        with theta_max back-filled per ladder group."""
        done: Dict[str, RunRecord] = {}
        if self.store is not None and resume:
            done = self.store.load_cell_records(self.plan)
        todo = [c for c in self.plan.cells if c.cell_id not in done]
        n_done = len(done)

        def _on_result(cell: Cell, rec: RunRecord):
            nonlocal n_done
            n_done += 1
            if self.store is not None:
                self.store.write_cell(cell, rec)
            if progress is not None:
                progress(cell, rec, n_done, len(self.plan.cells))

        checkpoint = None
        if self.store is not None:
            checkpoint = (self.store.plan_name, str(self.store.root))
        fresh = execute_cells(todo, factory=self.factory, parallel=parallel,
                              max_workers=max_workers, mp_context=mp_context,
                              backend=backend, lane_width=lane_width,
                              on_result=_on_result, checkpoint=checkpoint,
                              worker_timeout=worker_timeout)
        done.update({c.cell_id: r for c, r in zip(todo, fresh)})
        if self.store is not None:
            return self.store.consolidate(self.plan)
        return backfill_theta(self.plan, done)
