"""PlanRunner: shard whole experiment cells across the process pool.

Generalizes PR 1's ladder-point pool to arbitrary cells: every cell is an
independent (engine, arrival stream) measurement, so a plan fans out
cell-at-a-time with the same start-method policy as `parallel_sweep`
(fork when the parent is still JAX-free, spawn otherwise). Results stream
back in completion order and are written to the store immediately;
ordering of the returned list always follows the plan.

Serial fallback is *loud* (ISSUE 2 satellite): an unpicklable factory, a
pool start failure or a broken pool mid-run emits a `RuntimeWarning`
naming the reason before the remaining work degrades to the serial path —
results are identical either way, but silent 1-core runs of a 56-cell
matrix are a footgun.
"""
from __future__ import annotations

import concurrent.futures
import multiprocessing
import pickle
import sys
import warnings
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.records import RunRecord
from repro.core.sweep import run_point
from repro.experiments.plan import Cell, ExperimentPlan
from repro.experiments.store import ExperimentStore, backfill_theta


def fallback_warning(reason: str):
    warnings.warn(
        f"parallel execution unavailable ({reason}); "
        "falling back to the serial path (results are identical, just "
        "single-core)", RuntimeWarning, stacklevel=3)


def default_mp_context() -> str:
    """fork while the parent is JAX-free (sim-tier workers start in ms);
    spawn otherwise — forking a parent with live JAX threads can hang."""
    if ("fork" in multiprocessing.get_all_start_methods()
            and "jax" not in sys.modules):
        return "fork"
    return "spawn"


def run_cell(cell: Cell, factory: Optional[Callable] = None) -> RunRecord:
    """Execute one cell (top-level, so pool workers can import it under
    spawn). `factory` overrides the cell's own SimEngineSpec — that is how
    ladder plans carry arbitrary (even closure) engine factories."""
    return run_point(factory if factory is not None else cell.engine_spec(),
                     cell.arrival_spec(), warmup=cell.warmup,
                     horizon=cell.horizon,
                     failure_times=cell.failure_times, **cell.record_kw())


def _pool_task(payload: Tuple[Cell, Optional[Callable]]) -> RunRecord:
    cell, factory = payload
    return run_cell(cell, factory)


def execute_cells(cells: Sequence[Cell], *,
                  factory: Optional[Callable] = None,
                  parallel: bool = True,
                  max_workers: Optional[int] = None,
                  mp_context: Optional[str] = None,
                  on_result: Optional[Callable[[Cell, RunRecord],
                                               None]] = None
                  ) -> List[RunRecord]:
    """Run `cells`, fanned across a process pool when possible; returns
    records in cell order. `on_result` fires per finished cell *in
    completion order* (the store hook). The shared engine-room of both
    `PlanRunner` and `core.sweep.parallel_sweep`."""
    payloads = [(c, factory) for c in cells]
    results: Dict[int, RunRecord] = {}

    def _serial(idxs):
        for i in idxs:
            results[i] = _pool_task(payloads[i])
            if on_result:
                on_result(cells[i], results[i])

    if parallel and len(payloads) > 1:
        try:
            pickle.dumps(payloads[0])
        except (pickle.PicklingError, AttributeError, TypeError) as e:
            fallback_warning(f"engine factory is not picklable: {e!r}")
            parallel = False
    if parallel and len(payloads) > 1:
        ctx_name = mp_context or default_mp_context()
        pool = None
        try:
            ctx = multiprocessing.get_context(ctx_name)
            pool = concurrent.futures.ProcessPoolExecutor(
                max_workers=max_workers or min(len(payloads),
                                               multiprocessing.cpu_count()),
                mp_context=ctx)
        except (ValueError, OSError) as e:
            fallback_warning(f"process pool failed to start: {e!r}")
        if pool is not None:
            with pool:
                futs = {pool.submit(_pool_task, p): i
                        for i, p in enumerate(payloads)}
                try:
                    for fut in concurrent.futures.as_completed(futs):
                        i = futs[fut]
                        results[i] = fut.result()
                        if on_result:
                            on_result(cells[i], results[i])
                except (concurrent.futures.process.BrokenProcessPool,
                        pickle.PicklingError, EOFError) as e:
                    # pool *infrastructure* died: keep whatever finished
                    # (already reported through on_result) and run only the
                    # missing cells serially. A cell's own exception is not
                    # in this tuple — it propagates, failing fast instead
                    # of silently re-running the matrix single-core.
                    fallback_warning(f"process pool failed: {e!r}")
    if len(results) < len(payloads):
        _serial([i for i in range(len(payloads)) if i not in results])
    return [results[i] for i in range(len(payloads))]


class PlanRunner:
    """Execute an ExperimentPlan against a resumable store.

    With `store=None` the runner is a pure in-memory fan-out (what the
    refactored `lambda_sweep`/`parallel_sweep` use); with a store, each
    finished cell lands on disk immediately and `run(resume=True)` skips
    cells whose stored fingerprint still matches the plan.
    """

    def __init__(self, plan: ExperimentPlan,
                 store: Optional[ExperimentStore] = None,
                 factory: Optional[Callable] = None):
        self.plan = plan
        self.store = store
        self.factory = factory

    def run(self, *, resume: bool = True, parallel: bool = True,
            max_workers: Optional[int] = None,
            mp_context: Optional[str] = None,
            progress: Optional[Callable[[Cell, RunRecord, int, int],
                                        None]] = None
            ) -> List[RunRecord]:
        """Run (the remainder of) the plan; returns plan-ordered records
        with theta_max back-filled per ladder group."""
        done: Dict[str, RunRecord] = {}
        if self.store is not None and resume:
            done = self.store.load_cell_records(self.plan)
        todo = [c for c in self.plan.cells if c.cell_id not in done]
        n_done = len(done)

        def _on_result(cell: Cell, rec: RunRecord):
            nonlocal n_done
            n_done += 1
            if self.store is not None:
                self.store.write_cell(cell, rec)
            if progress is not None:
                progress(cell, rec, n_done, len(self.plan.cells))

        fresh = execute_cells(todo, factory=self.factory, parallel=parallel,
                              max_workers=max_workers, mp_context=mp_context,
                              on_result=_on_result)
        done.update({c.cell_id: r for c, r in zip(todo, fresh)})
        if self.store is not None:
            return self.store.consolidate(self.plan)
        return backfill_theta(self.plan, done)
