"""CLI: run an experiment plan against the resumable store.

    PYTHONPATH=src python -m repro.experiments.run --plan paper_a100 --resume
    PYTHONPATH=src python -m repro.experiments.run --plan mini_2x2 --analyze
    PYTHONPATH=src python -m repro.experiments.run --plan paper_crosshw \
        --resume --analyze --analyze-json

Resume is the default: re-invoking after a kill finishes only the
remaining cells and re-derives an identical consolidated CSV. `--fresh`
prunes cell files orphaned by plan edits (`store.prune`) and re-runs
(overwriting) every current cell instead. `--analyze-json` persists
the cross-hardware tables (spread compression, fp8 inversion, ordering
survival, planner payload) as `analysis.json` beside the store.
"""
from __future__ import annotations

import argparse
import time

from repro.experiments.analyze import report, write_tables
from repro.experiments.plans import PLANS, get_plan
from repro.experiments.runner import PlanRunner
from repro.experiments.store import ExperimentStore


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--plan", required=True,
                    help=f"one of: {', '.join(sorted(PLANS))}")
    ap.add_argument("--resume", action="store_true", default=True,
                    help="skip cells already in the store (default)")
    ap.add_argument("--fresh", dest="resume", action="store_false",
                    help="prune orphaned cell files, then re-run every "
                         "cell, overwriting stored results")
    ap.add_argument("--serial", action="store_true",
                    help="disable the process pool")
    ap.add_argument("--backend", default="process",
                    choices=("process", "vector", "jit"),
                    help="cell execution backend: per-cell process pool, "
                         "the vectorized numpy fleet (lanes x cores; "
                         "identical records, ~6x cells/s/core), or the "
                         "jit-compiled JAX fleet (tolerance-identical "
                         "records, ~4x the vector backend at 256 lanes)")
    ap.add_argument("--lane-width", type=int, default=None,
                    help="max cells per fleet chunk (vector/jit backends)")
    ap.add_argument("--workers", type=int, default=None)
    ap.add_argument("--mp-context", default=None,
                    choices=(None, "fork", "spawn", "forkserver"))
    ap.add_argument("--root", default=None,
                    help="store root (default results/experiments)")
    ap.add_argument("--analyze", action="store_true",
                    help="print the paper-figure report after the run")
    ap.add_argument("--analyze-json", action="store_true",
                    help="write the cross-hardware tables to "
                         "<store>/analysis.json after the run")
    ap.add_argument("--verify", action="store_true",
                    help="integrity-check the store against the plan "
                         "(torn/stale/orphaned cell files) and exit; "
                         "nonzero exit status on any issue")
    ap.add_argument("--worker-timeout", type=float, default=None,
                    help="seconds without any finished unit before the "
                         "pool is declared wedged, killed, and unfinished "
                         "cells re-dispatched (per-cell retry budget)")
    args = ap.parse_args(argv)

    plan = get_plan(args.plan)
    store = ExperimentStore(plan.name, args.root)
    if args.verify:
        res = store.verify(plan)
        for line in res["issues"]:
            print(f"ISSUE   {line}")
        for line in res["missing"]:
            print(f"missing {line}")
        print(f"store {store.dir}: {len(res['issues'])} issue(s), "
              f"{len(res['missing'])} of {len(plan.cells)} cells missing")
        return 1 if res["issues"] else 0
    if not args.resume and store.dir.exists():
        # --fresh also clears orphaned cell files (a plan edit renames
        # cell ids; superseded files would otherwise accumulate forever)
        pruned = store.prune(plan)
        if pruned:
            print(f"pruned {len(pruned)} stale cell file(s) from "
                  f"{store.dir}")
    already = len(store.completed_ids(plan)) if args.resume else 0
    print(f"plan {plan.name}: {len(plan.cells)} cells "
          f"({already} already in store at {store.dir})")

    t0 = time.time()

    def progress(cell, rec, n_done, n_total):
        print(f"[{n_done:>3}/{n_total}] {cell.cell_id:<46} "
              f"tps={rec.tps:>8.1f} c_eff=${rec.c_eff:>8.3f}", flush=True)

    runner = PlanRunner(plan, store=store)
    records = runner.run(resume=args.resume, parallel=not args.serial,
                         max_workers=args.workers,
                         mp_context=args.mp_context, backend=args.backend,
                         lane_width=args.lane_width,
                         worker_timeout=args.worker_timeout,
                         progress=progress)
    print(f"\n{len(records)}/{len(plan.cells)} cells consolidated to "
          f"{store.csv_path} in {time.time() - t0:.1f}s")
    if args.analyze:
        print()
        print(report(records, title=plan.name))
    if args.analyze_json:
        path = store.dir / "analysis.json"
        write_tables(records, path)
        print(f"cross-hardware tables written to {path}")


if __name__ == "__main__":
    raise SystemExit(main())
