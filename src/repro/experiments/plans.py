"""First-class experiment plans: the paper's benchmark matrices.

The paper's headline matrices are 42 H100 cells and 56 A100 cells over
(model, quant, lambda) — 6 resp. 8 (model, quant) combinations times the
7-point lambda ladder. Per DESIGN §3 the hardware axis maps onto TPU
generations: H100 NVL -> tpu-v5p (fast, pricey, 95 GB), A100 PCIe ->
tpu-v5e (slow, cheap, 16 GB). Both parts emulate fp8 (no native fp8
MXU path), reproducing the paper's hardware-conditional quantization
caveat: the HBM win survives, the compute path pays a dequant penalty, so
compute-bound dense models can invert while memory-bound MoEs still gain.

`paper_crosshw` (ISSUE 3) replicates the paper's §5.9/§7 cross-hardware
argument in one plan: the same trio across v5e + v5p + the native-fp8
v6e, with per-(arch, hw) TP degrees, so the spread-compression and
FP8-inversion tables derive from a single store.

TP degrees are chosen so bf16 weights fit the part's HBM (the sim tier
does not enforce fit, but cross-cell $/token comparisons are only
meaningful for deployable footprints); price_per_hr scales with chips.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

from repro.core.pricing import chip_hour_price
from repro.core.sweep import LAMBDA_LADDER
from repro.experiments.plan import Cell, ExperimentPlan, GridSpec, cell_seed
from repro.serving.autoscale import DAY_SCENARIOS, DayScenario

# paper benchmark trio: dense 8B / ultra-sparse 30B-A3B MoE / 47B-A13B MoE
PAPER_TRIO = ("llama31-8b", "qwen3-30b-a3b", "mixtral-8x7b")

# the cross-hardware TP footprints (bf16 weights fit each part's HBM),
# shared by paper_crosshw / paper_atlas / probe_int8_nonnative
CROSSHW_CHIPS = (
    ("llama31-8b", "tpu-v5e", 2),
    ("qwen3-30b-a3b", "tpu-v5e", 8),
    ("mixtral-8x7b", "tpu-v5e", 8),
    ("llama31-8b", "tpu-v5p", 1),
    ("qwen3-30b-a3b", "tpu-v5p", 1),
    ("mixtral-8x7b", "tpu-v5p", 2),
    ("llama31-8b", "tpu-v6e", 1),
    ("qwen3-30b-a3b", "tpu-v6e", 2),
    ("mixtral-8x7b", "tpu-v6e", 4),
)

# 25-point log-spaced lambda continuum, 1..200 req/s (the 7-point paper
# ladder's idle->saturation span, densified so the penalty curve is a
# curve instead of seven samples). Frozen literal: ladder values feed the
# per-cell seed derivation (int(lam*1000)), so they must never drift
# with numpy versions.
ATLAS_LADDER = (
    1.0, 1.25, 1.56, 1.94, 2.42, 3.02, 3.76, 4.69, 5.85, 7.29, 9.09,
    11.34, 14.14, 17.64, 21.99, 27.42, 34.2, 42.65, 53.18, 66.32, 82.7,
    103.13, 128.61, 160.38, 200.0,
)


def paper_h100() -> ExperimentPlan:
    """42 cells: 3 models x 2 quants x 7-lambda ladder on tpu-v5p."""
    return GridSpec(
        name="paper_h100",
        description="H100-analogue matrix (paper §5): 3 models x "
                    "{bf16, fp8} x 7-point ladder on tpu-v5p",
        archs=PAPER_TRIO,
        hws=("tpu-v5p",),
        quants=("bf16", "fp8"),
        ladder=LAMBDA_LADDER,
        n_chips_by_arch=(("llama31-8b", 1), ("qwen3-30b-a3b", 1),
                         ("mixtral-8x7b", 2)),
        seed=0,
        protocol="paper",
    ).expand()


def paper_a100() -> ExperimentPlan:
    """56 cells: 4 models x 2 quants x 7-lambda ladder on tpu-v5e.

    The extra dense mid-size model (phi3-medium-14b) widens the
    active-params ordering probe on the cheaper part, giving the 8-combo
    A100-analogue matrix of the paper."""
    return GridSpec(
        name="paper_a100",
        description="A100-analogue matrix (paper §5): 4 models x "
                    "{bf16, fp8} x 7-point ladder on tpu-v5e",
        archs=PAPER_TRIO + ("phi3-medium-14b",),
        hws=("tpu-v5e",),
        quants=("bf16", "fp8"),
        ladder=LAMBDA_LADDER,
        n_chips_by_arch=(("llama31-8b", 2), ("phi3-medium-14b", 4),
                         ("qwen3-30b-a3b", 8), ("mixtral-8x7b", 8)),
        seed=0,
        protocol="paper",
    ).expand()


def paper_crosshw() -> ExperimentPlan:
    """126 cells: 3 models x 3 hardware generations x {bf16, fp8} x
    7-lambda ladder — the paper's §5.9/§7 cross-hardware replication as
    ONE plan over ONE store.

    TP degrees fit bf16 weights to each part's HBM (v5p 95 GB, v6e 32 GB,
    v5e 16 GB), so the cross-hardware $/token comparison stays deployable.
    v6e is the native-fp8 entry: the fp8 uplift must NOT invert there,
    while the fp8-emulating v5e/v5p parts reproduce the paper's dense
    inversion — `analyze.fp8_inversion` conditions on exactly this."""
    return GridSpec(
        name="paper_crosshw",
        description="cross-hardware matrix (paper §5.9/§7): 3 models x "
                    "{tpu-v5e, tpu-v5p, tpu-v6e} x {bf16, fp8} x "
                    "7-point ladder, per-hardware TP",
        archs=PAPER_TRIO,
        hws=("tpu-v5e", "tpu-v5p", "tpu-v6e"),
        quants=("bf16", "fp8"),
        ladder=LAMBDA_LADDER,
        n_chips_by_arch_hw=CROSSHW_CHIPS,
        seed=0,
        protocol="paper",
    ).expand()


def paper_atlas() -> ExperimentPlan:
    """450 cells: 3 models x 3 hardware generations x {bf16, fp8} x the
    25-point log-spaced lambda *continuum* — the dense "penalty atlas"
    (ISSUE 4).

    The paper's core claim is a curve (C_eff spans 2.5-36x driven by
    lambda), but the 7-point ladder only samples it; related work prices
    over ever-larger scenario products (Melange's hw x model x load
    search, WiNGPT's swept economics), so the atlas densifies the load
    axis 3.6x at the same per-cell protocol. Feasible as one command
    because the fleet backend makes a 450-cell plan cost a few dozen
    cell-equivalents of wall time:

        python -m repro.experiments.run --plan paper_atlas \\
            --backend vector --resume --analyze

    `analyze.penalty_atlas` consumes the store: per (model, hw, quant)
    the dense lambda -> penalty curve, its knee (first lambda within 25%
    of the cost floor) and the idle/saturation spread that the PR-3
    spread-compression table only samples at 7 points."""
    return GridSpec(
        name="paper_atlas",
        description="dense penalty atlas: 3 models x {v5e, v5p, v6e} x "
                    "{bf16, fp8} x 25-point log-spaced lambda continuum",
        archs=PAPER_TRIO,
        hws=("tpu-v5e", "tpu-v5p", "tpu-v6e"),
        quants=("bf16", "fp8"),
        ladder=ATLAS_LADDER,
        n_chips_by_arch_hw=CROSSHW_CHIPS,
        seed=0,
        protocol="paper",
    ).expand()


def paper_ensemble() -> ExperimentPlan:
    """2016 cells: every `paper_atlas` (model, hw, quant) group x the
    7-point paper ladder x 16 independent arrival seeds — the
    Monte-Carlo ensemble behind the confidence bands (ISSUE 7).

    The paper's headline numbers carry an n=3 caveat; this plan resolves
    it inside the repo by replicating all 18 atlas groups at N=16 seeds
    so `analyze.ensemble_bands` can bootstrap confidence bands on the
    penalty / utilization / C_eff curves (threaded into the planner's
    deployment curves and `analysis.json`). Quick protocol keeps the
    per-cell cost ~10x below paper tier; at 2016 cells the plan is only
    tractable because of the jit fleet backend:

        python -m repro.experiments.run --plan paper_ensemble \\
            --backend jit --resume --analyze-json
    """
    return GridSpec(
        name="paper_ensemble",
        description="Monte-Carlo ensemble: 3 models x {v5e, v5p, v6e} x "
                    "{bf16, fp8} x 7-point ladder x 16 arrival seeds",
        archs=PAPER_TRIO,
        hws=("tpu-v5e", "tpu-v5p", "tpu-v6e"),
        quants=("bf16", "fp8"),
        ladder=LAMBDA_LADDER,
        n_chips_by_arch_hw=CROSSHW_CHIPS,
        seed_offsets=tuple(range(16)),
        seed=0,
        protocol="quick",
    ).expand()


def mini_ensemble() -> ExperimentPlan:
    """CI smoke for the ensemble axis: the mini_2x2 grid x 4 arrival
    seeds, smoke-tier traffic (16 cells). Enough replicates for
    `analyze.ensemble_bands` to emit finite (non-degenerate) bands."""
    return GridSpec(
        name="mini_ensemble",
        description="ensemble CI smoke: 2 archs x 2 lambdas x 4 arrival "
                    "seeds (sim tier)",
        archs=("llama31-8b", "qwen3-30b-a3b"),
        hws=("tpu-v5e",),
        quants=("bf16",),
        ladder=(5, 50),
        seed_offsets=(0, 1, 2, 3),
        seed=0,
        protocol="smoke",
        max_batch=64,
        num_pages=8192,
    ).expand()


def probe_int8_nonnative() -> ExperimentPlan:
    """126 cells exercising `quants_by_hw` at paper scale (ROADMAP PR-3
    follow-up): int8 — the natively-accelerated low-precision format on
    every TPU part — is probed on the fp8-*emulating* generations (v5e,
    v5p), while the native-fp8 v6e keeps its fp8 path; bf16 is the
    baseline everywhere. Per-hardware quant allow-lists carve 126 cells
    out of the full 189-cell product, reproducing the paper's §5.9
    guidance that the Q axis should follow each part's native formats."""
    return GridSpec(
        name="probe_int8_nonnative",
        description="int8-on-non-native-fp8 probe: per-hw quant "
                    "allow-lists (v5e/v5p: bf16+int8, v6e: bf16+fp8), "
                    "3 models x 7-ladder",
        archs=PAPER_TRIO,
        hws=("tpu-v5e", "tpu-v5p", "tpu-v6e"),
        quants=("bf16", "int8", "fp8"),
        ladder=LAMBDA_LADDER,
        n_chips_by_arch_hw=CROSSHW_CHIPS,
        quants_by_hw=(
            ("tpu-v5e", ("bf16", "int8")),
            ("tpu-v5p", ("bf16", "int8")),
            ("tpu-v6e", ("bf16", "fp8")),
        ),
        seed=0,
        protocol="paper",
    ).expand()


def paper_resilience() -> ExperimentPlan:
    """Pricing reliability (ISSUE 6): what failures, retries and shedding
    do to $/M *delivered* tokens.

    Grid A (48 cells): the core dense model on its cheap-part footprint
    (llama31-8b @ tpu-v5e x2), 3-lambda ladder x MTTF ladder
    {none, 40, 15, 6 s} x retry {off, 3 attempts with capped backoff}.
    Every resilient cell runs with a queue-depth cap so shed arrivals and
    crash-killed requests both feed the client retry loop; the
    (mttf=0, retry=0) column is the failure-free baseline
    `analyze.reliability_tables` normalizes inflation against.

    Grid B (14 cells): the same model priced failure-free on both its
    v5e and v5p footprints over the full 7-point ladder — the deployment
    curves `planner --availability` reprices with N+1 spares, so the
    cheapest failure-free footprint can flip under an availability target.
    """
    grid_a = GridSpec(
        name="paper_resilience",
        description="reliability pricing: llama31-8b @ tpu-v5e x2, "
                    "3-lambda x MTTF {0,40,15,6} x retry {0,3} under "
                    "admission control; + failure-free v5e/v5p ladders "
                    "for availability-aware planning",
        archs=("llama31-8b",),
        hws=("tpu-v5e",),
        quants=("bf16",),
        ladder=(5.0, 10.0, 25.0),
        n_chips=2,
        mttfs=(0.0, 40.0, 15.0, 6.0),
        retry_maxes=(0, 3),
        mttr=2.0,
        fail_frac=0.5,
        retry_base_s=0.25,
        max_queue_depth=512,
        seed=0,
        protocol="quick",
    ).expand()
    grid_b = GridSpec(
        name="paper_resilience",
        archs=("llama31-8b",),
        hws=("tpu-v5e", "tpu-v5p"),
        quants=("bf16",),
        ladder=LAMBDA_LADDER,
        n_chips_by_arch_hw=CROSSHW_CHIPS,
        seed=0,
        protocol="quick",
    ).expand()
    # grid A's failure-free baselines at lam {5,10,25} are the same cells
    # as grid B's v5e ladder points — keep the first copy of each id.
    seen = {c.cell_id for c in grid_a.cells}
    extra = tuple(c for c in grid_b.cells if c.cell_id not in seen)
    return ExperimentPlan(
        name="paper_resilience",
        cells=grid_a.cells + extra,
        seed=0,
        description=grid_a.description)


def mini_resilience() -> ExperimentPlan:
    """CI smoke for the resilience axes: 1 model x 1 lambda x
    MTTF {0, 10} x retry {0, 2}, smoke-tier traffic (4 cells)."""
    return GridSpec(
        name="mini_resilience",
        description="resilience CI smoke: llama31-8b, lam=10, "
                    "mttf {0,4} x retry {0,2} (sim tier)",
        archs=("llama31-8b",),
        hws=("tpu-v5e",),
        quants=("bf16",),
        ladder=(10,),
        mttfs=(0.0, 4.0),
        retry_maxes=(0, 2),
        mttr=1.0,
        retry_base_s=0.25,
        max_queue_depth=64,
        seed=0,
        protocol="smoke",
        max_batch=64,
        num_pages=8192,
    ).expand()


def mini_crosshw() -> ExperimentPlan:
    """CI smoke for the cross-hardware axis: 2 models x {v5e, v6e} x
    {bf16, fp8} x 2 lambdas, smoke-tier traffic (16 cells). Exercises the
    per-(arch, hw) TP override and both native-fp8 regimes."""
    return GridSpec(
        name="mini_crosshw",
        description="cross-hardware CI smoke: 2 models x 2 hw x "
                    "{bf16, fp8} x 2 lambdas (sim tier)",
        archs=("llama31-8b", "qwen3-30b-a3b"),
        hws=("tpu-v5e", "tpu-v6e"),
        quants=("bf16", "fp8"),
        ladder=(5, 50),
        n_chips_by_arch_hw=(("qwen3-30b-a3b", "tpu-v5e", 2),),
        seed=0,
        protocol="smoke",
        max_batch=64,
        num_pages=8192,
    ).expand()


def mini_2x2() -> ExperimentPlan:
    """CI smoke: 2 archs x 2 lambdas, smoke-tier traffic (4 cells)."""
    return GridSpec(
        name="mini_2x2",
        description="2x2 CI smoke matrix (sim tier)",
        archs=("llama31-8b", "qwen3-30b-a3b"),
        hws=("tpu-v5e",),
        quants=("bf16",),
        ladder=(5, 50),
        seed=0,
        protocol="smoke",
        max_batch=64,
        num_pages=8192,
    ).expand()


def quickstart() -> ExperimentPlan:
    """The quickstart example's single-model ladder as a stored plan."""
    return GridSpec(
        name="quickstart",
        description="quickstart: llama31-8b on tpu-v5e, quick protocol",
        archs=("llama31-8b",),
        hws=("tpu-v5e",),
        quants=("bf16",),
        ladder=(1, 5, 10, 25, 50, 100),
        seed=0,
        protocol="quick",
    ).expand()


def _day_cells(scenario: DayScenario, *, plan_name: str, max_requests: int,
               min_requests: int, max_batch: int = 256,
               num_pages: int = 65536, seed: int = 0) -> Tuple[Cell, ...]:
    """Expand a DayScenario into its measurement cells.

    The windows of a piecewise-constant day are stationary segments, so
    the store measures POLICY-AGNOSTIC stationary points: for each
    deployment, one cell per distinct quantized per-replica rate that
    any trajectory (static or policy) visits — `scenario.rate_ladder` is
    the shared source of truth, so `analyze.diurnal_tables` can map
    every (window, policy) back to its record. Each cell captures about
    one window's worth of traffic (lam x window_s requests, clamped)."""
    cells = []
    for dep in scenario.deployments:
        for lam in scenario.rate_ladder(dep):
            n = int(min(max_requests,
                        max(min_requests, round(lam * scenario.window_s))))
            cell = Cell(
                plan=plan_name, config=f"day:{scenario.name}",
                model=dep.model, arch=dep.model, hw=dep.hw,
                quant=dep.quant, n_chips=dep.n_chips, lam=float(lam),
                io_shape="chat", seed=0, n_requests=n, warmup=0,
                price_per_hr=dep.price_per_hr, max_batch=max_batch,
                num_pages=num_pages)
            cells.append(dataclasses.replace(
                cell, seed=cell_seed(seed, cell.seed_key, cell.lam)))
    return tuple(cells)


def paper_diurnal() -> ExperimentPlan:
    """The "cost of a day of traffic" store (ISSUE 8): every stationary
    per-replica rate the `paper_day` scenario's trajectories visit —
    24 windows x (static + reactive + cautious autoscaling) x 2
    deployments, deduplicated to the distinct quantized rates
    (~60 cells). `analyze.diurnal_tables` recomputes the fleet
    trajectories (pure) and prices each policy's day from these
    measurements; the committed profile is chosen so the
    static-vs-autoscaled verdict FLIPS between the two deployments.

        python -m repro.experiments.run --plan paper_diurnal \\
            --backend vector --resume --analyze
    """
    sc = DAY_SCENARIOS["paper_day"]
    return ExperimentPlan(
        name="paper_diurnal",
        cells=_day_cells(sc, plan_name="paper_diurnal",
                         max_requests=5000, min_requests=40),
        seed=0,
        description="cost of a day of traffic: per-replica stationary "
                    "rates for the paper_day 24h profile, static + 2 "
                    "autoscaling policies x 2 deployments")


def mini_diurnal() -> ExperimentPlan:
    """CI smoke for the non-stationary layer: the `mini_day` scenario's
    rate ladder at smoke tier (including a zero-rate window priced as
    idle), plus two profile-bearing cells — a trace replay and a diurnal
    sinusoid — that push lambda(t) streams through the fleet backend
    end to end."""
    sc = DAY_SCENARIOS["mini_day"]
    cells = list(_day_cells(sc, plan_name="mini_diurnal", max_requests=150,
                            min_requests=16, max_batch=64, num_pages=8192))
    dep = sc.deployments[0]
    t, knots = 0.0, []
    for r in sc.window_rates:
        knots.append((t, r))
        t += sc.window_s
    # the `profile:` config prefix marks non-stationary records: their
    # `lam` is the nominal mean of lambda(t), not a stationary offered
    # rate, so stationary analytics (_groups / fit_curves) skip them
    for config, kind, kn, period, args in (
            ("profile:trace_smoke", "trace", tuple(knots), sc.day_s, ()),
            ("profile:diurnal_smoke", "diurnal", (), 120.0,
             (1.0, 8.0, 0.5))):
        cell = Cell(
            plan="mini_diurnal", config=config, model=dep.model,
            arch=dep.model, hw=dep.hw, quant=dep.quant,
            n_chips=dep.n_chips, lam=4.0, io_shape="chat", seed=0,
            n_requests=120, warmup=0, price_per_hr=dep.price_per_hr,
            max_batch=64, num_pages=8192, profile_kind=kind,
            profile_knots=kn, profile_period_s=period, profile_args=args)
        cells.append(dataclasses.replace(
            cell, seed=cell_seed(0, cell.seed_key, cell.lam)))
    return ExperimentPlan(
        name="mini_diurnal", cells=tuple(cells), seed=0,
        description="diurnal CI smoke: mini_day rate ladder (incl. idle "
                    "window) + trace/diurnal lambda(t) stream cells")


# --------------------------------------------------------------------------
# flash crowds (ISSUE 9)
# --------------------------------------------------------------------------

# arrival class mix: interactive / batch / background. Half the crowd
# is latency-sensitive; the other half is deferrable work the
# controller can shed — the headroom graceful degradation spends.
FLASH_MIX = (0.5, 0.3, 0.2)

# MMPP burst cells sweeping burst intensity: (name, base rate, burst
# rate, base dwell s, burst dwell s). The deployment (llama31-8b @
# tpu-v5e x2, theta_max ~2.9k tok/s ~= 11.5 req/s at chat shapes)
# saturates under every burst state — "calm" barely, "crowd" at ~5x
# capacity — so the queue actually floods and the controller has
# something to survive.
FLASH_BURSTS = (
    ("calm", 6.0, 18.0, 40.0, 10.0),
    ("gusty", 6.0, 30.0, 40.0, 10.0),
    ("crowd", 6.0, 60.0, 40.0, 10.0),
)

# degradation-ON arm: enter brownout at depth 16 (refuse background,
# clamp outputs to 64 tokens — the clamp multiplies request-rate
# capacity, which is what keeps interactive TTFT under the SLO at 3-5x
# overload), hard-shed batch+background at depth 32, recover below 4;
# degradation-OFF arm: monitor-only policy (same TTFT SLO, so
# violations are counted identically) with only the class-blind queue
# cap shedding — "blind shedding".
FLASH_POLICY = dict(ovl_brownout_depth=16, ovl_shed_depth=32,
                    ovl_recover_depth=4, ovl_ttft_slo_s=2.0,
                    ovl_brownout_max_new=64)
FLASH_MONITOR = dict(ovl_ttft_slo_s=2.0)


def _flashcrowd_cells(*, plan_name: str, bursts, policy: dict,
                      monitor: dict, mqd: int, duration_s: float,
                      max_batch: int = 256, num_pages: int = 65536,
                      seed: int = 0) -> Tuple[Cell, ...]:
    """Expand MMPP burst scenarios into paired degradation-on/off cells.

    Both arms of a burst share one seed (derived from the arm-agnostic
    template cell), hence one arrival + class stream — the comparison is
    *paired*, isolating the controller's effect. `lam` is the
    time-weighted mean of the two MMPP states (the record's nominal
    rate); `n_requests` covers ~`duration_s` of that mean rate."""
    cells = []
    for bname, ra, rb, da, db in bursts:
        lam = (ra * da + rb * db) / (da + db)
        base = Cell(
            plan=plan_name, config=f"flash:{bname}", model="llama31-8b",
            arch="llama31-8b", hw="tpu-v5e", quant="bf16", n_chips=2,
            lam=lam, io_shape="chat", seed=0,
            n_requests=int(lam * duration_s), warmup=0,
            price_per_hr=chip_hour_price("tpu-v5e", 2),
            max_batch=max_batch, num_pages=num_pages,
            profile_kind="mmpp", profile_args=(ra, rb, da, db),
            class_mix=FLASH_MIX, max_queue_depth=mqd)
        shared = cell_seed(seed, base.seed_key, lam)
        for arm, ovl in (("on", policy), ("off", monitor)):
            cells.append(dataclasses.replace(
                base, config=f"flash:{bname}:{arm}", seed=shared, **ovl))
    return tuple(cells)


def paper_flashcrowd() -> ExperimentPlan:
    """Overload survival (ISSUE 9): 3 MMPP burst intensities x
    {degradation on, off} on the core cheap-part deployment (6 cells,
    ~150 s of traffic each).

    Each burst pair shares its arrival + priority-class stream; the ON
    arm runs the armed OverloadPolicy (priority shedding + token-budget
    brownout + hysteresis), the OFF arm a monitor-only policy behind the
    same queue cap (blind shedding, violations still counted).
    `analyze.overload_tables` prices both arms per SLO-met interactive
    token; the committed store is tuned so degradation wins every cell.

        python -m repro.experiments.run --plan paper_flashcrowd \\
            --backend vector --resume --analyze-json
    """
    return ExperimentPlan(
        name="paper_flashcrowd",
        cells=_flashcrowd_cells(
            plan_name="paper_flashcrowd", bursts=FLASH_BURSTS,
            policy=FLASH_POLICY, monitor=FLASH_MONITOR, mqd=256,
            duration_s=150.0),
        seed=0,
        description="flash-crowd survival: 3 MMPP burst intensities x "
                    "{degradation on, off}, llama31-8b @ tpu-v5e x2, "
                    "paired arrival streams")


def mini_flashcrowd() -> ExperimentPlan:
    """CI smoke for the overload layer: one MMPP burst x {on, off} at
    smoke tier (2 cells). Exercises class mixes, the armed controller
    and the monitor-only arm end to end through the fleet backend."""
    return ExperimentPlan(
        name="mini_flashcrowd",
        cells=_flashcrowd_cells(
            plan_name="mini_flashcrowd",
            bursts=(("squall", 3.0, 24.0, 30.0, 12.0),),
            policy=dict(ovl_brownout_depth=8, ovl_shed_depth=16,
                        ovl_recover_depth=2, ovl_ttft_slo_s=1.5,
                        ovl_brownout_max_new=64),
            monitor=dict(ovl_ttft_slo_s=1.5),
            mqd=96, duration_s=45.0, max_batch=64, num_pages=8192),
        seed=0,
        description="flash-crowd CI smoke: one MMPP burst x "
                    "{degradation on, off} (sim tier)")


def crossover_trio() -> ExperimentPlan:
    """The crossover example's three configs on tpu-v5p, quick protocol."""
    plans = []
    for arch, quant, chips in (("llama31-8b", "bf16", 1),
                               ("qwen3-30b-a3b", "int8", 1),
                               ("mixtral-8x7b", "bf16", 2)):
        plans.append(GridSpec(
            name="crossover_trio", archs=(arch,), hws=("tpu-v5p",),
            quants=(quant,), ladder=(1, 2, 5, 10, 25, 50, 100),
            n_chips=chips, seed=0, protocol="quick").expand())
    cells = tuple(c for p in plans for c in p.cells)
    return ExperimentPlan(
        name="crossover_trio", cells=cells, seed=0,
        description="crossover example: 3 (model, quant, TP) configs on "
                    "tpu-v5p, quick protocol")


PLANS: Dict[str, Callable[[], ExperimentPlan]] = {
    "paper_h100": paper_h100,
    "paper_a100": paper_a100,
    "paper_crosshw": paper_crosshw,
    "paper_atlas": paper_atlas,
    "paper_ensemble": paper_ensemble,
    "mini_ensemble": mini_ensemble,
    "probe_int8_nonnative": probe_int8_nonnative,
    "paper_resilience": paper_resilience,
    "mini_resilience": mini_resilience,
    "paper_diurnal": paper_diurnal,
    "mini_diurnal": mini_diurnal,
    "paper_flashcrowd": paper_flashcrowd,
    "mini_flashcrowd": mini_flashcrowd,
    "mini_crosshw": mini_crosshw,
    "mini_2x2": mini_2x2,
    "quickstart": quickstart,
    "crossover_trio": crossover_trio,
}


def get_plan(name: str) -> ExperimentPlan:
    if name not in PLANS:
        raise KeyError(f"unknown plan {name!r}; known: {sorted(PLANS)}")
    return PLANS[name]()
