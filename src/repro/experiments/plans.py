"""First-class experiment plans: the paper's benchmark matrices.

The paper's headline matrices are 42 H100 cells and 56 A100 cells over
(model, quant, lambda) — 6 resp. 8 (model, quant) combinations times the
7-point lambda ladder. Per DESIGN §3 the hardware axis maps onto TPU
generations: H100 NVL -> tpu-v5p (fast, pricey, 95 GB), A100 PCIe ->
tpu-v5e (slow, cheap, 16 GB). Both parts emulate fp8 (no native fp8
MXU path), reproducing the paper's hardware-conditional quantization
caveat: the HBM win survives, the compute path pays a dequant penalty, so
compute-bound dense models can invert while memory-bound MoEs still gain.

`paper_crosshw` (ISSUE 3) replicates the paper's §5.9/§7 cross-hardware
argument in one plan: the same trio across v5e + v5p + the native-fp8
v6e, with per-(arch, hw) TP degrees, so the spread-compression and
FP8-inversion tables derive from a single store.

TP degrees are chosen so bf16 weights fit the part's HBM (the sim tier
does not enforce fit, but cross-cell $/token comparisons are only
meaningful for deployable footprints); price_per_hr scales with chips.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

from repro.core.sweep import LAMBDA_LADDER
from repro.experiments.plan import Cell, ExperimentPlan, GridSpec, cell_seed
from repro.serving.autoscale import DAY_SCENARIOS, DayScenario

# paper benchmark trio: dense 8B / ultra-sparse 30B-A3B MoE / 47B-A13B MoE
PAPER_TRIO = ("llama31-8b", "qwen3-30b-a3b", "mixtral-8x7b")

# the cross-hardware TP footprints (bf16 weights fit each part's HBM),
# shared by paper_crosshw / paper_atlas / probe_int8_nonnative
CROSSHW_CHIPS = (
    ("llama31-8b", "tpu-v5e", 2),
    ("qwen3-30b-a3b", "tpu-v5e", 8),
    ("mixtral-8x7b", "tpu-v5e", 8),
    ("llama31-8b", "tpu-v5p", 1),
    ("qwen3-30b-a3b", "tpu-v5p", 1),
    ("mixtral-8x7b", "tpu-v5p", 2),
    ("llama31-8b", "tpu-v6e", 1),
    ("qwen3-30b-a3b", "tpu-v6e", 2),
    ("mixtral-8x7b", "tpu-v6e", 4),
)

# 25-point log-spaced lambda continuum, 1..200 req/s (the 7-point paper
# ladder's idle->saturation span, densified so the penalty curve is a
# curve instead of seven samples). Frozen literal: ladder values feed the
# per-cell seed derivation (int(lam*1000)), so they must never drift
# with numpy versions.
ATLAS_LADDER = (
    1.0, 1.25, 1.56, 1.94, 2.42, 3.02, 3.76, 4.69, 5.85, 7.29, 9.09,
    11.34, 14.14, 17.64, 21.99, 27.42, 34.2, 42.65, 53.18, 66.32, 82.7,
    103.13, 128.61, 160.38, 200.0,
)


def paper_h100() -> ExperimentPlan:
    """42 cells: 3 models x 2 quants x 7-lambda ladder on tpu-v5p."""
    return GridSpec(
        name="paper_h100",
        description="H100-analogue matrix (paper §5): 3 models x "
                    "{bf16, fp8} x 7-point ladder on tpu-v5p",
        archs=PAPER_TRIO,
        hws=("tpu-v5p",),
        quants=("bf16", "fp8"),
        ladder=LAMBDA_LADDER,
        n_chips_by_arch=(("llama31-8b", 1), ("qwen3-30b-a3b", 1),
                         ("mixtral-8x7b", 2)),
        seed=0,
        protocol="paper",
    ).expand()


def paper_a100() -> ExperimentPlan:
    """56 cells: 4 models x 2 quants x 7-lambda ladder on tpu-v5e.

    The extra dense mid-size model (phi3-medium-14b) widens the
    active-params ordering probe on the cheaper part, giving the 8-combo
    A100-analogue matrix of the paper."""
    return GridSpec(
        name="paper_a100",
        description="A100-analogue matrix (paper §5): 4 models x "
                    "{bf16, fp8} x 7-point ladder on tpu-v5e",
        archs=PAPER_TRIO + ("phi3-medium-14b",),
        hws=("tpu-v5e",),
        quants=("bf16", "fp8"),
        ladder=LAMBDA_LADDER,
        n_chips_by_arch=(("llama31-8b", 2), ("phi3-medium-14b", 4),
                         ("qwen3-30b-a3b", 8), ("mixtral-8x7b", 8)),
        seed=0,
        protocol="paper",
    ).expand()


def paper_crosshw() -> ExperimentPlan:
    """126 cells: 3 models x 3 hardware generations x {bf16, fp8} x
    7-lambda ladder — the paper's §5.9/§7 cross-hardware replication as
    ONE plan over ONE store.

    TP degrees fit bf16 weights to each part's HBM (v5p 95 GB, v6e 32 GB,
    v5e 16 GB), so the cross-hardware $/token comparison stays deployable.
    v6e is the native-fp8 entry: the fp8 uplift must NOT invert there,
    while the fp8-emulating v5e/v5p parts reproduce the paper's dense
    inversion — `analyze.fp8_inversion` conditions on exactly this."""
    return GridSpec(
        name="paper_crosshw",
        description="cross-hardware matrix (paper §5.9/§7): 3 models x "
                    "{tpu-v5e, tpu-v5p, tpu-v6e} x {bf16, fp8} x "
                    "7-point ladder, per-hardware TP",
        archs=PAPER_TRIO,
        hws=("tpu-v5e", "tpu-v5p", "tpu-v6e"),
        quants=("bf16", "fp8"),
        ladder=LAMBDA_LADDER,
        n_chips_by_arch_hw=CROSSHW_CHIPS,
        seed=0,
        protocol="paper",
    ).expand()


def paper_atlas() -> ExperimentPlan:
    """450 cells: 3 models x 3 hardware generations x {bf16, fp8} x the
    25-point log-spaced lambda *continuum* — the dense "penalty atlas"
    (ISSUE 4).

    The paper's core claim is a curve (C_eff spans 2.5-36x driven by
    lambda), but the 7-point ladder only samples it; related work prices
    over ever-larger scenario products (Melange's hw x model x load
    search, WiNGPT's swept economics), so the atlas densifies the load
    axis 3.6x at the same per-cell protocol. Feasible as one command
    because the fleet backend makes a 450-cell plan cost a few dozen
    cell-equivalents of wall time:

        python -m repro.experiments.run --plan paper_atlas \\
            --backend vector --resume --analyze

    `analyze.penalty_atlas` consumes the store: per (model, hw, quant)
    the dense lambda -> penalty curve, its knee (first lambda within 25%
    of the cost floor) and the idle/saturation spread that the PR-3
    spread-compression table only samples at 7 points."""
    return GridSpec(
        name="paper_atlas",
        description="dense penalty atlas: 3 models x {v5e, v5p, v6e} x "
                    "{bf16, fp8} x 25-point log-spaced lambda continuum",
        archs=PAPER_TRIO,
        hws=("tpu-v5e", "tpu-v5p", "tpu-v6e"),
        quants=("bf16", "fp8"),
        ladder=ATLAS_LADDER,
        n_chips_by_arch_hw=CROSSHW_CHIPS,
        seed=0,
        protocol="paper",
    ).expand()


def paper_ensemble() -> ExperimentPlan:
    """2016 cells: every `paper_atlas` (model, hw, quant) group x the
    7-point paper ladder x 16 independent arrival seeds — the
    Monte-Carlo ensemble behind the confidence bands (ISSUE 7).

    The paper's headline numbers carry an n=3 caveat; this plan resolves
    it inside the repo by replicating all 18 atlas groups at N=16 seeds
    so `analyze.ensemble_bands` can bootstrap confidence bands on the
    penalty / utilization / C_eff curves (threaded into the planner's
    deployment curves and `analysis.json`). Quick protocol keeps the
    per-cell cost ~10x below paper tier; at 2016 cells the plan is only
    tractable because of the jit fleet backend:

        python -m repro.experiments.run --plan paper_ensemble \\
            --backend jit --resume --analyze-json
    """
    return GridSpec(
        name="paper_ensemble",
        description="Monte-Carlo ensemble: 3 models x {v5e, v5p, v6e} x "
                    "{bf16, fp8} x 7-point ladder x 16 arrival seeds",
        archs=PAPER_TRIO,
        hws=("tpu-v5e", "tpu-v5p", "tpu-v6e"),
        quants=("bf16", "fp8"),
        ladder=LAMBDA_LADDER,
        n_chips_by_arch_hw=CROSSHW_CHIPS,
        seed_offsets=tuple(range(16)),
        seed=0,
        protocol="quick",
    ).expand()


def mini_ensemble() -> ExperimentPlan:
    """CI smoke for the ensemble axis: the mini_2x2 grid x 4 arrival
    seeds, smoke-tier traffic (16 cells). Enough replicates for
    `analyze.ensemble_bands` to emit finite (non-degenerate) bands."""
    return GridSpec(
        name="mini_ensemble",
        description="ensemble CI smoke: 2 archs x 2 lambdas x 4 arrival "
                    "seeds (sim tier)",
        archs=("llama31-8b", "qwen3-30b-a3b"),
        hws=("tpu-v5e",),
        quants=("bf16",),
        ladder=(5, 50),
        seed_offsets=(0, 1, 2, 3),
        seed=0,
        protocol="smoke",
        max_batch=64,
        num_pages=8192,
    ).expand()


def probe_int8_nonnative() -> ExperimentPlan:
    """126 cells exercising `quants_by_hw` at paper scale (ROADMAP PR-3
    follow-up): int8 — the natively-accelerated low-precision format on
    every TPU part — is probed on the fp8-*emulating* generations (v5e,
    v5p), while the native-fp8 v6e keeps its fp8 path; bf16 is the
    baseline everywhere. Per-hardware quant allow-lists carve 126 cells
    out of the full 189-cell product, reproducing the paper's §5.9
    guidance that the Q axis should follow each part's native formats."""
    return GridSpec(
        name="probe_int8_nonnative",
        description="int8-on-non-native-fp8 probe: per-hw quant "
                    "allow-lists (v5e/v5p: bf16+int8, v6e: bf16+fp8), "
                    "3 models x 7-ladder",
        archs=PAPER_TRIO,
        hws=("tpu-v5e", "tpu-v5p", "tpu-v6e"),
        quants=("bf16", "int8", "fp8"),
        ladder=LAMBDA_LADDER,
        n_chips_by_arch_hw=CROSSHW_CHIPS,
        quants_by_hw=(
            ("tpu-v5e", ("bf16", "int8")),
            ("tpu-v5p", ("bf16", "int8")),
            ("tpu-v6e", ("bf16", "fp8")),
        ),
        seed=0,
        protocol="paper",
    ).expand()


def paper_resilience() -> ExperimentPlan:
    """Pricing reliability (ISSUE 6): what failures, retries and shedding
    do to $/M *delivered* tokens.

    Grid A (48 cells): the core dense model on its cheap-part footprint
    (llama31-8b @ tpu-v5e x2), 3-lambda ladder x MTTF ladder
    {none, 40, 15, 6 s} x retry {off, 3 attempts with capped backoff}.
    Every resilient cell runs with a queue-depth cap so shed arrivals and
    crash-killed requests both feed the client retry loop; the
    (mttf=0, retry=0) column is the failure-free baseline
    `analyze.reliability_tables` normalizes inflation against.

    Grid B (14 cells): the same model priced failure-free on both its
    v5e and v5p footprints over the full 7-point ladder — the deployment
    curves `planner --availability` reprices with N+1 spares, so the
    cheapest failure-free footprint can flip under an availability target.
    """
    grid_a = GridSpec(
        name="paper_resilience",
        description="reliability pricing: llama31-8b @ tpu-v5e x2, "
                    "3-lambda x MTTF {0,40,15,6} x retry {0,3} under "
                    "admission control; + failure-free v5e/v5p ladders "
                    "for availability-aware planning",
        archs=("llama31-8b",),
        hws=("tpu-v5e",),
        quants=("bf16",),
        ladder=(5.0, 10.0, 25.0),
        n_chips=2,
        mttfs=(0.0, 40.0, 15.0, 6.0),
        retry_maxes=(0, 3),
        mttr=2.0,
        fail_frac=0.5,
        retry_base_s=0.25,
        max_queue_depth=512,
        seed=0,
        protocol="quick",
    ).expand()
    grid_b = GridSpec(
        name="paper_resilience",
        archs=("llama31-8b",),
        hws=("tpu-v5e", "tpu-v5p"),
        quants=("bf16",),
        ladder=LAMBDA_LADDER,
        n_chips_by_arch_hw=CROSSHW_CHIPS,
        seed=0,
        protocol="quick",
    ).expand()
    # grid A's failure-free baselines at lam {5,10,25} are the same cells
    # as grid B's v5e ladder points — keep the first copy of each id.
    seen = {c.cell_id for c in grid_a.cells}
    extra = tuple(c for c in grid_b.cells if c.cell_id not in seen)
    return ExperimentPlan(
        name="paper_resilience",
        cells=grid_a.cells + extra,
        seed=0,
        description=grid_a.description)


def mini_resilience() -> ExperimentPlan:
    """CI smoke for the resilience axes: 1 model x 1 lambda x
    MTTF {0, 10} x retry {0, 2}, smoke-tier traffic (4 cells)."""
    return GridSpec(
        name="mini_resilience",
        description="resilience CI smoke: llama31-8b, lam=10, "
                    "mttf {0,4} x retry {0,2} (sim tier)",
        archs=("llama31-8b",),
        hws=("tpu-v5e",),
        quants=("bf16",),
        ladder=(10,),
        mttfs=(0.0, 4.0),
        retry_maxes=(0, 2),
        mttr=1.0,
        retry_base_s=0.25,
        max_queue_depth=64,
        seed=0,
        protocol="smoke",
        max_batch=64,
        num_pages=8192,
    ).expand()


def mini_crosshw() -> ExperimentPlan:
    """CI smoke for the cross-hardware axis: 2 models x {v5e, v6e} x
    {bf16, fp8} x 2 lambdas, smoke-tier traffic (16 cells). Exercises the
    per-(arch, hw) TP override and both native-fp8 regimes."""
    return GridSpec(
        name="mini_crosshw",
        description="cross-hardware CI smoke: 2 models x 2 hw x "
                    "{bf16, fp8} x 2 lambdas (sim tier)",
        archs=("llama31-8b", "qwen3-30b-a3b"),
        hws=("tpu-v5e", "tpu-v6e"),
        quants=("bf16", "fp8"),
        ladder=(5, 50),
        n_chips_by_arch_hw=(("qwen3-30b-a3b", "tpu-v5e", 2),),
        seed=0,
        protocol="smoke",
        max_batch=64,
        num_pages=8192,
    ).expand()


def mini_2x2() -> ExperimentPlan:
    """CI smoke: 2 archs x 2 lambdas, smoke-tier traffic (4 cells)."""
    return GridSpec(
        name="mini_2x2",
        description="2x2 CI smoke matrix (sim tier)",
        archs=("llama31-8b", "qwen3-30b-a3b"),
        hws=("tpu-v5e",),
        quants=("bf16",),
        ladder=(5, 50),
        seed=0,
        protocol="smoke",
        max_batch=64,
        num_pages=8192,
    ).expand()


def quickstart() -> ExperimentPlan:
    """The quickstart example's single-model ladder as a stored plan."""
    return GridSpec(
        name="quickstart",
        description="quickstart: llama31-8b on tpu-v5e, quick protocol",
        archs=("llama31-8b",),
        hws=("tpu-v5e",),
        quants=("bf16",),
        ladder=(1, 5, 10, 25, 50, 100),
        seed=0,
        protocol="quick",
    ).expand()


def _day_cells(scenario: DayScenario, *, plan_name: str, max_requests: int,
               min_requests: int, max_batch: int = 256,
               num_pages: int = 65536, seed: int = 0) -> Tuple[Cell, ...]:
    """Expand a DayScenario into its measurement cells.

    The windows of a piecewise-constant day are stationary segments, so
    the store measures POLICY-AGNOSTIC stationary points: for each
    deployment, one cell per distinct quantized per-replica rate that
    any trajectory (static or policy) visits — `scenario.rate_ladder` is
    the shared source of truth, so `analyze.diurnal_tables` can map
    every (window, policy) back to its record. Each cell captures about
    one window's worth of traffic (lam x window_s requests, clamped)."""
    cells = []
    for dep in scenario.deployments:
        for lam in scenario.rate_ladder(dep):
            n = int(min(max_requests,
                        max(min_requests, round(lam * scenario.window_s))))
            cell = Cell(
                plan=plan_name, config=f"day:{scenario.name}",
                model=dep.model, arch=dep.model, hw=dep.hw,
                quant=dep.quant, n_chips=dep.n_chips, lam=float(lam),
                io_shape="chat", seed=0, n_requests=n, warmup=0,
                price_per_hr=dep.price_per_hr, max_batch=max_batch,
                num_pages=num_pages)
            cells.append(dataclasses.replace(
                cell, seed=cell_seed(seed, cell.seed_key, cell.lam)))
    return tuple(cells)


def paper_diurnal() -> ExperimentPlan:
    """The "cost of a day of traffic" store (ISSUE 8): every stationary
    per-replica rate the `paper_day` scenario's trajectories visit —
    24 windows x (static + reactive + cautious autoscaling) x 2
    deployments, deduplicated to the distinct quantized rates
    (~60 cells). `analyze.diurnal_tables` recomputes the fleet
    trajectories (pure) and prices each policy's day from these
    measurements; the committed profile is chosen so the
    static-vs-autoscaled verdict FLIPS between the two deployments.

        python -m repro.experiments.run --plan paper_diurnal \\
            --backend vector --resume --analyze
    """
    sc = DAY_SCENARIOS["paper_day"]
    return ExperimentPlan(
        name="paper_diurnal",
        cells=_day_cells(sc, plan_name="paper_diurnal",
                         max_requests=5000, min_requests=40),
        seed=0,
        description="cost of a day of traffic: per-replica stationary "
                    "rates for the paper_day 24h profile, static + 2 "
                    "autoscaling policies x 2 deployments")


def mini_diurnal() -> ExperimentPlan:
    """CI smoke for the non-stationary layer: the `mini_day` scenario's
    rate ladder at smoke tier (including a zero-rate window priced as
    idle), plus two profile-bearing cells — a trace replay and a diurnal
    sinusoid — that push lambda(t) streams through the fleet backend
    end to end."""
    sc = DAY_SCENARIOS["mini_day"]
    cells = list(_day_cells(sc, plan_name="mini_diurnal", max_requests=150,
                            min_requests=16, max_batch=64, num_pages=8192))
    dep = sc.deployments[0]
    t, knots = 0.0, []
    for r in sc.window_rates:
        knots.append((t, r))
        t += sc.window_s
    # the `profile:` config prefix marks non-stationary records: their
    # `lam` is the nominal mean of lambda(t), not a stationary offered
    # rate, so stationary analytics (_groups / fit_curves) skip them
    for config, kind, kn, period, args in (
            ("profile:trace_smoke", "trace", tuple(knots), sc.day_s, ()),
            ("profile:diurnal_smoke", "diurnal", (), 120.0,
             (1.0, 8.0, 0.5))):
        cell = Cell(
            plan="mini_diurnal", config=config, model=dep.model,
            arch=dep.model, hw=dep.hw, quant=dep.quant,
            n_chips=dep.n_chips, lam=4.0, io_shape="chat", seed=0,
            n_requests=120, warmup=0, price_per_hr=dep.price_per_hr,
            max_batch=64, num_pages=8192, profile_kind=kind,
            profile_knots=kn, profile_period_s=period, profile_args=args)
        cells.append(dataclasses.replace(
            cell, seed=cell_seed(0, cell.seed_key, cell.lam)))
    return ExperimentPlan(
        name="mini_diurnal", cells=tuple(cells), seed=0,
        description="diurnal CI smoke: mini_day rate ladder (incl. idle "
                    "window) + trace/diurnal lambda(t) stream cells")


def crossover_trio() -> ExperimentPlan:
    """The crossover example's three configs on tpu-v5p, quick protocol."""
    plans = []
    for arch, quant, chips in (("llama31-8b", "bf16", 1),
                               ("qwen3-30b-a3b", "int8", 1),
                               ("mixtral-8x7b", "bf16", 2)):
        plans.append(GridSpec(
            name="crossover_trio", archs=(arch,), hws=("tpu-v5p",),
            quants=(quant,), ladder=(1, 2, 5, 10, 25, 50, 100),
            n_chips=chips, seed=0, protocol="quick").expand())
    cells = tuple(c for p in plans for c in p.cells)
    return ExperimentPlan(
        name="crossover_trio", cells=cells, seed=0,
        description="crossover example: 3 (model, quant, TP) configs on "
                    "tpu-v5p, quick protocol")


PLANS: Dict[str, Callable[[], ExperimentPlan]] = {
    "paper_h100": paper_h100,
    "paper_a100": paper_a100,
    "paper_crosshw": paper_crosshw,
    "paper_atlas": paper_atlas,
    "paper_ensemble": paper_ensemble,
    "mini_ensemble": mini_ensemble,
    "probe_int8_nonnative": probe_int8_nonnative,
    "paper_resilience": paper_resilience,
    "mini_resilience": mini_resilience,
    "paper_diurnal": paper_diurnal,
    "mini_diurnal": mini_diurnal,
    "mini_crosshw": mini_crosshw,
    "mini_2x2": mini_2x2,
    "quickstart": quickstart,
    "crossover_trio": crossover_trio,
}


def get_plan(name: str) -> ExperimentPlan:
    if name not in PLANS:
        raise KeyError(f"unknown plan {name!r}; known: {sorted(PLANS)}")
    return PLANS[name]()
