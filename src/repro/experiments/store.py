"""Resumable on-disk result store for experiment plans.

Layout (one directory per plan under `results/experiments/`):

    results/experiments/<plan>/
        cell_<cell_id>.json     one finished cell: spec + fingerprint + record
        <plan>.csv              consolidated RunRecord corpus (plan order,
                                theta_max back-filled per ladder group)
        manifest.json           plan summary + per-cell status/fingerprints

Cell files are written atomically (tmp + os.replace) the moment a cell
finishes, so a killed run loses at most the in-flight cells. On restart a
cell is resumed only when its stored fingerprint still matches the plan's
spec — editing the plan invalidates exactly the edited cells.

The consolidated CSV and manifest are derived purely from the plan and
the cell files (no timestamps, fixed ordering), so a resumed run that
finishes the remaining cells emits byte-identical artifacts to an
uninterrupted one.
"""
from __future__ import annotations

import dataclasses
import json
import os
from pathlib import Path
from typing import Dict, List, Optional, Set

from repro.core.records import RunRecord, write_csv
from repro.experiments.plan import Cell, ExperimentPlan

DEFAULT_ROOT = Path(__file__).resolve().parents[3] / "results" / "experiments"


def _atomic_write(path: Path, text: str):
    tmp = path.with_suffix(path.suffix + ".tmp")
    tmp.write_text(text)
    os.replace(tmp, path)


def backfill_theta(plan: ExperimentPlan,
                   records: Dict[str, RunRecord]) -> List[RunRecord]:
    """theta_max = max measured TPS across each ladder group (§4.4), over
    `records` keyed by cell_id; returns records in plan order."""
    by_group: Dict[tuple, List[RunRecord]] = {}
    for c in plan.cells:
        if c.cell_id in records:
            by_group.setdefault(c.group_key, []).append(records[c.cell_id])
    for group in by_group.values():
        theta = max(r.tps for r in group)
        for r in group:
            r.theta_max = theta
    return [records[c.cell_id] for c in plan.cells if c.cell_id in records]


class ExperimentStore:
    def __init__(self, plan_name: str, root: Optional[Path] = None):
        self.root = Path(root) if root is not None else DEFAULT_ROOT
        self.dir = self.root / plan_name
        self.plan_name = plan_name

    def cell_path(self, cell: Cell) -> Path:
        return self.dir / f"cell_{cell.cell_id}.json"

    @property
    def csv_path(self) -> Path:
        return self.dir / f"{self.plan_name}.csv"

    @property
    def manifest_path(self) -> Path:
        return self.dir / "manifest.json"

    # ---- writes -------------------------------------------------------
    def write_cell(self, cell: Cell, record: RunRecord):
        self.dir.mkdir(parents=True, exist_ok=True)
        blob = {
            "cell_id": cell.cell_id,
            "fingerprint": cell.fingerprint(),
            "cell": dataclasses.asdict(cell),
            "record": dataclasses.asdict(record),
        }
        _atomic_write(self.cell_path(cell),
                      json.dumps(blob, indent=1, sort_keys=True))

    def consolidate(self, plan: ExperimentPlan) -> List[RunRecord]:
        """Rebuild CSV + manifest from whatever cells are on disk; pure in
        (plan, cell files), so partial/resumed/reordered runs converge to
        identical bytes once the same cells exist."""
        records = self.load_cell_records(plan)
        done = backfill_theta(plan, records)
        self.dir.mkdir(parents=True, exist_ok=True)
        write_csv(self.csv_path, done)
        manifest = {
            "plan": plan.name,
            "seed": plan.seed,
            "description": plan.description,
            "n_cells": len(plan.cells),
            "n_completed": len(done),
            "cells": [{
                "cell_id": c.cell_id,
                "fingerprint": c.fingerprint(),
                "status": "done" if c.cell_id in records else "pending",
            } for c in plan.cells],
        }
        _atomic_write(self.manifest_path,
                      json.dumps(manifest, indent=1, sort_keys=True))
        return done

    def prune(self, plan: ExperimentPlan) -> List[Path]:
        """Delete orphaned cell files: any `cell_*.json` that no current
        plan cell claims with a matching fingerprint (a plan edit renames
        cell ids, so superseded files would otherwise accumulate forever
        and survive `--fresh`). Consolidated artifacts are untouched —
        the next `consolidate` re-derives them from the surviving cells.
        Returns the removed paths."""
        want = {self.cell_path(c).name: c.fingerprint() for c in plan.cells}
        removed = []
        for path in sorted(self.dir.glob("cell_*.json")):
            try:
                blob = json.loads(path.read_text())
                fingerprint = blob.get("fingerprint") \
                    if isinstance(blob, dict) else None
            except (OSError, json.JSONDecodeError):
                fingerprint = None            # torn write: prune with rest
            if path.name not in want or want[path.name] != fingerprint:
                path.unlink()
                removed.append(path)
        return removed

    # ---- reads --------------------------------------------------------
    def load_cell_records(self, plan: ExperimentPlan) -> Dict[str, RunRecord]:
        """cell_id -> RunRecord for every stored cell whose fingerprint
        still matches the plan (stale results are ignored, hence re-run)."""
        out: Dict[str, RunRecord] = {}
        for cell in plan.cells:
            path = self.cell_path(cell)
            if not path.exists():
                continue
            try:
                blob = json.loads(path.read_text())
            except (OSError, json.JSONDecodeError):
                continue                      # torn write: treat as missing
            if not isinstance(blob, dict) or \
                    blob.get("fingerprint") != cell.fingerprint():
                continue
            record = blob.get("record")
            if not isinstance(record, dict):
                continue                      # payload missing: stale
            try:
                out[cell.cell_id] = RunRecord(**record)
            except TypeError:
                # schema drift (e.g. a cell written by an older RunRecord
                # missing fields, or carrying unknown ones): stale, re-run
                continue
        return out

    def completed_ids(self, plan: ExperimentPlan) -> Set[str]:
        return set(self.load_cell_records(plan))

    def verify(self, plan: ExperimentPlan) -> Dict[str, List[str]]:
        """Integrity check of the on-disk store against `plan`.

        Returns {"issues": [...], "missing": [...]} — `issues` are cell
        files that exist but cannot be resumed (torn JSON, fingerprint
        drift, missing/undecodable record payload) plus orphaned files no
        current cell claims; `missing` lists cells never run (informative
        only: an interrupted run is not corrupt). Every entry names the
        file and the reason, so `run.py --verify` can print and exit
        nonzero on `issues`."""
        issues: List[str] = []
        missing: List[str] = []
        claimed: Set[str] = set()
        for cell in plan.cells:
            path = self.cell_path(cell)
            claimed.add(path.name)
            if not path.exists():
                missing.append(f"{path.name}: cell never ran")
                continue
            try:
                blob = json.loads(path.read_text())
            except (OSError, json.JSONDecodeError) as e:
                issues.append(f"{path.name}: torn/unreadable JSON ({e})")
                continue
            if not isinstance(blob, dict):
                issues.append(f"{path.name}: not a cell blob "
                              f"(top-level {type(blob).__name__})")
                continue
            if blob.get("fingerprint") != cell.fingerprint():
                issues.append(
                    f"{path.name}: fingerprint drift (stored "
                    f"{blob.get('fingerprint')!r} != plan "
                    f"{cell.fingerprint()!r}; spec changed since it ran)")
                continue
            record = blob.get("record")
            if not isinstance(record, dict):
                issues.append(f"{path.name}: record payload missing")
                continue
            try:
                RunRecord(**record)
            except TypeError as e:
                issues.append(f"{path.name}: record schema drift ({e})")
        if self.dir.exists():
            for path in sorted(self.dir.glob("cell_*.json")):
                if path.name not in claimed:
                    issues.append(f"{path.name}: orphaned (no current "
                                  "plan cell claims it)")
        return {"issues": issues, "missing": missing}

    def load_records(self, plan: ExperimentPlan) -> List[RunRecord]:
        """Plan-ordered, theta-back-filled records (the analysis input)."""
        return backfill_theta(plan, self.load_cell_records(plan))
