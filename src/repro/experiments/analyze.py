"""Derive the paper's figures from an experiment store.

Everything here is a pure function of the consolidated records (no
engines are re-run): penalty-vs-lambda curves through `core.cost`, API
crossover points through `core.crossover`, the active-params saturation
ordering (§5.2), and the per-hardware FP8 uplift table (§5.3's
hardware-conditional inversion).

Cross-hardware tables (ISSUE 3, from a multi-hardware store such as
`paper_crosshw`): the spread-compression table — per (model, quant) the
min/max C_eff and load-driven spread on every hardware generation plus
the compression ratio between the widest and narrowest part (the paper's
2.5-36.3x H100 vs 7.0-11.4x A100 replication, §5.9/§7) — the FP8-uplift
table conditioned on native-fp8 hardware, and whether the active-params
saturation ordering survives on every generation.

    PYTHONPATH=src python -m repro.experiments.analyze --plan paper_a100
    PYTHONPATH=src python -m repro.experiments.analyze --plan paper_crosshw \
        --json results/experiments/paper_crosshw/analysis.json
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.cost import c_naive, underutilization_penalty
from repro.core.crossover import crossover_table
from repro.core.records import RunRecord
from repro.simulate.hardware import HW_BY_NAME


def _groups(records: Sequence[RunRecord]
            ) -> Dict[Tuple, List[RunRecord]]:
    """(model, hw, quant, n_chips, io_shape) -> ladder-ordered records.

    Resilient records (injected failures / client retries, ISSUE 6) are
    excluded: they sit at the same coordinates as their failure-free
    siblings and would pollute the classic cost curves with degraded
    points. They are analyzed by `reliability_tables` instead.
    Non-stationary records (config prefixed `profile:`, ISSUE 8) are
    excluded too: their `lam` is the nominal mean of lambda(t), not a
    stationary offered rate, so they are not ladder knots. Flash-crowd
    records (config prefixed `flash:`, ISSUE 9) are both non-stationary
    (MMPP bursts) and degradation-shaped; `overload_tables` owns them."""
    out: Dict[Tuple, List[RunRecord]] = {}
    for r in records:
        if r.mttf > 0.0 or r.retry_max > 0:
            continue
        if r.config.startswith(("profile:", "flash:")):
            continue
        key = (r.model, r.hw, r.quant, r.n_chips, r.io_shape)
        out.setdefault(key, []).append(r)
    for group in out.values():
        group.sort(key=lambda r: r.lam)
    return out


def penalty_curves(records: Sequence[RunRecord]) -> List[dict]:
    """Per group: the load-driven C_eff spread — idle-edge penalty, the
    saturation floor, and the max/min cost ratio across the ladder (the
    paper's 2.5-24x underutilization headline lives here)."""
    out = []
    for key, group in _groups(records).items():
        ceffs = [r.c_eff for r in group]
        naive = c_naive(group[0].price_per_hr, group[0].theta_max)
        out.append({
            "model": key[0], "hw": key[1], "quant": key[2],
            "n_chips": key[3], "io_shape": key[4],
            "lams": [r.lam for r in group],
            "c_eff": ceffs,
            "penalty": [underutilization_penalty(r.tps, r.theta_max)
                        for r in group],
            "util": [r.util for r in group],
            "c_naive": naive,
            "idle_penalty": underutilization_penalty(group[0].tps,
                                                     group[0].theta_max),
            "spread": max(ceffs) / min(ceffs),
            "theta_max": group[0].theta_max,
        })
    return out


def active_params_ordering(records: Sequence[RunRecord]
                           ) -> List[dict]:
    """§5.2: saturation throughput per (hw, quant) ranked against active
    parameter counts — active params, not total, should order theta_max.
    Plans deploy models at different TP degrees (bf16 fit), so the
    ordering compares *per-chip* saturation throughput."""
    from repro.configs import get_config
    rows: Dict[Tuple, List[dict]] = {}
    for key, group in _groups(records).items():
        model, hw, quant, n_chips = key[0], key[1], key[2], key[3]
        try:
            cfg = get_config(model)
            active = cfg.active_param_count()
            total = cfg.param_count()
        except KeyError:
            active = total = float("nan")
        rows.setdefault((hw, quant), []).append({
            "model": model, "active_params": active, "total_params": total,
            "theta_max": group[0].theta_max, "n_chips": n_chips,
            "theta_max_per_chip": group[0].theta_max / n_chips,
            "sat_c_eff": min(r.c_eff for r in group),
        })
    out = []
    for (hw, quant), models in sorted(rows.items()):
        models.sort(key=lambda m: -m["theta_max_per_chip"])
        by_active = sorted(models, key=lambda m: m["active_params"])
        out.append({
            "hw": hw, "quant": quant, "ranking": models,
            "ordered_by_active_params":
                [m["model"] for m in models] ==
                [m["model"] for m in by_active],
        })
    return out


def fp8_uplift(records: Sequence[RunRecord],
               baseline: str = "bf16", variant: str = "fp8") -> List[dict]:
    """§5.3 / §5.9: per (hw, model) saturation-TPS and cost uplift of the
    quantized variant over bf16. uplift < 1 is the paper's inversion —
    expected for compute-bound dense models on non-native-fp8 parts."""
    sat: Dict[Tuple, Dict[str, dict]] = {}
    for key, group in _groups(records).items():
        model, hw, quant = key[0], key[1], key[2]
        sat.setdefault((hw, model), {})[quant] = {
            "theta_max": group[0].theta_max,
            "sat_c_eff": min(r.c_eff for r in group),
        }
    out = []
    for (hw, model), by_quant in sorted(sat.items()):
        if baseline not in by_quant or variant not in by_quant:
            continue
        base, var = by_quant[baseline], by_quant[variant]
        out.append({
            "hw": hw, "model": model,
            "tps_uplift": var["theta_max"] / base["theta_max"],
            "cost_ratio": var["sat_c_eff"] / base["sat_c_eff"],
            "inverted": var["theta_max"] < base["theta_max"],
        })
    return out


def spread_compression(records: Sequence[RunRecord]) -> List[dict]:
    """§5.9/§7: per (model, quant), the load-driven C_eff spread on every
    hardware generation in the store, plus the compression ratio between
    the widest and the narrowest part. The paper's claim-robustness
    argument is that the spread *reproduces with compressed magnitude* on
    the cheaper part — single-hardware confounding would not survive
    this axis."""
    by_mq: Dict[Tuple, Dict[Tuple, dict]] = {}
    for key, group in _groups(records).items():
        model, hw, quant, n_chips, io_shape = key
        ceffs = [r.c_eff for r in group]
        # distinct footprints (two TP degrees on one part) stay distinct
        # rows instead of silently overwriting each other
        by_mq.setdefault((model, quant, io_shape), {})[(hw, n_chips)] = {
            "hw": hw, "n_chips": n_chips,
            "c_min": min(ceffs), "c_max": max(ceffs),
            "spread": max(ceffs) / min(ceffs),
            "theta_max": group[0].theta_max,
        }
    out = []
    for (model, quant, io_shape), by_hw in sorted(by_mq.items()):
        if len({h for h, _ in by_hw}) < 2:
            continue                 # the table is cross-hardware only
        widest = max(by_hw.values(), key=lambda h: h["spread"])
        narrowest = min(by_hw.values(), key=lambda h: h["spread"])
        out.append({
            "model": model, "quant": quant, "io_shape": io_shape,
            "per_hw": [by_hw[k] for k in sorted(by_hw)],
            "widest_hw": widest["hw"], "narrowest_hw": narrowest["hw"],
            "compression": widest["spread"] / narrowest["spread"],
        })
    return out


def fp8_inversion(records: Sequence[RunRecord],
                  baseline: str = "bf16", variant: str = "fp8"
                  ) -> List[dict]:
    """The FP8-uplift table conditioned on native-fp8 hardware: the
    paper's hardware-conditional caveat says the dense inversion is a
    property of the *part* (emulated-fp8 dequant penalty), not the model —
    so it must appear on non-native hardware and vanish on native-fp8
    hardware. `consistent` records whether each row obeys that rule
    (memory-bound MoEs may legitimately gain everywhere)."""
    out = []
    for row in fp8_uplift(records, baseline=baseline, variant=variant):
        hw = HW_BY_NAME.get(row["hw"])
        native = bool(hw.native_fp8) if hw is not None else False
        out.append({
            **row, "native_fp8": native,
            # an inversion on a native-fp8 part would break the story;
            # a gain on an emulating part is fine (MoEs keep the HBM win)
            "consistent": not (native and row["inverted"]),
        })
    return out


def penalty_atlas(records: Sequence[RunRecord],
                  min_points: int = 10) -> List[dict]:
    """ISSUE 4: the dense penalty-curve table from a lambda-*continuum*
    store (`paper_atlas`: 25 log-spaced offered rates instead of the
    7-point ladder). Per (model, hw, quant) group the full lambda ->
    (C_eff, penalty, utilization) curve plus the summary scalars the
    sparse ladders can only bracket:

    * `knee_lambda` — the first offered rate whose C_eff is within 25%
      of the saturation cost floor: where the paper's "substantial
      sustained load" condition (§7) actually begins on this hardware.
    * `half_cost_lambda` — the first rate at >=50% utilization (penalty
      <= 2x): the cheapest half of the curve starts here.
    * `idle_penalty` / `spread` — the curve's endpoints, directly
      comparable with the PR-3 spread-compression table.

    Groups with fewer than `min_points` distinct rates are skipped — the
    atlas is meaningful only for dense stores, so 7-point plans fall
    through to the classic tables untouched. Rows are `penalty_curves`
    rows (one source of truth for the shared scalars) extended with the
    continuum-only fields."""
    out = []
    for row in penalty_curves(records):
        if len(set(row["lams"])) < min_points:
            continue
        floor = min(row["c_eff"])
        knee = next((lam for lam, c in zip(row["lams"], row["c_eff"])
                     if c <= 1.25 * floor), float("nan"))
        half = next((lam for lam, u in zip(row["lams"], row["util"])
                     if u >= 0.5), float("nan"))
        out.append({**row, "c_floor": floor, "knee_lambda": knee,
                    "half_cost_lambda": half})
    return out


def ensemble_bands(records: Sequence[RunRecord],
                   min_seeds: int = 3) -> List[dict]:
    """ISSUE 7: Monte-Carlo confidence bands from an ensemble store
    (`paper_ensemble`: every atlas group replicated at N=16 independent
    arrival seeds; `mini_ensemble`: the CI-smoke 4-seed version).

    Per (model, hw, quant) group whose lambdas carry >= `min_seeds`
    replicates: the central-95% percentile-bootstrap band of the
    geometric mean of C_eff, the underutilization penalty and the
    utilization at every offered rate — the error bars the paper's n=3
    caveat ("broader validation needed") asks for. The bootstrap rides
    `planner.curves.bootstrap_band` (deterministic, CRC-seeded), so the
    C_eff band here brackets exactly the knot the planner interpolates
    from the same store. Single-seed stores return [] and every classic
    table is unchanged."""
    from repro.planner.curves import _band_rng, bootstrap_band
    import math
    out = []
    for key, group in _groups(records).items():
        by_lam: Dict[float, List[RunRecord]] = {}
        for r in group:
            by_lam.setdefault(r.lam, []).append(r)
        if max(len(v) for v in by_lam.values()) < min_seeds:
            continue
        metric_vals = {
            "c_eff": lambda r: r.c_eff,
            "penalty": lambda r: underutilization_penalty(r.tps,
                                                          r.theta_max),
            "util": lambda r: r.util,
        }
        lams = [lam for lam in sorted(by_lam)
                if len(by_lam[lam]) >= min_seeds]
        row = {
            "model": key[0], "hw": key[1], "quant": key[2],
            "n_chips": key[3], "io_shape": key[4],
            "n_seeds": max(len(by_lam[lam]) for lam in lams),
            "lams": lams,
            "n_per_lam": [len(by_lam[lam]) for lam in lams],
        }
        widest = 0.0
        for metric, value in metric_vals.items():
            rng = _band_rng(key, metric)
            mean, lo, hi = [], [], []
            for lam in lams:
                vals = [value(r) for r in by_lam[lam]]
                vals = [v for v in vals if math.isfinite(v) and v > 0]
                if len(vals) < min_seeds:
                    mean.append(float("nan"))
                    lo.append(float("nan"))
                    hi.append(float("nan"))
                    continue
                m, l, h = bootstrap_band(vals, rng)
                mean.append(m)
                lo.append(l)
                hi.append(h)
                if metric == "c_eff" and m > 0:
                    widest = max(widest, (h - l) / (2 * m))
            row[metric] = {"mean": mean, "lo": lo, "hi": hi}
        # the headline scalar: how tight the cost claim actually is —
        # the widest relative half-width of the C_eff band on the ladder
        row["max_rel_halfwidth_c_eff"] = widest
        out.append(row)
    return out


def reliability_tables(records: Sequence[RunRecord]) -> List[dict]:
    """ISSUE 6: the cost of reliability. One row per resilient record
    (mttf > 0 or retry_max > 0): goodput vs offered rate, the client
    retry-amplification factor, and — the headline — the inflation of
    C_eff per *delivered* token against the failure-free record at the
    same (model, hw, quant, footprint, io_shape, lambda). `tps` counts
    only completed requests' tokens, so C_eff is already per-delivered-
    token; failures/shedding shrink the denominator while the meter keeps
    running, which is exactly the inflation being priced."""
    base: Dict[Tuple, RunRecord] = {}
    for r in records:
        if r.mttf == 0.0 and r.retry_max == 0:
            base[(r.model, r.hw, r.quant, r.n_chips, r.io_shape, r.lam)] = r
    out = []
    for r in records:
        if r.mttf == 0.0 and r.retry_max == 0:
            continue
        b = base.get((r.model, r.hw, r.quant, r.n_chips, r.io_shape, r.lam))
        inflation = (r.c_eff / b.c_eff
                     if b is not None and b.c_eff > 0 else float("nan"))
        out.append({
            "model": r.model, "hw": r.hw, "quant": r.quant,
            "n_chips": r.n_chips, "io_shape": r.io_shape, "lam": r.lam,
            "mttf": r.mttf, "retry_max": r.retry_max,
            "offered_rps": r.lam, "goodput_rps": r.goodput_rps,
            "delivered_frac": (r.n_completed / r.n_requests
                               if r.n_requests else float("nan")),
            "retry_amplification": r.retry_amplification,
            "n_shed": r.n_shed, "n_timeout": r.n_timeout,
            "n_retried": r.n_retried, "n_abandoned": r.n_abandoned,
            "c_eff": r.c_eff,
            "c_eff_baseline": b.c_eff if b is not None else float("nan"),
            "c_eff_inflation": inflation,
        })
    # within a (coords, lam) block, rows ascend by failure *rate* (1/mttf,
    # with mttf=0 = rate 0 first) then retry budget — so the monotone-
    # inflation acceptance check reads straight down the table
    out.sort(key=lambda d: (d["model"], d["hw"], d["quant"], d["n_chips"],
                            d["io_shape"], d["lam"],
                            1.0 / d["mttf"] if d["mttf"] > 0 else 0.0,
                            d["retry_max"]))
    return out


def diurnal_tables(records: Sequence[RunRecord]) -> List[dict]:
    """ISSUE 8: the "cost of a day of traffic" table. Day-store records
    (config `day:<scenario>`) are stationary per-replica measurements at
    every rate the scenario's fleet trajectories visit;
    this recomputes the trajectories (pure, deterministic —
    `DayScenario.trajectories`) and prices the static footprint against
    every autoscaling policy from those measured points: per-window
    C_eff over the 24h profile, daily $ total and delivered tokens,
    the peak-hour penalty, and the static-vs-autoscaled verdict per
    deployment. The committed `paper_day` profile is built so the
    verdict FLIPS between its two deployments — autoscaling pays on the
    small-capacity footprint (trough savings span whole replicas) and
    costs on the big one (target-util headroom is pure premium when one
    replica already covers the peak)."""
    import math
    from repro.planner.tables import _clean
    from repro.serving.autoscale import DAY_SCENARIOS, price_day, \
        quantize_rate
    by_scenario: Dict[str, List[RunRecord]] = {}
    for r in records:
        if r.config.startswith("day:"):
            by_scenario.setdefault(r.config[4:], []).append(r)
    out = []
    for name in sorted(by_scenario):
        sc = DAY_SCENARIOS.get(name)
        if sc is None:
            continue                     # store from a retired scenario
        recs = by_scenario[name]
        for dep in sc.deployments:
            tps_by_lam = {
                quantize_rate(r.lam): r.tps for r in recs
                if (r.model, r.hw, r.quant, r.n_chips) ==
                   (dep.model, dep.hw, dep.quant, dep.n_chips)}
            if not tps_by_lam:
                continue
            missing = sorted(set(sc.rate_ladder(dep)) - set(tps_by_lam))
            policies = []
            for pname, traj in sc.trajectories(dep).items():
                try:
                    priced = price_day(traj, price_per_hr=dep.price_per_hr,
                                       tps_at=lambda l: tps_by_lam[l],
                                       lam_cap=dep.lam_cap)
                except KeyError:
                    continue             # ladder cell not yet run
                policies.append({"policy": pname, **priced})
            finite = [p for p in policies
                      if math.isfinite(p["day_c_eff"])]
            winner = min(finite, key=lambda p: p["day_c_eff"]) \
                if finite else None
            static = next((p for p in policies if p["policy"] == "static"),
                          None)
            saving = None
            if winner is not None and static is not None \
                    and static["day_c_eff"] > 0 \
                    and math.isfinite(static["day_c_eff"]):
                saving = 1.0 - winner["day_c_eff"] / static["day_c_eff"]
            out.append(_clean({
                "scenario": name, "deployment": dep.name,
                "model": dep.model, "hw": dep.hw, "quant": dep.quant,
                "n_chips": dep.n_chips, "price_per_hr": dep.price_per_hr,
                "lam_cap": dep.lam_cap, "window_s": sc.window_s,
                "n_windows": len(sc.window_rates),
                "peak_lam": sc.peak_lam,
                "static_replicas": sc.static_replicas(dep),
                "missing_rates": missing,
                "policies": policies,
                "winner": winner["policy"] if winner else None,
                "autoscaling_pays": bool(winner) and
                winner["policy"] != "static",
                "winner_saving_vs_static": saving,
            }))
    return out


def _overload_arm(r: RunRecord) -> dict:
    """Per-arm scalars for one flash-crowd record. `slo_met_frac` is the
    fraction of completed requests whose TTFT met the SLO;
    `slo_violation_minutes` spreads the violating fraction over the
    measurement window (a whole window out of SLO = window_s/60)."""
    from repro.core.cost import c_eff as _ceff
    done = max(r.n_completed, 1)
    slo_met_frac = 1.0 - r.n_slo_viol / done
    total_tokens = r.tps * r.window_s
    return {
        "offered_rps": r.lam, "goodput_rps": r.goodput_rps,
        "delivered_frac": (r.n_completed / r.n_requests
                           if r.n_requests else float("nan")),
        "n_shed": r.n_shed, "n_class_shed": r.n_class_shed,
        "n_timeout": r.n_timeout,
        "shed_frac": ((r.n_shed + r.n_timeout) / r.n_requests
                      if r.n_requests else 0.0),
        "n_browned": r.n_browned,
        "browned_token_frac": (r.browned_tokens
                               / (r.browned_tokens + total_tokens)
                               if r.browned_tokens + total_tokens > 0
                               else 0.0),
        "n_slo_viol": r.n_slo_viol, "slo_met_frac": slo_met_frac,
        "slo_violation_minutes": (r.window_s / 60.0)
        * (r.n_slo_viol / done),
        "ttft_p90_ms": r.ttft_p90_ms,
        "tps": r.tps, "interactive_tps": r.interactive_tps,
        "c_eff": r.c_eff,
        "c_eff_interactive": _ceff(r.price_per_hr, r.interactive_tps),
        # the headline denominator: interactive tokens delivered AND
        # within the TTFT SLO (per-class SLO counts are not recorded, so
        # the completed-request SLO-met fraction prorates the stream)
        "c_eff_slo_interactive": _ceff(
            r.price_per_hr, r.interactive_tps * slo_met_frac),
    }


def overload_tables(records: Sequence[RunRecord]) -> List[dict]:
    """ISSUE 9: priced graceful degradation under flash crowds. One row
    per (burst scenario, deployment) pair of a flash-crowd store (config
    `flash:<scenario>:<arm>`): the degradation-ON arm (armed
    OverloadPolicy: priority shedding + brownout) against the OFF arm
    (monitor-only policy — same queue cap, blind shedding, violations
    counted but nothing degraded) on the SAME arrival + class stream.

    The verdict metric is `c_eff_slo_interactive`: $/M interactive
    tokens delivered within the TTFT SLO. Degradation sheds background
    work and clamps token budgets, spending less of the window out of
    SLO — so it should beat blind shedding on cost per SLO-met
    interactive token even though it refuses more requests outright."""
    by_pair: Dict[Tuple, Dict[str, RunRecord]] = {}
    for r in records:
        if not r.config.startswith("flash:"):
            continue
        parts = r.config.split(":")
        scenario = parts[1] if len(parts) > 1 else ""
        arm = parts[2] if len(parts) > 2 else "on"
        key = (scenario, r.model, r.hw, r.quant, r.n_chips,
               r.io_shape, r.lam)
        by_pair.setdefault(key, {})[arm] = r
    out = []
    for key in sorted(by_pair, key=lambda k: (k[0], k[6])):
        arms = by_pair[key]
        row = {
            "scenario": key[0], "model": key[1], "hw": key[2],
            "quant": key[3], "n_chips": key[4], "io_shape": key[5],
            "lam": key[6],
            "arms": {arm: _overload_arm(r)
                     for arm, r in sorted(arms.items())},
        }
        on, off = row["arms"].get("on"), row["arms"].get("off")
        if on is not None and off is not None:
            row["degradation_wins"] = (on["c_eff_slo_interactive"]
                                       < off["c_eff_slo_interactive"])
            row["slo_minutes_saved"] = (off["slo_violation_minutes"]
                                        - on["slo_violation_minutes"])
            row["cost_ratio_off_over_on"] = (
                off["c_eff_slo_interactive"]
                / on["c_eff_slo_interactive"]
                if on["c_eff_slo_interactive"] > 0 else float("inf"))
        out.append(row)
    return out


def overload_verdict(rows: Sequence[dict]) -> dict:
    """Store-level headline over `overload_tables` rows: does graceful
    degradation beat blind shedding on cost per SLO-met interactive
    token on every burst cell? (The committed `paper_flashcrowd` grid is
    tuned so it does; the acceptance test asserts this.)"""
    pairs = [r for r in rows if "degradation_wins" in r]
    wins = sum(1 for r in pairs if r["degradation_wins"])
    return {
        "n_pairs": len(pairs),
        "wins": wins,
        "degradation_wins": bool(pairs) and wins == len(pairs),
        "total_slo_minutes_saved": sum(r["slo_minutes_saved"]
                                       for r in pairs),
    }


def render_overload(rows: Sequence[dict]) -> str:
    """Text rendering of `overload_tables` rows (report + planner)."""
    if not rows:
        return ""
    lines = ["-- surviving a flash crowd (degradation ON vs OFF, "
             "$/M SLO-met interactive tokens) --",
             f"{'scenario':<10} {'lam':>6} {'arm':<4} {'deliv':>6} "
             f"{'shed':>5} {'brown':>5} {'sloOK':>6} {'sloMin':>7} "
             f"{'$/M int-SLO':>11}"]
    for row in rows:
        for arm in ("on", "off"):
            a = row["arms"].get(arm)
            if a is None:
                continue
            ce = a["c_eff_slo_interactive"]
            lines.append(
                f"{row['scenario']:<10} {row['lam']:>6g} {arm:<4} "
                f"{a['delivered_frac']:>6.2f} {a['shed_frac']:>5.2f} "
                f"{a['browned_token_frac']:>5.2f} "
                f"{a['slo_met_frac']:>6.2f} "
                f"{a['slo_violation_minutes']:>7.2f} "
                + (f"{ce:>11.3f}" if ce != float("inf") else
                   f"{'inf':>11}"))
        if "degradation_wins" in row:
            tag = ("degradation pays" if row["degradation_wins"]
                   else "blind shedding cheaper")
            lines.append(f"  -> {tag} "
                         f"({row['cost_ratio_off_over_on']:.2f}x off/on, "
                         f"{row['slo_minutes_saved']:+.2f} SLO-min saved)")
    verdict = overload_verdict(rows)
    if verdict["n_pairs"]:
        lines.append(
            f"  => graceful degradation beats blind shedding on "
            f"{verdict['wins']}/{verdict['n_pairs']} burst cells")
    return "\n".join(lines)


def render_diurnal(rows: Sequence[dict]) -> str:
    """Text rendering of `diurnal_tables` rows (report + example)."""
    if not rows:
        return ""
    row0 = rows[0]
    lines = [
        f"-- cost of a day of traffic ({row0['scenario']}: "
        f"{row0['n_windows']} windows x {row0['window_s']:g} s, "
        f"peak {row0['peak_lam']:g} req/s) --"]
    for row in rows:
        lines.append(f"{row['deployment']} "
                     f"(static R={row['static_replicas']}, "
                     f"lam_cap {row['lam_cap']:g} req/s/replica):")
        lines.append(f"  {'policy':<10} {'repl-hrs':>8} {'daily $':>8} "
                     f"{'Mtok':>7} {'day C_eff':>9} {'peak pen':>8} "
                     f"{'idle':>4} {'sat':>3}")
        for p in row["policies"]:
            pen = f"{p['peak_penalty']:.2f}x" \
                if p["peak_penalty"] is not None else "n/a"
            dce = f"{p['day_c_eff']:.4f}" \
                if p["day_c_eff"] is not None else "inf"
            lines.append(
                f"  {p['policy']:<10} {p['replica_hours']:>8.2f} "
                f"{p['daily_cost_usd']:>8.3f} "
                f"{p['daily_tokens'] / 1e6:>7.2f} {dce:>9} "
                f"{pen:>8} {p['idle_windows']:>4d} "
                f"{p['saturated_windows']:>3d}")
        if row["winner"]:
            tag = f"cheapest day: {row['winner']}"
            if row["winner_saving_vs_static"]:
                tag += (f" ({100 * row['winner_saving_vs_static']:.0f}%"
                        f" below static)")
            if not row["autoscaling_pays"]:
                tag += "  [autoscaling does NOT pay here]"
            lines.append(f"  -> {tag}")
        if row["missing_rates"]:
            lines.append(f"  !! incomplete store: missing rates "
                         f"{row['missing_rates']}")
    return "\n".join(lines)


def crosshw_ordering(records: Sequence[RunRecord]) -> List[dict]:
    """§5.2 across the hardware axis: per quant, does the per-chip
    active-params saturation ordering survive on every generation?"""
    by_quant: Dict[str, List[dict]] = {}
    for row in active_params_ordering(records):
        by_quant.setdefault(row["quant"], []).append(row)
    out = []
    for quant, rows in sorted(by_quant.items()):
        if len(rows) < 2:
            continue
        out.append({
            "quant": quant,
            "hws": [r["hw"] for r in rows],
            "holds_on": [r["hw"] for r in rows
                         if r["ordered_by_active_params"]],
            "survives_all_hw": all(r["ordered_by_active_params"]
                                   for r in rows),
        })
    return out


def crosshw_tables(records: Sequence[RunRecord]) -> Dict[str, object]:
    """The cross-hardware artifacts as one JSON-ready payload. The
    penalty atlas joins when the store is dense enough (lambda-continuum
    plans); sparse-ladder stores carry an empty list there. The planner
    payload (ISSUE 5) serializes the fitted per-hardware curves — the
    knots a penalty-curve figure needs — plus the recommended deployment
    at the paper's reference loads."""
    from repro.planner.tables import planner_tables
    pairs = overload_tables(records)
    return {
        "spread_compression": spread_compression(records),
        "fp8_inversion": fp8_inversion(records),
        "active_params_ordering": crosshw_ordering(records),
        "penalty_atlas": penalty_atlas(records),
        "ensemble_bands": ensemble_bands(records),
        "planner_tables": planner_tables(records),
        "reliability": reliability_tables(records),
        "diurnal": diurnal_tables(records),
        "overload": {
            "pairs": pairs,
            "verdict": overload_verdict(pairs),
        },
    }


def write_tables(records: Sequence[RunRecord], path) -> None:
    """Persist `crosshw_tables` as JSON — the one serialization both CLIs
    (`run.py --analyze-json`, `analyze.py --json`) share, so the committed
    artifact can never drift between the two entry points."""
    with open(path, "w") as f:
        json.dump(crosshw_tables(records), f, indent=1, sort_keys=True)


def crossover_summary(records: Sequence[RunRecord]) -> List[dict]:
    """Per-group API crossover points (list prices, no SLA — §6.4 gate
    acknowledged explicitly here, as the examples always did)."""
    out = []
    for key, group in _groups(records).items():
        rows = crossover_table(group, accept_slo_mismatch=True)
        out.append({"model": key[0], "hw": key[1], "quant": key[2],
                    "tiers": rows})
    return out


def report(records: Sequence[RunRecord], title: str = "") -> str:
    """Human-readable consolidated report (what the CLI prints)."""
    lines = []
    if title:
        lines += [f"=== experiment report: {title} ===", ""]
    lines.append("-- load-driven C_eff spread (penalty = 1/U) --")
    lines.append(f"{'model':<24} {'hw':<9} {'quant':<5} {'theta_max':>9} "
                 f"{'idle pen':>9} {'spread':>7}")
    for row in penalty_curves(records):
        lines.append(
            f"{row['model']:<24} {row['hw']:<9} {row['quant']:<5} "
            f"{row['theta_max']:>9.0f} {row['idle_penalty']:>8.1f}x "
            f"{row['spread']:>6.1f}x")

    lines.append("")
    lines.append("-- active-params saturation ordering (§5.2, "
                 "per-chip theta_max) --")
    for row in active_params_ordering(records):
        order = " > ".join(f"{m['model']}({m['theta_max_per_chip']:.0f})"
                           for m in row["ranking"])
        ok = "matches" if row["ordered_by_active_params"] else "violates"
        lines.append(f"{row['hw']} {row['quant']}: {order}  "
                     f"[{ok} active-params order]")

    int8 = fp8_uplift(records, variant="int8")
    if int8:
        lines.append("")
        lines.append("-- INT8 uplift vs bf16 at saturation (native MXU "
                     "path on every part) --")
        lines.append(f"{'hw':<9} {'model':<24} {'TPS uplift':>10} "
                     f"{'cost ratio':>10}  note")
        for row in int8:
            note = "INVERTED (int8 slower)" if row["inverted"] else "gain"
            lines.append(f"{row['hw']:<9} {row['model']:<24} "
                         f"{row['tps_uplift']:>9.2f}x "
                         f"{row['cost_ratio']:>9.2f}x  {note}")

    uplift = fp8_inversion(records)
    if uplift:
        lines.append("")
        lines.append("-- FP8 uplift vs bf16 at saturation (per hardware, "
                     "conditioned on native fp8) --")
        lines.append(f"{'hw':<9} {'fp8':<8} {'model':<24} "
                     f"{'TPS uplift':>10} {'cost ratio':>10}  note")
        for row in uplift:
            note = "INVERTED (fp8 slower)" if row["inverted"] else "gain"
            if not row["consistent"]:
                note += "  !! inconsistent with native fp8"
            native = "native" if row["native_fp8"] else "emulated"
            lines.append(f"{row['hw']:<9} {native:<8} {row['model']:<24} "
                         f"{row['tps_uplift']:>9.2f}x "
                         f"{row['cost_ratio']:>9.2f}x  {note}")

    compression = spread_compression(records)
    if compression:
        lines.append("")
        lines.append("-- cross-hardware spread compression (§5.9/§7) --")
        lines.append(f"{'model':<24} {'quant':<5} "
                     f"{'per-hw spread (min..max C_eff)':<44} "
                     f"{'compression':>11}")
        for row in compression:
            per_hw = "  ".join(
                f"{h['hw']}:{h['spread']:.1f}x" for h in row["per_hw"])
            lines.append(f"{row['model']:<24} {row['quant']:<5} "
                         f"{per_hw:<44} {row['compression']:>10.2f}x "
                         f"(widest {row['widest_hw']})")
        for row in crosshw_ordering(records):
            tag = ("survives every hw" if row["survives_all_hw"] else
                   f"holds on {', '.join(row['holds_on']) or 'none'} "
                   f"of {', '.join(row['hws'])}")
            lines.append(f"active-params ordering [{row['quant']}]: {tag}")

    atlas = penalty_atlas(records)
    if atlas:
        lines.append("")
        lines.append("-- dense penalty atlas (lambda continuum, "
                     f"{len(atlas[0]['lams'])} points per curve) --")
        lines.append(f"{'model':<24} {'hw':<9} {'quant':<5} "
                     f"{'idle pen':>9} {'spread':>7} {'knee lam':>9} "
                     f"{'half-cost lam':>13}")
        for row in atlas:
            lines.append(
                f"{row['model']:<24} {row['hw']:<9} {row['quant']:<5} "
                f"{row['idle_penalty']:>8.1f}x {row['spread']:>6.1f}x "
                f"{row['knee_lambda']:>9.4g} {row['half_cost_lambda']:>13.4g}")

    bands = ensemble_bands(records)
    if bands:
        lines.append("")
        lines.append("-- Monte-Carlo confidence bands (central 95%, "
                     f"N={bands[0]['n_seeds']} arrival seeds) --")
        lines.append(f"{'model':<24} {'hw':<9} {'quant':<5} "
                     f"{'idle c_eff [lo..hi]':>24} "
                     f"{'sat c_eff [lo..hi]':>24} {'max hw':>7}")
        for row in bands:
            ce = row["c_eff"]
            idle = f"{ce['mean'][0]:.3f} [{ce['lo'][0]:.3f}.." \
                   f"{ce['hi'][0]:.3f}]"
            sat = f"{ce['mean'][-1]:.3f} [{ce['lo'][-1]:.3f}.." \
                  f"{ce['hi'][-1]:.3f}]"
            lines.append(
                f"{row['model']:<24} {row['hw']:<9} {row['quant']:<5} "
                f"{idle:>24} {sat:>24} "
                f"{100 * row['max_rel_halfwidth_c_eff']:>6.1f}%")

    reliability = reliability_tables(records)
    if reliability:
        lines.append("")
        lines.append("-- pricing reliability (C_eff per *delivered* "
                     "token vs failure-free baseline) --")
        lines.append(f"{'model':<24} {'lam':>6} {'mttf':>6} {'retry':>5} "
                     f"{'goodput':>8} {'ampl':>6} {'shed':>5} "
                     f"{'c_eff':>8} {'inflation':>9}")
        for row in reliability:
            mttf = f"{row['mttf']:g}" if row["mttf"] > 0 else "-"
            lines.append(
                f"{row['model']:<24} {row['lam']:>6g} {mttf:>6} "
                f"{row['retry_max']:>5d} {row['goodput_rps']:>8.2f} "
                f"{row['retry_amplification']:>5.2f}x {row['n_shed']:>5d} "
                f"{row['c_eff']:>8.3f} {row['c_eff_inflation']:>8.2f}x")

    diurnal = diurnal_tables(records)
    if diurnal:
        lines.append("")
        lines.extend(render_diurnal(diurnal).splitlines())

    overload = overload_tables(records)
    if overload:
        lines.append("")
        lines.extend(render_overload(overload).splitlines())

    from repro.planner.curves import fit_curves
    from repro.planner.portfolio import BLENDED_3CLASS, plan_portfolio
    from repro.planner.tables import (PORTFOLIO_LAMS, certification_rows,
                                      render_certification,
                                      render_portfolio)
    curves = fit_curves(records)
    if curves:
        lines.append("")
        lines.extend(
            render_certification(certification_rows(curves)).splitlines())
        for lam in PORTFOLIO_LAMS:
            lines.append("")
            lines.extend(render_portfolio(plan_portfolio(
                curves, BLENDED_3CLASS.scaled(lam))).splitlines())

    lines.append("")
    lines.append("-- API crossover (list prices, no SLA: §6.4 gate "
                 "acknowledged) --")
    for row in crossover_summary(records):
        for tier in row["tiers"]:
            lam = tier["lambda_star"]
            tag = ("always cheaper" if tier["self_host_always_cheaper"]
                   else f"lam*={lam:.2f}")
            lines.append(f"{row['model']:<24} {row['quant']:<5} vs "
                         f"{tier['tier']:<18} {tag}")
    return "\n".join(lines)


def load_store_records(plan_name: str, root: Optional[str] = None
                       ) -> List[RunRecord]:
    from repro.experiments.plans import get_plan
    from repro.experiments.store import ExperimentStore
    plan = get_plan(plan_name)
    return ExperimentStore(plan.name, root).load_records(plan)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--plan", required=True)
    ap.add_argument("--root", default=None,
                    help="store root (default results/experiments)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the cross-hardware tables "
                         "(spread compression, fp8 inversion, ordering "
                         "survival) as JSON")
    args = ap.parse_args(argv)
    records = load_store_records(args.plan, args.root)
    if not records:
        raise SystemExit(f"no completed cells in store for {args.plan!r}; "
                         f"run: python -m repro.experiments.run "
                         f"--plan {args.plan}")
    print(report(records, title=args.plan))
    if args.json:
        write_tables(records, args.json)
        print(f"\ncross-hardware tables written to {args.json}")


if __name__ == "__main__":
    main()
