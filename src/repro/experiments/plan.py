"""Declarative experiment cells and plans.

A `Cell` is one fully-specified measurement — everything `run_point`
needs, flattened into a frozen, picklable, hashable record: model/hw/quant
coordinates, the offered rate, the arrival protocol (request counts baked
to ints at expansion time) and the engine knobs. A `GridSpec` expands an
arch x hw x quant x n_chips x lambda x io_shape product into an
`ExperimentPlan`; expansion is pure, so the same spec always yields the
same cell list with the same per-cell seeds.

Seed derivation: each ladder group (every coordinate except lambda) gets a
group seed from the plan seed plus a CRC32 of the group key — stable
across processes and Python versions, unlike `hash()` — and each cell in
the group derives `group_seed + int(lam * 1000)`, the exact rule
`core.sweep._ladder_specs` has always used. A ladder plan built from the
same seed therefore reproduces `lambda_sweep` records bit-for-bit.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
import zlib
from typing import Callable, Dict, Iterator, List, Optional, Sequence, Tuple

from repro.core.pricing import chip_hour_price
from repro.core.sweep import (LAMBDA_LADDER, SimEngineSpec,
                              default_requests_per_point,
                              default_warmup_per_point)


def quick_requests_per_point(lam: float) -> int:
    """The examples' reduced protocol (~10x lighter than the paper's)."""
    return int(min(600, max(120, 20 * lam)))


def smoke_requests_per_point(lam: float) -> int:
    """CI-smoke tier: just enough traffic to exercise the queue."""
    return int(min(80, max(30, 4 * lam)))


def zero_warmup(lam: float) -> int:
    return 0


# protocol name -> (requests_per_point, warmup_per_point); cells bake the
# resulting ints so workers never ship callables across the pool.
PROTOCOLS: Dict[str, Tuple[Callable[[float], int], Callable[[float], int]]] = {
    "paper": (default_requests_per_point, default_warmup_per_point),
    "quick": (quick_requests_per_point, zero_warmup),
    "smoke": (smoke_requests_per_point, zero_warmup),
}


@dataclasses.dataclass(frozen=True)
class Cell:
    """One (model, hw, quant, n_chips, lambda, io_shape) measurement."""
    plan: str
    config: str                 # record label (paper C1..C6 or free-form)
    model: str
    arch: str                   # registry key for the engine factory
    hw: str
    quant: str
    n_chips: int
    lam: float
    io_shape: str
    seed: int
    n_requests: int
    warmup: int
    price_per_hr: float
    process: str = "poisson"
    cv: float = 1.0
    scale: float = 1.0
    horizon: Optional[float] = None
    failure_times: Tuple[float, ...] = ()
    engine_kind: str = "sim"
    # engine knobs (SimEngineSpec fields)
    max_batch: int = 256
    page_size: int = 16
    num_pages: int = 65536
    max_pages_per_seq: int = 64
    prefill_token_budget: int = 2048
    max_prefill_reqs: int = 8
    fast_forward: bool = True
    # resilience axes (ISSUE 6) — all-zero means off; they join cell_id /
    # group_key only when on, so pre-existing plans keep their historical
    # seed streams (and records) exactly.
    mttf: float = 0.0           # mean time to replica failure (0 = none)
    mttr: float = 0.0           # mean restart lag after a crash
    fail_frac: float = 0.5      # fraction of running slots lost per crash
    retry_max: int = 0          # client retry budget (0 = no retries)
    retry_base_s: float = 0.5   # backoff base (doubles per attempt)
    retry_jitter_s: float = 0.0
    max_queue_depth: int = 0    # engine admission-control shed depth
    deadline_s: float = 0.0     # engine queue-time deadline
    # Monte-Carlo replicate index (ISSUE 7). Nonzero offsets join
    # cell_id / seed_key so each replicate draws an independent arrival
    # stream; offset 0 stays OUT of the keys, so pre-ensemble plans keep
    # their historical seed streams (and committed records) byte-exactly.
    seed_offset: int = 0
    # lambda(t) (ISSUE 8): a non-stationary cell carries its RateProfile
    # flattened into hashable tuples (kind/knots/period/args — see
    # serving.arrivals.RateProfile). Empty kind = stationary; like
    # seed_offset, the default stays OUT of cell_id / seed_key /
    # fingerprint so every historical plan and committed store keeps its
    # exact ids, seeds and cell files.
    profile_kind: str = ""
    profile_knots: Tuple[Tuple[float, float], ...] = ()
    profile_period_s: float = 0.0
    profile_args: Tuple[float, ...] = ()
    # overload survival (ISSUE 9): priority-class mix over arrivals and
    # the flattened OverloadPolicy. All-default means off and, like
    # seed_offset/profile_*, stays OUT of cell_id / seed_key /
    # fingerprint so historical plans and committed stores keep their
    # exact ids, seeds and cell files. The ovl_* fields mirror
    # `serving.overload.OverloadPolicy` one-for-one.
    class_mix: Tuple[float, ...] = ()
    ovl_brownout_depth: int = 0
    ovl_shed_depth: int = 0
    ovl_recover_depth: int = 0
    ovl_ttft_slo_s: float = 0.0
    ovl_brownout_max_new: int = 0
    ovl_brownout_shed_floor: int = 2    # overload.BACKGROUND
    ovl_shed_floor: int = 1             # overload.BATCH
    # runner execution policy (not part of the measurement itself)
    cell_retries: int = 2       # re-dispatch budget after worker loss

    @property
    def profile_key(self) -> Tuple:
        return (self.profile_kind, self.profile_knots,
                self.profile_period_s, self.profile_args)

    @property
    def resilience_key(self) -> Tuple:
        return (self.mttf, self.mttr, self.fail_frac, self.retry_max,
                self.retry_base_s, self.retry_jitter_s,
                self.max_queue_depth, self.deadline_s)

    @property
    def overload_key(self) -> Tuple:
        return (self.class_mix, self.ovl_brownout_depth, self.ovl_shed_depth,
                self.ovl_recover_depth, self.ovl_ttft_slo_s,
                self.ovl_brownout_max_new, self.ovl_brownout_shed_floor,
                self.ovl_shed_floor)

    @property
    def overloaded(self) -> bool:
        """True when the cell carries a priority-class mix or an
        OverloadPolicy — armed or monitor-only (ttft_slo_s only)."""
        return (bool(self.class_mix) or self.ovl_brownout_depth > 0
                or self.ovl_shed_depth > 0 or self.ovl_brownout_max_new > 0
                or self.ovl_ttft_slo_s > 0.0)

    @property
    def resilient(self) -> bool:
        """True when any behavior-changing resilience knob is on.
        fail_frac/mttr/retry_base_s/jitter are parameters OF those knobs
        (nonzero defaults), so they don't gate by themselves."""
        return (self.mttf > 0.0 or self.retry_max > 0
                or self.max_queue_depth > 0 or self.deadline_s > 0.0)

    @property
    def cell_id(self) -> str:
        lam = f"{self.lam:g}".replace(".", "p")
        raw = (f"{self.arch}_{self.hw}_{self.quant}_x{self.n_chips}"
               f"_{self.io_shape}_lam{lam}")
        if self.resilient:
            mttf = f"{self.mttf:g}".replace(".", "p")
            raw += f"_mttf{mttf}_r{self.retry_max}"
        if self.seed_offset:
            raw += f"_s{self.seed_offset}"
        if self.profile_kind:
            pk = zlib.crc32(repr(self.profile_key).encode()) % 100000
            raw += f"_prof-{self.profile_kind}{pk}"
        if self.overloaded:
            ok = zlib.crc32(repr(self.overload_key).encode()) % 100000
            raw += f"_ovl{ok}"
        return raw.replace("/", "-")

    @property
    def seed_key(self) -> Tuple:
        """Arrival-seed group: the resilience axes are EXCLUDED, so every
        resilient cell shares its failure-free sibling's arrival stream.
        Reliability comparisons are therefore *paired* — same arrivals,
        same request shapes — isolating the failure/retry effect from
        arrival-realization noise.

        Ensemble replicates (nonzero `seed_offset`) append the offset so
        each replicate draws an independent arrival stream; offset 0 is
        omitted, keeping every historical plan's streams unchanged."""
        base = (self.config, self.model, self.arch, self.hw, self.quant,
                self.n_chips, self.io_shape, self.process, self.cv,
                self.scale, self.engine_kind)
        if self.seed_offset:
            base = base + (("seed_offset", self.seed_offset),)
        if self.profile_kind:
            base = base + (("profile",) + self.profile_key,)
        return base

    @property
    def group_key(self) -> Tuple:
        """Ladder group: theta_max is back-filled across cells that share
        everything but the offered rate."""
        base = self.seed_key
        if self.resilient:
            base = base + self.resilience_key
        if self.overloaded:
            # overload axes group like the resilience axes: they stay out
            # of seed_key (degradation-on/off arms share one arrival +
            # class stream — *paired* comparison) but split ladder groups,
            # so theta_max is back-filled per policy arm.
            base = base + (("ovl",) + self.overload_key,)
        return base

    def fingerprint(self) -> str:
        """Spec hash stored beside each result; a stale on-disk cell (spec
        changed since it ran) is re-run instead of resumed."""
        spec = dataclasses.asdict(self)
        if not self.seed_offset:
            # like the keys, the default-zero ensemble offset stays out
            # of the hash: stores committed before the axis existed must
            # keep resuming (and their cell files keep byte-identity)
            spec.pop("seed_offset")
        if not self.profile_kind:
            # same rule for the lambda(t) fields (ISSUE 8)
            for k in ("profile_kind", "profile_knots", "profile_period_s",
                      "profile_args"):
                spec.pop(k)
        if not self.overloaded:
            # and for the overload-survival fields (ISSUE 9)
            for k in ("class_mix", "ovl_brownout_depth", "ovl_shed_depth",
                      "ovl_recover_depth", "ovl_ttft_slo_s",
                      "ovl_brownout_max_new", "ovl_brownout_shed_floor",
                      "ovl_shed_floor"):
                spec.pop(k)
        blob = json.dumps(spec, sort_keys=True)
        return hashlib.sha256(blob.encode()).hexdigest()[:16]

    def engine_spec(self) -> SimEngineSpec:
        return SimEngineSpec(
            arch=self.arch, hw=self.hw, quant=self.quant,
            n_chips=self.n_chips, max_batch=self.max_batch,
            page_size=self.page_size, num_pages=self.num_pages,
            max_pages_per_seq=self.max_pages_per_seq,
            prefill_token_budget=self.prefill_token_budget,
            max_prefill_reqs=self.max_prefill_reqs,
            fast_forward=self.fast_forward,
            max_queue_depth=self.max_queue_depth,
            deadline_s=self.deadline_s,
            overload=self.overload_policy())

    def failure_spec(self):
        """FailureSpec for this cell, or None. The stream seed is derived
        from the cell seed at a fixed offset so every cell gets its own
        deterministic crash schedule."""
        if self.mttf <= 0.0:
            return None
        from repro.serving.resilience import FailureSpec
        return FailureSpec(mttf=self.mttf, mttr=self.mttr,
                           loss_frac=self.fail_frac, seed=self.seed + 911)

    def overload_policy(self):
        """OverloadPolicy for this cell, or None. A cell with only
        `ovl_ttft_slo_s` set carries a monitor-only policy (violations
        counted, nothing shed or clamped) — the degradation-OFF arm of
        the flash-crowd experiment."""
        if not (self.ovl_brownout_depth > 0 or self.ovl_shed_depth > 0
                or self.ovl_brownout_max_new > 0
                or self.ovl_ttft_slo_s > 0.0):
            return None
        from repro.serving.overload import OverloadPolicy
        return OverloadPolicy(
            brownout_depth=self.ovl_brownout_depth,
            shed_depth=self.ovl_shed_depth,
            recover_depth=self.ovl_recover_depth,
            ttft_slo_s=self.ovl_ttft_slo_s,
            brownout_max_new=self.ovl_brownout_max_new,
            brownout_shed_floor=self.ovl_brownout_shed_floor,
            shed_floor=self.ovl_shed_floor).validate()

    def retry_policy(self):
        if self.retry_max <= 0:
            return None
        from repro.serving.resilience import RetryPolicy
        return RetryPolicy(max_attempts=self.retry_max,
                           base_delay_s=self.retry_base_s,
                           jitter_s=self.retry_jitter_s,
                           seed=self.seed + 977)

    def arrival_spec(self):
        from repro.serving.arrivals import ArrivalSpec, RateProfile
        profile = None
        if self.profile_kind:
            profile = RateProfile(
                kind=self.profile_kind,
                knots=tuple(tuple(k) for k in self.profile_knots),
                period_s=self.profile_period_s,
                args=tuple(self.profile_args)).validate()
        return ArrivalSpec(lam=self.lam, n_requests=self.n_requests,
                           io_shape=self.io_shape, process=self.process,
                           cv=self.cv, seed=self.seed, scale=self.scale,
                           profile=profile, class_mix=self.class_mix)

    def record_kw(self) -> Dict:
        return dict(config=self.config, model=self.model, hw=self.hw,
                    n_chips=self.n_chips, quant=self.quant,
                    engine_kind=self.engine_kind,
                    price_per_hr=self.price_per_hr)


def group_seed(plan_seed: int, group_key: Sequence) -> int:
    """Stable per-group base seed (CRC32, not hash(): PYTHONHASHSEED-proof)."""
    key = "|".join(str(k) for k in group_key)
    return plan_seed + (zlib.crc32(key.encode()) % 900_000_000)


def cell_seed(plan_seed: int, group_key: Sequence, lam: float) -> int:
    """group base + the ladder rule `_ladder_specs` has always used."""
    return group_seed(plan_seed, group_key) + int(lam * 1000)


@dataclasses.dataclass(frozen=True)
class ExperimentPlan:
    name: str
    cells: Tuple[Cell, ...]
    seed: int = 0
    description: str = ""

    def __len__(self) -> int:
        return len(self.cells)

    def groups(self) -> Dict[Tuple, List[Cell]]:
        out: Dict[Tuple, List[Cell]] = {}
        for c in self.cells:
            out.setdefault(c.group_key, []).append(c)
        return out

    def transform(self, fn: Callable[[Cell], Cell],
                  suffix: str = "") -> "ExperimentPlan":
        """Plan transform: map every cell (e.g. a PERF-override variant).
        The transformed plan keeps per-cell seeds unless `fn` changes them."""
        cells = tuple(fn(c) for c in self.cells)
        return dataclasses.replace(
            self, name=self.name + suffix, cells=cells)

    def subset(self, pred: Callable[[Cell], bool]) -> "ExperimentPlan":
        return dataclasses.replace(
            self, cells=tuple(c for c in self.cells if pred(c)))


def iter_grid(**axes: Sequence) -> Iterator[Dict]:
    """Ordered cartesian product over named axes — the one grid walker the
    subsystem (and launch/optimized_sweep) share, so every consumer
    enumerates cells in the same deterministic order."""
    names = list(axes)
    for combo in itertools.product(*(axes[n] for n in names)):
        yield dict(zip(names, combo))


@dataclasses.dataclass(frozen=True)
class GridSpec:
    """Declarative arch x hw x quant x n_chips x lambda x io_shape grid.

    Hardware is a first-class axis (ISSUE 3): one spec can span several
    generations (`hws=("tpu-v5e", "tpu-v5p", "tpu-v6e")`) with
    per-hardware TP degrees — the same model needs more of the small-HBM
    part — via `n_chips_by_arch_hw`, and per-hardware quant restrictions
    via `quants_by_hw` (e.g. probe fp8 only on the native-fp8 part).
    """
    name: str
    archs: Tuple[str, ...]
    hws: Tuple[str, ...] = ("tpu-v5e",)
    quants: Tuple[str, ...] = ("bf16",)
    ladder: Tuple[float, ...] = LAMBDA_LADDER
    io_shapes: Tuple[str, ...] = ("chat",)
    n_chips: int = 1
    # per-arch TP override as (arch, n_chips) pairs (frozen-friendly map)
    n_chips_by_arch: Tuple[Tuple[str, int], ...] = ()
    # per-(arch, hw) TP override; wins over n_chips_by_arch. This is what
    # lets a cross-hardware plan deploy the same model at hardware-fitting
    # footprints (bf16 weights must fit the part's HBM).
    n_chips_by_arch_hw: Tuple[Tuple[str, str, int], ...] = ()
    # per-hw quant allow-list as (hw, (quant, ...)) pairs; an hw absent
    # from the map runs every quant in `quants`.
    quants_by_hw: Tuple[Tuple[str, Tuple[str, ...]], ...] = ()
    seed: int = 0
    protocol: str = "paper"
    process: str = "poisson"
    cv: float = 1.0
    scale: float = 1.0
    description: str = ""
    # engine knobs applied to every cell
    max_batch: int = 256
    num_pages: int = 65536
    max_pages_per_seq: int = 64
    fast_forward: bool = True
    # resilience axes (ISSUE 6): the grid walks mttf x retry_max after
    # lambda; the remaining knobs are scalars shared by every cell. The
    # defaults keep every pre-existing spec expanding to bit-identical
    # plans (all-zero == resilience off).
    mttfs: Tuple[float, ...] = (0.0,)
    retry_maxes: Tuple[int, ...] = (0,)
    mttr: float = 0.0
    fail_frac: float = 0.5
    retry_base_s: float = 0.5
    retry_jitter_s: float = 0.0
    max_queue_depth: int = 0
    deadline_s: float = 0.0
    # Monte-Carlo ensemble axis (ISSUE 7): each offset replicates the
    # whole grid with an independent arrival stream. The default (0,)
    # expands to the exact historical plan (offset 0 never joins keys).
    seed_offsets: Tuple[int, ...] = (0,)

    def chips_for(self, arch: str, hw: Optional[str] = None) -> int:
        if hw is not None:
            for a, h, n in self.n_chips_by_arch_hw:
                if (a, h) == (arch, hw):
                    return n
        return dict(self.n_chips_by_arch).get(arch, self.n_chips)

    def quants_for(self, hw: str) -> Tuple[str, ...]:
        allowed = dict(self.quants_by_hw).get(hw)
        if allowed is None:
            return self.quants
        return tuple(q for q in self.quants if q in allowed)

    def expand(self) -> ExperimentPlan:
        """Pure expansion: same spec -> same cells, same seeds."""
        req_fn, warm_fn = PROTOCOLS[self.protocol]
        cells: List[Cell] = []
        for ax in iter_grid(arch=self.archs, hw=self.hws, quant=self.quants,
                            io_shape=self.io_shapes, lam=self.ladder,
                            mttf=self.mttfs, retry_max=self.retry_maxes,
                            seed_offset=self.seed_offsets):
            if ax["quant"] not in self.quants_for(ax["hw"]):
                continue
            chips = self.chips_for(ax["arch"], ax["hw"])
            resil = ax["mttf"] > 0.0 or ax["retry_max"] > 0
            cell = Cell(
                plan=self.name, config=ax["arch"], model=ax["arch"],
                arch=ax["arch"], hw=ax["hw"], quant=ax["quant"],
                n_chips=chips, lam=float(ax["lam"]),
                io_shape=ax["io_shape"], seed=0,
                n_requests=req_fn(ax["lam"]), warmup=warm_fn(ax["lam"]),
                price_per_hr=chip_hour_price(ax["hw"], chips),
                process=self.process, cv=self.cv, scale=self.scale,
                max_batch=self.max_batch, num_pages=self.num_pages,
                max_pages_per_seq=self.max_pages_per_seq,
                fast_forward=self.fast_forward,
                mttf=float(ax["mttf"]), retry_max=int(ax["retry_max"]),
                # shared knobs only matter on resilient cells; keeping
                # them zeroed elsewhere preserves historical cell specs.
                mttr=self.mttr if ax["mttf"] > 0.0 else 0.0,
                fail_frac=self.fail_frac if ax["mttf"] > 0.0 else 0.5,
                retry_base_s=self.retry_base_s,
                retry_jitter_s=self.retry_jitter_s,
                max_queue_depth=self.max_queue_depth if resil else 0,
                deadline_s=self.deadline_s if resil else 0.0,
                seed_offset=int(ax["seed_offset"]))
            cells.append(dataclasses.replace(
                cell, seed=cell_seed(self.seed, cell.seed_key, cell.lam)))
        return ExperimentPlan(name=self.name, cells=tuple(cells),
                              seed=self.seed, description=self.description)


def ladder_plan(*, name: str = "ladder", ladder: Sequence[float],
                io_shape: str = "chat", scale: float = 1.0,
                requests_per_point: Optional[Callable[[float], int]] = None,
                warmup_per_point: Optional[Callable[[float], int]] = None,
                horizon: Optional[float] = None, seed: int = 0,
                process: str = "poisson", cv: float = 1.0,
                config: str = "", model: str = "", hw: str = "cpu-node",
                n_chips: int = 1, quant: str = "bf16",
                engine_kind: str = "sim", price_per_hr: float = 1.0,
                failure_times: Sequence[float] = (),
                arch: str = "") -> ExperimentPlan:
    """The single-group plan behind `lambda_sweep`/`parallel_sweep`.

    Seeds are `seed + int(lam * 1000)` — the raw sweep seed, NOT routed
    through `group_seed`, so refactored sweeps reproduce the historical
    records exactly.
    """
    if requests_per_point is None:
        requests_per_point = default_requests_per_point
    if warmup_per_point is None:
        warmup_per_point = default_warmup_per_point
    cells = tuple(
        Cell(plan=name, config=config, model=model, arch=arch, hw=hw,
             quant=quant, n_chips=n_chips, lam=float(lam), io_shape=io_shape,
             seed=seed + int(lam * 1000), n_requests=requests_per_point(lam),
             warmup=warmup_per_point(lam), price_per_hr=price_per_hr,
             process=process, cv=cv, scale=scale, horizon=horizon,
             failure_times=tuple(failure_times), engine_kind=engine_kind)
        for lam in ladder)
    return ExperimentPlan(name=name, cells=cells, seed=seed)
