"""Declarative experiment-matrix subsystem (ISSUE 2).

The paper's headline numbers come from full benchmark *matrices* — 42
H100-analogue and 56 A100-analogue cells over (model, hardware, quant,
lambda) — not from single lambda ladders. This package turns those
matrices into first-class, resumable objects:

  plan.py    — Cell / GridSpec / ExperimentPlan frozen dataclasses; a grid
               spec expands deterministically (same spec -> same cell list
               and same per-cell seeds, derived from the plan seed).
  store.py   — resumable on-disk result store:
               results/experiments/<plan>/cell_<id>.json per finished cell
               plus a consolidated CSV + manifest; completed cells are
               skipped on restart.
  runner.py  — PlanRunner + execute_cells with two backends: per-cell
               over the persistent process pool, or backend="vector" —
               cells chunked into lanes of the struct-of-arrays fleet
               simulator (ISSUE 4; bit-identical records, ~6x cells/s
               per core); serial fallback warns instead of hiding.
  plans.py   — the first-class plans: paper_h100 (42 cells on tpu-v5p),
               paper_a100 (56 cells on tpu-v5e), paper_crosshw (126 cells
               across v5e + v5p + v6e, ISSUE 3), paper_atlas (450-cell
               lambda-continuum penalty atlas, ISSUE 4),
               probe_int8_nonnative (126-cell per-hw quant probe),
               mini_2x2 / mini_crosshw (CI smokes), quickstart.
  analyze.py — derives the paper's figures from a store: penalty-vs-lambda
               spread, active-params saturation ordering, per-hardware FP8
               uplift, API crossover; cross-hardware tables (spread
               compression, native-fp8-conditioned inversion, ordering
               survival) from a multi-hardware store.
  run.py     — CLI: python -m repro.experiments.run --plan paper_a100 --resume

`core.sweep.lambda_sweep` / `parallel_sweep` are thin ladder plans over
this machinery; `launch.optimized_sweep` builds its grid via `iter_grid`.
"""
from repro.experiments.plan import (  # noqa: F401
    Cell, ExperimentPlan, GridSpec, iter_grid, ladder_plan)
from repro.experiments.plans import PLANS, get_plan  # noqa: F401
from repro.experiments.runner import PlanRunner, run_cell  # noqa: F401
from repro.experiments.store import ExperimentStore  # noqa: F401
