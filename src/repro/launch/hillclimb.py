import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

"""Perf hillclimb (§Perf): hypothesis -> change -> re-lower -> validate.

Three cells (picked from the baseline roofline table):
  A mixtral-8x7b x train_4k    — most collective-bound
  B llama31-8b  x decode_32k   — most representative of the paper (C1 serving)
  C xlstm-350m  x train_4k     — worst roofline fraction

Each variant re-lowers the cell with a lever flipped and records the three
terms; results land in results/perf/ and the printed log is the §Perf
iteration record.

    PYTHONPATH=src python -m repro.launch.hillclimb [--cell A|B|C|all]
"""
import argparse        # noqa: E402
import json            # noqa: E402
from pathlib import Path  # noqa: E402

import jax.numpy as jnp   # noqa: E402

import repro.launch.specs as specs_lib        # noqa: E402
import repro.models.model as model_lib        # noqa: E402
from repro.launch.dryrun import run_cell      # noqa: E402
from repro.parallel.sharding import DEFAULT   # noqa: E402

RESULTS = Path(__file__).resolve().parents[3] / "results" / "perf"


def run_variant(arch, shape, name, hypothesis, *, rules=None, perf=None,
                fsdp_threshold=None, multi_pod=False, quant="bf16"):
    prev_perf = model_lib.PERF
    prev_thresh = specs_lib.FSDP_THRESHOLD_BYTES
    try:
        model_lib.PERF = model_lib.PerfConfig(**(perf or {}))
        if fsdp_threshold is not None:
            specs_lib.FSDP_THRESHOLD_BYTES = fsdp_threshold
        rec = run_cell(arch, shape, multi_pod=multi_pod, save=False,
                       rules=rules, verbose=False, quant=quant)
    finally:
        model_lib.PERF = prev_perf
        specs_lib.FSDP_THRESHOLD_BYTES = prev_thresh
    t = rec["roofline"]
    row = {
        "variant": name, "hypothesis": hypothesis,
        "compute_s": t["compute_s"], "memory_hlo_s": t["memory_s"],
        "memory_floor_s": t["memory_analytic_s"],
        "collective_s": t["collective_s"],
        "coll_bytes": rec["collective_bytes"],
        "bottleneck": t["bottleneck"], "frac": t["roofline_frac"],
        "arg_bytes": rec["memory_analysis"].get("argument_size_in_bytes"),
        "temp_bytes": rec["memory_analysis"].get("temp_size_in_bytes"),
        "compile_s": rec["compile_s"],
    }
    RESULTS.mkdir(parents=True, exist_ok=True)
    tag = f"{arch}_{shape}_{name}".replace("/", "-").replace(" ", "_")
    (RESULTS / f"{tag}.json").write_text(json.dumps(row, indent=1))
    print(f"  [{name:<28}] comp={row['compute_s']:.3g}s "
          f"memHLO={row['memory_hlo_s']:.3g}s "
          f"coll={row['collective_s']:.3g}s "
          f"({row['coll_bytes']['total']:.3g}B) "
          f"temp={row['temp_bytes'] and row['temp_bytes']/1e9:.1f}GB "
          f"bound={row['bottleneck']} frac={row['frac']:.3f}")
    return row


def cell_A():
    print("\n=== CELL A: mixtral-8x7b x train_4k (collective-bound) ===")
    arch, shape = "mixtral-8x7b", "train_4k"
    rows = [run_variant(arch, shape, "baseline",
                        "post-MoE-group-fix faithful baseline")]
    rows.append(run_variant(
        arch, shape, "A1_seq_parallel",
        "activation all-reduces (2/layer of B*S*d) become sharded-residual "
        "AG/RS pairs: expect ~2x less activation collective volume",
        rules=DEFAULT.but(seq="model")))
    rows.append(run_variant(
        arch, shape, "A2_no_fsdp",
        "weights 5.8GB/dev fit TP-only: dropping FSDP kills the per-layer "
        "weight all-gathers (268GB/step) at +5.4GB residency",
        fsdp_threshold=1e18))
    rows.append(run_variant(
        arch, shape, "A3_seqpar_and_no_fsdp",
        "A1+A2 compose: both collective sources removed together",
        rules=DEFAULT.but(seq="model"), fsdp_threshold=1e18))
    # A4: larger dispatch groups -> fewer, fatter expert einsums; capacity
    # rounding waste shrinks (C = ceil(Sg*k/E*cf) quantizes less at Sg=1024)
    import dataclasses
    from repro.configs import _REGISTRY, get_config
    cfg = get_config(arch)
    try:
        _REGISTRY[arch] = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, group_size=1024))
        rows.append(run_variant(
            arch, shape, "A4_group1024_no_fsdp",
            "4x larger MoE dispatch groups on top of A2",
            fsdp_threshold=1e18))
    finally:
        _REGISTRY[arch] = cfg
    return rows


def cell_B():
    print("\n=== CELL B: llama31-8b x decode_32k (paper C1 serving) ===")
    arch, shape = "llama31-8b", "decode_32k"
    rows = [run_variant(arch, shape, "baseline",
                        "GSPMD decode over seq-sharded cache")]
    rows.append(run_variant(
        arch, shape, "B1_flash_decode",
        "shard_map partial-softmax: replaces GSPMD's gather/reshard of "
        "score tensors with 3 tiny psums (num/den/max)",
        perf=dict(flash_decode=True)))
    rows.append(run_variant(
        arch, shape, "B2_fp8_kv_cache",
        "fp8-e4m3 KV halves the dominant HBM stream (cache reads): memory "
        "floor 2.7ms -> ~1.4ms, HLO bytes should drop ~2x on cache ops",
        perf=dict(kv_cache_dtype=jnp.float8_e4m3fn)))
    rows.append(run_variant(
        arch, shape, "B3_flash_and_fp8",
        "compose B1+B2",
        perf=dict(flash_decode=True, kv_cache_dtype=jnp.float8_e4m3fn)))
    rows.append(run_variant(
        arch, shape, "B4_int8_weights_fp8_kv",
        "the fully-optimized serving config (beyond-paper Q stack): int8 "
        "weights halve the weight stream on top of the fp8 cache",
        perf=dict(flash_decode=True, kv_cache_dtype=jnp.float8_e4m3fn),
        quant="int8"))
    return rows


def cell_C():
    print("\n=== CELL C: xlstm-350m x train_4k (worst roofline frac) ===")
    arch, shape = "xlstm-350m", "train_4k"
    rows = [run_variant(arch, shape, "baseline",
                        "GSPMD recurrence: 413GB/step collective-permutes")]
    rows.append(run_variant(
        arch, shape, "C1_local_recurrence",
        "shard_map the xLSTM scans (batch-local, params replicated): "
        "permutes inside the time loop vanish; only param-grad psums "
        "(~10GB/step) remain",
        perf=dict(local_recurrence=True)))
    rows.append(run_variant(
        arch, shape, "C2_local_rec_seqpar",
        "C1 + sequence-parallel residual stream for the surrounding "
        "norms/projections",
        perf=dict(local_recurrence=True), rules=DEFAULT.but(seq="model")))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default="all", choices=["A", "B", "C", "all"])
    args = ap.parse_args()
    if args.cell in ("A", "all"):
        cell_A()
    if args.cell in ("B", "all"):
        cell_B()
    if args.cell in ("C", "all"):
        cell_C()


if __name__ == "__main__":
    main()
