"""Training driver: mesh-sharded train loop with checkpoint/restart.

    PYTHONPATH=src python -m repro.launch.train --arch llama31-8b \
        --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On the CPU container this drives reduced configs end-to-end (the ~100M
example uses it); on a real pod the same driver runs full configs under
make_production_mesh. Restart-and-continue: re-running with the same
--ckpt-dir resumes from the newest intact checkpoint.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.launch.mesh import make_host_mesh
from repro.launch.specs import (abstract_params, opt_shardings,
                                param_shardings)
from repro.models import init_params
from repro.parallel.sharding import shardctx
from repro.training import (CheckpointManager, SyntheticDataLoader, adamw,
                            adamw8bit, build_train_step)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama31-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--ff", type=int, default=1024)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--opt", default="adamw", choices=["adamw", "adamw8bit"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = (reduced(args.arch, layers=args.layers, d_model=args.d_model,
                   vocab=args.vocab, ff=args.ff)
           if args.reduced else get_config(args.arch))
    mesh = make_host_mesh()
    opt = (adamw8bit if args.opt == "adamw8bit" else adamw)(args.lr)

    with shardctx(mesh):
        params = init_params(jax.random.PRNGKey(args.seed), cfg)
        opt_state = opt.init(params)
        step_fn = jax.jit(build_train_step(cfg, opt, remat=True))

        start = 0
        cm = None
        if args.ckpt_dir:
            cm = CheckpointManager(args.ckpt_dir, keep=3)
            res = cm.restore_latest({"params": params, "opt": opt_state})
            if res is not None:
                start, tree, _ = res
                params, opt_state = tree["params"], tree["opt"]
                print(f"resumed from step {start}")

        dl = SyntheticDataLoader(
            cfg.vocab_size, args.batch, args.seq, seed=args.seed,
            frames=cfg.frontend_len if cfg.encoder_layers else 0,
            d_model=cfg.d_model,
            patches=16 if cfg.frontend == "vision_patches" else 0)

        t0 = time.time()
        tokens_done = 0
        for i in range(start, args.steps):
            params, opt_state, stats = step_fn(params, opt_state,
                                               dl.batch_at(i))
            tokens_done += args.batch * args.seq
            if (i + 1) % args.log_every == 0:
                loss = float(stats["loss"])
                tps = tokens_done / (time.time() - t0)
                print(f"step {i+1:5d} loss {loss:8.4f} "
                      f"gnorm {float(stats['grad_norm']):8.3f} "
                      f"tok/s {tps:9.0f}")
            if cm and (i + 1) % args.ckpt_every == 0:
                cm.save(i + 1, {"params": params, "opt": opt_state})
        if cm:
            cm.save(args.steps, {"params": params, "opt": opt_state})
            cm.wait()
        print(f"done: {args.steps - start} steps, "
              f"{time.time() - t0:.1f}s")
        return params


if __name__ == "__main__":
    main()
