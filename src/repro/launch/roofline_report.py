"""Regenerate the §Roofline table from the recorded dry-run corpus
(results/dryrun/*.json) without recompiling.

    PYTHONPATH=src python -m repro.launch.roofline_report [--mesh 16x16]
"""
from __future__ import annotations

import argparse
import glob
import json
from pathlib import Path

from repro.configs import SHAPES_BY_NAME, get_config
from repro.launch.roofline import compute_roofline
from repro.simulate.hardware import HW_BY_NAME

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def regenerate(mesh: str = "16x16", quant: str = "bf16", hw: str = "tpu-v5e"):
    rows = []
    for f in sorted(glob.glob(str(RESULTS / f"*_{mesh}_{quant}.json"))):
        r = json.load(open(f))
        cfg = get_config(r["arch"])
        shape = SHAPES_BY_NAME[r["shape"]]
        t = compute_roofline(
            cfg, shape, mesh_name=r["mesh"], n_devices=r["n_devices"],
            cost=r["cost_analysis"],
            coll_bytes=r["collective_bytes"]["total"],
            hw=HW_BY_NAME[hw], quant=quant)
        rows.append(t)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--quant", default="bf16")
    ap.add_argument("--hw", default="tpu-v5e")
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    rows = regenerate(args.mesh, args.quant, args.hw)
    rows.sort(key=lambda t: t.roofline_frac)
    sep = " | " if args.markdown else " "
    hdr = ["arch", "shape", "bound", "frac", "compute_s", "mem_floor_s",
           "mem_hlo_s", "coll_s", "mfr"]
    if args.markdown:
        print("| " + " | ".join(hdr) + " |")
        print("|" + "---|" * len(hdr))
    else:
        print(f"{'arch':<26} {'shape':<12} {'bound':<11} {'frac':>6} "
              f"{'compute_s':>10} {'memfloor':>9} {'memhlo':>9} "
              f"{'coll_s':>9} {'mfr':>5}")
    for t in rows:
        vals = [t.arch, t.shape, t.bottleneck, f"{t.roofline_frac:.3f}",
                f"{t.compute_s:.3g}", f"{t.memory_analytic_s:.3g}",
                f"{t.memory_s:.3g}", f"{t.collective_s:.3g}",
                f"{t.model_flops_ratio:.2f}"]
        if args.markdown:
            print("| " + " | ".join(vals) + " |")
        else:
            print(f"{vals[0]:<26} {vals[1]:<12} {vals[2]:<11} {vals[3]:>6} "
                  f"{vals[4]:>10} {vals[5]:>9} {vals[6]:>9} {vals[7]:>9} "
                  f"{vals[8]:>5}")


if __name__ == "__main__":
    main()
