"""Post-optimization HLO analysis: collective operand bytes, loop-aware.

XLA's cost_analysis counts while-loop (lax.scan) bodies ONCE, not
trip_count times — verified on this backend (a 5-step scan of a 128-flop
matmul reports 146 flops). The same holds for any text-level accounting,
so this parser:

  1. splits the module into computations,
  2. finds every `while`, reads the trip count from the constant in its
     condition computation,
  3. propagates an execution-count multiplier down the call graph
     (nested scans multiply),
  4. sums collective operand bytes weighted by the enclosing computation's
     multiplier.

The resulting per-device collective bytes are per *step*, comparable
across cells regardless of scan structure.
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter",
                  "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w.\-]+)\s*\(")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.*)$")
_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=(%[\w.\-]+),\s*body=(%[\w.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s*constant\((\d+)\)")
_CALL_RE = re.compile(r"(?:calls|to_apply|condition|body)=(%[\w.\-]+)")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_computations(hlo_text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    entry = None
    for line in hlo_text.splitlines():
        m = _HEADER_RE.match(line)
        if m and line.rstrip().endswith("{"):
            cur = m.group(1)
            comps[cur] = []
            if line.startswith("ENTRY"):
                entry = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    if entry is not None:
        comps["__entry__"] = comps[entry]
    return comps


def computation_multipliers(comps: Dict[str, List[str]]) -> Dict[str, float]:
    """Execution count per computation (nested while loops multiply)."""
    # trip count per condition computation
    def trip_of(cond: str) -> int:
        consts = [int(c) for lines in [comps.get(cond, [])]
                  for line in lines for c in _CONST_RE.findall(line)]
        return max(consts) if consts else 1

    mult: Dict[str, float] = {name: 1.0 for name in comps}
    entry = comps.get("__entry__")
    if entry is None:
        return mult
    # propagate from entry through the call graph (iterate to fixpoint)
    order = ["__entry__"]
    seen = {"__entry__"}
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        for line in comps.get(cname, []):
            w = _WHILE_RE.search(line)
            if w:
                cond, body = w.groups()
                mult[body] = mult.get(body, 1.0) * 0 + \
                    mult[cname] * trip_of(cond)
                if body not in seen:
                    seen.add(body)
                    order.append(body)
                continue
            for callee in _CALL_RE.findall(line):
                if callee not in seen and callee in comps:
                    mult[callee] = mult[cname]
                    seen.add(callee)
                    order.append(callee)
    return mult


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-kind collective operand bytes per device, loop-trip-weighted."""
    comps = parse_computations(hlo_text)
    mult = computation_multipliers(comps)

    out = {k: 0.0 for k in COLLECTIVE_OPS}
    for cname, lines in comps.items():
        if cname == "__entry__":
            continue
        m = mult.get(cname, 1.0)
        shapes: Dict[str, str] = {}
        pend: List[Tuple[str, List[str], str]] = []
        for line in lines:
            im = _INSTR_RE.match(line)
            if not im:
                continue
            name, rest = im.groups()
            # leading result-shape only (tuples span to the matching paren)
            if rest.startswith("("):
                shapes[name] = rest[:rest.find(")") + 1]
            else:
                shapes[name] = rest.split(" ")[0]
            for kind in COLLECTIVE_OPS:
                token = f" {kind}(" if f" {kind}(" in line else (
                    f" {kind}-start(" if f" {kind}-start(" in line else None)
                if token:
                    args = line.split(token, 1)[1].split(")", 1)[0]
                    ops = [a.strip().split(" ")[-1] for a in args.split(",")
                           if a.strip().startswith("%") or " %" in a]
                    pend.append((kind, ops, rest))
                    break
        for kind, ops, own in pend:
            b = sum(_shape_bytes(shapes.get(o, "")) for o in ops)
            if b == 0:
                b = _shape_bytes(own.split(f"{kind}")[0])
            out[kind] += b * m
    # entry-level collectives (outside any sub-computation) were attributed
    # to the entry's named computation already (it is in comps).
    out["total"] = sum(out[k] for k in COLLECTIVE_OPS)
    return out


def count_ops(hlo_text: str, opname: str) -> int:
    pat = r"=\s*[\w\[\]{},. ]*?" + re.escape(opname) + r"\("
    return len(re.findall(pat, hlo_text))


def scan_flop_multiplier(hlo_text: str) -> float:
    """Rough whole-module correction: weighted mean loop multiplier by
    instruction count — used to scale aggregate cost_analysis numbers when
    an analytic model is unavailable."""
    comps = parse_computations(hlo_text)
    mult = computation_multipliers(comps)
    tot = w = 0.0
    for cname, lines in comps.items():
        if cname == "__entry__":
            continue
        n = len(lines)
        tot += n * mult.get(cname, 1.0)
        w += n
    return tot / w if w else 1.0
