"""Production meshes.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax call).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """(16,16)=(data,model) single pod (256 chips) or (2,16,16)=
    (pod,data,model) for 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over whatever local devices exist (tests)."""
    n = len(jax.devices())
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))
