import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

"""Beyond-paper optimized sweep: apply the §Perf winners fleet-wide.

The (arch x shape) grid comes from the experiment subsystem's shared grid
walker (`repro.experiments.iter_grid`) and the PERF overrides are applied
as a *plan transform*: `perf_variant(cfg)` maps a baseline cell to its
optimized twin, and the sweep runs the transformed grid — the same
declarative shape as an ExperimentPlan.transform over engine cells.

Serving cells (decode/prefill): fp8-e4m3 KV cache + flash-decoding.
Recurrent-arch cells (ssm/hybrid): + shard_map-local recurrence.
Saves results/dryrun_opt/<cell>.json; prints baseline-vs-optimized frac.

    PYTHONPATH=src python -m repro.launch.optimized_sweep [--shapes decode_32k,long_500k]
"""
import argparse     # noqa: E402
import contextlib   # noqa: E402
import json         # noqa: E402
from pathlib import Path  # noqa: E402

import jax.numpy as jnp   # noqa: E402

import repro.models.model as model_lib       # noqa: E402
from repro.configs import ALL_ARCHS, get_config  # noqa: E402
from repro.experiments.plan import iter_grid  # noqa: E402
from repro.launch.dryrun import RESULTS, run_cell  # noqa: E402

OPT_RESULTS = RESULTS.parent / "dryrun_opt"


def perf_variant(cfg) -> "model_lib.PerfConfig":
    """The transform: baseline cell -> §Perf-winner overrides for it."""
    return model_lib.PerfConfig(
        kv_cache_dtype=jnp.float8_e4m3fn,
        flash_decode=True,
        local_recurrence=cfg.family in ("ssm", "hybrid"))


@contextlib.contextmanager
def perf_overrides(perf: "model_lib.PerfConfig"):
    prev = model_lib.PERF
    model_lib.PERF = perf
    try:
        yield
    finally:
        model_lib.PERF = prev


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--shapes", default="decode_32k,long_500k")
    ap.add_argument("--archs", default=",".join(ALL_ARCHS))
    args = ap.parse_args()
    shapes = args.shapes.split(",")
    OPT_RESULTS.mkdir(parents=True, exist_ok=True)

    rows = []
    for ax in iter_grid(arch=args.archs.split(","), shape=shapes):
        arch, shape = ax["arch"], ax["shape"]
        cfg = get_config(arch)
        if shape not in {s.name for s in cfg.shapes()}:
            continue
        base_f = RESULTS / f"{arch}_{shape}_16x16_bf16.json"
        base = json.load(open(base_f)) if base_f.exists() else None
        try:
            with perf_overrides(perf_variant(cfg)):
                rec = run_cell(arch, shape, save=False, verbose=False)
        except Exception as e:  # noqa: BLE001
            print(f"FAIL {arch} x {shape}: {e}")
            continue
        (OPT_RESULTS / f"{arch}_{shape}_16x16_bf16.json").write_text(
            json.dumps(rec, indent=1))
        t = rec["roofline"]
        b = base["roofline"] if base else {}
        rows.append((arch, shape, b.get("roofline_frac"),
                     t["roofline_frac"], b.get("memory_s"),
                     t["memory_s"], b.get("collective_s"),
                     t["collective_s"]))
        print(f"{arch:<26} {shape:<11} frac "
              f"{b.get('roofline_frac', float('nan')):.3f}"
              f"->{t['roofline_frac']:.3f}  mem "
              f"{b.get('memory_s', float('nan')):.4g}"
              f"->{t['memory_s']:.4g}  coll "
              f"{b.get('collective_s', float('nan')):.4g}"
              f"->{t['collective_s']:.4g}")
    better = sum(1 for r in rows if r[2] is not None and r[3] > r[2])
    print(f"\n{better}/{len(rows)} cells improved roofline fraction")


if __name__ == "__main__":
    main()
