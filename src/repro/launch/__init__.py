"""Launch layer: meshes, multi-pod dry-run, HLO analysis, roofline, CLIs."""
