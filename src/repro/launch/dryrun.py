import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above run before ANY other import — jax locks the device
count on first init. 512 placeholder host devices back both production
meshes: (16,16) single pod and (2,16,16) multi-pod.

Per cell: jit(step).lower(abstract args).compile() must succeed;
memory_analysis() proves fit, cost_analysis() + the HLO collective parser
feed §Roofline. Results land in results/dryrun/<cell>.json.

Usage:
    python -m repro.launch.dryrun --arch llama31-8b --shape decode_32k
    python -m repro.launch.dryrun --all [--multi-pod] [--quant int8]
"""
import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from pathlib import Path  # noqa: E402

import jax           # noqa: E402

from repro.configs import ALL_ARCHS, SHAPES_BY_NAME, get_config  # noqa: E402
from repro.launch.hlo_analysis import collective_bytes  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.roofline import compute_roofline  # noqa: E402
from repro.launch.specs import build_cell  # noqa: E402
from repro.parallel.sharding import shardctx  # noqa: E402
from repro.quant import BY_NAME as QUANT_BY_NAME  # noqa: E402
from repro.simulate.hardware import HW_BY_NAME  # noqa: E402

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             quant: str = "bf16", hw: str = "tpu-v5e",
             rules=None, save: bool = True, verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    n_dev = mesh.devices.size
    qcfg = QUANT_BY_NAME[quant] if quant != "bf16" else None

    t0 = time.time()
    with shardctx(mesh, rules):
        fn, args, in_shardings, donate = build_cell(cfg, shape, mesh, qcfg)
        jf = jax.jit(fn, in_shardings=in_shardings, donate_argnums=donate)
        with mesh:
            lowered = jf.lower(*args)
            compiled = lowered.compile()
    compile_s = time.time() - t0

    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        # older jaxlib returns one properties-dict per partition
        cost = cost[0] if cost else {}
    try:
        mem = compiled.memory_analysis()
        mem_d = {k: getattr(mem, k) for k in
                 ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes")
                 if hasattr(mem, k)}
    except Exception:
        mem_d = {}
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    terms = compute_roofline(
        cfg, shape, mesh_name=mesh_name, n_devices=n_dev, cost=cost,
        coll_bytes=coll["total"], hw=HW_BY_NAME[hw], quant=quant)

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "quant": quant, "hw": hw, "n_devices": n_dev,
        "compile_s": compile_s,
        "cost_analysis": {k: float(v) for k, v in cost.items()
                          if isinstance(v, (int, float))},
        "memory_analysis": {k: int(v) for k, v in mem_d.items()},
        "collective_bytes": coll,
        "roofline": terms.row(),
        "hlo_bytes": len(hlo),
        "status": "ok",
    }
    if verbose:
        print(f"[{arch} x {shape_name} x {mesh_name} x {quant}] "
              f"compile={compile_s:.1f}s "
              f"flops/dev={terms.flops_per_device:.3g} "
              f"bytes/dev={terms.bytes_per_device:.3g} "
              f"coll/dev={coll['total']:.3g} "
              f"bottleneck={terms.bottleneck} "
              f"frac={terms.roofline_frac:.3f}")
        if mem_d:
            print(f"  memory_analysis: {mem_d}")
    if save:
        RESULTS.mkdir(parents=True, exist_ok=True)
        tag = f"{arch}_{shape_name}_{mesh_name}_{quant}".replace("/", "-")
        (RESULTS / f"{tag}.json").write_text(json.dumps(rec, indent=1))
    return rec


def cells_for(arch: str):
    cfg = get_config(arch)
    return [s.name for s in cfg.shapes()]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--quant", default="bf16")
    ap.add_argument("--hw", default="tpu-v5e")
    args = ap.parse_args()

    jobs = []
    archs = ALL_ARCHS if (args.all or not args.arch) else [args.arch]
    for a in archs:
        shapes = cells_for(a) if (args.all or not args.shape) \
            else [args.shape]
        for s in shapes:
            meshes = [args.multi_pod] if not args.both_meshes \
                else [False, True]
            for mp in meshes:
                jobs.append((a, s, mp))

    failures = []
    for a, s, mp in jobs:
        try:
            run_cell(a, s, multi_pod=mp, quant=args.quant, hw=args.hw)
        except Exception as e:
            failures.append((a, s, mp, repr(e)))
            print(f"FAIL [{a} x {s} x mp={mp}]: {e}")
            traceback.print_exc()
    print(f"\n{len(jobs) - len(failures)}/{len(jobs)} cells compiled")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
