"""Three-term roofline from the compiled dry-run artifact (§Roofline).

    compute    = HLO_FLOPs_per_device / peak_FLOP/s
    memory     = HLO_bytes_per_device / HBM_bw
    collective = collective_bytes_per_device / ICI_link_bw

cost_analysis() on the SPMD module reports per-device flops/bytes;
collective bytes come from the HLO parser. MODEL_FLOPS (6·N·D train,
2·N_active·D serve) over HLO_FLOPs flags remat/redundancy waste. XLA:CPU's
"bytes accessed" over-counts vs a fused TPU executable, so the analytic
HBM floor (weights+state streamed once + activation traffic) is reported
alongside as `memory_analytic` (DESIGN §6).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

from repro.configs.base import ModelConfig, ShapeConfig
from repro.simulate.hardware import HardwareGen, V5E


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    flops_per_device: float          # analytic (scan-corrected) per device
    flops_per_device_hlo: float      # raw cost_analysis (bodies counted 1x)
    bytes_per_device: float          # scan-corrected estimate
    bytes_per_device_hlo: float
    scan_multiplier: float           # applied body-execution correction
    collective_bytes_per_device: float   # trip-count-weighted HLO parse
    compute_s: float
    memory_s: float
    memory_analytic_s: float
    collective_s: float
    model_flops: float
    model_flops_ratio: float       # useful / implemented (whole job)
    bottleneck: str
    roofline_frac: float           # resource floor / dominant term

    def row(self) -> Dict:
        return dataclasses.asdict(self)


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6·N_active·tokens (train) or 2·N_active·tokens (serve)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch            # one new token per sequence
    return 2.0 * n * tokens


def analytic_flops(cfg: ModelConfig, shape: ShapeConfig,
                   remat: bool = True) -> float:
    """Exact whole-job FLOPs of OUR implementation (XLA cost_analysis
    counts scan bodies once — verified — so the structural model is the
    ground truth; the raw HLO number ships alongside for audit).

    Matmul flops: fwd 2·N_active·T; backward +4·N·T; remat recompute +2·N·T.
    Attention: the chunked/flash path computes the full S x S rectangle
    (masked), so 4·B·Hq·hd·S·S_kv per attn layer fwd.
    """
    B, S = shape.global_batch, shape.seq_len
    n_attn = sum(1 for k in cfg.block_pattern() if k == "attn")
    hq, hd = cfg.num_heads, cfg.resolved_head_dim
    if shape.kind == "decode":
        T = B
        base = 2.0 * cfg.active_param_count() * T
        attn = 4.0 * B * n_attn * hq * hd * S        # read S-ctx per token
        return base + attn
    T = B * S
    fwd_mult, attn_mult = (1.0, 1.0)
    if shape.kind == "train":
        fwd_mult = 3.0 + (1.0 if remat else 0.0)     # fwd+bwd(2x)+remat
        attn_mult = fwd_mult
    base = 2.0 * cfg.active_param_count() * T * fwd_mult
    full_rect = S > 2048        # chunked path computes masked full S^2
    attn = 4.0 * B * n_attn * hq * hd * S * (S if full_rect else S / 2)
    attn *= attn_mult
    if cfg.encoder_layers:
        Se = cfg.frontend_len or 1500
        enc_p = cfg.encoder_layers * (
            4 * cfg.d_model * hq * hd + 2 * cfg.d_model * cfg.d_ff)
        base += 2.0 * enc_p * B * Se * fwd_mult
        attn += 4.0 * B * cfg.encoder_layers * hq * hd * Se * Se * attn_mult
    return base + attn


def analytic_memory_bytes(cfg: ModelConfig, shape: ShapeConfig,
                          weight_bytes_per_param: int = 2) -> float:
    """HBM floor: weights + KV/state traffic + activations, whole job."""
    w = cfg.param_count() * weight_bytes_per_param
    B, S = shape.global_batch, shape.seq_len
    act = 0.0
    if shape.kind in ("train", "prefill"):
        act = 4.0 * B * S * cfg.d_model * 2 * cfg.num_layers
        if shape.kind == "train":
            w *= 3            # params read + grad write + opt update traffic
    else:
        act = B * cfg.kv_bytes_per_token() * S     # read the whole cache
        w += B * cfg.kv_bytes_per_token()          # append one token
    return w + act


def compute_roofline(cfg: ModelConfig, shape: ShapeConfig, *,
                     mesh_name: str, n_devices: int,
                     cost: Dict[str, float],
                     coll_bytes: float,
                     hw: HardwareGen = V5E,
                     quant: str = "bf16") -> RooflineTerms:
    flops_hlo = float(cost.get("flops", 0.0))
    bytes_hlo = float(cost.get("bytes accessed", 0.0))
    peak = hw.peak(quant)

    # analytic (scan-corrected) compute; HLO raw reported alongside.
    flops_dev = analytic_flops(cfg, shape) / n_devices
    scan_mult = flops_dev / flops_hlo if flops_hlo else 1.0
    # bytes undercount lives in the same loop bodies -> scale by the same
    # body-execution multiplier (capped: never report below the raw value)
    bytes_dev = bytes_hlo * max(scan_mult, 1.0)

    compute_s = flops_dev / peak
    memory_s = bytes_dev / hw.hbm_bw
    mf = model_flops(cfg, shape)
    wbytes = 1 if quant in ("int8", "fp8") else 2
    mem_an = analytic_memory_bytes(cfg, shape, wbytes) / n_devices / hw.hbm_bw
    coll_s = coll_bytes / hw.ici_bw
    ratio = mf / (flops_dev * n_devices) if flops_dev else math.nan
    # bottleneck classification uses the analytic memory floor: XLA:CPU's
    # "bytes accessed" counts every unfused operand and would classify
    # every cell memory-bound (documented in EXPERIMENTS §Roofline; the
    # raw HLO term is reported alongside).
    terms = {"compute": compute_s, "memory": mem_an,
             "collective": coll_s}
    bottleneck = max(terms, key=terms.get)
    terms["memory"] = memory_s       # reported term stays the HLO formula
    useful_compute_s = (mf / n_devices) / peak
    # roofline fraction = irreducible time for the dominant resource over
    # its measured term: model FLOPs for compute-bound cells, the analytic
    # HBM floor for memory-bound cells; collective-bound cells have no
    # intrinsic floor (the collectives are scheme-induced), so the best
    # achievable is whichever physical term would dominate next.
    if bottleneck == "compute":
        frac = useful_compute_s / max(compute_s, 1e-30)
    elif bottleneck == "memory":
        frac = mem_an / max(memory_s, 1e-30)
    else:
        frac = max(useful_compute_s, mem_an) / max(coll_s, 1e-30)
    frac = min(frac, 1.0)
    return RooflineTerms(
        arch=cfg.name, shape=shape.name, mesh=mesh_name,
        n_devices=n_devices, flops_per_device=flops_dev,
        flops_per_device_hlo=flops_hlo,
        bytes_per_device=bytes_dev, bytes_per_device_hlo=bytes_hlo,
        scan_multiplier=scan_mult,
        collective_bytes_per_device=coll_bytes,
        compute_s=compute_s, memory_s=memory_s,
        memory_analytic_s=mem_an, collective_s=coll_s,
        model_flops=mf, model_flops_ratio=ratio,
        bottleneck=bottleneck, roofline_frac=frac)
