"""ShapeDtypeStruct input specs + sharding trees for every dry-run cell.

input_specs(cfg, shape) returns weak-type-correct stand-ins for every model
input — no device allocation ever happens in the dry-run. The step builders
return (fn, abstract_args, in_shardings, donate) ready for
jax.jit(...).lower(...).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import model as model_lib
from repro.parallel.sharding import (
    current_rules, logical_spec, param_spec_tree, zero1_spec)

SDS = jax.ShapeDtypeStruct


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Abstract model inputs for one (arch x shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        out = {"tokens": SDS((B, S), jnp.int32),
               "labels": SDS((B, S), jnp.int32)}
    elif shape.kind == "prefill":
        out = {"tokens": SDS((B, S), jnp.int32)}
    else:  # decode: one new token against a seq_len cache
        out = {"tokens": SDS((B, 1), jnp.int32)}
    if cfg.encoder_layers and shape.kind != "decode":
        out["frames"] = SDS((B, cfg.frontend_len or 1500, cfg.d_model),
                            jnp.bfloat16)
    if cfg.frontend == "vision_patches" and shape.kind != "decode":
        out["patches"] = SDS((B, model_lib.VLM_PATCHES, cfg.d_model),
                             jnp.bfloat16)
    return out


def batch_shardings(batch: Dict[str, Any], mesh: Mesh) -> Dict[str, Any]:
    out = {}
    for k, v in batch.items():
        names = ["batch"] + [None] * (len(v.shape) - 1)
        out[k] = NamedSharding(mesh, logical_spec(v.shape, names, mesh))
    return out


def abstract_params(cfg: ModelConfig) -> Any:
    return jax.eval_shape(
        lambda: model_lib.init_params(jax.random.PRNGKey(0), cfg))


def _shard_factor(spec: P, mesh: Mesh) -> int:
    f = 1
    for part in spec:
        for ax in ((part,) if isinstance(part, str) else (part or ())):
            f *= mesh.shape[ax]
    return f


FSDP_THRESHOLD_BYTES = 4e9   # per-device weight budget before FSDP kicks in


def param_shardings(cfg: ModelConfig, params_abs, mesh: Mesh,
                    fsdp: str = "auto"):
    """TP weight sharding, upgraded to 2D FSDPxTP when the TP-only layout
    would exceed the per-device budget (llama4-maverick: 50 GB -> 3.1 GB)."""
    specs = param_spec_tree(params_abs, mesh,
                            tied_embeddings=cfg.tie_embeddings)
    flat_spec, treedef = jax.tree.flatten(
        specs, is_leaf=lambda x: isinstance(x, P))
    flat_abs = treedef.flatten_up_to(params_abs)
    if fsdp == "auto":
        per_dev = sum(
            a.size * a.dtype.itemsize / _shard_factor(s, mesh)
            for s, a in zip(flat_spec, flat_abs) if a is not None)
        fsdp = "on" if per_dev > FSDP_THRESHOLD_BYTES else "off"
    if fsdp == "on":
        flat_spec = [zero1_spec(s, a.shape, mesh) if a is not None
                     and len(a.shape) >= 2 else s
                     for s, a in zip(flat_spec, flat_abs)]
    out = [NamedSharding(mesh, s) for s in flat_spec]
    return treedef.unflatten(out)


def opt_shardings(cfg: ModelConfig, opt_state_abs, mesh: Mesh):
    """Optimizer-state shardings: parameter rules + ZeRO-1 over `data`."""
    specs = param_spec_tree(opt_state_abs, mesh,
                            tied_embeddings=cfg.tie_embeddings)
    flat_spec, treedef = jax.tree.flatten(
        specs, is_leaf=lambda x: isinstance(x, P))
    flat_abs = treedef.flatten_up_to(opt_state_abs)
    out = [NamedSharding(mesh, zero1_spec(s, a.shape, mesh))
           for s, a in zip(flat_spec, flat_abs)]
    return treedef.unflatten(out)


# cache leaf logical names (mirrors model_lib.constrain_cache)
def _cache_names(name: str, ndim: int):
    if name in ("k", "v"):
        return (None, "batch", "kvheads", "kv_seq_tp", None)
    if name in ("xk", "xv"):
        return (None, "batch", None, "kvheads", None)
    names = [None, "batch"] + [None] * (ndim - 2)
    if name in ("h", "C") and ndim >= 3:
        names[2] = "ssm_inner"
    return tuple(names)


def cache_shardings(cfg: ModelConfig, cache_abs, mesh: Mesh):
    blocks = []
    for blk in cache_abs["blocks"]:
        out = {}
        for name, a in blk.items():
            spec = logical_spec(a.shape, _cache_names(name, len(a.shape)),
                                mesh)
            out[name] = NamedSharding(mesh, spec)
        blocks.append(out)
    return {"len": NamedSharding(mesh, logical_spec(
        cache_abs["len"].shape, ("batch",), mesh)), "blocks": blocks}


# ---------------------------------------------------------------------------
# Step builders per shape kind
# ---------------------------------------------------------------------------

def build_train_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                     qcfg=None, optimizer: str = "adamw8bit",
                     accum_steps: Optional[int] = None):
    """Returns (step_fn, args_abs, in_shardings)."""
    from repro.training import adamw, adamw8bit, build_train_step
    if accum_steps is None:
        # deeper microbatching for 100B+ (MoE dispatch buffers dominate)
        accum_steps = 16 if cfg.param_count() > 1e11 else 8
    opt = adamw8bit(1e-3) if optimizer == "adamw8bit" else adamw(1e-3)
    step = build_train_step(cfg, opt, qcfg=qcfg, remat=True,
                            accum_steps=accum_steps)
    params_abs = abstract_params(cfg)
    opt_abs = jax.eval_shape(opt.init, params_abs)
    batch = input_specs(cfg, shape)
    shardings = (param_shardings(cfg, params_abs, mesh),
                 opt_shardings(cfg, opt_abs, mesh),
                 batch_shardings(batch, mesh))
    # donate params + opt state (updated in place on device)
    return step, (params_abs, opt_abs, batch), shardings, (0, 1)


def build_prefill_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                       qcfg=None):
    params_abs = abstract_params(cfg)
    batch = input_specs(cfg, shape)

    def prefill_step(params, batch):
        return model_lib.prefill(params, cfg, batch, qcfg=qcfg)

    shardings = (param_shardings(cfg, params_abs, mesh),
                 batch_shardings(batch, mesh))
    return prefill_step, (params_abs, batch), shardings, ()


def build_decode_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                      qcfg=None):
    """serve_step: one new token with a KV cache of seq_len."""
    params_abs = abstract_params(cfg)
    B = shape.global_batch
    enc_len = cfg.frontend_len or 1500 if cfg.encoder_layers else 0
    cache_abs = model_lib.abstract_cache(cfg, B, shape.seq_len, enc_len)
    tok = SDS((B, 1), jnp.int32)

    def serve_step(params, token, cache):
        return model_lib.decode_step(params, cfg, token, cache, qcfg=qcfg)

    shardings = (param_shardings(cfg, params_abs, mesh),
                 NamedSharding(mesh, logical_spec((B, 1), ("batch", None),
                                                  mesh)),
                 cache_shardings(cfg, cache_abs, mesh))
    # donate the KV cache: decode updates it in place
    return serve_step, (params_abs, tok, cache_abs), shardings, (2,)


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, qcfg=None,
               **kw):
    """Returns (step_fn, abstract_args, in_shardings, donate_argnums)."""
    if shape.kind == "train":
        return build_train_cell(cfg, shape, mesh, qcfg, **kw)
    if shape.kind == "prefill":
        return build_prefill_cell(cfg, shape, mesh, qcfg)
    return build_decode_cell(cfg, shape, mesh, qcfg)
