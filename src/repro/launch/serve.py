"""Serving driver: engine + live cost meter under an offered-load schedule.

    PYTHONPATH=src python -m repro.launch.serve --arch llama31-8b \
        --tier sim --hw tpu-v5e --lam 5 --requests 200

real tier: reduced model, wall-clock JAX execution on the local device.
sim tier:  full config on the calibrated TPU step-time model.
The meter scrapes the engine's Prometheus text every --tick virtual
seconds and prints the live $/M-tok — the vllm-cost-meter analogue.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core import CostMeter, chip_hour_price
from repro.models import init_params
from repro.serving import (ArrivalSpec, Engine, EngineConfig, RealExecutor,
                           SimExecutor, synth_requests)
from repro.simulate import HW_BY_NAME, StepTimeModel


def build_engine(arch: str, tier: str, hw: str, quant: str = "bf16",
                 n_chips: int = 1, max_batch: int = 256,
                 seed: int = 0):
    if tier == "real":
        cfg = reduced(arch)
        params = init_params(jax.random.PRNGKey(seed), cfg)
        ex = RealExecutor(cfg, params, num_pages=512, page_size=16,
                          max_batch=8)
        ecfg = EngineConfig(max_batch=8, page_size=16, num_pages=512,
                            max_pages_per_seq=32)
    else:
        cfg = get_config(arch)
        stm = StepTimeModel(cfg, HW_BY_NAME[hw], n_chips=n_chips,
                            quant=quant)
        ex = SimExecutor(cfg, stm)
        ecfg = EngineConfig(max_batch=max_batch, page_size=16,
                            num_pages=65536, max_pages_per_seq=64)
    return Engine(ecfg, ex)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama31-8b")
    ap.add_argument("--tier", default="sim", choices=["real", "sim"])
    ap.add_argument("--hw", default="tpu-v5e")
    ap.add_argument("--quant", default="bf16")
    ap.add_argument("--chips", type=int, default=1)
    ap.add_argument("--lam", type=float, default=5.0)
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--io-shape", default="chat")
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--compare-tier", default=None)
    ap.add_argument("--accept-slo-mismatch", action="store_true")
    args = ap.parse_args(argv)

    if args.tier == "real" and args.scale == 1.0:
        args.scale = 0.05       # CPU tier shrinks token lengths

    eng = build_engine(args.arch, args.tier, args.hw, args.quant,
                       args.chips, seed=args.seed)
    price = chip_hour_price(args.hw, args.chips) if args.tier == "sim" \
        else 1.0
    meter = CostMeter(price, scrape=lambda: eng.metrics.render())

    spec = ArrivalSpec(lam=args.lam, n_requests=args.requests,
                       io_shape=args.io_shape, scale=args.scale,
                       seed=args.seed)
    reqs = synth_requests(spec)

    # drive the engine in slices so the meter ticks mid-run
    horizon = 0.0
    meter.tick()
    while any(r.finish_time is None and r.retries <= 2 for r in reqs):
        horizon += 10.0
        eng.run(reqs, horizon=horizon)
        s = meter.tick()
        if s:
            print(f"[meter t={s.t:8.1f}s] tps={s.tps:9.1f} "
                  f"inflight={s.inflight:5.0f} $/MTok={s.c_eff:10.4f}")
        if horizon > 24 * 3600:
            break

    summ = meter.summary()
    print(f"\nmeter summary: best-minute=${summ['best_minute']:.4f} "
          f"worst-minute=${summ['worst_minute']:.4f} "
          f"avg=${summ['time_weighted_avg']:.4f}")
    done = [r for r in reqs if r.finish_time is not None]
    if done:
        print(f"completed {len(done)}/{len(reqs)}  "
              f"TTFT p50={1e3*float(np.median([r.ttft for r in done])):.1f}ms")
    if args.compare_tier:
        print(meter.compare_api(
            args.compare_tier,
            accept_slo_mismatch=args.accept_slo_mismatch))


if __name__ == "__main__":
    main()
