"""Three-path identity check for admission/overload cells (ISSUE 9).

Runs the same (engine, arrivals) points through the per-token reference
loop, the event-driven fast path, and the fleet backend. The contract
(unchanged since PR 1/PR 4): every scheduling decision — and therefore
every integer field (completions, sheds, timeouts, class sheds,
brownouts, SLO violations) — is bit-identical across all three paths;
float fields are bit-identical between the fast path and the fleet
(the committed-store surface) and agree with the per-token reference
loop to float-rounding tolerance (the closed-form clock jump sums the
same step durations in a different association order). Exercised by
CI's overload-smoke job; handy standalone while hacking on the
scheduler."""
import dataclasses
import math
import sys

sys.path.insert(0, "src")

from repro.core.records import FIELDS
from repro.core.sweep import SimEngineSpec, run_point
from repro.serving.arrivals import ArrivalSpec
from repro.serving.fleet import FleetPoint, fleet_run_points
from repro.serving.overload import OverloadPolicy


def main():
    pol = OverloadPolicy(brownout_depth=6, shed_depth=12, recover_depth=2,
                         ttft_slo_s=0.6, brownout_max_new=24).validate()
    mon = OverloadPolicy(ttft_slo_s=0.6)
    base = dict(arch="llama31-8b", max_batch=8, num_pages=4096,
                max_pages_per_seq=64)
    cases = [
        ("mqd", SimEngineSpec(max_queue_depth=10, **base),
         ArrivalSpec(lam=6.0, n_requests=160, seed=3)),
        ("ddl", SimEngineSpec(deadline_s=1.2, **base),
         ArrivalSpec(lam=6.0, n_requests=160, seed=4)),
        ("mqd+ddl", SimEngineSpec(max_queue_depth=8, deadline_s=1.0, **base),
         ArrivalSpec(lam=7.0, n_requests=160, seed=5)),
        ("overload", SimEngineSpec(overload=pol, **base),
         ArrivalSpec(lam=7.0, n_requests=200, seed=6,
                     class_mix=(0.6, 0.3, 0.1))),
        ("overload+mqd+ddl",
         SimEngineSpec(overload=pol, max_queue_depth=40, deadline_s=2.0,
                       **base),
         ArrivalSpec(lam=8.0, n_requests=200, seed=7,
                     class_mix=(0.5, 0.3, 0.2))),
        ("monitor", SimEngineSpec(overload=mon, **base),
         ArrivalSpec(lam=6.0, n_requests=120, seed=8,
                     class_mix=(0.6, 0.3, 0.1))),
    ]
    failures = 0
    for name, spec, arr in cases:
        ref_spec = dataclasses.replace(spec, fast_forward=False)
        ref = run_point(ref_spec, arr, warmup=20, config=name)
        fast = run_point(spec, arr, warmup=20, config=name)
        fleet = fleet_run_points(
            [FleetPoint(engine=spec, arrivals=arr, warmup=20,
                        config=name)])[0]
        for fld in FIELDS:
            a, b, c = (getattr(ref, fld), getattr(fast, fld),
                       getattr(fleet, fld))
            ok = repr(b) == repr(c)     # fast <-> fleet: bitwise, always
            if isinstance(b, float) and not isinstance(b, bool):
                ok &= (a == b or (math.isnan(a) and math.isnan(b))
                       or abs(a - b) <= 1e-9 * max(abs(a), abs(b), 1.0))
            else:
                ok &= repr(a) == repr(b)   # decisions: bitwise everywhere
            if not ok:
                print(f"FAIL {name}.{fld}: ref={a!r} fast={b!r} "
                      f"fleet={c!r}")
                failures += 1
        shed = fleet.n_shed + fleet.n_timeout
        print(f"ok {name}: completed={fleet.n_completed} "
              f"shed+timeout={shed} class_shed={fleet.n_class_shed} "
              f"browned={fleet.n_browned} slo_viol={fleet.n_slo_viol}")
    if failures:
        print(f"{failures} field mismatches")
        sys.exit(1)
    print("all paths bit-identical")


if __name__ == "__main__":
    main()
