"""Portfolio-plan report (ISSUE 10): from the committed penalty atlas
to a multi-model fleet verdict in one command.

One model at one rate is not what an operator runs. The blended
workload here mixes three request classes — reasoning (flagship-only),
chat (mid-tier eligible), autocomplete (any tier) — and prices the same
blend three ways off the committed `paper_atlas` curves:

  silo           one dedicated fleet per class, all on the flagship
  flagship_pool  every class pooled onto the flagship
  routed_pool    a token-budget router picks each class's cheapest
                 eligible tier, survivors pool per model

Every greedy allocation in every arm is certified by the exact
branch-and-bound allocator; the optimality gap is printed per pool and
a beaten greedy is flagged loudly, never hidden.

    PYTHONPATH=src python examples/portfolio_report.py

Reads the committed store (running any missing cells through the fleet
backend first); no engines are re-run on a populated checkout.
"""
from repro.experiments import ExperimentStore, PlanRunner, get_plan
from repro.planner import (BLENDED_3CLASS, PORTFOLIO_LAMS,
                           certification_rows, fit_curves, plan_portfolio,
                           render_certification, render_portfolio)


def main():
    plan = get_plan("paper_atlas")
    store = ExperimentStore(plan.name)
    cached = len(store.completed_ids(plan))
    print(f"paper_atlas: {cached}/{len(plan.cells)} cells in store "
          f"({store.dir})")
    records = PlanRunner(plan, store=store).run(backend="vector")
    curves = fit_curves(records)

    print("\n=== is greedy_mix leaving money on the table? ===")
    print(render_certification(certification_rows(curves)))
    print("\nThe exact allocator explores the same decision space "
          "(measured footprints x\nreplica counts) by branch-and-bound; "
          "a zero gap is a certificate, not an\nassumption. Any loss "
          "would print as '!! greedy BEATEN'.")

    print("\n=== the 3-class blend: silo vs consolidated vs routed ===")
    for lam in PORTFOLIO_LAMS:
        print()
        print(render_portfolio(
            plan_portfolio(curves, BLENDED_3CLASS.scaled(lam),
                           chip_budget=8)))

    print("\nTwo honest surprises on this store: consolidation is the "
          "big win (one\npooled flagship fleet, ~67% off the silo "
          "bill), while routing classes to\ncheaper tiers LOSES money "
          "at every reference rate — fragmenting the pool\nacross "
          "three models re-introduces the underutilization penalty "
          "that\nconsolidation just removed. Routing only wins on "
          "$/M-token at saturation,\nwhere every fragment is busy. "
          "The router also refuses, never prices, a\nclass whose "
          "token budget exceeds a tier's measured decode length "
          "(paper\n§6.4 discipline).")


if __name__ == "__main__":
    main()
