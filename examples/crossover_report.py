"""Corrected crossover analysis (paper §5.6 + §6.3): where does self-
hosting actually beat each API tier once utilization is measured rather
than assumed — and how asymmetric input/output pricing moves the answer
for different workload shapes.

Consumes the `crossover_trio` experiment store (three (model, quant, TP)
configs on tpu-v5p); cells missing from the store are run once and
persisted, so re-invocations analyze without re-running engines.

    PYTHONPATH=src python examples/crossover_report.py
"""
from repro.core import c_naive, crossover_table
from repro.core.pricing import API_TIERS
from repro.experiments import ExperimentStore, PlanRunner, get_plan
from repro.simulate import V5P


def main():
    plan = get_plan("crossover_trio")
    store = ExperimentStore(plan.name)
    cached = len(store.completed_ids(plan))
    print(f"crossover_trio: {cached}/{len(plan.cells)} cells in store "
          f"({store.dir})")
    records = PlanRunner(plan, store=store).run()
    by_group = {}
    for r in records:
        by_group.setdefault((r.model, r.quant, r.n_chips), []).append(r)

    for (arch, quant, chips), recs in by_group.items():
        price = recs[0].price_per_hr
        naive = c_naive(price, max(r.tps for r in recs))

        print(f"\n=== {arch} {quant} x{chips} on {V5P.name} "
              f"(${price:.2f}/hr) ===")
        print(f"  naive token-volume cost (assumes theta_max): "
              f"${naive:.3f}/MTok")
        print(f"  measured C_eff: ${recs[0].c_eff:.3f} at lam=1  ...  "
              f"${min(r.c_eff for r in recs):.3f} at saturation")
        for row in crossover_table(recs, accept_slo_mismatch=True):
            lam = row["lambda_star"]
            tag = ("always cheaper (<= lowest measured lam)"
                   if row["extrapolated"] else f"lam* = {lam:.2f} rps")
            print(f"    vs {row['tier']:<18} "
                  f"(${row['api_output_per_mtok']:>5.2f}/MTok out): {tag}")

    print("\n--- asymmetric API pricing by workload shape (paper §6.3) ---")
    print(f"{'tier':<18} {'chat 512:256':>13} {'RAG 4096:1024':>14} "
          f"{'codegen 100:500':>16}")
    for name, tier in API_TIERS.items():
        print(f"{name:<18} "
              f"{tier.blended(512, 256):>12.2f}$ "
              f"{tier.blended(4096, 1024):>13.2f}$ "
              f"{tier.blended(100, 500):>15.2f}$")
    print("self-hosting bills input and output tokens at the same "
          "GPU-time rate;\ngeneration-heavy shapes amplify its advantage.")


if __name__ == "__main__":
    main()
