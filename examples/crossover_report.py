"""Corrected crossover analysis (paper §5.6 + §6.3): where does self-
hosting actually beat each API tier once utilization is measured rather
than assumed — and how asymmetric input/output pricing moves the answer
for different workload shapes.

    PYTHONPATH=src python examples/crossover_report.py
"""
from repro.configs import get_config
from repro.core import c_naive, crossover_table, lambda_sweep
from repro.core.pricing import API_TIERS
from repro.serving import Engine, EngineConfig, SimExecutor
from repro.simulate import StepTimeModel, V5P

CONFIGS = (("llama31-8b", "bf16", 1), ("qwen3-30b-a3b", "int8", 1),
           ("mixtral-8x7b", "bf16", 2))


def main():
    for arch, quant, chips in CONFIGS:
        cfg = get_config(arch)
        price = V5P.price_per_chip_hr * chips

        def factory():
            stm = StepTimeModel(cfg, V5P, n_chips=chips, quant=quant)
            return Engine(
                EngineConfig(max_batch=256, page_size=16, num_pages=65536,
                             max_pages_per_seq=64), SimExecutor(cfg, stm))

        recs = lambda_sweep(
            factory, ladder=(1, 2, 5, 10, 25, 50, 100),
            requests_per_point=lambda lam: int(min(600, max(120, 20 * lam))),
            warmup_per_point=lambda lam: 0, config=arch, model=arch,
            hw=V5P.name, price_per_hr=price, engine_kind="sim")
        naive = c_naive(price, max(r.tps for r in recs))

        print(f"\n=== {arch} {quant} x{chips} on {V5P.name} "
              f"(${price:.2f}/hr) ===")
        print(f"  naive token-volume cost (assumes theta_max): "
              f"${naive:.3f}/MTok")
        print(f"  measured C_eff: ${recs[0].c_eff:.3f} at lam=1  ...  "
              f"${min(r.c_eff for r in recs):.3f} at saturation")
        for row in crossover_table(recs, accept_slo_mismatch=True):
            lam = row["lambda_star"]
            tag = ("always cheaper (<= lowest measured lam)"
                   if row["extrapolated"] else f"lam* = {lam:.2f} rps")
            print(f"    vs {row['tier']:<18} "
                  f"(${row['api_output_per_mtok']:>5.2f}/MTok out): {tag}")

    print("\n--- asymmetric API pricing by workload shape (paper §6.3) ---")
    print(f"{'tier':<18} {'chat 512:256':>13} {'RAG 4096:1024':>14} "
          f"{'codegen 100:500':>16}")
    for name, tier in API_TIERS.items():
        print(f"{name:<18} "
              f"{tier.blended(512, 256):>12.2f}$ "
              f"{tier.blended(4096, 1024):>13.2f}$ "
              f"{tier.blended(100, 500):>15.2f}$")
    print("self-hosting bills input and output tokens at the same "
          "GPU-time rate;\ngeneration-heavy shapes amplify its advantage.")


if __name__ == "__main__":
    main()
