"""Quickstart: the concurrency-aware cost framework in ~60 seconds.

Runs the `quickstart` experiment plan (the paper's dense reference config
on the simulated v5e tier) against the resumable store — a second
invocation reads the finished cells instead of re-running engines — then
prints the C_eff(lambda) curve, the underutilization penalty (the paper's
headline 1/U factor), and the API crossover table.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import crossover_table, slo_operating_point
from repro.experiments import ExperimentStore, PlanRunner, get_plan
from repro.simulate import V5E


def main():
    plan = get_plan("quickstart")
    store = ExperimentStore(plan.name)
    cached = len(store.completed_ids(plan))
    print(f"sweeping {plan.cells[0].arch} on {V5E.name} "
          f"(${V5E.price_per_chip_hr}/chip-hr) — "
          f"{cached}/{len(plan.cells)} cells already in {store.dir}")
    recs = PlanRunner(plan, store=store).run()

    print(f"\n{'lam':>5} {'tok/s':>9} {'$ / MTok':>9} {'penalty':>8} "
          f"{'TTFT p99':>10} {'in-flight':>9}")
    for r in recs:
        print(f"{r.lam:>5g} {r.tps:>9.0f} {r.c_eff:>9.3f} "
              f"{r.penalty:>7.1f}x {r.ttft_p99_ms:>8.0f}ms "
              f"{r.mean_inflight:>9.1f}")

    print("\nutilization is an OUTPUT: the idle-edge penalty above is the "
          "factor every\nfixed-utilization calculator is wrong by "
          "(paper: 2.5-24x at 1-10 rps).")

    print("\nAPI crossover (list output-token prices, no SLA attached):")
    for row in crossover_table(recs, accept_slo_mismatch=True):
        lam = row["lambda_star"]
        note = " (extrapolated)" if row["extrapolated"] else ""
        print(f"  {row['tier']:<18} ${row['api_output_per_mtok']:>5.2f}/MTok"
              f"  crossover at lam*={lam:.2f}{note}")

    slo = slo_operating_point(recs, ttft_p99_ms=300.0, tpot_p99_ms=50.0)
    print(f"\nSLA (TTFT p99<=300ms, TPOT p99<=50ms): feasible up to "
          f"lam={slo.lam_max}, ${slo.c_at_sla:.3f}/MTok "
          f"= {slo.premium:.2f}x the (SLA-infeasible: "
          f"{not slo.sat_feasible}) saturation floor ${slo.c_sat:.3f}")

    print(f"\nfull paper matrices: python -m repro.experiments.run "
          f"--plan paper_a100 --resume")


if __name__ == "__main__":
    main()
