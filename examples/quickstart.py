"""Quickstart: the concurrency-aware cost framework in ~60 seconds.

Runs a lambda sweep of the paper's dense reference config on the simulated
v5e tier, prints the C_eff(lambda) curve, the underutilization penalty
(the paper's headline 1/U factor), and the API crossover table.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs import get_config
from repro.core import (crossover_table, lambda_sweep, slo_operating_point)
from repro.serving import Engine, EngineConfig, SimExecutor
from repro.simulate import StepTimeModel, V5E

ARCH = "llama31-8b"


def main():
    cfg = get_config(ARCH)

    def factory():
        stm = StepTimeModel(cfg, V5E, n_chips=1, quant="bf16")
        return Engine(EngineConfig(max_batch=256, page_size=16,
                                   num_pages=65536, max_pages_per_seq=64),
                      SimExecutor(cfg, stm))

    print(f"sweeping {ARCH} on {V5E.name} (${V5E.price_per_chip_hr}/chip-hr)")
    recs = lambda_sweep(
        factory, ladder=(1, 5, 10, 25, 50, 100),
        requests_per_point=lambda lam: int(min(600, max(120, 20 * lam))),
        warmup_per_point=lambda lam: 0,
        config="quickstart", model=ARCH, hw=V5E.name,
        price_per_hr=V5E.price_per_chip_hr, engine_kind="sim")

    print(f"\n{'lam':>5} {'tok/s':>9} {'$ / MTok':>9} {'penalty':>8} "
          f"{'TTFT p99':>10} {'in-flight':>9}")
    for r in recs:
        print(f"{r.lam:>5g} {r.tps:>9.0f} {r.c_eff:>9.3f} "
              f"{r.penalty:>7.1f}x {r.ttft_p99_ms:>8.0f}ms "
              f"{r.mean_inflight:>9.1f}")

    print("\nutilization is an OUTPUT: the idle-edge penalty above is the "
          "factor every\nfixed-utilization calculator is wrong by "
          "(paper: 2.5-24x at 1-10 rps).")

    print("\nAPI crossover (list output-token prices, no SLA attached):")
    for row in crossover_table(recs, accept_slo_mismatch=True):
        lam = row["lambda_star"]
        note = " (extrapolated)" if row["extrapolated"] else ""
        print(f"  {row['tier']:<18} ${row['api_output_per_mtok']:>5.2f}/MTok"
              f"  crossover at lam*={lam:.2f}{note}")

    slo = slo_operating_point(recs, ttft_p99_ms=300.0, tpot_p99_ms=50.0)
    print(f"\nSLA (TTFT p99<=300ms, TPOT p99<=50ms): feasible up to "
          f"lam={slo.lam_max}, ${slo.c_at_sla:.3f}/MTok "
          f"= {slo.premium:.2f}x the (SLA-infeasible: "
          f"{not slo.sat_feasible}) saturation floor ${slo.c_sat:.3f}")


if __name__ == "__main__":
    main()
