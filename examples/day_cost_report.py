"""Pricing a day of traffic (ISSUE 8): static vs autoscaled footprints.

Three views of the same question — "what does my 24h lambda(t) profile
actually cost?":

1. The committed `paper_diurnal` store's exact day table: every
   per-replica rate any trajectory visits is a MEASURED stationary cell,
   so the static-vs-autoscaled verdict needs no interpolation (and it
   FLIPS between the two committed footprints).
2. The planner's interpolated counterpart from any stationary store
   (`python -m repro.planner --plan paper_atlas --day paper_day`).
3. A live CostMeter walkthrough: one engine driven through a lambda(t)
   stream with a dead-of-night trough. The fleet-level day table prices
   the trough as an explicit infinite-cost idle window; on a single
   fast-forwarding engine the clock leaps the empty span, so the same
   billed-but-idle seconds surface as a cost SPIKE in the window where
   traffic reopens — two renderings of one fact: idle time is money.

    PYTHONPATH=src python examples/day_cost_report.py
"""
from repro.configs import get_config
from repro.experiments.analyze import (diurnal_tables, load_store_records,
                                       render_diurnal)
from repro.serving import (Engine, EngineConfig, RateProfile, SimExecutor,
                           meter_day_report)
from repro.simulate import V5E, StepTimeModel


def committed_day_table():
    print("=== 1. exact day table from the committed paper_diurnal store "
          "===")
    try:
        records = load_store_records("paper_diurnal")
    except OSError:
        records = []
    if not records:
        print("store absent — run: PYTHONPATH=src python -m "
              "repro.experiments.run --plan paper_diurnal --backend vector")
        return
    print(render_diurnal(diurnal_tables(records)))


def live_meter_walkthrough():
    print("\n=== 2. live meter through a trough-heavy lambda(t) stream ===")
    prof = RateProfile.piecewise([(30.0, 4.0), (120.0, 0.0), (30.0, 4.0)])
    cfg = get_config("llama31-8b")
    eng = Engine(EngineConfig(max_batch=64, page_size=16, num_pages=8192,
                              max_pages_per_seq=64),
                 SimExecutor(cfg, StepTimeModel(cfg, V5E)))
    rep = meter_day_report(eng, price_per_hr=1.2, profile=prof,
                          n_requests=240, seed=0, window_s=30.0)
    summ = rep["summary"]
    print(f"completed {rep['completed']}/{rep['requests']} requests over "
          f"{summ['minutes']:.0f} meter windows")
    worst = max(rep["window_costs"])
    for i, c in enumerate(rep["window_costs"]):
        tag = ""
        if c == float("inf"):
            tag = "  <- idle: billed, zero goodput"
        elif c == worst and worst > 2 * min(rep["window_costs"]):
            tag = "  <- the trough's billed-idle seconds land here"
        print(f"  window {i}: $/MTok = {c:10.4f}{tag}")
    swing = "n/a (idle window)" if summ["swing"] is None \
        else f"{summ['swing']:.1f}x"
    print(f"best ${summ['best_minute']:.4f}  worst ${summ['worst_minute']:.4f} "
          f"(idle windows: {summ['idle_minutes']:.0f})  swing {swing}  "
          f"avg ${summ['time_weighted_avg']:.4f}")
    print("\nan idle trough is a COST, not a gap in the data — the day "
          "table prices it as an explicit inf window; the live meter "
          "bills those seconds into the reopening window (paper §6.6, "
          "time-resolved).")


def main():
    committed_day_table()
    live_meter_walkthrough()


if __name__ == "__main__":
    main()
