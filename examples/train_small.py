"""Train a ~100M-parameter llama-family model for a few hundred steps on
the local device, with checkpoints + restart-and-continue.

    PYTHONPATH=src python examples/train_small.py --steps 300
    # kill it mid-run, re-run the same command: it resumes.

(~100M: d_model=640, 10 layers, ff=2560, vocab=16384.)
"""
import argparse

from repro.configs import reduced, get_config
from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = reduced("llama31-8b", layers=10, d_model=640, ff=2560, vocab=16384)
    print(f"config: {cfg.num_layers}L d={cfg.d_model} ff={cfg.d_ff} "
          f"vocab={cfg.vocab_size} -> {cfg.param_count()/1e6:.1f}M params")

    train_main([
        "--arch", "llama31-8b", "--reduced",
        "--layers", "10", "--d-model", "640", "--ff", "2560",
        "--vocab", "16384",
        "--steps", str(args.steps), "--batch", str(args.batch),
        "--seq", str(args.seq), "--lr", "1e-3", "--opt", "adamw8bit",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "50",
        "--log-every", "10",
    ])


if __name__ == "__main__":
    main()
