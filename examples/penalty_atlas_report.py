"""Dense penalty-atlas report (ISSUE 4).

The paper's core claim is a *curve* — C_eff spans 2.5-36x driven by
offered load — but the 7-point ladder only samples it. The `paper_atlas`
plan densifies the load axis to a 25-point log-spaced continuum across
three hardware generations (450 cells), cheap to (re)produce because the
fleet backend simulates a whole lane chunk per Python event loop:

    PYTHONPATH=src python -m repro.experiments.run --plan paper_atlas \\
        --backend vector --resume
    PYTHONPATH=src python examples/penalty_atlas_report.py

This example reads the committed store (running any missing cells
through the fleet backend first) and prints, per (model, hardware,
quant), the dense penalty curve as sparkline-style buckets plus the
knee/half-cost loads the sparse ladders can only bracket.
"""
from repro.experiments import ExperimentStore, PlanRunner, get_plan
from repro.experiments.analyze import penalty_atlas

BARS = " .:-=+*#%@"


def _spark(vals, lo=1.0, hi=50.0):
    """Log-bucketed penalty sparkline: '@' is idle-edge pain, ' ' is the
    saturation floor."""
    import math
    out = []
    for v in vals:
        f = (math.log(max(v, lo)) - math.log(lo)) / \
            (math.log(hi) - math.log(lo))
        out.append(BARS[min(int(f * (len(BARS) - 1) + 0.5),
                            len(BARS) - 1)])
    return "".join(out)


def main():
    plan = get_plan("paper_atlas")
    store = ExperimentStore(plan.name)
    cached = len(store.completed_ids(plan))
    print(f"paper_atlas: {cached}/{len(plan.cells)} cells in store "
          f"({store.dir})")
    records = PlanRunner(plan, store=store).run(backend="vector")

    atlas = penalty_atlas(records)
    lams = atlas[0]["lams"]
    print(f"\n--- dense penalty curves: lambda continuum "
          f"{lams[0]:g}..{lams[-1]:g} req/s, {len(lams)} points "
          f"(idle '@' -> saturated ' ') ---\n")
    print(f"{'model':<24} {'hw':<9} {'quant':<5} curve"
          f"{'':<{max(len(lams) - 5, 1)}} {'knee':>7} {'half':>7} "
          f"{'spread':>7}")
    for row in atlas:
        print(f"{row['model']:<24} {row['hw']:<9} {row['quant']:<5} "
              f"[{_spark(row['penalty'])}] {row['knee_lambda']:>7.4g} "
              f"{row['half_cost_lambda']:>7.4g} {row['spread']:>6.1f}x")

    print("\n--- where 'substantial sustained load' begins (knee = first "
          "lambda within 25% of the cost floor) ---")
    by_hw = {}
    for row in atlas:
        by_hw.setdefault(row["hw"], []).append(row)
    for hw, rows in sorted(by_hw.items()):
        knees = [r["knee_lambda"] for r in rows]
        print(f"  {hw:<9} knees span {min(knees):g}..{max(knees):g} req/s "
              f"across {len(rows)} (model, quant) curves")
    print("\nBelow the knee the per-token price is dominated by idle "
          "hardware, not by the model — the paper's §7 warning, now "
          "locatable to a specific offered rate per deployment.")


if __name__ == "__main__":
    main()
