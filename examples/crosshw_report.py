"""Cross-hardware replication report (paper §5.9 / §7, ISSUE 3).

The paper's strongest claim-robustness argument: the load-driven C_eff
spread reproduces across hardware generations with compressed magnitude
on the cheaper part (2.5-36.3x on the H100 analogue, 7.0-11.4x on the
A100 analogue), which rules out single-hardware confounding. This
example derives the spread-compression table, the native-fp8-conditioned
FP8-inversion table and the active-params ordering survival from the
committed `paper_crosshw` store; cells missing from the store are run
once and persisted.

    PYTHONPATH=src python examples/crosshw_report.py
"""
from repro.experiments import ExperimentStore, PlanRunner, get_plan
from repro.experiments.analyze import (crosshw_ordering, fp8_inversion,
                                       spread_compression)


def main():
    plan = get_plan("paper_crosshw")
    store = ExperimentStore(plan.name)
    cached = len(store.completed_ids(plan))
    print(f"paper_crosshw: {cached}/{len(plan.cells)} cells in store "
          f"({store.dir})")
    records = PlanRunner(plan, store=store).run()

    print("\n--- spread compression: same models, three hardware "
          "generations, one store ---")
    for row in spread_compression(records):
        print(f"\n{row['model']} [{row['quant']}]")
        for h in row["per_hw"]:
            print(f"  {h['hw']:<9} x{h['n_chips']}: "
                  f"C_eff ${h['c_min']:.3f} .. ${h['c_max']:.3f} "
                  f"-> spread {h['spread']:.1f}x")
        print(f"  compression {row['compression']:.2f}x "
              f"(widest on {row['widest_hw']}, narrowest on "
              f"{row['narrowest_hw']})")

    print("\n--- fp8 uplift, conditioned on native-fp8 hardware ---")
    for r in fp8_inversion(records):
        native = "native " if r["native_fp8"] else "emulated"
        tag = "INVERTED" if r["inverted"] else "gain"
        flag = "" if r["consistent"] else "  !! breaks the hw-conditional story"
        print(f"  {r['hw']:<9} [{native}] {r['model']:<24} "
              f"{r['tps_uplift']:.2f}x TPS, {r['cost_ratio']:.2f}x cost "
              f"-> {tag}{flag}")

    print("\n--- active-params saturation ordering across hardware ---")
    for row in crosshw_ordering(records):
        tag = ("survives on every generation" if row["survives_all_hw"]
               else f"holds on {', '.join(row['holds_on']) or 'none'}")
        print(f"  [{row['quant']}] {tag} ({', '.join(row['hws'])})")


if __name__ == "__main__":
    main()
