"""End-to-end serving driver (the paper-kind example): a small model served
with batched requests through the REAL JAX engine + paged KV cache, with
the live cost meter scraping Prometheus text as traffic ramps.

Phase schedule mirrors the paper's §6.7 six-phase live validation, scaled
to CPU throughput. Then the same six phases run on the simulated-v5p full
model for the paper-scale numbers.

    PYTHONPATH=src python examples/serve_cost_meter.py [--skip-real]
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core import CostMeter
from repro.models import init_params
from repro.serving import (ArrivalSpec, Engine, EngineConfig, RealExecutor,
                           SimExecutor, synth_requests)
from repro.simulate import StepTimeModel, V5P


def six_phase(eng, price, phases, phase_s, scale, label):
    meter = CostMeter(price, scrape=lambda: eng.metrics.render(),
                      minute_s=60.0)
    reqs, t0 = [], 0.0
    for i, lam in enumerate(phases):
        n = max(1, int(lam * phase_s))
        batch = synth_requests(ArrivalSpec(lam=lam, n_requests=n,
                                           seed=10 + i, scale=scale),
                               start=t0)
        t0 = max(r.arrival_time for r in batch)
        reqs += batch
    meter.tick()
    horizon = 0.0
    while any(r.finish_time is None for r in reqs):
        horizon += phase_s / 4
        eng.run(reqs, horizon=horizon)
        s = meter.tick()
        if s:
            print(f"  [{label} t={s.t:7.1f}s] tok/s={s.tps:9.1f} "
                  f"in-flight={s.inflight:4.0f}  $/MTok={s.c_eff:9.4f}")
        if horizon > 48 * 3600:
            break
    summ = meter.summary()
    done = [r for r in reqs if r.finish_time is not None]
    swing = "n/a (idle window)" if summ["swing"] is None \
        else f"{summ['swing']:.1f}x"
    print(f"  {label}: {len(done)}/{len(reqs)} ok | best-minute "
          f"${summ['best_minute']:.4f} worst ${summ['worst_minute']:.4f} "
          f"swing {swing} avg ${summ['time_weighted_avg']:.4f}")
    return summ


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-real", action="store_true")
    args = ap.parse_args()

    if not args.skip_real:
        print("=== REAL tier: reduced llama on local device, wall clock ===")
        cfg = reduced("llama31-8b")
        params = init_params(jax.random.PRNGKey(0), cfg)
        ex = RealExecutor(cfg, params, num_pages=512, page_size=16,
                          max_batch=8)
        eng = Engine(EngineConfig(max_batch=8, page_size=16, num_pages=512,
                                  max_pages_per_seq=32), ex)
        six_phase(eng, price=1.0, phases=(0.5, 1, 2, 4, 2, 0.5),
                  phase_s=20.0, scale=0.05, label="real")

    print("\n=== SIM tier: full llama31-8b on tpu-v5p model clock ===")
    cfg = get_config("llama31-8b")
    stm = StepTimeModel(cfg, V5P)
    eng = Engine(EngineConfig(max_batch=256, page_size=16, num_pages=65536,
                              max_pages_per_seq=64), SimExecutor(cfg, stm))
    six_phase(eng, price=V5P.price_per_chip_hr,
              phases=(1, 5, 15, 50, 15, 1), phase_s=120.0, scale=1.0,
              label="sim")
    print("\nany cost number quoted without a lambda attached is "
          "meaningless (paper §6.7).")


if __name__ == "__main__":
    main()
