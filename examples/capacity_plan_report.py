"""Capacity-plan report (ISSUE 5): from the committed penalty atlas to a
deployment decision in one command.

The paper's point is that the offered rate lambda — not a utilization
preset — drives the self-host decision. The committed `paper_atlas`
store holds the dense C_eff(lambda) continuum for every (model,
hardware, quant) footprint; `repro.planner` inverts it: what should an
operator with THIS lambda and THIS latency SLO actually deploy, and at
what $/M output tokens?

    PYTHONPATH=src python examples/capacity_plan_report.py

Reads the committed store (running any missing cells through the fleet
backend first); no engines are re-run on a populated checkout.
"""
from repro.core.slo import SLOTarget
from repro.experiments import ExperimentStore, PlanRunner, get_plan
from repro.planner import fit_curves, plan_capacity, render_plans


def main():
    plan = get_plan("paper_atlas")
    store = ExperimentStore(plan.name)
    cached = len(store.completed_ids(plan))
    print(f"paper_atlas: {cached}/{len(plan.cells)} cells in store "
          f"({store.dir})")
    records = PlanRunner(plan, store=store).run(backend="vector")
    curves = fit_curves(records)

    print("\n=== the operator's question: lambda drives the decision ===")
    for lam in (1.0, 10.0, 200.0):
        plans = plan_capacity(curves, lam)
        print()
        print(f"--- offered rate {lam:g} req/s ---")
        for p in plans:
            b = p.best
            dep = f"{b.hw}/{b.quant} x{b.n_chips}" + \
                (f" R={b.replicas}" if b.replicas > 1 else "")
            print(f"  {p.model:<24} -> {dep:<22} "
                  f"${b.c_eff:>7.3f}/M-tok  util {b.util:.2f}  "
                  f"penalty {b.penalty:.1f}x")
    print("\nNote the inversion: at idle the cheap generation wins "
          "($/hr dominates), at\nsaturation the native-fp8 part wins "
          "(tokens/s dominates) — a single\n'best hardware' answer "
          "does not exist without lambda.")

    print("\n=== an SLO turns splits from waste into the price of "
          "latency ===")
    slo = SLOTarget(ttft_p90_ms=2000.0)
    print(render_plans(plan_capacity(fit_curves(records,
                                                model="llama31-8b"),
                                     200.0, slo),
                       title="llama31-8b @ 200 rps, TTFT p90 <= 2s"))

    print("\n=== and some loads must be refused, not priced ===")
    tight = SLOTarget(ttft_p90_ms=5.0)
    plans = plan_capacity(fit_curves(records, model="llama31-8b"),
                          200.0, tight)
    print(render_plans(plans, title="llama31-8b @ 200 rps, TTFT p90 <= "
                                    "5ms (infeasible)"))


if __name__ == "__main__":
    main()
