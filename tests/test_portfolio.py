"""Portfolio planner (ISSUE 10 tentpole): workload spec, token-budget
routing, the exact branch-and-bound allocator certifying greedy_mix,
and the silo-vs-consolidated-vs-routed verdict — unit tests on
synthetic curves plus golden tests pinned to the committed
`paper_atlas` store (no engines run)."""
import json
import math

import pytest

from repro.core import c_eff as _c_eff
from repro.core.records import RunRecord
from repro.core.slo import SLOTarget
from repro.experiments.analyze import load_store_records
from repro.planner import (BLENDED_3CLASS, GAP_RTOL, WORKLOADS, Workload,
                           WorkloadClass, certification_rows, certify,
                           exact_mix, fit_curves, greedy_mix,
                           plan_portfolio, portfolio_row, render_portfolio,
                           route_workload)


def _rec(lam, tps, price=1.2, theta_max=1000.0, ttft_p90=100.0, **kw):
    base = dict(config="t", model="m", hw="hw-a", n_chips=1, quant="bf16",
                engine="sim", io_shape="chat", n_requests=10, n_completed=10,
                window_s=10.0, prompt_tps=0.0, ttft_p50_ms=ttft_p90 / 2,
                ttft_p90_ms=ttft_p90, ttft_p99_ms=ttft_p90 * 2,
                tpot_p50_ms=10.0, tpot_p99_ms=20.0, e2e_p50_ms=1000.0,
                e2e_p99_ms=2000.0, mean_inflight=lam, price_per_hr=price,
                c_eff=_c_eff(price, tps), theta_max=theta_max)
    base.update(kw)
    return RunRecord(lam=lam, tps=tps, **base)


def _ladder(hw="hw-a", price=1.2, theta=1000.0, lams=(1, 5, 10, 50, 100),
            halfsat=10.0, ttft_slope=20.0, **kw):
    out = []
    for lam in lams:
        tps = theta * lam / (lam + halfsat)
        out.append(_rec(lam, tps, price=price, theta_max=theta, hw=hw,
                        ttft_p90=ttft_slope * (1 + lam), **kw))
    return out


def _atlas_records():
    recs = load_store_records("paper_atlas")
    if len(recs) < 450:
        pytest.skip("paper_atlas store not populated")
    return recs


# ---- workload spec ----------------------------------------------------


def test_workload_class_validation():
    with pytest.raises(ValueError, match="lam"):
        WorkloadClass(name="c", lam=0.0, tiers=("m",))
    with pytest.raises(ValueError, match="lam"):
        WorkloadClass(name="c", lam=float("inf"), tiers=("m",))
    with pytest.raises(ValueError, match="tier"):
        WorkloadClass(name="c", lam=1.0, tiers=())
    with pytest.raises(ValueError, match="duplicate"):
        WorkloadClass(name="c", lam=1.0, tiers=("m", "m"))
    with pytest.raises(ValueError, match="io_shape"):
        WorkloadClass(name="c", lam=1.0, tiers=("m",), io_shape="weird")
    with pytest.raises(ValueError, match="budget_tokens"):
        WorkloadClass(name="c", lam=1.0, tiers=("m",), budget_tokens=-1)


def test_workload_class_budget_defaults_to_measured_decode():
    # chat decodes 256 tokens in serving.arrivals.IO_SHAPES
    c = WorkloadClass(name="c", lam=1.0, tiers=("m",))
    assert c.budget_tokens == 256
    assert c.flagship == "m"
    # explicit io_shape with explicit budget is accepted as-is
    c2 = WorkloadClass(name="c", lam=1.0, tiers=("m",), io_shape="weird",
                       budget_tokens=64)
    assert c2.budget_tokens == 64


def test_workload_validation_and_scaling():
    with pytest.raises(ValueError, match="no classes"):
        Workload(name="w", classes=())
    with pytest.raises(ValueError, match="duplicate"):
        Workload(name="w", classes=(
            WorkloadClass(name="a", lam=1.0, tiers=("m",)),
            WorkloadClass(name="a", lam=2.0, tiers=("m",))))
    w = BLENDED_3CLASS
    assert w.lam_total == pytest.approx(1.0)
    s = w.scaled(10.0)
    assert s.lam_total == pytest.approx(10.0)
    # the class mix is preserved under scaling
    assert [c.lam / 10.0 for c in s.classes] == \
        pytest.approx([c.lam for c in w.classes])
    with pytest.raises(ValueError):
        w.scaled(0.0)
    # flagship-first union across classes
    assert s.models == ("mixtral-8x7b", "qwen3-30b-a3b", "llama31-8b")


def test_workload_json_round_trip(tmp_path):
    w = BLENDED_3CLASS.scaled(10.0)
    d = w.to_dict()
    assert Workload.from_dict(d) == w
    path = tmp_path / "workload.json"
    path.write_text(json.dumps(d))
    assert Workload.from_json(str(path)) == w
    with pytest.raises(ValueError, match="classes"):
        Workload.from_dict({"name": "w"})
    assert "blended_3class" in WORKLOADS


# ---- router -----------------------------------------------------------


def _two_model_curves():
    # model "big" is pricier per token than "small" at every load
    recs = (_ladder(model="big", price=4.0, theta=1000.0)
            + _ladder(model="small", price=1.0, theta=1000.0))
    return fit_curves(recs)


def test_router_budget_gate_refuses_undemonstrated_budget():
    curves = _two_model_curves()
    w = Workload(name="w", classes=(
        WorkloadClass(name="long", lam=5.0, tiers=("big",),
                      budget_tokens=512),))       # chat decodes only 256
    res = route_workload(w, curves)
    d = res.decisions[0]
    assert not d.feasible and not res.feasible
    assert "512" in d.why_infeasible and "256" in d.why_infeasible
    assert d.quotes == ()                        # never even priced


def test_router_picks_cheapest_eligible_tier():
    curves = _two_model_curves()
    w = Workload(name="w", classes=(
        WorkloadClass(name="pinned", lam=5.0, tiers=("big",)),
        WorkloadClass(name="free", lam=5.0, tiers=("big", "small")),))
    res = route_workload(w, curves)
    by_name = {d.name: d for d in res.decisions}
    assert by_name["pinned"].routed == "big"
    assert not by_name["pinned"].routed_off_flagship
    assert by_name["free"].routed == "small"
    assert by_name["free"].routed_off_flagship
    assert res.n_routed_off_flagship == 1
    # both arms' pools: flagship pools everything on big, routed splits
    assert set(res.pools("flagship")) == {("big", "chat")}
    assert set(res.pools("routed")) == {("big", "chat"),
                                        ("small", "chat")}
    assert sum(d.lam for ds in res.pools("flagship").values()
               for d in ds) == pytest.approx(10.0)
    with pytest.raises(ValueError, match="arm"):
        res.pools("nope")


def test_router_missing_tier_curves_fall_through_with_reason():
    curves = _two_model_curves()
    w = Workload(name="w", classes=(
        WorkloadClass(name="c", lam=5.0, tiers=("ghost", "small")),))
    d = route_workload(w, curves).decisions[0]
    assert d.feasible and d.routed == "small"
    ghost = next(q for q in d.quotes if q.model == "ghost")
    assert not ghost.feasible and "no fitted curves" in ghost.why_infeasible


def test_router_ties_break_toward_flagship():
    # identical curves under two model names -> identical quotes
    recs = (_ladder(model="big", price=1.0)
            + _ladder(model="small", price=1.0))
    w = Workload(name="w", classes=(
        WorkloadClass(name="c", lam=5.0, tiers=("big", "small")),))
    d = route_workload(w, fit_curves(recs)).decisions[0]
    assert d.routed == "big"


# ---- exact allocator + certification ----------------------------------


def test_exact_matches_greedy_on_single_footprint():
    curves = fit_curves(_ladder())
    for lam in (1.0, 10.0, 250.0):
        greedy = greedy_mix(curves, lam)
        exact = exact_mix(curves, lam)
        assert exact is not None
        assert exact.c_eff == pytest.approx(greedy.c_eff, rel=1e-12)
        cert = certify(curves, lam)
        assert cert.gap == 0.0 and not cert.greedy_beaten
    # lam=250 needs 3 replicas of the 100-cap footprint
    assert exact_mix(curves, 250.0).n_replicas == 3


def test_exact_infeasible_matches_greedy_refusal():
    curves = fit_curves(_ladder())          # lam_max=100 -> cap 100
    # 250 rps cannot be exhausted by 2 replicas: both arms refuse
    assert greedy_mix(curves, 250.0, max_allocations=2) is None
    assert exact_mix(curves, 250.0, max_allocations=2) is None
    assert certify(curves, 250.0, max_allocations=2) is None


def test_exact_beats_greedy_on_constructed_instance():
    """The classic greedy trap: footprint A is cheapest per token for
    the first slice but its SLO cap strands a tail remainder, while
    footprint B covers the whole load alone for less total money."""
    slo = SLOTarget(ttft_p90_ms=200.0)
    # A: cheap, but TTFT crosses 200ms near lam=9 -> cap ~9 < lam
    recs_a = _ladder(hw="hw-a", price=0.5, theta=1000.0, ttft_slope=20.0)
    # B: pricier per hour, flat TTFT (always in SLO), serves 10 alone
    recs_b = _ladder(hw="hw-b", price=1.3, theta=2000.0, ttft_slope=1.0)
    curves = fit_curves(recs_a + recs_b)
    lam = 10.0
    greedy = greedy_mix(curves, lam, slo)
    exact = exact_mix(curves, lam, slo)
    # greedy grabs A for the bulk (cheapest at its ~9rps cap) and mops
    # the stranded tail with a second replica; exact proves one B
    # replica is cheaper overall
    assert len(greedy.allocations) == 2
    assert greedy.allocations[0].hw == "hw-a"
    assert exact.n_replicas == 1 and exact.allocations[0].hw == "hw-b"
    assert exact.c_eff < greedy.c_eff
    cert = certify(curves, lam, slo)
    assert cert.greedy_beaten and cert.gap > GAP_RTOL
    assert "BEATEN" in cert.describe()
    assert "hw-b" in cert.exact_label


def test_certify_reuses_precomputed_greedy():
    curves = fit_curves(_ladder())
    greedy = greedy_mix(curves, 10.0)
    cert = certify(curves, 10.0, greedy=greedy)
    assert cert.greedy_c_eff == greedy.c_eff and cert.gap == 0.0


def test_exact_rejects_mixed_model_groups():
    curves = fit_curves(_ladder() + _ladder(model="m2", hw="hw-b"))
    with pytest.raises(ValueError, match="heterogeneous"):
        exact_mix(curves, 5.0)


def test_certification_rows_on_committed_atlas():
    """Acceptance: on the committed store the exact allocator certifies
    greedy_mix at every reference load — gap exactly 0, loudly."""
    curves = fit_curves(_atlas_records())
    rows = certification_rows(curves)
    assert len(rows) == 9                   # 3 models x 3 lams
    for row in rows:
        assert row["feasible"], row
        assert row["gap"] == 0.0, row
        assert not row["greedy_beaten"], row
        assert row["greedy_c_eff"] == pytest.approx(row["exact_c_eff"])
        assert row["n_nodes"] >= 1


# ---- portfolio verdict (golden, committed paper_atlas) ----------------

# the committed 3-class blended-workload verdict: fleet $/hr per arm at
# lam_total in {1, 10, 200} (reference loads, §5). Routing carries a
# NEGATIVE bill saving on this store — splitting the pooled flagship
# load re-fragments utilization — which the table surfaces rather than
# hides; consolidation is the win.
GOLDEN_PORTFOLIO = {
    1.0: {"silo": 25.2, "flagship_pool": 8.4, "routed_pool": 15.0},
    10.0: {"silo": 25.2, "flagship_pool": 8.4, "routed_pool": 15.0},
    200.0: {"silo": 32.4, "flagship_pool": 10.8, "routed_pool": 18.9},
}


def test_portfolio_golden_on_committed_atlas():
    curves = fit_curves(_atlas_records())
    for lam_total, golden in GOLDEN_PORTFOLIO.items():
        plan = plan_portfolio(curves, BLENDED_3CLASS.scaled(lam_total))
        assert plan.feasible
        for arm, price in golden.items():
            assert plan.arms[arm].fleet_price_per_hr == \
                pytest.approx(price), (lam_total, arm)
            assert plan.arms[arm].max_gap == 0.0
        routed = {d.name: d.routed for d in plan.routing.decisions}
        assert routed == {"reasoning": "mixtral-8x7b",
                          "chat": "qwen3-30b-a3b",
                          "autocomplete": "llama31-8b"}
        sav = plan.savings()
        assert sav["consolidation"] == pytest.approx(
            1.0 - golden["flagship_pool"] / golden["silo"])
        assert sav["routing"] < 0.0          # fragmentation costs money
        assert sav["total"] == pytest.approx(
            1.0 - golden["routed_pool"] / golden["silo"])


def test_portfolio_c_eff_verdict_flips_at_saturation():
    """Per delivered token the story inverts at high rate: the routed
    fleet's cheaper tiers win once utilization is high (lam=200), while
    at low rates pooling on the flagship is cheapest."""
    curves = fit_curves(_atlas_records())
    low = plan_portfolio(curves, BLENDED_3CLASS.scaled(10.0))
    high = plan_portfolio(curves, BLENDED_3CLASS.scaled(200.0))
    assert low.arms["flagship_pool"].c_eff < low.arms["routed_pool"].c_eff
    assert high.arms["routed_pool"].c_eff < \
        high.arms["flagship_pool"].c_eff
    assert high.arms["routed_pool"].c_eff == pytest.approx(
        0.22371305458476984)
    assert high.arms["flagship_pool"].c_eff == pytest.approx(
        0.29488917459520764)


def test_portfolio_row_and_render_round_trip():
    curves = fit_curves(_atlas_records())
    plan = plan_portfolio(curves, BLENDED_3CLASS.scaled(10.0),
                          chip_budget=8)
    row = json.loads(json.dumps(portfolio_row(plan), allow_nan=False))
    assert row["feasible"] and row["within_chip_budget"]
    assert set(row["arms"]) == {"silo", "flagship_pool", "routed_pool"}
    for arm in row["arms"].values():
        assert arm["max_gap"] == 0.0
        assert arm["greedy_beaten_pools"] == []
    text = render_portfolio(plan)
    assert "consolidation +66.7%" in text
    assert "routing -78.6%" in text
    assert "chip budget 8: routed arm FITS" in text


def test_portfolio_infeasible_class_poisons_totals():
    curves = _two_model_curves()
    w = Workload(name="w", classes=(
        WorkloadClass(name="ok", lam=5.0, tiers=("big", "small")),
        WorkloadClass(name="too_long", lam=1.0, tiers=("big",),
                      budget_tokens=9999),))
    plan = plan_portfolio(curves, w)
    assert not plan.feasible
    for arm in plan.arms.values():
        assert not arm.feasible
        assert arm.fleet_price_per_hr is None
        assert "too_long" in arm.infeasible_classes
    assert all(v is None for v in plan.savings().values())
    assert "INFEASIBLE" in render_portfolio(plan)


# ---- CLI --------------------------------------------------------------


def test_cli_portfolio_mode(tmp_path, capsys):
    from repro.planner.__main__ import main
    out = tmp_path / "portfolio.json"
    main(["--plan", "paper_atlas", "--portfolio", "blended_3class",
          "--lam", "10", "--chip-budget", "8", "--json", str(out)])
    text = capsys.readouterr().out
    assert "blended_3class @ 10 rps" in text
    row = json.loads(out.read_text())
    assert row["feasible"] and row["lam_total"] == pytest.approx(10.0)
    assert row["arms"]["flagship_pool"]["fleet_price_per_hr"] == \
        pytest.approx(8.4)


def test_cli_portfolio_exit_3_on_infeasible_class(tmp_path):
    from repro.planner.__main__ import main
    spec = tmp_path / "w.json"
    spec.write_text(json.dumps({"name": "bad", "classes": [
        {"name": "huge", "lam": 5.0, "tiers": ["mixtral-8x7b"],
         "budget_tokens": 4096}]}))
    with pytest.raises(SystemExit) as e:
        main(["--plan", "paper_atlas", "--portfolio", str(spec)])
    assert e.value.code == 3


def test_cli_portfolio_unknown_spec():
    from repro.planner.__main__ import main
    with pytest.raises(SystemExit, match="unknown workload"):
        main(["--plan", "paper_atlas", "--portfolio", "nope_nope"])
    with pytest.raises(SystemExit) as e:
        main(["--plan", "paper_atlas", "--portfolio", "blended_3class",
              "--flash-crowd"])
    assert e.value.code == 2                # argparse usage error


def test_planner_tables_embed_portfolio_and_certification():
    recs = _atlas_records()
    from repro.planner import planner_tables
    t = planner_tables(recs)
    assert {r["lam_total"] for r in t["portfolio"]} == {1.0, 10.0, 200.0}
    assert all(r["feasible"] for r in t["portfolio"])
    assert all(row["gap"] == 0.0 for row in t["certification"])
    json.dumps(t, allow_nan=False)          # strict JSON
