"""Jit fleet backend (ISSUE 7): the equivalence matrix.

Three layers, mirroring the PR-4 fleet discipline one precision notch
down:

* The numpy path stays the *bitwise* oracle — `precision.enable_x64`
  must not move a single bit of `FleetStepModel` outputs or of the
  committed-store records the numpy fleet regenerates.
* Jit-vs-numpy RunRecords agree within the documented tolerance
  (`precision.jit_tolerance()`): every float field approx-equal, every
  int/str field exactly equal, on all three mini plans.
* Routing: retry-feedback / failure-injected / non-uniform cells fall
  back to the scalar-capable numpy path inside `jit_run_points`, and
  the `backend="jit"` execution path produces a complete store.
"""
import dataclasses
import math

import pytest

from repro.core.sweep import SimEngineSpec
from repro.experiments import ExperimentStore, PlanRunner, get_plan
from repro.experiments.plan import ladder_plan
from repro.experiments.runner import execute_cells
from repro.serving import precision
from repro.serving.arrivals import synth_arrays
from repro.serving.fleet import FleetPoint, FleetStepModel, fleet_run_points
from repro.serving.fleet_jit import jit_eligible, jit_run_points

jax = pytest.importorskip("jax")


def _points(cells, factory=None):
    return [FleetPoint(engine=factory or c.engine_spec(),
                       arrivals=c.arrival_spec(), warmup=c.warmup,
                       horizon=c.horizon, failure_times=c.failure_times,
                       **c.record_kw())
            for c in cells]


def _assert_records_close(oracle, got, ctx=""):
    """Float fields within `precision.jit_tolerance()`, everything else
    exactly equal — the documented jit-vs-numpy agreement contract."""
    rtol, atol = precision.jit_tolerance()
    assert len(oracle) == len(got)
    for a, b in zip(oracle, got):
        da, db = dataclasses.asdict(a), dataclasses.asdict(b)
        assert da.keys() == db.keys()
        for key in da:
            va, vb = da[key], db[key]
            if isinstance(va, float):
                if math.isnan(va):
                    assert math.isnan(vb), (ctx, a.lam, key)
                else:
                    assert vb == pytest.approx(va, rel=rtol, abs=atol), \
                        (ctx, a.model, a.hw, a.quant, a.lam, key, va, vb)
            else:
                assert va == vb, (ctx, a.model, a.lam, key, va, vb)


def _assert_records_equal(xs, ys, ctx=""):
    assert len(xs) == len(ys)
    for a, b in zip(xs, ys):
        da, db = dataclasses.asdict(a), dataclasses.asdict(b)
        for key in da:
            assert repr(da[key]) == repr(db[key]), \
                (ctx, a.model, a.hw, a.quant, a.lam, key, da[key], db[key])


# ---- precision policy --------------------------------------------------


def test_enable_x64_active_and_tolerance_switch(monkeypatch):
    assert precision.enable_x64()           # container jax supports x64
    assert precision.active_x64()
    assert precision.jit_tolerance() == precision.X64_TOLERANCE
    # the f32 fallback bound is what callers would see without x64
    monkeypatch.setitem(precision._STATE, "enabled", False)
    assert not precision.active_x64()
    assert precision.jit_tolerance() == precision.F32_TOLERANCE
    # the bounds themselves are ordered: x64 is the tight one
    assert precision.X64_TOLERANCE[0] < precision.F32_TOLERANCE[0]


def test_enable_x64_leaves_numpy_step_model_bitwise():
    """The satellite guard: flipping jax's dtype config cannot move a
    bit of the pure-numpy roofline (the committed stores' oracle)."""
    from repro.configs import get_config
    from repro.simulate import HW_BY_NAME, StepTimeModel
    import numpy as np
    models = [StepTimeModel(get_config("llama31-8b"),
                            HW_BY_NAME["tpu-v5e"], n_chips=2),
              StepTimeModel(get_config("qwen3-30b-a3b"),
                            HW_BY_NAME["tpu-v6e"], n_chips=2, quant="fp8")]
    b = np.array([17.0, 203.0])
    ctx = np.array([512.0, 37.5])
    k = np.array([9.0, 411.0])
    before = FleetStepModel(models)
    dt0 = before.decode_time(b, ctx).tobytes()
    dtm0 = before.decode_time_multi(b, ctx, k).tobytes()
    pf0 = before.prefill_time(b, ctx).tobytes()
    assert precision.enable_x64()
    after = FleetStepModel(models)      # rebuilt under the jax flag
    assert after.decode_time(b, ctx).tobytes() == dt0
    assert after.decode_time_multi(b, ctx, k).tobytes() == dtm0
    assert after.prefill_time(b, ctx).tobytes() == pf0


# ---- jit-vs-numpy equivalence matrix -----------------------------------


@pytest.mark.parametrize("plan_name",
                         ["mini_2x2", "mini_crosshw", "mini_resilience"])
def test_jit_records_match_numpy_within_tolerance(plan_name):
    """The tentpole contract on every mini plan: the numpy fleet is the
    oracle, the jit backend agrees field-for-field within the
    documented tolerance (mini_resilience rides the scalar fallback
    inside jit_run_points, so it is exact by construction)."""
    cells = list(get_plan(plan_name).cells)
    oracle = fleet_run_points(_points(cells))
    got = jit_run_points(_points(cells))
    _assert_records_close(oracle, got, plan_name)


def test_jit_on_result_streams_every_lane():
    cells = list(get_plan("mini_crosshw").cells)
    seen = {}
    recs = jit_run_points(_points(cells),
                          on_result=lambda i, r: seen.setdefault(i, r))
    assert sorted(seen) == list(range(len(cells)))
    for i, rec in enumerate(recs):
        assert seen[i] is rec


def test_uniform_warmup_lanes_ride_jit_with_identical_records():
    """Warmup is a measurement-phase no-op for jit-eligible lanes (the
    jit loop skips it outright); records must still match the numpy
    fleet, which replays the full warmup protocol."""
    fac = SimEngineSpec("llama31-8b", max_batch=64, num_pages=8192)
    plan = ladder_plan(ladder=(5, 25), arch="llama31-8b",
                       model="llama31-8b", hw="tpu-v5e",
                       requests_per_point=lambda lam: 150,
                       warmup_per_point=lambda lam: 25)
    pts = _points(list(plan.cells), factory=fac)
    assert all(jit_eligible(p, synth_arrays(p.arrivals)) for p in pts)
    _assert_records_close(fleet_run_points(pts), jit_run_points(pts),
                          "warmup")


# ---- scalar-fallback routing -------------------------------------------


def test_resilient_cells_are_not_jit_eligible():
    """Retry-feedback cells (failure injection, client retries, shed /
    deadline admission control) must route to the scalar path — the jit
    loop has no failure machinery by design."""
    for cell in get_plan("mini_resilience").cells:
        p = _points([cell])[0]
        stream = synth_arrays(p.arrivals)
        assert jit_eligible(p, stream) == (not cell.resilient)


def test_failure_times_and_nonuniform_shapes_fall_back():
    fac = SimEngineSpec("llama31-8b", max_batch=64, num_pages=8192)
    plan = ladder_plan(ladder=(10,), arch="llama31-8b",
                       model="llama31-8b", hw="tpu-v5e",
                       requests_per_point=lambda lam: 60,
                       warmup_per_point=lambda lam: 0)
    base = _points(list(plan.cells), factory=fac)[0]
    assert jit_eligible(base, synth_arrays(base.arrivals))
    # explicit failure injection -> scalar path
    failed = dataclasses.replace(base, failure_times=(0.5,))
    assert not jit_eligible(failed, synth_arrays(failed.arrivals))
    # sampled (non-uniform) request shapes -> numpy fleet path (the
    # log-normal tail needs a bigger per-seq page budget than the mini
    # engine default, on any backend)
    sampled = dataclasses.replace(
        base,
        engine=dataclasses.replace(fac, max_pages_per_seq=512),
        arrivals=dataclasses.replace(base.arrivals, io_shape="variable"))
    assert not jit_eligible(sampled, synth_arrays(sampled.arrivals))
    # a mixed batch still returns one record per point, in order
    mixed = [base, failed, sampled]
    oracle = fleet_run_points(mixed)
    got = jit_run_points(mixed)
    _assert_records_close(oracle, got, "mixed-routing")


# ---- execution backend ---------------------------------------------------


def test_jit_backend_store_complete_and_tolerance_identical(tmp_path):
    """`backend="jit"` fills a complete store whose records agree with
    the vector backend's within tolerance (the CI matrix-smoke check)."""
    plan = get_plan("mini_2x2")
    s1 = ExperimentStore(plan.name, tmp_path / "vector")
    s2 = ExperimentStore(plan.name, tmp_path / "jit")
    vec = PlanRunner(plan, store=s1).run(parallel=False, backend="vector")
    jit = PlanRunner(plan, store=s2).run(parallel=False, backend="jit")
    assert len(jit) == len(plan.cells)
    assert len(s2.completed_ids(plan)) == len(plan.cells)
    _assert_records_close(vec, jit, "jit-store")


def test_jit_backend_handles_reference_cells():
    """fast_forward=False cells cannot ride any fleet lane; the jit
    backend must route them through the per-cell path transparently."""
    plan = get_plan("mini_2x2")
    mixed = [dataclasses.replace(c, fast_forward=(i % 2 == 0))
             for i, c in enumerate(plan.cells)]
    process = execute_cells(mixed, parallel=False, backend="process")
    jit = execute_cells(mixed, parallel=False, backend="jit")
    _assert_records_close(process, jit, "mixed-ff")


# ---- committed-store regeneration (numpy oracle) ------------------------


def test_committed_atlas_cells_regenerate_bitwise_on_numpy_path():
    """Acceptance: enabling x64 for the jit backend leaves the numpy
    fleet byte-identical to the committed stores. Re-runs a sample of
    committed `paper_atlas` cells (cheap low-lambda paper-protocol
    points) through the numpy fleet under the jax flag and repr-compares
    against the stored records."""
    plan = get_plan("paper_atlas")
    store = ExperimentStore(plan.name)
    stored = store.load_cell_records(plan)
    if len(stored) < len(plan.cells):
        pytest.skip("paper_atlas store not populated")
    assert precision.enable_x64()
    sample = [c for c in plan.cells if c.lam <= 1.25][:4]
    assert len(sample) == 4
    fresh = fleet_run_points(_points(sample))
    _assert_records_equal([stored[c.cell_id] for c in sample], fresh,
                          "committed-atlas")
