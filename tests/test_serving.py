"""Serving-engine invariants: completion, token conservation, Little's law,
page accounting, failure re-queue, chunked-prefill budget."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import init_params
from repro.serving import (ArrivalSpec, Engine, EngineConfig, RealExecutor,
                           SimExecutor, synth_requests)
from repro.serving.kv_cache import PageManager
from repro.simulate import StepTimeModel, V5E

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:                                       # pragma: no cover
    HAVE_HYP = False


def _sim_engine(max_batch=64, num_pages=4096, **ecfg_kw):
    cfg = get_config("llama31-8b")
    stm = StepTimeModel(cfg, V5E)
    return Engine(EngineConfig(max_batch=max_batch, page_size=16,
                               num_pages=num_pages, max_pages_per_seq=64,
                               **ecfg_kw), SimExecutor(cfg, stm))


def test_all_requests_complete_and_tokens_conserved():
    eng = _sim_engine()
    reqs = synth_requests(ArrivalSpec(lam=10, n_requests=50, seed=3))
    eng.run(reqs)
    assert all(r.finish_time is not None for r in reqs)
    want = sum(r.max_new_tokens for r in reqs)
    got = eng.metrics.get("repro:generation_tokens_total")
    assert got == want
    assert eng.metrics.get("repro:request_success_total") == 50
    # all pages returned
    assert eng.pm.free_pages == eng.pm.num_pages - 1
    assert len(eng.pm.free_slots) == eng.cfg.max_batch


def test_littles_law():
    """Time-averaged in-flight ~= lambda_effective * mean residence."""
    eng = _sim_engine(max_batch=128, num_pages=16384)
    reqs = synth_requests(ArrivalSpec(lam=5, n_requests=300, seed=0))
    eng.run(reqs)
    done = [r for r in reqs if r.finish_time is not None]
    lam_eff = len(done) / eng.t
    W = float(np.mean([r.e2e for r in done]))
    N = eng.mean_inflight()
    assert abs(N - lam_eff * W) / max(N, 1e-9) < 0.15, (N, lam_eff * W)


def test_ttft_ordering_and_latency_growth():
    """TTFT includes queueing; higher lambda => higher p99 TTFT."""
    p99 = {}
    for lam in (1.0, 50.0):
        eng = _sim_engine()
        reqs = synth_requests(ArrivalSpec(lam=lam, n_requests=100, seed=1))
        eng.run(reqs)
        done = [r for r in reqs if r.ttft is not None]
        for r in done:
            assert r.first_token_time >= r.arrival_time
            assert r.finish_time >= r.first_token_time
        p99[lam] = np.percentile([r.ttft for r in done], 99)
    assert p99[50.0] > p99[1.0]


def test_failure_requeue_completes():
    eng = _sim_engine()
    reqs = synth_requests(ArrivalSpec(lam=20, n_requests=40, seed=2))
    eng.run(reqs, failure_times=[0.5, 1.5])
    assert eng.metrics.get("repro:request_preempted_total") > 0
    # bounded retries: every request either finished or exhausted retries
    for r in reqs:
        assert r.finish_time is not None or r.retries > eng.cfg.max_retries
    done = [r for r in reqs if r.finish_time is not None]
    assert len(done) >= 38          # at most a couple lost to retry budget
    assert eng.pm.free_pages == eng.pm.num_pages - 1


def test_real_executor_roundtrip(rng):
    cfg = reduced("llama31-8b")
    params = init_params(rng, cfg)
    ex = RealExecutor(cfg, params, num_pages=128, page_size=8, max_batch=4)
    eng = Engine(EngineConfig(max_batch=4, page_size=8, num_pages=128,
                              max_pages_per_seq=16), ex)
    reqs = synth_requests(ArrivalSpec(lam=50, n_requests=6, scale=0.02,
                                      seed=4))
    eng.run(reqs)
    assert all(r.finish_time is not None for r in reqs)
    assert eng.metrics.get("repro:generation_tokens_total") == \
        sum(r.max_new_tokens for r in reqs)


def test_page_manager_zero_length_admit_keeps_free_list():
    """Regression: admit(0, 0) must reserve a slot with zero pages, not
    wipe the free list (the sliced `del free[-0:]` pitfall)."""
    pm = PageManager(num_pages=128, page_size=8, max_batch=8,
                     max_pages_per_seq=16)
    slot = pm.admit(0, 0)
    assert slot is not None
    assert pm.free_pages == pm.num_pages - 1
    assert pm.pages_of[slot] == []
    pm.release(slot)
    assert pm.free_pages == pm.num_pages - 1
    assert sorted(pm.free_slots) == list(range(8))


if HAVE_HYP:
    @given(st.lists(st.tuples(st.integers(1, 60), st.integers(1, 40)),
                    min_size=1, max_size=25),
           st.integers(0, 10_000))
    @settings(max_examples=25, deadline=None)
    def test_page_manager_never_leaks(lens, seed):
        pm = PageManager(num_pages=128, page_size=8, max_batch=8,
                         max_pages_per_seq=16)
        rng = np.random.default_rng(seed)
        live = []
        for prompt, new in lens:
            if pm.can_admit(prompt, new):
                slot = pm.admit(prompt, new)
                assert slot is not None
                live.append(slot)
            if live and rng.random() < 0.5:
                pm.release(live.pop(rng.integers(len(live))))
        for s in live:
            pm.release(s)
        assert pm.free_pages == pm.num_pages - 1
        assert sorted(pm.free_slots) == list(range(8))
        assert pm.utilization() == 0.0
