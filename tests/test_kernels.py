"""Per-kernel allclose sweeps: Pallas (interpret=True) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.int8_matmul.ops import int8_matmul
from repro.kernels.int8_matmul.ref import int8_matmul_ref
from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.kernels.ssm_scan.ops import ssm_scan
from repro.kernels.ssm_scan.ref import ssm_scan_ref

RNG = np.random.default_rng(0)


def _randn(shape, dtype=jnp.bfloat16):
    return jnp.asarray(RNG.normal(size=shape), dtype)


@pytest.mark.parametrize("B,Sq,Sk,Hq,Hkv,D,causal", [
    (2, 256, 256, 4, 2, 64, True),
    (1, 128, 256, 8, 8, 128, False),
    (2, 256, 256, 4, 1, 64, True),       # MQA
    (1, 512, 512, 2, 2, 32, True),
])
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_flash_attention(B, Sq, Sk, Hq, Hkv, D, causal, dtype):
    q, k, v = (_randn((B, Sq, Hq, D), dtype), _randn((B, Sk, Hkv, D), dtype),
               _randn((B, Sk, Hkv, D), dtype))
    out = flash_attention(q, k, v, causal=causal, use_kernel=True,
                          interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal)
    tol = 0.06 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


@pytest.mark.parametrize("B,Hq,Hkv,D,page,maxp,P", [
    (2, 4, 2, 64, 8, 4, 16),
    (3, 8, 1, 128, 16, 3, 64),
    (1, 4, 4, 64, 8, 6, 12),
    (4, 8, 2, 64, 8, 5, 64),
])
def test_paged_attention(B, Hq, Hkv, D, page, maxp, P):
    q = _randn((B, Hq, D))
    kp, vp = _randn((P, page, Hkv, D)), _randn((P, page, Hkv, D))
    bt = jnp.asarray(RNG.choice(P, size=(B, maxp),
                                replace=B * maxp > P), jnp.int32)
    sl = jnp.asarray(RNG.integers(1, page * maxp + 1, size=(B,)), jnp.int32)
    out = paged_attention(q, kp, vp, bt, sl, use_kernel=True, interpret=True)
    ref = paged_attention_ref(q, kp, vp, bt, sl)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=0.06)


@pytest.mark.parametrize("M,K,N", [(256, 256, 256), (512, 512, 256),
                                   (256, 1024, 512)])
def test_int8_matmul(M, K, N):
    xq = jnp.asarray(RNG.integers(-127, 128, (M, K)), jnp.int8)
    wq = jnp.asarray(RNG.integers(-127, 128, (K, N)), jnp.int8)
    xs = jnp.asarray([0.013], jnp.float32)
    ws = jnp.asarray(RNG.uniform(0.001, 0.02, (1, N)), jnp.float32)
    out = int8_matmul(xq, wq, xs, ws, use_kernel=True, interpret=True)
    ref = int8_matmul_ref(xq, wq, xs, ws)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5)


@pytest.mark.parametrize("B,S,di,N,chunk,bdi", [
    (2, 512, 512, 16, 128, 256),
    (1, 256, 1024, 8, 256, 512),
    (3, 512, 512, 4, 64, 512),
])
def test_ssm_scan(B, S, di, N, chunk, bdi):
    u = jnp.asarray(RNG.normal(size=(B, S, di)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, (B, S, di)), jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(B, S, N)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(B, S, N)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 2.0, (di, N)), jnp.float32)
    D = jnp.asarray(RNG.normal(size=(di,)), jnp.float32)
    h0 = jnp.asarray(RNG.normal(size=(B, di, N)), jnp.float32)
    y, h = ssm_scan(u, dt, Bm, Cm, A, D, h0, use_kernel=True,
                    interpret=True, chunk=chunk, block_di=bdi)
    yr, hr = ssm_scan_ref(u, dt, Bm, Cm, A, D, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=1e-4)


def test_ssm_scan_state_continuity():
    """Scanning two halves with carried state == one full scan."""
    B, S, di, N = 1, 256, 256, 8
    u = jnp.asarray(RNG.normal(size=(B, S, di)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.001, 0.1, (B, S, di)), jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(B, S, N)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(B, S, N)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 2.0, (di, N)), jnp.float32)
    D = jnp.asarray(RNG.normal(size=(di,)), jnp.float32)
    y_full, h_full = ssm_scan_ref(u, dt, Bm, Cm, A, D)
    half = S // 2
    y1, h1 = ssm_scan_ref(u[:, :half], dt[:, :half], Bm[:, :half],
                          Cm[:, :half], A, D)
    y2, h2 = ssm_scan_ref(u[:, half:], dt[:, half:], Bm[:, half:],
                          Cm[:, half:], A, D, h0=h1)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full), atol=1e-4)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_full[:, half:]),
                               atol=1e-4)
