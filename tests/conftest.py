"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see 1 CPU device;
only the dry-run subprocess uses 512 placeholder devices."""
import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
