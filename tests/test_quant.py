"""Quantization substrate: roundtrip error bounds, tree transform, linear."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.quant import (BY_NAME, INT8, FP8_EMULATED, linear,
                         quantize_tree, quantize_weight)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:                                       # pragma: no cover
    HAVE_HYP = False

RNG = np.random.default_rng(7)


@pytest.mark.parametrize("mode,maxrel", [("int8", 0.02), ("fp8", 0.08)])
def test_weight_roundtrip_error(mode, maxrel):
    w = jnp.asarray(RNG.normal(size=(128, 256)) * 0.02, jnp.bfloat16)
    qw = quantize_weight(w, mode)
    deq = qw["q"].astype(jnp.float32) * qw["scale"]
    err = np.abs(np.asarray(deq) - np.asarray(w, np.float32))
    ref = np.abs(np.asarray(w, np.float32)).max(axis=0, keepdims=True)
    assert (err / np.maximum(ref, 1e-9)).max() < maxrel


@pytest.mark.parametrize("qcfg", [INT8, FP8_EMULATED])
def test_linear_quantized_close_to_bf16(qcfg):
    x = jnp.asarray(RNG.normal(size=(4, 32, 128)), jnp.bfloat16)
    w = jnp.asarray(RNG.normal(size=(128, 64)) * 0.05, jnp.bfloat16)
    ref = linear(x, w, None)
    qw = quantize_weight(w, qcfg.mode)
    out = linear(x, qw, qcfg)
    rel = float(jnp.linalg.norm((out - ref).astype(jnp.float32)) /
                jnp.linalg.norm(ref.astype(jnp.float32)))
    assert rel < 0.05, rel


def test_quantize_tree_skips_non_matmul_leaves(rng):
    from repro.configs import reduced
    from repro.models import init_params
    cfg = reduced("jamba-v0.1-52b")          # covers mamba + moe + attn
    params = init_params(rng, cfg)
    qparams = quantize_tree(params, "int8")

    def walk(p, path=""):
        if isinstance(p, dict):
            if set(p) == {"q", "scale"}:
                assert p["q"].dtype == jnp.int8
                return
            for k, v in p.items():
                walk(v, f"{path}/{k}")
        elif isinstance(p, (list, tuple)):
            for i, v in enumerate(p):
                walk(v, path)
    walk(qparams)
    # embeddings / norms / SSM tensors stay unquantized
    assert qparams["embed"].dtype == jnp.bfloat16
    blk = qparams["blocks"][0]
    assert blk["ln1"]["scale"].dtype == jnp.bfloat16


def test_quantized_model_forward_close(rng):
    from repro.configs import reduced
    from repro.models import init_params, forward
    cfg = reduced("llama31-8b")
    params = init_params(rng, cfg)
    batch = {"tokens": jnp.arange(32, dtype=jnp.int32).reshape(2, 16) % 50,
             "labels": jnp.ones((2, 16), jnp.int32)}
    ref, _ = forward(params, cfg, batch)
    qp = quantize_tree(params, "int8")
    out, _ = forward(qp, cfg, batch, qcfg=INT8)
    # logits shift a little; argmax agreement is the serving-level contract
    agree = float(jnp.mean((jnp.argmax(out, -1) ==
                            jnp.argmax(ref, -1)).astype(jnp.float32)))
    assert agree > 0.9, agree


if HAVE_HYP:
    @given(st.integers(2, 64), st.integers(2, 64),
           st.floats(1e-4, 10.0), st.integers(0, 2**31 - 1))
    @settings(max_examples=30, deadline=None)
    def test_int8_roundtrip_bound_property(m, n, scale, seed):
        r = np.random.default_rng(seed)
        w = jnp.asarray(r.normal(size=(m, n)) * scale, jnp.float32)
        qw = quantize_weight(w.astype(jnp.bfloat16), "int8")
        deq = np.asarray(qw["q"], np.float32) * np.asarray(qw["scale"])
        colmax = np.abs(np.asarray(w)).max(axis=0)
        err = np.abs(deq - np.asarray(w, np.float32))
        # error bounded by half a quantization step (+bf16 noise) per column
        assert (err <= colmax / 127.0 + 0.01 * colmax + 1e-6).all()
