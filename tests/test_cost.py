"""Cost-model invariants (paper Eq. 1-4), property-based via hypothesis."""
import math

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:                                       # pragma: no cover
    HAVE_HYP = False

from repro.core import (c_eff, c_naive, underutilization_penalty,
                        utilization, interp_c_eff, crossover_lambda,
                        crossover_table)
from repro.core.pricing import API_TIERS, APITier
from repro.core.records import RunRecord


def _rec(lam, tps, price=1.2, **kw):
    base = dict(config="t", model="m", hw="h", n_chips=1, quant="bf16",
                engine="sim", io_shape="chat", n_requests=10, n_completed=10,
                window_s=10.0, prompt_tps=0.0, ttft_p50_ms=1, ttft_p90_ms=1,
                ttft_p99_ms=1, tpot_p50_ms=1, tpot_p99_ms=1, e2e_p50_ms=1,
                e2e_p99_ms=1, mean_inflight=1.0, price_per_hr=price,
                c_eff=c_eff(price, tps), theta_max=0.0)
    base.update(kw)
    return RunRecord(lam=lam, tps=tps, **base)


def test_penalty_is_exactly_one_over_u():
    """The paper's central identity: C_eff/C_naive == 1/U, by construction."""
    price, tmax = 6.98, 6238.0
    for tps in (255.4, 2501.8, 6238.0):
        lhs = c_eff(price, tps) / c_naive(price, tmax)
        rhs = underutilization_penalty(tps, tmax)
        assert math.isclose(lhs, rhs, rel_tol=1e-12)


def test_paper_headline_numbers():
    """Llama 3.1 8B FP16 on one H100 at $6.98/hr (paper Table 3):
    6238 tok/s -> $0.311/MTok; 255 tok/s at lambda=1 -> $7.60 (24.4x)."""
    assert math.isclose(c_eff(6.98, 6238.0), 0.3108, rel_tol=1e-3)
    assert math.isclose(c_eff(6.98, 255.0), 7.603, rel_tol=1e-3)
    assert math.isclose(underutilization_penalty(255.0, 6238.0), 24.46,
                        rel_tol=1e-3)


if HAVE_HYP:
    pos = st.floats(min_value=1e-3, max_value=1e9, allow_nan=False)

    @given(price=pos, tps=pos)
    @settings(max_examples=200, deadline=None)
    def test_c_eff_properties(price, tps):
        c = c_eff(price, tps)
        assert c > 0
        # linear in price, inverse in throughput
        assert math.isclose(c_eff(2 * price, tps), 2 * c, rel_tol=1e-9)
        assert math.isclose(c_eff(price, 2 * tps), c / 2, rel_tol=1e-9)

    @given(tps=pos, tmax=pos)
    @settings(max_examples=200, deadline=None)
    def test_utilization_bounds(tps, tmax):
        u = utilization(min(tps, tmax), tmax)
        assert 0 <= u <= 1 + 1e-12
        assert underutilization_penalty(min(tps, tmax), tmax) >= 1 - 1e-12

    @given(st.lists(st.tuples(
        st.floats(min_value=0.1, max_value=500),
        st.floats(min_value=1.0, max_value=1e5)),
        min_size=2, max_size=8, unique_by=lambda t: t[0]))
    @settings(max_examples=100, deadline=None)
    def test_interp_within_envelope(pts):
        recs = [_rec(lam, tps) for lam, tps in pts]
        lams = sorted(r.lam for r in recs)
        mid = math.sqrt(lams[0] * lams[-1])
        v = interp_c_eff(recs, mid)
        lo = min(r.c_eff for r in recs)
        hi = max(r.c_eff for r in recs)
        assert lo - 1e-9 <= v <= hi + 1e-9


def test_crossover_monotone_curve():
    # monotone decreasing C_eff: crossing 1.0 between lam=2 (c=2) & lam=8
    recs = [_rec(1, 100), _rec(2, 500), _rec(8, 4000), _rec(32, 8000)]
    # price 1.2 -> c_eff: 3.33, 0.67, 0.083, 0.042
    res = crossover_lambda(recs, 1.0)
    assert res is not None
    lam, extrap = res
    assert 1 < lam < 2 and not extrap
    # never crosses an impossibly cheap tier
    assert crossover_lambda(recs, 1e-9) is None


def test_crossover_table_gated():
    recs = [_rec(1, 100), _rec(10, 1000)]
    with pytest.raises(ValueError):
        crossover_table(recs)       # must refuse without SLO-mismatch ack
    rows = crossover_table(recs, accept_slo_mismatch=True)
    assert {r["tier"] for r in rows} == set(API_TIERS)


def test_api_blended_price():
    t = APITier("x", 5.0, 30.0)
    # paper §6.3: 100:500 shape -> ~$25.8-26/MTok aggregate on output basis
    assert math.isclose(t.blended(100, 500), (100 * 5 + 500 * 30) / 500,
                        rel_tol=1e-12)
