"""Cost-model invariants (paper Eq. 1-4), property-based via hypothesis."""
import math

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:                                       # pragma: no cover
    HAVE_HYP = False

from repro.core import (aggregate_points, c_eff, c_naive, crossover_lambda,
                        crossover_table, interp_c_eff, interp_loglog,
                        underutilization_penalty, utilization)
from repro.core.pricing import API_TIERS, APITier
from repro.core.records import RunRecord


def _rec(lam, tps, price=1.2, **kw):
    base = dict(config="t", model="m", hw="h", n_chips=1, quant="bf16",
                engine="sim", io_shape="chat", n_requests=10, n_completed=10,
                window_s=10.0, prompt_tps=0.0, ttft_p50_ms=1, ttft_p90_ms=1,
                ttft_p99_ms=1, tpot_p50_ms=1, tpot_p99_ms=1, e2e_p50_ms=1,
                e2e_p99_ms=1, mean_inflight=1.0, price_per_hr=price,
                c_eff=c_eff(price, tps), theta_max=0.0)
    base.update(kw)
    return RunRecord(lam=lam, tps=tps, **base)


def test_penalty_is_exactly_one_over_u():
    """The paper's central identity: C_eff/C_naive == 1/U, by construction."""
    price, tmax = 6.98, 6238.0
    for tps in (255.4, 2501.8, 6238.0):
        lhs = c_eff(price, tps) / c_naive(price, tmax)
        rhs = underutilization_penalty(tps, tmax)
        assert math.isclose(lhs, rhs, rel_tol=1e-12)


def test_paper_headline_numbers():
    """Llama 3.1 8B FP16 on one H100 at $6.98/hr (paper Table 3):
    6238 tok/s -> $0.311/MTok; 255 tok/s at lambda=1 -> $7.60 (24.4x)."""
    assert math.isclose(c_eff(6.98, 6238.0), 0.3108, rel_tol=1e-3)
    assert math.isclose(c_eff(6.98, 255.0), 7.603, rel_tol=1e-3)
    assert math.isclose(underutilization_penalty(255.0, 6238.0), 24.46,
                        rel_tol=1e-3)


if HAVE_HYP:
    pos = st.floats(min_value=1e-3, max_value=1e9, allow_nan=False)

    @given(price=pos, tps=pos)
    @settings(max_examples=200, deadline=None)
    def test_c_eff_properties(price, tps):
        c = c_eff(price, tps)
        assert c > 0
        # linear in price, inverse in throughput
        assert math.isclose(c_eff(2 * price, tps), 2 * c, rel_tol=1e-9)
        assert math.isclose(c_eff(price, 2 * tps), c / 2, rel_tol=1e-9)

    @given(tps=pos, tmax=pos)
    @settings(max_examples=200, deadline=None)
    def test_utilization_bounds(tps, tmax):
        u = utilization(min(tps, tmax), tmax)
        assert 0 <= u <= 1 + 1e-12
        assert underutilization_penalty(min(tps, tmax), tmax) >= 1 - 1e-12

    @given(st.lists(st.tuples(
        st.floats(min_value=0.1, max_value=500),
        st.floats(min_value=1.0, max_value=1e5)),
        min_size=2, max_size=8, unique_by=lambda t: t[0]))
    @settings(max_examples=100, deadline=None)
    def test_interp_within_envelope(pts):
        recs = [_rec(lam, tps) for lam, tps in pts]
        lams = sorted(r.lam for r in recs)
        mid = math.sqrt(lams[0] * lams[-1])
        v = interp_c_eff(recs, mid)
        lo = min(r.c_eff for r in recs)
        hi = max(r.c_eff for r in recs)
        assert lo - 1e-9 <= v <= hi + 1e-9


def test_crossover_monotone_curve():
    # monotone decreasing C_eff: crossing 1.0 between lam=2 (c=2) & lam=8
    recs = [_rec(1, 100), _rec(2, 500), _rec(8, 4000), _rec(32, 8000)]
    # price 1.2 -> c_eff: 3.33, 0.67, 0.083, 0.042
    res = crossover_lambda(recs, 1.0)
    assert res is not None
    lam, extrap = res
    assert 1 < lam < 2 and not extrap
    # never crosses an impossibly cheap tier
    assert crossover_lambda(recs, 1e-9) is None


def test_interp_flat_segment_is_exact():
    """ISSUE 5 regression: an exactly-5.0 curve must interpolate to 5.0,
    not exp(log(5.0)) = 4.999999999999999."""
    recs = [_rec(1, 100, c_eff=5.0), _rec(10, 100, c_eff=5.0),
            _rec(100, 100, c_eff=5.0)]
    for lam in (1.0, 3.0, 10.0, 31.6, 100.0):
        assert interp_c_eff(recs, lam) == 5.0
    # knot hits return the knot value exactly even on sloped curves
    recs = [_rec(1, 100, c_eff=7.3), _rec(10, 1000, c_eff=0.73)]
    assert interp_c_eff(recs, 1.0) == 7.3
    assert interp_c_eff(recs, 10.0) == 0.73


def test_duplicate_lambda_records_aggregate():
    """ISSUE 5 regression: merged/overlapping stores carry duplicate-lambda
    records; the verdict must key off the aggregate, not whichever
    duplicate sorts first, and equal-lambda pairs must not divide by
    zero-width log segments."""
    # identical duplicates collapse exactly (no log/exp round-trip)
    recs = [_rec(1, 100, c_eff=8.0), _rec(1, 100, c_eff=8.0),
            _rec(10, 1000, c_eff=0.5)]
    assert interp_c_eff(recs, 1.0) == 8.0
    assert interp_c_eff(recs, 5.0) == interp_c_eff(
        [_rec(1, 100, c_eff=8.0), _rec(10, 1000, c_eff=0.5)], 5.0)

    # disagreeing duplicates aggregate by geometric mean
    (x, y), = aggregate_points([(1.0, 4.0), (1.0, 16.0)])
    assert x == 1.0 and y == pytest.approx(8.0, rel=1e-12)

    # pre-fix failure 1: sorted (lam, c_eff) tuples keyed "always cheaper"
    # off the *lower* duplicate; the aggregate (gm(4, 16) = 8 > 5) says no
    dup = [_rec(1, 100, c_eff=4.0), _rec(1, 100, c_eff=16.0),
           _rec(10, 1000, c_eff=0.5)]
    res = crossover_lambda(dup, 5.0)
    assert res is not None
    lam, extrap = res
    assert not extrap and 1.0 < lam < 10.0

    # pre-fix failure 2: an equal-lambda pair straddling the tier price
    # made interp hit a zero-width log segment (ZeroDivisionError)
    straddle = [_rec(1, 100, c_eff=9.0), _rec(1, 100, c_eff=2.0),
                _rec(10, 1000, c_eff=0.1)]
    assert interp_c_eff(straddle, 1.0) == pytest.approx(
        math.sqrt(9.0 * 2.0), rel=1e-12)
    res = crossover_lambda(straddle, 1.0)
    assert res is not None and not res[1]


def test_interp_loglog_empty_and_single():
    assert math.isnan(interp_loglog([], 5.0))
    assert interp_loglog([(2.0, 3.0)], 1.0) == 3.0
    assert interp_loglog([(2.0, 3.0)], 9.0) == 3.0


def test_disagreeing_duplicates_with_unloggable_values():
    """Aggregation must not take logs of non-positive or infinite
    duplicate values: a clamped edge query used to crash with a math
    domain error the moment such a pair existed anywhere on the curve."""
    assert interp_loglog([(1.0, 0.0), (1.0, 5.0), (10.0, 2.0)], 0.5) == 0.0
    (_, y), _ = aggregate_points([(1.0, 0.0), (1.0, 5.0), (10.0, 2.0)])
    assert y == 0.0                     # propagate the floor, no log
    (_, y), = aggregate_points([(1.0, math.inf), (1.0, 2.0)])
    assert y == math.inf                # no exp-overflow either
    # interior queries across a segment with an unloggable endpoint clamp
    # to the nearer knot instead of raising math-domain errors
    pts = [(1.0, 0.0), (1.0, 5.0), (10.0, 2.0)]
    assert interp_loglog(pts, 1.5) == 0.0       # nearer the zero knot
    assert interp_loglog(pts, 9.0) == 2.0       # nearer the finite knot
    assert interp_loglog([(1.0, math.inf), (10.0, 2.0)], 9.0) == 2.0


def test_crossover_table_gated():
    recs = [_rec(1, 100), _rec(10, 1000)]
    with pytest.raises(ValueError):
        crossover_table(recs)       # must refuse without SLO-mismatch ack
    rows = crossover_table(recs, accept_slo_mismatch=True)
    assert {r["tier"] for r in rows} == set(API_TIERS)


def test_api_blended_price():
    t = APITier("x", 5.0, 30.0)
    # paper §6.3: 100:500 shape -> ~$25.8-26/MTok aggregate on output basis
    assert math.isclose(t.blended(100, 500), (100 * 5 + 500 * 30) / 500,
                        rel_tol=1e-12)
