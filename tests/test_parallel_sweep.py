"""parallel_sweep must reproduce serial lambda_sweep records exactly —
same deterministic per-point seeds, same ladder order — whether the
points actually ran in pool workers or fell back to the serial path."""
import dataclasses

import pytest

from repro.core import SimEngineSpec, lambda_sweep, parallel_sweep
from repro.serving import Engine, EngineConfig, SimExecutor

LADDER = (1, 10, 50)


def _kw():
    return dict(ladder=LADDER,
                requests_per_point=lambda lam: 80,
                warmup_per_point=lambda lam: 0,
                config="C1", model="llama31-8b", hw="tpu-v5e",
                price_per_hr=1.2)


def _records_equal(xs, ys):
    assert len(xs) == len(ys)
    for a, b in zip(xs, ys):
        assert dataclasses.asdict(a) == dataclasses.asdict(b)
    assert [r.lam for r in xs] == list(LADDER)      # ladder order preserved


def test_parallel_matches_serial():
    fac = SimEngineSpec("llama31-8b", max_batch=64, num_pages=8192)
    serial = lambda_sweep(fac, **_kw())
    par = parallel_sweep(fac, max_workers=3, **_kw())
    _records_equal(serial, par)


def test_parallel_with_warmup_matches_serial():
    fac = SimEngineSpec("llama31-8b", max_batch=64, num_pages=8192)
    kw = _kw()
    kw["warmup_per_point"] = lambda lam: 10
    serial = lambda_sweep(fac, **kw)
    par = parallel_sweep(fac, **kw)
    _records_equal(serial, par)


def test_unpicklable_factory_falls_back_to_serial_with_warning():
    """A closure factory cannot cross the process boundary; the sweep must
    degrade to the serial path with identical results — and say so
    (ISSUE 2 satellite: the fallback warns instead of hiding)."""
    from repro.configs import get_config
    from repro.simulate import StepTimeModel, V5E

    def closure_factory():
        cfg = get_config("llama31-8b")
        return Engine(EngineConfig(max_batch=64, page_size=16,
                                   num_pages=8192, max_pages_per_seq=64),
                      SimExecutor(cfg, StepTimeModel(cfg, V5E)))

    serial = lambda_sweep(closure_factory, **_kw())
    with pytest.warns(RuntimeWarning, match="falling back to the serial"):
        par = parallel_sweep(closure_factory, **_kw())
    _records_equal(serial, par)


def test_sim_engine_spec_is_picklable_and_builds():
    import pickle

    fac = SimEngineSpec("qwen3-30b-a3b", hw="tpu-v5p", quant="int8",
                        n_chips=2, fast_forward=False)
    fac2 = pickle.loads(pickle.dumps(fac))
    eng = fac2()
    assert isinstance(eng, Engine)
    assert eng.cfg.fast_forward is False
    assert eng.ex.model.quant == "int8" and eng.ex.model.n_chips == 2
