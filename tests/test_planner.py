"""Capacity planner (ISSUE 5 tentpole): fitted deployment curves,
replica/mix optimization, SLO rejection — unit tests on synthetic
curves plus golden tests pinned to the committed `paper_atlas` /
`paper_crosshw` stores (no engines run)."""
import json
import math

import pytest

from repro.core import c_eff as _c_eff
from repro.core.crossover import interp_c_eff
from repro.core.records import RunRecord
from repro.core.slo import SLOTarget
from repro.experiments.analyze import crossover_summary, load_store_records
from repro.planner import (DeploymentCurve, enumerate_options, fit_curves,
                           greedy_mix, plan_capacity, planner_tables,
                           rank_options, render_plans, slo_feasible_cap)


def _rec(lam, tps, price=1.2, theta_max=1000.0, ttft_p90=100.0, **kw):
    base = dict(config="t", model="m", hw="hw-a", n_chips=1, quant="bf16",
                engine="sim", io_shape="chat", n_requests=10, n_completed=10,
                window_s=10.0, prompt_tps=0.0, ttft_p50_ms=ttft_p90 / 2,
                ttft_p90_ms=ttft_p90, ttft_p99_ms=ttft_p90 * 2,
                tpot_p50_ms=10.0, tpot_p99_ms=20.0, e2e_p50_ms=1000.0,
                e2e_p99_ms=2000.0, mean_inflight=lam, price_per_hr=price,
                c_eff=_c_eff(price, tps), theta_max=theta_max)
    base.update(kw)
    return RunRecord(lam=lam, tps=tps, **base)


def _ladder(hw="hw-a", price=1.2, theta=1000.0, lams=(1, 5, 10, 50, 100),
            halfsat=10.0, **kw):
    """A monotone synthetic ladder: tps saturating in lam (half throughput
    at lam=halfsat), TTFT rising with lam."""
    out = []
    for lam in lams:
        tps = theta * lam / (lam + halfsat)
        out.append(_rec(lam, tps, price=price, theta_max=theta, hw=hw,
                        ttft_p90=20.0 * (1 + lam), **kw))
    return out


# ---- curve fitting ----------------------------------------------------


def test_fit_curves_groups_and_flags():
    recs = _ladder() + _ladder(hw="hw-b", price=0.6, theta=400.0)
    curves = fit_curves(recs)
    assert [c.hw for c in curves] == ["hw-a", "hw-b"]
    a = curves[0]
    assert a.lam_min == 1 and a.lam_max == 100 and not a.dense
    assert a.monotone_c_eff
    assert a.extrapolated(0.5) and a.extrapolated(101) \
        and not a.extrapolated(50)
    # knot hits are exact (the hardened primitive), including C_eff
    for r in recs[:5]:
        assert a.c_eff(r.lam) == r.c_eff
        assert a.util(r.lam) == r.util
    # between knots the curve is the store interpolation, bit for bit
    for lam in (2.3, 7.7, 60.0):
        assert a.c_eff(lam) == interp_c_eff(recs[:5], lam)


def test_fit_curves_filters_by_model_and_io_shape():
    recs = _ladder() + _ladder(model="m2") + _ladder(io_shape="rag")
    assert len(fit_curves(recs)) == 3
    assert len(fit_curves(recs, model="m2")) == 1
    only = fit_curves(recs, io_shape="chat", model="m")
    assert len(only) == 1 and only[0].io_shape == "chat"


def test_nonfinite_knots_dropped():
    recs = _ladder()
    recs[0] = _rec(1, 0.0, theta_max=1000.0)        # tps=0 -> c_eff=inf
    curve = fit_curves(recs)[0]
    assert len(curve.knots["c_eff"]) == 4           # inf knot dropped
    assert math.isfinite(curve.c_eff(1.0))


# ---- optimization invariants -----------------------------------------


def test_collapsed_top_knot_caps_demonstrated_span():
    """A ladder whose top cell collapsed (c_eff = inf) has demonstrated
    nothing at that load: the dropped knot must cap lam_max, so the load
    is rejected as beyond-range instead of silently priced at the
    clamped last-finite knot."""
    recs = _ladder()
    recs[-1] = _rec(100, 0.0, theta_max=1000.0)      # collapse at lam=100
    curve = fit_curves(recs)[0]
    assert curve.lam_max == 50 and curve.extrapolated(100)
    # the dropped inf knot must not flip the monotonicity flag either
    assert curve.monotone_c_eff
    ranked, rejected = rank_options(
        enumerate_options([curve], 100.0, max_replicas=1))
    assert ranked == []
    assert "beyond the measured range" in rejected[0].why_infeasible
    # the SLO cap inherits the tightened ceiling too
    assert slo_feasible_cap(curve, None) == 50


def test_replica_split_never_cheaper_on_monotone_curve():
    """R replicas at lambda cost one replica's C_eff at lambda/R, which a
    concave-down (monotone-decreasing C_eff) curve prices >= the single
    replica at lambda — splits buy latency headroom, not cheaper tokens."""
    curves = fit_curves(_ladder())
    options = enumerate_options(curves, 50.0, max_replicas=8)
    ranked, _ = rank_options(options)
    single = next(o for o in ranked if o.replicas == 1)
    assert ranked[0] == single
    for o in ranked:
        if o.replicas > 1:
            assert o.c_eff >= single.c_eff
            assert o.fleet_price_per_hr > single.fleet_price_per_hr
            # Little's law: per-replica concurrency falls with the split
            assert o.mean_inflight <= single.mean_inflight


def test_beyond_measured_range_rejected_not_priced():
    curves = fit_curves(_ladder())                   # measured to lam=100
    options = enumerate_options(curves, 900.0, max_replicas=4)
    ranked, rejected = rank_options(options)
    assert ranked == []                             # 900/4 = 225 > 100
    assert all("beyond the measured range" in o.why_infeasible
               for o in rejected)
    # ... but a split that brings lambda/R inside the range is feasible
    ranked, _ = rank_options(
        enumerate_options(curves, 900.0, max_replicas=16))
    assert ranked and all(o.lam_per_replica <= 100 for o in ranked)


def test_slo_infeasible_rejected_not_priced():
    curves = fit_curves(_ladder())                   # TTFT p90 >= 40ms
    slo = SLOTarget(ttft_p90_ms=1.0)                 # impossible
    plans = plan_capacity(curves, 10.0, slo)
    assert len(plans) == 1 and not plans[0].feasible
    assert plans[0].best is None
    assert all("violates SLO" in o.why_infeasible
               for o in plans[0].rejected)
    # a split CAN rescue a merely-tight SLO: TTFT falls with lambda/R
    slo = SLOTarget(ttft_p90_ms=500.0)               # needs lam/R <= 24
    ranked, _ = rank_options(
        enumerate_options(curves, 100.0, slo, max_replicas=8))
    assert ranked and all(o.replicas >= 5 for o in ranked)
    assert all(o.ttft_p90_ms <= 500.0 for o in ranked)


def test_dead_footprint_rejected_not_ranked():
    """A footprint whose every cell priced to inf (nothing completed) has
    no finite-cost knots; it must be rejected with a reason — never
    ranked as a nan-cost 'best' just because its group key sorts first."""
    dead = [_rec(lam, 0.0, hw="hw-0dead", theta_max=1000.0)
            for lam in (1, 5, 10, 50, 100)]
    curves = fit_curves(_ladder() + dead)
    assert curves[0].hw == "hw-0dead"               # sorts before hw-a
    ranked, rejected = rank_options(enumerate_options(curves, 10.0))
    assert ranked and all(o.hw == "hw-a" for o in ranked)
    assert any("no finite-cost" in o.why_infeasible for o in rejected)
    plans = plan_capacity(curves, 10.0)
    assert plans[0].best.hw == "hw-a"


def test_io_shapes_never_compete_in_one_ranking():
    recs = _ladder() + _ladder(io_shape="rag", hw="hw-b")
    plans = plan_capacity(fit_curves(recs), 10.0)
    assert [(p.model, p.io_shape) for p in plans] == \
        [("m", "chat"), ("m", "rag")]
    assert all(o.hw == "hw-a" for o in plans[0].ranked)
    assert all(o.hw == "hw-b" for o in plans[1].ranked)


def test_slo_feasible_cap_bisection():
    curve = fit_curves(_ladder())[0]                 # TTFT = 20*(1+lam)
    assert slo_feasible_cap(curve, None) == curve.lam_max
    cap = slo_feasible_cap(curve, SLOTarget(ttft_p90_ms=500.0))
    assert curve.interp("ttft_p90_ms", cap) == pytest.approx(500.0, rel=1e-6)
    assert slo_feasible_cap(curve, SLOTarget(ttft_p90_ms=1.0)) == 0.0


def test_greedy_mix_prefers_bulk_carrier_plus_cheap_tail():
    """Mélange shape: the premium part is cheaper per token at its cap, the
    small part prices the remainder cheaper than a second premium replica
    would at low utilization."""
    # premium needs concurrency to shine (half throughput at lam=40);
    # the small part saturates fast (half throughput at lam=2)
    premium = _ladder(hw="hw-big", price=4.0, theta=4000.0, halfsat=40.0,
                      lams=(1, 5, 10, 50, 100))
    small = _ladder(hw="hw-small", price=0.5, theta=300.0, halfsat=2.0,
                    lams=(1, 5, 10, 50, 100))
    curves = fit_curves(premium + small)
    assert curves[0].c_eff(100) < curves[1].c_eff(100)   # big wins the bulk
    assert curves[1].c_eff(10) < curves[0].c_eff(10)     # small wins the tail
    mix = greedy_mix(curves, 110.0)
    assert mix is not None
    assert [a.hw for a in mix.allocations] == ["hw-big", "hw-small"]
    assert mix.allocations[0].lam == 100.0          # bulk at the big cap
    assert mix.allocations[1].lam == pytest.approx(10.0)
    assert mix.fleet_price_per_hr == 4.5
    # the blend must beat forcing the tail onto a second premium replica
    two_big = 2 * 4.0 * 1e6 / (3600.0 * (curves[0].tps(100) +
                                         curves[0].tps(10)))
    assert mix.c_eff < two_big
    # nothing can serve an SLO nothing meets
    assert greedy_mix(curves, 110.0, SLOTarget(ttft_p90_ms=1.0)) is None


def test_planner_tables_payload_is_strict_json():
    recs = _ladder() + _ladder(hw="hw-b", price=0.6, theta=400.0)
    recs[0] = _rec(1, 0.0, theta_max=1000.0)        # force an inf somewhere
    payload = planner_tables(recs, lams=(1.0, 50.0, 1e9))
    text = json.dumps(payload, allow_nan=False)     # raises on inf/nan
    assert json.loads(text) == payload
    by_lam = {}
    for row in payload["recommendations"]:
        by_lam.setdefault(row["lam"], []).append(row)
    assert by_lam[1e9][0]["feasible"] is False      # rejected, not priced
    assert by_lam[50.0][0]["feasible"] is True


# ---- golden tests against the committed stores ------------------------


def _atlas_records():
    recs = load_store_records("paper_atlas")
    if len(recs) < 450:
        pytest.skip("paper_atlas store not populated")
    return recs


GOLDEN_ATLAS = {
    # lam -> model -> (hw, quant, n_chips, replicas): idle loads land on
    # cheap/premium per-token winners, saturation on the native-fp8 v6e
    1.0: {"llama31-8b": ("tpu-v5e", "fp8", 2, 1),
          "mixtral-8x7b": ("tpu-v5p", "fp8", 2, 1),
          "qwen3-30b-a3b": ("tpu-v5p", "bf16", 1, 1)},
    10.0: {"llama31-8b": ("tpu-v5e", "bf16", 2, 1),
           "mixtral-8x7b": ("tpu-v5p", "fp8", 2, 1),
           "qwen3-30b-a3b": ("tpu-v5p", "fp8", 1, 1)},
    200.0: {"llama31-8b": ("tpu-v6e", "fp8", 1, 1),
            "mixtral-8x7b": ("tpu-v6e", "fp8", 4, 1),
            "qwen3-30b-a3b": ("tpu-v6e", "fp8", 2, 1)},
}


def test_golden_recommendations_on_committed_atlas():
    curves = fit_curves(_atlas_records())
    assert len(curves) == 18 and all(c.dense for c in curves)
    for lam, by_model in GOLDEN_ATLAS.items():
        plans = plan_capacity(curves, lam)
        assert [p.model for p in plans] == sorted(by_model)
        for plan in plans:
            best = plan.best
            assert (best.hw, best.quant, best.n_chips, best.replicas) == \
                by_model[plan.model], (lam, plan.model)
            assert best.feasible and not best.extrapolated


def test_top_row_c_eff_matches_store_interpolation_exactly():
    """Acceptance (ISSUE 5): the ranked table's top single-replica row
    reprices the store's interpolated curve within 1e-9."""
    recs = _atlas_records()
    by_group = {}
    for r in recs:
        by_group.setdefault((r.model, r.hw, r.quant, r.n_chips), []).append(r)
    for lam in (1.0, 5.0, 42.0, 200.0):
        for plan in plan_capacity(fit_curves(recs), lam):
            top = next(o for o in plan.ranked if o.replicas == 1)
            want = interp_c_eff(
                by_group[(plan.model, top.hw, top.quant, top.n_chips)], lam)
            assert abs(top.c_eff - want) <= 1e-9


def test_crossover_verdicts_agree_with_analyze():
    """Acceptance (ISSUE 5): the planner's per-tier verdicts are the same
    rows `analyze.crossover_summary` derives from the same store."""
    recs = _atlas_records()
    summary = {(r["model"], r["hw"], r["quant"]): r["tiers"]
               for r in crossover_summary(recs)}
    for plan in plan_capacity(fit_curves(recs), 5.0):
        best = plan.best
        assert plan.crossover == \
            summary[(plan.model, best.hw, best.quant)]


def test_replica_monotonicity_on_committed_atlas():
    """R*C_eff-at-lambda/R economics: no replica split beats the best
    single-replica option anywhere on the committed concave-down curves."""
    curves = fit_curves(_atlas_records())
    assert all(c.monotone_c_eff for c in curves)
    for lam in (10.0, 80.0, 200.0):
        for plan in plan_capacity(curves, lam):
            best_single = min(o.c_eff for o in plan.ranked
                              if o.replicas == 1)
            for o in plan.ranked:
                if o.replicas > 1:
                    assert o.c_eff >= best_single - 1e-12


def test_slo_bound_plan_on_committed_atlas():
    """A tight-but-achievable TTFT target at saturation load forces
    replica splits; an impossible one is rejected, never priced."""
    curves = fit_curves(_atlas_records(),  model="llama31-8b")
    plans = plan_capacity(curves, 200.0, SLOTarget(ttft_p90_ms=2000.0))
    assert len(plans) == 1 and plans[0].feasible
    assert all(o.ttft_p90_ms <= 2000.0 for o in plans[0].ranked)
    assert all(o.replicas > 1 for o in plans[0].ranked)

    plans = plan_capacity(curves, 200.0, SLOTarget(ttft_p90_ms=0.001))
    assert not plans[0].feasible and plans[0].best is None
    text = render_plans(plans, title="t")
    assert "INFEASIBLE" in text and "violates SLO" in text


def test_committed_crosshw_sparse_ladders_accepted_with_flags():
    recs = load_store_records("paper_crosshw")
    if len(recs) < 126:
        pytest.skip("paper_crosshw store not populated")
    curves = fit_curves(recs)
    assert len(curves) == 18 and not any(c.dense for c in curves)
    plans = plan_capacity(curves, 5.0)
    assert all(p.feasible for p in plans)
    assert all(not o.dense for p in plans for o in p.ranked)
    # below the measured ladder the planner flags, not invents
    plans = plan_capacity(curves, 0.25)
    for p in plans:
        assert p.best.extrapolated


# ---- CLI --------------------------------------------------------------


def test_cli_plan_and_json(tmp_path, capsys):
    from repro.planner.__main__ import main
    _atlas_records()
    out_json = tmp_path / "plan.json"
    main(["--plan", "paper_atlas", "--lam", "5", "--model", "llama31-8b",
          "--json", str(out_json)])
    text = capsys.readouterr().out
    assert "capacity plan: paper_atlas" in text
    assert "§6.4 gate acknowledged" in text
    blob = json.loads(out_json.read_text())
    assert len(blob) == 1 and blob[0]["model"] == "llama31-8b"
    assert blob[0]["feasible"] and blob[0]["best"]["replicas"] == 1


def test_cli_infeasible_exits_3(capsys):
    from repro.planner.__main__ import main
    _atlas_records()
    with pytest.raises(SystemExit) as exc:
        main(["--plan", "paper_atlas", "--lam", "99999"])
    assert exc.value.code == 3
    assert "INFEASIBLE" in capsys.readouterr().out


# ---- ISSUE 10 satellites ----------------------------------------------


def test_greedy_mix_rejects_mixed_model_curves():
    # one allocation serves one (model, io_shape): a mixed list used to
    # be silently labeled with curves[0].model
    recs = _ladder() + _ladder(model="m2", hw="hw-b")
    curves = fit_curves(recs)
    with pytest.raises(ValueError, match="heterogeneous"):
        greedy_mix(curves, 5.0)
    recs = _ladder() + _ladder(io_shape="rag", hw="hw-b")
    with pytest.raises(ValueError, match="heterogeneous"):
        greedy_mix(fit_curves(recs), 5.0)
    with pytest.raises(ValueError, match="empty"):
        greedy_mix([], 5.0)


def test_availability_target_validates_inputs():
    from repro.planner import AvailabilityTarget, spares_needed
    # nines >= 1.0 can never be certified by finitely many spares (the
    # binomial tail is < 1 for any p < 1) — used to loop and return
    # nonsense instead of raising
    for bad in (1.0, 1.5, 0.0, -0.1):
        with pytest.raises(ValueError, match="availability"):
            AvailabilityTarget(availability=bad)
    for bad in (0.0, -0.5, 1.01):
        with pytest.raises(ValueError, match="replica_availability"):
            AvailabilityTarget(replica_availability=bad)
    # valid targets still work end to end
    t = AvailabilityTarget(availability=0.999,
                           replica_availability=0.99)
    s = spares_needed(2, t)
    assert s is not None and s >= 1
    # perfect replicas need no spares
    assert spares_needed(3, AvailabilityTarget(
        availability=0.999, replica_availability=1.0)) == 0


def test_slo_feasible_cap_unconstrained_and_knot_edge():
    curve = fit_curves(_ladder())[0]
    # no SLO -> the full measured range
    assert slo_feasible_cap(curve, None) == curve.lam_max
    # SLO bound equal to the TTFT at an interior knot: the bisection
    # must land on that knot (ttft = 20*(1+lam) -> 1020ms at lam=50)
    slo = SLOTarget(ttft_p90_ms=1020.0)
    cap = slo_feasible_cap(curve, slo)
    assert cap == pytest.approx(50.0, rel=1e-6)
    # SLO at the lam_max knot exactly -> cap is lam_max, no bisection
    assert slo_feasible_cap(
        curve, SLOTarget(ttft_p90_ms=20.0 * 101)) == curve.lam_max


def test_slo_feasible_cap_infeasible_at_minimum():
    curve = fit_curves(_ladder())[0]     # ttft(lam_min=1) = 40ms
    assert slo_feasible_cap(curve, SLOTarget(ttft_p90_ms=10.0)) == 0.0
    # and greedy_mix then refuses the whole group
    assert greedy_mix([curve], 5.0, SLOTarget(ttft_p90_ms=10.0)) is None


def test_slo_feasible_cap_flat_segment_curve():
    # constant TTFT across the ladder: the cap is all-or-nothing
    recs = [_rec(lam, 1000.0 * lam / (lam + 10.0), ttft_p90=100.0)
            for lam in (1, 5, 10, 50, 100)]
    curve = fit_curves(recs)[0]
    assert slo_feasible_cap(curve, SLOTarget(ttft_p90_ms=100.0)) \
        == curve.lam_max
    assert slo_feasible_cap(curve, SLOTarget(ttft_p90_ms=99.9)) == 0.0
