"""Training substrate: learning, 8-bit parity, compression, checkpoints."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced
from repro.models import init_params
from repro.training import (CheckpointManager, SyntheticDataLoader, adamw,
                            adamw8bit, build_train_step, compress_int8,
                            decompress_int8, error_feedback_update)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:                                       # pragma: no cover
    HAVE_HYP = False


def _train(opt, steps=25, accum=1, seed=0):
    cfg = reduced("llama31-8b", d_model=128, ff=256, layers=2)
    params = init_params(jax.random.PRNGKey(seed), cfg)
    st_ = opt.init(params)
    step = jax.jit(build_train_step(cfg, opt, remat=True,
                                    accum_steps=accum))
    dl = SyntheticDataLoader(cfg.vocab_size, 8, 32, seed=1)
    losses = []
    for i in range(steps):
        params, st_, stats = step(params, st_, dl.batch_at(i))
        losses.append(float(stats["loss"]))
    return losses


def test_adamw_learns():
    losses = _train(adamw(3e-3))
    assert losses[-1] < losses[0] - 0.5


def test_adamw8bit_matches_fp32_closely():
    l32 = _train(adamw(3e-3))
    l8 = _train(adamw8bit(3e-3))
    assert l8[-1] < l8[0] - 0.5
    assert abs(l8[-1] - l32[-1]) < 0.3      # 8-bit moments track fp32


def test_grad_accumulation_equivalence():
    """accum_steps=4 over batch 8 ~= accum_steps=1 (same data, same seed)."""
    l1 = _train(adamw(1e-3), steps=8, accum=1)
    l4 = _train(adamw(1e-3), steps=8, accum=4)
    assert abs(l1[-1] - l4[-1]) < 0.15, (l1[-1], l4[-1])


def test_compression_error_feedback():
    """EF accumulates residuals: avg dequantized stream -> true gradient."""
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(64, 64)) * 1e-3, jnp.float32)
    err = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    n = 50
    for _ in range(n):
        q, scale, err = error_feedback_update(g, err)
        acc = acc + decompress_int8(q, scale)
    rel = float(jnp.linalg.norm(acc / n - g) / jnp.linalg.norm(g))
    assert rel < 0.01, rel      # without EF this residual bias persists


def test_compression_roundtrip_bound():
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.normal(size=(128,)), jnp.float32)
    q, scale = compress_int8(g)
    err = jnp.abs(decompress_int8(q, scale) - g)
    assert float(jnp.max(err)) <= float(scale) * 0.5 + 1e-7


def test_checkpoint_restart_continues_training():
    """Kill/restart semantics: resume from step k reproduces the run."""
    cfg = reduced("llama31-8b", d_model=64, ff=128, layers=2)
    opt = adamw(1e-3)
    dl = SyntheticDataLoader(cfg.vocab_size, 4, 16, seed=2)
    step = jax.jit(build_train_step(cfg, opt, remat=False))

    def run(params, st_, lo, hi):
        for i in range(lo, hi):
            params, st_, stats = step(params, st_, dl.batch_at(i))
        return params, st_, float(stats["loss"])

    p0 = init_params(jax.random.PRNGKey(0), cfg)
    s0 = opt.init(p0)
    # straight run 0..10
    p_a, s_a, loss_a = run(p0, s0, 0, 10)
    # run 0..5, checkpoint, "crash", restore, run 5..10
    p_b, s_b, _ = run(p0, s0, 0, 5)
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d, async_save=False)
        cm.save(5, {"params": p_b, "opt": s_b})
        stepn, tree, _ = cm.restore_latest({"params": p_b, "opt": s_b})
        assert stepn == 5
        p_c, s_c, loss_c = run(tree["params"], tree["opt"], 5, 10)
    assert abs(loss_a - loss_c) < 1e-2, (loss_a, loss_c)


if HAVE_HYP:
    @given(st.integers(1, 4096), st.integers(0, 2**31 - 1))
    @settings(max_examples=40, deadline=None)
    def test_q8_codec_roundtrip_property(n, seed):
        from repro.training.optimizer import _q8, _dq8
        r = np.random.default_rng(seed)
        x = jnp.asarray(r.normal(size=(n,)) ** 3, jnp.float32)  # heavy tail
        q, s = _q8(x, 256)
        back = _dq8(q, s, 256)
        assert back.shape == x.shape
        # sqrt codec: error within ~2*absmax/127 * sqrt scale per block
        absmax = float(jnp.max(jnp.abs(x)))
        assert float(jnp.max(jnp.abs(back - x))) <= absmax * 0.02 + 1e-9
