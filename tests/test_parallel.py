"""Sharding-rule resolution + multi-device features (via subprocess with
forced host devices, since the test process owns a single CPU device)."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import (DEFAULT, logical_spec, param_spec_tree,
                                     shardctx, zero1_spec)

REPO = Path(__file__).resolve().parents[1]


def _mesh22():
    # a fake mesh over 1 device can't exist; use abstract reasoning via the
    # subprocess for real meshes and pure-logic checks here with mesh=None.
    return None


def test_logical_spec_no_mesh_is_empty():
    assert logical_spec((4, 8), ("batch", "ff")) == P()


def _run_sub(code: str) -> str:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=str(REPO / "src"))
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    return out.stdout


def test_logical_spec_divisibility_drop():
    out = _run_sub("""
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.parallel.sharding import logical_spec
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        # ff divisible -> sharded; heads=6 not divisible by 4 -> dropped
        assert logical_spec((8, 16), (None, "ff"), mesh) == P(None, "model")
        assert logical_spec((8, 6), (None, "qheads"), mesh) == P()
        # batch takes both axes' product when divisible
        assert logical_spec((8, 4), ("batch", None), mesh) == P("data")
        # axis used at most once
        s = logical_spec((4, 16, 16), ("batch", "ff", "vocab"), mesh)
        assert s == P("data", "model")
        print("ok")
    """)
    assert "ok" in out


def test_zero1_and_param_specs():
    out = _run_sub("""
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.parallel.sharding import param_spec_tree, zero1_spec
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        import jax.numpy as jnp
        params = {"blocks": [{"attn": {"wq": jnp.zeros((8, 16))},
                              "mlp": {"up": jnp.zeros((8, 16)),
                                      "down": jnp.zeros((16, 8))}}],
                  "embed": jnp.zeros((32, 8)), "lm_head": jnp.zeros((8, 32))}
        specs = param_spec_tree(params, mesh)
        assert specs["blocks"][0]["mlp"]["up"] == P(None, "model")
        assert specs["blocks"][0]["mlp"]["down"] == P("model")
        assert specs["lm_head"] == P(None, "model")
        z = zero1_spec(P(None, "model"), (8, 16), mesh)
        assert z == P("data", "model")
        print("ok")
    """)
    assert "ok" in out


def test_pipeline_parallel_matches_sequential():
    out = _run_sub("""
        import jax, jax.numpy as jnp
        from repro.parallel.pipeline import pipeline_apply
        mesh = jax.make_mesh((4,), ("stage",))
        S, NM, MB, D = 4, 8, 2, 16
        key = jax.random.PRNGKey(0)
        Ws = jax.random.normal(key, (S, D, D)) * 0.3
        params = {"w": Ws}
        def stage_fn(p, x):
            return jnp.tanh(x @ p["w"])
        x = jax.random.normal(jax.random.PRNGKey(1), (NM, MB, D))
        got = pipeline_apply(stage_fn, params, x, mesh)
        want = x
        for s in range(S):
            want = jnp.tanh(want @ Ws[s])
        import numpy as np
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
        print("ok")
    """)
    assert "ok" in out


def test_flash_decoding_partial_softmax_combine():
    out = _run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.models.attention import (decode_attention,
                                            decode_attention_partial)
        from repro.parallel.collectives import combine_partial_softmax
        mesh = jax.make_mesh((8,), ("kv",))
        B, Hq, Hkv, S, D = 2, 8, 2, 64, 32
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, 1, Hq, D), jnp.float32)
        kc = jax.random.normal(ks[1], (B, Hkv, S, D), jnp.float32)
        vc = jax.random.normal(ks[2], (B, Hkv, S, D), jnp.float32)
        cache_len = jnp.array([37, 64], jnp.int32)
        ref = decode_attention(q, kc, vc, cache_len)

        def shard_fn(q, kc, vc, cache_len):
            i = jax.lax.axis_index("kv")
            s_loc = kc.shape[2]
            pos = i * s_loc + jnp.arange(s_loc)
            valid = pos[None, :] < cache_len[:, None]
            num, den, m = decode_attention_partial(q, kc, vc, valid)
            out = combine_partial_softmax(num, den, m, "kv")
            return out.astype(q.dtype)

        f = shard_map(shard_fn, mesh=mesh,
                      in_specs=(P(), P(None, None, "kv"),
                                P(None, None, "kv"), P()),
                      out_specs=P(), check_rep=False)
        got = f(q, kc, vc, cache_len)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)
        print("ok")
    """)
    assert "ok" in out
