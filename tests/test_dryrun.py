"""Multi-pod dry-run smoke: lower+compile representative cells in a
subprocess (the 512 placeholder devices must be installed before jax
initializes, which has already happened in the pytest process)."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]


def _run_cells(code: str, timeout=1200) -> str:
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    env.pop("XLA_FLAGS", None)      # dryrun.py sets its own
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=timeout)
    assert out.returncode == 0, out.stdout[-3000:] + out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_dryrun_single_and_multipod_cells():
    out = _run_cells("""
from repro.launch.dryrun import run_cell
# smallest assigned arch: both meshes; decode exercises the serve path
r1 = run_cell("whisper-base", "decode_32k", multi_pod=False, save=False)
r2 = run_cell("whisper-base", "decode_32k", multi_pod=True, save=False)
r3 = run_cell("xlstm-350m", "train_4k", multi_pod=True, save=False)
for r in (r1, r2, r3):
    assert r["status"] == "ok"
    assert r["roofline"]["compute_s"] > 0
    assert r["cost_analysis"].get("flops", 0) > 0
assert r1["n_devices"] == 256 and r2["n_devices"] == 512
print("DRYRUN_OK")
""")
    assert "DRYRUN_OK" in out


def test_dryrun_results_recorded():
    """The committed dry-run sweep must cover every assigned cell."""
    res = REPO / "results" / "dryrun"
    if not res.exists() or not list(res.glob("*.json")):
        pytest.skip("dry-run sweep not yet executed")
    from repro.configs import ASSIGNED_ARCHS, get_config
    missing = []
    for arch in ASSIGNED_ARCHS:
        for shape in get_config(arch).shapes():
            tag = f"{arch}_{shape.name}_16x16_bf16.json"
            if not (res / tag).exists():
                missing.append(tag)
    assert not missing, f"dry-run cells missing: {missing}"
    # recorded cells are well-formed
    sample = json.loads(next(iter(res.glob("*.json"))).read_text())
    assert {"roofline", "cost_analysis", "collective_bytes"} <= set(sample)
