"""Cross-hardware experiment plans + spread-compression analysis (ISSUE 3).

Three layers: structural conformance of the multi-hardware plans (per-
(arch, hw) TP overrides, per-hw quant filters, price book), the paper's
§5.9/§7 claims asserted against the committed `paper_crosshw` store
(126 cells, three hardware generations, one store — fast, no engines
run), and a live quick-protocol replication marked `slow` for the
non-blocking CI job.
"""
import json

import pytest

from repro.core.pricing import chip_hour_price
from repro.experiments import ExperimentStore, GridSpec, PlanRunner, get_plan
from repro.experiments.analyze import (crosshw_tables, fp8_inversion,
                                       load_store_records, penalty_curves,
                                       report, spread_compression)
from repro.experiments.store import DEFAULT_ROOT


# ---- plan structure ---------------------------------------------------


def test_paper_crosshw_plan_structure():
    plan = get_plan("paper_crosshw")
    assert len(plan) == 126          # 3 models x 3 hw x 2 quants x 7-ladder
    assert {c.hw for c in plan.cells} == {"tpu-v5e", "tpu-v5p", "tpu-v6e"}
    assert {c.quant for c in plan.cells} == {"bf16", "fp8"}
    assert len({c.cell_id for c in plan.cells}) == 126
    # the per-(arch, hw) TP override deploys the same model at
    # hardware-fitting footprints
    chips = {(c.arch, c.hw): c.n_chips for c in plan.cells}
    assert chips[("mixtral-8x7b", "tpu-v5e")] == 8
    assert chips[("mixtral-8x7b", "tpu-v5p")] == 2
    assert chips[("mixtral-8x7b", "tpu-v6e")] == 4
    assert chips[("llama31-8b", "tpu-v5e")] == 2
    assert chips[("llama31-8b", "tpu-v5p")] == 1
    # price book follows the per-hw chip counts
    for c in plan.cells:
        assert c.price_per_hr == chip_hour_price(c.hw, c.n_chips)


def test_mini_crosshw_plan_structure():
    plan = get_plan("mini_crosshw")
    assert len(plan) == 16           # 2 models x 2 hw x 2 quants x 2 lams
    assert {c.hw for c in plan.cells} == {"tpu-v5e", "tpu-v6e"}
    chips = {(c.arch, c.hw): c.n_chips for c in plan.cells}
    assert chips[("qwen3-30b-a3b", "tpu-v5e")] == 2
    assert chips[("qwen3-30b-a3b", "tpu-v6e")] == 1     # default


def test_chips_for_resolution_order():
    spec = GridSpec(name="g", archs=("a",), hws=("h1", "h2"), n_chips=3,
                    n_chips_by_arch=(("a", 5),),
                    n_chips_by_arch_hw=(("a", "h1", 7),))
    assert spec.chips_for("a", "h1") == 7      # (arch, hw) wins
    assert spec.chips_for("a", "h2") == 5      # falls back to per-arch
    assert spec.chips_for("b", "h1") == 3      # then the grid default
    assert spec.chips_for("a") == 5            # hw-less legacy lookup


def test_quants_by_hw_filters_cells():
    plan = GridSpec(
        name="g", archs=("llama31-8b",), hws=("tpu-v5e", "tpu-v6e"),
        quants=("bf16", "fp8"), ladder=(5,), protocol="smoke",
        quants_by_hw=(("tpu-v5e", ("bf16",)),)).expand()
    assert {(c.hw, c.quant) for c in plan.cells} == {
        ("tpu-v5e", "bf16"), ("tpu-v6e", "bf16"), ("tpu-v6e", "fp8")}


# ---- the committed paper_crosshw store --------------------------------


def _store_records():
    recs = load_store_records("paper_crosshw")
    if len(recs) < 126:
        pytest.skip("paper_crosshw store not populated")
    return recs


def test_committed_store_spread_band_and_fp8_inversion():
    """Acceptance (ISSUE 3): the sim-tier load-driven spread lands in the
    paper's plausible band (>5x) on EVERY hardware generation, and the
    dense-FP8 inversion reproduces on the non-native-fp8 parts only."""
    recs = _store_records()
    for row in penalty_curves(recs):
        assert 5.0 < row["spread"] < 100.0, \
            (row["model"], row["hw"], row["quant"], row["spread"])
    inv = {(r["hw"], r["model"]): r for r in fp8_inversion(recs)}
    # compute-bound dense model: fp8 pays the dequant penalty on the
    # emulating parts (paper's hardware-conditional caveat) ...
    assert inv[("tpu-v5e", "llama31-8b")]["inverted"]
    assert inv[("tpu-v5p", "llama31-8b")]["inverted"]
    # ... and gains on the native-fp8 part
    assert not inv[("tpu-v6e", "llama31-8b")]["inverted"]
    assert inv[("tpu-v6e", "llama31-8b")]["tps_uplift"] > 1.0
    # the memory-bound ultra-sparse MoE keeps its HBM win everywhere
    for hw in ("tpu-v5e", "tpu-v5p", "tpu-v6e"):
        assert not inv[(hw, "qwen3-30b-a3b")]["inverted"]
    # no row may break the native-fp8 conditioning
    assert all(r["consistent"] for r in inv.values())


def test_committed_store_spread_compression_table():
    recs = _store_records()
    table = spread_compression(recs)
    assert len(table) == 6                      # 3 models x 2 quants
    for row in table:
        hws = [h["hw"] for h in row["per_hw"]]
        assert hws == sorted(hws) and len(hws) == 3
        assert row["compression"] >= 1.0
        assert row["widest_hw"] in hws and row["narrowest_hw"] in hws
        for h in row["per_hw"]:
            assert 0 < h["c_min"] < h["c_max"]
    # the report renders the cross-hardware sections for a multi-hw store
    text = report(recs, title="paper_crosshw")
    assert "spread compression" in text
    assert "conditioned on native fp8" in text


def test_committed_analysis_json_matches_fresh_derivation():
    """`--analyze-json` artifact is a pure function of the store."""
    recs = _store_records()
    path = DEFAULT_ROOT / "paper_crosshw" / "analysis.json"
    if not path.exists():
        pytest.skip("analysis.json not committed")
    blob = json.loads(path.read_text())
    fresh = json.loads(json.dumps(crosshw_tables(recs)))
    assert blob == fresh


# ---- live replication (non-blocking CI job) ---------------------------


@pytest.mark.slow
def test_live_crosshw_matrix_reproduces_spread_band(tmp_path):
    """The full cross-hardware analysis on a live quick-protocol run —
    no committed artifacts involved: idle-to-saturation spread >5x on
    both generations and the fp8 inversion conditioned on native fp8."""
    plan = GridSpec(
        name="live_crosshw",
        archs=("llama31-8b", "qwen3-30b-a3b"),
        hws=("tpu-v5e", "tpu-v6e"),
        quants=("bf16", "fp8"),
        ladder=(1, 25, 200),
        n_chips_by_arch_hw=(("qwen3-30b-a3b", "tpu-v5e", 2),),
        protocol="quick").expand()
    recs = PlanRunner(plan, store=ExperimentStore(plan.name, tmp_path)).run()
    assert len(recs) == len(plan.cells)
    for row in penalty_curves(recs):
        assert row["spread"] > 5.0, (row["model"], row["hw"], row["quant"])
    inv = {(r["hw"], r["model"]): r for r in fp8_inversion(recs)}
    assert inv[("tpu-v5e", "llama31-8b")]["inverted"]
    assert not inv[("tpu-v6e", "llama31-8b")]["inverted"]
    assert all(r["consistent"] for r in inv.values())
