"""Cross-hardware experiment plans + spread-compression analysis (ISSUE 3).

Three layers: structural conformance of the multi-hardware plans (per-
(arch, hw) TP overrides, per-hw quant filters, price book), the paper's
§5.9/§7 claims asserted against the committed `paper_crosshw` store
(126 cells, three hardware generations, one store — fast, no engines
run), and a live quick-protocol replication marked `slow` for the
non-blocking CI job.
"""
import json

import pytest

from repro.core.pricing import chip_hour_price
from repro.experiments import ExperimentStore, GridSpec, PlanRunner, get_plan
from repro.experiments.analyze import (crosshw_tables, fp8_inversion,
                                       fp8_uplift, load_store_records,
                                       penalty_atlas, penalty_curves,
                                       report, spread_compression)
from repro.experiments.plans import ATLAS_LADDER
from repro.experiments.store import DEFAULT_ROOT


# ---- plan structure ---------------------------------------------------


def test_paper_crosshw_plan_structure():
    plan = get_plan("paper_crosshw")
    assert len(plan) == 126          # 3 models x 3 hw x 2 quants x 7-ladder
    assert {c.hw for c in plan.cells} == {"tpu-v5e", "tpu-v5p", "tpu-v6e"}
    assert {c.quant for c in plan.cells} == {"bf16", "fp8"}
    assert len({c.cell_id for c in plan.cells}) == 126
    # the per-(arch, hw) TP override deploys the same model at
    # hardware-fitting footprints
    chips = {(c.arch, c.hw): c.n_chips for c in plan.cells}
    assert chips[("mixtral-8x7b", "tpu-v5e")] == 8
    assert chips[("mixtral-8x7b", "tpu-v5p")] == 2
    assert chips[("mixtral-8x7b", "tpu-v6e")] == 4
    assert chips[("llama31-8b", "tpu-v5e")] == 2
    assert chips[("llama31-8b", "tpu-v5p")] == 1
    # price book follows the per-hw chip counts
    for c in plan.cells:
        assert c.price_per_hr == chip_hour_price(c.hw, c.n_chips)


def test_mini_crosshw_plan_structure():
    plan = get_plan("mini_crosshw")
    assert len(plan) == 16           # 2 models x 2 hw x 2 quants x 2 lams
    assert {c.hw for c in plan.cells} == {"tpu-v5e", "tpu-v6e"}
    chips = {(c.arch, c.hw): c.n_chips for c in plan.cells}
    assert chips[("qwen3-30b-a3b", "tpu-v5e")] == 2
    assert chips[("qwen3-30b-a3b", "tpu-v6e")] == 1     # default


def test_chips_for_resolution_order():
    spec = GridSpec(name="g", archs=("a",), hws=("h1", "h2"), n_chips=3,
                    n_chips_by_arch=(("a", 5),),
                    n_chips_by_arch_hw=(("a", "h1", 7),))
    assert spec.chips_for("a", "h1") == 7      # (arch, hw) wins
    assert spec.chips_for("a", "h2") == 5      # falls back to per-arch
    assert spec.chips_for("b", "h1") == 3      # then the grid default
    assert spec.chips_for("a") == 5            # hw-less legacy lookup


def test_quants_by_hw_filters_cells():
    plan = GridSpec(
        name="g", archs=("llama31-8b",), hws=("tpu-v5e", "tpu-v6e"),
        quants=("bf16", "fp8"), ladder=(5,), protocol="smoke",
        quants_by_hw=(("tpu-v5e", ("bf16",)),)).expand()
    assert {(c.hw, c.quant) for c in plan.cells} == {
        ("tpu-v5e", "bf16"), ("tpu-v6e", "bf16"), ("tpu-v6e", "fp8")}


# ---- ISSUE 4 plans: dense atlas + int8 probe --------------------------


def test_paper_atlas_plan_structure():
    plan = get_plan("paper_atlas")
    assert len(plan) == 450      # 3 models x 3 hw x 2 quants x 25 lams
    assert len({c.cell_id for c in plan.cells}) == 450
    assert {c.lam for c in plan.cells} == set(ATLAS_LADDER)
    assert len(ATLAS_LADDER) == 25
    # log-spaced continuum: strictly increasing, ~1.25x steps, 1..200
    ratios = [b / a for a, b in zip(ATLAS_LADDER, ATLAS_LADDER[1:])]
    assert all(1.15 < r < 1.35 for r in ratios)
    assert ATLAS_LADDER[0] == 1.0 and ATLAS_LADDER[-1] == 200.0
    # same footprints + price book as the crosshw plan
    crosshw = {(c.arch, c.hw): c.n_chips
               for c in get_plan("paper_crosshw").cells}
    for c in plan.cells:
        assert c.n_chips == crosshw[(c.arch, c.hw)]
        assert c.price_per_hr == chip_hour_price(c.hw, c.n_chips)


def test_probe_int8_nonnative_plan_structure():
    """ROADMAP PR-3 follow-up: quants_by_hw exercised at paper scale —
    int8 on the fp8-emulating parts, fp8 kept on the native-fp8 part."""
    plan = get_plan("probe_int8_nonnative")
    assert len(plan) == 126      # 3 models x 3 hw x 2-of-3 quants x 7
    by_hw = {}
    for c in plan.cells:
        by_hw.setdefault(c.hw, set()).add(c.quant)
    assert by_hw == {"tpu-v5e": {"bf16", "int8"},
                     "tpu-v5p": {"bf16", "int8"},
                     "tpu-v6e": {"bf16", "fp8"}}


def test_committed_atlas_store_dense_curves():
    recs = load_store_records("paper_atlas")
    if len(recs) < 450:
        pytest.skip("paper_atlas store not populated")
    atlas = penalty_atlas(recs)
    assert len(atlas) == 18      # 3 models x 3 hw x 2 quants
    for row in atlas:
        assert len(row["lams"]) == 25
        assert row["lams"] == sorted(row["lams"])
        # the load-driven spread lands in the paper's band on every curve
        assert 5.0 < row["spread"] < 100.0, (row["model"], row["hw"])
        # the knee exists inside the swept range and is past the idle edge
        assert row["lams"][0] < row["knee_lambda"] <= row["lams"][-1]
        # half-cost load is at or before the knee (util rises monotonically
        # in lambda on the sim tier)
        assert row["half_cost_lambda"] <= row["knee_lambda"]
        # the curve's penalty floor is ~1 at saturation
        assert min(row["penalty"]) == pytest.approx(1.0, abs=1e-6)
    # the atlas is part of the committed analysis payload
    import json as _json
    path = DEFAULT_ROOT / "paper_atlas" / "analysis.json"
    if path.exists():
        blob = _json.loads(path.read_text())
        fresh = _json.loads(_json.dumps(crosshw_tables(recs)))
        assert blob == fresh


def test_committed_int8_probe_store():
    recs = load_store_records("probe_int8_nonnative")
    if len(recs) < 126:
        pytest.skip("probe_int8_nonnative store not populated")
    rows = {(r["hw"], r["model"]): r
            for r in fp8_uplift(recs, variant="int8")}
    # int8 rides the native MXU path on the emulating parts: the
    # memory-bound MoEs must gain; rows exist only where int8 ran
    assert {hw for hw, _ in rows} == {"tpu-v5e", "tpu-v5p"}
    for hw in ("tpu-v5e", "tpu-v5p"):
        assert rows[(hw, "qwen3-30b-a3b")]["tps_uplift"] > 1.0
        assert rows[(hw, "mixtral-8x7b")]["tps_uplift"] > 1.0
    # fp8 rows exist only on the native part
    fp8 = {(r["hw"], r["model"]) for r in fp8_uplift(recs)}
    assert {hw for hw, _ in fp8} == {"tpu-v6e"}
    # report renders the int8 section for this store
    assert "INT8 uplift" in report(recs, title="probe_int8_nonnative")


def test_penalty_atlas_skips_sparse_stores():
    recs = load_store_records("paper_crosshw")
    if len(recs) < 126:
        pytest.skip("paper_crosshw store not populated")
    assert penalty_atlas(recs) == []     # 7-point ladders are not dense


# ---- the committed paper_crosshw store --------------------------------


def _store_records():
    recs = load_store_records("paper_crosshw")
    if len(recs) < 126:
        pytest.skip("paper_crosshw store not populated")
    return recs


def test_committed_store_spread_band_and_fp8_inversion():
    """Acceptance (ISSUE 3): the sim-tier load-driven spread lands in the
    paper's plausible band (>5x) on EVERY hardware generation, and the
    dense-FP8 inversion reproduces on the non-native-fp8 parts only."""
    recs = _store_records()
    for row in penalty_curves(recs):
        assert 5.0 < row["spread"] < 100.0, \
            (row["model"], row["hw"], row["quant"], row["spread"])
    inv = {(r["hw"], r["model"]): r for r in fp8_inversion(recs)}
    # compute-bound dense model: fp8 pays the dequant penalty on the
    # emulating parts (paper's hardware-conditional caveat) ...
    assert inv[("tpu-v5e", "llama31-8b")]["inverted"]
    assert inv[("tpu-v5p", "llama31-8b")]["inverted"]
    # ... and gains on the native-fp8 part
    assert not inv[("tpu-v6e", "llama31-8b")]["inverted"]
    assert inv[("tpu-v6e", "llama31-8b")]["tps_uplift"] > 1.0
    # the memory-bound ultra-sparse MoE keeps its HBM win everywhere
    for hw in ("tpu-v5e", "tpu-v5p", "tpu-v6e"):
        assert not inv[(hw, "qwen3-30b-a3b")]["inverted"]
    # no row may break the native-fp8 conditioning
    assert all(r["consistent"] for r in inv.values())


def test_committed_store_spread_compression_table():
    recs = _store_records()
    table = spread_compression(recs)
    assert len(table) == 6                      # 3 models x 2 quants
    for row in table:
        hws = [h["hw"] for h in row["per_hw"]]
        assert hws == sorted(hws) and len(hws) == 3
        assert row["compression"] >= 1.0
        assert row["widest_hw"] in hws and row["narrowest_hw"] in hws
        for h in row["per_hw"]:
            assert 0 < h["c_min"] < h["c_max"]
    # the report renders the cross-hardware sections for a multi-hw store
    text = report(recs, title="paper_crosshw")
    assert "spread compression" in text
    assert "conditioned on native fp8" in text


def test_committed_analysis_json_matches_fresh_derivation():
    """`--analyze-json` artifact is a pure function of the store."""
    recs = _store_records()
    path = DEFAULT_ROOT / "paper_crosshw" / "analysis.json"
    if not path.exists():
        pytest.skip("analysis.json not committed")
    blob = json.loads(path.read_text())
    fresh = json.loads(json.dumps(crosshw_tables(recs)))
    assert blob == fresh


# ---- live replication (non-blocking CI job) ---------------------------


@pytest.mark.slow
def test_live_crosshw_matrix_reproduces_spread_band(tmp_path):
    """The full cross-hardware analysis on a live quick-protocol run —
    no committed artifacts involved: idle-to-saturation spread >5x on
    both generations and the fp8 inversion conditioned on native fp8."""
    plan = GridSpec(
        name="live_crosshw",
        archs=("llama31-8b", "qwen3-30b-a3b"),
        hws=("tpu-v5e", "tpu-v6e"),
        quants=("bf16", "fp8"),
        ladder=(1, 25, 200),
        n_chips_by_arch_hw=(("qwen3-30b-a3b", "tpu-v5e", 2),),
        protocol="quick").expand()
    recs = PlanRunner(plan, store=ExperimentStore(plan.name, tmp_path)).run()
    assert len(recs) == len(plan.cells)
    for row in penalty_curves(recs):
        assert row["spread"] > 5.0, (row["model"], row["hw"], row["quant"])
    inv = {(r["hw"], r["model"]): r for r in fp8_inversion(recs)}
    assert inv[("tpu-v5e", "llama31-8b")]["inverted"]
    assert not inv[("tpu-v6e", "llama31-8b")]["inverted"]
    assert all(r["consistent"] for r in inv.values())


# ---- the committed paper_ensemble store (ISSUE 7) ---------------------


def test_committed_ensemble_store_confidence_bands():
    """Acceptance: the committed `paper_ensemble` store carries finite
    central-95% bands on every penalty/C_eff curve — every atlas group
    at N=16 arrival seeds — and they are threaded into the planner's
    fitted curves."""
    from repro.experiments.analyze import ensemble_bands
    from repro.planner.curves import fit_curves
    recs = load_store_records("paper_ensemble")
    if len(recs) < 2016:
        pytest.skip("paper_ensemble store not populated")
    bands = ensemble_bands(recs)
    assert len(bands) == 18              # 3 models x 3 hw x 2 quants
    import math
    for row in bands:
        assert row["n_seeds"] == 16
        assert len(row["lams"]) == 7
        for metric in ("c_eff", "penalty", "util"):
            for lo, mean, hi in zip(row[metric]["lo"], row[metric]["mean"],
                                    row[metric]["hi"]):
                assert math.isfinite(lo) and math.isfinite(hi)
                assert 0 < lo <= mean <= hi
        # n=16 must actually tighten the claim: the widest C_eff CI
        # half-width stays under 25% of the mean on every curve
        assert 0 <= row["max_rel_halfwidth_c_eff"] < 0.25
    # the bands ride the planner's fitted curves from the same store
    curves = fit_curves(recs)
    assert len(curves) == 18
    for c in curves:
        assert set(c.bands) == {"c_eff", "util", "tps"}
        lo, hi = c.band("c_eff", c.lam_min)
        assert 0 < lo <= hi
        # the band brackets the aggregated knot the planner interpolates
        assert lo <= c.c_eff(c.lam_min) <= hi


def test_committed_ensemble_analysis_json_matches_fresh_derivation():
    recs = load_store_records("paper_ensemble")
    if len(recs) < 2016:
        pytest.skip("paper_ensemble store not populated")
    path = DEFAULT_ROOT / "paper_ensemble" / "analysis.json"
    if not path.exists():
        pytest.skip("analysis.json not committed")
    blob = json.loads(path.read_text())
    fresh = json.loads(json.dumps(crosshw_tables(recs)))
    assert blob == fresh
