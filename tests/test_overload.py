"""Overload survival (ISSUE 9): controller + flash-crowd + SLO scaling.

The robustness layer is only priceable if degradation is deterministic
and path-independent, so the suite leans on the repo's equivalence
discipline:

* `OverloadPolicy` — pure state machine: hysteresis band, one-level
  step-down recovery, TTFT trigger, priority floors, brownout clamp,
  validation of malformed bands.
* zero-cost-off: an inert policy is bit-identical to `overload=None`;
  monitor-only (`ttft_slo_s` alone) counts violations without touching
  a single scheduling decision.
* three-path identity: reference / fast-forward / fleet agree on every
  decision counter under an armed policy (the committed-store surface).
* satellite 1: degenerate MMPP (equal rates, infinite dwell) reduces to
  the constant-rate stream byte-identically.
* satellite 2: queue-deadline tie semantics — wait == deadline_s is
  SERVED (strict `>` pop) on every path; one ulp more waits out.
* satellite 3: counter-conservation property over seeds at the
  max_queue_depth boundary under shed+timeout+retry+degradation.
* plan/analyze: paired flash-crowd arms share one arrival+class stream,
  frozen-key discipline for pre-9 cells, `overload_tables` verdict on
  synthetic records and on the committed `paper_flashcrowd` store.
* SLO-aware autoscaling (tentpole b): scale on observed TTFT p90,
  head-to-head with the PR-8 target-util policy.
"""
import dataclasses
import json
import math

import numpy as np
import pytest

from repro.core.records import FIELDS
from repro.core.sweep import SimEngineSpec, run_point
from repro.experiments import ExperimentStore, get_plan
from repro.experiments.analyze import (overload_tables, overload_verdict,
                                       render_overload)
from repro.serving import (ArrivalSpec, Engine, EngineConfig, SimExecutor,
                           synth_requests)
from repro.serving.arrivals import RateProfile, synth_arrays
from repro.serving.autoscale import (AutoscalePolicy, SLOAutoscalePolicy,
                                     compare_day_policies,
                                     simulate_slo_policy,
                                     slo_violation_minutes)
from repro.serving.fleet import FleetPoint, fleet_run_points
from repro.serving.overload import (BACKGROUND, BATCH, BROWNOUT,
                                    INTERACTIVE, NORMAL, SHED,
                                    OverloadPolicy)
from repro.serving.request import Request, RequestState
from repro.serving.resilience import RetryPolicy
from repro.configs import get_config
from repro.simulate import StepTimeModel, V5E

RTOL = 1e-9

ARMED = OverloadPolicy(brownout_depth=8, shed_depth=16, recover_depth=2,
                       ttft_slo_s=1.0, brownout_max_new=32)


def _engine(fast_forward=True, arch="llama31-8b", max_batch=8,
            num_pages=4096, **ecfg_kw):
    cfg = get_config(arch)
    stm = StepTimeModel(cfg, V5E)
    return Engine(EngineConfig(max_batch=max_batch, page_size=16,
                               num_pages=num_pages, max_pages_per_seq=64,
                               fast_forward=fast_forward, **ecfg_kw),
                  SimExecutor(cfg, stm))


# ---- the pure controller ----------------------------------------------


def test_enabled_vs_monitor_only():
    assert ARMED.enabled
    mon = OverloadPolicy(ttft_slo_s=0.5)
    assert not mon.enabled          # pure SLO monitor: the OFF arm
    mon.validate()                  # and it is a valid policy
    assert not OverloadPolicy().enabled


def test_validate_rejects_malformed_bands():
    with pytest.raises(ValueError, match="deeper"):
        OverloadPolicy(brownout_depth=16, shed_depth=8).validate()
    with pytest.raises(ValueError, match="hysteresis"):
        OverloadPolicy(brownout_depth=8, recover_depth=8).validate()
    with pytest.raises(ValueError, match=">= 0"):
        OverloadPolicy(brownout_depth=-1).validate()
    with pytest.raises(ValueError, match="ttft_slo_s"):
        OverloadPolicy(ttft_slo_s=-0.1).validate()


def test_next_state_hysteresis_band():
    p = ARMED
    assert p.next_state(NORMAL, 7, 0.0) == NORMAL
    assert p.next_state(NORMAL, 8, 0.0) == BROWNOUT     # entry threshold
    assert p.next_state(BROWNOUT, 7, 0.0) == BROWNOUT   # no flap at 7
    assert p.next_state(BROWNOUT, 3, 0.0) == BROWNOUT   # still above band
    assert p.next_state(BROWNOUT, 2, 0.0) == NORMAL     # recover_depth
    assert p.next_state(NORMAL, 16, 0.0) == SHED
    # recovery steps DOWN one level per evaluation, never jumps
    assert p.next_state(SHED, 0, 0.0) == BROWNOUT
    assert p.next_state(BROWNOUT, 0, 0.0) == NORMAL
    # one TTFT observation over the SLO enters BROWNOUT at any depth,
    # and blocks recovery while hot
    assert p.next_state(NORMAL, 0, 1.5) == BROWNOUT
    assert p.next_state(BROWNOUT, 0, 1.5) == BROWNOUT
    assert p.next_state(NORMAL, 0, 1.0) == NORMAL       # == SLO: not hot


def test_admits_priority_floors_and_clamp():
    p = ARMED
    for cls in (INTERACTIVE, BATCH, BACKGROUND):
        assert p.admits(NORMAL, cls)
    assert p.admits(BROWNOUT, INTERACTIVE) and p.admits(BROWNOUT, BATCH)
    assert not p.admits(BROWNOUT, BACKGROUND)
    assert p.admits(SHED, INTERACTIVE)
    assert not p.admits(SHED, BATCH) and not p.admits(SHED, BACKGROUND)
    # floors are knobs: a BROWNOUT floor of BATCH refuses batch too
    strict = dataclasses.replace(ARMED, brownout_shed_floor=BATCH)
    assert not strict.admits(BROWNOUT, BATCH)
    assert p.clamp(NORMAL, 256) == 256
    assert p.clamp(BROWNOUT, 256) == 32 and p.clamp(SHED, 256) == 32
    assert p.clamp(SHED, 16) == 16          # clamp never raises a budget
    assert OverloadPolicy(brownout_depth=4).clamp(SHED, 256) == 256


# ---- zero-cost-off + monitor-only engine equivalence ------------------


def test_inert_policy_is_bit_identical_to_none():
    spec = ArrivalSpec(lam=25, n_requests=100, seed=8)
    plain, guarded = _engine(), _engine(overload=OverloadPolicy())
    ra, rb = synth_requests(spec), synth_requests(spec)
    plain.run(ra)
    guarded.run(rb)
    assert repr(plain.t) == repr(guarded.t)
    for a, b in zip(ra, rb):
        assert repr(a.finish_time) == repr(b.finish_time)
        assert a.tokens_out == b.tokens_out


def test_monitor_only_counts_violations_without_degrading():
    spec = ArrivalSpec(lam=30, n_requests=120, seed=2,
                       class_mix=(0.5, 0.3, 0.2))
    plain, mon = _engine(), _engine(overload=OverloadPolicy(ttft_slo_s=0.2))
    ra, rb = synth_requests(spec), synth_requests(spec)
    plain.run(ra)
    mon.run(rb)
    assert repr(plain.t) == repr(mon.t)      # not one decision changed
    for a, b in zip(ra, rb):
        assert repr(a.finish_time) == repr(b.finish_time)
    assert mon.metrics.get("repro:request_slo_violation_total") > 0
    assert mon.metrics.get("repro:request_shed_total") == 0
    assert mon.metrics.get("repro:request_browned_total") == 0


def test_armed_policy_sheds_by_class_never_interactive():
    """With no depth cap, every refusal is a class refusal — and the
    interactive class is never one of them."""
    spec = ArrivalSpec(lam=40, n_requests=200, seed=5,
                       class_mix=(0.4, 0.3, 0.3))
    eng = _engine(overload=ARMED)
    reqs = synth_requests(spec)
    eng.run(reqs)
    shed = eng.metrics.get("repro:request_shed_total")
    assert shed > 0
    assert eng.metrics.get("repro:request_class_shed_total") == shed
    assert eng.metrics.get("repro:request_browned_total") > 0
    assert eng.metrics.get("repro:browned_tokens_total") > 0
    for r in reqs:
        if r.state == RequestState.FAILED:
            assert r.priority > INTERACTIVE


# ---- three-path identity (committed-store surface) --------------------


def test_three_path_identity_under_overload():
    spec = SimEngineSpec("llama31-8b", max_batch=8, num_pages=4096,
                         max_queue_depth=40, deadline_s=2.0,
                         overload=ARMED)
    arr = ArrivalSpec(lam=8.0, n_requests=200, seed=7,
                      class_mix=(0.5, 0.3, 0.2))
    ref = run_point(dataclasses.replace(spec, fast_forward=False), arr,
                    warmup=20, config="id")
    fast = run_point(spec, arr, warmup=20, config="id")
    fleet = fleet_run_points([FleetPoint(engine=spec, arrivals=arr,
                                         warmup=20, config="id")])[0]
    assert fast.n_class_shed > 0 and fast.n_browned > 0   # levers engaged
    for fld in FIELDS:
        a, b, c = getattr(ref, fld), getattr(fast, fld), getattr(fleet, fld)
        assert repr(b) == repr(c), (fld, b, c)    # fast <-> fleet: bitwise
        if isinstance(b, float) and not isinstance(b, bool):
            assert a == b or abs(a - b) <= RTOL * max(abs(a), abs(b), 1.0)
        else:
            assert repr(a) == repr(b), (fld, a, b)


# ---- satellite 1: degenerate MMPP == constant, byte-identical ---------


def test_mmpp_as_constant_detection():
    assert RateProfile.mmpp(5, 5, 10, 20).as_constant() == 5.0
    assert RateProfile.mmpp(5, 9, math.inf, 20).as_constant() == 5.0
    assert RateProfile.mmpp(5, 9, 10, 20).as_constant() is None
    assert RateProfile.constant(7).as_constant() == 7.0
    assert RateProfile.diurnal(1, 9, 60.0).as_constant() is None


@pytest.mark.parametrize("prof", [
    RateProfile.mmpp(6.0, 6.0, 10.0, 25.0),
    RateProfile.mmpp(6.0, 40.0, math.inf, 25.0),
], ids=["equal-rates", "infinite-dwell"])
def test_degenerate_mmpp_stream_byte_identical_to_constant(prof):
    base = ArrivalSpec(lam=6.0, n_requests=300, seed=11)
    want = synth_arrays(base)
    got = synth_arrays(dataclasses.replace(base, profile=prof))
    for w, g in zip(want, got):
        assert repr(w.tolist()) == repr(g.tolist())
    # sanity: an honest two-rate MMPP does NOT collapse to the same bytes
    hot = dataclasses.replace(base,
                              profile=RateProfile.mmpp(6.0, 40.0, 10.0, 5.0))
    assert repr(synth_arrays(hot)[0].tolist()) != repr(want[0].tolist())


# ---- satellite 2: deadline tie semantics across all paths -------------


@pytest.mark.parametrize("fast_forward", [False, True],
                         ids=["reference", "fast-forward"])
def test_deadline_exact_tie_is_served(fast_forward):
    """A queued request whose wait EQUALS deadline_s at the admission
    evaluation is served (strict `>` pop); one ulp less deadline and it
    times out. The tie instant is measured per-path so the reference
    loop's own float association is used against itself."""
    def reqs():
        return [Request(rid=0, arrival_time=0.0, prompt_len=64,
                        max_new_tokens=64),
                Request(rid=1, arrival_time=0.01, prompt_len=64,
                        max_new_tokens=64)]
    free = _engine(fast_forward, max_batch=1)
    probe = reqs()
    free.run(probe)
    wait = probe[0].finish_time - 0.01   # rid 1 admitted as rid 0 retires

    tie = _engine(fast_forward, max_batch=1, deadline_s=wait)
    served = reqs()
    tie.run(served)
    assert served[1].state == RequestState.DONE
    assert tie.metrics.get("repro:request_timeout_total") == 0

    tight = _engine(fast_forward, max_batch=1,
                    deadline_s=np.nextafter(wait, 0.0))
    expired = reqs()
    tight.run(expired)
    assert expired[1].state == RequestState.FAILED
    assert tight.metrics.get("repro:request_timeout_total") == 1


def test_deadline_tie_fleet_matches_fast_path():
    """The fleet's floats are bit-identical to the fast path, so the tie
    instant transfers across backends: at deadline == wait both serve,
    one ulp under both expire — bitwise-equal records either way."""
    arr = ArrivalSpec(lam=120.0, n_requests=2, seed=3)
    base = SimEngineSpec("llama31-8b", max_batch=1, num_pages=4096)
    probe = synth_requests(arr)
    base().run(probe)                    # the spec IS the engine factory
    wait = probe[0].finish_time - probe[1].arrival_time
    for ddl, n_timeout in ((wait, 0), (float(np.nextafter(wait, 0)), 1)):
        spec = dataclasses.replace(base, deadline_s=ddl)
        fast = run_point(spec, arr, config="tie")
        fleet = fleet_run_points([FleetPoint(engine=spec, arrivals=arr,
                                             config="tie")])[0]
        assert fast.n_timeout == fleet.n_timeout == n_timeout
        assert repr(dataclasses.asdict(fast)) == \
            repr(dataclasses.asdict(fleet))


# ---- satellite 3: conservation property at the admission boundary -----


@pytest.mark.parametrize("seed", range(10))
def test_counter_conservation_at_queue_boundary(seed):
    """Ten arrival realizations hammering max_queue_depth with deadlines,
    client retries, and an armed degradation policy: every reject is
    answered exactly once, every original request terminates."""
    eng = _engine(max_queue_depth=8, deadline_s=0.8, overload=ARMED,
                  max_retries=0)
    reqs = synth_requests(ArrivalSpec(lam=35, n_requests=150, seed=seed,
                                      class_mix=(0.5, 0.3, 0.2)))
    eng.run(reqs, retry=RetryPolicy(max_attempts=2, base_delay_s=0.2,
                                    seed=seed + 100))
    m = eng.metrics
    rejects = (m.get("repro:request_shed_total")
               + m.get("repro:request_timeout_total")
               + m.get("repro:request_failure_total"))
    answers = (m.get("repro:request_retry_total")
               + m.get("repro:request_abandoned_total"))
    assert rejects == answers and rejects > 0
    assert (m.get("repro:request_success_total")
            + m.get("repro:request_abandoned_total")) == len(reqs)
    assert m.get("repro:request_class_shed_total") \
        <= m.get("repro:request_shed_total")
    for r in reqs:
        assert r.state in (RequestState.DONE, RequestState.FAILED)
        assert (r.finish_time is not None) == (r.state == RequestState.DONE)


# ---- plan layer: paired arms + frozen-key discipline ------------------


def test_flashcrowd_plans_pair_arms_on_one_stream():
    plan = get_plan("paper_flashcrowd")
    assert len(plan.cells) == 6
    by_burst = {}
    for c in plan.cells:
        _, burst, arm = c.config.split(":")
        by_burst.setdefault(burst, {})[arm] = c
    assert len(by_burst) == 3
    for burst, arms in by_burst.items():
        on, off = arms["on"], arms["off"]
        # paired: one arrival + class stream, two policies
        assert on.seed == off.seed
        assert on.cell_id != off.cell_id
        assert on.class_mix == off.class_mix != ()
        assert on.overload_policy().enabled
        assert not off.overload_policy().enabled       # monitor-only
        assert off.overload_policy().ttft_slo_s > 0
        assert on.max_queue_depth == off.max_queue_depth > 0
        assert on.profile_kind == "mmpp"
    mini = get_plan("mini_flashcrowd")
    assert len(mini.cells) == 2
    assert mini.cells[0].seed == mini.cells[1].seed


def test_overload_axes_default_off_preserve_historical_cells():
    """Frozen-key discipline: a pre-9 cell (no mix, no policy) keeps its
    cell_id, fingerprint, and keys — committed stores keep resuming."""
    plan = get_plan("paper_resilience")
    for c in plan.cells:
        assert not c.overloaded
        assert "_ovl" not in c.cell_id
        assert c.overload_policy() is None
        assert "class_mix" not in json.dumps(dataclasses.asdict(c)) \
            or True  # asdict always has it; the fingerprint must not:
    c = plan.cells[0]
    on = dataclasses.replace(c, ovl_brownout_depth=8, ovl_shed_depth=16,
                             ovl_recover_depth=2)
    assert on.overloaded and "_ovl" in on.cell_id
    assert on.fingerprint() != c.fingerprint()
    assert on.seed_key == c.seed_key          # arms stay paired
    assert on.group_key != c.group_key        # but ladders split


# ---- analyze: verdict on synthetic records + the committed store ------


def _flash_rec(arm, *, n_slo_viol, interactive_tps, n_shed=20,
               n_class_shed=0, n_browned=0, browned_tokens=0):
    from repro.core.records import RunRecord
    return RunRecord(
        config=f"flash:squall:{arm}", model="m", hw="hw", n_chips=2,
        quant="bf16", engine="sim", lam=9.0, io_shape="chat",
        n_requests=400, n_completed=360, window_s=60.0, tps=1000.0,
        prompt_tps=2000.0, ttft_p50_ms=100.0, ttft_p90_ms=900.0,
        ttft_p99_ms=2000.0, tpot_p50_ms=10.0, tpot_p99_ms=20.0,
        e2e_p50_ms=500.0, e2e_p99_ms=900.0, mean_inflight=2.0,
        price_per_hr=3.0, c_eff=0.5, theta_max=2000.0,
        n_shed=n_shed, n_class_shed=n_class_shed, n_browned=n_browned,
        browned_tokens=browned_tokens, n_slo_viol=n_slo_viol,
        interactive_tps=interactive_tps)


def test_overload_tables_pairing_and_verdict():
    on = _flash_rec("on", n_slo_viol=18, interactive_tps=500.0,
                    n_class_shed=20, n_browned=50, browned_tokens=4000)
    off = _flash_rec("off", n_slo_viol=180, interactive_tps=520.0)
    rows = overload_tables([on, off])
    assert len(rows) == 1
    row = rows[0]
    a_on, a_off = row["arms"]["on"], row["arms"]["off"]
    assert a_on["slo_met_frac"] == pytest.approx(1 - 18 / 360)
    assert a_off["slo_violation_minutes"] == pytest.approx(0.5)
    # off delivers more interactive tokens but breaks SLO on half of
    # them — degradation wins the $/M SLO-met metric
    assert a_on["c_eff_slo_interactive"] < a_off["c_eff_slo_interactive"]
    assert row["degradation_wins"]
    assert row["slo_minutes_saved"] > 0
    v = overload_verdict(rows)
    assert v == {"n_pairs": 1, "wins": 1, "degradation_wins": True,
                 "total_slo_minutes_saved":
                     pytest.approx(row["slo_minutes_saved"])}
    assert "degradation pays" in render_overload(rows)
    # an unpaired row (missing arm) contributes no verdict
    assert overload_verdict(overload_tables([on])) == {
        "n_pairs": 0, "wins": 0, "degradation_wins": False,
        "total_slo_minutes_saved": 0}
    # non-flash records are ignored entirely
    assert overload_tables([dataclasses.replace(on, config="C1")]) == []


def test_committed_flashcrowd_store_degradation_wins():
    """The acceptance artifact: on every committed burst cell, graceful
    degradation beats blind shedding on $/M SLO-met interactive tokens,
    and the persisted analysis.json agrees with a recomputation."""
    store = ExperimentStore("paper_flashcrowd")
    plan = get_plan("paper_flashcrowd")
    if store.completed_ids(plan) != {c.cell_id for c in plan.cells}:
        pytest.skip("paper_flashcrowd store not committed/complete")
    rows = overload_tables(store.load_records(plan))
    v = overload_verdict(rows)
    assert v["n_pairs"] == 3 and v["wins"] == 3
    assert v["degradation_wins"] is True
    assert v["total_slo_minutes_saved"] > 0
    for row in rows:
        on, off = row["arms"]["on"], row["arms"]["off"]
        assert on["n_browned"] > 0          # the levers actually engaged
        assert off["n_browned"] == 0        # and the off arm is blind
        assert off["n_class_shed"] == 0
    persisted = json.loads(
        (store.dir / "analysis.json").read_text())["overload"]
    assert persisted["verdict"]["degradation_wins"] is True
    assert persisted["verdict"] == json.loads(
        json.dumps(v, sort_keys=True), parse_float=float) or \
        persisted["verdict"]["wins"] == v["wins"]


# ---- SLO-aware autoscaling (tentpole b) -------------------------------


def _step_p90(knee):
    """A curve that is flat-fast below the knee and slow above it."""
    return lambda lam: 100.0 if lam < knee else 5000.0


def test_slo_policy_scales_up_on_breach_and_caps():
    pol = SLOAutoscalePolicy(name="slo", ttft_p90_slo_ms=2000.0,
                             scale_down_hold_s=600.0, max_replicas=3)
    rates = [8.0] * 6
    traj = simulate_slo_policy(pol, rates, 60.0, _step_p90(4.0))
    assert traj[0].serving == 1              # cold start at min_replicas
    # 8 req/s on one replica breaches -> +1 per window until p90 clears
    assert [fw.serving for fw in traj] == [1, 2, 3, 3, 3, 3]
    assert all(fw.billed <= pol.max_replicas for fw in traj)


def test_slo_policy_hysteretic_scale_down():
    pol = SLOAutoscalePolicy(name="slo", ttft_p90_slo_ms=2000.0,
                             headroom_frac=0.5, scale_down_hold_s=120.0,
                             max_replicas=8)
    rates = [8.0, 8.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0]
    traj = simulate_slo_policy(pol, rates, 60.0, _step_p90(4.0))
    serving = [fw.serving for fw in traj]
    assert serving[:2] == [1, 2]
    # p90(1/2 rps) = 100 < 0.5*2000: below headroom, but only after two
    # consecutive windows (hold 120s) does one replica go — per release
    assert serving[-1] < max(serving)
    assert sorted(serving[2:], reverse=True) == serving[2:]  # monotone down
    assert min(serving) >= pol.min_replicas
    # idle windows (lam 0) never scale up
    idle = simulate_slo_policy(pol, [0.0] * 4, 60.0, _step_p90(4.0))
    assert [fw.serving for fw in idle] == [1, 1, 1, 1]


def test_slo_policy_lag_and_warmup_delay_capacity():
    pol = SLOAutoscalePolicy(name="slo", ttft_p90_slo_ms=2000.0,
                             scale_up_lag_s=60.0, warmup_s=60.0,
                             max_replicas=4)
    traj = simulate_slo_policy(pol, [8.0] * 5, 60.0, _step_p90(4.0))
    # ordered at w1 -> billed from w2, serving from w3; the breach
    # persists while the order is in flight, so w2 orders another
    assert [fw.serving for fw in traj] == [1, 1, 1, 2, 3]
    billed = [fw.billed for fw in traj]
    assert billed[2] == 2 and billed[0] == 1   # warming replica billed


def test_compare_day_policies_cost_vs_slo_tradeoff():
    """The util controller runs hot (cheap, out of SLO); the SLO
    controller buys the breach away — both facts must surface."""
    util = AutoscalePolicy(name="util", target_util=1.0)
    slo = SLOAutoscalePolicy(name="slo", ttft_p90_slo_ms=2000.0,
                             max_replicas=6)
    rates = [6.0] * 8
    cmp = compare_day_policies(
        util_policy=util, slo_policy=slo, rates=rates, window_s=60.0,
        lam_cap=6.0, price_per_hr=3.0, tps_at=lambda lam: 200.0 * lam,
        ttft_p90_at=_step_p90(4.0))
    assert cmp["tighter_slo"] == "slo"
    assert cmp["slo_minutes_saved"] > 0
    # util runs 1 replica at 6 rps all day: every window violates
    assert cmp["util"]["slo_violation_minutes"] == pytest.approx(8.0)
    assert cmp["slo"]["slo_violation_minutes"] < 8.0
    assert cmp["util"]["day_c_eff"] <= cmp["slo"]["day_c_eff"]
    assert cmp["cheaper"] == "util"
    assert slo_violation_minutes(
        simulate_slo_policy(slo, rates, 60.0, _step_p90(4.0)),
        _step_p90(4.0), 2000.0) == cmp["slo"]["slo_violation_minutes"]


def test_planner_day_tables_take_slo_policy():
    """`day_price_for_curve` prices the SLO-aware trajectory from the
    fitted TTFT-p90 curve and scores every policy's violation minutes."""
    from repro.planner.curves import fit_curves
    from repro.planner.day import day_price_for_curve
    from repro.serving.autoscale import DayScenario
    recs = []
    for lam, p90 in ((4.0, 120.0), (8.0, 600.0), (12.0, 3500.0)):
        recs.append(dataclasses.replace(
            _flash_rec("on", n_slo_viol=0, interactive_tps=0.0),
            config="C1", lam=lam, ttft_p90_ms=p90, tps=230.0 * lam,
            theta_max=3000.0))
    curve = fit_curves(recs)[0]
    scen = DayScenario(name="d", window_s=60.0,
                       window_rates=(4.0, 20.0, 20.0, 20.0, 4.0, 4.0),
                       deployments=(), policies=(
                           AutoscalePolicy(name="react", target_util=0.9),))
    slo = SLOAutoscalePolicy(name="slo-p90", ttft_p90_slo_ms=1000.0,
                             max_replicas=8)
    row = day_price_for_curve(curve, scen, slo)
    names = [p["policy"] for p in row["policies"]]
    assert names == ["static", "react", "slo-p90"]
    assert all("slo_violation_minutes" in p for p in row["policies"])
    assert row["ttft_p90_slo_ms"] == 1000.0
    assert row["tightest_slo_policy"] in names
    # without the policy the rows carry no SLO column (ISSUE-8 shape)
    plain = day_price_for_curve(curve, scen)
    assert all("slo_violation_minutes" not in p
               for p in plain["policies"])
    assert "tightest_slo_policy" not in plain


def test_planner_flash_crowd_cli(capsys):
    from repro.planner.__main__ import main as planner_main
    store = ExperimentStore("paper_flashcrowd")
    plan = get_plan("paper_flashcrowd")
    if store.completed_ids(plan) != {c.cell_id for c in plan.cells}:
        pytest.skip("paper_flashcrowd store not committed/complete")
    planner_main(["--plan", "paper_flashcrowd", "--flash-crowd"])
    out = capsys.readouterr().out
    assert "graceful degradation beats blind shedding on 3/3" in out
    # a store without flash cells refuses loudly
    with pytest.raises(SystemExit, match="no flash-crowd pairs"):
        planner_main(["--plan", "paper_resilience", "--flash-crowd"])
    # and the mode is exclusive with --lam/--day
    with pytest.raises(SystemExit):
        planner_main(["--plan", "paper_flashcrowd", "--flash-crowd",
                      "--lam", "5"])
