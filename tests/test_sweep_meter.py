"""Lambda-sweep protocol + live meter: the paper's claims reproduce in-sim."""
import math

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (CostMeter, LAMBDA_LADDER, lambda_sweep,
                        slo_operating_point, stability_table)
from repro.core.sweep import run_point
from repro.serving import (ArrivalSpec, Engine, EngineConfig, SimExecutor,
                           synth_requests)
from repro.simulate import StepTimeModel, V5E, V5P


def _factory(arch="llama31-8b", hw=V5E, quant="bf16", n_chips=1,
             max_batch=128):
    cfg = get_config(arch)

    def make():
        stm = StepTimeModel(cfg, hw, n_chips=n_chips, quant=quant)
        return Engine(EngineConfig(max_batch=max_batch, page_size=16,
                                   num_pages=32768, max_pages_per_seq=64),
                      SimExecutor(cfg, stm))
    return make


def _sweep(arch="llama31-8b", hw=V5E, quant="bf16", ladder=(1, 5, 25, 100),
           price=1.20, n_chips=1):
    return lambda_sweep(
        _factory(arch, hw, quant, n_chips), ladder=ladder,
        requests_per_point=lambda lam: int(min(600, max(120, 20 * lam))),
        warmup_per_point=lambda lam: 0,
        config=arch, model=arch, hw=hw.name, price_per_hr=price * n_chips,
        n_chips=n_chips, quant=quant, engine_kind="sim")


def test_cost_cliff_shape():
    """Paper Fig.1: C_eff falls steeply then flattens; penalty collapses
    toward 1 at saturation."""
    recs = _sweep()
    ceffs = [r.c_eff for r in recs]
    assert ceffs[0] > 3 * ceffs[-1]              # the cliff
    assert recs[0].penalty > 3.0                 # idle penalty
    assert abs(recs[-1].penalty - 1.0) < 0.25    # saturation -> ~1x
    # monotone non-increasing cost along the ladder
    for a, b in zip(ceffs, ceffs[1:]):
        assert b <= a * 1.05


def test_penalty_equals_one_over_u():
    recs = _sweep()
    for r in recs:
        assert math.isclose(r.penalty, 1.0 / r.util, rel_tol=1e-9)


def test_cross_hardware_spread_compression():
    """Paper §5.9: the cheaper/slower part shows a NARROWER idle-to-sat
    spread. v5e (cheap, slow) vs v5p (fast, pricey)."""
    spread = {}
    for hw, price in ((V5E, 1.20), (V5P, 4.20)):
        recs = _sweep(hw=hw, price=price)
        spread[hw.name] = max(r.c_eff for r in recs) / \
            min(r.c_eff for r in recs)
    assert spread["tpu-v5p"] > spread["tpu-v5e"], spread
    # both still show the order-of-magnitude-class cliff
    assert spread["tpu-v5e"] > 3


def test_moe_fp8_asymmetry():
    """Paper §5.3 TPU analogue: the int8/fp8-style weight-halving helps the
    memory-bound MoE (qwen3-30b-a3b) more than the dense 8B."""
    gain = {}
    for arch in ("llama31-8b", "qwen3-30b-a3b"):
        sat = {}
        for quant in ("bf16", "int8"):
            recs = _sweep(arch=arch, quant=quant, ladder=(25, 100))
            sat[quant] = max(r.tps for r in recs)
        gain[arch] = sat["int8"] / sat["bf16"]
    assert gain["qwen3-30b-a3b"] > gain["llama31-8b"], gain


def test_slo_point_and_premium():
    recs = _sweep(ladder=(1, 5, 10, 25, 50, 100))
    res = slo_operating_point(recs, ttft_p99_ms=1000.0, tpot_p99_ms=120.0)
    assert res.premium >= 1.0
    if res.lam_max is not None:
        assert res.c_at_sla >= res.c_sat


def test_meter_agrees_with_engine_ground_truth():
    """§6.7 'validation of agreement': the Prometheus-scraping meter must
    reproduce the engine's own windowed cost within float noise."""
    cfg = get_config("llama31-8b")
    stm = StepTimeModel(cfg, V5E)
    eng = Engine(EngineConfig(max_batch=128, page_size=16, num_pages=32768,
                              max_pages_per_seq=64), SimExecutor(cfg, stm))
    meter = CostMeter(1.20, scrape=lambda: eng.metrics.render())
    from repro.serving import synth_requests
    reqs = synth_requests(ArrivalSpec(lam=10, n_requests=150, seed=0))
    meter.tick()
    horizon = 0.0
    while any(r.finish_time is None for r in reqs):
        horizon += 5.0
        eng.run(reqs, horizon=horizon)
        meter.tick()
        if horizon > 3600:
            break
    total_tok = eng.metrics.get("repro:generation_tokens_total")
    metered_tok = sum(s.tokens for s in meter.samples)
    assert abs(metered_tok - total_tok) <= 1e-6
    summ = meter.summary()
    truth = 1.20 * 1e6 / (3600.0 * total_tok / eng.t)
    assert math.isclose(summ["time_weighted_avg"], truth, rel_tol=1e-6)
    assert summ["worst_minute"] >= summ["best_minute"]


def test_meter_conformance_with_run_record():
    """ISSUE 3 meter conformance: a CostMeter ticking against a sim-tier
    engine's Prometheus text (the virtual-clock path the meter docstring
    promises) must converge to the C_eff the sweep protocol records for
    the same (factory, arrival stream) point."""
    price = 1.20
    spec = ArrivalSpec(lam=10, n_requests=200, seed=3)
    rec = run_point(_factory(), spec, price_per_hr=price,
                    model="llama31-8b", hw="tpu-v5e")

    eng = _factory()()
    meter = CostMeter(price, scrape=lambda: eng.metrics.render(),
                      minute_s=5.0)
    reqs = synth_requests(spec)
    meter.tick()                        # baseline sample at t=0
    horizon = 0.0
    while any(r.finish_time is None for r in reqs):
        horizon += 2.0
        eng.run(reqs, horizon=horizon)
        meter.tick()
        assert horizon < 3600
    meter.tick()                        # drain the final window

    summ = meter.summary()
    # windowed meter vs protocol record: two readings of one ground truth
    assert math.isclose(summ["time_weighted_avg"], rec.c_eff, rel_tol=1e-6)
    # the meter's windows bracket the whole-run average
    assert summ["best_minute"] <= summ["time_weighted_avg"] * (1 + 1e-9)
    assert summ["worst_minute"] >= summ["time_weighted_avg"] * (1 - 1e-9)
    # and the metered token total equals the record's completed tokens
    metered = sum(s.tokens for s in meter.samples)
    assert metered == pytest.approx(rec.tps * rec.window_s, rel=1e-9)


def test_stability_cv_small_for_repeats():
    """§5.8: repeat runs with distinct seeds reproduce TPS/C_eff tightly."""
    runs = {}
    for lam in (5.0,):
        rs = []
        for seed in range(3):
            spec = ArrivalSpec(lam=lam, n_requests=150, seed=seed)
            rec = run_point(_factory(), spec, price_per_hr=1.20)
            rs.append(rec)
        runs[lam] = rs
    table = stability_table(runs)
    assert table[0]["c_eff_cv_pct"] < 5.0
